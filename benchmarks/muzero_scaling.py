"""Paper Fig. 4c — Sebulba/MuZero FPS as a function of device count.

The paper reports linear FPS scaling for search-based agents.  Points run
in subprocesses with N placeholder devices, each with a fixed 1:3
actor:learner core ratio; FPS trend across replicas is the reproduced
quantity.

Output: ``muzero_scale_<N>dev`` CSV lines; no BENCH json (paper-shape
check, not a regression trajectory).  Honest timing: FPS is whole-run
wall-clock over a fixed frame budget measured inside the subprocess, with
the first trajectory's compile cost amortized by the budget — comparisons
are valid across device counts because every point pays it identically.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import sys; sys.path.insert(0, {src!r})
    import jax
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.agents.muzero import MuZeroAgent, MuZeroConfig
    from repro.envs import HostPong, BatchedHostEnv
    from repro import optim

    agent = MuZeroAgent(HostPong.num_actions,
                        MuZeroConfig(num_simulations=8, max_depth=4,
                                     unroll_steps=3))
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        optimizer=optim.adam(1e-3, clip_norm=1.0), agent=agent,
        config=SebulbaConfig(num_actor_cores=max(1, {n} // 4),
                             threads_per_actor_core=2,
                             actor_batch_size=12, trajectory_length=12,
                             learner_microbatches=2),
    )
    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames={frames})
    print("RESULT", out["fps"])
    """
)


def measure(n_devices: int, frames: int = 3_000) -> float:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(n=n_devices, frames=frames,
                                              src=src)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            return float(line.split()[1])
    raise RuntimeError("no result line")


def main(device_counts=(4, 8)) -> list[str]:
    lines = []
    for n in device_counts:
        fps = measure(n)
        lines.append(f"muzero_scaling_d{n},{1e6 / fps:.3f},fps={fps:,.0f}")
        print(lines[-1], flush=True)
    return lines


if __name__ == "__main__":
    main()
