"""Aggregate dry-run JSONs into the EXPERIMENTS.md §Roofline table.

Reads ``experiments/dryrun/*.json`` artifacts (written by
``repro.launch.dryrun``) and prints the analytic roofline table — no
timing happens here at all, so the honest-timing rules are trivially met:
every number is a deterministic function of the arch configs.  No BENCH
json; ``benchmarks/run.py`` appends the table to full runs when dry-run
artifacts exist.
"""

from __future__ import annotations

import glob
import json
import os


def load(out_dir: str = "experiments/dryrun") -> list[dict]:
    rows = []
    for path in sorted(glob.glob(os.path.join(out_dir, "*.json"))):
        with open(path) as f:
            rows.append(json.load(f))
    return rows


def fmt_row(r: dict) -> str:
    if "skipped" in r:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | SKIP | — | — | — | — "
            f"| — | {r['skipped'].split(';')[0]} |"
        )
    if "error" in r:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | FAIL | — | — | — | — "
            f"| — | {r['error'][:60]} |"
        )
    ro = r.get("roofline")
    mem = r["memory"]
    if not ro:
        return (
            f"| {r['arch']} | {r['shape']} | {r['mesh']} | ok | — | — | — | — "
            f"| {mem['peak_gb']:.2f} | compile-only (multi-pod pass) |"
        )
    return (
        f"| {r['arch']} | {r['shape']} | {r['mesh']} "
        f"| {ro['dominant']} "
        f"| {ro['compute_s'] * 1e3:.2f} | {ro['memory_s'] * 1e3:.2f} "
        f"| {ro['collective_s'] * 1e3:.2f} "
        f"| {ro['useful_flops_ratio']:.2f} "
        f"| {mem['peak_gb']:.2f} | |"
    )


HEADER = (
    "| arch | shape | mesh | dominant | compute ms | memory ms "
    "| collective ms | 6ND/HLO | peak GB/dev | note |\n"
    "|---|---|---|---|---|---|---|---|---|---|"
)


def main(out_dir: str = "experiments/dryrun") -> str:
    rows = load(out_dir)
    # order: single-pod first, then multi-pod
    rows.sort(key=lambda r: (r.get("mesh", ""), r.get("arch", ""),
                             r.get("shape", "")))
    table = HEADER + "\n" + "\n".join(fmt_row(r) for r in rows)
    print(table)
    return table


if __name__ == "__main__":
    main()
