"""Benchmark harness — one section per paper table/figure.

Prints ``name,us_per_call,derived`` CSV lines.

Sections:
  * kernel micro-benches (the TPU-kernel oracle paths, timed on CPU)
  * Fig. 4a  — Anakin FPS vs device count   (anakin_scaling)
  * Fig. 4b  — Sebulba FPS vs actor batch   (sebulba_batch)
  * Fig. 4c  — MuZero FPS vs device count   (muzero_scaling)
  * §Anakin  — grid-world steps/sec single-device (the "5M steps/s on 8
    TPU cores" claim, CPU-scaled)
  * suites   — replay / sebulba (actor pipeline) / learner (donated
    update + publish throttling) / recurrent (R2D2 temporal core +
    burn-in), each writing its BENCH_*.json (schema documented in each
    suite module's docstring, honest-timing rules included)
  * roofline — aggregated dry-run table, if experiments/dryrun exists

``python -m benchmarks.run --quick`` runs only the fast sections (used by
CI); the full run takes ~10 minutes on this container.
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def _section(name: str, fn, lines: list[str]) -> None:
    print(f"# --- {name} ---", flush=True)
    try:
        out = fn()
        if out:
            lines.extend(out)
    except Exception as e:  # noqa: BLE001
        traceback.print_exc()
        lines.append(f"{name},nan,error={type(e).__name__}")


def _anakin_single_device() -> list[str]:
    import jax

    from repro import optim
    from repro.agents.actor_critic import MLPActorCritic
    from repro.core.anakin import Anakin, AnakinConfig
    from repro.envs import Catch

    env = Catch()
    net = MLPActorCritic(env.num_actions, (64, 64))
    ank = Anakin(
        env, net, optim.adam(3e-3, clip_norm=1.0),
        AnakinConfig(unroll_length=10, batch_per_device=64,
                     iterations_per_call=50),
    )
    state = ank.init_state(jax.random.key(0))
    state, _ = ank.run(state)  # compile
    jax.block_until_ready(state)
    t0 = time.time()
    for _ in range(3):
        state, _ = ank.run(state)
    jax.block_until_ready(state)
    fps = 3 * ank.steps_per_call / (time.time() - t0)
    return [
        f"anakin_catch_1dev,{1e6 / fps:.3f},steps_per_s={fps:,.0f} "
        f"(paper: 5M steps/s on free 8-core TPU)"
    ]


def _replay_suite(lines: list[str]) -> None:
    """--suite replay: insert/sample throughput -> BENCH_replay.json (the
    perf trajectory future replay PRs regress against)."""
    from benchmarks import replay_bench

    _section(
        "replay insert/sample throughput",
        lambda: replay_bench.main(json_path="BENCH_replay.json"),
        lines,
    )


def _sebulba_suite(lines: list[str], include_e2e: bool = True) -> None:
    """--suite sebulba: fused-vs-legacy actor-loop numbers plus the
    subprocess end-to-end FPS -> BENCH_sebulba.json (the actor-pipeline
    perf trajectory)."""
    from benchmarks import sebulba_pipeline

    _section(
        "sebulba actor pipeline (fused vs legacy)",
        lambda: sebulba_pipeline.main(
            json_path="BENCH_sebulba.json", include_e2e=include_e2e
        ),
        lines,
    )


def _learner_suite(lines: list[str]) -> None:
    """--suite learner: donated/cached learner-update latency + publish
    transfer counts -> BENCH_learner.json (the learner-pipeline perf
    trajectory)."""
    from benchmarks import learner_bench

    _section(
        "sebulba learner pipeline (donated vs legacy)",
        lambda: learner_bench.main(json_path="BENCH_learner.json"),
        lines,
    )


def _envs_suite(lines: list[str]) -> None:
    """--suite envs: host BatchedHostEnv loop vs fused device fleet step
    at B=4/32 -> BENCH_envs.json (the env-pipeline perf trajectory)."""
    from benchmarks import env_bench

    _section(
        "env stepping (host pool vs device fleet)",
        lambda: env_bench.main(json_path="BENCH_envs.json"),
        lines,
    )


def _recurrent_suite(lines: list[str]) -> None:
    """--suite recurrent: R2D2 learner step — rglru-kernel vs lax-scan
    temporal core, burn-in 0 vs K overhead -> BENCH_recurrent.json (the
    recurrent-agent perf trajectory)."""
    from benchmarks import recurrent_bench

    _section(
        "recurrent learner (rglru vs lax core, burn-in overhead)",
        lambda: recurrent_bench.main(json_path="BENCH_recurrent.json"),
        lines,
    )


def _fault_suite(lines: list[str]) -> None:
    """--suite fault: supervised-Sebulba throughput-degradation curve
    (no-fault / crash-restart / hang-watchdog / quarantine) + measured
    recovery latency -> BENCH_fault.json (the fault-tolerance perf
    trajectory)."""
    from benchmarks import fault_bench

    _section(
        "fault suite (supervision degradation + recovery)",
        lambda: fault_bench.main(json_path="BENCH_fault.json"),
        lines,
    )


def _elastic_suite(lines: list[str]) -> None:
    """--suite elastic: multi-host membership scale-out (per-host fps
    flat 1->2->4) + SIGKILL host-loss recovery latency ->
    BENCH_elastic.json (the elasticity perf trajectory)."""
    from benchmarks import elastic_bench

    _section(
        "elastic suite (membership scale-out + host-kill recovery)",
        lambda: elastic_bench.main(json_path="BENCH_elastic.json"),
        lines,
    )


def _lm_suite(lines: list[str]) -> None:
    """--suite lm: actor decode throughput, fused KV-cache carry vs naive
    full-forward re-scoring at B=4/32 -> BENCH_lm.json (the LM-policy perf
    trajectory; acceptance floor >= 2x fused at B=32)."""
    from benchmarks import lm_bench

    _section(
        "lm decode (fused KV-cache carry vs full-forward re-scoring)",
        lambda: lm_bench.main(json_path="BENCH_lm.json"),
        lines,
    )


def _serve_suite(lines: list[str]) -> None:
    """--suite serve: continuous batching (paged KV + chunked prefill)
    vs static batching at mixed prompt/gen lengths -> BENCH_serve.json
    (the serving perf trajectory; acceptance floor >= 1.5x useful
    tokens/s over static on the mixed workload)."""
    from benchmarks import serve_bench

    _section(
        "serve (continuous vs static batching, mixed lengths)",
        lambda: serve_bench.main(json_path="BENCH_serve.json"),
        lines,
    )


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fast sections only")
    ap.add_argument("--suite",
                    choices=["all", "replay", "sebulba", "learner",
                             "recurrent", "envs", "fault", "elastic", "lm",
                             "serve"],
                    default="all",
                    help="'replay' -> BENCH_replay.json only; 'sebulba' -> "
                         "BENCH_sebulba.json only (actor pipeline + e2e FPS); "
                         "'learner' -> BENCH_learner.json only (donated "
                         "learner update + publish throttling); 'recurrent' "
                         "-> BENCH_recurrent.json only (R2D2 core + burn-in); "
                         "'envs' -> BENCH_envs.json only (host pool vs "
                         "device fleet stepping); 'fault' -> BENCH_fault.json "
                         "only (supervision degradation + recovery latency); "
                         "'elastic' -> BENCH_elastic.json only (multi-host "
                         "scale-out + host-kill recovery); 'lm' -> "
                         "BENCH_lm.json only (fused decode-carry acting vs "
                         "full-forward re-scoring); 'serve' -> "
                         "BENCH_serve.json only (continuous vs static "
                         "batching at mixed prompt/gen lengths)")
    args = ap.parse_args()

    lines: list[str] = []
    print("name,us_per_call,derived")

    suites = {
        "replay": _replay_suite,
        "sebulba": _sebulba_suite,
        "learner": _learner_suite,
        "recurrent": _recurrent_suite,
        "envs": _envs_suite,
        "fault": _fault_suite,
        "elastic": _elastic_suite,
        "lm": _lm_suite,
        "serve": _serve_suite,
    }
    if args.suite in suites:
        suites[args.suite](lines)
        print("# --- summary CSV ---")
        for line in lines:
            print(line)
        return

    from benchmarks import kernel_bench

    _section("kernels", kernel_bench.main, lines)
    _section("anakin single-device (paper §Anakin)", _anakin_single_device,
             lines)

    if not args.quick:
        from benchmarks import anakin_scaling, muzero_scaling, sebulba_batch

        _section("Fig 4a anakin scaling",
                 lambda: anakin_scaling.main((1, 2, 4, 8)), lines)
        _section("Fig 4b sebulba actor batch",
                 lambda: sebulba_batch.main((12, 24, 48)), lines)
        _section("Fig 4c muzero scaling",
                 lambda: muzero_scaling.main((4, 8)), lines)
        # keep the regression JSONs fresh on full runs, not just per-suite
        _replay_suite(lines)
        _sebulba_suite(lines)
        _learner_suite(lines)
        _recurrent_suite(lines)
        _envs_suite(lines)
        _fault_suite(lines)
        _elastic_suite(lines)
        _lm_suite(lines)
        _serve_suite(lines)

    # roofline table from dry-run artifacts, if present
    try:
        import glob

        if glob.glob("experiments/dryrun/*.json"):
            from benchmarks import roofline_table

            print("# --- roofline (from dry-run artifacts) ---")
            roofline_table.main()
    except Exception:  # noqa: BLE001
        traceback.print_exc()

    print("# --- summary CSV ---")
    for line in lines:
        print(line)


if __name__ == "__main__":
    main()
