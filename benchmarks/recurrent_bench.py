"""Recurrent-learner benchmark: temporal-core choice and burn-in overhead.

What it measures: the full R2D2 learner step (loss + grad through the
recurrent unroll, jitted) at the B=4 and B=32 operating points, over

  * **core** — ``rglru`` (the ``rglru_scan`` kernel wrapper; these
    stored-state scans run the log-depth ``associative_scan`` + linear-
    memory custom VJP on every backend, so the CPU timing here IS the
    production schedule) vs ``lax`` (the sequential ``jax.lax.scan``
    reference), same math, different schedule;
  * **burn-in** — 0 vs K=5: stored-state refresh re-unrolls K steps
    gradient-free before the V-trace loss, so its cost is the extra
    forward-only prefix (the backward pass still covers only T-K steps).

Honest-timing rules (shared by every suite in this directory): jit
tracing/compilation is hoisted out of all timed windows (``time_call``
warms up before timing), inputs are created outside the timed region, and
every variant is timed by the same median-of-iters estimator.  Single-item
wall-clock on this CPU container reflects XLA CPU scheduling, not
accelerator behaviour — the cross-variant *ratios* are the signal.

``benchmarks/run.py --suite recurrent`` (also part of ``--suite all`` full
runs) writes ``BENCH_recurrent.json``:

    {"batch_<B>": {
        "rglru": {"burn0_us": float, "burnK_us": float,
                   "burn_overhead": burnK_us / burn0_us},
        "lax":   {... same ...},
        "core_speedup_burn0": lax.burn0_us / rglru.burn0_us,
        "burn_in": K, "trajectory_length": T, "rnn_width": W}, ...}

CSV lines mirror the JSON (``recurrent_update_<core>_b<B>`` plus a
``_burnK`` variant per core).

Honest reading of the committed CPU run: ``burn_overhead`` < 1 — burn-in
makes the update CHEAPER here, because the burn-in prefix is forward-only
while the backward pass (the expensive autodiff through the scan) covers
only the remaining T-K steps; and ``core_speedup_burn0`` ~0.84 — the
sequential lax core beats the log-depth associative scan on CPU, where the
scan's O(T log T) work costs more than its parallel depth saves (the
associative core's log-depth win needs a parallel backend to show).
"""

from __future__ import annotations

import argparse
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import csv_line, time_call

BATCHES = (4, 32)
TRAJ = 20
BURN = 5
RNN_WIDTH = 64
OBS = 16


def _traj(batch: int, rnn_width: int, seed: int = 0):
    from repro.data.trajectory import Trajectory

    rng = np.random.RandomState(seed)
    disc = (rng.rand(batch, TRAJ) > 0.1).astype(np.float32) * 0.99
    return Trajectory(
        obs=jnp.asarray(rng.rand(batch, TRAJ, OBS), jnp.float32),
        actions=jnp.asarray(rng.randint(0, 4, (batch, TRAJ)), jnp.int32),
        rewards=jnp.asarray(rng.rand(batch, TRAJ), jnp.float32),
        discounts=jnp.asarray(disc),
        behaviour_logp=jnp.asarray(
            np.log(rng.uniform(0.2, 0.9, (batch, TRAJ))), jnp.float32
        ),
        bootstrap_obs=jnp.asarray(rng.rand(batch, OBS), jnp.float32),
        init_carry=jnp.asarray(rng.rand(batch, rnn_width), jnp.float32),
    )


def bench_update(batch: int) -> dict:
    """-> per-core {burn0_us, burnK_us, burn_overhead} + core speedup."""
    from repro.agents.recurrent import (
        RecurrentImpalaAgent,
        RecurrentMLPActorCritic,
    )
    from repro.core.sebulba import SebulbaConfig

    base_cfg = SebulbaConfig(
        num_actor_cores=1, actor_batch_size=batch, trajectory_length=TRAJ
    )
    traj = _traj(batch, RNN_WIDTH)
    out: dict = {
        "burn_in": BURN, "trajectory_length": TRAJ, "rnn_width": RNN_WIDTH,
    }
    for core in ("rglru", "lax"):
        net = RecurrentMLPActorCritic(
            4, hidden=(64,), rnn_width=RNN_WIDTH, core=core
        )
        params = net.init(jax.random.key(0), (OBS,))
        res = {}
        for label, burn in (("burn0", 0), (f"burn{BURN}", BURN)):
            agent = RecurrentImpalaAgent(
                net, dataclasses.replace(base_cfg, burn_in=burn)
            )
            step = jax.jit(
                lambda p, t, _agent=agent: jax.grad(
                    lambda pp: _agent.loss(pp, t)[0]
                )(p)
            )
            res[f"{label}_us"] = round(time_call(step, params, traj), 1)
        res["burn_overhead"] = round(
            res[f"burn{BURN}_us"] / res["burn0_us"], 3
        )
        out[core] = res
    out["core_speedup_burn0"] = round(
        out["lax"]["burn0_us"] / out["rglru"]["burn0_us"], 2
    )
    return out


def main(json_path: str | None = None) -> list[str]:
    results = {f"batch_{b}": bench_update(b) for b in BATCHES}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    lines = []
    for key, r in results.items():
        b = key.split("_")[1]
        K = r["burn_in"]
        for core in ("rglru", "lax"):
            lines.append(csv_line(
                f"recurrent_update_{core}_b{b}", r[core]["burn0_us"],
                f"burn{K}_us={r[core][f'burn{K}_us']} "
                f"overhead={r[core]['burn_overhead']}x",
            ))
        lines.append(csv_line(
            f"recurrent_core_speedup_b{b}", 0.0,
            f"lax/rglru={r['core_speedup_burn0']}x",
        ))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_recurrent.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(
        json_path="BENCH_recurrent.json" if args.json else None
    ):
        print(line)
    if args.json:
        print("wrote BENCH_recurrent.json")
