"""Replay-ring throughput: insert + sample rates vs buffer capacity.

Measures the device-resident paths (donated-jit insert, inverse-CDF
sample) on trajectory slots shaped like the Sebulba HostPong workload
(T=20 steps of 16x16x1 frames, ~20KB/slot).  Reported as microseconds per
call and slots/second; ``--json`` (or ``benchmarks/run.py --suite replay``)
additionally writes ``BENCH_replay.json`` so future PRs can regress
against the trajectory.

``BENCH_replay.json`` schema — one entry per ring capacity:

    {"<capacity>": {"insert_us": float, "sample_us": float,
                    "insert_slots_per_s": int, "sample_slots_per_s": int}}

Honest timing: both paths run through ``_timing.time_call`` (warmup calls
hoist jit compile out of the timed window, median-of-iters with
``block_until_ready``); the insert path re-creates its donated state
OUTSIDE the timed region so donation churn is never billed to the op.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from benchmarks._timing import csv_line, time_call
from repro.data.trajectory import Trajectory
from repro.replay import ReplayBuffer

INSERT_BATCH = 32
SAMPLE_BATCH = 64
SIZES = (1024, 8192, 65536)


def _traj(B: int, T: int = 20, hw: int = 16) -> Trajectory:
    return Trajectory(
        obs=jnp.zeros((B, T, hw, hw, 1), jnp.float32),
        actions=jnp.zeros((B, T), jnp.int32),
        rewards=jnp.zeros((B, T), jnp.float32),
        discounts=jnp.ones((B, T), jnp.float32),
        behaviour_logp=jnp.zeros((B, T), jnp.float32),
        bootstrap_obs=jnp.zeros((B, hw, hw, 1), jnp.float32),
    )


def bench(sizes=SIZES, prioritized: bool = True) -> dict:
    """-> {capacity: {insert_us, sample_us, insert_slots_per_s, ...}}"""
    results: dict[str, dict] = {}
    traj = _traj(INSERT_BATCH)
    for capacity in sizes:
        buf = ReplayBuffer(capacity, prioritized=prioritized)
        state = buf.init(traj)
        # fill the ring so sampling sees a full valid range; statically
        # counted — a size() loop condition would block on a device->host
        # sync after every donated insert
        for _ in range(-(-capacity // INSERT_BATCH)):
            state = buf.insert(state, traj)

        # insert path: donation consumes the state, so thread it through
        # the timing loop instead of using time_call's repeated-args shape
        st = state
        insert_us = []
        for _ in range(12):
            t0 = time.perf_counter()
            st = buf.insert(st, traj)
            jax.block_until_ready(st.priorities)
            insert_us.append((time.perf_counter() - t0) * 1e6)
        insert_us.sort()
        ins = insert_us[len(insert_us) // 2]

        key = jax.random.key(0)
        sam = time_call(
            lambda: buf.sample(st, key, SAMPLE_BATCH), warmup=2, iters=10
        )
        results[str(capacity)] = {
            "insert_us": round(ins, 1),
            "sample_us": round(sam, 1),
            "insert_slots_per_s": round(INSERT_BATCH / (ins * 1e-6)),
            "sample_slots_per_s": round(SAMPLE_BATCH / (sam * 1e-6)),
        }
    return results


def csv_lines(results: dict) -> list[str]:
    lines = []
    for capacity, r in results.items():
        lines.append(csv_line(
            f"replay_insert_cap{capacity}", r["insert_us"],
            f"slots_per_s={r['insert_slots_per_s']:,}",
        ))
        lines.append(csv_line(
            f"replay_sample_cap{capacity}", r["sample_us"],
            f"slots_per_s={r['sample_slots_per_s']:,}",
        ))
    return lines


def write_json(results: dict, path: str = "BENCH_replay.json") -> None:
    with open(path, "w") as f:
        json.dump(results, f, indent=2)


def main(sizes=SIZES, json_path: str | None = None,
         prioritized: bool = True) -> list[str]:
    results = bench(sizes, prioritized=prioritized)
    if json_path:
        write_json(results, json_path)
    return csv_lines(results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_replay.json")
    ap.add_argument("--sizes", type=int, nargs="+", default=list(SIZES))
    ap.add_argument("--uniform", action="store_true",
                    help="measure the uniform-sampling path instead of PER")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(tuple(args.sizes),
                     json_path="BENCH_replay.json" if args.json else None,
                     prioritized=not args.uniform):
        print(line)
    if args.json:
        print("wrote BENCH_replay.json")
