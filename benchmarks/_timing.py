"""Shared timing helpers for the benchmark harness."""

from __future__ import annotations

import time

import jax


def time_call(fn, *args, warmup: int = 2, iters: int = 10) -> float:
    """Median-of-iters wall time per call, in microseconds."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def csv_line(name: str, us: float, derived: str = "") -> str:
    return f"{name},{us:.1f},{derived}"
