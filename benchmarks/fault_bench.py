"""Fault-tolerance cost and recovery: throughput degradation + latency.

Measures what supervision (ISSUE 7) actually buys and what it costs, on
the tiny HostBandit Sebulba topology (1 actor core x 2 threads) so env
and transport overheads stay constant across conditions:

  * ``no_fault``          — supervision enabled, empty fault plan: the
    steady-state baseline every other condition is normalized against
    (and the "supervision is free when nothing fails" claim);
  * ``crash_restart``     — each actor slot is killed once mid-run by a
    deterministic ``FaultPlan``; the supervisor restarts both.  Reports
    the measured recovery latency (death -> replacement's first
    trajectory put) alongside throughput;
  * ``hang_watchdog``     — one slot hangs (heartbeats freeze) and the
    watchdog must cancel + restart it; throughput rides on the surviving
    slot until the stall budget expires;
  * ``quarantine_degrade``— one slot crashes past ``max_restarts`` and is
    quarantined early: the THROUGHPUT-DEGRADATION point — half the fleet
    for essentially the whole run, normalized FPS against ``no_fault``.

``benchmarks/run.py --suite fault`` writes ``BENCH_fault.json``:

    {"<condition>": {
        "fps", "frames", "seconds",
        "actor_restarts", "actor_quarantined", "watchdog_stalls",
        "throughput_vs_no_fault",              # fps / no_fault fps
        "recovery_latency_s_mean", "recovery_latency_s_max"  # when any
    }}

Honest timing: one untimed warmup fit (a throwaway Sebulba on the same
shapes) populates the in-process XLA compile cache before ANY condition
is timed — otherwise the first condition eats every compile and the
faulted conditions come out "faster than no-fault".  Faults are
scheduled by step, not wall clock, so the schedule is reproducible.
"""

from __future__ import annotations

import argparse
import json
import time

from benchmarks._timing import csv_line

TOTAL_FRAMES = 24_000
STALL_TIMEOUT = 0.25


def _sebulba(plan):
    import repro.optim as optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    return Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.sgd(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=2,
            actor_batch_size=4, trajectory_length=2, queue_capacity=2,
            max_restarts=2, restart_backoff=0.01,
            stall_timeout=STALL_TIMEOUT,
        ),
        fault_plan=plan,
    )


def _plans():
    from repro.fault import FaultEvent, FaultPlan

    return {
        "no_fault": None,
        "crash_restart": FaultPlan(events=(
            FaultEvent(kind="crash", target="actor:0", step=50),
            FaultEvent(kind="crash", target="actor:1", step=80),
        ), seed=0),
        "hang_watchdog": FaultPlan(events=(
            FaultEvent(kind="hang", target="actor:1", step=50),
        ), seed=0),
        "quarantine_degrade": FaultPlan(events=tuple(
            FaultEvent(kind="crash", target="actor:0", step=s)
            for s in (10, 11, 12)
        ), seed=0),
    }


def bench(total_frames: int = TOTAL_FRAMES) -> dict:
    import jax

    # warmup: compile the act/update programs once, outside every timed
    # window (the cache is per-process, keyed by computation shape)
    _sebulba(None).fit(jax.random.key(0), total_frames=256)

    results: dict[str, dict] = {}
    for name, plan in _plans().items():
        seb = _sebulba(plan)
        t0 = time.perf_counter()
        res = seb.fit(jax.random.key(0), total_frames=total_frames)
        dt = time.perf_counter() - t0
        latencies = seb.supervisor.recovery_latencies()
        entry = {
            "fps": round(res["frames"] / dt, 1),
            "frames": res["frames"],
            "seconds": round(dt, 3),
            "actor_restarts": res["actor_restarts"],
            "actor_quarantined": res["actor_quarantined"],
            "watchdog_stalls": res["watchdog_stalls"],
        }
        if latencies:
            entry["recovery_latency_s_mean"] = round(
                sum(latencies) / len(latencies), 4
            )
            entry["recovery_latency_s_max"] = round(max(latencies), 4)
        results[name] = entry
    base = results["no_fault"]["fps"]
    for entry in results.values():
        entry["throughput_vs_no_fault"] = round(entry["fps"] / base, 3)
    return results


def write_json(results: dict, path: str = "BENCH_fault.json") -> None:
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)


def main(total_frames: int = TOTAL_FRAMES,
         json_path: str | None = None) -> list[str]:
    results = bench(total_frames)
    if json_path:
        write_json(results, json_path)
    lines = []
    for name, r in results.items():
        us_per_frame = 1e6 * r["seconds"] / max(1, r["frames"])
        lines.append(csv_line(
            f"fault/{name}", us_per_frame,
            f"fps={r['fps']} vs_no_fault={r['throughput_vs_no_fault']} "
            f"restarts={r['actor_restarts']} "
            f"quarantined={r['actor_quarantined']} "
            f"stalls={r['watchdog_stalls']}",
        ))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--frames", type=int, default=TOTAL_FRAMES)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_fault.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(total_frames=args.frames,
                     json_path="BENCH_fault.json" if args.json else None):
        print(line)
    if args.json:
        print("wrote BENCH_fault.json")
