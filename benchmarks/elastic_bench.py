"""Multi-host elasticity: scale-out overhead + host-kill recovery.

Measures what the ISSUE 8 membership/routing layer costs and how fast
the fleet recovers from losing a host, with REAL subprocess workers
sharing one lease directory (the multi-host detection path, not an
in-process simulation):

  * ``scaleout`` — per-host training throughput as the membership grows
    1 -> 2 -> 4 hosts.  One member trains (the tiny HostBandit Sebulba,
    same topology as fault_bench) while the other members are
    subprocess lease-renewers; every learner drain iteration pays the
    full elastic path (cluster poll, registry sync over N lease files,
    epoch tag checks).  Per-host fps should be FLAT within 20% —
    membership size must not tax the training loop.

    Honesty note: this container has ONE cpu, so co-training workers
    would measure cpu contention, not elasticity overhead.  Scaling the
    *membership* while one member trains isolates exactly the cost this
    PR added; on a real pod each host has its own cores and the same
    flatness claim applies to co-training hosts.

  * ``host_kill`` — a subprocess member is SIGKILLed mid-run (no
    goodbye: its lease must EXPIRE).  Reports the measured recovery
    latency (kill -> membership epoch bump, lower-bounded by the lease
    ttl) and the survivor's ``hosts_lost`` / ``reshards`` accounting.

``benchmarks/run.py --suite elastic`` writes ``BENCH_elastic.json``:

    {"scaleout": {"1": {"per_host_fps", "frames", "seconds", "epoch"},
                  "2": {...}, "4": {...},
                  "per_host_flatness": min/max per-host fps},
     "host_kill": {"recovery_latency_s", "lease_ttl_s",
                   "hosts_lost", "reshards", "fps"}}

Honest timing: each training worker runs its own untimed warmup fit
(fresh process, fresh XLA compile cache) before its timed fit, and the
members are up (leases live, membership synced) before timing starts —
the scale-out numbers time steady-state training, never compiles or
fleet bring-up.  The kill is wall-clock (the parent waits for the timed
fit to begin), but detection is by lease expiry, so the measured
latency is the real contract: ttl + one sync interval.
"""

from __future__ import annotations

import argparse
import json
import os
import signal
import subprocess
import sys
import time

from benchmarks._timing import csv_line

TOTAL_FRAMES = 16_000
LEASE_TTL = 0.5
_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _sebulba(cluster=None):
    import repro.optim as optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    return Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.sgd(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=2,
            actor_batch_size=4, trajectory_length=2, queue_capacity=2,
            max_restarts=2, restart_backoff=0.01,
        ),
        cluster=cluster,
    )


# ------------------------------------------------------------- worker side


def _train_worker(args) -> None:
    """One training host: join the membership, warm up untimed, touch
    the start marker, run the timed fit, print one JSON result line."""
    import jax

    from repro.distributed import HostSupervisor

    _sebulba(None).fit(jax.random.key(0), total_frames=256)  # compile cache
    sup = HostSupervisor(args.registry, args.host_id, ttl=args.ttl)
    seb = _sebulba(cluster=sup)
    marker = os.path.join(args.registry, f"started_{args.host_id}")
    with open(marker, "w") as f:
        f.write(str(os.getpid()))
    t0 = time.perf_counter()
    res = seb.fit(jax.random.key(0), total_frames=args.frames)
    dt = time.perf_counter() - t0
    print(json.dumps({
        "host_id": args.host_id,
        "frames": res["frames"],
        "seconds": round(dt, 3),
        "fps": round(res["frames"] / dt, 1),
        "hosts_lost": res["hosts_lost"],
        "hosts_joined": res["hosts_joined"],
        "reshards": res["reshards"],
        "epoch": res["epoch"],
        "stale_epoch_trajs": seb.stale_epoch_trajs,
    }), flush=True)


def _member_worker(args) -> None:
    """One membership-only host: announce and renew until killed."""
    from repro.distributed import HostRegistry

    registry = HostRegistry(args.registry, ttl=args.ttl)
    registry.announce(args.host_id)
    while True:  # killed by the parent (scaleout: TERM; kill test: KILL)
        time.sleep(args.ttl / 3.0)
        registry.renew(args.host_id)


# ------------------------------------------------------------- parent side


def _spawn(mode: str, registry: str, host_id: str, *, frames: int = 0,
           ttl: float = LEASE_TTL) -> subprocess.Popen:
    cmd = [
        sys.executable, "-m", "benchmarks.elastic_bench",
        "--worker", mode, "--registry", registry, "--host-id", host_id,
        "--ttl", str(ttl),
    ]
    if frames:
        cmd += ["--frames", str(frames)]
    env = dict(os.environ)
    src = os.path.join(_REPO_ROOT, "src")
    env["PYTHONPATH"] = os.pathsep.join(
        p for p in (src, env.get("PYTHONPATH")) if p
    )
    return subprocess.Popen(
        cmd, cwd=_REPO_ROOT, env=env,
        stdout=subprocess.PIPE, stderr=subprocess.DEVNULL, text=True,
    )


def _wait_for(predicate, timeout: float, what: str) -> None:
    deadline = time.monotonic() + timeout
    while not predicate():
        if time.monotonic() > deadline:
            raise TimeoutError(f"timed out waiting for {what}")
        time.sleep(0.02)


def _wait_live(registry_dir: str, n: int, ttl: float) -> None:
    from repro.distributed import HostRegistry

    reg = HostRegistry(registry_dir, ttl=ttl)
    _wait_for(
        lambda: len(reg.live_hosts()) >= n, timeout=30.0,
        what=f"{n} live leases in {registry_dir}",
    )


def _read_result(proc: subprocess.Popen, timeout: float = 300.0) -> dict:
    out, _ = proc.communicate(timeout=timeout)
    if proc.returncode != 0:
        raise RuntimeError(f"worker failed (rc={proc.returncode}): {out}")
    return json.loads(out.strip().splitlines()[-1])


def _scaleout(tmp: str, total_frames: int) -> dict:
    results: dict[str, dict] = {}
    for n in (1, 2, 4):
        registry = os.path.join(tmp, f"scale{n}")
        members = [
            _spawn("member", registry, f"member{i}")
            for i in range(n - 1)
        ]
        try:
            if members:
                _wait_live(registry, n - 1, LEASE_TTL)
            trainer = _spawn(
                "train", registry, "trainer", frames=total_frames
            )
            res = _read_result(trainer)
        finally:
            for m in members:
                m.terminate()
            for m in members:
                m.wait(timeout=10.0)
        results[str(n)] = {
            "per_host_fps": res["fps"],
            "frames": res["frames"],
            "seconds": res["seconds"],
            "epoch": res["epoch"],
        }
    fps = [r["per_host_fps"] for r in results.values()]
    results["per_host_flatness"] = round(min(fps) / max(fps), 3)
    return results


def _host_kill(tmp: str, total_frames: int) -> dict:
    from repro.distributed import HostRegistry

    registry = os.path.join(tmp, "kill")
    victim = _spawn("member", registry, "victim")
    _wait_live(registry, 1, LEASE_TTL)
    trainer = _spawn("train", registry, "survivor", frames=total_frames)
    marker = os.path.join(registry, "started_survivor")
    _wait_for(
        lambda: os.path.exists(marker), timeout=120.0,
        what="survivor's timed fit to start",
    )
    time.sleep(0.2)  # let the timed fit get into steady state
    victim.send_signal(signal.SIGKILL)  # no goodbye: the lease must expire
    t_kill = time.monotonic()
    reg = HostRegistry(registry, ttl=LEASE_TTL)
    # the parent is a legitimate sync participant: racing bumps converge
    # (registry semantics), so polling here never confuses the survivor
    _wait_for(
        lambda: "victim" not in reg.sync().hosts, timeout=30.0,
        what="the victim's lease to expire and the epoch to bump",
    )
    latency = time.monotonic() - t_kill
    victim.wait(timeout=10.0)
    res = _read_result(trainer)
    return {
        "recovery_latency_s": round(latency, 3),
        "lease_ttl_s": LEASE_TTL,
        "hosts_lost": res["hosts_lost"],
        "reshards": res["reshards"],
        "fps": res["fps"],
        "stale_epoch_trajs": res["stale_epoch_trajs"],
    }


def bench(total_frames: int = TOTAL_FRAMES) -> dict:
    import tempfile

    with tempfile.TemporaryDirectory(prefix="elastic_bench_") as tmp:
        return {
            "scaleout": _scaleout(tmp, total_frames),
            "host_kill": _host_kill(tmp, total_frames),
        }


def write_json(results: dict, path: str = "BENCH_elastic.json") -> None:
    with open(path, "w") as f:
        json.dump(results, f, indent=2, sort_keys=True)


def main(total_frames: int = TOTAL_FRAMES,
         json_path: str | None = None) -> list[str]:
    results = bench(total_frames)
    if json_path:
        write_json(results, json_path)
    lines = []
    for n in ("1", "2", "4"):
        r = results["scaleout"][n]
        us_per_frame = 1e6 * r["seconds"] / max(1, r["frames"])
        lines.append(csv_line(
            f"elastic/scaleout_{n}host", us_per_frame,
            f"per_host_fps={r['per_host_fps']} "
            f"flatness={results['scaleout']['per_host_flatness']}",
        ))
    k = results["host_kill"]
    lines.append(csv_line(
        "elastic/host_kill", 1e6 * k["recovery_latency_s"],
        f"recovery_s={k['recovery_latency_s']} ttl_s={k['lease_ttl_s']} "
        f"hosts_lost={k['hosts_lost']} reshards={k['reshards']}",
    ))
    return lines


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--worker", choices=["train", "member"],
                    help="internal: run as a subprocess worker")
    ap.add_argument("--registry", help="shared lease directory (worker)")
    ap.add_argument("--host-id", help="this worker's host id")
    ap.add_argument("--ttl", type=float, default=LEASE_TTL)
    ap.add_argument("--frames", type=int, default=TOTAL_FRAMES)
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_elastic.json")
    args = ap.parse_args()
    if args.worker == "train":
        _train_worker(args)
    elif args.worker == "member":
        _member_worker(args)
    else:
        print("name,us_per_call,derived")
        for line in main(
            total_frames=args.frames,
            json_path="BENCH_elastic.json" if args.json else None,
        ):
            print(line)
        if args.json:
            print("wrote BENCH_elastic.json")
