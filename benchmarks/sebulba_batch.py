"""Paper Fig. 4b — Sebulba FPS as a function of actor batch size.

The paper scales actor batch 32 -> 128 on an 8-core TPU and reaches 200K
FPS.  Here the same sweep runs on 8 placeholder CPU devices (2 actor + 6
learner cores) at reduced batches; the figure of merit is the TREND (bigger
actor batches amortize per-step host/device overhead), which reproduces.

Output: ``sebulba_batch_<B>`` CSV lines; ``measure(batch, frames)`` is also
the end-to-end FPS point ``--suite sebulba`` records in
``BENCH_sebulba.json`` (key ``e2e``).  Honest timing: FPS is whole-run
wall-clock over a fixed frame budget in a fresh subprocess — compile cost
is inside the budget but identical across batch points, so the trend is
compile-neutral.
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.agents.impala import ConvActorCritic
    from repro.envs import HostPong, BatchedHostEnv
    from repro import optim

    net = ConvActorCritic(HostPong.num_actions, channels=(8,), blocks=1,
                          hidden=64)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net, optimizer=optim.rmsprop(2e-4, clip_norm=1.0),
        config=SebulbaConfig(num_actor_cores=2, threads_per_actor_core=2,
                             actor_batch_size={batch},
                             trajectory_length=20),
    )
    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames={frames})
    print("RESULT", out["fps"], out["updates"])
    """
)


def measure(batch: int, frames: int = 20_000) -> float:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(batch=batch, frames=frames,
                                              src=src)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            return float(line.split()[1])
    raise RuntimeError("no result line")


def main(batches=(12, 24, 48)) -> list[str]:
    lines = []
    for b in batches:
        fps = measure(b)
        lines.append(f"sebulba_actor_batch_{b},{1e6 / fps:.3f},fps={fps:,.0f}")
        print(lines[-1], flush=True)
    return lines


if __name__ == "__main__":
    main()
