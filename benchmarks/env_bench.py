"""Host vs device environment stepping (before/after for the device fleet).

Measures the same Pong game driven through both Podracer env regimes, with
an identical light MLP policy in the loop so env + glue dominate:

  * ``host``   — ``BatchedHostEnv`` of numpy ``HostPong`` envs on the
    shared thread pool, jitted inference, and the per-step round trip the
    host path cannot avoid: obs host->device, a blocking action sync
    device->host, then Python env stepping;
  * ``device`` — a ``DeviceEnvFleet`` of pure-JAX ``Pong`` twins with env
    step + action sampling fused into ONE donated jit per step.  Nothing
    leaves the device inside the loop; the only sync is the end-of-window
    ``block_until_ready``.

Both sides run the bit-exact twin of the same game (tests/test_device_envs
.py), so the delta is purely host-loop overhead vs on-device stepping —
the gap the Podracer paper's Anakin/Sebulba split is about.

``benchmarks/run.py --suite envs`` writes ``BENCH_envs.json``:

    {"batch_<B>": {
         "host_us_per_step", "host_steps_per_s", "host_fps",
         "device_us_per_step", "device_steps_per_s", "device_fps",
         "speedup", "batch"}}

(``*_fps`` = env frames/s = batch * steps/s; ``speedup`` = host us /
device us.)

Honest timing: both loops warm up (jit compile + pool spin-up never land
in a measurement), each timed window is best-of-3, and the host loop's
action sync is counted (it is part of that architecture, not an artifact).
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import csv_line

BATCHES = (4, 32)
MEASURE_STEPS = 60


def _policy(batch_hint: int):
    from repro.agents.actor_critic import BatchedMLPActorCritic

    net = BatchedMLPActorCritic(num_actions=3, hidden=(32,))
    params = net.init(jax.random.key(0), (16, 16, 1))

    def act(params, obs, rng):
        logits, _ = net.apply(params, obs)
        return jax.random.categorical(rng, logits)

    return params, act


def bench_host(batch: int, steps: int = MEASURE_STEPS) -> float:
    """-> best-of-3 seconds for ``steps`` batched host env steps."""
    from repro.envs import BatchedHostEnv, HostPong

    params, act = _policy(batch)
    jit_act = jax.jit(act)
    benv = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=batch)
    try:
        def window() -> float:
            obs = benv.reset()
            rng = jax.random.key(1)
            t0 = time.perf_counter()
            for _ in range(steps):
                rng, a_rng = jax.random.split(rng)
                actions = jit_act(params, jnp.asarray(obs), a_rng)
                # the host path's inherent per-step device->host sync
                obs, _, _ = benv.step(np.asarray(actions))
            return time.perf_counter() - t0

        window()  # warm: jit compile + pool thread spin-up
        return min(window() for _ in range(3))
    finally:
        benv.close()


def bench_device(batch: int, steps: int = MEASURE_STEPS) -> float:
    """-> best-of-3 seconds for ``steps`` fused fleet steps."""
    from repro.envs import DeviceEnvFleet, Pong

    params, act = _policy(batch)
    fleet = DeviceEnvFleet(Pong, batch)

    def fused(params, env_state, obs, rng):
        rng, a_rng = jax.random.split(rng)
        actions = act(params, obs, a_rng)
        env_state, ts = fleet.step(env_state, actions)
        return env_state, ts.obs, rng

    step = jax.jit(fused, donate_argnums=(1, 2, 3))

    def window() -> float:
        env_state = fleet.init(jax.random.key(1))
        obs = fleet.observe(env_state)
        rng = jax.random.key(2)
        t0 = time.perf_counter()
        for _ in range(steps):
            env_state, obs, rng = step(params, env_state, obs, rng)
        jax.block_until_ready(obs)
        return time.perf_counter() - t0

    window()  # warm: jit compile
    return min(window() for _ in range(3))


def bench_batch(batch: int, steps: int = MEASURE_STEPS) -> dict:
    out = {"batch": batch}
    for name, fn in (("host", bench_host), ("device", bench_device)):
        us = fn(batch, steps) / steps * 1e6
        out[f"{name}_us_per_step"] = round(us, 1)
        out[f"{name}_steps_per_s"] = round(1e6 / us, 1)
        out[f"{name}_fps"] = round(batch * 1e6 / us)
    out["speedup"] = round(
        out["host_us_per_step"] / out["device_us_per_step"], 2
    )
    return out


def csv_lines(results: dict) -> list[str]:
    lines = []
    for key, r in results.items():
        b = r["batch"]
        lines.append(csv_line(
            f"env_step_host_b{b}", r["host_us_per_step"],
            f"fps={r['host_fps']:,}"))
        lines.append(csv_line(
            f"env_step_device_b{b}", r["device_us_per_step"],
            f"fps={r['device_fps']:,} speedup={r['speedup']}x"))
    return lines


def main(json_path: str | None = None,
         steps: int = MEASURE_STEPS) -> list[str]:
    results = {f"batch_{b}": bench_batch(b, steps) for b in BATCHES}
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return csv_lines(results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_envs.json")
    ap.add_argument("--steps", type=int, default=MEASURE_STEPS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(
        json_path="BENCH_envs.json" if args.json else None, steps=args.steps
    ):
        print(line)
    if args.json:
        print("wrote BENCH_envs.json")
