"""Kernel micro-benchmarks: one line per kernel path.

Times the production non-TPU implementations (jnp chunked/associative/ref
paths — the exact code the CPU backend executes and the TPU-kernel oracles).
Pallas-interpret timings are not wall-clock meaningful and are excluded.

Output: one ``<kernel>_<shape>`` CSV line per path; no BENCH json.  Honest
timing: every path goes through ``_timing.time_call`` (explicit warmup
calls, then median-of-iters with ``block_until_ready``), so jit compile
and async dispatch never contaminate a sample.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks._timing import csv_line, time_call
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.vtrace.ops import vtrace
from repro.models import attention as attn


def main() -> list[str]:
    lines = []
    ks = jax.random.split(jax.random.key(0), 8)

    # flash-style chunked attention (prefill path)
    B, T, H, K, h = 2, 1024, 8, 2, 64
    q = jax.random.normal(ks[0], (B, T, H, h), jnp.bfloat16)
    k = jax.random.normal(ks[1], (B, T, K, h), jnp.bfloat16)
    v = jax.random.normal(ks[2], (B, T, K, h), jnp.bfloat16)
    fn = jax.jit(lambda q, k, v: attn.full_attention(q, k, v, chunk=256))
    us = time_call(fn, q, k, v)
    flops = 4 * B * T * T * H * h / 2  # causal
    lines.append(csv_line("attn_chunked_1k", us, f"gflops={flops / us / 1e3:.1f}"))

    fnw = jax.jit(
        lambda q, k, v: attn.sliding_window_attention(q, k, v, window=256)
    )
    us = time_call(fnw, q, k, v)
    lines.append(csv_line("attn_sliding_1k_w256", us, ""))

    # SSD scan (mamba2)
    B, T, Hs, P, N = 2, 1024, 8, 64, 64
    x = jax.random.normal(ks[3], (B, T, Hs, P), jnp.bfloat16)
    dt = jax.nn.softplus(jax.random.normal(ks[4], (B, T, Hs)))
    A = -jnp.exp(jax.random.normal(ks[5], (Hs,)) * 0.5)
    Bm = jax.random.normal(ks[6], (B, T, N), jnp.bfloat16) * 0.3
    Cm = jax.random.normal(ks[7], (B, T, N), jnp.bfloat16) * 0.3
    fn = jax.jit(lambda *a: ssd_scan(*a, chunk=256))
    us = time_call(fn, x, dt, A, Bm, Cm)
    lines.append(csv_line("ssd_scan_1k", us, f"tokens_per_s={B * T / us * 1e6:,.0f}"))

    # RG-LRU scan
    B, T, W = 4, 1024, 512
    x = jax.random.normal(ks[0], (B, T, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, W)))
    gi = jax.nn.sigmoid(jax.random.normal(ks[2], (B, T, W)))
    fn = jax.jit(rglru_scan)
    us = time_call(fn, x, a, gi)
    lines.append(csv_line("rglru_scan_1k", us, f"tokens_per_s={B * T / us * 1e6:,.0f}"))

    # V-trace
    B, T = 256, 64
    lr = jax.random.normal(ks[3], (B, T)) * 0.3
    disc = jnp.full((B, T), 0.99)
    rew = jax.random.normal(ks[4], (B, T))
    val = jax.random.normal(ks[5], (B, T))
    boot = jax.random.normal(ks[6], (B,))
    fn = jax.jit(vtrace)
    us = time_call(fn, lr, disc, rew, val, boot)
    lines.append(csv_line("vtrace_256x64", us, f"steps_per_s={B * T / us * 1e6:,.0f}"))

    for line in lines:
        print(line, flush=True)
    return lines


if __name__ == "__main__":
    main()
