"""Actor-pipeline benchmark (before/after for the fused Sebulba hot path).

Measures the actor hot loop two ways on the same synthetic host workload:

  * ``legacy`` — the pre-fusion reference, frozen here: separate jitted
    inference, 3 host->device transfers per env step (obs, rewards,
    discounts), a blocking host sync, and a T-way ``jnp.stack`` per leaf at
    drain time (``TrajectoryAccumulator``);
  * ``fused``  — one donated-jit act-step per env step writing the
    device-resident trajectory ring in place, per-step host data batched
    into a single (2, B) transfer, and a zero-copy drain
    (``DeviceTrajectoryBuffer``), i.e. Sebulba's current path.

The env is a zero-cost stub (precomputed numpy arrays) so the numbers
isolate the host/device glue the fused pipeline removes — exactly the
overhead Inci et al. measure dominating distributed-RL step time.  The
optional end-to-end section reruns the Fig. 4b subprocess sweep (8
placeholder devices, 2 actor + 6 learner cores) for a whole-system FPS
figure.  ``benchmarks/run.py --suite sebulba`` writes both into
``BENCH_sebulba.json``, the trajectory future actor-pipeline PRs regress
against.

``BENCH_sebulba.json`` schema:

    {"actor_loop": {"batch_<B>": {
         "legacy_us_per_step", "legacy_steps_per_s", "legacy_fps",
         "fused_us_per_step", "fused_steps_per_s", "fused_fps",
         "speedup", "actor_batch", "trajectory_length"}},
     "e2e": {"fps", "actor_batch", "frames"}}

Honest timing: both loops are warmed for a full trajectory + drain before
their timed windows (jit compile and the first-shard transfer never land
in a measurement), each window is best-of-3, and both variants pay the
same drain+shard cycles per window.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from benchmarks._timing import csv_line

BATCH = 32
# two operating points: B=4 is overhead-dominated (inference is cheap, so
# the per-step host/device glue the fusion removes is visible), B=32 is
# compute-dominated on this CPU container (conv inference ~ms/step swamps
# the glue — the regime a real accelerator does NOT sit in)
BATCHES = (4, BATCH)
TRAJ = 20
MEASURE_STEPS = 3 * TRAJ


class _StubHostEnv:
    """Batched-env stand-in with near-zero host cost: fixed obs/reward
    buffers, so the loop time is the device pipeline, not the env."""

    def __init__(self, batch: int, obs_shape=(16, 16, 1)):
        rng = np.random.RandomState(0)
        self.obs = rng.rand(batch, *obs_shape).astype(np.float32)
        self.rewards = rng.rand(batch).astype(np.float32)
        self.dones = np.zeros(batch, bool)

    def reset(self):
        return self.obs

    def step(self, actions):
        return self.obs, self.rewards, self.dones


def _build(batch: int):
    from repro import optim
    from repro.agents.impala import ConvActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import HostPong

    net = ConvActorCritic(HostPong.num_actions, channels=(8,), blocks=1,
                          hidden=64)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: _StubHostEnv(n),
        network=net,
        optimizer=optim.rmsprop(2e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=batch, trajectory_length=TRAJ,
        ),
    )
    params, _ = seb.init(jax.random.key(0), (16, 16, 1))
    return seb, params


def _run_fused(seb, params, env, device, steps: int) -> float:
    """-> seconds for ``steps`` env steps on the fused pipeline."""
    cfg = seb.cfg
    obs = env.reset()
    rng = jax.device_put(jax.random.key(1), device)
    host_data = np.zeros((2, cfg.actor_batch_size), np.float32)
    buf = None
    t = 0
    t0 = time.perf_counter()
    for _ in range(steps):
        obs_dev = jax.device_put(obs, device)
        hd_dev = jax.device_put(host_data, device)
        if buf is None:
            buf = seb._make_actor_buffer(params, obs_dev, device)
        if t == cfg.trajectory_length:
            traj, buf = seb._drain(buf, hd_dev, obs_dev)
            t = 0
            shards = seb._shard_for_learners(traj)
            jax.block_until_ready(shards.actions)
        actions, buf, rng, _ = seb._act_step(
            params, buf, rng, obs_dev, hd_dev, ()
        )
        obs, rewards, dones = env.step(np.asarray(actions))
        host_data = np.stack(
            [rewards, (~dones).astype(np.float32) * cfg.discount]
        )
        t += 1
    if t == cfg.trajectory_length:
        # the legacy loop drains right after the T-th add; match it so both
        # timed windows contain the same number of drain+shard cycles
        obs_dev = jax.device_put(obs, device)
        hd_dev = jax.device_put(host_data, device)
        traj, buf = seb._drain(buf, hd_dev, obs_dev)
        shards = seb._shard_for_learners(traj)
        jax.block_until_ready(shards.actions)
    jax.block_until_ready(buf.actions)
    return time.perf_counter() - t0


def _run_legacy(seb, params, env, device, steps: int, inference) -> float:
    """The frozen pre-fusion actor loop: per-leaf transfers + host-list
    accumulate + stack-at-drain (kept verbatim as the 'before' reference,
    independent of what core/sebulba.py now does).  ``inference`` is the
    jitted act fn, built ONCE by the caller — the pre-fusion Sebulba jitted
    it once in __init__ too, and re-wrapping per run would put a fresh
    trace+compile inside every timed window."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.data.trajectory import TrajectoryAccumulator

    cfg = seb.cfg
    sharding = NamedSharding(seb.learner_mesh, P("batch"))
    obs = env.reset()
    acc = TrajectoryAccumulator(cfg.trajectory_length)
    rng = jax.random.key(1)
    t0 = time.perf_counter()
    for _ in range(steps):
        rng, a_rng = jax.random.split(rng)
        obs_dev = jax.device_put(obs, device)
        # canonical repro.api act: (actions, ActAux(logp, extras), carry)
        actions, aux, _ = inference(params, obs_dev, a_rng, ())
        actions_host = np.asarray(actions)
        next_obs, rewards, dones = env.step(actions_host)
        discounts = (~dones).astype(np.float32) * cfg.discount
        acc.add(obs_dev, actions, jax.device_put(rewards, device),
                jax.device_put(discounts, device), aux.logp, aux.extras)
        obs = next_obs
        if acc.full:
            traj = acc.drain(bootstrap_obs=jax.device_put(obs, device))
            shards = jax.tree.map(
                lambda x: jax.device_put(np.asarray(x), sharding), traj
            )
            jax.block_until_ready(shards.actions)
    return time.perf_counter() - t0


def bench_actor_loop(batch: int = BATCH, steps: int = MEASURE_STEPS) -> dict:
    """-> {legacy_us_per_step, fused_us_per_step, speedup, *_fps}."""
    import functools

    seb, params = _build(batch)
    device = seb.split.actor_devices[0]
    env = _StubHostEnv(batch)
    legacy = functools.partial(
        _run_legacy, inference=jax.jit(seb.agent.act)
    )
    results = {}
    for name, runner in (("legacy", legacy), ("fused", _run_fused)):
        runner(seb, params, env, device, seb.cfg.trajectory_length + 2)  # jit
        best = min(runner(seb, params, env, device, steps) for _ in range(3))
        us = best / steps * 1e6
        results[f"{name}_us_per_step"] = round(us, 1)
        results[f"{name}_steps_per_s"] = round(1e6 / us, 1)
        results[f"{name}_fps"] = round(batch * 1e6 / us)
    results["speedup"] = round(
        results["legacy_us_per_step"] / results["fused_us_per_step"], 2
    )
    results["actor_batch"] = batch
    results["trajectory_length"] = TRAJ
    return results


def bench_e2e(frames: int = 12_000, batch: int = 24) -> dict:
    """End-to-end Sebulba FPS on the 8-placeholder-device topology
    (subprocess; the Fig. 4b harness at a single batch point)."""
    from benchmarks import sebulba_batch

    fps = sebulba_batch.measure(batch, frames=frames)
    return {"fps": round(fps), "actor_batch": batch, "frames": frames}


def csv_lines(results: dict) -> list[str]:
    lines = []
    for key, loop in results["actor_loop"].items():
        b = loop["actor_batch"]
        lines.append(csv_line(
            f"sebulba_actor_step_legacy_b{b}", loop["legacy_us_per_step"],
            f"fps={loop['legacy_fps']:,}"))
        lines.append(csv_line(
            f"sebulba_actor_step_fused_b{b}", loop["fused_us_per_step"],
            f"fps={loop['fused_fps']:,} speedup={loop['speedup']}x"))
    if "e2e" in results:
        e = results["e2e"]
        lines.append(csv_line(
            "sebulba_e2e_8core", 1e6 / max(e["fps"], 1), f"fps={e['fps']:,}"
        ))
    return lines


def main(json_path: str | None = None, include_e2e: bool = True,
         e2e_frames: int = 12_000) -> list[str]:
    results = {
        "actor_loop": {
            f"batch_{b}": bench_actor_loop(batch=b) for b in BATCHES
        }
    }
    if include_e2e:
        results["e2e"] = bench_e2e(frames=e2e_frames)
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return csv_lines(results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_sebulba.json")
    ap.add_argument("--no-e2e", action="store_true",
                    help="skip the subprocess end-to-end FPS run")
    ap.add_argument("--frames", type=int, default=12_000)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(
        json_path="BENCH_sebulba.json" if args.json else None,
        include_e2e=not args.no_e2e, e2e_frames=args.frames,
    ):
        print(line)
    if args.json:
        print("wrote BENCH_sebulba.json")
