"""LM serving: continuous batching (paged KV + chunked prefill) vs static
batching at mixed prompt/generation lengths.

The PR 10 tentpole claim: on a realistic mixed-length workload a static
batch moves at the pace of its SLOWEST member — every prompt pads to the
batch max, every row decodes until the longest generation finishes —
while the continuous-batching ``ServeEngine`` admits the next request
the moment a row frees and only ever processes real tokens.  This bench
serves the same request set both ways:

  * ``static``  — requests grouped ``batch_rows`` at a time in arrival
    order; each group pads prompts to its max length, runs one fused
    ``prefill_step``, then lockstep greedy decode for the group's max
    generation length (the pre-PR-10 ``launch/serve.py`` loop);
  * ``continuous`` — the ``ServeEngine`` over the paged cache, chunked
    prefill interleaved with decode under the token budget, greedy
    sampling so outputs are comparable.

Throughput is **useful** tokens (each request's real prompt + generated
tokens) over wall-clock, so static batching's padding and stall tokens
count against it as time, never as work.

``benchmarks/run.py --suite serve`` writes ``BENCH_serve.json``:

    {"workload": {"requests", "batch_rows", "prompt_lens", "gen_lens",
                  "useful_tokens"},
     "static":     {"seconds", "tokens_per_s"},
     "continuous": {"seconds", "tokens_per_s", "ttft_p50_ms",
                    "ttft_p95_ms", "tpot_p50_ms", "tpot_p95_ms",
                    "cache_occupancy_peak", "cache_occupancy_mean",
                    "preempted"},
     "speedup": continuous tokens_per_s / static tokens_per_s}

(Acceptance floor: speedup >= 1.5x on this container's mixed workload.)

Honest timing: both paths warm up first (one full serve of the workload,
so jit compiles never land in a measurement — the engine's two
compilations are reused across ``reset()``), each measured window is
best-of-3, and every window ends on materialized outputs
(``block_until_ready`` / host-side token lists).
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks._timing import csv_line

BATCH_ROWS = 8
PROMPT_LENS = (8, 16, 32)
# high-variance generation lengths — the continuous-batching story: most
# requests answer briefly, a few generate long, and a static batch holds
# EVERY row until its longest member finishes
GEN_LENS = (4, 8, 96)
NUM_REQUESTS = 24
BLOCK_SIZE = 16
MAX_SEQ = 128  # >= max prompt + max gen - 1


def _model():
    from repro.configs.base import get_config
    from repro.models.model import make_model

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"), num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
        remat="none",
    )
    return cfg, make_model(cfg, unroll=True)


def _workload(vocab_size: int):
    """The mixed-length request set: prompt/gen lengths cycle out of phase
    so groups of BATCH_ROWS always mix short and long requests."""
    from repro.serve import Request

    key = jax.random.key(7)
    reqs = []
    for i in range(NUM_REQUESTS):
        L = PROMPT_LENS[i % len(PROMPT_LENS)]
        g = GEN_LENS[(i // 2) % len(GEN_LENS)]
        toks = jax.random.randint(
            jax.random.fold_in(key, i), (L,), 0, vocab_size, dtype=jnp.int32
        )
        reqs.append(Request(
            rid=i + 1, prompt=tuple(int(t) for t in toks), max_new_tokens=g
        ))
    return reqs


def _useful_tokens(reqs) -> int:
    return sum(len(r.prompt) + r.max_new_tokens for r in reqs)


def bench_static(model, params, reqs) -> float:
    """-> best-of-3 seconds serving the workload with static batching."""
    from repro.launch.steps import make_serve_step

    prefill = jax.jit(model.prefill_step)
    serve = jax.jit(make_serve_step(model))
    groups = [reqs[i:i + BATCH_ROWS] for i in range(0, len(reqs), BATCH_ROWS)]

    def window() -> float:
        t0 = time.perf_counter()
        for group in groups:
            B = BATCH_ROWS
            L = max(len(r.prompt) for r in group)
            g = max(r.max_new_tokens for r in group)
            prompts = jnp.zeros((B, L), jnp.int32)
            for i, r in enumerate(group):
                # left-pad-free layout: prompt right-padded to the batch max
                prompts = prompts.at[i, :len(r.prompt)].set(
                    jnp.asarray(r.prompt, jnp.int32)
                )
            cache, _ = model.init_cache(B, L + g)
            logits, _, cache = prefill(
                params, cache, prompts, jnp.zeros((B,), jnp.int32)
            )
            tok = jnp.argmax(logits[:, -1:], -1).astype(jnp.int32)
            for t in range(L, L + g - 1):
                tok, cache = serve(params, cache, tok, jnp.int32(t))
            jax.block_until_ready(tok)
        return time.perf_counter() - t0

    window()  # warm: jit compiles for every (L, g) group shape
    return min(window() for _ in range(3))


def bench_continuous(model, params, reqs) -> tuple[float, dict]:
    """-> (best-of-3 seconds, final engine result) for the ServeEngine."""
    from repro.serve import ServeConfig, ServeEngine

    scfg = ServeConfig(
        batch_rows=BATCH_ROWS, prefill_chunk=32,
        token_budget=BATCH_ROWS + 32, block_size=BLOCK_SIZE,
        num_blocks=1 + BATCH_ROWS * (MAX_SEQ // BLOCK_SIZE),
        max_seq=MAX_SEQ, temperature=0.0, seed=0,
    )
    engine = ServeEngine(model, params, scfg, paged=True)
    engine.run(reqs)  # warm: the engine's two jit compiles

    best, result = None, None
    for _ in range(3):
        engine.reset()
        t0 = time.perf_counter()
        res = engine.run(reqs)
        dt = time.perf_counter() - t0
        if best is None or dt < best:
            best, result = dt, res
    return best, result


def main(json_path: str | None = None) -> list[str]:
    cfg, model = _model()
    params = model.init(jax.random.key(0))
    reqs = _workload(cfg.vocab_size)
    useful = _useful_tokens(reqs)

    static_s = bench_static(model, params, reqs)
    cont_s, res = bench_continuous(model, params, reqs)
    static_tps = useful / static_s
    cont_tps = useful / cont_s
    speedup = cont_tps / static_tps

    results = {
        "workload": {
            "requests": len(reqs), "batch_rows": BATCH_ROWS,
            "prompt_lens": list(PROMPT_LENS), "gen_lens": list(GEN_LENS),
            "useful_tokens": useful,
        },
        "static": {
            "seconds": round(static_s, 4),
            "tokens_per_s": round(static_tps, 1),
        },
        "continuous": {
            "seconds": round(cont_s, 4),
            "tokens_per_s": round(cont_tps, 1),
            "ttft_p50_ms": round(res["ttft_p50"] * 1e3, 2),
            "ttft_p95_ms": round(res["ttft_p95"] * 1e3, 2),
            "tpot_p50_ms": round(res["tpot_p50"] * 1e3, 2),
            "tpot_p95_ms": round(res["tpot_p95"] * 1e3, 2),
            "cache_occupancy_peak": round(res["cache_occupancy_peak"], 3),
            "cache_occupancy_mean": round(res["cache_occupancy_mean"], 3),
            "preempted": res["preempted"],
        },
        "speedup": round(speedup, 2),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return [
        csv_line("serve_static", static_s / useful * 1e6,
                 f"tok_per_s={static_tps:,.0f}"),
        csv_line("serve_continuous", cont_s / useful * 1e6,
                 f"tok_per_s={cont_tps:,.0f} speedup={speedup:.2f}x "
                 f"ttft_p50_ms={results['continuous']['ttft_p50_ms']}"),
    ]


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_serve.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(json_path="BENCH_serve.json" if args.json else None):
        print(line)
    if args.json:
        print("wrote BENCH_serve.json")
