"""Learner-pipeline benchmark (before/after for the donated Sebulba learner).

Two sections, written into ``BENCH_learner.json`` by
``benchmarks/run.py --suite learner``:

  * ``update`` — latency of one on-policy learner update at the B=4 and
    B=32 operating points, comparing

      - ``legacy`` — the pre-PR learner path, frozen here: the same
        shard_map'd update program jitted once but with NO buffer donation
        (params/opt_state double-buffer every call) and a fresh metrics
        pytree returned to host handles per update;
      - ``fused``  — the current path: compile-cached per trajectory
        shape, params/opt_state/trajectory/metrics-accumulator all
        donated, metrics folded into a device-resident accumulator.

    Compilation is hoisted out of every timed window (both variants are
    warmed up first, and all consumable inputs — fresh trajectories and
    params/opt_state copies — are created between, never inside, the
    timed loops; both variants get identical churn).  B=4 is the
    overhead-dominated operating point where the pipeline glue shows; at
    B=32 the update is conv-grad compute-bound on this CPU container
    (~95% of the 100+ ms step is XLA compute identical in both variants),
    so wall-clock sits at parity there and the structural win is the
    deterministic ``*_alloc_bytes_per_update`` / ``update_in_place``
    fields: donation rewrites params+opt_state in place instead of
    double-buffering them every update — the accelerator-regime saving
    (HBM allocation + copy) that CPU wall-clock cannot surface.

  * ``publish`` — parameter-publish transfers over a fixed update count,
    publish-every-update (pre-PR, ``publish_throttle=False``) vs the
    overlap-aware versioned publish, under a slow-actor regime where the
    actor consumes one publish in ``consume_every`` learner updates.  This
    is the regime a fast accelerator learner sits in (sub-ms updates,
    actors busy stepping envs); when actors consume every publish no skip
    triggers and both policies transfer identically.

``BENCH_learner.json`` schema:

    {"update": {"batch_<B>": {
         "legacy_us_per_update", "legacy_updates_per_s",
         "fused_us_per_update", "fused_updates_per_s", "speedup",
         "update_in_place": bool, "legacy_alloc_bytes_per_update",
         "fused_alloc_bytes_per_update", "actor_batch",
         "trajectory_length", "updates_per_window"}},
     "publish": {"actor_batch", "updates", "consume_every",
                 "legacy_transfers", "legacy_skipped", "legacy_bytes",
                 "throttled_transfers", "throttled_skipped",
                 "throttled_bytes", "param_bytes", "transfer_ratio"}}

(us/speedup fields are wall-clock and noisy on CPU; the ``*_alloc_bytes``
/ ``update_in_place`` / transfer-count fields are deterministic and are
the regression signal.)
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks._timing import csv_line

BATCHES = (4, 32)
TRAJ = 20
UPDATES = 20  # learner updates per timed window


def _build(batch: int, **cfg_overrides):
    from repro import optim
    from repro.agents.impala import ConvActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import HostPong

    net = ConvActorCritic(HostPong.num_actions, channels=(8,), blocks=1,
                          hidden=64)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: None,  # learner-only: no actor threads
        network=net,
        optimizer=optim.rmsprop(2e-4, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=batch, trajectory_length=TRAJ,
            **cfg_overrides,
        ),
    )
    params, opt_state = seb.init(jax.random.key(0), (16, 16, 1))
    return seb, params, opt_state


def _make_traj(seb, batch: int, seed: int):
    """A synthetic learner-sharded trajectory batch (same structure the
    actor drain produces)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.data.trajectory import Trajectory

    rng = np.random.RandomState(seed)
    sharding = NamedSharding(seb.learner_mesh, P("batch"))
    traj = Trajectory(
        obs=rng.rand(batch, TRAJ, 16, 16, 1).astype(np.float32),
        actions=rng.randint(0, 3, (batch, TRAJ)).astype(np.int32),
        rewards=rng.rand(batch, TRAJ).astype(np.float32),
        discounts=np.full((batch, TRAJ), 0.99, np.float32),
        behaviour_logp=np.log(
            rng.uniform(0.2, 0.9, (batch, TRAJ))
        ).astype(np.float32),
        bootstrap_obs=rng.rand(batch, 16, 16, 1).astype(np.float32),
    )
    return jax.tree.map(lambda x: jax.device_put(x, sharding), traj)


def _copy(tree):
    return jax.tree.map(jnp.copy, tree)


def bench_update(batch: int, updates: int = UPDATES, reps: int = 8) -> dict:
    """-> {legacy_us_per_update, fused_us_per_update, speedup, ...}.

    Both variants run the identical compiled math; the timed windows
    differ only in the pipeline around it (donation + accumulator).  Each
    window chains ``updates`` learner updates; windows alternate variant
    order every rep so container load drift hits both equally, and the
    per-variant minimum over all windows estimates the true floor.  Both
    windows get identical allocation churn (fresh trajectory copies built
    before the clock starts) so the donated variant's consumed inputs
    don't bias cache state.
    """
    seb, params0, opt0 = _build(batch)
    example = _make_traj(seb, batch, 0)

    # the pre-PR program: the identical shard_map'd update core, jitted
    # with no donation (this IS what `jax.jit(self._build_update())` ran)
    legacy = jax.jit(seb._build_update(example))
    fused, core = seb._get_update(example)
    macc0 = seb._fresh_macc(
        jax.eval_shape(core, params0, opt0, example)[2]
    )

    trajs = [_make_traj(seb, batch, 1 + i) for i in range(updates)]

    # compile both OUTSIDE the timed windows (donated warmup consumes its
    # inputs, so it gets private copies)
    jax.block_until_ready(legacy(params0, opt0, trajs[0]))
    jax.block_until_ready(
        fused(_copy(params0), _copy(opt0), _make_traj(seb, batch, 999),
              _copy(macc0))
    )

    def run_legacy() -> float:
        p, o = _copy(params0), _copy(opt0)
        fresh = [jax.tree.map(jnp.copy, t) for t in trajs]
        jax.block_until_ready((p, o, fresh))
        t0 = time.perf_counter()
        for traj in fresh:
            p, o, metrics = legacy(p, o, traj)
        jax.block_until_ready((p, metrics))
        return time.perf_counter() - t0

    def run_fused() -> float:
        p, o, macc = _copy(params0), _copy(opt0), _copy(macc0)
        fresh = [jax.tree.map(jnp.copy, t) for t in trajs]
        jax.block_until_ready((p, o, macc, fresh))
        t0 = time.perf_counter()
        for traj in fresh:
            p, o, macc = fused(p, o, traj, macc)
        jax.block_until_ready((p, macc))
        return time.perf_counter() - t0

    # paired estimator: each rep times both variants back to back (order
    # alternating), so slow container-load drift is common-mode within a
    # pair; the median of per-pair ratios is the speedup, robust to drift
    # that a min-over-windows estimator conflates with the variants
    pairs = []
    best = {"legacy": float("inf"), "fused": float("inf")}
    for r in range(reps):
        if r % 2 == 0:
            l, f = run_legacy(), run_fused()
        else:
            f, l = run_fused(), run_legacy()
        pairs.append(l / f)
        best["legacy"] = min(best["legacy"], l)
        best["fused"] = min(best["fused"], f)
    results = {}
    for name in ("legacy", "fused"):
        us = best[name] / updates * 1e6
        results[f"{name}_us_per_update"] = round(us, 1)
        results[f"{name}_updates_per_s"] = round(1e6 / us, 2)
    results["speedup"] = round(float(np.median(pairs)), 3)

    # deterministic (noise-free) structural costs of one update: bytes the
    # pre-PR path allocates for its double-buffered outputs vs the donated
    # path, which must write params/opt_state in place (asserted via
    # buffer pointers — the learner-state working set halves)
    state_bytes = sum(
        leaf.nbytes for leaf in jax.tree.leaves((params0, opt0))
    )
    p, o, macc = _copy(params0), _copy(opt0), _copy(macc0)
    in_ptrs = [leaf.unsafe_buffer_pointer()
               for leaf in jax.tree.leaves((p, o))]
    p2, o2, _ = fused(p, o, _make_traj(seb, batch, 1000), macc)
    out_ptrs = [leaf.unsafe_buffer_pointer()
                for leaf in jax.tree.leaves((p2, o2))]
    results["update_in_place"] = in_ptrs == out_ptrs
    results["legacy_alloc_bytes_per_update"] = state_bytes
    results["fused_alloc_bytes_per_update"] = (
        0 if in_ptrs == out_ptrs else state_bytes
    )
    results["actor_batch"] = batch
    results["trajectory_length"] = TRAJ
    results["updates_per_window"] = updates
    return results


def bench_publish(batch: int = 32, updates: int = 32,
                  consume_every: int = 4) -> dict:
    """Publish transfers over ``updates`` learner updates, actor consuming
    one publish per ``consume_every`` updates -> before/after counts."""
    out = {"actor_batch": batch, "updates": updates,
           "consume_every": consume_every}
    for name, throttle in (("legacy", False), ("throttled", True)):
        seb, params, _ = _build(batch, publish_throttle=throttle)
        param_bytes = sum(l.nbytes for l in jax.tree.leaves(params))
        base_sent = seb.publishes_sent  # init's forced publish
        for u in range(updates):
            if u % consume_every == 0:
                # the simulated (slow) actor picks up its standing slot
                seb._slot_consumed[0] = seb._param_slots[0][0]
            seb._publish_params(params)
        sent = seb.publishes_sent - base_sent
        out[f"{name}_transfers"] = sent
        out[f"{name}_skipped"] = seb.publishes_skipped
        out[f"{name}_bytes"] = sent * param_bytes
    out["param_bytes"] = param_bytes
    out["transfer_ratio"] = round(
        out["legacy_transfers"] / max(out["throttled_transfers"], 1), 2
    )
    return out


def csv_lines(results: dict) -> list[str]:
    lines = []
    for key, upd in results["update"].items():
        b = upd["actor_batch"]
        lines.append(csv_line(
            f"learner_update_legacy_b{b}", upd["legacy_us_per_update"],
            f"updates_per_s={upd['legacy_updates_per_s']}"))
        lines.append(csv_line(
            f"learner_update_fused_b{b}", upd["fused_us_per_update"],
            f"updates_per_s={upd['fused_updates_per_s']} "
            f"speedup={upd['speedup']}x"))
    pub = results["publish"]
    lines.append(csv_line(
        "learner_publish_transfers", 0.0,
        f"legacy={pub['legacy_transfers']} "
        f"throttled={pub['throttled_transfers']} "
        f"ratio={pub['transfer_ratio']}x "
        f"bytes_saved={pub['legacy_bytes'] - pub['throttled_bytes']:,}"))
    return lines


def main(json_path: str | None = None) -> list[str]:
    # B=4 windows are short, so drift within a legacy/fused pair is the
    # noise floor — many short pairs beat few long ones there
    points = {4: dict(updates=12, reps=16), 32: dict(updates=20, reps=8)}
    results = {
        "update": {
            f"batch_{b}": bench_update(batch=b, **points[b]) for b in BATCHES
        },
        "publish": bench_publish(),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return csv_lines(results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_learner.json")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(json_path="BENCH_learner.json" if args.json else None):
        print(line)
    if args.json:
        print("wrote BENCH_learner.json")
