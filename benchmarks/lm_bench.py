"""LM actor decode: fused KV-cache carry vs naive full-forward re-scoring.

The ISSUE 9 tentpole moves autoregressive generation into Sebulba's fused
act-step with the KV cache as the declared carry.  The alternative an LM
actor without a carry protocol is stuck with is re-scoring: every emitted
token re-runs the full causal forward over the context window and reads
the last position's logits.  This bench measures both at the actor's
shapes:

  * ``fused`` — ``model.decode_step`` behind the ``flash_decode`` wrapper,
    one token per step, KV cache + position threaded exactly like the
    ``LMPolicyAgent`` carry (sampling included, one donated jit per step);
  * ``naive`` — a full ``model.forward`` over the fixed ``SEQ_LEN``
    context per emitted token (the window stays fixed-shape so the naive
    path compiles ONCE; a growing prefix would retrace per length and
    unfairly charge compile time to it).

``benchmarks/run.py --suite lm`` writes ``BENCH_lm.json``:

    {"batch_<B>": {
         "fused_us_per_token", "fused_tokens_per_s",
         "naive_us_per_token", "naive_tokens_per_s",
         "speedup", "batch", "seq_len"},
     "model": {...}}

(``tokens_per_s`` = batch * 1e6 / us_per_token; ``speedup`` = naive us /
fused us.  Acceptance floor: >= 2x at B = 32 on this container.)

Honest timing: both paths warm up (jit compile never lands in a
measurement), each timed window is best-of-3, and both windows end on a
``block_until_ready``.  The model is a small dense GQA transformer (the
zoo's qwen2 template) so decode math, not host glue, dominates.
"""

from __future__ import annotations

import argparse
import dataclasses
import json
import time

import jax
import jax.numpy as jnp

from benchmarks._timing import csv_line

BATCHES = (4, 32)
SEQ_LEN = 128
MEASURE_TOKENS = 16


def _model():
    from repro.configs.base import get_config
    from repro.models.model import make_model

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"), num_layers=4, d_model=256, num_heads=4,
        num_kv_heads=2, head_dim=64, d_ff=1024, vocab_size=4096,
        remat="none",
    )
    return cfg, make_model(cfg, unroll=True)


def bench_fused(model, params, batch: int,
                tokens: int = MEASURE_TOKENS) -> float:
    """-> best-of-3 seconds for ``tokens`` decode-carry act steps."""

    def act(params, carry, obs, rng):
        rng, sub = jax.random.split(rng)
        logits, _, cache = model.decode_step(
            params, carry["cache"], obs.reshape(-1, 1), jnp.max(carry["pos"])
        )
        actions = jax.random.categorical(sub, logits[:, 0].astype(jnp.float32))
        return {"cache": cache, "pos": carry["pos"] + 1}, actions, rng

    step = jax.jit(act, donate_argnums=(1,))

    def window() -> float:
        cache, _ = model.init_cache(batch, SEQ_LEN)
        carry = {"cache": cache, "pos": jnp.zeros((batch,), jnp.int32)}
        obs = jnp.zeros((batch,), jnp.int32)
        rng = jax.random.key(0)
        t0 = time.perf_counter()
        for _ in range(tokens):
            carry, obs, rng = step(params, carry, obs, rng)
        jax.block_until_ready(obs)
        return time.perf_counter() - t0

    window()  # warm: jit compile
    return min(window() for _ in range(3))


def bench_naive(model, params, batch: int,
                tokens: int = MEASURE_TOKENS) -> float:
    """-> best-of-3 seconds for ``tokens`` full-forward re-scoring steps."""

    def rescore(params, context, pos, rng):
        rng, sub = jax.random.split(rng)
        logits, _, _ = model.forward(params, {"tokens": context})
        last = jnp.take_along_axis(
            logits, pos[None, None, None].repeat(batch, 0), axis=1
        )[:, 0].astype(jnp.float32)
        actions = jax.random.categorical(sub, last)
        context = jax.vmap(
            lambda c, a: c.at[pos + 1].set(a)
        )(context, actions)
        return context, pos + 1, rng

    step = jax.jit(rescore, donate_argnums=(1,))

    def window() -> float:
        context = jnp.zeros((batch, SEQ_LEN), jnp.int32)
        pos = jnp.int32(0)
        rng = jax.random.key(0)
        t0 = time.perf_counter()
        for _ in range(tokens):
            context, pos, rng = step(params, context, pos, rng)
        jax.block_until_ready(context)
        return time.perf_counter() - t0

    window()  # warm: jit compile
    return min(window() for _ in range(3))


def bench_batch(model, params, batch: int,
                tokens: int = MEASURE_TOKENS) -> dict:
    out = {"batch": batch, "seq_len": SEQ_LEN}
    for name, fn in (("fused", bench_fused), ("naive", bench_naive)):
        us = fn(model, params, batch, tokens) / tokens * 1e6
        out[f"{name}_us_per_token"] = round(us, 1)
        out[f"{name}_tokens_per_s"] = round(batch * 1e6 / us, 1)
    out["speedup"] = round(
        out["naive_us_per_token"] / out["fused_us_per_token"], 2
    )
    return out


def csv_lines(results: dict) -> list[str]:
    lines = []
    for key, r in results.items():
        if key == "model":
            continue
        b = r["batch"]
        lines.append(csv_line(
            f"lm_decode_naive_b{b}", r["naive_us_per_token"],
            f"tok_per_s={r['naive_tokens_per_s']:,}"))
        lines.append(csv_line(
            f"lm_decode_fused_b{b}", r["fused_us_per_token"],
            f"tok_per_s={r['fused_tokens_per_s']:,} "
            f"speedup={r['speedup']}x"))
    return lines


def main(json_path: str | None = None,
         tokens: int = MEASURE_TOKENS) -> list[str]:
    cfg, model = _model()
    params = model.init(jax.random.key(0))
    results = {
        f"batch_{b}": bench_batch(model, params, b, tokens) for b in BATCHES
    }
    results["model"] = {
        "arch": "qwen2 template", "num_layers": cfg.num_layers,
        "d_model": cfg.d_model, "vocab_size": cfg.vocab_size,
        "seq_len": SEQ_LEN,
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(results, f, indent=2)
    return csv_lines(results)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", action="store_true",
                    help="also write BENCH_lm.json")
    ap.add_argument("--tokens", type=int, default=MEASURE_TOKENS)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    for line in main(
        json_path="BENCH_lm.json" if args.json else None, tokens=args.tokens
    ):
        print(line)
    if args.json:
        print("wrote BENCH_lm.json")
