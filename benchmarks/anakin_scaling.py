"""Paper Fig. 4a — Anakin FPS as a function of device count.

The paper shows near-linear scaling 16 -> 128 TPU cores.  This container
has one physical CPU, so each point runs in a subprocess with
``--xla_force_host_platform_device_count=N`` placeholder devices: the point
is that the *same program* replicates across N devices with one config
change (the paper's claim), and the per-device work stays constant.  On
shared-CPU placeholders wall-clock FPS cannot exceed 1x, so we report both
raw FPS and per-device efficiency; real-hardware scaling is projected in
EXPERIMENTS.md from the collective-term roofline.

Output: ``anakin_scale_<N>dev`` CSV lines (us/step + fps/efficiency in the
derived column); no BENCH json — the scaling figure is a paper-shape
check, not a regression trajectory.  Honest timing: each subprocess warms
its compiled step before its timed window, so jit compile never lands in
a measurement (the shared rule for every suite in this directory).
"""

from __future__ import annotations

import os
import subprocess
import sys
import textwrap

_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
    import sys; sys.path.insert(0, {src!r})
    import time, jax
    from repro.core.anakin import Anakin, AnakinConfig
    from repro.agents.actor_critic import MLPActorCritic
    from repro.envs import Catch
    from repro import optim

    env = Catch()
    net = MLPActorCritic(env.num_actions, (64, 64))
    ank = Anakin(env, net, optim.adam(3e-3, clip_norm=1.0),
                 AnakinConfig(unroll_length=10, batch_per_device=32,
                              iterations_per_call=20))
    state = ank.init_state(jax.random.key(0))
    state, _ = ank.run(state)  # compile
    jax.block_until_ready(state)
    t0 = time.time()
    calls = 3
    for _ in range(calls):
        state, _ = ank.run(state)
    jax.block_until_ready(state)
    dt = time.time() - t0
    print("RESULT", ank.steps_per_call * calls / dt)
    """
)


def measure(n_devices: int) -> float:
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    out = subprocess.run(
        [sys.executable, "-c", _SCRIPT.format(n=n_devices, src=src)],
        capture_output=True, text=True, timeout=600, env=env,
    )
    if out.returncode != 0:
        raise RuntimeError(out.stderr[-2000:])
    for line in out.stdout.splitlines():
        if line.startswith("RESULT"):
            return float(line.split()[1])
    raise RuntimeError("no result line")


def main(device_counts=(1, 2, 4, 8)) -> list[str]:
    lines = []
    base = None
    for n in device_counts:
        fps = measure(n)
        base = base or fps
        lines.append(
            f"anakin_scaling_d{n},{1e6 / fps:.3f},"
            f"fps={fps:,.0f} rel={fps / base:.2f} per_dev={fps / n:,.0f}"
        )
        print(lines[-1], flush=True)
    return lines


if __name__ == "__main__":
    main()
