"""Optimizer library tests (unit + hypothesis properties)."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro import optim


def test_adam_converges_quadratic():
    opt = optim.adam(0.1)
    params = {"x": jnp.array([5.0, -3.0])}
    state = opt.init(params)
    for _ in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        updates, state = opt.update(grads, state, params)
        params = optim.apply_updates(params, updates)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


@given(st.integers(0, 2**31 - 1), st.floats(0.1, 10.0))
@settings(max_examples=25, deadline=None)
def test_clip_by_global_norm_property(seed, max_norm):
    rng = np.random.RandomState(seed)
    grads = {"a": jnp.asarray(rng.randn(7)), "b": jnp.asarray(rng.randn(3, 2))}
    clip = optim.clip_by_global_norm(max_norm)
    out, _ = clip.update(grads, clip.init(grads))
    norm = float(optim.global_norm(out))
    assert norm <= max_norm * (1 + 1e-4)
    # direction preserved
    ratio = float(out["a"][0] / grads["a"][0]) if abs(grads["a"][0]) > 1e-6 else 1.0
    assert ratio >= 0


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=15, deadline=None)
def test_sgd_matches_manual(seed):
    rng = np.random.RandomState(seed)
    g = jnp.asarray(rng.randn(5))
    opt = optim.sgd(0.5)
    state = opt.init({"w": g})
    updates, _ = opt.update({"w": g}, state)
    np.testing.assert_allclose(updates["w"], -0.5 * g, rtol=1e-6)


def test_adam_moments_dtype_follows_params():
    params = {"w": jnp.zeros((4,), jnp.bfloat16)}
    opt = optim.adam(1e-3)
    state = opt.init(params)
    adam_state = state[0]
    assert adam_state.mu["w"].dtype == jnp.bfloat16


def test_schedule_warmup_cosine():
    sched = optim.warmup_cosine(1.0, warmup=10, total_steps=110)
    assert float(sched(jnp.int32(0))) == 0.0
    assert abs(float(sched(jnp.int32(10))) - 1.0) < 1e-6
    assert float(sched(jnp.int32(110))) < 1e-6


def test_rmsprop_step_finite():
    opt = optim.rmsprop(1e-2, clip_norm=1.0)
    params = {"w": jnp.ones((3,))}
    state = opt.init(params)
    updates, state = opt.update({"w": jnp.ones((3,))}, state, params)
    assert bool(jnp.isfinite(updates["w"]).all())


def test_state_shardings_structure():
    params = {"w": jnp.zeros((4,)), "b": jnp.zeros((2,))}
    opt = optim.adam(1e-3, clip_norm=1.0)
    state = opt.init(params)
    p_shard = {"w": "WSHARD", "b": "BSHARD"}
    s = optim.state_shardings(state, p_shard, "REP")
    flat = jax.tree.leaves(s, is_leaf=lambda x: isinstance(x, str))
    assert "WSHARD" in flat and "REP" in flat
