"""Model-layer unit tests: attention variants, MoE dispatch, rope, norms."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.ref import attention_ref
from repro.models import attention as attn
from repro.models import layers
from repro.models import moe as moe_lib
from repro.param import ParamBuilder


def test_chunked_attention_matches_naive():
    ks = jax.random.split(jax.random.key(0), 3)
    B, T, H, K, h = 2, 96, 4, 2, 32
    q = jax.random.normal(ks[0], (B, T, H, h))
    k = jax.random.normal(ks[1], (B, T, K, h))
    v = jax.random.normal(ks[2], (B, T, K, h))
    out = attn.full_attention(q, k, v, causal=True, chunk=32)
    ref = attention_ref(q, k, v, causal=True)
    assert jnp.abs(out - ref).max() < 1e-4


def test_sliding_window_attention_matches_masked():
    ks = jax.random.split(jax.random.key(1), 3)
    B, T, H, K, h, W = 1, 128, 2, 1, 32, 32
    q = jax.random.normal(ks[0], (B, T, H, h))
    k = jax.random.normal(ks[1], (B, T, K, h))
    v = jax.random.normal(ks[2], (B, T, K, h))
    out = attn.sliding_window_attention(q, k, v, window=W)
    ref = attention_ref(q, k, v, causal=True, window=W)
    assert jnp.abs(out - ref).max() < 1e-4


def test_decode_attention_matches_last_row():
    ks = jax.random.split(jax.random.key(2), 3)
    B, S, H, K, h = 2, 64, 4, 2, 32
    q1 = jax.random.normal(ks[0], (B, 1, H, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    # decode at pos = S-1 == full attention over the cache
    out = attn.decode_attention(q1, kc, vc, jnp.int32(S - 1))
    ref = attention_ref(q1, kc, vc, causal=False)
    assert jnp.abs(out - ref).max() < 1e-4


def test_decode_attention_per_row_positions_match_scalar():
    """ISSUE 10: decode with a (B,) position vector (the serving decode
    dispatch) is bit-exact with scalar-position decode row by row."""
    ks = jax.random.split(jax.random.key(11), 3)
    B, S, H, K, h = 3, 16, 4, 2, 8
    q1 = jax.random.normal(ks[0], (B, 1, H, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    pos = jnp.array([0, 5, 15], jnp.int32)
    out = attn.decode_attention(q1, kc, vc, pos)
    for b in range(B):
        row = attn.decode_attention(
            q1[b : b + 1], kc[b : b + 1], vc[b : b + 1],
            jnp.int32(int(pos[b])),
        )
        assert jnp.array_equal(out[b : b + 1], row)


def test_chunk_decode_attention_matches_sequential_decode():
    """ISSUE 10: a (B, C) prefill chunk attending over the cache (chunk
    K/V already written) is bit-exact with C single-token decode steps at
    per-row staggered positions."""
    ks = jax.random.split(jax.random.key(12), 3)
    B, C, S, H, K, h = 3, 4, 16, 4, 2, 8
    q = jax.random.normal(ks[0], (B, C, H, h))
    kc = jax.random.normal(ks[1], (B, S, K, h))
    vc = jax.random.normal(ks[2], (B, S, K, h))
    pos = jnp.array([0, 3, 7], jnp.int32)
    out = attn.chunk_decode_attention(q, kc, vc, pos)
    for i in range(C):
        step = attn.decode_attention(q[:, i : i + 1], kc, vc, pos + i)
        assert jnp.array_equal(out[:, i : i + 1], step)


def test_update_paged_kv_cache_routes_oob_to_scratch():
    """Out-of-range chunk positions (padded prefill tails, idle rows at
    pos = max_seq) land on reserved page 0; in-range writes land exactly
    where the block table maps them."""
    B, C, K, h, bs, nb = 2, 2, 1, 4, 4, 2
    P = 1 + B * nb
    kp = jnp.zeros((P, bs, K, h))
    vp = jnp.zeros((P, bs, K, h))
    tables = jnp.array([[1, 2], [3, 4]], jnp.int32)
    k = jnp.ones((B, C, K, h))
    v = jnp.full((B, C, K, h), 2.0)
    # row 0 writes slots 3..4 (pages 1 then 2); row 1 is idle at max_seq
    pos = jnp.array([3, nb * bs], jnp.int32)
    kp2, vp2 = attn.update_paged_kv_cache(kp, vp, k, v, tables, pos)
    assert kp2[1, 3].max() == 1 and kp2[2, 0].max() == 1
    assert vp2[2, 0].max() == 2
    assert jnp.abs(kp2[3:]).max() == 0  # idle row touched only scratch
    assert jnp.abs(kp2[1, :3]).max() == 0 and jnp.abs(kp2[2, 1:]).max() == 0


def test_rope_relative_property():
    """RoPE inner products depend only on relative positions."""
    k1, k2 = jax.random.split(jax.random.key(3))
    q = jax.random.normal(k1, (1, 1, 1, 64))
    k = jax.random.normal(k2, (1, 1, 1, 64))
    def score(qp, kp):
        qr = layers.apply_rope(q, jnp.array([qp]), 10_000.0)
        kr = layers.apply_rope(k, jnp.array([kp]), 10_000.0)
        return float(jnp.sum(qr * kr))
    assert abs(score(5, 3) - score(105, 103)) < 1e-3
    assert abs(score(5, 3) - score(6, 3)) > 1e-4  # sanity: not constant


def test_rms_norm_scale_invariance():
    b = ParamBuilder(jax.random.key(0))
    layers.init_rms_norm(b, "n", 32)
    params, _ = b.build()
    x = jax.random.normal(jax.random.key(1), (4, 32))
    y1 = layers.rms_norm(params["n"], x)
    y2 = layers.rms_norm(params["n"], x * 100.0)
    assert jnp.abs(y1 - y2).max() < 1e-3


# ------------------------------------------------------------------ MoE


def _moe_setup(E=4, k=2, shared=0, d=32, f=16, N=64):
    dims = moe_lib.MoEDims(d, f, E, k, shared, 4.0)  # big cf: no drops
    b = ParamBuilder(jax.random.key(0))
    moe_lib.init_moe(b, "moe", dims)
    params, _ = b.build()
    x = jax.random.normal(jax.random.key(1), (2, N // 2, d))
    return params["moe"], x, dims


def test_moe_sort_matches_dense_dispatch():
    params, x, dims = _moe_setup()
    out_s, aux_s = moe_lib.moe_ffn(params, x, dims, impl="sort")
    out_d, aux_d = moe_lib.moe_ffn(params, x, dims, impl="dense")
    assert jnp.abs(out_s - out_d).max() < 1e-4
    assert abs(float(aux_s - aux_d)) < 1e-5


def test_moe_shared_experts_always_active():
    params, x, dims = _moe_setup(shared=1)
    out, _ = moe_lib.moe_ffn(params, x, dims, impl="sort")
    # zero the router: routed contribution changes, shared stays
    params2 = dict(params, router=params["router"] * 0.0)
    out2, _ = moe_lib.moe_ffn(params2, x, dims, impl="sort")
    assert out.shape == x.shape
    assert bool(jnp.isfinite(out).all()) and bool(jnp.isfinite(out2).all())


def test_moe_capacity_drops_tokens_not_nan():
    params, x, dims = _moe_setup()
    dims = dims._replace(capacity_factor=0.25)  # force drops
    out, aux = moe_lib.moe_ffn(params, x, dims, impl="sort")
    assert bool(jnp.isfinite(out).all())
    assert bool(jnp.isfinite(aux))


def test_moe_aux_loss_balanced_is_lower():
    """Uniform routing gives (near-)minimal aux loss."""
    params, x, dims = _moe_setup(E=4, k=1)
    logits_uniform = jnp.zeros((x.shape[0] * x.shape[1], 4))
    # aux for uniform probs = E * sum(frac * 1/E) = 1
    probs = jax.nn.softmax(logits_uniform, -1)
    frac = jnp.array([0.25] * 4)
    aux_uniform = 4 * jnp.sum(frac * probs.mean(0))
    assert abs(float(aux_uniform) - 1.0) < 1e-5


def test_moe_grads_flow_to_router():
    params, x, dims = _moe_setup()

    def loss(p):
        out, aux = moe_lib.moe_ffn(p, x, dims, impl="sort")
        return jnp.sum(out**2) + aux

    g = jax.grad(loss)(params)
    assert float(jnp.abs(g["router"]).max()) > 0.0
    assert float(jnp.abs(g["w_gate"]).max()) > 0.0


# ---------------------------------------------- prefill/decode parity (LM)


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,unroll",
    [
        ("qwen2-1.5b", False),   # dense, stacked scan-over-layers
        ("qwen2-1.5b", True),    # dense, looped (the LMPolicyAgent layout)
        ("deepseek-moe-16b", True),   # moe family
        ("mamba2-1.3b", True),        # ssm family
    ],
)
def test_prefill_decode_step_logit_parity(arch, unroll):
    """ISSUE 9 satellite: autoregressive ``decode_step`` (the LM agent's
    act hot loop, flash_decode path included) reproduces the full causal
    prefill logits position by position across the zoo families.

    float32 params/cache so the pin is on the MATH, not on bf16 rounding;
    the MoE capacity factor is raised so prefill routing drops no tokens
    (decode routes one token per step and never drops — a capacity-dropped
    prefill token is a real, expected divergence, not a decode bug).
    """
    import dataclasses

    import numpy as np

    from repro.configs.base import get_reduced_config
    from repro.models.model import make_model

    cfg = dataclasses.replace(
        get_reduced_config(arch), param_dtype="float32",
        cache_dtype="float32", remat="none",
    )
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = make_model(cfg, unroll=unroll)
    params = model.init(jax.random.key(0))
    B, T = 2, 8
    tokens = jax.random.randint(
        jax.random.key(1), (B, T), 0, cfg.vocab_size, dtype=jnp.int32
    )
    ref_logits, ref_values, _ = model.forward(params, {"tokens": tokens})

    cache, _ = model.init_cache(B, T)
    step = jax.jit(model.decode_step)
    dec_logits, dec_values = [], []
    for t in range(T):
        lg, vv, cache = step(params, cache, tokens[:, t : t + 1], jnp.int32(t))
        dec_logits.append(lg[:, 0])
        dec_values.append(vv[:, 0])
    np.testing.assert_allclose(
        np.asarray(jnp.stack(dec_logits, axis=1)), np.asarray(ref_logits),
        atol=1e-4, rtol=1e-4,
    )
    np.testing.assert_allclose(
        np.asarray(jnp.stack(dec_values, axis=1)), np.asarray(ref_values),
        atol=1e-4, rtol=1e-4,
    )


@pytest.mark.slow
@pytest.mark.parametrize(
    "arch,unroll",
    [
        ("qwen2-1.5b", False),
        ("qwen2-1.5b", True),
        ("deepseek-moe-16b", True),
    ],
)
def test_prefill_step_matches_forward(arch, unroll):
    """ISSUE 10 satellite: the fused chunked-prefill step (what
    ``examples/serve_lm.py`` and the ServeEngine now route prompts
    through, replacing the old teacher-forced decode loop) reproduces the
    full causal forward logits — both as one whole-prompt chunk and as
    two carried 4-token chunks."""
    import dataclasses

    import numpy as np

    from repro.configs.base import get_reduced_config
    from repro.models.model import make_model

    cfg = dataclasses.replace(
        get_reduced_config(arch), param_dtype="float32",
        cache_dtype="float32", remat="none",
    )
    if cfg.family == "moe":
        cfg = dataclasses.replace(cfg, moe_capacity_factor=8.0)
    model = make_model(cfg, unroll=unroll)
    params = model.init(jax.random.key(0))
    B, T = 2, 8
    tokens = jax.random.randint(
        jax.random.key(1), (B, T), 0, cfg.vocab_size, dtype=jnp.int32
    )
    ref_logits, ref_values, _ = model.forward(params, {"tokens": tokens})

    cache, _ = model.init_cache(B, T)
    logits, values, _ = model.prefill_step(
        params, cache, tokens, jnp.zeros((B,), jnp.int32)
    )
    np.testing.assert_allclose(np.asarray(logits), np.asarray(ref_logits),
                               atol=1e-4, rtol=1e-4)
    np.testing.assert_allclose(np.asarray(values), np.asarray(ref_values),
                               atol=1e-4, rtol=1e-4)

    cache, _ = model.init_cache(B, T)
    chunks = []
    for c in range(0, T, 4):
        lg, _, cache = model.prefill_step(
            params, cache, tokens[:, c : c + 4],
            jnp.full((B,), c, jnp.int32),
        )
        chunks.append(lg)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate(chunks, axis=1)), np.asarray(ref_logits),
        atol=1e-4, rtol=1e-4,
    )


@pytest.mark.slow
def test_unrolled_decode_cache_is_batch_leading():
    """The ``unroll=True`` cache layout contract the Sebulba carry protocol
    depends on: every leaf is batch-leading (episode-reset broadcast and
    ``split_for_learners`` both act on axis 0)."""
    import dataclasses

    from repro.configs.base import get_reduced_config
    from repro.models.model import make_model

    for arch in ("qwen2-1.5b", "deepseek-moe-16b", "mamba2-1.3b"):
        cfg = dataclasses.replace(get_reduced_config(arch), remat="none")
        model = make_model(cfg, unroll=True)
        B = 3
        cache, _ = model.init_cache(B, 8)
        for path, leaf in jax.tree_util.tree_flatten_with_path(cache)[0]:
            assert leaf.shape[0] == B, (
                arch, jax.tree_util.keystr(path), leaf.shape
            )
