"""ISSUE 7 — deterministic fault injection and durable checkpointing.

  * ``FaultPlan.random`` is a pure function of its arguments: same seed,
    same schedule; different seed, different schedule (chaos tests are
    ordinary regression tests);
  * actor injectors fire their scheduled kinds at their scheduled per-SLOT
    steps — and exactly once across incarnations (the counter survives a
    restart);
  * ``FaultyHostEnv`` raises from the env step on schedule and passes
    through otherwise;
  * checkpoint writes are atomic: a killed write leaves tmp debris and NO
    stamp, a torn write lands but is rejected by the embedded checksum;
  * directory restore falls back newest-to-oldest over damaged stamps and
    reports the skip count (``meta["fallbacks"]`` →
    ``checkpoint_fallbacks``);
  * ``resolve_auto_resume`` scans checkpoint_dir, starts fresh on empty,
    and refuses ambiguous recovery sources.
"""

import os

import numpy as np
import pytest

from repro import api
from repro.checkpoint import CheckpointCorruptError, restore, save
from repro.fault import (
    ActorFaultInjector,
    FaultEvent,
    FaultPlan,
    FaultyHostEnv,
    InjectedCheckpointKill,
    InjectedCrash,
    InjectedEnvError,
)


# -------------------------------------------------------------- plan


def test_fault_plan_is_deterministic():
    kwargs = dict(
        actors=3, horizon=50, crash_rate=0.1, hang_rate=0.05,
        slow_rate=0.1, env_error_rate=0.05, ckpt_kill_every=7,
    )
    a = FaultPlan.random(123, **kwargs)
    b = FaultPlan.random(123, **kwargs)
    assert a.events == b.events and a.seed == 123
    c = FaultPlan.random(124, **kwargs)
    assert c.events != a.events


def test_fault_plan_warmup_protects_early_steps():
    plan = FaultPlan.random(
        0, actors=2, horizon=30, crash_rate=0.5, warmup=5
    )
    assert plan.events, "a 0.5 rate over 2x25 draws must schedule something"
    assert all(e.step >= 5 for e in plan.events)


def test_fault_event_validation():
    with pytest.raises(ValueError):
        FaultEvent(kind="meteor", target="actor:0", step=1)
    with pytest.raises(ValueError):
        FaultEvent(kind="crash", target="actor:0", step=-1)
    with pytest.raises(ValueError):
        FaultEvent(kind="ckpt_kill", target="actor:0", step=1)


def test_for_target_and_injector_scoping():
    plan = FaultPlan(events=(
        FaultEvent(kind="crash", target="actor:0", step=3),
        FaultEvent(kind="env_error", target="actor:1", step=2),
        FaultEvent(kind="ckpt_kill", target="checkpoint", step=0),
    ))
    assert len(plan.for_target("actor:0")) == 1
    assert plan.actor_injector(0) is not None
    assert plan.actor_injector(1) is not None
    assert plan.actor_injector(2) is None, "no events -> no injector"
    assert plan.checkpoint_injector() is not None
    assert plan.env_injector() is None


# ---------------------------------------------------------- actor injector


def test_actor_injector_fires_on_schedule_exactly_once():
    inj = ActorFaultInjector([FaultEvent(kind="crash", target="actor:0", step=2)])
    inj.tick()
    inj.tick()
    with pytest.raises(InjectedCrash):
        inj.tick()
    # the slot counter moved past the event: a restarted incarnation
    # sharing this injector runs clean from here on
    for _ in range(20):
        inj.tick()
    assert [e.kind for e in inj.fired] == ["crash"]


def test_actor_injector_slow_is_latency_not_failure():
    inj = ActorFaultInjector([
        FaultEvent(kind="slow", target="actor:0", step=1, seconds=0.01, span=2),
    ])
    import time

    t0 = time.monotonic()
    for _ in range(4):
        inj.tick()
    assert time.monotonic() - t0 >= 0.02
    assert not inj.fired or all(e.kind == "slow" for e in inj.fired)


def test_actor_injector_hang_wakes_on_cancel_and_unwinds():
    import threading

    inj = ActorFaultInjector([FaultEvent(kind="hang", target="actor:0", step=0)])
    cancel = threading.Event()
    raised = {}

    def body():
        try:
            inj.tick(cancel=cancel)
        except InjectedCrash as e:
            raised["e"] = e

    t = threading.Thread(target=body, daemon=True)
    t.start()
    t.join(timeout=0.2)
    assert t.is_alive(), "hang must block while cancel is unset"
    cancel.set()
    t.join(timeout=5.0)
    assert not t.is_alive() and "e" in raised


# ------------------------------------------------------------- host env


class _CountingEnv:
    num_actions = 2
    obs_shape = (3,)

    def __init__(self):
        self.steps = 0
        self.closed = False

    def reset(self):
        return np.zeros(self.obs_shape, np.float32)

    def step(self, action):
        self.steps += 1
        return np.zeros(self.obs_shape, np.float32), 0.0, False, {}

    def close(self):
        self.closed = True


def test_faulty_host_env_raises_on_schedule():
    plan = FaultPlan(events=(
        FaultEvent(kind="env_error", target="env", step=2),
    ))
    inner = _CountingEnv()
    env = FaultyHostEnv(inner, plan.env_injector())
    assert env.num_actions == 2 and env.obs_shape == (3,)
    env.reset()
    env.step(0)
    env.step(1)
    with pytest.raises(InjectedEnvError):
        env.step(0)
    env.step(1)  # past the schedule: clean again
    assert inner.steps == 3  # the injected step never reached the inner env
    env.close()
    assert inner.closed


# ------------------------------------------------------ durable checkpoints


def _params():
    return {"w": np.arange(6, dtype=np.float32).reshape(2, 3),
            "b": np.zeros(3, np.float32)}


def test_save_is_atomic_under_kill(tmp_path):
    d = str(tmp_path)
    api.save_checkpoint(d, _params(), param_version=1, updates=1, frames=8)
    plan = FaultPlan(events=(
        FaultEvent(kind="ckpt_kill", target="checkpoint", step=0),
    ))
    inj = plan.checkpoint_injector()
    with pytest.raises(InjectedCheckpointKill):
        api.save_checkpoint(
            d, _params(), param_version=2, updates=2, frames=16, fault=inj,
        )
    # the kill left tmp debris but NO v2 stamp — and the v1 stamp still
    # restores, untouched by the failed write
    stamps = api.checkpoint_stamps(d)
    assert [v for v, _ in stamps] == [1]
    assert any(n.endswith(".tmp") for n in os.listdir(d))
    _, meta = api.restore_checkpoint(d, _params())
    assert meta["param_version"] == 1 and meta["fallbacks"] == 0


def test_torn_write_is_detected_and_skipped(tmp_path):
    d = str(tmp_path)
    p = _params()
    api.save_checkpoint(d, p, param_version=1, updates=1, frames=8)
    plan = FaultPlan(events=(
        FaultEvent(kind="ckpt_corrupt", target="checkpoint", step=0),
    ))
    api.save_checkpoint(
        d, {k: v + 1 for k, v in p.items()}, param_version=2, updates=2,
        frames=16, fault=plan.checkpoint_injector(),
    )
    stamps = api.checkpoint_stamps(d)
    assert [v for v, _ in stamps] == [2, 1], "the torn write DID land"
    torn = stamps[0][1]
    like = {"params": p, "meta": {"param_version": 0, "updates": 0, "frames": 0}}
    with pytest.raises(CheckpointCorruptError):
        restore(torn, like)
    # directory restore falls back to the newest VALID stamp and counts it
    restored, meta = api.restore_checkpoint(d, p)
    assert meta["param_version"] == 1 and meta["fallbacks"] == 1
    np.testing.assert_array_equal(restored["w"], p["w"])


def test_checksum_rejects_bit_flip(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save(path, {"x": np.arange(16, dtype=np.float32)})
    data = bytearray(open(path, "rb").read())
    # flip a byte deep in the payload (past the zip directory headers)
    data[len(data) // 2] ^= 0xFF
    open(path, "wb").write(bytes(data))
    with pytest.raises(CheckpointCorruptError):
        restore(path, {"x": np.zeros(16, np.float32)})


def test_all_damaged_raises_corrupt_not_missing(tmp_path):
    d = str(tmp_path)
    plan = FaultPlan(events=(
        FaultEvent(kind="ckpt_corrupt", target="checkpoint", step=0),
    ))
    api.save_checkpoint(
        d, _params(), param_version=1, updates=1, frames=8,
        fault=plan.checkpoint_injector(),
    )
    with pytest.raises(CheckpointCorruptError):
        api.restore_checkpoint(d, _params())


def test_resolve_auto_resume_contract(tmp_path):
    d = str(tmp_path)
    # empty dir -> fresh start
    assert api.resolve_auto_resume(None, d, True) is None
    api.save_checkpoint(d, _params(), param_version=3, updates=3, frames=24)
    assert api.resolve_auto_resume(None, d, True) == d
    # off -> passthrough
    assert api.resolve_auto_resume("elsewhere", d, False) == "elsewhere"
    with pytest.raises(ValueError):
        api.resolve_auto_resume("elsewhere", d, True)
    with pytest.raises(ValueError):
        api.resolve_auto_resume(None, None, True)
