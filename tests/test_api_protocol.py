"""ISSUE 5 acceptance: the unified repro.api agent/runner protocol.

  * every registered agent passes ``AgentSpec`` validation and resolves
    WITHOUT the legacy adapter (the zoo is fully migrated);
  * the act/initial_carry shape-and-dtype contract holds: actions (B,),
    float logp (B,), extras keyed exactly by ``AgentSpec.extras_keys``,
    carry out mirroring carry in;
  * ``loss(weights=None)`` equals explicit-ones weights for replay agents
    (the canonical "None means unweighted" pin) and on-policy agents
    reject weights with a fix-it error;
  * ``core/sebulba.py`` contains no runtime arity sniffing or class-marker
    checks — all agent validation goes through ``repro.api``;
  * the ``run()``/``fit()`` result schema is one dict across on-policy
    Sebulba, off-policy Sebulba, and Anakin (absent counters 0, never
    missing);
  * runner-owned checkpointing: ``checkpoint_every`` writes
    ``param_version``-stamped files and ``restore_from`` round-trips.
"""

import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, optim
from repro.envs import BatchedHostEnv, Catch, HostBandit

B, T = 4, 6

# LM fixtures trace a real (if toy) transformer through act and loss —
# jit-heavy enough to stay out of the fast tier with the rest of the LM
# surface (ISSUE 9 satellite), without losing conformance coverage.
_PARAMS = [
    pytest.param(n, marks=pytest.mark.slow) if n.startswith("lm_") else n
    for n in api.registered_agents()
]


@pytest.fixture(scope="module", params=_PARAMS)
def fixture(request):
    return request.param, api.make_agent(request.param)


def _obs_dtype(fx):
    return jnp.float32 if fx.obs_dtype is None else fx.obs_dtype


def _random_obs(rng, shape, dtype, num_actions):
    """np.RandomState -> obs array; integer dtypes mean token observations
    bounded by the vocabulary (= num_actions for LM agents)."""
    if jnp.issubdtype(dtype, jnp.integer):
        return jnp.asarray(rng.randint(0, num_actions, shape), dtype)
    return jnp.asarray(rng.rand(*shape), dtype)


def _act(agent, obs_shape, batch=B, seed=0, obs_dtype=jnp.float32,
         num_actions=4):
    params = agent.init(jax.random.key(seed), obs_shape)
    carry = agent.initial_carry(batch)
    obs = _random_obs(
        np.random.RandomState(seed + 1), (batch,) + obs_shape, obs_dtype,
        num_actions,
    )
    actions, aux, new_carry = jax.jit(agent.act)(
        params, obs, jax.random.key(seed + 2), carry
    )
    return params, carry, actions, aux, new_carry


def _make_traj(agent, spec, params, obs_shape, num_actions, seed=0,
               obs_dtype=jnp.float32):
    """Synthetic trajectory matching the agent's declared surface, shaped
    exactly as the actor ring would drain it (extras from act's abstract
    output, init_carry from initial_carry)."""
    from repro.data.trajectory import Trajectory

    rng = np.random.RandomState(seed)
    carry_spec = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype),
        agent.initial_carry(B),
    )
    obs_spec = jax.ShapeDtypeStruct((B,) + obs_shape, obs_dtype)
    _, aux_spec, _ = jax.eval_shape(
        agent.act, params, obs_spec, jax.random.key(0), carry_spec
    )
    extras = jax.tree.map(
        lambda s: jnp.asarray(
            rng.rand(s.shape[0], T, *s.shape[1:]), s.dtype
        ),
        aux_spec.extras,
    )
    init_carry = jax.tree.map(
        lambda s: jnp.asarray(rng.rand(*s.shape), s.dtype), carry_spec
    )
    return Trajectory(
        obs=_random_obs(rng, (B, T) + obs_shape, obs_dtype, num_actions),
        actions=jnp.asarray(rng.randint(0, num_actions, (B, T)), jnp.int32),
        rewards=jnp.asarray(rng.rand(B, T), jnp.float32),
        discounts=jnp.full((B, T), 0.99, jnp.float32),
        behaviour_logp=jnp.asarray(
            np.log(rng.uniform(0.2, 0.9, (B, T))), jnp.float32
        ),
        bootstrap_obs=_random_obs(rng, (B,) + obs_shape, obs_dtype,
                                  num_actions),
        extras=extras,
        init_carry=init_carry,
    )


# ------------------------------------------------------- spec conformance


def test_registry_covers_the_zoo():
    names = api.registered_agents()
    for expected in ("impala", "actor_critic", "ppo", "muzero",
                     "replay_impala", "recurrent_impala",
                     "recurrent_replay_impala", "lm_policy",
                     "lm_replay_policy"):
        assert expected in names


def test_agent_resolves_without_legacy_adapter(fixture):
    name, fx = fixture
    assert isinstance(fx.agent.spec, api.AgentSpec), name
    resolved, spec = api.resolve_agent(fx.agent)
    assert resolved is fx.agent, (
        f"{name} should resolve natively, not through the migration shim"
    )
    assert spec is fx.agent.spec
    assert not api.is_legacy_adapter(resolved)


def test_act_contract_shapes_and_dtypes(fixture):
    name, fx = fixture
    spec = fx.agent.spec
    params, carry, actions, aux, new_carry = _act(
        fx.agent, fx.obs_shape, obs_dtype=_obs_dtype(fx),
        num_actions=fx.num_actions,
    )
    assert actions.shape == (B,), name
    assert jnp.issubdtype(actions.dtype, jnp.integer), name
    assert isinstance(aux, api.ActAux), name
    assert aux.logp.shape == (B,), name
    assert jnp.issubdtype(aux.logp.dtype, jnp.floating), name
    # extras keyed exactly by the declaration
    if spec.extras_keys:
        assert sorted(aux.extras) == sorted(spec.extras_keys), name
        for leaf in jax.tree.leaves(aux.extras):
            assert leaf.shape[0] == B, name
    else:
        assert jax.tree.leaves(aux.extras) == [], name
    # carry out mirrors carry in (structure, shapes, dtypes)
    assert jax.tree.structure(new_carry) == jax.tree.structure(carry), name
    for a, b in zip(jax.tree.leaves(new_carry), jax.tree.leaves(carry)):
        assert a.shape == b.shape and a.dtype == b.dtype, name
    # recurrent declaration <-> a real carry
    assert spec.recurrent == bool(jax.tree.leaves(carry)), name


def test_loss_contract_and_weights_pin(fixture):
    name, fx = fixture
    agent, spec = fx.agent, fx.agent.spec
    params = agent.init(jax.random.key(0), fx.obs_shape)
    traj = _make_traj(agent, spec, params, fx.obs_shape, fx.num_actions,
                      obs_dtype=_obs_dtype(fx))
    total, aux = jax.jit(agent.loss)(params, traj)
    assert total.shape == () and np.isfinite(float(total)), name
    assert isinstance(aux, api.LossAux), name
    assert aux.metrics and all(
        np.isfinite(float(v)) for v in jax.tree.leaves(aux.metrics)
    ), name
    if spec.replay:
        assert np.asarray(aux.priorities).shape == (B,), name
        # the canonical pin: weights=None IS the unweighted loss
        total_ones, aux_ones = jax.jit(agent.loss)(
            params, traj, jnp.ones((B,), jnp.float32)
        )
        np.testing.assert_allclose(
            float(total), float(total_ones), rtol=1e-6,
            err_msg=f"{name}: loss(weights=None) != loss(ones)",
        )
        np.testing.assert_allclose(
            np.asarray(aux.priorities), np.asarray(aux_ones.priorities),
            rtol=1e-6,
        )
    else:
        assert aux.priorities == (), name
        with pytest.raises(ValueError, match="importance weights"):
            agent.loss(params, traj, jnp.ones((B,), jnp.float32))


# --------------------------------------------- validation fix-it messages


def test_extras_declaration_mismatch_rejected():
    spec = jax.ShapeDtypeStruct((B, 3), jnp.float32)
    with pytest.raises(ValueError, match="do not match the declared"):
        api.validate_extras(
            {"bar": spec}, api.AgentSpec(extras_keys=("foo",)), "X"
        )
    with pytest.raises(ValueError, match="extras as a dict"):
        api.validate_extras(spec, api.AgentSpec(extras_keys=("foo",)), "X")
    with pytest.raises(ValueError, match="declares no"):
        api.validate_extras({"bar": spec}, api.AgentSpec(), "X")
    api.validate_extras({"foo": spec}, api.AgentSpec(extras_keys=("foo",)),
                        "X")  # exact match passes
    api.validate_extras((), api.AgentSpec(), "X")


def test_declared_spec_signature_validation_fix_it():
    class MissingCarryArg:
        spec = api.AgentSpec(recurrent=True)

        def init(self, rng, obs_shape):
            return {}

        def initial_carry(self, batch):
            return jnp.zeros((batch, 2))

        def act(self, params, obs, rng):  # lost the carry
            raise NotImplementedError

        def loss(self, params, traj, weights=None):
            raise NotImplementedError

    with pytest.raises(ValueError, match=r"act\(params, obs, rng, carry\)"):
        api.resolve_agent(MissingCarryArg())

    class NoWeightsParam(MissingCarryArg):
        def act(self, params, obs, rng, carry):
            raise NotImplementedError

        def loss(self, params, traj):  # lost the weights
            raise NotImplementedError

    with pytest.raises(ValueError, match=r"weights=None"):
        api.resolve_agent(NoWeightsParam())

    class UndeclaredCarry(NoWeightsParam):
        spec = api.AgentSpec(recurrent=False)  # lies about the carry

        def loss(self, params, traj, weights=None):
            raise NotImplementedError

    with pytest.raises(ValueError, match="recurrent=True"):
        api.resolve_agent(UndeclaredCarry())


class _KVCarryAgent:
    """Minimal declared-spec agent with an LM-shaped carry: a zero-valued
    but decidedly nonzero-SHAPED KV-cache pytree plus position counter."""

    spec = api.AgentSpec(recurrent=True)

    def __init__(self, pos_offset=0):
        self._off = pos_offset

    def init(self, rng, obs_shape):
        return {}

    def initial_carry(self, batch):
        return {
            "cache": {
                "layer_0": {
                    "k": jnp.zeros((batch, 8, 2, 4), jnp.bfloat16),
                    "v": jnp.zeros((batch, 8, 2, 4), jnp.bfloat16),
                }
            },
            "pos": jnp.full((batch,), self._off, jnp.int32),
        }

    def act(self, params, obs, rng, carry):
        raise NotImplementedError

    def loss(self, params, traj, weights=None):
        raise NotImplementedError


def test_zero_valued_kv_cache_carry_validates():
    """ISSUE 9 satellite: the zero-carry check is on VALUES, not shapes —
    a KV-cache carry with a position counter must resolve natively."""
    resolved, spec = api.resolve_agent(_KVCarryAgent())
    assert spec.recurrent and not api.is_legacy_adapter(resolved)


def test_nonzero_carry_rejected_naming_the_leaf():
    """The fix-it error pinpoints WHICH leaf breaks the zero-value
    invariant (here the position counter) and spells out that shape/dtype
    are unconstrained."""
    with pytest.raises(ValueError, match=r"leaf \['pos'\]"):
        api.resolve_agent(_KVCarryAgent(pos_offset=3))
    with pytest.raises(ValueError, match="must be all zeros"):
        api.resolve_agent(_KVCarryAgent(pos_offset=3))


def test_sebulba_core_has_no_arity_sniffing():
    """Acceptance: no runtime arity-sniffing or class-marker checks remain
    in core/sebulba.py — agent introspection lives in repro.api only."""
    import repro.core.sebulba as mod

    src = pathlib.Path(mod.__file__).read_text()
    assert "import inspect" not in src
    assert "inspect." not in src
    assert "replay_protocol" not in src
    assert "getattr(self.agent" not in src
    assert "resolve_agent" in src  # the one sanctioned entry point


# ------------------------------------------------- unified runner surface


def _tiny_sebulba(replay=None):
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig

    return Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=4, trajectory_length=2, replay=replay,
        ),
    )


def _tiny_anakin():
    from repro.agents.actor_critic import MLPActorCritic
    from repro.core.anakin import Anakin, AnakinConfig

    env = Catch()
    return Anakin(
        env, MLPActorCritic(env.num_actions, (16,)), optim.sgd(1e-2),
        AnakinConfig(unroll_length=5, batch_per_device=8,
                     iterations_per_call=2),
    )


def test_runners_satisfy_the_protocol():
    assert isinstance(_tiny_sebulba(), api.Runner)
    assert isinstance(_tiny_anakin(), api.Runner)


def test_result_schema_unified_across_all_paths():
    """Satellite: one documented result schema.  Counters an architecture
    does not have read 0, never missing."""
    from repro.configs.base import ReplayConfig

    out_on = _tiny_sebulba().fit(jax.random.key(0), total_frames=64)
    out_off = _tiny_sebulba(
        ReplayConfig(capacity=16, sample_batch_size=4, min_size=4)
    ).fit(jax.random.key(0), total_frames=160)
    out_ank = _tiny_anakin().fit(jax.random.key(0), total_frames=80)

    for name, out in (("on", out_on), ("off", out_off), ("anakin", out_ank)):
        missing = set(api.RESULT_KEYS) - set(out)
        assert not missing, f"{name} result missing {missing}"
        for key in ("updates", "frames", "param_version", "publishes_sent",
                    "publishes_skipped", "put_blocked", "traj_dropped",
                    "replay_size", "checkpoints_saved"):
            assert isinstance(out[key], int), (name, key, type(out[key]))
    # architecture-absent counters are zeros, not gaps
    assert out_on["replay_size"] == 0
    assert out_off["replay_size"] > 0
    for key in ("publishes_sent", "publishes_skipped", "put_blocked",
                "traj_dropped", "replay_size"):
        assert out_ank[key] == 0
    assert out_ank["param_version"] == out_ank["updates"]


# --------------------------------------------------- runner checkpointing


def test_sebulba_checkpoint_wiring(tmp_path):
    """Satellite: the runner owns persistence — boundary saves stamped
    with param_version, a final save, and restore_from warm-starting."""
    d = str(tmp_path / "ckpts")
    seb = _tiny_sebulba()
    out = seb.fit(
        jax.random.key(0), total_frames=64, checkpoint_dir=d,
        checkpoint_every=2,
    )
    assert out["checkpoints_saved"] >= 1
    latest = api.latest_checkpoint(d)
    assert latest is not None
    restored, meta = api.restore_checkpoint(latest, out["params"])
    assert meta["param_version"] == out["param_version"]
    assert meta["updates"] == out["updates"]
    for a, b in zip(jax.tree.leaves(restored), jax.tree.leaves(out["params"])):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # warm start from the directory (latest stamp wins)
    seb2 = _tiny_sebulba()
    out2 = seb2.fit(jax.random.key(1), total_frames=32, restore_from=d)
    assert out2["updates"] > 0


def test_checkpoint_every_requires_dir():
    with pytest.raises(ValueError, match="checkpoint_dir"):
        api.CheckpointPolicy(None, 5)


def test_restore_continues_the_version_line(tmp_path):
    """Resuming into the SAME checkpoint_dir must stamp new checkpoints
    ABOVE the restored one — otherwise latest_checkpoint keeps resolving
    to the stale pre-restore params."""
    d = str(tmp_path / "ck")
    out1 = _tiny_sebulba().fit(
        jax.random.key(0), total_frames=64, checkpoint_dir=d,
        checkpoint_every=2,
    )
    first_latest = api.latest_checkpoint(d)
    out2 = _tiny_sebulba().fit(
        jax.random.key(1), total_frames=64, checkpoint_dir=d,
        checkpoint_every=2, restore_from=d,
    )
    assert out2["param_version"] > out1["param_version"]
    latest = api.latest_checkpoint(d)
    assert latest != first_latest
    _, meta = api.restore_checkpoint(latest, out2["params"])
    assert meta["param_version"] == out2["param_version"]
    assert meta["updates"] > out1["updates"]  # cumulative stamps

    # same continuity on Anakin's block-granular fit
    d2 = str(tmp_path / "ck_ank")
    a1 = _tiny_anakin().fit(jax.random.key(0), total_frames=80,
                            checkpoint_dir=d2, checkpoint_every=2)
    a2 = _tiny_anakin().fit(jax.random.key(1), total_frames=80,
                            checkpoint_dir=d2, checkpoint_every=2,
                            restore_from=d2)
    assert a2["param_version"] == a1["param_version"] + a2["updates"]
    _, meta2 = api.restore_checkpoint(d2, a2["params"])
    assert meta2["param_version"] == a2["param_version"]


def test_restore_does_not_resave_the_restored_boundary():
    """A resumed fit's first boundary save must land at the NEXT every-N
    boundary, not immediately duplicate the just-restored params."""
    policy = api.CheckpointPolicy("unused-dir", 100, base_updates=250)
    fired = []
    policy._save = lambda params, **kw: fired.append(kw["updates"])
    policy.maybe_save(None, param_version=251, updates=251, frames=0)
    policy.maybe_save(None, param_version=299, updates=299, frames=0)
    assert fired == []  # still inside the restored boundary
    policy.maybe_save(None, param_version=300, updates=300, frames=0)
    assert fired == [300]
    # a resumed fit that trained NOTHING must not re-write the restored
    # params from final_save (updates is cumulative == the base)
    idle = api.CheckpointPolicy("unused-dir", 100, base_updates=250)
    idle._save = lambda params, **kw: fired.append(("final", kw["updates"]))
    idle.final_save(None, param_version=251, updates=250, frames=0)
    assert fired == [300]
    idle.final_save(None, param_version=252, updates=251, frames=0)
    assert fired == [300, ("final", 251)]


def test_latest_checkpoint_survives_nine_digit_versions(tmp_path):
    """Stamps outgrow the 8-digit zero padding without disappearing from
    restore (numeric compare, not lexical; \\d+ not \\d{8})."""
    d = str(tmp_path)
    for version in (99_999_999, 100_000_000):
        api.save_checkpoint(d, {"w": jnp.zeros((2,))}, param_version=version)
    assert api.latest_checkpoint(d) == api.checkpoint_path(d, 100_000_000)
    _, meta = api.restore_checkpoint(d, {"w": jnp.zeros((2,))})
    assert meta["param_version"] == 100_000_000


def test_agentspec_extras_keys_string_footgun():
    """A bare string must mean one key, not its characters."""
    assert api.AgentSpec(extras_keys="visit_probs").extras_keys == (
        "visit_probs",
    )
    with pytest.raises(TypeError, match="strings"):
        api.AgentSpec(extras_keys=(1,))


def test_legacy_markerless_replay_agent_still_accepted():
    """Pre-protocol behavior pin: in replay mode, a spec-less agent whose
    loss takes three positional args (no replay_protocol marker) was
    accepted with the (metrics, td) aux convention — the legacy shim must
    keep accepting it (the replay hint disambiguates what a bare 3-arg
    loss means)."""
    from repro.agents import BatchedMLPActorCritic
    from repro.configs.base import ReplayConfig
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.rl import losses as L

    class MarkerlessReplay:
        def __init__(self, network):
            self.net = network

        def init(self, rng, obs_shape):
            return self.net.init(rng, obs_shape)

        def act(self, params, obs, rng):  # legacy 3-arg, 3-tuple
            logits, _ = self.net.apply(params, obs)
            actions = jax.random.categorical(rng, logits)
            return actions, L.log_prob(logits, actions), ()

        def loss(self, params, traj, weights=None):  # legacy (metrics, td)
            B, T = traj.actions.shape
            obs_flat = traj.obs.reshape((B * T,) + traj.obs.shape[2:])
            logits, values = self.net.apply(params, obs_flat)
            out = L.weighted_impala_loss(
                logits.reshape(B, T, -1), values.reshape(B, T),
                traj.actions, traj.behaviour_logp, traj.rewards,
                traj.discounts,
                self.net.apply(params, traj.bootstrap_obs)[1],
                importance_weights=weights,
            )
            return out.total, ({"loss": out.total}, out.per_seq_td)

    net = BatchedMLPActorCritic(4, hidden=(16,))
    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net, optimizer=optim.adam(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=4, trajectory_length=2,
            replay=ReplayConfig(capacity=16, sample_batch_size=4,
                                min_size=4),
        ),
        agent=MarkerlessReplay(net),
    )
    assert seb.spec.replay and api.is_legacy_adapter(seb.agent)
    out = seb.fit(jax.random.key(0), total_frames=160)
    assert out["updates"] > 0 and np.isfinite(out["metrics"]["loss"])
    # ...while the SAME signature on-policy still means an unweighted
    # legacy agent (plain metrics aux) and must not be marked replay
    _, spec_on = api.resolve_agent(MarkerlessReplay(net), replay_hint=False)
    assert not spec_on.replay


def test_anakin_checkpoint_block_granularity(tmp_path):
    """checkpoint_every smaller than the compiled block still saves once
    per crossed boundary (updates advance iterations_per_call at a time)."""
    d = str(tmp_path / "ck")
    out = _tiny_anakin().fit(
        jax.random.key(0), total_frames=240, checkpoint_dir=d,
        checkpoint_every=1,
    )
    assert out["checkpoints_saved"] >= 2
    _, meta = api.restore_checkpoint(d, out["params"])
    assert meta["param_version"] == out["param_version"]
