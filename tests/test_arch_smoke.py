"""Per-architecture smoke tests (deliverable f).

For each of the 10 assigned architectures: instantiate the REDUCED variant
(2-3 layers, d_model <= 512, <= 4 experts), run one forward and one full
train step on CPU, assert output shapes and absence of NaNs; run one decode
step against a cache and check it agrees with the teacher-forced forward.
"""

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs.base import ARCH_IDS, get_config, get_reduced_config
from repro.launch.specs import make_batch
from repro.launch.steps import TrainHParams, make_serve_step, make_train_step
from repro.models import make_model
from repro.models import attention as attn

ASSIGNED = {
    "mamba2_1p3b": dict(num_layers=48, d_model=2048, vocab_size=50_280,
                        ssm_state=128),
    "gemma3_4b": dict(num_layers=34, d_model=2560, num_heads=8,
                      num_kv_heads=4, d_ff=10_240, vocab_size=262_144),
    "recurrentgemma_2b": dict(num_layers=26, d_model=2560, num_heads=10,
                              num_kv_heads=1, d_ff=7680, vocab_size=256_000),
    "granite_moe_1b": dict(num_layers=24, d_model=1024, num_heads=16,
                           num_kv_heads=8, d_ff=512, vocab_size=49_155,
                           num_experts=32, num_experts_per_tok=8),
    "llama3_405b": dict(num_layers=126, d_model=16_384, num_heads=128,
                        num_kv_heads=8, d_ff=53_248, vocab_size=128_256),
    "deepseek_moe_16b": dict(num_layers=28, d_model=2048, num_heads=16,
                             num_kv_heads=16, d_ff=1408, vocab_size=102_400,
                             num_experts=64, num_experts_per_tok=6,
                             num_shared_experts=2),
    "qwen2_1p5b": dict(num_layers=28, d_model=1536, num_heads=12,
                       num_kv_heads=2, d_ff=8960, vocab_size=151_936,
                       qkv_bias=True),
    "llama32_vision_11b": dict(num_layers=40, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14_336,
                               vocab_size=128_256, cross_attn_every=5),
    "whisper_medium": dict(num_layers=24, d_model=1024, num_heads=16,
                           num_kv_heads=16, d_ff=4096, vocab_size=51_865,
                           encoder_layers=24),
    "qwen3_4b": dict(num_layers=36, d_model=2560, num_heads=32,
                     num_kv_heads=8, d_ff=9728, vocab_size=151_936,
                     qk_norm=True),
}


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    for field, expect in ASSIGNED[arch].items():
        assert getattr(cfg, field) == expect, (arch, field)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_reduced_config_bounds(arch):
    cfg = get_reduced_config(arch)
    assert cfg.num_layers <= 3
    assert cfg.d_model <= 512
    assert cfg.num_experts <= 4


@pytest.mark.slow  # whole-zoo train-step sweep (~70s); full tier only
@pytest.mark.parametrize("arch", ARCH_IDS)
def test_forward_and_train_step(arch):
    cfg = get_reduced_config(arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 32
    batch = make_batch(cfg, B, T)
    logits, values, aux = model.forward(params, batch)
    assert logits.shape == (B, T, cfg.vocab_size)
    assert values.shape == (B, T)
    assert bool(jnp.isfinite(logits).all())
    assert bool(jnp.isfinite(values).all())

    opt = optim.adam(1e-3, clip_norm=1.0)
    step = jax.jit(make_train_step(model, opt, TrainHParams()))
    opt_state = opt.init(params)
    params2, opt_state, metrics = step(params, opt_state, batch)
    assert bool(jnp.isfinite(metrics["loss"]))
    # parameters actually changed
    diffs = jax.tree.map(
        lambda a, b: float(jnp.abs(a.astype(jnp.float32)
                                   - b.astype(jnp.float32)).max()),
        params, params2,
    )
    assert max(jax.tree.leaves(diffs)) > 0.0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_decode_matches_forward(arch):
    cfg = get_reduced_config(arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, T = 2, 16
    batch = make_batch(cfg, B, T, rng=jax.random.key(7))
    logits_f, _, _ = model.forward(params, batch)
    cache, _ = model.init_cache(B, T)

    # populate cross-modal memory the way prefill would
    if cfg.family == "vlm":
        mem = batch["images"].astype(jnp.bfloat16) @ params["projector"][
            "w"
        ].astype(jnp.bfloat16)
        for i in range(cfg.num_layers):
            if model._is_cross(i):
                mk, mv = attn.cross_kv(params[f"layer_{i}"]["cross"], mem)
                cache[f"layer_{i}"]["mem_k"] = mk
                cache[f"layer_{i}"]["mem_v"] = mv
    if cfg.family == "audio":
        enc = model._encode_audio(params, batch["frames"])
        mks, mvs = [], []
        for i in range(cfg.num_layers):
            p_i = jax.tree.map(lambda x: x[i], params["blocks"])
            mk, mv = attn.cross_kv(p_i["cross"], enc)
            mks.append(mk)
            mvs.append(mv)
        cache["blocks"]["mem_k"] = jnp.stack(mks)
        cache["blocks"]["mem_v"] = jnp.stack(mvs)

    step = jax.jit(model.decode_step)
    errs = []
    toks = batch["tokens"]
    for t in range(T):
        lg, _, cache = step(params, cache, toks[:, t : t + 1], jnp.int32(t))
        errs.append(float(jnp.abs(lg[:, 0] - logits_f[:, t]).max()))
    assert max(errs) < 0.15, errs  # bf16 accumulation tolerance


@pytest.mark.parametrize("arch", ["qwen3_4b", "granite_moe_1b", "mamba2_1p3b"])
def test_serve_step_shapes(arch):
    cfg = get_reduced_config(arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 64
    cache, _ = model.init_cache(B, S)
    serve = jax.jit(make_serve_step(model))
    tok = jnp.zeros((B, 1), jnp.int32)
    tok2, cache = serve(params, cache, tok, jnp.int32(0))
    assert tok2.shape == (B, 1)
    assert tok2.dtype == jnp.int32
