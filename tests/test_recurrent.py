"""Recurrent-agent (R2D2) carry semantics, ISSUE 4 acceptance:

  * the fused actor resets the carry on episode boundaries (discount
    channel) — bit-identical to manually zeroing those rows;
  * the carry entering step 0 of a slice is stored and drains as
    ``Trajectory.init_carry`` (R2D2 stored state), bit-exact;
  * stored state round-trips the replay ring bit-exact;
  * burn-in cuts the gradient tape exactly — grads w.r.t. burn-in steps
    are exactly zero;
  * the sequence unroll (rglru kernel wrapper AND the pure-lax reference
    core) matches the actor's step-by-step path with resets;
  * feed-forward agents pass through the carry plumbing untouched
    (empty-() carry, no new buffer leaves) — the PR 2/3 bit-exact pins in
    test_trajectory_buffer.py / test_learner_pipeline.py run against the
    same act-step and keep guarding the numerics;
  * agent-protocol and burn-in validation fail fast, not in a jit trace;
  * end-to-end: recurrent agents train through both the on-policy and the
    replay (true R2D2) Sebulba paths on the CPU mesh.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import optim
from repro.agents.recurrent import (
    RecurrentImpalaAgent,
    RecurrentMLPActorCritic,
    RecurrentReplayImpalaAgent,
)
from repro.configs.base import ReplayConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.data.trajectory import Trajectory
from repro.envs import BatchedHostEnv, HostBandit
from repro.replay import ReplayBuffer

B, T, OBS, W = 4, 6, 4, 8


def _make_seb(burn_in=0, traj_len=T, batch=B, replay=None, agent_cls=None,
              core="rglru"):
    cfg = SebulbaConfig(
        num_actor_cores=1, threads_per_actor_core=1, actor_batch_size=batch,
        trajectory_length=traj_len, burn_in=burn_in, replay=replay,
    )
    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W, core=core)
    agent_cls = agent_cls or (
        RecurrentReplayImpalaAgent if replay else RecurrentImpalaAgent
    )
    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net, optimizer=optim.adam(1e-3), config=cfg,
        agent=agent_cls(net, cfg),
    )
    return seb, net


def _traj(rng: np.random.RandomState, discounts=None, batch=B, traj_len=T):
    if discounts is None:
        discounts = np.full((batch, traj_len), 0.99, np.float32)
    return Trajectory(
        obs=jnp.asarray(rng.rand(batch, traj_len, OBS), jnp.float32),
        actions=jnp.asarray(
            rng.randint(0, 4, (batch, traj_len)), jnp.int32
        ),
        rewards=jnp.asarray(rng.rand(batch, traj_len), jnp.float32),
        discounts=jnp.asarray(discounts, jnp.float32),
        behaviour_logp=jnp.asarray(
            np.log(rng.uniform(0.2, 0.9, (batch, traj_len))), jnp.float32
        ),
        bootstrap_obs=jnp.asarray(rng.rand(batch, OBS), jnp.float32),
        init_carry=jnp.asarray(rng.rand(batch, W), jnp.float32),
    )


# ----------------------------------------------------- fused actor carry


def test_actor_resets_carry_on_episode_boundary_bit_exact():
    """Rows whose previous step ended (discount channel == 0) must restart
    from the initial state: the fused step with those discounts must be
    bit-identical to manually zeroing those carry rows and passing
    non-terminal discounts."""
    seb, net = _make_seb()
    params, _ = seb.init(jax.random.key(0), (OBS,))
    device = seb.split.actor_devices[0]
    rng = np.random.RandomState(3)
    obs = jax.device_put(
        jnp.asarray(rng.rand(B, OBS), jnp.float32), device
    )
    carry = jnp.asarray(rng.rand(B, W), jnp.float32)
    rewards = rng.rand(B).astype(np.float32)
    disc_ended = np.full((B,), 0.9, np.float32)
    disc_ended[[0, 2]] = 0.0  # rows 0 and 2 closed their episodes

    def run(disc, c):
        buf = seb._make_actor_buffer(params, obs, device)
        hd = jax.device_put(np.stack([rewards, disc]), device)
        # the fused step donates its carry; hand it a private copy so the
        # caller's array survives for the comparisons below
        actions, buf, _, new_carry = seb._act_step(
            params, buf, jax.device_put(jax.random.key(5), device), obs,
            hd, jnp.copy(jax.device_put(c, device)),
        )
        return actions, new_carry, buf

    act_a, carry_a, buf_a = run(disc_ended, carry)
    manual = carry.at[jnp.asarray([0, 2])].set(0.0)
    act_b, carry_b, buf_b = run(np.full((B,), 0.9, np.float32), manual)

    np.testing.assert_array_equal(np.asarray(act_a), np.asarray(act_b))
    np.testing.assert_array_equal(np.asarray(carry_a), np.asarray(carry_b))
    # the stored slice-initial state is the POST-reset carry in both runs
    np.testing.assert_array_equal(
        np.asarray(buf_a.carry0), np.asarray(manual)
    )
    np.testing.assert_array_equal(
        np.asarray(buf_b.carry0), np.asarray(manual)
    )


def test_stored_state_snapshot_survives_drain_bit_exact():
    """The carry entering step 0 drains as Trajectory.init_carry, and the
    LIVE carry persists across the drain into the next slice's snapshot."""
    seb, net = _make_seb(traj_len=3)
    params, _ = seb.init(jax.random.key(0), (OBS,))
    device = seb.split.actor_devices[0]
    rng = np.random.RandomState(7)
    c0 = jnp.asarray(rng.rand(B, W), jnp.float32)
    carry = jnp.copy(jax.device_put(c0, device))  # donated by the 1st step
    buf = None
    hd = jax.device_put(
        jnp.concatenate(
            [jnp.zeros((1, B)), jnp.full((1, B), 0.9)]
        ).astype(jnp.float32),
        device,
    )
    for t in range(3):
        obs = jax.device_put(
            jnp.asarray(rng.rand(B, OBS), jnp.float32), device
        )
        if buf is None:
            buf = seb._make_actor_buffer(params, obs, device)
        _, buf, _, carry = seb._act_step(
            params, buf, jax.device_put(jax.random.key(t), device), obs,
            jnp.copy(hd), carry,
        )
    live = jnp.copy(carry)  # drain must not touch the live carry
    traj, fresh = seb._drain(
        buf, jnp.copy(hd),
        jax.device_put(jnp.zeros((B, OBS), jnp.float32), device),
    )
    np.testing.assert_array_equal(np.asarray(traj.init_carry), np.asarray(c0))
    np.testing.assert_array_equal(np.asarray(fresh.carry0), 0.0)
    # next slice: its t==0 snapshot is the live carry, not the old one
    obs = jax.device_put(jnp.asarray(rng.rand(B, OBS), jnp.float32), device)
    _, fresh, _, _ = seb._act_step(
        params, fresh, jax.device_put(jax.random.key(9), device), obs,
        jnp.copy(hd), carry,
    )
    np.testing.assert_array_equal(
        np.asarray(fresh.carry0), np.asarray(live)
    )


def test_feedforward_agents_pass_through_untouched():
    """ff agents keep the () carry end to end: no carry leaves in the ring,
    () back from the fused step, () init_carry on the drained trajectory
    (the PR 2/3 pins then guard the numerics on this same path)."""
    from repro.agents import BatchedMLPActorCritic

    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, actor_batch_size=B, trajectory_length=2
        ),
    )
    assert not seb._recurrent
    params, _ = seb.init(jax.random.key(0), (OBS,))
    device = seb.split.actor_devices[0]
    obs = jax.device_put(jnp.ones((B, OBS), jnp.float32), device)
    buf = seb._make_actor_buffer(params, obs, device)
    assert buf.carry0 == ()
    hd = jax.device_put(jnp.zeros((2, B), jnp.float32), device)
    _, buf, _, carry = seb._act_step(
        params, buf, jax.device_put(jax.random.key(1), device), obs, hd, ()
    )
    assert carry == ()
    _, buf, _, _ = seb._act_step(
        params, buf, jax.device_put(jax.random.key(2), device), obs,
        jnp.copy(hd), ()
    )
    traj, _ = seb._drain(buf, jnp.copy(hd), obs)
    assert traj.init_carry == ()


# ------------------------------------------------- replay ring round trip


def test_replay_roundtrip_stored_state_bit_exact():
    """insert -> sample must hand back the stored init_carry (and every
    other leaf) bit-for-bit — replayed sequences unroll from the exact
    state the actor recorded."""
    rng = np.random.RandomState(11)
    traj = _traj(rng, batch=8)
    buf = ReplayBuffer(capacity=8, prioritized=True)
    state = buf.init(traj)
    state = buf.insert(state, traj)
    sampled, idx, _ = buf.sample(state, jax.random.key(0), 32)
    idx = np.asarray(idx)
    for name, stored, got in zip(traj._fields, traj, sampled):
        for a, b in zip(jax.tree.leaves(stored), jax.tree.leaves(got)):
            np.testing.assert_array_equal(
                np.asarray(a)[idx], np.asarray(b),
                err_msg=f"{name} did not round-trip the ring bit-exact",
            )
    assert sampled.init_carry.shape == (32, W)
    assert sampled.init_carry.dtype == jnp.float32


# ----------------------------------------------------- learner-side unroll


@pytest.mark.parametrize("core", ["rglru", "lax"])
def test_unroll_matches_stepwise_actor_path_with_resets(core):
    """apply_seq (either core) over a trajectory with mid-slice episode
    boundaries must match the actor's step-by-step path: same logits,
    values, and final carry (the reset folded into the decay gate is the
    same computation the actor does by zeroing the carry)."""
    seb, net = _make_seb(core=core)
    agent = seb.agent
    params, _ = seb.init(jax.random.key(0), (OBS,))
    rng = np.random.RandomState(0)
    disc = np.full((B, T), 0.99, np.float32)
    disc[0, 2] = 0.0  # episode boundary inside the slice
    disc[2, 0] = 0.0
    disc[3, 4] = 0.0
    traj = _traj(rng, discounts=disc)
    reset = agent._reset_mask(traj.discounts)
    logits, values, h_last = net.apply_seq(
        params, traj.obs, traj.init_carry, reset
    )

    h = traj.init_carry
    outs = []
    for t in range(T):
        h = jnp.where(reset[:, t][:, None], 0.0, h)
        lg, v, h = net.apply_step(params, traj.obs[:, t], h)
        outs.append((lg, v))
    np.testing.assert_allclose(
        np.asarray(logits),
        np.asarray(jnp.stack([o[0] for o in outs], axis=1)),
        rtol=2e-5, atol=2e-6,
    )
    np.testing.assert_allclose(
        np.asarray(values),
        np.asarray(jnp.stack([o[1] for o in outs], axis=1)),
        rtol=2e-5, atol=2e-6,
    )
    np.testing.assert_allclose(
        np.asarray(h_last), np.asarray(h), rtol=2e-5, atol=2e-6
    )


def test_rglru_and_lax_cores_agree():
    seb_a, net_a = _make_seb(core="rglru")
    seb_b, net_b = _make_seb(core="lax")
    params = net_a.init(jax.random.key(0), (OBS,))
    rng = np.random.RandomState(5)
    obs = jnp.asarray(rng.rand(B, T, OBS), jnp.float32)
    h0 = jnp.asarray(rng.rand(B, W), jnp.float32)
    reset = jnp.zeros((B, T), bool).at[1, 3].set(True)
    la, va, ha = net_a.apply_seq(params, obs, h0, reset)
    lb, vb, hb = net_b.apply_seq(params, obs, h0, reset)
    np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(va), np.asarray(vb),
                               rtol=2e-5, atol=2e-6)
    np.testing.assert_allclose(np.asarray(ha), np.asarray(hb),
                               rtol=2e-5, atol=2e-6)


def test_burn_in_gradient_mask_exactly_zero():
    """Grads w.r.t. the burn-in window (obs steps < K, and the stored
    init_carry, whose only consumer is that window) must be EXACTLY zero —
    stop_gradient cuts the tape, it doesn't just shrink the numbers — while
    the trained window's grads are live."""
    K = 2
    seb, net = _make_seb(burn_in=K)
    agent = seb.agent
    params, _ = seb.init(jax.random.key(0), (OBS,))
    traj = _traj(np.random.RandomState(1))

    g_obs, g_carry = jax.grad(
        lambda o, c: agent.loss(
            params, traj._replace(obs=o, init_carry=c)
        )[0],
        argnums=(0, 1),
    )(traj.obs, traj.init_carry)
    g_obs = np.asarray(g_obs)
    assert np.all(g_obs[:, :K] == 0.0), "burn-in obs grads must be exact 0"
    assert np.abs(g_obs[:, K:]).max() > 0.0, "trained window grads missing"
    assert np.all(np.asarray(g_carry) == 0.0)

    # without burn-in the stored state IS on the tape
    seb0, _ = _make_seb(burn_in=0)
    g_carry0 = jax.grad(
        lambda c: seb0.agent.loss(params, traj._replace(init_carry=c))[0]
    )(traj.init_carry)
    assert np.abs(np.asarray(g_carry0)).max() > 0.0


def test_burn_in_loss_trains_suffix_only():
    """burn_in=K must equal scoring only the last T-K steps: perturbing a
    burn-in step's reward leaves the loss bit-identical."""
    K = 2
    seb, _ = _make_seb(burn_in=K)
    params, _ = seb.init(jax.random.key(0), (OBS,))
    traj = _traj(np.random.RandomState(2))
    base, _ = seb.agent.loss(params, traj)
    bumped = traj._replace(
        rewards=traj.rewards.at[:, 0].add(100.0)
    )
    pert, _ = seb.agent.loss(params, bumped)
    assert float(base) == float(pert)
    trained = traj._replace(rewards=traj.rewards.at[:, K].add(100.0))
    pert2, _ = seb.agent.loss(params, trained)
    assert float(base) != float(pert2)


# ------------------------------------------------------------- validation


def test_burn_in_requires_recurrent_agent():
    from repro.agents import BatchedMLPActorCritic

    with pytest.raises(ValueError, match="recurrent-agent feature"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=BatchedMLPActorCritic(4, hidden=(16,)),
            optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                num_actor_cores=1, actor_batch_size=B,
                trajectory_length=4, burn_in=1,
            ),
        )


def test_burn_in_must_leave_trained_steps():
    with pytest.raises(ValueError, match="at least one"):
        _make_seb(burn_in=T, traj_len=T)


def test_recurrent_agent_needs_carry_arg_in_act():
    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W)

    class BadAgent(RecurrentImpalaAgent):
        def act(self, params, obs, rng):  # lost the carry
            raise NotImplementedError

    with pytest.raises(ValueError, match="act\\(params, obs, rng, carry\\)"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=net, optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                num_actor_cores=1, actor_batch_size=B, trajectory_length=4
            ),
            agent=BadAgent(net, SebulbaConfig()),
        )


def test_replay_protocol_agent_rejected_onpolicy():
    """The recurrent replay agent shares ReplayImpalaAgent's aux protocol
    (metrics, td) without its base class — the on-policy guard must key on
    the protocol marker, not isinstance, and reject it too."""
    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W)
    with pytest.raises(ValueError, match="requires SebulbaConfig.replay"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=net, optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                num_actor_cores=1, actor_batch_size=B, trajectory_length=4
            ),
            agent=RecurrentReplayImpalaAgent(net, SebulbaConfig()),
        )


def test_defaulted_carry_arg_accepted_and_knobs_stay_keyword_only():
    """act(..., carry=None) on a recurrent agent satisfies the canonical
    4-positional call.  Extra acting knobs must be keyword-only — the
    runner passes the carry in positional slot 4 on every step, so a knob
    parked there would silently receive (); the protocol rejects that at
    construction with a fix-it."""

    class DefaultCarry(RecurrentImpalaAgent):
        def act(self, params, obs, rng, carry=None):
            return super().act(params, obs, rng, carry)

    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W)
    cfg = SebulbaConfig(
        num_actor_cores=1, actor_batch_size=B, trajectory_length=4
    )
    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net, optimizer=optim.adam(1e-3), config=cfg,
        agent=DefaultCarry(net, cfg),
    )
    assert seb._recurrent

    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import ImpalaAgent

    class KeywordKnob(ImpalaAgent):
        def act(self, params, obs, rng, carry=(), *, greedy=False):
            return super().act(params, obs, rng, carry)

    ff_net = BatchedMLPActorCritic(4, hidden=(16,))
    seb_ff = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=ff_net, optimizer=optim.adam(1e-3), config=cfg,
        agent=KeywordKnob(ff_net, cfg),
    )
    assert not seb_ff._recurrent

    class PositionalKnob(ImpalaAgent):
        def act(self, params, obs, rng, greedy=False):  # knob in the
            return super().act(params, obs, rng)        # carry's slot

    with pytest.raises(ValueError, match="keyword-only"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=ff_net, optimizer=optim.adam(1e-3), config=cfg,
            agent=PositionalKnob(ff_net, cfg),
        )


def test_nonzero_initial_carry_rejected():
    """Both reset mechanisms (actor jnp.where, learner decay-gate fold)
    restore zero state; an agent advertising a nonzero initial carry would
    silently diverge them and must be rejected at construction."""

    class NonZero(RecurrentImpalaAgent):
        def initial_carry(self, batch_size):
            return jnp.ones((batch_size, W), jnp.float32)

    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W)
    with pytest.raises(ValueError, match="must be all zeros"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=net, optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                num_actor_cores=1, actor_batch_size=B, trajectory_length=4
            ),
            agent=NonZero(net, SebulbaConfig()),
        )


def test_carrying_act_without_initial_carry_rejected():
    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=W)

    class NoMarker:
        def __init__(self):
            self.net = net

        def init(self, rng, obs_shape):
            return net.init(rng, obs_shape)

        def act(self, params, obs, rng, carry):
            raise NotImplementedError

        def loss(self, params, traj):
            raise NotImplementedError

    with pytest.raises(ValueError, match="initial_carry"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=net, optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                num_actor_cores=1, actor_batch_size=B, trajectory_length=4
            ),
            agent=NoMarker(),
        )


# ------------------------------------------------------------ end to end


def test_e2e_recurrent_onpolicy_trains():
    """Recurrent agent through the on-policy donated learner path (carry
    threads the fused actor, init_carry rides the learner shards)."""
    seb, _ = _make_seb(burn_in=1, traj_len=4, batch=6)
    out = seb.run(jax.random.key(0), (OBS,), total_frames=240)
    assert out["updates"] > 0
    assert np.isfinite(out["metrics"]["loss"])


def test_e2e_recurrent_replay_trains_r2d2():
    """ISSUE 4 acceptance: true R2D2 — recurrent net, stored state riding
    the prioritized replay ring, burn-in — trains end to end on the CPU
    mesh through the fused off-policy update."""
    replay = ReplayConfig(
        capacity=64, sample_batch_size=6, min_size=12, prioritized=True
    )
    seb, _ = _make_seb(burn_in=1, traj_len=4, batch=6, replay=replay)
    out = seb.run(jax.random.key(0), (OBS,), total_frames=480)
    assert out["updates"] > 0
    assert out["replay_size"] > 0
    assert np.isfinite(out["metrics"]["loss"])
