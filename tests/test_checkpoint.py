"""Checkpoint save/restore roundtrips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {
            "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
            "c": jnp.array(3, jnp.int32),
        },
    }
    path = str(tmp_path / "ckpt.npz")
    save(path, tree)
    out = restore(path, jax.tree.map(lambda x: jnp.zeros_like(x), tree))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_allclose(
        np.asarray(out["nested"]["b"], np.float32),
        np.asarray(tree["nested"]["b"], np.float32),
    )


def test_restore_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save(path, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore(path, {"w": jnp.zeros((4,))})


def test_restore_missing_key_raises(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save(path, {"w": jnp.zeros((3,))})
    with pytest.raises(KeyError):
        restore(path, {"w": jnp.zeros((3,)), "extra": jnp.zeros((1,))})


def test_concurrent_writers_one_directory(tmp_path):
    """Many hosts checkpoint into ONE shared directory (multi-host
    elasticity): writers in different processes racing the same stamp
    must never tear each other — the staging name embeds the pid and
    basename, and the final write is one atomic ``os.replace``.  After
    the race every stamp verifies, the contested stamp is exactly one
    writer's payload, and no staging debris is left behind."""
    import subprocess
    import sys

    from repro.checkpoint import verify

    worker = (
        "import sys, numpy as np, jax.numpy as jnp\n"
        "from repro.checkpoint import save\n"
        "d, tag = sys.argv[1], int(sys.argv[2])\n"
        "tree = {'w': jnp.full((32,), float(tag))}\n"
        "for _ in range(20):\n"
        "    save(d + '/ckpt_00000001.npz', tree)   # contested stamp\n"
        "save(d + f'/ckpt_0000000{tag}.npz', tree)  # private stamp\n"
    )
    procs = [
        subprocess.Popen(
            [sys.executable, "-c", worker, str(tmp_path), str(tag)],
            stderr=subprocess.PIPE,
        )
        for tag in (2, 3)
    ]
    for p in procs:
        _, err = p.communicate(timeout=120)
        assert p.returncode == 0, err.decode()[-2000:]
    for tag in (1, 2, 3):
        path = str(tmp_path / f"ckpt_0000000{tag}.npz")
        assert verify(path), f"stamp {tag} failed verification"
    out = restore(str(tmp_path / "ckpt_00000001.npz"),
                  {"w": jnp.zeros((32,))})
    assert float(out["w"][0]) in (2.0, 3.0)  # one write, never a blend
    assert np.unique(np.asarray(out["w"])).size == 1
    debris = [n for n in tmp_path.iterdir() if n.suffix == ".tmp"]
    assert debris == []


def test_model_params_roundtrip(tmp_path):
    from repro.configs.base import get_reduced_config
    from repro.models import make_model

    cfg = get_reduced_config("qwen3_4b")
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    path = str(tmp_path / "model.npz")
    save(path, params)
    out = restore(path, params)
    same = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()), params, out
    )
    assert all(jax.tree.leaves(same))
