"""Checkpoint save/restore roundtrips."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import restore, save


def test_roundtrip_mixed_dtypes(tmp_path):
    tree = {
        "a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
        "nested": {
            "b": jnp.ones((4,), jnp.bfloat16) * 1.5,
            "c": jnp.array(3, jnp.int32),
        },
    }
    path = str(tmp_path / "ckpt.npz")
    save(path, tree)
    out = restore(path, jax.tree.map(lambda x: jnp.zeros_like(x), tree))
    assert out["nested"]["b"].dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_allclose(
        np.asarray(out["nested"]["b"], np.float32),
        np.asarray(tree["nested"]["b"], np.float32),
    )


def test_restore_shape_mismatch_raises(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save(path, {"w": jnp.zeros((3,))})
    with pytest.raises(ValueError):
        restore(path, {"w": jnp.zeros((4,))})


def test_restore_missing_key_raises(tmp_path):
    path = str(tmp_path / "ckpt.npz")
    save(path, {"w": jnp.zeros((3,))})
    with pytest.raises(KeyError):
        restore(path, {"w": jnp.zeros((3,)), "extra": jnp.zeros((1,))})


def test_model_params_roundtrip(tmp_path):
    from repro.configs.base import get_reduced_config
    from repro.models import make_model

    cfg = get_reduced_config("qwen3_4b")
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    path = str(tmp_path / "model.npz")
    save(path, params)
    out = restore(path, params)
    same = jax.tree.map(
        lambda a, b: bool((np.asarray(a) == np.asarray(b)).all()), params, out
    )
    assert all(jax.tree.leaves(same))
