"""Pure-JAX MCTS tests: the search must find the better arm of a known MDP."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.rl.mcts import mcts_search


def _bandit_fns(best_arm=2, num_actions=4):
    """A depth-1 bandit hidden in the MuZero interface: dynamics reward is
    +1 for the best arm, 0 otherwise; values are 0."""

    def representation(params, obs):
        return jnp.zeros((4,))

    def dynamics(params, h, action):
        reward = jnp.where(action == best_arm, 1.0, 0.0)
        return h + 0.01, reward  # slight drift to make nodes distinct

    def prediction(params, h):
        return jnp.zeros((num_actions,)), jnp.float32(0.0)

    return representation, dynamics, prediction


def test_mcts_finds_best_arm():
    rep, dyn, pred = _bandit_fns(best_arm=2)
    obs = jnp.zeros((3, 5))
    out = mcts_search(
        {}, obs, jax.random.key(0),
        representation=rep, dynamics=dyn, prediction=pred,
        num_simulations=32, num_actions=4, max_depth=2,
        temperature=0.0, exploration_frac=0.0,
    )
    np.testing.assert_array_equal(np.asarray(out.action), [2, 2, 2])
    assert (np.asarray(out.visit_probs)[:, 2] > 0.5).all()


def test_mcts_visit_probs_normalized():
    rep, dyn, pred = _bandit_fns()
    out = mcts_search(
        {}, jnp.zeros((2, 5)), jax.random.key(1),
        representation=rep, dynamics=dyn, prediction=pred,
        num_simulations=16, num_actions=4, max_depth=3,
    )
    np.testing.assert_allclose(
        np.asarray(out.visit_probs).sum(-1), 1.0, rtol=1e-5
    )
    assert np.isfinite(np.asarray(out.root_value)).all()


def test_mcts_root_value_reflects_reward():
    """With a +1 reward on every path (all arms good), root value -> ~1."""
    def dyn_all_good(params, h, action):
        return h + 0.01, jnp.float32(1.0)

    rep, _, pred = _bandit_fns()
    out = mcts_search(
        {}, jnp.zeros((1, 5)), jax.random.key(2),
        representation=rep, dynamics=dyn_all_good, prediction=pred,
        num_simulations=32, num_actions=4, max_depth=2, discount=0.0,
    )
    assert float(out.root_value[0]) > 0.5
