"""Device trajectory ring tests: donation/in-place update invariants, the
one-step reward lag, drain wraparound, agent-extras storage, and bit-exact
equivalence of the fused Sebulba act-step against the legacy
TrajectoryAccumulator path on HostPong (ISSUE 2 acceptance)."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.trajectory import (
    TrajectoryAccumulator,
    buffer_add,
    buffer_drain,
    device_buffer_init,
)

B, T = 4, 5


def make_buf(extras_spec=()):
    return device_buffer_init(
        T,
        jax.ShapeDtypeStruct((B, 3), jnp.float32),
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.float32),
        extras_spec,
    )


def step_inputs(i: float):
    """(obs, actions, logp, extras, rew_disc) for synthetic step i."""
    return (
        jnp.full((B, 3), i, jnp.float32),
        jnp.full((B,), int(i), jnp.int32),
        jnp.full((B,), -i, jnp.float32),
        (),
        jnp.full((2, B), i, jnp.float32),  # prev step's [rewards; discounts]
    )


def test_buffer_init_shapes_and_cursors():
    buf = make_buf(extras_spec=jax.ShapeDtypeStruct((B, 7), jnp.float32))
    assert buf.obs.shape == (B, T, 3)
    assert buf.actions.shape == (B, T) and buf.actions.dtype == jnp.int32
    assert buf.rewards.shape == (B, T) and buf.rewards.dtype == jnp.float32
    assert buf.extras.shape == (B, T, 7)
    assert buf.length == T
    assert int(buf.t) == 0 and not bool(buf.has_prev)


def test_donated_add_updates_ring_in_place():
    """The fused act-step donates the ring: the old handle must be consumed
    and the storage reused in place (no per-step reallocation) — the
    replay-ring recipe applied to the actor pipeline."""
    step = jax.jit(buffer_add, donate_argnums=(0,))
    buf = make_buf()
    obs_ptr = buf.obs.unsafe_buffer_pointer()
    old = buf
    buf = step(buf, *step_inputs(1.0))
    assert old.obs.is_deleted(), "donated input must be consumed"
    assert buf.obs.unsafe_buffer_pointer() == obs_ptr, (
        "donation must reuse the ring storage in place"
    )
    assert int(buf.t) == 1 and bool(buf.has_prev)


def test_reward_lag_one_step_and_first_write_masked():
    """rewards/discounts of step t arrive with step t+1's transfer and land
    at slot t; the very first add has no pending step, so its rew_disc
    payload must not be written anywhere."""
    step = jax.jit(buffer_add, donate_argnums=(0,))
    buf = make_buf()
    buf = step(buf, *step_inputs(1.0))  # garbage rew_disc=1.0: masked out
    np.testing.assert_array_equal(np.asarray(buf.rewards), 0.0)
    buf = step(buf, *step_inputs(2.0))  # delivers step-0 rewards (=2.0)
    np.testing.assert_array_equal(np.asarray(buf.rewards[:, 0]), 2.0)
    np.testing.assert_array_equal(np.asarray(buf.rewards[:, 1:]), 0.0)
    np.testing.assert_array_equal(np.asarray(buf.discounts[:, 0]), 2.0)


def test_drain_aliases_ring_and_resets():
    """Drain hands the ring storage to the trajectory zero-copy (donation
    aliasing) and returns a zeroed ring with reset cursors."""
    step = jax.jit(buffer_add, donate_argnums=(0,))
    drain = jax.jit(buffer_drain, donate_argnums=(0,))
    buf = make_buf()
    for i in range(T):
        buf = step(buf, *step_inputs(float(i + 1)))
    ring_ptr = buf.obs.unsafe_buffer_pointer()
    boot = jnp.full((B, 3), 99.0)
    traj, fresh = drain(buf, jnp.full((2, B), 9.0), boot)
    assert traj.obs.unsafe_buffer_pointer() == ring_ptr, (
        "trajectory must take ownership of the donated ring storage"
    )
    # obs at slot t is step t+1's payload (i+1); final rewards from drain
    np.testing.assert_array_equal(
        np.asarray(traj.obs[0, :, 0]), np.arange(1.0, T + 1)
    )
    np.testing.assert_array_equal(
        np.asarray(traj.rewards[0]), [2.0, 3.0, 4.0, 5.0, 9.0]
    )
    np.testing.assert_array_equal(np.asarray(traj.bootstrap_obs), boot)
    assert int(fresh.t) == 0 and not bool(fresh.has_prev)
    for leaf in jax.tree.leaves(fresh):
        np.testing.assert_array_equal(np.asarray(leaf), 0)


def test_drain_wraparound_second_trajectory_independent():
    """After a drain the ring is immediately reusable: a second fill+drain
    must produce the second trajectory exactly, with no leakage from the
    first (the drained trajectory keeps its own storage)."""
    step = jax.jit(buffer_add, donate_argnums=(0,))
    drain = jax.jit(buffer_drain, donate_argnums=(0,))
    buf = make_buf()
    for i in range(T):
        buf = step(buf, *step_inputs(float(i + 1)))
    traj1, buf = drain(buf, jnp.full((2, B), 9.0), jnp.zeros((B, 3)))
    for i in range(T):
        buf = step(buf, *step_inputs(float(100 + i)))
    traj2, buf = drain(buf, jnp.full((2, B), 7.0), jnp.ones((B, 3)))
    np.testing.assert_array_equal(
        np.asarray(traj1.obs[0, :, 0]), np.arange(1.0, T + 1)
    )
    np.testing.assert_array_equal(
        np.asarray(traj2.obs[0, :, 0]), np.arange(100.0, 100.0 + T)
    )
    # first-add-after-drain rew_disc (=100) is masked: slot 0 rewards come
    # from the second add (=101), the final slot from the drain (=7)
    np.testing.assert_array_equal(
        np.asarray(traj2.rewards[0]), [101.0, 102.0, 103.0, 104.0, 7.0]
    )
    assert int(buf.t) == 0


def test_extras_pytree_gets_time_axis():
    step = jax.jit(buffer_add, donate_argnums=(0,))
    drain = jax.jit(buffer_drain, donate_argnums=(0,))
    buf = make_buf(extras_spec={"visit": jax.ShapeDtypeStruct((B, 2), jnp.float32)})
    for i in range(T):
        obs, act, logp, _, hd = step_inputs(float(i))
        buf = step(buf, obs, act, logp, {"visit": jnp.full((B, 2), float(i))}, hd)
    traj, _ = drain(buf, jnp.zeros((2, B)), jnp.zeros((B, 3)))
    assert traj.extras["visit"].shape == (B, T, 2)
    np.testing.assert_array_equal(
        np.asarray(traj.extras["visit"][0, :, 0]), np.arange(float(T))
    )


# ------------------------------------------------ fused vs legacy pipeline


def test_fused_act_step_bit_exact_vs_legacy_accumulate():
    """The ISSUE 2 pin: the fused donated act-step + device ring must
    reproduce the legacy per-step-transfer + TrajectoryAccumulator path
    bit-for-bit on HostPong — same actions, same trajectories."""
    from repro import optim
    from repro.agents.impala import ConvActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostPong

    T, B = 6, 4
    net = ConvActorCritic(
        HostPong.num_actions, channels=(8,), blocks=1, hidden=32
    )
    cfg = SebulbaConfig(
        num_actor_cores=1, threads_per_actor_core=1,
        actor_batch_size=B, trajectory_length=T,
    )
    seb = Sebulba(
        env_factory=lambda s: HostPong(seed=s),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net, optimizer=optim.adam(1e-3), config=cfg,
    )
    params, _ = seb.init(jax.random.key(0), (16, 16, 1))
    device = seb.split.actor_devices[0]
    seed = 7

    def run_legacy():
        env = BatchedHostEnv(lambda i: HostPong(seed=seed * 10_000 + i), B)
        inference = jax.jit(seb.agent.act)
        obs = env.reset()
        acc = TrajectoryAccumulator(T)
        rng = jax.random.key(seed)
        for _ in range(T):
            rng, a_rng = jax.random.split(rng)
            obs_dev = jax.device_put(obs, device)
            # canonical repro.api act: (actions, ActAux(logp, extras), carry)
            actions, aux, _ = inference(params, obs_dev, a_rng, ())
            next_obs, rewards, dones = env.step(np.asarray(actions))
            discounts = (~dones).astype(np.float32) * cfg.discount
            acc.add(
                obs_dev, actions, jax.device_put(rewards, device),
                jax.device_put(discounts, device), aux.logp, aux.extras,
            )
            obs = next_obs
        return acc.drain(bootstrap_obs=jax.device_put(obs, device))

    def run_fused():
        env = BatchedHostEnv(lambda i: HostPong(seed=seed * 10_000 + i), B)
        obs = env.reset()
        rng = jax.device_put(jax.random.key(seed), device)
        host_data = np.zeros((2, B), np.float32)
        buf = None
        for _ in range(T):
            obs_dev = jax.device_put(obs, device)
            hd_dev = jax.device_put(host_data, device)
            if buf is None:
                buf = seb._make_actor_buffer(params, obs_dev, device)
            actions, buf, rng, _ = seb._act_step(
                params, buf, rng, obs_dev, hd_dev, ()
            )
            next_obs, rewards, dones = env.step(np.asarray(actions))
            host_data = np.stack(
                [rewards, (~dones).astype(np.float32) * cfg.discount]
            )
            obs = next_obs
        traj, _ = seb._drain(
            buf, jax.device_put(host_data, device),
            jax.device_put(obs, device),
        )
        return traj

    legacy, fused = run_legacy(), run_fused()
    for name, a, b in zip(legacy._fields, legacy, fused):
        if name == "extras":
            assert a == () and b == ()
            continue
        for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
            assert np.array_equal(np.asarray(x), np.asarray(y)), (
                f"{name} diverged between fused and legacy pipelines"
            )


def test_integer_token_obs_round_trip_bit_exact():
    """ISSUE 9 satellite: int32 token observations (LM policies) survive
    write/drain/split_for_learners bit-exact with their dtype intact — the
    ring allocates from per-step specs, so an integer obs spec must never
    be silently floated."""
    from repro.data.trajectory import split_for_learners

    step = jax.jit(buffer_add, donate_argnums=(0,))
    drain = jax.jit(buffer_drain, donate_argnums=(0,))
    carry_spec = {
        "cache": jax.ShapeDtypeStruct((B, 4, 2, 2), jnp.bfloat16),
        "pos": jax.ShapeDtypeStruct((B,), jnp.int32),
    }
    buf = device_buffer_init(
        T,
        jax.ShapeDtypeStruct((B,), jnp.int32),  # scalar token obs
        jax.ShapeDtypeStruct((B,), jnp.int32),
        jax.ShapeDtypeStruct((B,), jnp.float32),
        (),
        carry_spec,
    )
    assert buf.obs.dtype == jnp.int32
    assert buf.carry0["cache"].dtype == jnp.bfloat16
    assert buf.carry0["pos"].dtype == jnp.int32

    rng = np.random.RandomState(0)
    tokens = rng.randint(0, 50_000, (T, B)).astype(np.int32)
    for i in range(T):
        carry = {
            "cache": jnp.full((B, 4, 2, 2), i + 1, jnp.bfloat16),
            "pos": jnp.full((B,), i, jnp.int32),
        }
        buf = step(
            buf,
            jnp.asarray(tokens[i]),
            jnp.asarray(tokens[i]),  # actions ARE tokens for LM agents
            jnp.full((B,), -0.5, jnp.float32),
            (),
            jnp.full((2, B), 0.5, jnp.float32),
            carry,
        )
    boot = jnp.asarray(rng.randint(0, 50_000, (B,)), jnp.int32)
    traj, fresh = drain(buf, jnp.full((2, B), 1.0, jnp.float32), boot)

    assert traj.obs.dtype == jnp.int32 and traj.actions.dtype == jnp.int32
    assert traj.bootstrap_obs.dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(traj.obs), tokens.T)
    np.testing.assert_array_equal(np.asarray(traj.bootstrap_obs),
                                  np.asarray(boot))
    # slice-initial carry: the t == 0 snapshot, dtypes intact
    assert traj.init_carry["cache"].dtype == jnp.bfloat16
    assert traj.init_carry["pos"].dtype == jnp.int32
    np.testing.assert_array_equal(np.asarray(traj.init_carry["pos"]), 0)
    np.testing.assert_array_equal(
        np.asarray(traj.init_carry["cache"].astype(jnp.float32)), 1.0
    )
    # learner sharding keeps integer dtypes bit-exact
    shards = split_for_learners(traj, 2)
    got = np.concatenate([np.asarray(s.obs) for s in shards], axis=0)
    np.testing.assert_array_equal(got, tokens.T)
    for s in shards:
        assert s.obs.dtype == jnp.int32
        assert s.init_carry["pos"].dtype == jnp.int32
    # fresh ring preserves the spec dtypes too
    assert fresh.obs.dtype == jnp.int32
    assert fresh.carry0["cache"].dtype == jnp.bfloat16
