"""Causality property test: for EVERY autoregressive architecture, the
logits at position t must be invariant to tokens after t.  This catches
mask bugs, scan off-by-ones, conv leakage, and ring-cache errors in one
sweep across the whole zoo."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import ARCH_IDS, get_reduced_config
from repro.launch.specs import make_batch
from repro.models import make_model


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_causal_invariance(arch):
    cfg = get_reduced_config(arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, T, t_cut = 2, 24, 11
    batch = make_batch(cfg, B, T, rng=jax.random.key(3))
    logits_a, _, _ = model.forward(params, batch)

    # perturb everything strictly after t_cut
    tokens_b = batch["tokens"].at[:, t_cut + 1 :].set(
        (batch["tokens"][:, t_cut + 1 :] + 7) % cfg.vocab_size
    )
    batch_b = dict(batch, tokens=tokens_b)
    logits_b, _, _ = model.forward(params, batch_b)

    prefix_diff = float(
        jnp.abs(logits_a[:, : t_cut + 1] - logits_b[:, : t_cut + 1]).max()
    )
    suffix_diff = float(
        jnp.abs(logits_a[:, t_cut + 1 :] - logits_b[:, t_cut + 1 :]).max()
    )
    assert prefix_diff == 0.0, f"{arch}: future tokens leaked into the past"
    assert suffix_diff > 0.0, f"{arch}: suffix insensitive (degenerate test)"


def test_sliding_window_variant_locality():
    """Beyond-paper long-context variant: a uniform-local ('L') pattern must
    route through the looped path (windows applied), making influence
    strictly local — the property that licenses long_500k for dense archs."""
    import dataclasses

    cfg = dataclasses.replace(
        get_reduced_config("qwen2_1p5b"), sliding_window=16, layer_pattern="L"
    )
    model = make_model(cfg)
    assert not model.stacked
    params = model.init(jax.random.key(0))
    B, T = 2, 48
    batch = make_batch(cfg, B, T, rng=jax.random.key(3))
    la, _, _ = model.forward(params, batch)
    tokens_b = batch["tokens"].at[:, 0].set(
        (batch["tokens"][:, 0] + 3) % cfg.vocab_size
    )
    lb, _, _ = model.forward(params, dict(batch, tokens=tokens_b))
    # with 2 layers x window 16, influence cannot reach past ~2*16 tokens
    assert float(jnp.abs(la[:, 40] - lb[:, 40]).max()) == 0.0
    assert float(jnp.abs(la[:, 5] - lb[:, 5]).max()) > 0.0
