"""ISSUE 8 unit level — lease membership, pure placement, replay routing.

Registry: leases expire (not announce-order), ``sync`` bumps the epoch
exactly when the live set changes (idempotent otherwise, convergent
across racing registries on one directory), and ``expire`` is the
step-deterministic stand-in for a SIGKILLed host's TTL running out.

Placement: ``stable_hash`` / ``shard_assignment`` / ``owner_rank`` are
pure functions of ``(seq_id, epoch, world_size)`` — the zero-coordination
contract every host derives the same layout from.

Routing: ``DistributedReplay`` inserts by owner hash, samples bit-exactly
within an epoch (same key, same draw), re-normalizes PER statistics over
the surviving shard set, refuses stale epochs, and reshards
deterministically (two replicas of the same transition produce
bit-identical shard states) while counting lost sequences.
"""

import subprocess
import sys

import numpy as np
import pytest

from repro.distributed import (
    DistributedReplay,
    HostRegistry,
    Membership,
    StaleEpochError,
    owner_rank,
    shard_assignment,
    stable_hash,
)

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


# --------------------------------------------------------------- registry


def test_lease_lifecycle_announce_renew_expire_retire(tmp_path):
    reg = HostRegistry(str(tmp_path), ttl=10.0)
    reg.announce("a", now=100.0)
    reg.announce("b", now=100.0)
    assert reg.live_hosts(now=105.0) == ("a", "b")
    # death is the absence of renewal: a's lease runs out, b renews
    reg.renew("b", now=109.0)
    assert reg.live_hosts(now=112.0) == ("b",)
    # expire() fast-forwards the TTL (simulated SIGKILL) but leaves the
    # lease file behind, exactly as a killed host would
    reg.expire("b", now=112.0)
    assert reg.live_hosts(now=112.0) == ()
    assert (tmp_path / "lease_b.json").exists()
    # retire() is the graceful goodbye: the lease file is gone
    reg.announce("a", now=112.0)
    reg.retire("a")
    assert reg.live_hosts(now=112.0) == ()
    assert not (tmp_path / "lease_a.json").exists()
    reg.retire("a")  # idempotent


def test_registry_rejects_bad_ids_and_ttl(tmp_path):
    with pytest.raises(ValueError):
        HostRegistry(str(tmp_path), ttl=0.0)
    reg = HostRegistry(str(tmp_path), ttl=1.0)
    for bad in ("", " padded ", "a/b"):
        with pytest.raises(ValueError):
            reg.announce(bad)


def test_sync_bumps_epoch_only_on_membership_change(tmp_path):
    reg = HostRegistry(str(tmp_path), ttl=10.0)
    assert reg.current() == Membership(epoch=0, hosts=())
    reg.announce("b", now=100.0)
    reg.announce("a", now=100.0)
    m1 = reg.sync(now=101.0)
    assert m1.epoch == 1 and m1.hosts == ("a", "b")  # sorted, not insert order
    # idempotent: nothing changed, no bump
    assert reg.sync(now=102.0) == m1
    # a second registry on the same directory observes the same record
    # (any participant may sync — racing writers of the same change are
    # idempotent by construction)
    other = HostRegistry(str(tmp_path), ttl=10.0)
    assert other.current() == m1
    reg.expire("a", now=103.0)
    m2 = other.sync(now=103.0)
    assert m2 == Membership(epoch=2, hosts=("b",))


def test_membership_rank_is_sorted_and_raises_for_strangers():
    m = Membership(epoch=3, hosts=("alpha", "beta", "gamma"))
    assert m.world_size == 3
    assert [m.rank(h) for h in m.hosts] == [0, 1, 2]
    with pytest.raises(KeyError):
        m.rank("delta")


def test_torn_lease_reads_as_absent(tmp_path):
    reg = HostRegistry(str(tmp_path), ttl=10.0)
    (tmp_path / "lease_torn.json").write_text("{not json")
    reg.announce("ok", now=100.0)
    assert reg.live_hosts(now=101.0) == ("ok",)


# -------------------------------------------------------- pure placement


def test_stable_hash_is_process_independent(tmp_path):
    # Python's builtin hash is salted per process; stable_hash must agree
    # across interpreters or two hosts route the same id differently
    out = subprocess.run(
        [sys.executable, "-c",
         "from repro.distributed import stable_hash; "
         "print(stable_hash(42), stable_hash('seq-7'))"],
        capture_output=True, text=True, check=True,
    )
    child = tuple(int(x) for x in out.stdout.split())
    assert child == (stable_hash(42), stable_hash("seq-7"))


def test_shard_assignment_is_pure_and_a_permutation():
    for epoch in (0, 1, 7, 123):
        for n in (1, 2, 5):
            perm = shard_assignment(epoch, n)
            assert perm == shard_assignment(epoch, n)  # pure
            assert sorted(perm) == list(range(n))
    with pytest.raises(ValueError):
        shard_assignment(1, 0)


def test_owner_rank_in_range_and_epoch_redeals():
    owners_e1 = [owner_rank(i, 1, 4) for i in range(256)]
    assert all(0 <= o < 4 for o in owners_e1)
    assert owners_e1 == [owner_rank(i, 1, 4) for i in range(256)]
    # the epoch bump re-deals ownership (spreads reshard load) — at
    # least some keys must move between epochs
    owners_e2 = [owner_rank(i, 2, 4) for i in range(256)]
    assert owners_e1 != owners_e2


# ---------------------------------------------------------------- routing


def _attached(hosts=("a", "b", "c"), epoch=1, cap=8, **kw):
    rep = DistributedReplay(cap, **kw)
    rep.attach(Membership(epoch=epoch, hosts=tuple(hosts)),
               {"x": jnp.zeros((1, 2), jnp.float32)})
    return rep


def _batch(ids):
    ids = np.asarray(ids, np.int64)
    return ids, {"x": jnp.stack([jnp.full((2,), float(i)) for i in ids])}


def test_insert_routes_by_owner_and_sample_is_bit_exact():
    rep = _attached(cap=16)  # the hot hash bucket holds 14 of 24 ids
    ids, batch = _batch(range(24))
    rep.insert(ids, batch, epoch=1)
    assert rep.size() == 24
    # per-shard occupancy matches the pure ownership map
    m = rep.membership
    want = {h: 0 for h in m.hosts}
    for i in ids:
        want[m.hosts[owner_rank(int(i), 1, 3)]] += 1
    assert rep.sizes() == want
    # bit-exact within an epoch: the same key draws the same batch
    key = jax.random.key(0)
    b1, parts1, p1 = rep.sample(key, 9, epoch=1)
    b2, parts2, p2 = rep.sample(key, 9, epoch=1)
    np.testing.assert_array_equal(np.asarray(b1["x"]), np.asarray(b2["x"]))
    np.testing.assert_array_equal(p1, p2)
    assert [(h, i.tolist()) for h, i in parts1] == \
           [(h, i.tolist()) for h, i in parts2]
    assert b1["x"].shape == (9, 2) and p1.shape == (9,)


def test_stale_epoch_raises_on_insert_and_sample():
    rep = _attached(epoch=2)
    ids, batch = _batch(range(6))
    with pytest.raises(StaleEpochError):
        rep.insert(ids, batch, epoch=1)
    rep.insert(ids, batch, epoch=2)
    with pytest.raises(StaleEpochError):
        rep.sample(jax.random.key(0), 3, epoch=3)


def test_oversized_insert_chunks_to_ring_capacity():
    # a single host must absorb many rings' worth in one call (the
    # reshard-into-fewer-hosts path): ring semantics, newest survive
    rep = _attached(hosts=("only",), cap=4)
    ids, batch = _batch(range(11))
    rep.insert(ids, batch, epoch=1)
    assert rep.size() == 4
    shard = rep._shards["only"]
    assert sorted(shard.ids.tolist()) == [7, 8, 9, 10]


def test_sample_before_insert_and_empty_attach_raise():
    rep = DistributedReplay(8)
    with pytest.raises(ValueError):
        rep.attach(Membership(epoch=1, hosts=()), {"x": jnp.zeros((1,))})
    with pytest.raises(RuntimeError):
        rep.size()  # not attached
    rep = _attached()
    with pytest.raises(ValueError):
        rep.sample(jax.random.key(0), 4, epoch=1)


def test_per_probs_renormalize_over_surviving_shards():
    rep = _attached(hosts=("a", "b"), epoch=1, cap=16, prioritized=True)
    ids, batch = _batch(range(16))
    rep.insert(ids, batch, epoch=1)
    _, parts, probs = rep.sample(jax.random.key(1), 8, epoch=1)
    # each shard got half the draw, so its local probabilities are scaled
    # by alloc/batch = 1/2 — the global distribution a PER correction
    # can trust: every draw's probability is in (0, 1/2]
    assert np.all(probs > 0.0) and np.all(probs <= 0.5 + 1e-6)
    w = rep.importance_weights(probs, beta=0.4)
    assert w.shape == (8,) and w.dtype == np.float32
    assert np.isclose(w.max(), 1.0)  # normalized by the batch max
    # priority writeback round-trips through the routing record
    rep.update_priorities(parts, np.linspace(0.1, 1.0, 8))
    with pytest.raises(ValueError):
        rep.update_priorities(parts, np.ones((10,), np.float32))


def test_reshard_is_deterministic_and_counts_losses():
    def build():
        rep = _attached(hosts=("a", "b", "c"), epoch=1, cap=16)
        ids, batch = _batch(range(18))
        rep.insert(ids, batch, epoch=1)
        return rep

    lost_host = "a"
    survivors = Membership(epoch=2, hosts=("b", "c"))
    r1, r2 = build(), build()
    on_lost = r1.sizes()[lost_host]
    out1 = r1.reshard(survivors)
    out2 = r2.reshard(survivors)
    assert out1 == out2
    assert out1["lost"] == on_lost and on_lost > 0
    assert out1["hosts_lost"] == (lost_host,)
    assert out1["hosts_joined"] == ()
    assert out1["migrated"] == 18 - on_lost
    assert r1.sequences_lost == on_lost
    # bit-identical shard states on both replicas — the no-coordinator
    # invariant: every host reshards locally and agrees
    for host in survivors.hosts:
        s1, s2 = r1._shards[host], r2._shards[host]
        np.testing.assert_array_equal(s1.ids, s2.ids)
        np.testing.assert_array_equal(
            np.asarray(s1.state.storage["x"]),
            np.asarray(s2.state.storage["x"]),
        )
    # and the new layout matches the NEW epoch's pure ownership map
    for host, shard in r1._shards.items():
        for sid in shard.ids[shard.ids >= 0]:
            assert survivors.hosts[owner_rank(int(sid), 2, 2)] == host
    # post-reshard operation continues at the new epoch only
    with pytest.raises(StaleEpochError):
        r1.sample(jax.random.key(0), 4, epoch=1)
    b, _, _ = r1.sample(jax.random.key(0), 4, epoch=2)
    assert b["x"].shape == (4, 2)


def test_reshard_same_epoch_is_a_noop_and_join_is_counted():
    rep = _attached(hosts=("a", "b"), epoch=1, cap=8)
    ids, batch = _batch(range(8))
    rep.insert(ids, batch, epoch=1)
    assert rep.reshard(rep.membership)["migrated"] == 0
    out = rep.reshard(Membership(epoch=2, hosts=("a", "b", "d")))
    assert out["hosts_joined"] == ("d",)
    assert out["lost"] == 0 and out["migrated"] == 8
    assert rep.size() == 8
    with pytest.raises(ValueError):
        rep.reshard(Membership(epoch=3, hosts=()))
