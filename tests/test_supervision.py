"""ISSUE 7 acceptance — actor supervision, elasticity, and recovery.

Unit level (dummy actor bodies, no jax): crashed incarnations restart
with exponential backoff and fresh seeds, a slot exceeding max_restarts
is quarantined, the heartbeat watchdog cancels hung incarnations (with a
startup grace while the first step compiles), EVERY failure's traceback
is recorded (no crash masking), and ``join`` reports threads that refuse
to stop.

Integration level (tiny Sebulba on forced multi-device CPU): the chaos
proof — a FaultPlan killing one of two actors mid-run and hanging the
other, ``fit`` completing without deadlock with nonzero
``actor_restarts``/``watchdog_stalls``; quarantine degrading the fleet
instead of killing the run; a dead fleet raising ``SebulbaStallError``
with diagnostics and all tracebacks; and the kill → checkpoint →
``auto_resume`` round trip continuing the cumulative
frame/update/param_version stamps through a damaged newest checkpoint.
"""

import threading
import time
import warnings

import numpy as np
import pytest

from repro.core.supervision import (
    ActorSupervisor,
    SebulbaStallError,
)

jax = pytest.importorskip("jax")


def _poll_until(sup, cond, timeout=5.0):
    deadline = time.monotonic() + timeout
    while time.monotonic() < deadline:
        sup.poll()
        if cond():
            return True
        time.sleep(0.005)
    return False


def _stopper(stop):
    def body(handle):
        handle.frames = 1
        while not (stop.is_set() or handle.cancel.is_set()):
            handle.beat()
            time.sleep(0.002)

    return body


# ------------------------------------------------------------------ units


def test_restart_uses_fresh_seed_and_counts():
    stop = threading.Event()
    seeds = []

    def body(handle):
        seeds.append(handle.seed)
        if handle.incarnation == 0:
            raise RuntimeError("boom")
        _stopper(stop)(handle)

    sup = ActorSupervisor(
        slots=[(0, 1)], spawn=body, stop=stop,
        max_restarts=3, restart_backoff=0.01, stall_timeout=5.0,
    )
    sup.start()
    assert _poll_until(sup, lambda: sup.actor_restarts == 1)
    assert _poll_until(sup, lambda: len(seeds) == 2)
    assert seeds[0] == 1 and seeds[1] != seeds[0], "restart must fold the seed"
    assert sup.can_progress()
    stop.set()
    assert sup.join(timeout=5.0) == []
    # the crash is on record even though the slot recovered
    assert [name for name, _ in sup.errors()] == ["actor-0r0"]


def test_backoff_is_exponential():
    stop = threading.Event()

    def body(handle):
        raise RuntimeError("always dies")

    sup = ActorSupervisor(
        slots=[(0, 1)], spawn=body, stop=stop,
        max_restarts=2, restart_backoff=0.05, stall_timeout=5.0,
    )
    sup.start()
    assert _poll_until(sup, lambda: sup.actor_quarantined == 1)
    slot = sup._slots[0]
    assert slot.state == "quarantined" and slot.restarts == 2
    # three incarnations total: original + max_restarts replacements
    assert len(slot.handles) == 3
    gaps = [
        b.heartbeat - a.died_at
        for a, b in zip(slot.handles, slot.handles[1:])
    ]
    # second gap waits 2x the base backoff (poll cadence adds jitter, so
    # assert the floor, not the exact doubling)
    assert gaps[0] >= 0.04 and gaps[1] >= 0.09
    assert not sup.can_progress()
    sup.join(timeout=1.0)


def test_no_crash_masking_every_traceback_recorded():
    stop = threading.Event()

    def body(handle):
        raise RuntimeError(f"boom-{handle.slot}-{handle.incarnation}")

    sup = ActorSupervisor(
        slots=[(0, 1), (0, 2)], spawn=body, stop=stop,
        max_restarts=1, restart_backoff=0.01, stall_timeout=5.0,
    )
    sup.start()
    assert _poll_until(sup, lambda: sup.actor_quarantined == 2)
    errors = sup.errors()
    assert len(errors) == 4, "2 slots x 2 incarnations, nothing masked"
    messages = " ".join(tb for _, tb in errors)
    for slot in (0, 1):
        for inc in (0, 1):
            assert f"boom-{slot}-{inc}" in messages
    err = sup.stall_error(queue_depth=0)
    assert isinstance(err, SebulbaStallError)
    assert len(err.diagnostics["tracebacks"]) == 4
    assert err.diagnostics["actor_quarantined"] == 2
    assert "boom-1-1" in str(err)
    sup.join(timeout=1.0)


def test_watchdog_cancels_hung_actor_but_spares_startups():
    stop = threading.Event()
    hang = threading.Event()

    def body(handle):
        if handle.incarnation == 0 and hang.is_set():
            handle.frames = 1  # past startup grace
            handle.beat()
            handle.cancel.wait()  # wedged: no more heartbeats
            return
        _stopper(stop)(handle)

    sup = ActorSupervisor(
        slots=[(0, 1)], spawn=body, stop=stop,
        max_restarts=2, restart_backoff=0.01, stall_timeout=0.05,
    )
    # startup grace: an incarnation with frames == 0 is compiling, not
    # hung — it must never trip the watchdog however stale its stamp
    grace_sup = ActorSupervisor(
        slots=[(0, 1)], spawn=lambda h: h.cancel.wait(), stop=stop,
        max_restarts=0, restart_backoff=0.01, stall_timeout=0.01,
    )
    grace_sup.start()
    time.sleep(0.1)
    grace_sup.poll()
    assert grace_sup.watchdog_stalls == 0 and grace_sup.can_progress()
    grace_sup.join(timeout=1.0)

    hang.set()
    sup.start()
    assert _poll_until(sup, lambda: sup.watchdog_stalls == 1)
    assert _poll_until(sup, lambda: sup.actor_restarts == 1)
    name, tb = sup.errors()[0]
    assert name == "actor-0r0" and "heartbeat stalled" in tb
    stop.set()
    assert sup.join(timeout=5.0) == []


def test_join_reports_leaked_threads():
    stop = threading.Event()
    wedge = threading.Event()

    def body(handle):
        handle.frames = 1
        wedge.wait()  # ignores stop AND cancel: truly wedged

    sup = ActorSupervisor(
        slots=[(0, 1)], spawn=body, stop=stop,
        max_restarts=0, restart_backoff=0.01, stall_timeout=60.0,
    )
    sup.start()
    stop.set()
    leaked = sup.join(timeout=0.2)
    assert leaked == ["actor-0r0"]
    wedge.set()  # let the daemon thread die before the test exits


def test_recovery_latencies_drop_incomplete_pairs():
    """A latency is only ever adjacent death -> adjacent first put.
    A dead incarnation with no replacement measures nothing, and a
    replacement cancelled before its own first put neither completes the
    previous pairing nor baselines the next — incomplete pairs are
    DROPPED, never mis-paired across the gap."""
    from repro.core.supervision import ActorHandle

    sup = ActorSupervisor(
        slots=[(0, 1), (1, 2)], spawn=lambda h: None,
        stop=threading.Event(),
    )

    def handle(slot, inc, put_at=None, died_at=None):
        h = ActorHandle(slot, inc, core_id=0, seed=0)
        h.first_put_at, h.died_at = put_at, died_at
        return h

    # slot 0: produced, died, replacement cancelled mid-compile (no put,
    # then died), third incarnation produced.  Pairing h0's death with
    # h2's put would fabricate a latency spanning the dead middle
    # incarnation — both adjacent pairs are incomplete, so: nothing.
    sup._slots[0].handles = [
        handle(0, 0, put_at=1.0, died_at=2.0),
        handle(0, 1, put_at=None, died_at=3.0),
        handle(0, 2, put_at=4.5),
    ]
    # slot 1: produced then died with no replacement (quarantined) —
    # a dead-end incarnation measures nothing either
    sup._slots[1].handles = [handle(1, 0, put_at=1.0, died_at=6.0)]
    assert sup.recovery_latencies() == []

    # the complete adjacent pair DOES measure (and only it)
    sup._slots[1].handles.append(handle(1, 1, put_at=6.25))
    assert sup.recovery_latencies() == [0.25]


def test_supervisor_validates_config():
    stop = threading.Event()
    for bad in (
        dict(max_restarts=-1),
        dict(restart_backoff=0),
        dict(stall_timeout=0),
    ):
        kwargs = dict(
            max_restarts=1, restart_backoff=0.01, stall_timeout=1.0,
        )
        kwargs.update(bad)
        with pytest.raises(ValueError):
            ActorSupervisor(
                slots=[(0, 1)], spawn=lambda h: None, stop=stop, **kwargs
            )


# ------------------------------------------------------------ integration


def _chaos_sebulba(plan, **cfg_kwargs):
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    cfg = dict(
        num_actor_cores=1, threads_per_actor_core=2, actor_batch_size=4,
        trajectory_length=2, queue_capacity=2,
        max_restarts=2, restart_backoff=0.01, stall_timeout=0.25,
    )
    cfg.update(cfg_kwargs)
    return Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.sgd(1e-3),
        config=SebulbaConfig(**cfg),
        fault_plan=plan,
    )


def test_chaos_crash_and_hang_fit_completes():
    """THE acceptance chaos proof: one of two actors killed mid-run and
    the other hung; fit completes without deadlock, restarts the crash,
    watchdog-cancels the hang, and reports both through RESULT_KEYS."""
    from repro.fault import FaultEvent, FaultPlan

    plan = FaultPlan(events=(
        FaultEvent(kind="crash", target="actor:0", step=6),
        FaultEvent(kind="hang", target="actor:1", step=8),
    ), seed=0)
    seb = _chaos_sebulba(plan)
    with warnings.catch_warnings():
        warnings.simplefilter("error", RuntimeWarning)  # no leaked threads
        res = seb.fit(jax.random.key(0), total_frames=12000)
    assert res["frames"] >= 12000 and res["updates"] > 0
    assert res["actor_restarts"] >= 1
    assert res["watchdog_stalls"] >= 1
    assert res["actor_quarantined"] == 0
    assert np.isfinite(res["metrics"]["loss"])
    # both failures are on record, and the recovery latency probe paired
    # at least one death with its replacement's first trajectory
    assert len(seb.supervisor.errors()) >= 2
    assert all(lat >= 0.0 for lat in seb.supervisor.recovery_latencies())


def test_quarantine_degrades_instead_of_dying():
    """A slot that keeps crashing is quarantined after max_restarts; the
    surviving actor keeps feeding every learner shard and fit completes."""
    from repro.fault import FaultEvent, FaultPlan

    plan = FaultPlan(events=tuple(
        FaultEvent(kind="crash", target="actor:0", step=s)
        for s in (4, 5, 6)
    ), seed=0)
    seb = _chaos_sebulba(plan, max_restarts=2)
    res = seb.fit(jax.random.key(0), total_frames=4000)
    assert res["frames"] >= 4000
    assert res["actor_quarantined"] == 1
    assert res["actor_restarts"] == 2
    states = {s.slot_id: s.state for s in seb.supervisor._slots}
    assert states[0] == "quarantined"


def test_dead_fleet_raises_structured_stall_error():
    """When NO actor can make progress the learner raises
    SebulbaStallError carrying diagnostics and every traceback — it does
    not poll an empty queue forever."""
    from repro.fault import FaultEvent, FaultPlan

    plan = FaultPlan(events=tuple(
        FaultEvent(kind="crash", target="actor:0", step=s) for s in (4, 5)
    ), seed=0)
    seb = _chaos_sebulba(
        plan, threads_per_actor_core=1, max_restarts=1,
    )
    with pytest.raises(SebulbaStallError) as exc_info:
        seb.fit(jax.random.key(0), total_frames=10**9)
    err = exc_info.value
    assert err.diagnostics["actor_quarantined"] == 1
    assert err.diagnostics["actors"][0]["state"] == "quarantined"
    assert "queue_depth" in err.diagnostics
    assert "param_versions" in err.diagnostics
    assert len(err.diagnostics["tracebacks"]) == 2, "both crashes reported"
    assert "injected crash" in str(err)


def test_kill_checkpoint_auto_resume_round_trip(tmp_path):
    """Durable-recovery round trip: train with checkpointing, damage the
    newest stamp (a torn write), auto-resume — the run restores from the
    newest VALID stamp, counts the fallback, and continues the cumulative
    frame/update/param_version line so new stamps sort above the old."""
    from repro import api

    d = str(tmp_path)
    seb1 = _chaos_sebulba(None)
    res1 = seb1.fit(
        jax.random.key(0), total_frames=400,
        checkpoint_dir=d, checkpoint_every=2,
    )
    stamps = api.checkpoint_stamps(d)
    assert len(stamps) >= 2
    newest_version, newest_path = stamps[0]
    assert newest_version == res1["param_version"]
    with open(newest_path, "rb") as f:
        payload = f.read()
    with open(newest_path, "wb") as f:
        f.write(payload[: len(payload) // 2])  # torn write

    seb2 = _chaos_sebulba(None)
    res2 = seb2.fit(
        jax.random.key(1), total_frames=400,
        checkpoint_dir=d, checkpoint_every=2, auto_resume=True,
    )
    assert res2["checkpoint_fallbacks"] == 1
    # version line continued from the restored (second-newest) stamp
    restored_version = stamps[1][0]
    assert res2["param_version"] > restored_version
    final_version, final_path = api.checkpoint_stamps(d)[0]
    assert final_version == res2["param_version"] > newest_version
    params_like = jax.tree.map(np.asarray, res2["params"])
    _, meta = api.restore_checkpoint(final_path, params_like)
    # cumulative stamps: the resumed run's final checkpoint carries the
    # restored run's updates and frames plus its own
    _, meta1 = api.restore_checkpoint(stamps[1][1], params_like)
    assert meta["updates"] == meta1["updates"] + res2["updates"]
    # frames also continue cumulatively, but the final stamp may be the
    # last BOUNDARY save (final_save dedupes an unchanged version), whose
    # frame count trails the post-shutdown total — assert the window
    assert meta1["frames"] < meta["frames"] <= meta1["frames"] + res2["frames"]

    # fresh directory + auto_resume -> fresh start, no error
    seb3 = _chaos_sebulba(None)
    res3 = seb3.fit(
        jax.random.key(2), total_frames=64,
        checkpoint_dir=str(tmp_path / "fresh"), auto_resume=True,
    )
    assert res3["checkpoint_fallbacks"] == 0
