"""train_step / serve_step behaviour: loss decreases, microbatch equivalence."""

import dataclasses

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.configs.base import get_reduced_config
from repro.launch.specs import make_batch
from repro.launch.steps import (
    TrainHParams,
    make_loss_fn,
    make_optimizer,
    make_serve_step,
    make_train_step,
)
from repro.models import make_model


def test_train_loss_decreases_on_fixed_batch():
    cfg = get_reduced_config("qwen2_1p5b")
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    opt = optim.adam(3e-3, clip_norm=1.0)
    step = jax.jit(make_train_step(model, opt))
    opt_state = opt.init(params)
    batch = make_batch(cfg, 4, 32)
    losses = []
    for _ in range(8):
        params, opt_state, metrics = step(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0], losses


@pytest.mark.slow  # grad-accumulation equivalence; heaviest single jit
def test_microbatched_grads_match_full_batch():
    cfg = dataclasses.replace(
        get_reduced_config("qwen3_4b"), microbatches=4, remat="none",
        param_dtype="float32",
    )
    cfg1 = dataclasses.replace(cfg, microbatches=1)
    model = make_model(cfg1)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 8, 16)

    hp = TrainHParams()
    loss_fn = make_loss_fn(model, hp)
    g_full, _ = jax.grad(loss_fn, has_aux=True)(params, batch)

    model4 = make_model(cfg)
    # same params structure
    opt = optim.sgd(1.0)
    step4 = make_train_step(model4, opt, hp)
    step1 = make_train_step(model, opt, hp)
    p4, _, m4 = jax.jit(step4)(params, opt.init(params), batch)
    p1, _, m1 = jax.jit(step1)(params, opt.init(params), batch)
    # sgd(1.0): params' = params - grads, so param diff == grad diff
    err = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max() if a.ndim else abs(a - b)),
        p4, p1,
    )
    assert max(jax.tree.leaves(err)) < 2e-2
    assert abs(float(m4["ce"]) - float(m1["ce"])) < 1e-2


def test_serve_step_greedy_consistency():
    cfg = get_reduced_config("gemma3_4b")
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    cache, _ = model.init_cache(B, S)
    serve = jax.jit(make_serve_step(model))
    tok = jnp.ones((B, 1), jnp.int32)
    t1, cache = serve(params, cache, tok, jnp.int32(0))
    logits, _, _ = model.decode_step(
        params, jax.tree.map(jnp.zeros_like, cache), tok, jnp.int32(0)
    )
    assert (t1[:, 0] == jnp.argmax(logits[:, 0], -1)).all()


def test_vtrace_weight_changes_loss():
    cfg = get_reduced_config("qwen2_1p5b")
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    batch = make_batch(cfg, 2, 16)
    l0, _ = make_loss_fn(model, TrainHParams(rl_weight=0.0))(params, batch)
    l1, _ = make_loss_fn(model, TrainHParams(rl_weight=1.0))(params, batch)
    assert abs(float(l0) - float(l1)) > 1e-6
