"""Every examples/*.py entry point stays runnable (ISSUE 5 satellite).

Mirrors tests/test_benchmarks_import.py for the examples directory: until
now only the impala/r2d2 paths were exercised indirectly (via the bench
subprocess sweeps), so ``sebulba_muzero.py`` and ``quickstart.py`` could
rot silently — and the muzero example's documented 8-device invocation in
fact did (its fixed actor batch didn't divide across 6 learners).

Two layers:

  * fast tier — import every examples/*.py module (catches renamed
    imports, moved helpers, syntax rot at collection speed);
  * slow tier — run each RL entry point end to end for a few hundred
    frames in a 2-placeholder-device subprocess (real actor/learner core
    split, real fit loop, real result dict).
"""

import importlib.util
import os
import pathlib
import subprocess
import sys

import pytest

_EXAMPLES = pathlib.Path(__file__).resolve().parent.parent / "examples"
_ALL = sorted(p.stem for p in _EXAMPLES.glob("*.py"))

# every RL entry point + the flags that shrink it to smoke scale; keys are
# run labels (an example may appear more than once, e.g. with and without
# chaos injection), values are (example stem, argv)
_RL_RUNS = {
    "quickstart": ("quickstart", ["--frames", "2000"]),
    "sebulba_impala": ("sebulba_impala",
                       ["--frames", "400", "--actor-batch", "6",
                        "--trajectory", "5"]),
    "sebulba_impala_chaos": ("sebulba_impala",
                             ["--frames", "400", "--actor-batch", "6",
                              "--trajectory", "5", "--chaos", "7"]),
    "sebulba_impala_elastic_chaos": ("sebulba_impala",
                                     ["--frames", "400", "--actor-batch",
                                      "6", "--trajectory", "5", "--hosts",
                                      "3", "--chaos", "7"]),
    "sebulba_r2d2": ("sebulba_r2d2",
                     ["--frames", "400", "--actor-batch", "6",
                      "--trajectory", "6", "--burn-in", "1",
                      "--capacity", "64", "--replay-batch", "6",
                      "--min-size", "12", "--rnn-width", "16"]),
    "sebulba_muzero": ("sebulba_muzero",
                       ["--frames", "300", "--simulations", "4",
                        "--actor-batch", "6", "--trajectory", "6",
                        "--microbatches", "2"]),
    "sebulba_scenarios": ("sebulba_scenarios",
                          ["--frames", "400", "--actor-batch", "6",
                           "--trajectory", "5"]),
    "sebulba_scenarios_chaos": ("sebulba_scenarios",
                                ["--frames", "400", "--actor-batch", "6",
                                 "--trajectory", "5", "--chaos", "7"]),
    "train_lm_rl": ("train_lm_rl",
                    ["--preset", "tiny", "--frames", "256",
                     "--prompt-len", "4", "--actor-batch", "4"]),
    "train_lm_rl_replay": ("train_lm_rl",
                           ["--preset", "tiny", "--frames", "384",
                            "--prompt-len", "4", "--actor-batch", "4",
                            "--replay"]),
}


@pytest.mark.parametrize("name", _ALL)
def test_example_module_imports(name):
    spec = importlib.util.spec_from_file_location(
        f"examples_{name}", _EXAMPLES / f"{name}.py"
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    assert hasattr(mod, "main"), name


@pytest.mark.slow
@pytest.mark.parametrize("label", sorted(_RL_RUNS))
def test_rl_example_runs_end_to_end(label):
    name, argv = _RL_RUNS[label]
    src = str(pathlib.Path(__file__).resolve().parent.parent / "src")
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
    env["PYTHONPATH"] = src + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else ""
    )
    proc = subprocess.run(
        [sys.executable, str(_EXAMPLES / f"{name}.py"), *argv],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "FPS" in proc.stdout, proc.stdout[-2000:]
    if "--chaos" in argv:
        # the chaos run must survive its schedule and report supervision
        # counters (the example prints them only when --chaos is set)
        assert "chaos:" in proc.stdout, proc.stdout[-2000:]
    if "--hosts" in argv:
        # the elastic run must survive its host schedule and report the
        # membership counters (epoch / lost / joined / reshards)
        assert "hosts:" in proc.stdout, proc.stdout[-2000:]
