"""Per-kernel validation: Pallas (interpret=True) vs the pure-jnp oracle,
swept over shapes and dtypes."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_attention.flash_attention import flash_attention_pallas
from repro.kernels.flash_attention.ref import attention_ref
from repro.kernels.rglru_scan.ops import _assoc_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref
from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas
from repro.kernels.ssd_scan.ops import _chunked_ssd
from repro.kernels.ssd_scan.ref import ssd_scan_ref
from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas
from repro.kernels.vtrace.ref import vtrace_ref
from repro.kernels.vtrace.vtrace import vtrace_pallas


def _tol(dtype):
    return 2e-2 if dtype == jnp.bfloat16 else 2e-5


# ---------------------------------------------------------------- flash attn


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,S,H,K,h,causal,window",
    [
        (2, 128, 128, 4, 2, 64, True, 0),
        (1, 256, 256, 4, 4, 32, True, 0),
        (2, 128, 128, 4, 1, 64, False, 0),  # MQA, non-causal
        (1, 256, 256, 2, 2, 64, True, 64),  # sliding window
        (1, 128, 128, 8, 2, 128, True, 0),  # GQA 4:1, wide head
    ],
)
def test_flash_attention_matches_ref(B, T, S, H, K, h, causal, window, dtype):
    ks = jax.random.split(jax.random.key(T + H + h), 3)
    q = jax.random.normal(ks[0], (B, T, H, h), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, S, K, h), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, S, K, h), jnp.float32).astype(dtype)
    out = flash_attention_pallas(
        q, k, v, causal=causal, window=window,
        block_q=64, block_kv=64, interpret=True,
    )
    ref = attention_ref(q, k, v, causal=causal, window=window)
    assert out.dtype == dtype
    assert jnp.abs(out.astype(jnp.float32) - ref.astype(jnp.float32)).max() < _tol(dtype)


# ------------------------------------------------------------------ ssd scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,H,P,N,Q",
    [(2, 128, 4, 32, 16, 32), (1, 256, 2, 64, 32, 64), (2, 64, 8, 16, 8, 16)],
)
def test_ssd_scan_matches_ref(B, T, H, P, N, Q, dtype):
    ks = jax.random.split(jax.random.key(T + P), 5)
    x = jax.random.normal(ks[0], (B, T, H, P), jnp.float32).astype(dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = (jax.random.normal(ks[3], (B, T, N)) * 0.3).astype(dtype)
    Cm = (jax.random.normal(ks[4], (B, T, N)) * 0.3).astype(dtype)
    y_ref, s_ref = ssd_scan_ref(x, dt, A, Bm, Cm)
    y_chk, s_chk = _chunked_ssd(x, dt, A, Bm, Cm, Q, None)
    y_pal, s_pal = ssd_scan_pallas(x, dt, A, Bm, Cm, chunk=Q, interpret=True)
    tol = 0.05 if dtype == jnp.bfloat16 else 1e-4
    for y in (y_chk, y_pal):
        assert jnp.abs(y.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < tol
    for s in (s_chk, s_pal):
        assert jnp.abs(s - s_ref).max() < tol


# ---------------------------------------------------------------- rglru scan


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,T,W,bt,bw", [(2, 64, 128, 32, 64), (1, 128, 256, 64, 128)]
)
def test_rglru_matches_ref(B, T, W, bt, bw, dtype):
    ks = jax.random.split(jax.random.key(T + W), 3)
    x = jax.random.normal(ks[0], (B, T, W), jnp.float32).astype(dtype)
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, W))).astype(dtype)
    gi = jax.nn.sigmoid(jax.random.normal(ks[2], (B, T, W))).astype(dtype)
    y_ref, h_ref = rglru_scan_ref(x, a, gi)
    y_a, _ = _assoc_scan(x, a, gi, None)
    y_p, h_p = rglru_scan_pallas(x, a, gi, block_t=bt, block_w=bw, interpret=True)
    tol = _tol(dtype)
    assert jnp.abs(y_a.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < tol
    assert jnp.abs(y_p.astype(jnp.float32) - y_ref.astype(jnp.float32)).max() < tol
    assert jnp.abs(h_p - h_ref).max() < tol


def test_rglru_h0_custom_vjp_matches_scan_autodiff():
    """The linear-memory custom VJP on the h0 != None path (the R2D2
    stored-state unroll) must produce the same gradients — including dh0
    and the h_T output cotangent — as plain autodiff through the
    sequential lax.scan reference."""
    ks = jax.random.split(jax.random.key(7), 4)
    B, T, W = 2, 24, 8
    x = jax.random.normal(ks[0], (B, T, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, W)))
    gi = jax.nn.sigmoid(jax.random.normal(ks[2], (B, T, W)))
    h0 = jax.random.normal(ks[3], (B, W))
    cy = jax.random.normal(jax.random.key(9), (B, T, W))
    ch = jax.random.normal(jax.random.key(10), (B, W))

    def loss(fn):
        def inner(x, a, gi, h0):
            y, hT = fn(x, a, gi, h0)
            return jnp.sum(y * cy) + jnp.sum(hT * ch)

        return jax.grad(inner, argnums=(0, 1, 2, 3))

    g_ops = loss(lambda *args: _assoc_scan(*args))(x, a, gi, h0)
    g_ref = loss(rglru_scan_ref)(x, a, gi, h0)
    for go, gr in zip(g_ops, g_ref):
        assert jnp.abs(go - gr).max() < 1e-4


def test_rglru_carry_state():
    """Scan from h0 equals splitting the sequence in two (ops path)."""
    ks = jax.random.split(jax.random.key(0), 3)
    B, T, W = 2, 32, 16
    x = jax.random.normal(ks[0], (B, T, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, W)))
    gi = jax.nn.sigmoid(jax.random.normal(ks[2], (B, T, W)))
    y_full, h_full = rglru_scan_ref(x, a, gi)
    y1, h1 = rglru_scan_ref(x[:, :16], a[:, :16], gi[:, :16])
    y2, h2 = rglru_scan_ref(x[:, 16:], a[:, 16:], gi[:, 16:], h0=h1)
    assert jnp.abs(jnp.concatenate([y1, y2], 1) - y_full).max() < 1e-5
    assert jnp.abs(h2 - h_full).max() < 1e-5


# -------------------------------------------------------------------- vtrace


@pytest.mark.parametrize(
    "B,T,bb",
    [
        (8, 32, 8), (16, 100, 4), (4, 7, 4),
        # B not a multiple of block_b: the kernel pads rows up to the block
        # (it used to raise here, with an inverted error message)
        (10, 12, 4), (5, 9, 4), (3, 6, 2),
    ],
)
def test_vtrace_matches_ref(B, T, bb):
    ks = jax.random.split(jax.random.key(B * T), 5)
    lr = jax.random.normal(ks[0], (B, T)) * 0.3
    disc = (jax.random.uniform(ks[1], (B, T)) > 0.1).astype(jnp.float32) * 0.99
    rew = jax.random.normal(ks[2], (B, T))
    val = jax.random.normal(ks[3], (B, T))
    boot = jax.random.normal(ks[4], (B,))
    o_ref = vtrace_ref(lr, disc, rew, val, boot)
    o_p = vtrace_pallas(lr, disc, rew, val, boot, block_b=bb, interpret=True)
    assert jnp.abs(o_p.vs - o_ref.vs).max() < 1e-5
    assert jnp.abs(o_p.pg_advantages - o_ref.pg_advantages).max() < 1e-5
