"""Dry-run machinery integration tests.

The full 512-device sweep lives in experiments/; here a single light
(arch, shape) pair runs end-to-end in a subprocess (the dry-run must own
jax initialization because of XLA_FLAGS), plus in-process tests of the
pieces that don't need 512 devices.
"""

import json
import os
import subprocess
import sys

import pytest

from repro.configs.base import ARCH_IDS, INPUT_SHAPES, get_config
from repro.launch.roofline import model_flops_for
from repro.launch.specs import batch_specs, decode_specs


def test_input_specs_cover_all_archs():
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in INPUT_SHAPES.values():
            if shape.kind in ("train", "prefill"):
                specs, axes = batch_specs(cfg, shape)
                assert specs["tokens"].shape == (shape.global_batch,
                                                 shape.seq_len)
                assert set(axes) == set(specs)
            else:
                specs, axes = decode_specs(cfg, shape)
                assert specs["tokens"].shape == (shape.global_batch, 1)


def test_model_flops_scaling():
    cfg = get_config("qwen2_1p5b")
    train = model_flops_for(cfg, INPUT_SHAPES["train_4k"], "train")
    prefill = model_flops_for(cfg, INPUT_SHAPES["prefill_32k"], "prefill")
    decode = model_flops_for(cfg, INPUT_SHAPES["decode_32k"], "decode")
    # train does 3x the flops per token of inference; decode is per-token
    tokens_train = 256 * 4096
    tokens_prefill = 32 * 32768
    assert train / tokens_train == pytest.approx(
        3 * prefill / tokens_prefill, rel=1e-6
    )
    assert decode == pytest.approx(2 * cfg.active_param_count() * 128, rel=1e-6)


def test_moe_active_flops_smaller_than_total():
    cfg = get_config("deepseek_moe_16b")
    assert cfg.active_param_count() < 0.5 * cfg.param_count()


@pytest.mark.slow
def test_dryrun_single_pair_subprocess(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out_dir = str(tmp_path / "dryrun")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "mamba2-1.3b", "--shape", "long_500k", "--out", out_dir],
        capture_output=True, text=True, timeout=560, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    path = os.path.join(out_dir, "mamba2_1p3b_long_500k_16x16.json")
    with open(path) as f:
        res = json.load(f)
    assert res["memory"]["fits_16gb"]
    assert res["roofline"]["dominant"] in ("compute", "memory", "collective")
    assert res["roofline"]["chips"] == 256


@pytest.mark.slow
def test_dryrun_skips_full_attention_long_context(tmp_path):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    out_dir = str(tmp_path / "dryrun")
    proc = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun",
         "--arch", "qwen3-4b", "--shape", "long_500k", "--out", out_dir],
        capture_output=True, text=True, timeout=300, env=env,
        cwd=os.path.join(os.path.dirname(__file__), ".."),
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    with open(os.path.join(out_dir, "qwen3_4b_long_500k_16x16.json")) as f:
        res = json.load(f)
    assert "skipped" in res
