"""Environment invariants (JAX envs + host envs), partly hypothesis-driven."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.envs import BatchedHostEnv, Catch, GridWorld, HostPong


@given(st.integers(0, 2**31 - 1), st.lists(st.integers(0, 2), min_size=30, max_size=30))
@settings(max_examples=20, deadline=None)
def test_catch_invariants(seed, actions):
    env = Catch()
    state = env.init(jax.random.key(seed))
    step = jax.jit(env.step)
    for a in actions:
        state, ts = step(state, jnp.int32(a))
        obs = np.asarray(ts.obs)
        assert obs.sum() in (1.0, 2.0)  # ball + paddle (may overlap)
        assert obs[-1].sum() >= 1.0  # paddle always on bottom row
        assert float(ts.reward) in (-1.0, 0.0, 1.0)
        if float(ts.reward) != 0.0:
            assert float(ts.discount) == 0.0  # reward only at episode end
        assert 0 <= int(state.ball_y) < env.rows


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_catch_episode_length(seed):
    """Every episode lasts exactly rows-1 steps."""
    env = Catch()
    state = env.init(jax.random.key(seed))
    step = jax.jit(env.step)
    count, done_steps = 0, []
    for t in range(36):
        state, ts = step(state, jnp.int32(1))
        count += 1
        if float(ts.discount) == 0.0:
            done_steps.append(count)
            count = 0
    assert all(d == env.rows - 1 for d in done_steps)
    assert len(done_steps) == 4


@given(st.integers(0, 2**31 - 1), st.lists(st.integers(0, 3), min_size=60, max_size=60))
@settings(max_examples=15, deadline=None)
def test_gridworld_invariants(seed, actions):
    env = GridWorld(size=5, horizon=20)
    state = env.init(jax.random.key(seed))
    step = jax.jit(env.step)
    for a in actions:
        state, ts = step(state, jnp.int32(a))
        obs = np.asarray(ts.obs)
        assert obs[..., 0].sum() == 1.0  # exactly one agent
        assert obs[..., 1].sum() == 1.0  # exactly one goal
        # agent and goal never coincide right after (re)spawn
        if bool(ts.first):
            assert not np.all(state.pos == state.goal)


def test_hostpong_api():
    env = HostPong(seed=3)
    obs = env.reset()
    assert obs.shape == env.obs_shape
    total_done = 0
    for t in range(500):
        obs, r, done, _ = env.step(np.random.randint(0, 3))
        assert obs.shape == env.obs_shape
        assert obs.sum() in (1.0, 2.0)
        if done:
            total_done += 1
            obs = env.reset()
    assert total_done >= 1


def test_batched_env_parallel_step():
    benv = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=6)
    obs = benv.reset()
    assert obs.shape == (6,) + benv.obs_shape
    for _ in range(50):
        obs, rew, dones = benv.step(np.random.randint(0, 3, size=6))
    assert obs.shape == (6,) + benv.obs_shape
    assert rew.dtype == np.float32
    assert dones.dtype == bool


def test_batched_env_autoreset():
    """Batched env auto-resets sub-episodes; lives never go negative."""
    benv = BatchedHostEnv(lambda i: HostPong(max_lives=1, seed=i), num_envs=4)
    benv.reset()
    for _ in range(200):
        benv.step(np.zeros(4, np.int64))
    for env in benv.envs:
        assert env.lives >= 0
        assert not env.needs_reset


# ------------------------------------------------ shared pool lifecycle


def _fresh_pool_state():
    """Isolate pool-lifecycle tests from envs other tests leaked (pre-
    close() code never released references)."""
    if BatchedHostEnv._shared_pool is not None:
        BatchedHostEnv._shared_pool.shutdown(wait=False)
    BatchedHostEnv._shared_pool = None
    BatchedHostEnv._shared_refs = 0


def test_shared_pool_honors_larger_request():
    """A later caller needing more workers grows the shared pool instead
    of being silently pinned to the first caller's size (old bug)."""
    _fresh_pool_state()
    small = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=2)
    first_size = small.pool._max_workers
    big = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=32)
    assert big.pool is small.pool, "one process-wide pool"
    assert big.pool._max_workers >= 32 > first_size
    # and the grown pool actually runs 32-wide batches
    big.reset()
    obs, _, _ = big.step(np.zeros(32, np.int64))
    assert obs.shape == (32,) + big.obs_shape
    small.close()
    big.close()


def test_batched_env_close_releases_shared_pool():
    """close() releases the env's pool reference; the last release shuts
    the shared executor down (threads no longer outlive fit())."""
    _fresh_pool_state()
    a = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=2)
    b = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=2)
    pool = a.pool
    a.close()
    a.close()  # idempotent
    assert BatchedHostEnv._shared_pool is pool, "b still holds a reference"
    b.close()
    assert BatchedHostEnv._shared_pool is None
    assert pool._shutdown
    # the next env transparently builds a fresh pool
    c = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=2)
    c.reset()
    c.step(np.zeros(2, np.int64))
    c.close()
    assert BatchedHostEnv._shared_pool is None


def test_batched_env_private_pool_untouched_by_close():
    from concurrent.futures import ThreadPoolExecutor

    _fresh_pool_state()
    pool = ThreadPoolExecutor(max_workers=2)
    env = BatchedHostEnv(lambda i: HostPong(seed=i), num_envs=2, pool=pool)
    env.close()
    assert not pool._shutdown, "caller-owned pools are the caller's to close"
    pool.shutdown()


def test_batched_env_reset_fans_out_over_pool():
    """reset() steps the member envs on the pool (old code looped
    serially on the calling thread)."""
    import threading

    _fresh_pool_state()
    reset_threads = []

    class RecordingPong(HostPong):
        def reset(self):
            reset_threads.append(threading.current_thread().name)
            return super().reset()

    benv = BatchedHostEnv(lambda i: RecordingPong(seed=i), num_envs=6)
    obs = benv.reset()
    assert obs.shape == (6,) + benv.obs_shape
    assert len(reset_threads) == 6
    assert all(name.startswith("env-pool") for name in reset_threads)
    # fan-out returns envs in order: row i is env i's frame
    for i, env in enumerate(benv.envs):
        np.testing.assert_array_equal(obs[i], env._observe())
    benv.close()
