"""Sharding rules + roofline HLO parsing unit tests."""

import jax
import jax.numpy as jnp
import pytest
from jax.sharding import Mesh, PartitionSpec as P

from repro.launch.roofline import _shape_bytes, collective_bytes
from repro.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    batch_axes,
    spec_for_axes,
    spec_for_shape,
    tree_shardings,
)


@pytest.fixture(scope="module")
def mesh():
    return jax.make_mesh((1, 1), ("data", "model"))


def test_spec_for_axes_basic(mesh):
    spec = spec_for_axes(("embed", "mlp"), DEFAULT_RULES, mesh)
    assert spec == P(None, "model")


def test_spec_for_axes_drops_missing_mesh_axis(mesh):
    # "pod" does not exist on a single-pod mesh
    spec = spec_for_axes(("batch",), DEFAULT_RULES, mesh)
    assert spec == P("data")


def test_spec_for_axes_unknown_raises(mesh):
    with pytest.raises(KeyError):
        spec_for_axes(("nonsense",), DEFAULT_RULES, mesh)


def test_spec_for_shape_divisibility():
    big = jax.make_mesh((1, 4), ("data", "model"), devices=jax.devices() * 4) \
        if len(jax.devices()) >= 1 else None
    # build a fake 4-way model mesh via numpy devices trick is not possible;
    # instead exercise the logic with mesh shape (1,1): everything divides.
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    spec = spec_for_shape((12, 128), ("heads", "mlp"), DEFAULT_RULES, mesh)
    assert spec == P("model", None) or spec == P(None, None) or True


def test_spec_for_shape_drops_nondivisible():
    """On a (1,1) mesh everything divides; emulate non-divisibility by a
    rules table pointing at a size-1 axis — dims always divide by 1, so
    instead check the code path with an artificial mesh axis size via the
    mesh shape dict."""
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    # size-1 axes always divide: sharding kept
    spec = spec_for_shape((7,), ("mlp",), DEFAULT_RULES, mesh)
    assert spec == P("model")


def test_tree_shardings_structure(mesh):
    axes = {"a": ("embed", "mlp"), "b": {"c": ("vocab", "embed")}}
    shapes = {
        "a": jax.ShapeDtypeStruct((4, 8), jnp.float32),
        "b": {"c": jax.ShapeDtypeStruct((16, 4), jnp.float32)},
    }
    out = tree_shardings(axes, mesh, DEFAULT_RULES, shapes)
    assert out["a"].spec == P(None, "model")
    assert out["b"]["c"].spec == P("model", None)


def test_batch_axes(mesh):
    assert batch_axes(mesh, DEFAULT_RULES) == ("data",)


def test_fsdp_rules_shard_embed(mesh):
    spec = spec_for_axes(("embed",), FSDP_RULES, mesh)
    assert spec == P("data")


# ------------------------------------------------------ roofline HLO parsing


def test_shape_bytes():
    assert _shape_bytes("bf16[16,128]") == 16 * 128 * 2
    assert _shape_bytes("f32[4,4]{1,0}") == 64
    assert _shape_bytes("(bf16[8], f32[2])") == 16 + 8


def test_collective_bytes_parsing():
    hlo = """
  %ag = bf16[32,1024]{1,0} all-gather(bf16[2,1024] %x), replica_groups={}
  %ar = f32[128]{0} all-reduce(f32[128] %y), to_apply=%add
  %alltoall = f32[16,64]{1,0} all-to-all(f32[16,64] %z), dimensions={0}
  %other = f32[128]{0} add(f32[128] %a, f32[128] %b)
"""
    out = collective_bytes(hlo)
    assert out["all-gather"] == 32 * 1024 * 2
    assert out["all-reduce"] == 128 * 4
    assert out["all-to-all"] == 16 * 64 * 4
    assert out["reduce-scatter"] == 0
