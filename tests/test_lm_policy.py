"""ISSUE 9 tentpole tests: the LM policy as a first-class Podracer agent.

Pins, per the test archetype (every seam gets a conformance or parity
check, not just smoke):

  * TokenEnv semantics — scripted episodes for copy and reverse, dense
    per-token reward, auto-reset with next-episode obs, batch lockstep;
  * the decode-carry layout contract (batch-leading zero-valued leaves);
  * THE tentpole parity pin: behaviour log-probs from the autoregressive
    ``act`` KV-cache path equal the teacher-forced ``forward`` log-probs
    the learner's loss computes over the same tokens — actor conditioning
    == learner conditioning, position by position;
  * episode-boundary carry reset: after an env auto-reset the carry
    Sebulba threads back in is the zero initial carry, so generation
    restarts at position 0;
  * end-to-end ``Sebulba.fit`` on the token task — on-policy and replay —
    through the unchanged core (ring, drain, shard, publish), with the
    unified result schema.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, optim
from repro.agents.lm_policy import LMPolicyAgent, LMReplayPolicyAgent
from repro.api.env import validate_device_env
from repro.configs.base import get_config
from repro.envs import TokenEnv
from repro.envs.token_env import PAD, SEP


def tiny_cfg(**overrides):
    """A 2-layer float32 toy transformer off the qwen2 template (GQA, no
    softcap -> decode takes the flash_decode path)."""
    kw = dict(
        num_layers=2, d_model=32, num_heads=2, num_kv_heads=1, head_dim=16,
        d_ff=64, vocab_size=32, remat="none", param_dtype="float32",
        cache_dtype="float32",
    )
    kw.update(overrides)
    return dataclasses.replace(get_config("qwen2-1.5b"), **kw)


# ------------------------------------------------------------- TokenEnv


def test_token_env_validates_and_scripted_copy_episode():
    env = TokenEnv(vocab_size=16, prompt_len=3, data_vocab=4)
    validate_device_env(env)
    assert env.obs_shape == () and env.episode_len == 6

    s = env.init(jax.random.key(0))
    prompt = [int(x) for x in s.prompt]
    assert all(SEP < p < SEP + 1 + 4 for p in prompt)
    assert int(env.observe(s)) == prompt[0]

    obs_seq, rew, disc = [int(env.observe(s))], [], []
    # teacher phase actions are ignored; then copy the prompt perfectly
    for a in [0, 0, 0] + prompt:
        s, ts = env.step(s, jnp.int32(a))
        obs_seq.append(int(ts.obs))
        rew.append(float(ts.reward))
        disc.append(float(ts.discount))
    # obs: prompt tokens, SEP, then the agent's own emissions fed back
    assert obs_seq[:4] == prompt + [SEP]
    assert obs_seq[4:6] == prompt[:2]  # autoregressive feedback
    assert rew == [0.0, 0.0, 0.0, 1.0, 1.0, 1.0]
    assert disc == [1.0] * 5 + [0.0]  # terminal marks the boundary
    # the terminal obs already belongs to the NEXT episode (auto-reset)
    assert int(s.t) == 0 and int(s.last_action) == PAD


def test_token_env_reverse_task_rewards_reversed_prompt():
    env = TokenEnv(vocab_size=16, prompt_len=3, task="reverse", data_vocab=8)
    s = env.init(jax.random.key(1))
    prompt = [int(x) for x in s.prompt]
    rew = []
    for a in [0, 0, 0] + prompt[::-1]:
        s, ts = env.step(s, jnp.int32(a))
        rew.append(float(ts.reward))
    assert rew == [0.0, 0.0, 0.0, 1.0, 1.0, 1.0]
    # emitting the prompt FORWARD must not be rewarded (unless palindromic)
    env2 = TokenEnv(vocab_size=16, prompt_len=3, task="reverse", data_vocab=8)
    s2 = env2.init(jax.random.key(1))
    hits = 0
    for i, a in enumerate([0, 0, 0] + prompt):
        s2, ts = env2.step(s2, jnp.int32(a))
        hits += float(ts.reward) if i >= 3 else 0.0
    expected = sum(p == q for p, q in zip(prompt, prompt[::-1]))
    assert hits == expected


def test_token_env_batch_stays_in_lockstep():
    """Fixed-length episodes + simultaneous start: every row resets on the
    same step forever — the invariant the shared decode position needs."""
    env = TokenEnv(vocab_size=16, prompt_len=2)
    B = 5
    states = jax.vmap(env.init)(jax.random.split(jax.random.key(0), B))
    step = jax.vmap(env.step)
    for t in range(3 * env.episode_len):
        actions = jnp.full((B,), 3, jnp.int32)
        states, ts = step(states, actions)
        firsts = np.asarray(ts.first)
        assert firsts.all() or not firsts.any(), (t, firsts)
        assert (np.asarray(ts.first)
                == ((t + 1) % env.episode_len == 0)).all()


def test_token_env_bad_args_rejected():
    with pytest.raises(ValueError, match="copy"):
        TokenEnv(task="sort")
    with pytest.raises(ValueError, match="data_vocab"):
        TokenEnv(vocab_size=8, data_vocab=7)


# ------------------------------------------------------ carry layout


def test_decode_carry_is_batch_leading_and_zero_valued():
    agent = LMPolicyAgent(tiny_cfg(), max_seq=8)
    B = 3
    carry = agent.initial_carry(B)
    leaves = jax.tree_util.tree_flatten_with_path(carry)[0]
    assert leaves, "recurrent carry must be nonempty"
    for path, leaf in leaves:
        assert leaf.shape[0] == B, (jax.tree_util.keystr(path), leaf.shape)
        assert not np.any(np.asarray(leaf)), jax.tree_util.keystr(path)
    # and the protocol admits it natively (the relaxed zero-VALUE check)
    resolved, spec = api.resolve_agent(agent)
    assert resolved is agent and spec.recurrent


# ------------------------------------- tentpole parity: act vs forward


@pytest.mark.slow
def test_act_kv_cache_logp_matches_teacher_forced_forward():
    """The decode-carry act path and the loss's teacher-forced prefill
    must condition identically: log pi(a_t | obs_<=t) computed step by
    step through the KV cache equals the same quantity read out of one
    full forward over the episode's observations."""
    from repro.rl import losses

    env = TokenEnv(vocab_size=32, prompt_len=3, data_vocab=6)
    E = env.episode_len
    agent = LMPolicyAgent(tiny_cfg(), max_seq=E)
    B = 4
    params = agent.init(jax.random.key(0), ())

    states = jax.vmap(env.init)(jax.random.split(jax.random.key(1), B))
    carry = agent.initial_carry(B)
    act = jax.jit(agent.act)
    env_step = jax.jit(jax.vmap(env.step))
    obs_hist, act_hist, logp_hist = [], [], []
    obs = jax.vmap(env.observe)(states)
    for t in range(E):
        actions, aux, carry = act(
            params, obs, jax.random.fold_in(jax.random.key(2), t), carry
        )
        obs_hist.append(obs)
        act_hist.append(actions)
        logp_hist.append(aux.logp)
        states, ts = env_step(states, actions)
        obs = ts.obs
    assert int(jnp.max(carry["pos"])) == E

    tokens = jnp.stack(obs_hist, axis=1)  # (B, E) — what the ring stores
    logits, _, _ = agent.model.forward(params, {"tokens": tokens})
    for t in range(E):
        fwd_logp = losses.log_prob(
            logits[:, t].astype(jnp.float32), act_hist[t]
        )
        np.testing.assert_allclose(
            np.asarray(logp_hist[t]), np.asarray(fwd_logp), atol=1e-4,
            err_msg=f"act/forward conditioning diverged at position {t}",
        )


@pytest.mark.slow
def test_episode_reset_restarts_generation_from_zero_state():
    """Reproduce Sebulba's fused-step reset (jnp.where against the initial
    carry where discount == 0) across an episode boundary: the second
    episode's first decode must be bit-identical to a cold start."""
    env = TokenEnv(vocab_size=32, prompt_len=2, data_vocab=4)
    E = env.episode_len
    agent = LMPolicyAgent(tiny_cfg(), max_seq=E)
    B = 2
    params = agent.init(jax.random.key(0), ())
    carry0 = agent.initial_carry(B)

    states = jax.vmap(env.init)(jax.random.split(jax.random.key(3), B))
    carry = carry0
    obs = jax.vmap(env.observe)(states)
    for t in range(E):
        actions, _, carry = agent.act(
            params, obs, jax.random.fold_in(jax.random.key(4), t), carry
        )
        states, ts = jax.vmap(env.step)(states, actions)
        obs = ts.obs
        if t == E - 1:
            assert (np.asarray(ts.discount) == 0.0).all()
            # the runner's reset: restore the initial carry on ended rows
            ended = ts.discount == 0.0
            carry = jax.tree.map(
                lambda c0, c: jnp.where(
                    ended.reshape((B,) + (1,) * (c.ndim - 1)), c0, c
                ),
                carry0, carry,
            )
    for a, b in zip(jax.tree.leaves(carry), jax.tree.leaves(carry0)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # next act on the post-reset carry == cold-start act, bit for bit
    a1, aux1, _ = jax.jit(agent.act)(
        params, obs, jax.random.key(5), carry
    )
    a2, aux2, _ = jax.jit(agent.act)(
        params, obs, jax.random.key(5), agent.initial_carry(B)
    )
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_array_equal(np.asarray(aux1.logp), np.asarray(aux2.logp))


# ------------------------------------------------- end-to-end Sebulba


def _lm_sebulba(agent, env, replay=None, trajectory_length=None):
    from repro.core.sebulba import Sebulba, SebulbaConfig

    return Sebulba(
        optimizer=optim.adam(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1, actor_batch_size=4,
            trajectory_length=trajectory_length or env.episode_len,
            replay=replay,
        ),
        agent=agent,
        device_env=env,
    )


@pytest.mark.slow
def test_lm_policy_trains_through_sebulba_fit():
    """Generation fuses into the device-fleet step and flows through the
    UNCHANGED ring/drain/shard/publish machinery: fit() runs, updates
    land, the unified result schema holds."""
    env = TokenEnv(vocab_size=32, prompt_len=2, data_vocab=4)
    agent = LMPolicyAgent(tiny_cfg(), max_seq=env.episode_len)
    out = _lm_sebulba(agent, env).fit(jax.random.key(0), total_frames=64)
    assert out["updates"] > 0 and out["frames"] >= 64
    assert np.isfinite(out["metrics"]["loss"])
    assert set(api.RESULT_KEYS) <= set(out)


@pytest.mark.slow
def test_lm_replay_policy_trains_off_policy():
    """The replay capability composes: int32 token trajectories through
    the replay ring, PER weights into the loss, priorities back out."""
    from repro.configs.base import ReplayConfig

    env = TokenEnv(vocab_size=32, prompt_len=2, data_vocab=4)
    agent = LMReplayPolicyAgent(tiny_cfg(), max_seq=env.episode_len)
    out = _lm_sebulba(
        agent, env,
        replay=ReplayConfig(capacity=32, sample_batch_size=4, min_size=8,
                            prioritized=True),
    ).fit(jax.random.key(0), total_frames=160)
    assert out["updates"] > 0
    assert out["replay_size"] > 0
    assert np.isfinite(out["metrics"]["loss"])
