"""Property-based tests (hypothesis) for the RL math: V-trace, returns,
losses.  These are the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from _hypothesis_compat import given, settings, st

from repro.kernels.vtrace.ref import vtrace_ref
from repro.rl import losses, returns as rets

jax.config.update("jax_platform_name", "cpu")


def _traj(draw, B=2, T=8):
    shape = (B, T)
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    return (
        jnp.asarray(rng.randn(*shape) * 0.5, jnp.float32),  # log_rhos
        jnp.asarray((rng.rand(*shape) > 0.2) * 0.95, jnp.float32),  # discounts
        jnp.asarray(rng.randn(*shape), jnp.float32),  # rewards
        jnp.asarray(rng.randn(*shape), jnp.float32),  # values
        jnp.asarray(rng.randn(B), jnp.float32),  # bootstrap
    )


@st.composite
def traj_strategy(draw):
    return _traj(draw)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_on_policy_equals_td_lambda(t):
    """With log_rhos == 0 and no clipping, vs == TD(lambda=1) returns
    (V-trace reduces to n-step bootstrapped targets on-policy)."""
    _, disc, rew, val, boot = t
    out = vtrace_ref(jnp.zeros_like(rew), disc, rew, val, boot)
    v_tp1 = jnp.concatenate([val[:, 1:], boot[:, None]], axis=1)
    lam = rets.lambda_returns(rew, disc, v_tp1, lambda_=1.0)
    np.testing.assert_allclose(out.vs, lam, rtol=1e-4, atol=1e-4)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_clipping_monotone(t):
    """rho clipping only shrinks |vs - V| row-wise vs the unclipped trace."""
    lr, disc, rew, val, boot = t
    tight = vtrace_ref(lr, disc, rew, val, boot, clip_rho=1e-6, clip_c=1e-6)
    # with clip -> 0, corrections vanish: vs -> values
    np.testing.assert_allclose(tight.vs, val, rtol=1e-3, atol=1e-3)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_zero_discount_resets(t):
    """Where discount == 0 everywhere, vs_t = V_t + rho*(r_t - V_t)."""
    lr, _, rew, val, boot = t
    out = vtrace_ref(lr, jnp.zeros_like(rew), rew, val, boot)
    rho = jnp.minimum(1.0, jnp.exp(lr))
    expect = val + rho * (rew - val)
    np.testing.assert_allclose(out.vs, expect, rtol=1e-4, atol=1e-4)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_gae_lambda1_equals_full_returns(t):
    _, disc, rew, val, boot = t
    adv, targets = rets.gae(rew, disc, val, boot, lambda_=1.0)
    full = rets.discounted_returns(
        rew, disc, boot
    )  # G_t with bootstrap at the tail
    np.testing.assert_allclose(targets, full, rtol=1e-4, atol=1e-4)


def test_discounted_returns_simple():
    rew = jnp.array([[1.0, 1.0, 1.0]])
    disc = jnp.array([[0.5, 0.5, 0.5]])
    out = rets.discounted_returns(rew, disc, jnp.array([0.0]))
    np.testing.assert_allclose(out, [[1.75, 1.5, 1.0]])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_entropy_bounds(seed):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(4, 7) * 3)
    ent = losses.entropy(logits)
    assert (ent >= -1e-5).all()
    assert (ent <= np.log(7) + 1e-5).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_log_prob_consistency(seed):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(5, 4))
    actions = jnp.asarray(rng.randint(0, 4, 5))
    lp = losses.log_prob(logits, actions)
    full = jax.nn.log_softmax(logits, -1)
    np.testing.assert_allclose(
        lp, jnp.take_along_axis(full, actions[:, None], 1)[:, 0], rtol=1e-5
    )


def test_impala_loss_gradient_direction():
    """Positive advantage -> gradient increases action log-prob."""
    logits = jnp.zeros((1, 1, 3))
    values = jnp.zeros((1, 1))
    actions = jnp.array([[1]])
    behaviour_logp = jnp.log(jnp.array([[1 / 3]]))
    rewards = jnp.array([[10.0]])  # big positive reward -> positive adv
    discounts = jnp.array([[0.0]])
    boot = jnp.array([0.0])

    def pg_only(lg):
        out = losses.impala_loss(
            lg, values, actions, behaviour_logp, rewards, discounts, boot,
            entropy_cost=0.0, value_cost=0.0,
        )
        return out.pg

    g = jax.grad(pg_only)(logits)
    # decreasing loss means increasing logit of action 1
    assert g[0, 0, 1] < 0


# ------------------------------------------------ PER / weighted V-trace


def _traj_batch(key, B=4, T=5, A=3):
    ks = jax.random.split(key, 5)
    logits = jax.random.normal(ks[0], (B, T, A))
    values = jax.random.normal(ks[1], (B, T))
    actions = jax.random.randint(ks[2], (B, T), 0, A)
    behaviour_logp = jnp.log(jnp.full((B, T), 1.0 / A))
    rewards = jax.random.normal(ks[3], (B, T))
    discounts = jnp.full((B, T), 0.9)
    boot = jax.random.normal(ks[4], (B,))
    return logits, values, actions, behaviour_logp, rewards, discounts, boot


def test_per_importance_weights_formula():
    """w_i = (N * P(i))^-beta, normalized so max(w) == 1."""
    probs = jnp.array([0.5, 0.25, 0.125, 0.125])
    size = jnp.asarray(8)
    beta = 0.4
    w = losses.per_importance_weights(probs, size, beta)
    expect = (8.0 * np.asarray(probs)) ** (-beta)
    expect = expect / expect.max()
    np.testing.assert_allclose(np.asarray(w), expect, rtol=1e-6)
    assert np.isclose(np.asarray(w).max(), 1.0)
    # uniform sampling (P = 1/N) is weightless: every w == 1
    w_uni = losses.per_importance_weights(
        jnp.full((4,), 1.0 / 8.0), size, beta
    )
    np.testing.assert_allclose(np.asarray(w_uni), np.ones(4), rtol=1e-6)


def test_per_importance_weights_beta_zero_is_uniform():
    probs = jnp.array([0.7, 0.2, 0.1])
    w = losses.per_importance_weights(probs, jnp.asarray(16), 0.0)
    np.testing.assert_allclose(np.asarray(w), np.ones(3), rtol=1e-6)


def test_weighted_impala_loss_none_weights_bit_exact():
    """impala_loss must stay the exact uniform-weight special case."""
    args = _traj_batch(jax.random.key(0))
    plain = losses.impala_loss(*args)
    weighted = losses.weighted_impala_loss(*args, importance_weights=None)
    for a, b in zip(plain, weighted[: len(plain)]):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_weighted_impala_loss_weights_scale_contribution():
    """Down-weighting a sequence moves the loss toward excluding it."""
    args = _traj_batch(jax.random.key(1), B=2)
    w_first = losses.weighted_impala_loss(
        *args, importance_weights=jnp.array([1.0, 0.0]),
        entropy_cost=0.0, value_cost=1.0,
    )
    w_uniform = losses.weighted_impala_loss(
        *args, importance_weights=jnp.array([1.0, 1.0]),
        entropy_cost=0.0, value_cost=1.0,
    )
    # zero weight on row 1 must change the total (unless the rows were
    # miraculously identical) and the gradient w.r.t. row 1's logits is 0
    assert not np.isclose(float(w_first.total), float(w_uniform.total))

    def row1_loss(lg):
        a = (lg,) + args[1:]
        return losses.weighted_impala_loss(
            *a, importance_weights=jnp.array([1.0, 0.0]),
            entropy_cost=0.0, value_cost=1.0,
        ).total

    g = jax.grad(row1_loss)(args[0])
    np.testing.assert_allclose(np.asarray(g[1]), 0.0, atol=1e-7)


def test_weighted_impala_loss_per_seq_td():
    """per_seq_td is the per-sequence mean |vs - V|, shape (B,)."""
    args = _traj_batch(jax.random.key(2), B=3, T=4)
    out = losses.weighted_impala_loss(*args)
    assert out.per_seq_td.shape == (3,)
    assert bool(jnp.all(out.per_seq_td >= 0.0))
    # doubling the value error of one row must raise only its td
    logits, values = args[0], args[1]
    far_values = values.at[0].add(100.0)
    out2 = losses.weighted_impala_loss(logits, far_values, *args[2:])
    assert float(out2.per_seq_td[0]) > float(out.per_seq_td[0])
