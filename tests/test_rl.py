"""Property-based tests (hypothesis) for the RL math: V-trace, returns,
losses.  These are the system's core invariants."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from repro.kernels.vtrace.ref import vtrace_ref
from repro.rl import losses, returns as rets

jax.config.update("jax_platform_name", "cpu")


def _traj(draw, B=2, T=8):
    shape = (B, T)
    rng = np.random.RandomState(draw(st.integers(0, 2**31 - 1)))
    return (
        jnp.asarray(rng.randn(*shape) * 0.5, jnp.float32),  # log_rhos
        jnp.asarray((rng.rand(*shape) > 0.2) * 0.95, jnp.float32),  # discounts
        jnp.asarray(rng.randn(*shape), jnp.float32),  # rewards
        jnp.asarray(rng.randn(*shape), jnp.float32),  # values
        jnp.asarray(rng.randn(B), jnp.float32),  # bootstrap
    )


@st.composite
def traj_strategy(draw):
    return _traj(draw)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_on_policy_equals_td_lambda(t):
    """With log_rhos == 0 and no clipping, vs == TD(lambda=1) returns
    (V-trace reduces to n-step bootstrapped targets on-policy)."""
    _, disc, rew, val, boot = t
    out = vtrace_ref(jnp.zeros_like(rew), disc, rew, val, boot)
    v_tp1 = jnp.concatenate([val[:, 1:], boot[:, None]], axis=1)
    lam = rets.lambda_returns(rew, disc, v_tp1, lambda_=1.0)
    np.testing.assert_allclose(out.vs, lam, rtol=1e-4, atol=1e-4)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_clipping_monotone(t):
    """rho clipping only shrinks |vs - V| row-wise vs the unclipped trace."""
    lr, disc, rew, val, boot = t
    tight = vtrace_ref(lr, disc, rew, val, boot, clip_rho=1e-6, clip_c=1e-6)
    # with clip -> 0, corrections vanish: vs -> values
    np.testing.assert_allclose(tight.vs, val, rtol=1e-3, atol=1e-3)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_vtrace_zero_discount_resets(t):
    """Where discount == 0 everywhere, vs_t = V_t + rho*(r_t - V_t)."""
    lr, _, rew, val, boot = t
    out = vtrace_ref(lr, jnp.zeros_like(rew), rew, val, boot)
    rho = jnp.minimum(1.0, jnp.exp(lr))
    expect = val + rho * (rew - val)
    np.testing.assert_allclose(out.vs, expect, rtol=1e-4, atol=1e-4)


@given(traj_strategy())
@settings(max_examples=25, deadline=None)
def test_gae_lambda1_equals_full_returns(t):
    _, disc, rew, val, boot = t
    adv, targets = rets.gae(rew, disc, val, boot, lambda_=1.0)
    full = rets.discounted_returns(
        rew, disc, boot
    )  # G_t with bootstrap at the tail
    np.testing.assert_allclose(targets, full, rtol=1e-4, atol=1e-4)


def test_discounted_returns_simple():
    rew = jnp.array([[1.0, 1.0, 1.0]])
    disc = jnp.array([[0.5, 0.5, 0.5]])
    out = rets.discounted_returns(rew, disc, jnp.array([0.0]))
    np.testing.assert_allclose(out, [[1.75, 1.5, 1.0]])


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=20, deadline=None)
def test_entropy_bounds(seed):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(4, 7) * 3)
    ent = losses.entropy(logits)
    assert (ent >= -1e-5).all()
    assert (ent <= np.log(7) + 1e-5).all()


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_log_prob_consistency(seed):
    rng = np.random.RandomState(seed)
    logits = jnp.asarray(rng.randn(5, 4))
    actions = jnp.asarray(rng.randint(0, 4, 5))
    lp = losses.log_prob(logits, actions)
    full = jax.nn.log_softmax(logits, -1)
    np.testing.assert_allclose(
        lp, jnp.take_along_axis(full, actions[:, None], 1)[:, 0], rtol=1e-5
    )


def test_impala_loss_gradient_direction():
    """Positive advantage -> gradient increases action log-prob."""
    logits = jnp.zeros((1, 1, 3))
    values = jnp.zeros((1, 1))
    actions = jnp.array([[1]])
    behaviour_logp = jnp.log(jnp.array([[1 / 3]]))
    rewards = jnp.array([[10.0]])  # big positive reward -> positive adv
    discounts = jnp.array([[0.0]])
    boot = jnp.array([0.0])

    def pg_only(lg):
        out = losses.impala_loss(
            lg, values, actions, behaviour_logp, rewards, discounts, boot,
            entropy_cost=0.0, value_cost=0.0,
        )
        return out.pg

    g = jax.grad(pg_only)(logits)
    # decreasing loss means increasing logit of action 1
    assert g[0, 0, 1] < 0
