"""PR 10 serving stack: paged KV blocks, the continuous-batching
scheduler, seeded sampling, and the ServeEngine.

The load-bearing pins:

  * paged-vs-dense bit-exactness — the same prompts/seeds produce
    IDENTICAL token streams through block tables and through the dense
    ``(B, max_seq)`` cache, including after pages cycle through the
    free list (the mixed workload needs 16 pages total against a
    12-page pool, so later requests always run on recycled blocks);
  * the scheduler chaos test — staggered arrivals + a pool tight enough
    to force cache-pressure preemption still completes every request
    with outputs identical to the unpressured dense run
    (recompute-on-restart + per-request sampling streams);
  * sampling determinism — a request's tokens are a function of
    ``(seed, rid, token index)`` only, never of row/batch placement.
"""

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api
from repro.launch.steps import request_keys, sample_tokens
from repro.serve import (
    BlockAllocator,
    CacheExhausted,
    Request,
    RowTables,
    Scheduler,
    ServeConfig,
    ServeEngine,
)

# ------------------------------------------------------------------ blocks


def test_allocator_lifo_reserves_scratch_page():
    """Page 0 is the reserved scratch target for out-of-range writes: it
    is never handed out, and releasing it is an error.  Frees are LIFO so
    page layouts replay deterministically."""
    alloc = BlockAllocator(5)
    assert [alloc.alloc() for _ in range(4)] == [1, 2, 3, 4]
    assert alloc.free_blocks == 0 and alloc.used_blocks == 4
    with pytest.raises(CacheExhausted):
        alloc.alloc()
    alloc.release(3)
    alloc.release(2)
    assert alloc.alloc() == 2  # LIFO: last freed, first reused
    with pytest.raises(ValueError):
        alloc.release(0)


def test_row_tables_grow_release_and_occupancy():
    alloc = BlockAllocator(6)
    tables = RowTables(batch_rows=2, blocks_per_row=3, block_size=4,
                       allocator=alloc)
    tables.ensure(0, 0)      # slot 0 -> 1 page
    tables.ensure(0, 7)      # slots through 7 -> 2 pages
    tables.ensure(1, 3)
    arr = tables.as_array()
    assert arr.shape == (2, 3)
    assert arr[0, 0] != 0 and arr[0, 1] != 0 and arr[0, 2] == 0
    assert tables.occupancy() == pytest.approx(3 / 5)
    with pytest.raises(ValueError):
        tables.ensure(0, 12)  # past blocks_per_row * block_size
    tables.release(0)
    assert alloc.used_blocks == 1
    assert tables.as_array()[0].tolist() == [0, 0, 0]


# --------------------------------------------------------------- scheduler


def _cfg(**kw) -> ServeConfig:
    base = dict(batch_rows=2, prefill_chunk=4, token_budget=3,
                block_size=8, num_blocks=9, max_seq=32)
    base.update(kw)
    return ServeConfig(**base)


def test_request_and_config_validation():
    with pytest.raises(ValueError):
        Request(rid=1, prompt=(), max_new_tokens=1)
    with pytest.raises(ValueError):
        Request(rid=1, prompt=(1,), max_new_tokens=0)
    with pytest.raises(ValueError):
        _cfg(token_budget=0).validate()
    with pytest.raises(ValueError):
        _cfg(max_seq=30).validate()  # not a multiple of block_size
    sched = Scheduler(_cfg())
    with pytest.raises(ValueError):  # needs L + max_new - 1 = 33 slots
        sched.submit(Request(rid=1, prompt=tuple(range(30)),
                             max_new_tokens=4))


def test_scheduler_budget_splits_decode_first_then_chunked_prefill():
    """Sarathi interleaving: every decode row costs one budget token up
    front; the remainder goes to prefill chunks of at most C tokens."""
    sched = Scheduler(_cfg())
    sched.submit(Request(rid=1, prompt=tuple(range(6)), max_new_tokens=2))
    sched.submit(Request(rid=2, prompt=tuple(range(8)), max_new_tokens=2))
    assert sched.admit(now=0) == [1, 2]

    plan = sched.plan_step()  # budget 3: row 0 gets a 3-token chunk
    assert plan.prefill_rows == [0] and plan.decode_rows == []
    assert plan.prefill_len.tolist() == [3, 0]
    assert plan.prefill_pos[0] == 0 and plan.rids.tolist() == [1, 2]
    sched.record_prefill(plan, np.zeros(2, np.int32))

    plan = sched.plan_step()  # row 0 finishes (3 left), samples token 1
    assert plan.finish_rows == [0] and plan.tok_idx[0] == 0
    sched.record_prefill(plan, np.array([7, 0], np.int32))

    plan = sched.plan_step()  # row 0 decodes (priority), row 1 gets 3-1=2
    assert plan.decode_rows == [0] and plan.prefill_rows == [1]
    assert plan.decode_tokens[0, 0] == 7 and plan.decode_pos[0] == 6
    assert plan.tok_idx[0] == 1 and plan.prefill_len[1] == 2


def test_scheduler_seeded_admission_is_deterministic():
    reqs = [Request(rid=r, prompt=(1, 2), max_new_tokens=1)
            for r in (1, 2, 3, 4, 5)]
    expect = sorted((1, 2, 3, 4, 5),
                    key=lambda r: zlib.crc32(f"9:{r}".encode()))
    orders = []
    for _ in range(2):
        sched = Scheduler(_cfg(batch_rows=5, seed=9,
                               shuffle_admissions=True))
        for r in reqs:
            sched.submit(r)
        orders.append(sched.admit(now=0))
    assert orders[0] == orders[1] == expect
    # default is plain FIFO
    sched = Scheduler(_cfg(batch_rows=5))
    for r in reqs:
        sched.submit(r)
    assert sched.admit(now=0) == [1, 2, 3, 4, 5]


def test_scheduler_preempts_youngest_and_requeues_front():
    sched = Scheduler(_cfg())
    for r in (1, 2, 3):
        sched.submit(Request(rid=r, prompt=(1, 2), max_new_tokens=1))
    assert sched.admit(now=0) == [1, 2]
    row, rid = sched.preempt_youngest()
    assert rid == 2 and row == 1 and sched.preempted == 1
    # the preempted request re-enters BEFORE the never-admitted rid 3
    assert sched.admit(now=0) == [2]
    assert [r.rid for r in sched._queue] == [3]


# ---------------------------------------------------------------- sampling


def test_sampling_keyed_by_request_not_row():
    """ISSUE 10 bugfix pin: the serve step's sampling is seeded per
    ``(seed, rid, token index)`` — moving a request to a different batch
    row (as continuous batching constantly does) cannot change its
    tokens."""
    logits = jax.random.normal(jax.random.key(0), (4, 64))
    rids = jnp.array([11, 22, 33, 44])
    idx = jnp.array([0, 1, 2, 3])
    toks = sample_tokens(logits, request_keys(7, rids, idx),
                         temperature=0.7, top_k=8)
    perm = jnp.array([2, 0, 3, 1])
    toks_p = sample_tokens(logits[perm],
                           request_keys(7, rids[perm], idx[perm]),
                           temperature=0.7, top_k=8)
    assert jnp.array_equal(toks_p, toks[perm])
    # different seed, different stream (for this draw)
    toks_s = sample_tokens(logits, request_keys(8, rids, idx),
                           temperature=0.7, top_k=8)
    assert not jnp.array_equal(toks_s, toks)


def test_sampling_greedy_default_and_topk_one():
    logits = jax.random.normal(jax.random.key(1), (3, 32))
    keys = request_keys(0, jnp.array([1, 2, 3]), jnp.array([0, 0, 0]))
    greedy = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    assert jnp.array_equal(sample_tokens(logits, keys), greedy)
    assert jnp.array_equal(
        sample_tokens(logits, keys, temperature=2.0, top_k=1), greedy
    )


# ------------------------------------------------------------------ result


def test_make_serve_result_schema_absent_as_zero():
    res = api.make_serve_result(outputs={1: [2, 3]}, seconds=2.0,
                                tokens_prefilled=10, tokens_decoded=10)
    assert set(api.SERVE_RESULT_KEYS) <= set(res)
    assert res["preempted"] == 0 and res["ttft_p50"] == 0.0
    assert res["tokens_per_s"] == pytest.approx(10.0)
    with pytest.raises(TypeError):
        api.make_serve_result(outputs={}, seconds=1.0, bogus=1)


# ------------------------------------------------------------------ engine


def test_engine_rejects_unpageable_families():
    from repro.configs.base import get_reduced_config
    from repro.models.model import make_model

    model = make_model(get_reduced_config("mamba2-1.3b"))
    with pytest.raises(ValueError):
        ServeEngine(model, None, ServeConfig())


@pytest.fixture(scope="module")
def small_lm():
    from repro.configs.base import get_config
    from repro.models.model import make_model

    cfg = dataclasses.replace(
        get_config("qwen2-1.5b"), num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, head_dim=32, d_ff=256, vocab_size=512, remat="none",
    )
    model = make_model(cfg, unroll=True)
    return model, model.init(jax.random.key(0))


def _mixed_requests():
    """7 requests, staggered arrivals, mixed prompt/gen lengths.  Page
    demand sums to 16 blocks against the 12-page pool below, so the free
    list necessarily recycles pages mid-run."""
    key = jax.random.key(3)
    spec = [(5, 4, 0), (12, 6, 0), (3, 8, 1), (17, 3, 2), (9, 5, 4),
            (6, 7, 5), (14, 4, 6)]
    reqs = []
    for i, (L, g, arrival) in enumerate(spec):
        toks = jax.random.randint(jax.random.fold_in(key, i), (L,), 0, 512)
        reqs.append(Request(rid=i + 1, prompt=tuple(int(t) for t in toks),
                            max_new_tokens=g, arrival=arrival))
    return reqs


def _engine_cfg(num_blocks: int) -> ServeConfig:
    return ServeConfig(batch_rows=3, prefill_chunk=8, token_budget=11,
                       block_size=8, num_blocks=num_blocks, max_seq=32,
                       temperature=0.8, top_k=8, seed=42)


@pytest.fixture(scope="module")
def dense_outputs(small_lm):
    model, params = small_lm
    return ServeEngine(model, params, _engine_cfg(13),
                       paged=False).run(_mixed_requests())


@pytest.mark.slow
def test_paged_generation_bitexact_with_dense_after_block_reuse(
        small_lm, dense_outputs):
    """ISSUE 10 acceptance pin: identical token streams through block
    tables and the dense cache — on a workload whose page demand (16)
    exceeds the pool (12), so reuse from the free list is exercised."""
    model, params = small_lm
    engine = ServeEngine(model, params, _engine_cfg(13), paged=True)
    res = engine.run(_mixed_requests())
    assert res["outputs"] == dense_outputs["outputs"]
    for req in _mixed_requests():
        assert len(res["outputs"][req.rid]) == req.max_new_tokens
    assert engine.allocator.used_blocks == 0  # every page released


@pytest.mark.slow
def test_chaos_staggered_arrivals_with_cache_pressure(
        small_lm, dense_outputs):
    """ISSUE 10 acceptance pin: a pool tight enough to force preemption
    (5 usable pages for requests needing up to 3 each) still completes
    every request, with outputs identical to the unpressured dense run —
    recompute-on-restart replays the same per-request sampling streams."""
    model, params = small_lm
    res = ServeEngine(model, params, _engine_cfg(6),
                      paged=True).run(_mixed_requests())
    assert res["preempted"] > 0
    assert res["completed"] == 7
    assert res["outputs"] == dense_outputs["outputs"]


@pytest.mark.slow
def test_engine_counters_and_reset_determinism(small_lm):
    model, params = small_lm
    reqs = _mixed_requests()
    engine = ServeEngine(model, params, _engine_cfg(13), paged=True)
    first = engine.run(reqs)
    engine.reset()
    second = engine.run(reqs)  # compiled steps reused, same tokens
    assert first["outputs"] == second["outputs"]
    assert first["tokens_prefilled"] == sum(len(r.prompt) for r in reqs)
    assert first["tokens_decoded"] == sum(
        r.max_new_tokens - 1 for r in reqs
    )
    assert first["completed"] == 7 and first["steps"] > 0
    assert first["prefill_chunks"] > 0
    assert 0 < first["cache_occupancy_mean"] <= \
        first["cache_occupancy_peak"] <= 1
    assert first["ttft_p95"] >= first["ttft_p50"] >= 0
