"""flash_decode Pallas kernel vs the decode oracle, swept."""

import jax
import jax.numpy as jnp
import pytest

from repro.kernels.flash_decode.flash_decode import flash_decode_pallas
from repro.kernels.flash_decode.ref import decode_attention_ref


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize(
    "B,S,H,K,h,pos,window,bs",
    [
        (2, 256, 4, 2, 64, 100, 0, 64),
        (1, 512, 8, 1, 32, 511, 0, 128),  # MQA, full cache
        (2, 256, 4, 4, 64, 200, 64, 64),  # MHA + sliding window
        (1, 128, 8, 2, 128, 0, 0, 64),  # first token
    ],
)
def test_flash_decode_matches_ref(B, S, H, K, h, pos, window, bs, dtype):
    ks = jax.random.split(jax.random.key(S + pos), 3)
    q = jax.random.normal(ks[0], (B, 1, H, h), jnp.float32).astype(dtype)
    kc = jax.random.normal(ks[1], (B, S, K, h), jnp.float32).astype(dtype)
    vc = jax.random.normal(ks[2], (B, S, K, h), jnp.float32).astype(dtype)
    out = flash_decode_pallas(
        q, kc, vc, jnp.int32(pos), window=window, block_s=bs, interpret=True
    )
    ref = decode_attention_ref(q, kc, vc, jnp.int32(pos), window=window)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-5
    assert jnp.abs(
        out.astype(jnp.float32) - ref.astype(jnp.float32)
    ).max() < tol


@pytest.mark.parametrize("B", [4, 32])
@pytest.mark.parametrize("pos", [0, 7, 31])
def test_flash_decode_actor_shapes_gqa(B, pos):
    """ISSUE 9 satellite: the LM actor's exact decode shapes — (B, 1)
    queries at B = 4/32 against a small fixed cache, with GQA
    ``num_kv_heads < num_heads`` — match the oracle, including pos = 0
    (freshly reset carry) and the final cache slot."""
    S, H, K, h = 32, 4, 2, 64
    ks = jax.random.split(jax.random.key(B * 100 + pos), 3)
    q = jax.random.normal(ks[0], (B, 1, H, h), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, K, h), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, K, h), jnp.float32)
    out = flash_decode_pallas(
        q, kc, vc, jnp.int32(pos), block_s=16, interpret=True
    )
    ref = decode_attention_ref(q, kc, vc, jnp.int32(pos))
    assert jnp.abs(out - ref).max() < 1e-5


def test_flash_decode_per_row_positions_one_batch():
    """ISSUE 10 satellite: ragged per-row positions — rows at pos 0, 7
    and 31 decoded in ONE batch (the continuous-batching decode dispatch
    shape) — match the oracle, and the oracle's per-row batch equals the
    stacked scalar-position calls exactly."""
    S, H, K, h = 32, 4, 2, 64
    pos = jnp.array([0, 7, 31], jnp.int32)
    ks = jax.random.split(jax.random.key(17), 3)
    q = jax.random.normal(ks[0], (3, 1, H, h), jnp.float32)
    kc = jax.random.normal(ks[1], (3, S, K, h), jnp.float32)
    vc = jax.random.normal(ks[2], (3, S, K, h), jnp.float32)
    ref = decode_attention_ref(q, kc, vc, pos)
    out = flash_decode_pallas(q, kc, vc, pos, block_s=16, interpret=True)
    assert jnp.abs(out - ref).max() < 1e-5
    for b in range(3):
        row = decode_attention_ref(
            q[b : b + 1], kc[b : b + 1], vc[b : b + 1], jnp.int32(int(pos[b]))
        )
        assert jnp.array_equal(ref[b : b + 1], row)


def test_flash_decode_paged_matches_refs_with_permuted_tables():
    """ISSUE 10 satellite: the block-table kernel at a scrambled
    logical->physical page layout (page 0 reserved scratch) matches the
    paged oracle, which itself is bit-exact with the dense oracle over
    the gathered cache."""
    from repro.kernels.flash_decode.flash_decode import (
        flash_decode_pallas_paged,
    )
    from repro.kernels.flash_decode.ref import (
        gather_pages,
        paged_decode_attention_ref,
    )

    B, nb, bs, H, K, h = 3, 4, 8, 4, 2, 32
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(23), 4)
    q = jax.random.normal(ks[0], (B, 1, H, h), jnp.float32)
    kp = jax.random.normal(ks[1], (P, bs, K, h), jnp.float32)
    vp = jax.random.normal(ks[2], (P, bs, K, h), jnp.float32)
    tables = 1 + jax.random.permutation(ks[3], P - 1)[: B * nb].reshape(B, nb)
    tables = tables.astype(jnp.int32)
    pos = jnp.array([0, 9, 31], jnp.int32)

    ref = paged_decode_attention_ref(q, kp, vp, tables, pos)
    dense = decode_attention_ref(
        q, gather_pages(kp, tables), gather_pages(vp, tables), pos
    )
    assert jnp.array_equal(ref, dense)
    out = flash_decode_pallas_paged(q, kp, vp, tables, pos, interpret=True)
    assert jnp.abs(out - ref).max() < 1e-5


def test_flash_decode_wrapper_paged_jnp_path_and_window_guard():
    """``flash_decode(block_tables=...)`` (the serving decode route) is
    bit-exact with the paged oracle off-TPU, and rejects the unsupported
    block-tables + sliding-window combination."""
    from repro.kernels.flash_decode.ops import flash_decode
    from repro.kernels.flash_decode.ref import paged_decode_attention_ref

    B, nb, bs, H, K, h = 2, 3, 8, 2, 1, 32
    P = 1 + B * nb
    ks = jax.random.split(jax.random.key(29), 4)
    q = jax.random.normal(ks[0], (B, 1, H, h), jnp.float32)
    kp = jax.random.normal(ks[1], (P, bs, K, h), jnp.float32)
    vp = jax.random.normal(ks[2], (P, bs, K, h), jnp.float32)
    tables = 1 + jax.random.permutation(ks[3], P - 1).reshape(B, nb)
    tables = tables.astype(jnp.int32)
    pos = jnp.array([4, 20], jnp.int32)
    out = flash_decode(q, kp, vp, pos, block_tables=tables)
    ref = paged_decode_attention_ref(q, kp, vp, tables, pos)
    assert jnp.array_equal(out, ref)
    with pytest.raises(ValueError):
        flash_decode(q, kp, vp, pos, block_tables=tables, window=8)


def test_flash_decode_wrapper_cpu_path_is_oracle_exact():
    """``flash_decode`` (the wrapper transformer decode now routes
    through) falls back to ``decode_attention`` off-TPU — bit-exact with
    the oracle, so the PR 2/3/4 decode pins are unaffected by the
    rerouting."""
    from repro.kernels.flash_decode.ops import flash_decode

    B, S, H, K, h = 4, 16, 2, 1, 32
    ks = jax.random.split(jax.random.key(9), 3)
    q = jax.random.normal(ks[0], (B, 1, H, h), jnp.float32)
    kc = jax.random.normal(ks[1], (B, S, K, h), jnp.float32)
    vc = jax.random.normal(ks[2], (B, S, K, h), jnp.float32)
    out = flash_decode(q, kc, vc, jnp.int32(5))
    ref = decode_attention_ref(q, kc, vc, jnp.int32(5))
    assert jnp.array_equal(out, ref)


def test_ppo_loss_and_agent():
    from repro.agents.ppo import PPOAgent
    from repro.agents.impala import ConvActorCritic
    from repro.data.trajectory import Trajectory

    net = ConvActorCritic(3, channels=(8,), blocks=1, hidden=32)
    agent = PPOAgent(net)
    params = agent.init(jax.random.key(0), (8, 8, 1))
    B, T = 4, 6
    traj = Trajectory(
        obs=jnp.ones((B, T, 8, 8, 1)),
        actions=jnp.zeros((B, T), jnp.int32),
        rewards=jnp.ones((B, T)),
        discounts=jnp.full((B, T), 0.9),
        behaviour_logp=jnp.full((B, T), -1.0),
        bootstrap_obs=jnp.ones((B, 8, 8, 1)),
    )
    loss, metrics = jax.jit(agent.loss)(params, traj)
    assert bool(jnp.isfinite(loss))
    g = jax.grad(lambda p: agent.loss(p, traj)[0])(params)
    assert max(float(jnp.abs(x).max()) for x in jax.tree.leaves(g)) > 0
