"""End-to-end behaviour tests for the Podracer system (paper claims at
laptop scale): Anakin solves Catch fully on-device; Sebulba trains an
IMPALA agent off host environments; the two share RL substrate."""

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.agents.actor_critic import MLPActorCritic
from repro.core.anakin import Anakin, AnakinConfig
from repro.envs import Catch, GridWorld

# full training loops: excluded from the fast tier, run in full tier-1
pytestmark = pytest.mark.slow


def test_anakin_solves_catch_end_to_end():
    """The paper's Colab demo regime: a few seconds of on-device training
    reaches optimal Catch play (mean reward/step == 1/(rows-1))."""
    env = Catch()
    net = MLPActorCritic(env.num_actions, (64, 64))
    ank = Anakin(
        env, net, optim.adam(3e-3, clip_norm=1.0),
        AnakinConfig(unroll_length=10, batch_per_device=64,
                     iterations_per_call=50),
    )
    state = ank.init_state(jax.random.key(0))
    reward = -1.0
    for _ in range(10):
        state, m = ank.run(state)
        reward = float(m["reward"])
        if reward > 0.10:
            break
    assert reward > 0.10  # optimal is 1/9 ~ 0.111


def test_anakin_gridworld_improves():
    env = GridWorld(size=5, horizon=20)
    net = MLPActorCritic(env.num_actions, (64, 64))
    ank = Anakin(
        env, net, optim.adam(1e-3, clip_norm=1.0),
        AnakinConfig(unroll_length=20, batch_per_device=64,
                     iterations_per_call=30),
    )
    state = ank.init_state(jax.random.key(1))
    first, last = None, None
    for i in range(8):
        state, m = ank.run(state)
        if first is None:
            first = float(m["reward"])
        last = float(m["reward"])
    assert last > first


def test_whole_program_is_one_xla_call():
    """Anakin's defining property: N updates x T env steps x B envs run as
    ONE compiled XLA program — verify no per-step Python dispatch by
    checking the jitted callable is cached after the first call."""
    env = Catch()
    net = MLPActorCritic(env.num_actions, (16,))
    ank = Anakin(
        env, net, optim.sgd(1e-2),
        AnakinConfig(unroll_length=5, batch_per_device=8,
                     iterations_per_call=20),
    )
    state = ank.init_state(jax.random.key(0))
    # first call may retrace once (input shardings differ from the loop's
    # steady-state placement); after that the program must be cached.
    state, _ = ank.run(state)
    state, _ = ank.run(state)
    sizes0 = ank._run._cache_size()
    for _ in range(3):
        state, _ = ank.run(state)
    assert ank._run._cache_size() == sizes0  # no retrace in steady state
