"""Anakin integration tests: learning, determinism, both replication modes."""

import jax
import jax.numpy as jnp
import pytest

from repro import optim
from repro.agents.actor_critic import MLPActorCritic
from repro.core.anakin import Anakin, AnakinConfig
from repro.envs import Catch


def _make(mode, iterations=30, seed=0):
    env = Catch()
    net = MLPActorCritic(env.num_actions, (32, 32))
    opt = optim.adam(3e-3, clip_norm=1.0)
    ank = Anakin(
        env, net, opt,
        AnakinConfig(unroll_length=9, batch_per_device=32,
                     iterations_per_call=iterations, mode=mode),
    )
    state = ank.init_state(jax.random.key(seed))
    return ank, state


@pytest.mark.slow  # full training loop (6x50 iterations)
@pytest.mark.parametrize("mode", ["shard_map", "jit"])
def test_anakin_learns_catch(mode):
    ank, state = _make(mode, iterations=50)
    rewards = []
    for _ in range(6):
        state, m = ank.run(state)
        rewards.append(float(m["reward"]))
    # Catch: random ~= -0.05 mean reward/step; solved = +1/9 ~= 0.111
    assert rewards[-1] > 0.05, rewards
    assert rewards[-1] > rewards[0]


def test_anakin_deterministic():
    ank1, s1 = _make("shard_map", iterations=10, seed=7)
    ank2, s2 = _make("shard_map", iterations=10, seed=7)
    s1, m1 = ank1.run(s1)
    s2, m2 = ank2.run(s2)
    diff = jax.tree.map(
        lambda a, b: float(jnp.abs(a - b).max()), s1.params, s2.params
    )
    assert max(jax.tree.leaves(diff)) == 0.0


def test_anakin_modes_agree_on_gradients():
    """shard_map (explicit pmean) and jit (GSPMD) runs are the same program
    on 1 device: same seed must give identical metrics."""
    ank1, s1 = _make("shard_map", iterations=5, seed=3)
    ank2, s2 = _make("jit", iterations=5, seed=3)
    _, m1 = ank1.run(s1)
    _, m2 = ank2.run(s2)
    assert abs(float(m1["loss"]) - float(m2["loss"])) < 1e-4


def test_anakin_steps_per_call_accounting():
    ank, _ = _make("jit", iterations=10)
    assert ank.steps_per_call == 10 * 9 * 32 * jax.device_count()


@pytest.mark.parametrize("mode", ["shard_map", "jit"])
def test_anakin_run_donates_state_in_place(mode):
    """ISSUE 3: the compiled block donates AnakinState — the input state is
    consumed and its storage reused (no double-buffering of params/
    opt_state/env_state), and chaining the returned state keeps working."""
    ank, state = _make(mode, iterations=2)
    old_leaves = jax.tree.leaves(state)
    ptrs = {l.unsafe_buffer_pointer() for l in old_leaves}
    state2, _ = ank.run(state)
    jax.block_until_ready(state2)
    assert all(l.is_deleted() for l in old_leaves), (
        "donated input state must be consumed"
    )
    new_ptrs = {l.unsafe_buffer_pointer() for l in jax.tree.leaves(state2)}
    assert ptrs & new_ptrs, "donation should reuse state storage in place"
    state3, m = ank.run(state2, num_calls=2)  # chaining still works
    assert jnp.isfinite(m["loss"])


def test_anakin_metrics_reduced_on_device():
    """Per-call metrics come back as device scalars (reduced over the
    compiled block's iterations inside the program, not stacked)."""
    ank, state = _make("jit", iterations=4)
    _, metrics = ank.run(state)
    for k, v in metrics.items():
        assert jnp.ndim(v) == 0, (k, v.shape)
    assert float(metrics["episodes"]) >= 0.0
