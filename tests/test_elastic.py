"""ISSUE 8 acceptance — host membership chaos, rejoin-resume, host-tier
supervision wired into Sebulba.

Unit level: ``HostSupervisor`` lifecycle (idempotent start, poll-before-
start, peer-id collisions), ``SimulatedPeerHost`` crash/preempt/rejoin
driving real lease files, rejoin restoring from the newest VALID
checkpoint stamp (a torn newest stamp is skipped), and the seeded
host-level ``FaultPlan`` draws (deterministic schedules, actor draws
untouched by the host extension, target validation).

Integration level (THE chaos proof): a tiny Sebulba mounted with a
``cluster=`` HostSupervisor whose FaultPlan kills a peer host mid-run
and rejoins it later — ``fit`` completes with nonzero ``hosts_lost`` /
``reshards``, the rejoined peer records its resume stamp, and the
result carries the membership epoch.

Multi-process level (slow tier): a REAL subprocess member is SIGKILLed
and its death is detected by lease expiry alone — the detection path
the elastic bench times.
"""

import os
import signal
import time

import pytest

from repro.distributed import (
    HostRegistry,
    HostSupervisor,
    SimulatedPeerHost,
)
from repro.fault import FaultEvent, FaultPlan

jax = pytest.importorskip("jax")
import jax.numpy as jnp  # noqa: E402


# ------------------------------------------------------------------ units


def test_supervisor_lifecycle_and_validation(tmp_path):
    sup = HostSupervisor(str(tmp_path), "host0", ttl=5.0)
    with pytest.raises(RuntimeError):
        sup.poll(0)  # no baseline membership before start
    m = sup.start()
    assert m.hosts == ("host0",) and sup.epoch == m.epoch
    assert sup.start() is m  # idempotent (Sebulba.run starts it again)
    assert sup.poll(0) is None  # stable membership: no bump
    assert sup.rank() == 0 and sup.world_size == 1
    sup.stop()
    with pytest.raises(ValueError):
        HostSupervisor(str(tmp_path), "host0", peers=("host0",))


def test_peer_crash_and_rejoin_bump_epochs(tmp_path):
    sup = HostSupervisor(str(tmp_path), "host0", ttl=5.0, peers=("p0",))
    base = sup.start()
    assert base.hosts == ("host0", "p0")
    try:
        sup.peers["p0"].crash()
        m = sup.poll(1)
        assert m is not None and m.hosts == ("host0",)
        assert (sup.hosts_lost, sup.hosts_joined, sup.reshards) == (1, 0, 1)
        assert sup.poll(2) is None  # loss observed once, not re-counted
        sup.peers["p0"].rejoin()
        m = sup.poll(3)
        assert m is not None and m.hosts == ("host0", "p0")
        assert (sup.hosts_lost, sup.hosts_joined, sup.reshards) == (1, 1, 2)
        assert m.epoch == base.epoch + 2
    finally:
        sup.stop()


def test_rejoin_restores_from_newest_valid_stamp(tmp_path):
    """The PR 7 auto-resume contract as a membership event: the rejoining
    host skips a torn newest stamp and records the newest VALID one."""
    from repro.checkpoint import save

    ckpt = tmp_path / "ckpts"
    ckpt.mkdir()
    save(str(ckpt / "ckpt_00000001.npz"), {"w": jnp.zeros((2,))})
    save(str(ckpt / "ckpt_00000002.npz"), {"w": jnp.ones((2,))})
    (ckpt / "ckpt_00000003.npz").write_bytes(b"torn mid-preemption")
    reg = HostRegistry(str(tmp_path / "reg"), ttl=5.0)
    peer = SimulatedPeerHost(reg, "p0", checkpoint_dir=str(ckpt))
    peer.start()
    try:
        peer.crash()
        assert reg.live_hosts() == ()
        peer.rejoin()
        assert peer.rejoins == 1 and peer.state == "running"
        assert peer.resumed_from == str(ckpt / "ckpt_00000002.npz")
        assert reg.live_hosts() == ("p0",)
    finally:
        peer.stop()


def test_preempt_retires_lease_but_crash_leaves_debris(tmp_path):
    reg = HostRegistry(str(tmp_path), ttl=5.0)
    for host, fault, debris in (("a", "crash", True),
                                ("b", "preempt", False)):
        peer = SimulatedPeerHost(reg, host)
        peer.start()
        getattr(peer, fault)()
        assert host not in reg.live_hosts()
        assert (tmp_path / f"lease_{host}.json").exists() is debris
        peer.stop()


def test_host_fault_plan_draws_are_seeded_and_validated():
    with pytest.raises(ValueError):
        FaultEvent(kind="host_crash", target="actor:0", step=1)
    kw = dict(actors=2, horizon=40, crash_rate=0.05,
              peer_hosts=("p0", "p1"), host_crash_rate=0.1,
              host_rejoin_after=10)
    p1, p2 = FaultPlan.random(7, **kw), FaultPlan.random(7, **kw)
    assert p1.events == p2.events  # same seed, same schedule
    host_events = [e for e in p1.events if e.kind.startswith("host_")]
    assert host_events, "expected host draws at these rates"
    assert all(e.target.startswith("host:") for e in host_events)
    # one fault cycle per host: at most one crash/preempt per peer, each
    # rejoin exactly host_rejoin_after later
    for pid in ("p0", "p1"):
        mine = [e for e in host_events if e.target == f"host:{pid}"]
        faults = [e for e in mine if e.kind != "host_rejoin"]
        rejoins = [e for e in mine if e.kind == "host_rejoin"]
        assert len(faults) <= 1
        if rejoins:
            assert rejoins[0].step == faults[0].step + 10
    # the host extension must not perturb the PR 7 actor schedules
    base = FaultPlan.random(7, actors=2, horizon=40, crash_rate=0.05)
    assert [e for e in p1.events if not e.kind.startswith("host_")] == \
           list(base.events)
    # the injector drains due events in step order
    inj = p1.host_injector()
    drained = inj.due(10_000)
    assert drained == sorted(drained, key=lambda e: e.step)
    assert inj.due(10_000) == []


# ------------------------------------------------------------ integration


def _cluster_sebulba(tmp, plan, peers, ckpt_dir=None, **cfg_kwargs):
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    cfg = dict(
        num_actor_cores=1, threads_per_actor_core=2, actor_batch_size=4,
        trajectory_length=2, queue_capacity=2,
        max_restarts=2, restart_backoff=0.01,
    )
    cfg.update(cfg_kwargs)
    # generous ttl: crash/preempt/rejoin are explicit step-scheduled
    # events (expire() fast-forwards, retire() deletes), so detection
    # never waits on the ttl — but a tight one would let a starved renew
    # thread on a loaded 1-cpu CI box expire the trainer's OWN lease and
    # inflate the counters with spurious lost/rejoined transitions
    sup = HostSupervisor(
        os.path.join(tmp, "registry"), "host0", ttl=10.0, peers=peers,
        fault_plan=plan, checkpoint_dir=ckpt_dir,
    )
    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.sgd(1e-3),
        config=SebulbaConfig(**cfg),
        cluster=sup,
    )
    return seb, sup


def test_host_chaos_fit_completes_with_reshard_accounting(tmp_path):
    """THE ISSUE 8 chaos proof: a seeded FaultPlan crashes a peer host
    mid-run (and rejoins it later); fit completes, the result reports
    nonzero hosts_lost/reshards, the epoch advanced, and the rejoined
    peer resumed from the newest valid stamp."""
    from repro.checkpoint import save

    ckpt = tmp_path / "ckpts"
    ckpt.mkdir()
    save(str(ckpt / "ckpt_00000005.npz"), {"w": jnp.zeros((2,))})
    plan = FaultPlan(events=(
        FaultEvent(kind="host_crash", target="host:p0", step=4),
        FaultEvent(kind="host_rejoin", target="host:p0", step=10),
        FaultEvent(kind="host_preempt", target="host:p1", step=16),
    ), seed=0)
    seb, sup = _cluster_sebulba(
        str(tmp_path), plan, peers=("p0", "p1"), ckpt_dir=str(ckpt)
    )
    res = seb.fit(jax.random.key(0), total_frames=12000)
    assert res["frames"] >= 12000 and res["updates"] > 20
    assert res["hosts_lost"] == 2     # p0 crash + p1 preempt
    assert res["hosts_joined"] == 1   # p0 rejoin
    assert res["reshards"] == 3       # one epoch bump per transition
    assert res["epoch"] == 4  # baseline sync + one bump per transition
    assert seb.stale_epoch_trajs >= 0
    # the rejoin restored from the (only, hence newest valid) stamp
    assert sup.resumes() == [("p0", str(ckpt / "ckpt_00000005.npz"))]
    # graceful exit retired every lease: nothing left to expire
    assert sup.registry.live_hosts() == ()


def test_cluster_without_faults_adds_no_counters(tmp_path):
    seb, _ = _cluster_sebulba(str(tmp_path), None, peers=())
    res = seb.fit(jax.random.key(0), total_frames=4000)
    assert res["frames"] >= 4000
    assert res["hosts_lost"] == 0 and res["hosts_joined"] == 0
    assert res["reshards"] == 0 and seb.stale_epoch_trajs == 0
    assert res["epoch"] >= 1  # the baseline sync recorded host0


# ---------------------------------------------------------- multi-process


@pytest.mark.slow
def test_subprocess_member_sigkill_detected_by_lease_expiry(tmp_path):
    """A real subprocess member is SIGKILLed (no goodbye): the only
    death signal is its lease running out — the detection path the
    elastic bench times and a real preempted worker exercises."""
    from benchmarks.elastic_bench import _spawn, _wait_for

    ttl = 0.5
    registry = str(tmp_path / "reg")
    member = _spawn("member", registry, "m0", ttl=ttl)
    reg = HostRegistry(registry, ttl=ttl)
    try:
        _wait_for(lambda: "m0" in reg.live_hosts(), timeout=30.0,
                  what="the member's first lease")
        base = reg.sync()
        assert "m0" in base.hosts
        member.send_signal(signal.SIGKILL)
        t0 = time.monotonic()
        _wait_for(lambda: "m0" not in reg.sync().hosts, timeout=30.0,
                  what="the lease to expire after SIGKILL")
        latency = time.monotonic() - t0
        after = reg.current()
        assert after.epoch == base.epoch + 1
        # expiry-bound detection: roughly one TTL, never instant-but-
        # flaky (generous ceiling for a loaded CI box)
        assert latency < 20.0
        assert (tmp_path / "reg" / "lease_m0.json").exists()  # debris stays
    finally:
        member.kill()
        member.wait(timeout=10.0)
