"""Sebulba integration tests.

The actor/learner core split needs multiple devices, so the full test runs
in a subprocess with ``--xla_force_host_platform_device_count=8`` (2 actor +
6 learner cores, true device-to-device transfers).  In-process tests cover
the single-device degenerate topology and the data plumbing.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import split_devices
from repro.data.trajectory import Trajectory, TrajectoryAccumulator, split_for_learners


def test_split_devices_single():
    split = split_devices(2, devices=jax.devices())
    if len(jax.devices()) == 1:
        assert split.actor_devices == split.learner_devices


def test_trajectory_accumulator_shapes():
    acc = TrajectoryAccumulator(4)
    for t in range(4):
        acc.add(
            jnp.zeros((3, 5)), jnp.zeros((3,), jnp.int32),
            jnp.zeros((3,)), jnp.ones((3,)), jnp.zeros((3,)),
        )
    assert acc.full
    traj = acc.drain(bootstrap_obs=jnp.zeros((3, 5)))
    assert traj.obs.shape == (3, 4, 5)
    assert traj.actions.shape == (3, 4)
    assert not acc.full


def test_split_for_learners():
    traj = Trajectory(
        obs=jnp.arange(24).reshape(6, 2, 2).astype(jnp.float32),
        actions=jnp.zeros((6, 2), jnp.int32),
        rewards=jnp.zeros((6, 2)),
        discounts=jnp.ones((6, 2)),
        behaviour_logp=jnp.zeros((6, 2)),
        bootstrap_obs=jnp.zeros((6, 2)),
    )
    parts = split_for_learners(traj, 3)
    assert len(parts) == 3
    assert parts[0].obs.shape == (2, 2, 2)
    np.testing.assert_allclose(parts[1].obs, traj.obs[2:4])


class _NumpyGuard:
    """Proxy numpy module that rejects host materialization of jax arrays.

    ``Sebulba._shard_for_learners`` must never pull trajectory leaves to
    host numpy (the paper's direct device-to-device transfer); patching the
    module's ``np`` binding with this proxy makes any such round trip fail
    loudly while leaving jax's own numpy untouched.
    """

    def __init__(self):
        self.violations = []

    def _guarded(self, fn):
        def inner(a, *args, **kwargs):
            if isinstance(a, jax.Array):
                self.violations.append(fn.__name__)
                raise AssertionError(
                    f"np.{fn.__name__} called on a jax.Array: host "
                    "round-trip on the actor->learner path"
                )
            return fn(a, *args, **kwargs)

        return inner

    def __getattr__(self, name):
        attr = getattr(np, name)
        if name in ("asarray", "array", "split", "stack", "concatenate"):
            return self._guarded(attr)
        return attr


def test_shard_for_learners_stays_on_device(monkeypatch):
    """ISSUE 2 acceptance: sharded learner batches are built from device
    slices — no np.asarray of trajectory leaves on the actor->learner
    path — and land as one globally-sharded array per leaf."""
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core import sebulba as sebulba_mod
    from repro.envs import BatchedHostEnv, HostBandit

    seb = sebulba_mod.Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3),
        config=sebulba_mod.SebulbaConfig(
            num_actor_cores=1, actor_batch_size=6, trajectory_length=2
        ),
    )
    traj = Trajectory(
        obs=jnp.arange(24.0).reshape(6, 2, 2),
        actions=jnp.zeros((6, 2), jnp.int32),
        rewards=jnp.ones((6, 2)),
        discounts=jnp.ones((6, 2)),
        behaviour_logp=jnp.zeros((6, 2)),
        bootstrap_obs=jnp.zeros((6, 2)),
    )
    guard = _NumpyGuard()
    monkeypatch.setattr(sebulba_mod, "np", guard)
    shards = seb._shard_for_learners(traj)
    assert guard.violations == []
    for leaf in jax.tree.leaves(shards):
        assert isinstance(leaf, jax.Array)
        assert set(leaf.sharding.device_set) == set(
            seb.split.learner_devices
        )
    np.testing.assert_array_equal(np.asarray(shards.obs), np.asarray(traj.obs))


_SHARD_GUARD_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    import numpy as real_np
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core import sebulba as sebulba_mod
    from repro.data.trajectory import Trajectory
    from repro.envs import BatchedHostEnv, HostBandit

    class Guard:
        def __getattr__(self, name):
            attr = getattr(real_np, name)
            if name in ("asarray", "array", "split", "stack", "concatenate"):
                def inner(a, *args, **kw):
                    assert not isinstance(a, jax.Array), (
                        "np." + name + " on a jax.Array: host round-trip "
                        "on the actor->learner path"
                    )
                    return attr(a, *args, **kw)
                return inner
            return attr

    seb = sebulba_mod.Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3),
        config=sebulba_mod.SebulbaConfig(
            num_actor_cores=1, actor_batch_size=4, trajectory_length=2
        ),
    )
    assert seb.L == 2, seb.L  # the non-degenerate multi-learner split path
    traj = Trajectory(
        obs=jax.device_put(jnp.arange(16.0).reshape(4, 2, 2),
                           seb.split.actor_devices[0]),
        actions=jnp.zeros((4, 2), jnp.int32),
        rewards=jnp.ones((4, 2)), discounts=jnp.ones((4, 2)),
        behaviour_logp=jnp.zeros((4, 2)), bootstrap_obs=jnp.zeros((4, 2)),
    )
    sebulba_mod.np = Guard()
    shards = seb._shard_for_learners(traj)
    sebulba_mod.np = real_np
    devs = [s.data.devices() for s in shards.obs.addressable_shards]
    assert [d for ds in devs for d in ds] == list(seb.split.learner_devices)
    assert real_np.array_equal(
        real_np.asarray(shards.obs), real_np.asarray(traj.obs)
    )
    print("SHARD_GUARD_OK")
    """
)


def test_shard_for_learners_multi_learner_no_host_roundtrip():
    """The L>1 split path (the one that used to np.asarray the whole
    trajectory) must build its shards from device slices — checked on a
    3-device subprocess so the fast tier exercises the real branch."""
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SHARD_GUARD_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=240, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SHARD_GUARD_OK" in proc.stdout


def test_bench_actor_loop_reports_both_pipelines():
    """The --suite sebulba micro-bench must produce the before/after
    actor-loop numbers BENCH_sebulba.json records (tiny sizes here; the
    subprocess FPS sweep is the slow-marked test below)."""
    from benchmarks import sebulba_pipeline

    res = sebulba_pipeline.bench_actor_loop(batch=8, steps=10)
    for key in ("legacy_us_per_step", "fused_us_per_step", "speedup",
                "legacy_fps", "fused_fps"):
        assert key in res and res[key] > 0, res


@pytest.mark.slow
def test_bench_sebulba_e2e_subprocess_sweep():
    """End-to-end FPS point of --suite sebulba (8 placeholder devices in a
    subprocess — slow tier only, keeping the fast tier ~3.5 min)."""
    from benchmarks import sebulba_pipeline

    res = sebulba_pipeline.bench_e2e(frames=6_000)
    assert res["fps"] > 0


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.agents.impala import ConvActorCritic
    from repro.envs import HostPong, BatchedHostEnv
    from repro import optim

    assert len(jax.devices()) == 8
    net = ConvActorCritic(HostPong.num_actions, channels=(8,), blocks=1, hidden=64)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net,
        optimizer=optim.rmsprop(2e-4, clip_norm=1.0),
        config=SebulbaConfig(num_actor_cores=2, threads_per_actor_core=2,
                             actor_batch_size=12, trajectory_length=10),
    )
    assert seb.split.num_actors == 2 and seb.split.num_learners == 6

    # true D2D sharding: slices built on the actor core land one-per-learner
    import jax.numpy as jnp
    import numpy as np
    from repro.data.trajectory import Trajectory
    traj = Trajectory(
        obs=jax.device_put(jnp.arange(12.0 * 10 * 4).reshape(12, 10, 4),
                           seb.split.actor_devices[0]),
        actions=jnp.zeros((12, 10), jnp.int32),
        rewards=jnp.zeros((12, 10)), discounts=jnp.ones((12, 10)),
        behaviour_logp=jnp.zeros((12, 10)),
        bootstrap_obs=jnp.zeros((12, 4)),
    )
    shards = seb._shard_for_learners(traj)
    per_learner = [s.data.devices() for s in shards.obs.addressable_shards]
    assert [d for ds in per_learner for d in ds] == list(seb.split.learner_devices)
    assert shards.obs.shape == (12, 10, 4)
    assert np.array_equal(np.asarray(shards.obs), np.asarray(traj.obs))

    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames=4000)
    assert out["updates"] > 0, out
    assert out["frames"] >= 4000
    import math
    assert math.isfinite(out["metrics"]["loss"])
    print("SEBULBA_OK", out["updates"], out["frames"])
    """
)


@pytest.mark.slow
def test_sebulba_8core_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=480, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SEBULBA_OK" in proc.stdout
