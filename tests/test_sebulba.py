"""Sebulba integration tests.

The actor/learner core split needs multiple devices, so the full test runs
in a subprocess with ``--xla_force_host_platform_device_count=8`` (2 actor +
6 learner cores, true device-to-device transfers).  In-process tests cover
the single-device degenerate topology and the data plumbing.
"""

import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.topology import split_devices
from repro.data.trajectory import Trajectory, TrajectoryAccumulator, split_for_learners


def test_split_devices_single():
    split = split_devices(2, devices=jax.devices())
    if len(jax.devices()) == 1:
        assert split.actor_devices == split.learner_devices


def test_trajectory_accumulator_shapes():
    acc = TrajectoryAccumulator(4)
    for t in range(4):
        acc.add(
            jnp.zeros((3, 5)), jnp.zeros((3,), jnp.int32),
            jnp.zeros((3,)), jnp.ones((3,)), jnp.zeros((3,)),
        )
    assert acc.full
    traj = acc.drain(bootstrap_obs=jnp.zeros((3, 5)))
    assert traj.obs.shape == (3, 4, 5)
    assert traj.actions.shape == (3, 4)
    assert not acc.full


def test_split_for_learners():
    traj = Trajectory(
        obs=jnp.arange(24).reshape(6, 2, 2).astype(jnp.float32),
        actions=jnp.zeros((6, 2), jnp.int32),
        rewards=jnp.zeros((6, 2)),
        discounts=jnp.ones((6, 2)),
        behaviour_logp=jnp.zeros((6, 2)),
        bootstrap_obs=jnp.zeros((6, 2)),
    )
    parts = split_for_learners(traj, 3)
    assert len(parts) == 3
    assert parts[0].obs.shape == (2, 2, 2)
    np.testing.assert_allclose(parts[1].obs, traj.obs[2:4])


_SUBPROCESS_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import sys; sys.path.insert(0, {src!r})
    import jax
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.agents.impala import ConvActorCritic
    from repro.envs import HostPong, BatchedHostEnv
    from repro import optim

    assert len(jax.devices()) == 8
    net = ConvActorCritic(HostPong.num_actions, channels=(8,), blocks=1, hidden=64)
    seb = Sebulba(
        env_factory=lambda seed: HostPong(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net,
        optimizer=optim.rmsprop(2e-4, clip_norm=1.0),
        config=SebulbaConfig(num_actor_cores=2, threads_per_actor_core=2,
                             actor_batch_size=12, trajectory_length=10),
    )
    assert seb.split.num_actors == 2 and seb.split.num_learners == 6
    out = seb.run(jax.random.key(0), (16, 16, 1), total_frames=4000)
    assert out["updates"] > 0, out
    assert out["frames"] >= 4000
    import math
    assert math.isfinite(out["metrics"]["loss"])
    print("SEBULBA_OK", out["updates"], out["frames"])
    """
)


@pytest.mark.slow
def test_sebulba_8core_subprocess():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _SUBPROCESS_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=480, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "SEBULBA_OK" in proc.stdout
