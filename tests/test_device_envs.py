"""Device env zoo: contract validation, host-twin bit-exact parity,
jit/vmap invariance, fleet scenario mixing, and the fused env+act step."""

import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import api, optim
from repro.agents.actor_critic import BatchedMLPActorCritic, MLPActorCritic
from repro.api import ScenarioMix, resolve_scenarios, scenario_rows
from repro.configs.base import ReplayConfig
from repro.core.anakin import Anakin, AnakinConfig
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import (
    Bandit,
    Catch,
    DeviceEnvFleet,
    GridWorld,
    HostDeviceEnv,
    HostPong,
    Pong,
)

# ------------------------------------------------------------- contract


@pytest.mark.parametrize("env_cls", [Bandit, Catch, GridWorld, Pong])
def test_device_env_contract(env_cls):
    api.validate_device_env(env_cls())


def test_contract_rejects_host_envs():
    with pytest.raises(ValueError, match="BatchedHostEnv path"):
        api.validate_device_env(HostPong())


def test_contract_rejects_lying_obs_shape():
    class LyingPong:
        num_actions = 3
        obs_shape = (4, 4, 1)  # declared shape != observe's real output

        def __init__(self):
            self._env = Pong()

        def init(self, rng):
            return self._env.init(rng)

        def observe(self, state):
            return self._env.observe(state)

        def step(self, state, action):
            return self._env.step(state, action)

    with pytest.raises(ValueError, match="obs_shape"):
        api.validate_device_env(LyingPong())


# ------------------------------------------- host twin bit-exact parity


@pytest.mark.parametrize("seed", [0, 3, 11])
def test_pong_matches_host_twin_bit_exact(seed):
    """Same seed -> identical obs/reward/done streams from the numpy
    HostPong and the pure-JAX Pong, through episode boundaries (the host
    twin's reset() consumes the same spawn draw the device env's
    auto-reset does)."""
    host = HostPong(seed=seed)
    dev = HostDeviceEnv(Pong(), seed=seed)
    assert np.array_equal(host._observe(), dev.reset())
    rng = np.random.RandomState(seed)
    terminals = 0
    for t in range(400):
        a = int(rng.randint(3))
        d_obs, d_rew, d_done, _ = dev.step(a)
        h_obs, h_rew, h_done, _ = host.step(a)
        assert h_rew == d_rew and h_done == d_done, f"step {t}"
        if h_done:
            terminals += 1
            # the device obs already opened the next episode; the host
            # twin gets there via reset(), consuming the same draw
            assert np.array_equal(host.reset(), d_obs), f"step {t}"
        else:
            assert np.array_equal(h_obs, d_obs), f"step {t}"
    assert terminals >= 3


def test_hostpong_terminal_frame_shows_the_miss():
    """The done frame is the true terminal board: the missed ball sits on
    the bottom row, not respawned at the top (old bug)."""
    env = HostPong(max_lives=1, seed=0)
    for _ in range(100):
        obs, _, done, _ = env.step(1)
        if done:
            break
    assert done
    assert obs[0].sum() == 0.0, "no freshly-spawned ball at the top"
    assert obs[-1].sum() == 2.0, "miss frame: ball AND paddle on bottom row"
    assert env.ball_y >= env.h - 1


def test_spawn_stream_is_trace_invariant():
    from repro.envs import spawn_ball

    key = jax.random.key(7)
    eager = [spawn_ball(key, n, 16) for n in range(5)]
    jitted = jax.jit(lambda n: spawn_ball(key, n, 16))
    for n, (x, v) in enumerate(eager):
        jx, jv = jitted(n)
        assert float(x) == float(jx) and float(v) == float(jv)
        assert 1 <= float(x) <= 14 and float(v) in (-1.0, 1.0)


# -------------------------------------------------- fleet vs eager envs


@pytest.mark.parametrize(
    "env_factory,steps",
    [
        (lambda: Bandit(), 30),
        (lambda: Catch(), 60),
        (lambda: GridWorld(size=5, horizon=12), 60),
        (lambda: Pong(max_lives=1), 100),
    ],
)
def test_fleet_matches_eager_env_streams(env_factory, steps):
    """The jitted, vmapped fleet reproduces each row's single-env stream
    bit-exactly, through auto-reset boundaries.  (The reference step is
    jitted too: vmap is bit-exact, but XLA's fma fusion makes compiled
    float arithmetic differ from eager by 1 ulp on some envs.)"""
    B = 4
    env = env_factory()
    fleet = DeviceEnvFleet(env_factory, B)
    root = jax.random.key(42)
    fstate = fleet.init(root)
    keys = jax.random.split(root, B)
    estates = [env.init(keys[i]) for i in range(B)]
    np.testing.assert_array_equal(
        np.asarray(fleet.observe(fstate)),
        np.stack([np.asarray(env.observe(s)) for s in estates]),
    )
    fstep = jax.jit(fleet.step)
    estep = jax.jit(env.step)
    rng = np.random.RandomState(0)
    for t in range(steps):
        actions = rng.randint(0, env.num_actions, size=B)
        fstate, fts = fstep(fstate, jnp.asarray(actions, jnp.int32))
        for i in range(B):
            estates[i], ets = estep(estates[i], jnp.int32(actions[i]))
            np.testing.assert_array_equal(
                np.asarray(fts.obs)[i], np.asarray(ets.obs), f"row {i} t {t}"
            )
            assert float(fts.reward[i]) == float(ets.reward)
            assert float(fts.discount[i]) == float(ets.discount)


def test_hostdeviceenv_adapter_autoresets():
    env = HostDeviceEnv(Catch(), seed=1)
    obs = env.reset()
    assert obs.shape == env.obs_shape
    dones = 0
    for _ in range(40):
        obs, rew, done, _ = env.step(1)
        dones += bool(done)
        # reset after done is a no-op: the device env already reset
        if done:
            assert np.array_equal(env.reset(), obs)
    assert dones >= 3


# ------------------------------------------------------- scenario mixes


def test_scenario_rows_apportionment():
    mix = [
        ScenarioMix("a", 2.0, Pong),
        ScenarioMix("b", 1.0, Pong),
        ScenarioMix("c", 1.0, Pong),
    ]
    scenarios = resolve_scenarios(mix)
    rows = scenario_rows(scenarios, 16)
    assert sum(rows) == 16 and all(r >= 1 for r in rows)
    assert rows[0] > rows[1] == rows[2]
    # every scenario is guaranteed a seat even at tiny batches
    assert scenario_rows(scenarios, 3) == (1, 1, 1)
    with pytest.raises(ValueError, match="cannot seat"):
        scenario_rows(scenarios, 2)


def test_resolve_scenarios_validation():
    with pytest.raises(ValueError, match="unique"):
        resolve_scenarios(
            [ScenarioMix("x", 1.0, Pong), ScenarioMix("x", 1.0, Pong)]
        )
    with pytest.raises(ValueError, match="> 0"):
        resolve_scenarios([ScenarioMix("x", 0.0, Pong)])
    with pytest.raises(ValueError, match="share obs_shape"):
        resolve_scenarios(
            [ScenarioMix("p", 1.0, Pong), ScenarioMix("c", 1.0, Catch)]
        )
    # a bare env or factory normalizes to a one-entry portfolio
    (only,) = resolve_scenarios(Pong())
    assert only.name == "Pong" and only.weight == 1.0
    (only,) = resolve_scenarios(Catch)
    assert only.name == "Catch"


def test_fleet_shard_layout_preserves_mix():
    """Each of the ``shards`` equal blocks carries the same scenario
    composition, so slicing across learner devices keeps the mix."""
    mix = [
        ScenarioMix("a", 1.0, lambda: Pong(max_lives=1)),
        ScenarioMix("b", 1.0, Pong),
    ]
    fleet = DeviceEnvFleet(mix, 8, shards=2)
    ids = fleet.scenario_ids
    first, second = ids[:4], ids[4:]
    np.testing.assert_array_equal(first, second)
    assert fleet.rows == (4, 4)
    with pytest.raises(ValueError, match="divide"):
        DeviceEnvFleet(mix, 6, shards=4)


def test_fleet_stats_counts_per_scenario():
    """On-device segment counters match a host-side tally of the same
    timestep stream, attributed to the right scenario rows."""
    mix = [
        ScenarioMix("lives1", 1.0, lambda: Pong(max_lives=1)),
        ScenarioMix("lives3", 2.0, Pong),
    ]
    fleet = DeviceEnvFleet(mix, 5)
    assert fleet.rows == (2, 3)
    ids = np.asarray(fleet.scenario_ids)
    state = fleet.init(jax.random.key(0))
    stats = fleet.init_stats()
    step = jax.jit(fleet.step)
    rng = np.random.RandomState(1)
    expect_eps = np.zeros(2)
    expect_rew = np.zeros(2)
    for _ in range(150):
        actions = jnp.asarray(rng.randint(0, 3, size=5), jnp.int32)
        state, ts = step(state, actions)
        stats = fleet.update_stats(stats, ts)
        done = np.asarray(ts.discount) == 0.0
        rew = np.asarray(ts.reward)
        for s in range(2):
            expect_eps[s] += done[ids == s].sum()
            expect_rew[s] += rew[ids == s].sum()
    summary = fleet.stats_summary(stats)
    assert expect_eps[0] > 0 and expect_eps[1] > 0
    for s, name in enumerate(("lives1", "lives3")):
        assert summary[name]["rows"] == fleet.rows[s]
        assert summary[name]["episodes"] == expect_eps[s]
        assert summary[name]["reward_sum"] == pytest.approx(expect_rew[s])
    assert np.isfinite(summary["lives1"]["mean_return"])


# ------------------------------------------- fused env+act step (Sebulba)


def _device_sebulba(cfg=None, **kw):
    cfg = cfg or SebulbaConfig(
        num_actor_cores=1, threads_per_actor_core=1,
        actor_batch_size=4, trajectory_length=4,
    )
    return Sebulba(
        network=BatchedMLPActorCritic(num_actions=3, hidden=(16,)),
        optimizer=optim.sgd(1e-3), config=cfg,
        device_env=kw.pop("device_env", Pong), **kw,
    )


def test_fused_env_act_step_donation():
    """The device actor program donates the ring, rng, env state, and
    carry — the whole actor state updates in place, one dispatch a step."""
    seb = _device_sebulba()
    fleet = seb._fleet
    device = seb.split.actor_devices[0]
    params, _ = seb.init(jax.random.key(0), fleet.obs_shape)
    env_state = jax.device_put(fleet.init(jax.random.key(1)), device)
    obs = jax.device_put(fleet.observe(env_state), device)
    rew_disc = jax.device_put(jnp.zeros((2, 4), jnp.float32), device)
    stats = jax.device_put(fleet.init_stats(), device)
    rng = jax.device_put(jax.random.key(2), device)
    buf = seb._make_actor_buffer(params, obs, device)

    old_buf, old_env = buf, env_state
    buf_ptr = buf.obs.unsafe_buffer_pointer()
    out = seb._device_act_step(
        params, buf, rng, env_state, obs, rew_disc, (), stats
    )
    buf, rng, env_state, obs, rew_disc, carry, stats = out
    jax.block_until_ready(out)
    assert old_buf.obs.is_deleted(), "donated ring must be consumed"
    assert buf.obs.unsafe_buffer_pointer() == buf_ptr, (
        "donation must reuse the ring storage in place"
    )
    assert all(
        leaf.is_deleted() for leaf in jax.tree.leaves(old_env)
    ), "donated env state must be consumed"
    assert not any(
        leaf.is_deleted() for leaf in jax.tree.leaves(params)
    ), "params are read-only"
    assert int(buf.t) == 1


def test_sebulba_device_env_end_to_end():
    """Device-env Sebulba trains across a 2-scenario mix and reports
    per-scenario counters through the unified result schema."""
    seb = _device_sebulba(device_env=[
        ScenarioMix("lives1", 1.0, lambda: Pong(max_lives=1)),
        ScenarioMix("lives3", 1.0, Pong),
    ])
    res = seb.fit(jax.random.key(0), total_frames=800)
    assert not (set(api.RESULT_KEYS) - set(res))
    assert res["frames"] >= 800 and res["updates"] >= 1
    assert set(res["scenarios"]) == {"lives1", "lives3"}
    for name, counters in res["scenarios"].items():
        assert counters["rows"] == 2
        assert counters["episodes"] > 0
    assert res["scenarios"]["lives1"]["episodes"] > (
        res["scenarios"]["lives3"]["episodes"]
    ), "1-life episodes end ~3x as often"
    assert np.isfinite(res["mean_return"])


def test_sebulba_device_loop_uses_fused_step():
    """Actor threads drive the fused device step (env+act in one program);
    the host path's per-step action sync never runs."""
    calls = []
    seb = _device_sebulba()
    real_step = seb._device_act_step

    def spying_step(*args):
        calls.append(threading.current_thread().name)
        return real_step(*args)

    seb._device_act_step = spying_step
    res = seb.fit(jax.random.key(0), total_frames=200)
    assert res["frames"] >= 200
    assert calls and all(name.startswith("actor-") for name in calls)


def test_sebulba_requires_some_environment():
    with pytest.raises(ValueError, match="needs an environment"):
        Sebulba(network=BatchedMLPActorCritic(num_actions=3),
                optimizer=optim.sgd(1e-3))


def test_scenario_replay_strata_validation():
    mix = [
        ScenarioMix("a", 1.0, lambda: Pong(max_lives=1)),
        ScenarioMix("b", 1.0, Pong),
    ]
    cfg = SebulbaConfig(
        num_actor_cores=1, threads_per_actor_core=1, actor_batch_size=4,
        trajectory_length=4,
        replay=ReplayConfig(capacity=10, sample_batch_size=4, min_size=4),
    )
    net = BatchedMLPActorCritic(num_actions=3, hidden=(16,))
    with pytest.raises(ValueError, match="scenario-pure"):
        Sebulba(network=net, optimizer=optim.sgd(1e-3), config=cfg,
                device_env=mix)
    cfg = SebulbaConfig(
        num_actor_cores=1, threads_per_actor_core=1, actor_batch_size=4,
        trajectory_length=4,
        replay=ReplayConfig(capacity=12, sample_batch_size=4, min_size=4),
    )
    seb = Sebulba(network=net, optimizer=optim.sgd(1e-3), config=cfg,
                  device_env=mix)
    # 12 slots cycle the 4-row layout 3 times: 2 rows each x 3
    assert seb.replay_strata == {"a": 6, "b": 6}


_MULTI_CORE_SCRIPT = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=3"
import sys; sys.path.insert(0, {src!r})
import jax
from repro import optim
from repro.agents.actor_critic import BatchedMLPActorCritic
from repro.api import ScenarioMix
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.envs import Pong

seb = Sebulba(
    network=BatchedMLPActorCritic(num_actions=3, hidden=(16,)),
    optimizer=optim.sgd(1e-3),
    config=SebulbaConfig(num_actor_cores=2, threads_per_actor_core=1,
                         actor_batch_size=4, trajectory_length=4),
    device_env=[ScenarioMix("a", 1.0, lambda: Pong(max_lives=1)),
                ScenarioMix("b", 1.0, Pong)],
)
assert len(seb.split.actor_devices) == 2
res = seb.fit(jax.random.key(0), total_frames=600)
assert set(res["scenarios"]) == {{"a", "b"}}, res["scenarios"]
# both actor cores contribute: 2 threads x 2 rows per scenario
assert res["scenarios"]["a"]["episodes"] > 0
assert res["scenarios"]["a"]["rows"] == 2
print("MULTI_CORE_OK", res["scenarios"]["a"]["episodes"])
"""


@pytest.mark.slow
def test_device_fleet_multi_actor_core_subprocess():
    """Per-thread FleetStats live on each actor core's own device; the
    snapshot aggregation must sum them across devices (3-device subprocess:
    2 actor cores + 1 learner)."""
    import os
    import subprocess
    import sys

    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _MULTI_CORE_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=420, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-3000:]
    assert "MULTI_CORE_OK" in proc.stdout


# ------------------------------------------------------- Anakin (fleet)


def _anakin_fleet(mode):
    fleet = DeviceEnvFleet(
        [ScenarioMix("easy", 2.0, lambda: Pong(max_lives=1)),
         ScenarioMix("hard", 1.0, Pong)],
        8,
    )
    cfg = AnakinConfig(unroll_length=4, batch_per_device=8,
                       iterations_per_call=2, mode=mode)
    return Anakin(fleet, MLPActorCritic(num_actions=3, hidden=(16,)),
                  optim.sgd(1e-3), cfg)


def test_anakin_fleet_modes_agree():
    results = {}
    for mode in ("shard_map", "jit"):
        res = _anakin_fleet(mode).fit(jax.random.key(0), total_frames=200)
        assert set(res["scenarios"]) == {"easy", "hard"}
        assert res["scenarios"]["easy"]["rows"] == 5
        results[mode] = res
    for name in ("easy", "hard"):
        a = results["shard_map"]["scenarios"][name]
        b = results["jit"]["scenarios"][name]
        assert a["reward_per_step"] == pytest.approx(
            b["reward_per_step"], abs=1e-5
        )
        assert a["episodes_per_step"] == pytest.approx(
            b["episodes_per_step"], abs=1e-5
        )


def test_anakin_fleet_batch_must_match():
    fleet = DeviceEnvFleet(Pong, 4)
    cfg = AnakinConfig(batch_per_device=8)
    with pytest.raises(ValueError, match="global batch"):
        Anakin(fleet, MLPActorCritic(num_actions=3), optim.sgd(1e-3), cfg)
