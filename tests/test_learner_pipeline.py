"""Learner-pipeline tests (ISSUE 3): the donated, compile-cached update is
pinned bit-exact against the un-donated pre-cache path, compiles exactly
once per trajectory shape, accumulates metrics on device, and the
overlap-aware versioned publish never skips forever, never goes backwards,
and never hands an actor a torn or donated-away slot."""

import queue
import threading

import jax
import jax.numpy as jnp
import numpy as np

from repro import optim
from repro.agents import BatchedMLPActorCritic
from repro.core.sebulba import Sebulba, SebulbaConfig
from repro.data.trajectory import Trajectory
from repro.envs import BatchedHostEnv, HostBandit


def _make_seb(batch=6, traj_len=3, **cfg):
    return Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3),
        config=SebulbaConfig(
            num_actor_cores=1, actor_batch_size=batch,
            trajectory_length=traj_len, **cfg,
        ),
    )


def _make_traj(seb, batch, traj_len, seed):
    from jax.sharding import NamedSharding, PartitionSpec as P

    rng = np.random.RandomState(seed)
    sharding = NamedSharding(seb.learner_mesh, P("batch"))
    traj = Trajectory(
        obs=rng.rand(batch, traj_len, 4).astype(np.float32),
        actions=rng.randint(0, 4, (batch, traj_len)).astype(np.int32),
        rewards=rng.rand(batch, traj_len).astype(np.float32),
        discounts=np.full((batch, traj_len), 0.99, np.float32),
        behaviour_logp=np.log(
            rng.uniform(0.2, 0.9, (batch, traj_len))
        ).astype(np.float32),
        bootstrap_obs=rng.rand(batch, 4).astype(np.float32),
    )
    return jax.tree.map(lambda x: jax.device_put(x, sharding), traj)


def _copy(tree):
    return jax.tree.map(jnp.copy, tree)


# ------------------------------------------------- donated update semantics


def test_donated_cached_update_bit_exact_vs_precache_path():
    """The ISSUE 3 pin: N updates through the donated, compile-cached,
    accumulator-carrying path must reproduce the pre-cache reference (the
    same shard_map'd core jitted with NO donation) bit-for-bit — params,
    opt_state, and the metric means."""
    B, T, N = 6, 3, 4
    seb = _make_seb(B, T)
    params0, opt0 = seb.init(jax.random.key(0), (4,))
    trajs = [_make_traj(seb, B, T, 10 + i) for i in range(N)]

    # reference: the pre-PR program — identical math, no donation, fresh
    # metrics returned per update, averaged on host
    reference = jax.jit(seb._build_update(trajs[0]))
    p_ref, o_ref = params0, opt0
    ms = []
    for traj in trajs:
        p_ref, o_ref, m = reference(p_ref, o_ref, traj)
        ms.append(m)
    ref_means = {k: float(np.mean([float(m[k]) for m in ms])) for k in ms[0]}

    update, core = seb._get_update(trajs[0])
    macc = seb._fresh_macc(jax.eval_shape(core, params0, opt0, trajs[0])[2])
    p, o = _copy(params0), _copy(opt0)
    for traj in trajs:
        p, o, macc = update(p, o, _copy(traj), macc)

    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(jax.tree.leaves(o_ref), jax.tree.leaves(o)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    drained = seb._drain_macc(macc)
    assert set(drained) == set(ref_means)
    for k in ref_means:
        np.testing.assert_allclose(drained[k], ref_means[k], rtol=1e-6)


def test_donated_update_runs_in_place():
    """Donation must consume params/opt_state and reuse their storage (the
    learner state stops double-buffering)."""
    B, T = 6, 3
    seb = _make_seb(B, T)
    params0, opt0 = seb.init(jax.random.key(0), (4,))
    traj = _make_traj(seb, B, T, 0)
    update, core = seb._get_update(traj)
    macc = seb._fresh_macc(jax.eval_shape(core, params0, opt0, traj)[2])

    p, o = _copy(params0), _copy(opt0)
    in_ptrs = [l.unsafe_buffer_pointer() for l in jax.tree.leaves((p, o))]
    old_leaf = jax.tree.leaves(p)[0]
    p2, o2, _ = update(p, o, traj, macc)
    assert old_leaf.is_deleted(), "donated params must be consumed"
    out_ptrs = [l.unsafe_buffer_pointer() for l in jax.tree.leaves((p2, o2))]
    assert in_ptrs == out_ptrs, "donation must reuse the state storage"


def test_one_compile_per_trajectory_shape():
    """The compile-count probe: N same-shape updates -> exactly one trace;
    a second trajectory shape -> exactly one more."""
    B, T = 6, 3
    seb = _make_seb(B, T)
    params0, opt0 = seb.init(jax.random.key(0), (4,))
    traj = _make_traj(seb, B, T, 0)
    update, core = seb._get_update(traj)
    macc = seb._fresh_macc(jax.eval_shape(core, params0, opt0, traj)[2])
    assert seb.update_traces == 0
    p, o = _copy(params0), _copy(opt0)
    for i in range(4):
        p, o, macc = update(p, o, _make_traj(seb, B, T, i), macc)
        update2, _ = seb._get_update(_make_traj(seb, B, T, 99))
        assert update2 is update, "same shape must hit the update cache"
    assert seb.update_traces == 1, seb.update_traces

    # a new trajectory shape (different T) builds+compiles exactly once more
    traj_t2 = _make_traj(seb, B, T + 1, 0)
    update_b, _ = seb._get_update(traj_t2)
    assert update_b is not update
    p2, o2 = _copy(p), _copy(o)
    p2, o2, _ = update_b(p2, o2, traj_t2, seb._fresh_macc())
    assert seb.update_traces == 2, seb.update_traces


def test_metrics_accumulator_drains_means_and_resets():
    seb = _make_seb()
    params0, opt0 = seb.init(jax.random.key(0), (4,))
    traj = _make_traj(seb, 6, 3, 0)
    update, core = seb._get_update(traj)
    macc = seb._fresh_macc(jax.eval_shape(core, params0, opt0, traj)[2])
    assert seb._drain_macc(macc) is None  # empty accumulator -> no metrics
    p, o = _copy(params0), _copy(opt0)
    p, o, macc = update(p, o, traj, macc)
    m = seb._drain_macc(macc)
    assert m is not None and np.isfinite(m["loss"])
    assert seb._drain_macc(seb._fresh_macc()) is None  # reset drains empty


# ------------------------------------------------ overlap-aware publishing


def test_publish_skips_unconsumed_slot_and_stays_monotone():
    """A slow actor core: publishes while its slot is unconsumed must be
    skipped (no transfer, slot untouched); once the actor stamps
    consumption the next publish lands with a strictly higher version."""
    seb = _make_seb()
    params0, _ = seb.init(jax.random.key(0), (4,))  # forced initial publish
    assert seb.publishes_sent == 1 and seb.publishes_skipped == 0
    v0, slot0 = seb._param_slots[0]

    observed = [v0]
    for _ in range(4):  # learner outpaces the actor: all skipped
        seb._publish_params(params0)
        version, slot = seb._param_slots[0]
        observed.append(version)
        assert slot is slot0, "skipped publish must leave the slot standing"
    assert seb.publishes_sent == 1 and seb.publishes_skipped == 4
    assert seb._params_version == 5  # versions advance even when skipped

    seb._slot_consumed[0] = seb._param_slots[0][0]  # actor picks the slot up
    seb._publish_params(params0)
    version, slot = seb._param_slots[0]
    observed.append(version)
    assert slot is not slot0 and version == 6
    assert seb.publishes_sent == 2
    assert observed == sorted(observed), "actor-visible versions must be monotone"


def test_publish_throttle_off_publishes_every_update():
    seb = _make_seb(publish_throttle=False)
    params0, _ = seb.init(jax.random.key(0), (4,))
    for _ in range(5):
        seb._publish_params(params0)  # nobody consumes; all sent anyway
    assert seb.publishes_sent == 6 and seb.publishes_skipped == 0


def test_publish_slot_survives_donated_update_on_shared_device():
    """Degenerate single-device topology: the published slot must own its
    storage, so the donated learner update consuming params cannot
    invalidate what actor threads are reading (device_put to the same
    device aliases — the publish must copy)."""
    seb = _make_seb()
    assert seb._shared_devices, "CPU test topology shares the device"
    params0, opt0 = seb.init(jax.random.key(0), (4,))
    _version, slot_params = seb._param_slots[0]
    slot_before = np.asarray(jax.tree.leaves(slot_params)[0]).copy()

    traj = _make_traj(seb, 6, 3, 0)
    update, core = seb._get_update(traj)
    macc = seb._fresh_macc(jax.eval_shape(core, params0, opt0, traj)[2])
    update(params0, opt0, traj, macc)  # donates params0/opt0

    leaf = jax.tree.leaves(slot_params)[0]
    assert not leaf.is_deleted(), "slot must not alias donated learner state"
    np.testing.assert_array_equal(np.asarray(leaf), slot_before)


# ------------------------------------------- actor-side queue put (retry)


def _handle(seb, slot=0):
    """A bare ActorHandle for exercising ``_queue_put`` outside ``run``
    (matches what the supervisor would hand an actor incarnation)."""
    from repro.core.supervision import ActorHandle

    return ActorHandle(slot=slot, incarnation=0, core_id=0, seed=slot + 1)


def test_queue_put_retries_on_full_and_counts_blocked():
    """Satellite: a full queue must block-and-retry (counting the blocked
    intervals on the incarnation's handle), not silently drop the
    trajectory."""
    seb = _make_seb(queue_capacity=1)
    seb._queue.put("occupying")  # fill the queue
    handle = _handle(seb)
    done = threading.Event()
    result = {}

    def put():
        result["ok"] = seb._queue_put("shards", handle)
        done.set()

    t = threading.Thread(target=put, daemon=True)
    t.start()
    assert not done.wait(timeout=1.2), "put must still be retrying"
    assert handle.put_blocked >= 1
    assert seb._queue.get() == "occupying"  # learner frees a slot
    assert done.wait(timeout=5.0)
    # puts are tagged with the membership epoch at put time (multi-host
    # elasticity: the learner drops trajectories that straddle a reshard)
    assert result["ok"] and seb._queue.get() == (seb._epoch, "shards")
    assert handle.traj_dropped == 0
    assert handle.first_put_at is not None  # recovery-latency stamp landed


def test_queue_put_drops_only_on_stop():
    seb = _make_seb(queue_capacity=1)
    seb._queue.put("occupying")
    seb._stop.set()
    handle = _handle(seb)
    assert seb._queue_put("shards", handle) is False
    assert handle.traj_dropped == 1


def test_queue_put_unblocks_on_watchdog_cancel():
    """Satellite (graceful shutdown): every put retry must re-check not
    just the global stop event but this incarnation's cancel flag — a
    watchdog-abandoned actor must never spin in the retry loop."""
    seb = _make_seb(queue_capacity=1)
    seb._queue.put("occupying")
    handle = _handle(seb)
    done = threading.Event()
    result = {}

    def put():
        result["ok"] = seb._queue_put("shards", handle)
        done.set()

    t = threading.Thread(target=put, daemon=True)
    t.start()
    assert not done.wait(timeout=0.8), "put must still be retrying"
    handle.cancel.set()  # watchdog abandons the incarnation
    assert done.wait(timeout=5.0), "cancel must break the retry loop"
    assert result["ok"] is False and handle.traj_dropped == 1


def test_run_reports_publish_and_queue_counters():
    seb = _make_seb(batch=4, traj_len=2, threads_per_actor_core=2)
    out = seb.run(jax.random.key(0), (4,), total_frames=200)
    assert out["updates"] > 0
    assert out["param_version"] == out["updates"] + 1
    assert out["publishes_sent"] + out["publishes_skipped"] == (
        out["param_version"]
    )
    for key in ("put_blocked", "traj_dropped"):
        assert key in out and out[key] >= 0
    assert np.isfinite(out["metrics"]["loss"])
