"""Replay subsystem tests: ring semantics, prioritized sampling statistics,
jit shape/dtype invariants, bit-exact sampling determinism (the
test_causality.py pattern applied to replay), sharded-mesh behaviour, and
an end-to-end off-policy Sebulba smoke run on the CPU mesh."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import ReplayConfig
from repro.data.trajectory import Trajectory
from repro.replay import ReplayBuffer, buffer


def make_traj(B=4, T=3, obs_dim=5, val=0.0, seed=None):
    if seed is not None:
        rng = np.random.RandomState(seed)
        rewards = jnp.asarray(rng.randn(B, T), jnp.float32)
    else:
        rewards = jnp.full((B, T), val, jnp.float32)
    return Trajectory(
        obs=jnp.full((B, T, obs_dim), val, jnp.float32),
        actions=jnp.zeros((B, T), jnp.int32),
        rewards=rewards,
        discounts=jnp.ones((B, T), jnp.float32),
        behaviour_logp=jnp.zeros((B, T), jnp.float32),
        bootstrap_obs=jnp.full((B, obs_dim), val, jnp.float32),
    )


# ------------------------------------------------------------------ ring


def test_ring_wraparound_overwrites_oldest():
    buf = ReplayBuffer(capacity=8)
    state = buf.init(make_traj(B=3))
    # 5 inserts x 3 items = 15 > capacity 8: the ring wraps (twice at slot 0)
    for i in range(5):
        state = buf.insert(state, make_traj(B=3, val=float(i)))
    assert buf.size(state) == 8
    assert int(state.total_added) == 15
    assert int(state.insert_pos) == 15 % 8
    # slot contents: writes land at (3i + j) % 8 for batch j of insert i,
    # so each slot holds the val of the LAST insert that touched it
    expect = np.zeros(8)
    for i in range(5):
        for j in range(3):
            expect[(3 * i + j) % 8] = float(i)
    np.testing.assert_allclose(np.asarray(state.storage.obs[:, 0, 0]), expect)


def test_empty_and_partial_fill_sampling_only_hits_valid_slots():
    buf = ReplayBuffer(capacity=16)
    state = buf.init(make_traj(B=4))
    state = buf.insert(state, make_traj(B=4, val=7.0))
    assert buf.size(state) == 4
    _, idx, _ = buf.sample(state, jax.random.key(0), 64)
    assert int(jnp.max(idx)) < 4  # never samples an empty slot


# ------------------------------------------------------- prioritized stats


def test_prioritized_sampling_distribution_chi_squared():
    """Empirical draw counts must match p_i^alpha proportions.

    With alpha=1 and priorities 1..16 the expected probabilities are
    i/sum(1..16); a chi-squared statistic over 8000 draws should sit far
    below the df=15 critical value (~37.7 at p=0.001) unless the sampler is
    biased.  Fixed key -> the statistic is deterministic, not flaky.
    """
    buf = ReplayBuffer(capacity=16, prioritized=True, priority_exponent=1.0)
    state = buf.init(make_traj(B=16))
    state = buf.insert(state, make_traj(B=16))
    prios = jnp.arange(1.0, 17.0)
    state = buf.update_priorities(state, jnp.arange(16), prios)

    n = 8000
    _, idx, probs = buf.sample(state, jax.random.key(42), n)
    counts = np.bincount(np.asarray(idx), minlength=16)
    expect = np.asarray(prios / prios.sum()) * n
    chi2 = float(((counts - expect) ** 2 / expect).sum())
    assert chi2 < 37.7, f"chi2={chi2:.1f}, counts={counts}"
    # reported selection probabilities match the analytic distribution
    np.testing.assert_allclose(
        np.asarray(probs),
        np.asarray((prios / prios.sum())[idx]),
        rtol=1e-5,
    )


def test_uniform_sampling_distribution_chi_squared():
    buf = ReplayBuffer(capacity=16)
    state = buf.init(make_traj(B=16))
    state = buf.insert(state, make_traj(B=16))
    n = 8000
    _, idx, probs = buf.sample(state, jax.random.key(3), n)
    counts = np.bincount(np.asarray(idx), minlength=16)
    chi2 = float(((counts - n / 16) ** 2 / (n / 16)).sum())
    assert chi2 < 37.7, f"chi2={chi2:.1f}"
    np.testing.assert_allclose(np.asarray(probs), 1 / 16, rtol=1e-5)


def test_new_items_enter_at_max_priority():
    buf = ReplayBuffer(capacity=8, prioritized=True)
    state = buf.init(make_traj(B=2))
    state = buf.insert(state, make_traj(B=2))
    state = buf.update_priorities(state, jnp.array([0, 1]), jnp.array([9.0, 2.0]))
    state = buf.insert(state, make_traj(B=2, val=1.0))
    np.testing.assert_allclose(np.asarray(state.priorities[2:4]), [9.0, 9.0])


# ------------------------------------------------- jit + dtype invariants


def test_insert_sample_shapes_dtypes_under_jit():
    """The ReplayBuffer entry points are jitted (with donation); sampled
    leaves must preserve the stored shapes and dtypes exactly."""
    buf = ReplayBuffer(capacity=32, prioritized=True)
    traj = make_traj(B=8, T=4, obs_dim=6)
    state = buf.init(traj)
    state = buf.insert(state, traj)
    batch, idx, probs = buf.sample(state, jax.random.key(1), 5)
    assert batch.obs.shape == (5, 4, 6) and batch.obs.dtype == jnp.float32
    assert batch.actions.shape == (5, 4) and batch.actions.dtype == jnp.int32
    assert batch.rewards.shape == (5, 4)
    assert batch.bootstrap_obs.shape == (5, 6)
    assert idx.shape == (5,) and jnp.issubdtype(idx.dtype, jnp.integer)
    assert probs.shape == (5,) and probs.dtype == jnp.float32
    # state invariants survive the donated round-trip
    assert state.priorities.dtype == jnp.float32
    assert state.insert_pos.dtype == jnp.int32
    assert state.total_added.dtype == jnp.int32


def test_pure_functions_compose_inside_jit():
    """insert/sample/update_priorities are pure pytree->pytree functions, so
    arbitrary compositions must trace into a single jit."""

    @jax.jit
    def roundtrip(state, traj, key):
        state = buffer.insert(state, traj)
        batch, idx, probs = buffer.sample(state, key, 4, prioritized=True)
        return buffer.update_priorities(state, idx, probs + 1.0), batch

    traj = make_traj(B=4)
    state = buffer.init(traj, 16)
    state, batch = roundtrip(state, traj, jax.random.key(0))
    assert batch.obs.shape == (4, 3, 5)
    assert int(state.total_added) == 4


# ----------------------------------------------------------- determinism


def test_sample_bit_exact_determinism_under_fixed_keys():
    """Same (state, key) -> bit-identical indices, probs, and payloads;
    different keys -> different draws (degeneracy check, mirroring
    test_causality.py's suffix assertion)."""
    buf = ReplayBuffer(capacity=64, prioritized=True)
    state = buf.init(make_traj(B=16))
    for i in range(4):
        state = buf.insert(state, make_traj(B=16, seed=100 + i))

    key = jax.random.key(1234)
    b1, i1, p1 = buf.sample(state, key, 32)
    b2, i2, p2 = buf.sample(state, key, 32)
    assert np.array_equal(np.asarray(i1), np.asarray(i2))
    assert np.array_equal(np.asarray(p1), np.asarray(p2))
    for a, b in zip(jax.tree.leaves(b1), jax.tree.leaves(b2)):
        assert np.array_equal(np.asarray(a), np.asarray(b))

    _, i3, _ = buf.sample(state, jax.random.key(4321), 32)
    assert not np.array_equal(np.asarray(i1), np.asarray(i3))


def test_insert_then_sample_deterministic_across_reconstruction():
    """Rebuilding the buffer from scratch replays to an identical state:
    storage, priorities, and subsequent draws are bit-exact."""

    def build():
        buf = ReplayBuffer(capacity=16, prioritized=True)
        state = buf.init(make_traj(B=4))
        for i in range(3):
            state = buf.insert(state, make_traj(B=4, seed=i))
        return buf, state

    buf_a, state_a = build()
    buf_b, state_b = build()
    for a, b in zip(jax.tree.leaves(state_a), jax.tree.leaves(state_b)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    _, ia, _ = buf_a.sample(state_a, jax.random.key(9), 8)
    _, ib, _ = buf_b.sample(state_b, jax.random.key(9), 8)
    assert np.array_equal(np.asarray(ia), np.asarray(ib))


# -------------------------------------------------------------- sharded


def test_sharded_replay_single_device_mesh():
    """The degenerate 1-device learner mesh (CPU default) must behave like
    the plain buffer: local == global."""
    from jax.sharding import Mesh

    from repro.replay import ShardedReplay

    mesh = Mesh(np.array(jax.devices()[:1]), ("batch",))
    rep = ShardedReplay(mesh, 16, prioritized=True)
    state = rep.init(make_traj(B=4))
    for i in range(3):
        state = rep.insert(state, make_traj(B=4, val=float(i)))
    assert rep.size(state) == 12
    batch, idx, probs = rep.sample(state, jax.random.key(0), 8)
    assert batch.obs.shape == (8, 3, 5)
    b2, i2, _ = rep.sample(state, jax.random.key(0), 8)
    assert np.array_equal(np.asarray(idx), np.asarray(i2))
    state = rep.update_priorities(state, idx, probs + 0.5)


def test_replay_config_validation():
    with pytest.raises(ValueError):
        ReplayConfig(capacity=8, sample_batch_size=16)
    with pytest.raises(ValueError):
        ReplayConfig(capacity=8, sample_batch_size=4, min_size=99)
    with pytest.raises(ValueError):
        ReplayConfig(importance_anneal_updates=-1)
    with pytest.raises(ValueError):
        ReplayConfig(importance_exponent=1.5)


# ----------------------------------------------------- PER beta annealing


def test_importance_beta_linear_anneal():
    cfg = ReplayConfig(
        importance_exponent=0.4, importance_anneal_updates=100
    )
    assert float(cfg.importance_beta(0)) == pytest.approx(0.4)
    assert float(cfg.importance_beta(50)) == pytest.approx(0.7)
    assert float(cfg.importance_beta(100)) == pytest.approx(1.0)
    assert float(cfg.importance_beta(10_000)) == pytest.approx(1.0)  # clamps


def test_importance_beta_disabled_is_constant_float():
    cfg = ReplayConfig(importance_exponent=0.4)
    # no anneal -> a plain python float (no device constant in the trace)
    assert cfg.importance_beta(0) == 0.4
    assert cfg.importance_beta(10**6) == 0.4


def test_importance_beta_traced_through_weights():
    """The schedule must compose into the fused jit: traced update index ->
    traced beta -> the exact (N * P)^-beta / max weights."""
    from repro.rl import losses

    cfg = ReplayConfig(
        importance_exponent=0.5, importance_anneal_updates=10
    )
    probs = jnp.asarray([0.1, 0.2, 0.4], jnp.float32)

    @jax.jit
    def weights_at(update_idx):
        return losses.per_importance_weights(
            probs, jnp.int32(8), cfg.importance_beta(update_idx)
        )

    for idx, beta in [(0, 0.5), (5, 0.75), (10, 1.0), (99, 1.0)]:
        w = np.asarray(8.0 * probs) ** -beta
        np.testing.assert_allclose(
            np.asarray(weights_at(idx)), w / w.max(), rtol=1e-6
        )


def test_offpolicy_sebulba_with_annealed_beta_smoke():
    """The anneal threads through the fused off-policy step (traced
    update index) without retracing or NaNs."""
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=BatchedMLPActorCritic(4, hidden=(16,)),
        optimizer=optim.adam(1e-3, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=8, trajectory_length=5,
            replay=ReplayConfig(
                capacity=64, sample_batch_size=8, min_size=8,
                importance_anneal_updates=3,
            ),
        ),
    )
    out = seb.run(jax.random.key(0), (4,), total_frames=600)
    assert out["updates"] >= 2, out
    assert np.isfinite(out["metrics"]["loss"])


# ------------------------------------------------- end-to-end off-policy


def test_offpolicy_sebulba_smoke_cpu_mesh():
    """Off-policy Sebulba on the CPU mesh + HostBandit: fills the replay
    ring, then completes >= 2 learner updates sampling mixed online/replay
    batches (acceptance criterion)."""
    from repro import optim
    from repro.agents import BatchedMLPActorCritic, ReplayImpalaAgent
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    net = BatchedMLPActorCritic(4, hidden=(32,))
    seb = Sebulba(
        env_factory=lambda seed: HostBandit(seed=seed),
        make_batched_env=lambda f, n: BatchedHostEnv(f, n),
        network=net,
        optimizer=optim.adam(1e-3, clip_norm=1.0),
        config=SebulbaConfig(
            num_actor_cores=1, threads_per_actor_core=1,
            actor_batch_size=8, trajectory_length=5,
            replay=ReplayConfig(capacity=64, sample_batch_size=8, min_size=8),
        ),
    )
    assert isinstance(seb.agent, ReplayImpalaAgent)  # auto-selected
    out = seb.run(jax.random.key(0), (4,), total_frames=600)
    assert out["updates"] >= 2, out
    assert out["replay_size"] >= 8
    assert np.isfinite(out["metrics"]["loss"])
    # every update republishes through the versioned slot (+1 from init)
    assert out["param_version"] == out["updates"] + 1


def test_offpolicy_rejects_bad_configs():
    from repro import optim
    from repro.agents import BatchedMLPActorCritic
    from repro.core.sebulba import Sebulba, SebulbaConfig
    from repro.envs import BatchedHostEnv, HostBandit

    with pytest.raises(ValueError, match="microbatches"):
        Sebulba(
            env_factory=lambda seed: HostBandit(seed=seed),
            make_batched_env=lambda f, n: BatchedHostEnv(f, n),
            network=BatchedMLPActorCritic(4, hidden=(16,)),
            optimizer=optim.adam(1e-3),
            config=SebulbaConfig(
                actor_batch_size=8, learner_microbatches=2,
                replay=ReplayConfig(
                    capacity=64, sample_batch_size=8, min_size=8
                ),
            ),
        )


def test_integer_token_trajectory_round_trips_bit_exact():
    """ISSUE 9 satellite: LM trajectories — int32 token obs/bootstrap plus
    a KV-cache init_carry — insert and sample through the replay ring with
    dtypes intact and token values bit-exact (a silent float cast would
    corrupt token ids the learner re-embeds)."""
    B, T = 4, 3
    rng = np.random.RandomState(3)
    tokens = rng.randint(0, 50_000, (B, T)).astype(np.int32)
    boot = rng.randint(0, 50_000, (B,)).astype(np.int32)
    traj = Trajectory(
        obs=jnp.asarray(tokens),
        actions=jnp.asarray(tokens),
        rewards=jnp.zeros((B, T), jnp.float32),
        discounts=jnp.ones((B, T), jnp.float32),
        behaviour_logp=jnp.zeros((B, T), jnp.float32),
        bootstrap_obs=jnp.asarray(boot),
        init_carry={
            "cache": jnp.full((B, 4, 2, 2), 2.0, jnp.bfloat16),
            "pos": jnp.zeros((B,), jnp.int32),
        },
    )
    buf = ReplayBuffer(capacity=16)
    state = buf.init(traj)
    assert state.storage.obs.dtype == jnp.int32
    assert state.storage.init_carry["cache"].dtype == jnp.bfloat16
    state = buf.insert(state, traj)
    batch, idx, _ = buf.sample(state, jax.random.key(0), 6)
    assert batch.obs.dtype == jnp.int32
    assert batch.bootstrap_obs.dtype == jnp.int32
    assert batch.init_carry["pos"].dtype == jnp.int32
    assert batch.init_carry["cache"].dtype == jnp.bfloat16
    sel = np.asarray(idx)
    np.testing.assert_array_equal(np.asarray(batch.obs), tokens[sel])
    np.testing.assert_array_equal(np.asarray(batch.bootstrap_obs), boot[sel])
    np.testing.assert_array_equal(
        np.asarray(batch.init_carry["cache"].astype(jnp.float32)), 2.0
    )
