"""Hypothesis compatibility shim for the property-based test files.

The container image does not ship ``hypothesis``; importing it at module
scope used to error *collection* for the whole tier-1 run.  This shim
re-exports the real library when present and otherwise falls back to a
minimal, deterministic property runner: each ``@given`` test is executed
``max_examples`` times against draws from an explicitly-seeded
``random.Random`` stream, so the fallback tests are bit-reproducible from
run to run (no flaky shrinking, no example database).

Only the strategy surface the repo's tests use is implemented:
``integers``, ``floats``, ``lists``, ``sampled_from``, ``booleans``, and
``composite``.  Everything is a ``Strategy`` with a single ``example(rand)``
method, which keeps the semantics obvious and the failure messages small
(the failing draw index + values are attached to the assertion).
"""

from __future__ import annotations

import random

try:  # pragma: no cover - exercised only where hypothesis is installed
    from hypothesis import given, settings  # noqa: F401
    from hypothesis import strategies as st  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    _DEFAULT_MAX_EXAMPLES = 10
    _SEED = 0x9E3779B9  # fixed: every run draws the same example stream

    class Strategy:
        def __init__(self, sample_fn, label="strategy"):
            self._sample = sample_fn
            self.label = label

        def example(self, rand: random.Random):
            return self._sample(rand)

        def __repr__(self):
            return f"<{self.label}>"

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            return Strategy(
                lambda r: r.randint(min_value, max_value),
                f"integers({min_value}, {max_value})",
            )

        @staticmethod
        def floats(min_value, max_value):
            return Strategy(
                lambda r: r.uniform(min_value, max_value),
                f"floats({min_value}, {max_value})",
            )

        @staticmethod
        def booleans():
            return Strategy(lambda r: bool(r.getrandbits(1)), "booleans()")

        @staticmethod
        def sampled_from(elements):
            elements = list(elements)
            return Strategy(lambda r: r.choice(elements), "sampled_from")

        @staticmethod
        def lists(elements: Strategy, min_size=0, max_size=10):
            def sample(r):
                n = r.randint(min_size, max_size)
                return [elements.example(r) for _ in range(n)]

            return Strategy(sample, f"lists({elements.label})")

        @staticmethod
        def composite(fn):
            """``@st.composite`` wraps fn(draw, *args) into a strategy
            factory, exactly like the real API."""

            def make(*args, **kwargs):
                def sample(r):
                    return fn(lambda s: s.example(r), *args, **kwargs)

                return Strategy(sample, f"composite:{fn.__name__}")

            return make

    st = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        def deco(fn):
            fn._compat_max_examples = max_examples
            return fn

        return deco

    def given(*strategies):
        def deco(fn):
            # NB: no functools.wraps — copying __wrapped__ would make pytest
            # see the original argument names and demand fixtures for them.
            def runner():
                # @settings may sit above @given (decorating the runner) or
                # below it (decorating the test fn) — honor both orders
                n = getattr(
                    runner, "_compat_max_examples",
                    getattr(fn, "_compat_max_examples", _DEFAULT_MAX_EXAMPLES),
                )
                rand = random.Random(_SEED)
                for i in range(n):
                    drawn = tuple(s.example(rand) for s in strategies)
                    try:
                        fn(*drawn)
                    except Exception as e:  # noqa: BLE001
                        raise AssertionError(
                            f"property falsified on deterministic example "
                            f"{i}/{n}: {drawn!r}"
                        ) from e

            runner.__name__ = fn.__name__
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
