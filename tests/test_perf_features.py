"""Tests for the §Perf beyond-paper features: flash custom VJP, a2a MoE,
fp8 KV cache, fused CE."""

import dataclasses
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import pytest

from repro.configs.base import get_reduced_config
from repro.envs import Bandit
from repro.models import attention as attn
from repro.models import make_model


def test_flash_vjp_matches_autodiff_reference():
    ks = jax.random.split(jax.random.key(0), 4)
    B, T, H, K, h = 2, 64, 4, 2, 32
    q, k, v, do = (jax.random.normal(kk, (B, T, H if i != 1 and i != 2 else K, h))
                   for i, kk in enumerate(ks))
    k = jax.random.normal(ks[1], (B, T, K, h))
    v = jax.random.normal(ks[2], (B, T, K, h))
    do = jax.random.normal(ks[3], (B, T, H, h))

    def ref(q, k, v):
        qg = q.reshape(B, T, K, H // K, h).astype(jnp.float32) * (h**-0.5)
        logits = jnp.einsum("btkgh,bskh->bkgts", qg, k.astype(jnp.float32))
        m = jnp.arange(T)[:, None] >= jnp.arange(T)[None, :]
        logits = jnp.where(m[None, None, None], logits, -1e30)
        p = jax.nn.softmax(logits, -1)
        o = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
        return o.reshape(B, T, H, h)

    f = lambda q, k, v: jnp.vdot(do, attn.full_attention(q, k, v, chunk=16))
    r = lambda q, k, v: jnp.vdot(do, ref(q, k, v))
    g1 = jax.grad(f, argnums=(0, 1, 2))(q, k, v)
    g2 = jax.grad(r, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g1, g2):
        assert jnp.abs(a - b).max() < 1e-4


def test_fp8_kv_cache_decode():
    cfg = dataclasses.replace(
        get_reduced_config("qwen3_4b"), cache_dtype="float8_e4m3fn"
    )
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    B, S = 2, 32
    cache, _ = model.init_cache(B, S)
    assert jax.tree.leaves(cache)[0].dtype == jnp.float8_e4m3fn
    step = jax.jit(model.decode_step)
    logits, _, cache = step(params, cache, jnp.zeros((B, 1), jnp.int32),
                            jnp.int32(0))
    assert bool(jnp.isfinite(logits).all())

    # quantized decode stays close to the bf16-cache decode
    cfg16 = dataclasses.replace(cfg, cache_dtype="bfloat16")
    m16 = make_model(cfg16)
    cache16, _ = m16.init_cache(B, S)
    l16, _, _ = jax.jit(m16.decode_step)(
        params, cache16, jnp.zeros((B, 1), jnp.int32), jnp.int32(0)
    )
    # logits agree in ranking for the top token
    assert (jnp.argmax(logits[:, 0], -1) == jnp.argmax(l16[:, 0], -1)).all()


def test_bandit_env():
    env = Bandit(num_arms=3, noise=0.0)
    s = env.init(jax.random.key(0))
    step = jax.jit(env.step)
    s2, ts = step(s, s.best_arm)
    assert float(ts.reward) == 1.0
    assert float(ts.discount) == 0.0
    s3, ts = step(s2, (s2.best_arm + 1) % 3)
    assert float(ts.reward) == 0.0


_A2A_SCRIPT = textwrap.dedent(
    """
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
    import sys; sys.path.insert(0, {src!r})
    import jax, jax.numpy as jnp
    from repro.models import moe as moe_lib
    from repro.param import ParamBuilder

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    dims = moe_lib.MoEDims(32, 16, 4, 2, 1, 8.0)
    b = ParamBuilder(jax.random.key(0))
    moe_lib.init_moe(b, "moe", dims)
    params, _ = b.build()
    x = jax.random.normal(jax.random.key(1), (4, 16, 32))
    out_s, aux_s = moe_lib.moe_ffn(params["moe"], x, dims, impl="sort")
    out_a, aux_a = jax.jit(
        lambda p, x: moe_lib.moe_ffn(p, x, dims, impl="a2a", mesh=mesh)
    )(params["moe"], x)
    err = float(jnp.abs(out_a - out_s).max())
    assert err < 1e-4, err
    g = jax.grad(lambda p: jnp.sum(
        moe_lib.moe_ffn(p, x, dims, impl="a2a", mesh=mesh)[0] ** 2
    ))(params["moe"])
    assert float(jnp.abs(g["router"]).max()) > 0
    assert float(jnp.abs(g["w_down"]).max()) > 0
    print("A2A_OK", err)
    """
)


@pytest.mark.slow
def test_moe_a2a_matches_sort_on_mesh():
    src = os.path.join(os.path.dirname(__file__), "..", "src")
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    proc = subprocess.run(
        [sys.executable, "-c", _A2A_SCRIPT.format(src=src)],
        capture_output=True, text=True, timeout=300, env=env,
    )
    assert proc.returncode == 0, proc.stderr[-2000:]
    assert "A2A_OK" in proc.stdout


def test_rglru_custom_vjp_matches_autodiff():
    from repro.kernels.rglru_scan.ops import _assoc_scan_core
    from repro.kernels.rglru_scan.ref import rglru_scan_ref

    ks = jax.random.split(jax.random.key(5), 4)
    B, T, W = 2, 48, 24
    x = jax.random.normal(ks[0], (B, T, W))
    a = jax.nn.sigmoid(jax.random.normal(ks[1], (B, T, W)))
    gi = jax.nn.sigmoid(jax.random.normal(ks[2], (B, T, W)))
    dy = jax.random.normal(ks[3], (B, T, W))
    f = lambda x, a, gi: jnp.vdot(dy, _assoc_scan_core(x, a, gi))
    r = lambda x, a, gi: jnp.vdot(dy, rglru_scan_ref(x, a, gi)[0])
    g1 = jax.grad(f, argnums=(0, 1, 2))(x, a, gi)
    g2 = jax.grad(r, argnums=(0, 1, 2))(x, a, gi)
    for aa, bb in zip(g1, g2):
        assert jnp.abs(aa - bb).max() < 1e-5


def test_ssd_custom_vjp_matches_autodiff():
    from repro.kernels.ssd_scan.ops import _ssd_chunk_scan
    from repro.kernels.ssd_scan.ref import ssd_scan_ref

    ks = jax.random.split(jax.random.key(7), 6)
    B, T, H, P, N = 2, 64, 4, 16, 8
    x = jax.random.normal(ks[0], (B, T, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, T, H)))
    A = -jnp.exp(jax.random.normal(ks[2], (H,)) * 0.5)
    Bm = jax.random.normal(ks[3], (B, T, N)) * 0.3
    Cm = jax.random.normal(ks[4], (B, T, N)) * 0.3
    dy = jax.random.normal(ks[5], (B, T, H, P))
    f = lambda *a: jnp.vdot(dy, _ssd_chunk_scan(*a, 4)[0])
    r = lambda *a: jnp.vdot(dy, ssd_scan_ref(*a)[0])
    g1 = jax.grad(f, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    g2 = jax.grad(r, argnums=(0, 1, 2, 3, 4))(x, dt, A, Bm, Cm)
    for aa, bb in zip(g1, g2):
        assert jnp.abs(aa - bb).max() < 1e-3
