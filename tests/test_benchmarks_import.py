"""Every benchmarks/*.py module must import cleanly (fast tier).

The bench suites are invoked lazily (``benchmarks/run.py --suite ...``), so
a broken import — a renamed Sebulba internal, a moved helper — would
otherwise surface only when someone runs the benches.  Importing them all
here makes suite regressions fail test collection instead.
"""

import importlib
import pathlib

import pytest

_BENCH_DIR = pathlib.Path(__file__).resolve().parent.parent / "benchmarks"
_MODULES = sorted(
    p.stem for p in _BENCH_DIR.glob("*.py") if not p.stem.startswith("_")
) + ["_timing"]


@pytest.mark.parametrize("name", _MODULES)
def test_benchmark_module_imports(name):
    mod = importlib.import_module(f"benchmarks.{name}")
    assert hasattr(mod, "main") or name == "_timing", name


def test_run_registers_envs_suite():
    """``--suite envs`` stays wired to env_bench -> BENCH_envs.json."""
    import inspect

    from benchmarks import run

    assert '"envs": _envs_suite' in inspect.getsource(run.main)
    assert "BENCH_envs.json" in inspect.getsource(run._envs_suite)


def test_run_registers_fault_suite():
    """``--suite fault`` stays wired to fault_bench -> BENCH_fault.json
    (the ISSUE 7 supervision-degradation / recovery-latency suite)."""
    import inspect

    from benchmarks import run

    assert '"fault": _fault_suite' in inspect.getsource(run.main)
    assert "BENCH_fault.json" in inspect.getsource(run._fault_suite)


def test_run_registers_elastic_suite():
    """``--suite elastic`` stays wired to elastic_bench ->
    BENCH_elastic.json (the ISSUE 8 multi-host scale-out / host-kill
    recovery suite)."""
    import inspect

    from benchmarks import run

    assert '"elastic": _elastic_suite' in inspect.getsource(run.main)
    assert "BENCH_elastic.json" in inspect.getsource(run._elastic_suite)


def test_run_registers_lm_suite():
    """``--suite lm`` stays wired to lm_bench -> BENCH_lm.json (the ISSUE
    9 fused decode-carry vs full-forward re-scoring suite)."""
    import inspect

    from benchmarks import run

    assert '"lm": _lm_suite' in inspect.getsource(run.main)
    assert "BENCH_lm.json" in inspect.getsource(run._lm_suite)


def test_run_registers_serve_suite():
    """``--suite serve`` stays wired to serve_bench -> BENCH_serve.json
    (the ISSUE 10 continuous-vs-static batching suite)."""
    import inspect

    from benchmarks import run

    assert '"serve": _serve_suite' in inspect.getsource(run.main)
    assert "BENCH_serve.json" in inspect.getsource(run._serve_suite)
