"""The unified Podracer runner surface (`repro.api`).

Both Podracer architectures front the same training contract:

    runner.fit(rng, total_frames, *, log_every=0,
               checkpoint_dir=None, checkpoint_every=0,
               restore_from=None, auto_resume=False) -> result dict

and every training entry point — on-policy Sebulba, off-policy (replay)
Sebulba, Anakin — returns ONE documented result schema (``RESULT_KEYS``).
Counters a given architecture does not have (Anakin never publishes params
or queues trajectories) are reported as 0, never missing, so downstream
tooling reads one shape.

Result schema (``make_result`` fills the defaults and rejects unknown
keys):

    params             final parameters (device pytree)
    updates            learner/optimizer updates applied
    frames             env frames generated
    fps                frames / seconds
    seconds            wall-clock of the fit
    param_version      logical params version (Sebulba: publish version the
                       actors observe; Anakin: update count)
    publishes_sent     actor-core param transfers dispatched (Sebulba)
    publishes_skipped  overlap-aware publish skips (Sebulba)
    put_blocked        full-queue retry intervals on the actor side
    traj_dropped       trajectories dropped at shutdown
    replay_size        filled replay slots at exit (off-policy Sebulba)
    checkpoints_saved  checkpoints written by the runner
    actor_restarts     supervised actor incarnations respawned after a
                       crash or watchdog stall (Sebulba)
    actor_quarantined  actor slots retired after max_restarts failures
    watchdog_stalls    hung-actor detections (heartbeat older than
                       stall_timeout)
    checkpoint_fallbacks  damaged checkpoints skipped while restoring
                       (restore fell back to the newest VALID stamp)
    hosts_joined       hosts observed joining the membership after start
                       (multi-host elastic Sebulba; includes rejoins)
    hosts_lost         hosts whose lease expired or retired mid-run
    reshards           membership epoch bumps observed (each triggers the
                       deterministic replay reshard + forced republish)
    epoch              final membership epoch (0 when not multi-host)
    mean_return        mean episode return (NaN when untracked)
    metrics            drained learner metrics (means since last drain)
    scenarios          per-scenario counters when training on a device-env
                       scenario mix ({name: {weight, rows, episodes,
                       reward_sum, return_sum, mean_return, [replay_slots]}},
                       empty dict otherwise)

Checkpointing: the runner owns persistence so examples stop hand-rolling
it.  Every ``checkpoint_every`` updates (and once more at the end of a
fit) the runner writes a ``param_version``-stamped npz via
``repro.checkpoint``; ``restore_from`` accepts a checkpoint file or a
directory (the newest VALID stamp wins — damaged checkpoints are skipped
and counted as ``checkpoint_fallbacks``).  ``auto_resume=True`` makes
``fit`` scan ``checkpoint_dir`` itself, so a preempted run relaunches
from wherever it last persisted with no extra flags.  The save syncs
params to host, so it costs one device->host pull per boundary — like
metric drains, it never touches the steady-state donated update loop.
"""

from __future__ import annotations

import math
import os
import re
from typing import Any, Protocol, runtime_checkable

import jax
import numpy as np

from repro import checkpoint
from repro.checkpoint import CheckpointCorruptError

PyTree = Any

RESULT_KEYS = (
    "params",
    "updates",
    "frames",
    "fps",
    "seconds",
    "param_version",
    "publishes_sent",
    "publishes_skipped",
    "put_blocked",
    "traj_dropped",
    "replay_size",
    "checkpoints_saved",
    "actor_restarts",
    "actor_quarantined",
    "watchdog_stalls",
    "checkpoint_fallbacks",
    "hosts_joined",
    "hosts_lost",
    "reshards",
    "epoch",
    "mean_return",
    "metrics",
    "scenarios",
)

_COUNTER_DEFAULTS = {
    "param_version": 0,
    "publishes_sent": 0,
    "publishes_skipped": 0,
    "put_blocked": 0,
    "traj_dropped": 0,
    "replay_size": 0,
    "checkpoints_saved": 0,
    "actor_restarts": 0,
    "actor_quarantined": 0,
    "watchdog_stalls": 0,
    "checkpoint_fallbacks": 0,
    "hosts_joined": 0,
    "hosts_lost": 0,
    "reshards": 0,
    "epoch": 0,
}


@runtime_checkable
class Runner(Protocol):
    """Anything that trains an Agent to a frame budget — Sebulba, Anakin,
    and whatever the next Podracer is.  ``fit`` owns the whole loop:
    initialization (or ``restore_from``), training, periodic checkpoints,
    and the unified result dict."""

    def fit(
        self,
        rng: jax.Array,
        total_frames: int,
        *,
        log_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        restore_from: str | None = None,
        auto_resume: bool = False,
    ) -> dict: ...


def make_result(
    *,
    params: PyTree,
    updates: int,
    frames: int,
    seconds: float,
    metrics: dict,
    mean_return: float = float("nan"),
    scenarios: dict | None = None,
    **counters: int,
) -> dict:
    """Assemble the unified runner result.  Unset counters default to 0;
    a counter outside the schema is a programming error and raises."""
    unknown = set(counters) - set(_COUNTER_DEFAULTS)
    if unknown:
        raise TypeError(f"unknown result counters: {sorted(unknown)}")
    out = {
        "params": params,
        "updates": int(updates),
        "frames": int(frames),
        "fps": float(frames) / seconds if seconds > 0 else 0.0,
        "seconds": float(seconds),
        "mean_return": float(mean_return),
        "metrics": dict(metrics),
        "scenarios": dict(scenarios) if scenarios else {},
    }
    for key, default in _COUNTER_DEFAULTS.items():
        out[key] = int(counters.get(key, default))
    return out


# ------------------------------------------------------------- serve results

SERVE_RESULT_KEYS = (
    "outputs",
    "completed",
    "admitted",
    "preempted",
    "steps",
    "prefill_chunks",
    "tokens_prefilled",
    "tokens_decoded",
    "tokens_per_s",
    "seconds",
    "queue_depth_peak",
    "cache_occupancy_peak",
    "cache_occupancy_mean",
    "ttft_p50",
    "ttft_p95",
    "tpot_p50",
    "tpot_p95",
)

_SERVE_INT_DEFAULTS = {
    "completed": 0,
    "admitted": 0,
    "preempted": 0,
    "steps": 0,
    "prefill_chunks": 0,
    "tokens_prefilled": 0,
    "tokens_decoded": 0,
    "queue_depth_peak": 0,
}

_SERVE_FLOAT_DEFAULTS = {
    "cache_occupancy_peak": 0.0,
    "cache_occupancy_mean": 0.0,
    "ttft_p50": 0.0,
    "ttft_p95": 0.0,
    "tpot_p50": 0.0,
    "tpot_p95": 0.0,
}


def make_serve_result(
    *,
    outputs: dict,
    seconds: float,
    **counters,
) -> dict:
    """Assemble the unified ServeEngine result — the serving twin of
    ``make_result``: one documented schema (``SERVE_RESULT_KEYS``), unset
    counters default to 0 (absent-as-0, never missing), unknown counters
    raise.

        outputs               {request id: [generated token ids]}
        completed             requests finished
        admitted              queue -> row admissions (re-admissions after
                              a preemption count again)
        preempted             cache-pressure preemptions (recompute-on-
                              restart; outputs stay deterministic)
        steps                 engine iterations
        prefill_chunks        chunked-prefill dispatches
        tokens_prefilled      prompt tokens written through prefill
        tokens_decoded        decode-step tokens processed
        tokens_per_s          (tokens_prefilled + tokens_decoded) / seconds
        seconds               wall-clock of the run
        queue_depth_peak      max requests waiting in the queue
        cache_occupancy_peak  max fraction of KV pages (paged) or rows
                              (dense) in use
        cache_occupancy_mean  mean of the same, over steps
        ttft_p50 / ttft_p95   time-to-first-token percentiles (s)
        tpot_p50 / tpot_p95   time-per-output-token percentiles (s)
    """
    known = set(_SERVE_INT_DEFAULTS) | set(_SERVE_FLOAT_DEFAULTS)
    unknown = set(counters) - known
    if unknown:
        raise TypeError(f"unknown serve counters: {sorted(unknown)}")
    out = {"outputs": dict(outputs), "seconds": float(seconds)}
    for key, default in _SERVE_INT_DEFAULTS.items():
        out[key] = int(counters.get(key, default))
    for key, default in _SERVE_FLOAT_DEFAULTS.items():
        out[key] = float(counters.get(key, default))
    tokens = out["tokens_prefilled"] + out["tokens_decoded"]
    out["tokens_per_s"] = tokens / out["seconds"] if out["seconds"] > 0 else 0.0
    return out


# ------------------------------------------------------------ checkpoints

# \d+ (not \d{8}): the zero-padded stamp is min-width, so versions past
# 10^8 write 9+ digit names — they must stay visible to restore
_CKPT_RE = re.compile(r"^ckpt_(\d+)\.npz$")


def checkpoint_path(directory: str, param_version: int) -> str:
    return os.path.join(directory, f"ckpt_{param_version:08d}.npz")


def save_checkpoint(
    directory: str,
    params: PyTree,
    *,
    param_version: int,
    updates: int = 0,
    frames: int = 0,
    fault=None,
) -> str:
    """Write a ``param_version``-stamped checkpoint (atomic npz with an
    embedded checksum) and return its path.  The stamp names the file, so
    a directory of checkpoints sorts by version and ``latest_checkpoint``
    needs no sidecar index.  ``fault`` threads the deterministic
    checkpoint injector (repro.fault) into the writer."""
    path = checkpoint_path(directory, param_version)
    checkpoint.save(path, {"params": params, "meta": _meta(
        param_version=param_version, updates=updates, frames=frames
    )}, fault=fault)
    return path


def _meta(**values: int) -> dict:
    return {k: np.asarray(v, np.int64) for k, v in values.items()}


def checkpoint_stamps(directory: str) -> list[tuple[int, str]]:
    """Every ``ckpt_*.npz`` in ``directory`` as (version, path), newest
    first.  Compared numerically — lexical order breaks once stamps
    outgrow the 8-digit zero padding.  Non-checkpoint debris (e.g. the
    tmp files a killed write leaves behind) is ignored."""
    if not os.path.isdir(directory):
        return []
    stamps = []
    for name in os.listdir(directory):
        m = _CKPT_RE.match(name)
        if m:
            stamps.append((int(m.group(1)), os.path.join(directory, name)))
    return sorted(stamps, reverse=True)


def latest_checkpoint(directory: str) -> str | None:
    """Highest-``param_version`` checkpoint in ``directory`` (None if the
    directory is missing or holds no checkpoints)."""
    stamps = checkpoint_stamps(directory)
    return stamps[0][1] if stamps else None


def newest_valid_checkpoint(directory: str) -> str | None:
    """Path of the newest stamp that passes checksum verification, or
    None when no valid checkpoint exists.  The rejoining-host restore
    source (multi-host elasticity): a host re-entering the fleet resumes
    from here, skipping any stamp another host tore mid-preemption —
    same fallback order as ``restore_checkpoint`` on a directory, but
    read-only and without materializing params."""
    for _, path in checkpoint_stamps(directory):
        if checkpoint.verify(path):
            return path
    return None


def _restore_file(path: str, params_like: PyTree) -> tuple[PyTree, dict]:
    like = {
        "params": params_like,
        "meta": _meta(param_version=0, updates=0, frames=0),
    }
    tree = checkpoint.restore(path, like)
    meta = {k: int(v) for k, v in tree["meta"].items()}
    return tree["params"], meta


def restore_checkpoint(path: str, params_like: PyTree) -> tuple[PyTree, dict]:
    """Restore ``(params, meta)`` from a checkpoint file, or from the
    newest VALID checkpoint when ``path`` is a directory: damaged stamps
    (torn writes, corruption — ``CheckpointCorruptError``) are skipped
    newest-to-oldest and counted in ``meta["fallbacks"]``, so a
    checkpoint-write kill never strands a resumable run.  ``params_like``
    supplies the target structure (shapes validated by repro.checkpoint);
    ``meta`` holds the int stamps (param_version, updates, frames)."""
    if not os.path.isdir(path):
        params, meta = _restore_file(path, params_like)
        meta["fallbacks"] = 0
        return params, meta
    stamps = checkpoint_stamps(path)
    if not stamps:
        raise FileNotFoundError(f"no ckpt_*.npz checkpoints in {path}")
    skipped: list[str] = []
    for _, ckpt_path in stamps:
        try:
            params, meta = _restore_file(ckpt_path, params_like)
        except CheckpointCorruptError:
            skipped.append(ckpt_path)
            continue
        meta["fallbacks"] = len(skipped)
        return params, meta
    raise CheckpointCorruptError(
        f"every checkpoint in {path} is damaged: {skipped}"
    )


def restore_for_fit(
    restore_from: str, params_like: PyTree, opt, sharding
) -> tuple[PyTree, PyTree, dict]:
    """The shared runner warm-start: restore params from a checkpoint (or
    a directory's newest valid stamp), place them on ``sharding``, and
    build a FRESH optimizer state for them (research-checkpoint semantics
    — only params persist).  Returns ``(params, opt_state, meta)``; the
    caller continues its version line from ``meta`` so post-restore
    stamps sort above the restored one, and surfaces
    ``meta["fallbacks"]`` as the ``checkpoint_fallbacks`` counter."""
    restored, meta = restore_checkpoint(restore_from, params_like)
    params = jax.device_put(restored, sharding)
    opt_state = jax.device_put(opt.init(params), sharding)
    return params, opt_state, meta


def resolve_auto_resume(
    restore_from: str | None, checkpoint_dir: str | None, auto_resume: bool
) -> str | None:
    """The ``fit(..., auto_resume=True)`` contract, shared by runners:
    scan ``checkpoint_dir`` and resume from it when it holds any stamped
    checkpoint, start fresh when it does not (first launch).  Explicit
    ``restore_from`` and ``auto_resume`` are mutually exclusive — the
    caller must pick one recovery source."""
    if not auto_resume:
        return restore_from
    if restore_from is not None:
        raise ValueError(
            "auto_resume=True scans checkpoint_dir itself; drop "
            "restore_from (or pass it alone)"
        )
    if not checkpoint_dir:
        raise ValueError(
            "auto_resume=True needs checkpoint_dir: that is the directory "
            "a preempted run re-scans on relaunch"
        )
    return checkpoint_dir if checkpoint_stamps(checkpoint_dir) else None


class CheckpointPolicy:
    """Host-side boundary logic shared by the runners: save every
    ``every`` updates plus a final save, count what was written, and keep
    the donated update loop untouched in between.  Inert (zero branches
    taken) when ``directory`` is None or ``every`` is 0 — except that a
    bare ``directory`` still gets the final save, so ``fit(...,
    checkpoint_dir=...)`` alone persists the result."""

    def __init__(self, directory: str | None, every: int,
                 base_updates: int = 0, fault=None):
        if every < 0:
            raise ValueError("checkpoint_every must be >= 0")
        if every and not directory:
            raise ValueError(
                "checkpoint_every requires checkpoint_dir: the runner "
                "needs somewhere to write the stamped checkpoints"
            )
        self.directory = directory
        self.every = every
        self.fault = fault  # checkpoint fault injector (repro.fault)
        self.saved = 0
        self._last_version = None
        # seed the boundary from the restored update count, so a resumed
        # fit's first save lands at the NEXT boundary instead of
        # re-writing a near-duplicate of the just-restored params
        self._base_updates = base_updates
        self._last_boundary = base_updates // every if every else 0

    def _save(self, params, *, param_version: int, updates: int,
              frames: int) -> None:
        save_checkpoint(
            self.directory, params, param_version=param_version,
            updates=updates, frames=frames, fault=self.fault,
        )
        self.saved += 1
        self._last_version = param_version

    def maybe_save(self, params, *, param_version: int, updates: int,
                   frames: int) -> None:
        """Call whenever the update count advances (by one — Sebulba — or
        by a compiled block — Anakin); saves once per crossed ``every``
        boundary.  Cheap int check unless it fires."""
        if not (self.directory and self.every):
            return
        boundary = updates // self.every
        if boundary > self._last_boundary:
            self._last_boundary = boundary
            self._save(params, param_version=param_version, updates=updates,
                       frames=frames)

    def final_save(self, params, *, param_version: int, updates: int,
                   frames: int) -> None:
        """End-of-fit save, skipped when the boundary save already caught
        this exact version — or when THIS fit trained nothing (``updates``
        is cumulative; a resumed fit that did zero new updates would
        otherwise re-write the just-restored params)."""
        if (
            self.directory
            and updates > self._base_updates
            and self._last_version != param_version
        ):
            self._save(params, param_version=param_version, updates=updates,
                       frames=frames)


def updates_for_frames(total_frames: int, frames_per_update: int) -> int:
    """Minimum updates covering ``total_frames`` (ceil division) — shared
    by runners that step in fixed frame chunks (Anakin)."""
    return max(1, math.ceil(total_frames / frames_per_update))
