"""Registry of the repo's agents, keyed by name (`repro.api`).

Exists so protocol tooling — the conformance suite in
tests/test_api_protocol.py, future CLI entry points — can enumerate every
agent the repo ships and hold each to the canonical contract without
maintaining a parallel list by hand.  Factories build LAPTOP-SCALE
fixtures (tiny nets, tiny obs) and import lazily, so importing
``repro.api`` never drags in the agent zoo.

Each factory returns an ``AgentFixture``: the agent (with its declared
``AgentSpec``), the observation shape its ``init`` expects, the number of
actions, and the observation dtype (``None`` means float32; LM agents set
``jnp.int32`` so the harness feeds token observations) — everything a
generic harness needs to init params, act, and build a synthetic
trajectory for the loss contract.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple


class AgentFixture(NamedTuple):
    agent: Any
    obs_shape: tuple[int, ...]
    num_actions: int
    obs_dtype: Any = None  # None -> float32; integer dtypes = token obs


_REGISTRY: dict[str, Callable[[], AgentFixture]] = {}


def register_agent(name: str):
    """Decorator: register a zero-arg AgentFixture factory under ``name``."""

    def deco(factory: Callable[[], AgentFixture]):
        if name in _REGISTRY:
            raise ValueError(f"agent {name!r} already registered")
        _REGISTRY[name] = factory
        return factory

    return deco


def registered_agents() -> tuple[str, ...]:
    """All registered agent names, sorted (stable test parametrization)."""
    return tuple(sorted(_REGISTRY))


def make_agent(name: str) -> AgentFixture:
    try:
        factory = _REGISTRY[name]
    except KeyError:
        raise KeyError(
            f"unknown agent {name!r}; registered: {registered_agents()}"
        ) from None
    return factory()


def _sebulba_config(**overrides):
    from repro.core.sebulba import SebulbaConfig

    kwargs = dict(
        num_actor_cores=1, threads_per_actor_core=1, actor_batch_size=4,
        trajectory_length=5,
    )
    kwargs.update(overrides)
    return SebulbaConfig(**kwargs)


@register_agent("impala")
def _impala() -> AgentFixture:
    from repro.agents.impala import ConvActorCritic, ImpalaAgent

    net = ConvActorCritic(3, channels=(8,), blocks=1, hidden=32)
    return AgentFixture(ImpalaAgent(net, _sebulba_config()), (8, 8, 1), 3)


@register_agent("actor_critic")
def _actor_critic() -> AgentFixture:
    """The vector-obs MLP actor-critic, run through the IMPALA agent (the
    network itself is runner-agnostic; Anakin vmaps its single-obs twin)."""
    from repro.agents.actor_critic import BatchedMLPActorCritic
    from repro.agents.impala import ImpalaAgent

    net = BatchedMLPActorCritic(4, hidden=(16,))
    return AgentFixture(ImpalaAgent(net, _sebulba_config()), (4,), 4)


@register_agent("ppo")
def _ppo() -> AgentFixture:
    from repro.agents.actor_critic import BatchedMLPActorCritic
    from repro.agents.ppo import PPOAgent

    return AgentFixture(PPOAgent(BatchedMLPActorCritic(4, hidden=(16,))),
                        (4,), 4)


@register_agent("replay_impala")
def _replay_impala() -> AgentFixture:
    from repro.agents.actor_critic import BatchedMLPActorCritic
    from repro.agents.replay_impala import ReplayImpalaAgent

    net = BatchedMLPActorCritic(4, hidden=(16,))
    return AgentFixture(ReplayImpalaAgent(net, _sebulba_config()), (4,), 4)


@register_agent("recurrent_impala")
def _recurrent_impala() -> AgentFixture:
    from repro.agents.recurrent import (
        RecurrentImpalaAgent,
        RecurrentMLPActorCritic,
    )

    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=8)
    return AgentFixture(RecurrentImpalaAgent(net, _sebulba_config()), (4,), 4)


@register_agent("recurrent_replay_impala")
def _recurrent_replay_impala() -> AgentFixture:
    from repro.agents.recurrent import (
        RecurrentMLPActorCritic,
        RecurrentReplayImpalaAgent,
    )

    net = RecurrentMLPActorCritic(4, hidden=(16,), rnn_width=8)
    return AgentFixture(
        RecurrentReplayImpalaAgent(net, _sebulba_config(burn_in=1)), (4,), 4
    )


def _lm_cfg():
    """A 2-layer toy transformer off the qwen2 template (GQA, no softcap,
    so decode takes the flash_decode path)."""
    import dataclasses

    from repro.configs.base import get_config

    return dataclasses.replace(
        get_config("qwen2-1.5b"), num_layers=2, d_model=32, num_heads=2,
        num_kv_heads=1, head_dim=16, d_ff=64, vocab_size=32, remat="none",
    )


@register_agent("lm_policy")
def _lm_policy() -> AgentFixture:
    import jax.numpy as jnp

    from repro.agents.lm_policy import LMPolicyAgent

    cfg = _lm_cfg()
    agent = LMPolicyAgent(cfg, max_seq=8)
    return AgentFixture(agent, (), cfg.vocab_size, jnp.int32)


@register_agent("lm_replay_policy")
def _lm_replay_policy() -> AgentFixture:
    import jax.numpy as jnp

    from repro.agents.lm_policy import LMReplayPolicyAgent

    cfg = _lm_cfg()
    agent = LMReplayPolicyAgent(cfg, max_seq=8)
    return AgentFixture(agent, (), cfg.vocab_size, jnp.int32)


@register_agent("muzero")
def _muzero() -> AgentFixture:
    from repro.agents.muzero import MuZeroAgent, MuZeroConfig

    agent = MuZeroAgent(3, MuZeroConfig(
        hidden_dim=16, num_simulations=4, max_depth=3, unroll_steps=2
    ))
    return AgentFixture(agent, (6, 6, 1), 3)
