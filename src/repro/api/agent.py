"""The unified Podracer agent protocol (`repro.api`).

One canonical signature set serves every agent — feed-forward, recurrent,
on-policy, replay, search-based — so the Podracer cores (Sebulba, Anakin)
contain ZERO runtime arity-sniffing or class-marker checks:

    init(rng, obs_shape)                     -> params
    initial_carry(batch)                     -> carry pytree (() if none)
    act(params, obs, rng, carry)             -> (actions, ActAux, carry)
    loss(params, traj, weights=None)         -> (scalar, LossAux)

``ActAux`` carries the behaviour log-prob plus any agent-specific per-step
``extras`` (e.g. MCTS visit distributions — a dict keyed by
``AgentSpec.extras_keys``, stored in the device trajectory ring).
``LossAux`` carries the learner metrics dict plus per-sequence replay
``priorities`` (``()`` for agents that produce none).  ``weights=None``
means an unweighted loss; replay-capable agents apply PER importance
weights when given.

Capabilities are DECLARED, not sniffed: every agent exposes a frozen
``AgentSpec`` (``agent.spec``) saying whether it is ``recurrent`` (threads
a nonempty carry), ``replay``-capable (accepts importance weights and
returns priorities), and which ``extras_keys`` its act emits.  The spec is
validated once at runner construction by ``resolve_agent`` with fix-it
error messages; nothing about the protocol touches the traced hot path —
NamedTuple auxes flatten to exactly the tuple leaves the pre-protocol code
passed, so the donated act/update jits trace to bit-identical programs.

Migration from the old implicit protocol (3-arg ``act`` for feed-forward
agents, 4-tuple act returns for recurrent ones, ``replay_protocol`` class
markers, bare ``(metrics, td)`` loss aux) is handled by ``resolve_agent``:
an agent with no declared ``spec`` is inspected ONCE here — the signature
sniffing that used to live in ``Sebulba.__init__`` — and wrapped in a
``_LegacyAgent`` adapter presenting the canonical surface.  New agents
should declare a spec and skip the shim (see ARCHITECTURE.md §Protocol).
"""

from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Mapping, NamedTuple, Protocol, runtime_checkable

import jax
import numpy as np

PyTree = Any


class ActAux(NamedTuple):
    """Per-step acting outputs besides the actions themselves.

    ``logp`` is the behaviour log-probability of the sampled action under
    the acting policy (what V-trace/PPO correct against); ``extras`` is an
    agent-specific fixed-shape pytree stored per step in the trajectory
    ring — a dict keyed by ``AgentSpec.extras_keys``, or ``()``.
    """

    logp: jax.Array
    extras: Any = ()


class LossAux(NamedTuple):
    """Loss auxiliaries: learner ``metrics`` (a flat dict of scalars,
    folded into the device-resident accumulator) and per-sequence replay
    ``priorities`` (the PER write-back signal; ``()`` when the agent
    declares ``replay=False``)."""

    metrics: Any
    priorities: Any = ()


@dataclasses.dataclass(frozen=True)
class AgentSpec:
    """Declared agent capabilities, validated once at runner construction.

    ``recurrent``  — ``initial_carry(batch)`` returns a nonempty all-zeros
                     carry that ``act`` threads (and Sebulba stores as the
                     R2D2 stored state / resets on episode boundaries).
    ``replay``     — ``loss`` applies importance ``weights`` and returns
                     per-sequence ``LossAux.priorities``; required by
                     Sebulba's replay mode, rejected by the on-policy one.
    ``extras_keys``— exact key set of the dict ``ActAux.extras`` emits
                     (``()`` means no extras).  Gives agent extras (e.g.
                     MuZero visit distributions) a checked, named slot in
                     the trajectory ring instead of an anonymous pytree.
    """

    recurrent: bool = False
    replay: bool = False
    extras_keys: tuple[str, ...] = ()

    def __post_init__(self):
        keys = self.extras_keys
        if isinstance(keys, str):
            keys = (keys,)  # a bare string means ONE key, not its chars
        keys = tuple(keys)
        for k in keys:
            if not isinstance(k, str):
                raise TypeError(
                    f"extras_keys must be strings, got {type(k).__name__}"
                )
        object.__setattr__(self, "extras_keys", keys)


@runtime_checkable
class Agent(Protocol):
    """The canonical Podracer agent. See module docstring for semantics."""

    spec: AgentSpec

    def init(self, rng: jax.Array, obs_shape) -> PyTree: ...

    def initial_carry(self, batch: int) -> PyTree: ...

    def act(
        self, params: PyTree, obs, rng: jax.Array, carry: PyTree = ()
    ) -> tuple[jax.Array, ActAux, PyTree]: ...

    def loss(
        self, params: PyTree, traj, weights: jax.Array | None = None
    ) -> tuple[jax.Array, LossAux]: ...


# --------------------------------------------------------------- validation


_POS_KINDS = (
    inspect.Parameter.POSITIONAL_ONLY,
    inspect.Parameter.POSITIONAL_OR_KEYWORD,
)


def _positional_arity(fn) -> tuple[int, int, bool]:
    """(capable, required, has_var_positional) positional-arg counts of a
    bound method — capable counts defaulted params (what an N-positional
    call can fill), required counts default-less ones."""
    params = inspect.signature(fn).parameters
    capable = sum(p.kind in _POS_KINDS for p in params.values())
    required = sum(
        p.kind in _POS_KINDS and p.default is inspect.Parameter.empty
        for p in params.values()
    )
    var_pos = any(
        p.kind is inspect.Parameter.VAR_POSITIONAL for p in params.values()
    )
    return capable, required, var_pos


def _check_zero_carry(agent, name: str) -> None:
    """Both carry-reset mechanisms (the actor's jnp.where against the
    initial carry, the learner's decay-gate fold) restore ZERO state; a
    nonzero initial carry would silently diverge them.

    The check is on VALUES, not shapes: a carry of any size and structure
    validates so long as every leaf is zero-valued.  An autoregressive
    KV-cache pytree with a position counter (repro/agents/lm_policy.py) is
    the canonical nonzero-shaped, zero-valued carry.
    """
    leaves_with_path, _ = jax.tree_util.tree_flatten_with_path(
        agent.initial_carry(1)
    )
    for path, leaf in leaves_with_path:
        if np.any(np.asarray(leaf) != 0):
            where = jax.tree_util.keystr(path) or "<root>"
            raise ValueError(
                f"{name}.initial_carry must be all zeros in every leaf, "
                f"but leaf {where} has nonzero entries: episode resets in "
                "the fused actor step and the learner's decay-gate reset "
                "fold (repro/agents/recurrent.py) both restore zero state. "
                "Shape and dtype are unconstrained — a zero-valued KV "
                "cache plus position counter validates fine; only the "
                "t=0 VALUE must be zero"
            )


def validate_agent(agent, spec: AgentSpec) -> None:
    """Check a declared-spec agent against the canonical protocol, raising
    ValueError with a fix-it message on the first violation.  Runs once at
    runner construction — never inside a trace."""
    name = type(agent).__name__
    for method in ("init", "act", "loss", "initial_carry"):
        if not callable(getattr(agent, method, None)):
            raise ValueError(
                f"{name} does not implement the repro.api.Agent protocol: "
                f"missing {method}() — see repro/api/agent.py for the "
                "canonical signatures"
            )
    act_pos = [
        p for p in inspect.signature(agent.act).parameters.values()
        if p.kind in _POS_KINDS
    ]
    var_pos = any(
        p.kind is inspect.Parameter.VAR_POSITIONAL
        for p in inspect.signature(agent.act).parameters.values()
    )
    if not var_pos and len(act_pos) < 4:
        raise ValueError(
            f"{name}.act takes {len(act_pos)} positional arguments; the "
            "canonical protocol is act(params, obs, rng, carry) -> "
            "(actions, ActAux(logp, extras), carry) — feed-forward agents "
            "receive (and should return) the empty () carry"
        )
    if not var_pos and act_pos[3].name != "carry":
        # the runner passes the carry positionally in slot 4 on EVERY act;
        # a knob parked there (e.g. temperature=1.0) would silently
        # receive () inside the jit trace — fail at construction instead
        raise ValueError(
            f"{name}.act's 4th positional parameter is "
            f"{act_pos[3].name!r}, but the canonical protocol passes the "
            "carry there (act(params, obs, rng, carry)); rename it, and "
            "make extra knobs keyword-only (e.g. `*, "
            f"{act_pos[3].name}=...`)"
        )
    capable, _required, var_pos = _positional_arity(agent.loss)
    if not var_pos and capable < 3:
        raise ValueError(
            f"{name}.loss takes {capable} positional arguments; the "
            "canonical protocol is loss(params, trajectory, weights=None) "
            "-> (scalar, LossAux(metrics, priorities)) — weights=None "
            "means unweighted"
        )
    if spec.recurrent:
        if not jax.tree.leaves(agent.initial_carry(1)):
            raise ValueError(
                f"{name} declares AgentSpec(recurrent=True) but "
                "initial_carry(batch) returns an empty pytree; recurrent "
                "agents must expose the zero carry the runner threads, "
                "stores, and resets"
            )
        _check_zero_carry(agent, name)
    elif jax.tree.leaves(agent.initial_carry(1)):
        raise ValueError(
            f"{name}.initial_carry returns a nonempty carry but the "
            "declared AgentSpec has recurrent=False; declare "
            "AgentSpec(recurrent=True) so the runner threads (and stores) "
            "the carry"
        )


def validate_extras(extras_spec, spec: AgentSpec, name: str) -> None:
    """Check act's abstract ``extras`` structure against the declared
    ``extras_keys`` (called by runners after ``jax.eval_shape`` of act, so
    it costs nothing on the hot path)."""
    if spec.extras_keys:
        if not isinstance(extras_spec, Mapping):
            raise ValueError(
                f"{name} declares AgentSpec.extras_keys="
                f"{spec.extras_keys} so act must emit its extras as a "
                f"dict with exactly those keys; got "
                f"{type(extras_spec).__name__}"
            )
        got = tuple(sorted(extras_spec))
        if got != tuple(sorted(spec.extras_keys)):
            raise ValueError(
                f"{name}.act extras keys {got} do not match the declared "
                f"AgentSpec.extras_keys {tuple(sorted(spec.extras_keys))}"
            )
    elif jax.tree.leaves(extras_spec):
        raise ValueError(
            f"{name}.act emits extras but declares no "
            "AgentSpec.extras_keys; name them (a dict of fixed-shape "
            "arrays) so their trajectory-ring storage is part of the "
            "agent's declared surface"
        )


# --------------------------------------------------- legacy-protocol shim


class _LegacyAgent:
    """Adapter presenting the canonical protocol over a pre-``repro.api``
    agent (3-arg feed-forward ``act``, 4-tuple recurrent act returns,
    ``replay_protocol`` class marker, bare loss aux).  Built only by
    ``resolve_agent`` for agents with no declared spec — new agents should
    declare an ``AgentSpec`` instead and skip this shim entirely."""

    def __init__(self, agent, spec: AgentSpec):
        self.wrapped = agent
        self.spec = spec

    def init(self, rng, obs_shape):
        return self.wrapped.init(rng, obs_shape)

    def initial_carry(self, batch: int):
        if self.spec.recurrent:
            return self.wrapped.initial_carry(batch)
        return ()

    def act(self, params, obs, rng, carry=()):
        if self.spec.recurrent:
            actions, logp, extras, carry = self.wrapped.act(
                params, obs, rng, carry
            )
            return actions, ActAux(logp, extras), carry
        actions, logp, extras = self.wrapped.act(params, obs, rng)
        return actions, ActAux(logp, extras), ()

    def loss(self, params, traj, weights=None):
        if self.spec.replay:
            total, (metrics, priorities) = self.wrapped.loss(
                params, traj, weights
            )
            return total, LossAux(metrics, priorities)
        total, metrics = self.wrapped.loss(params, traj)
        return total, LossAux(metrics)


def _derive_legacy_spec(agent, replay_hint: bool) -> AgentSpec:
    """Inspect a spec-less agent ONCE (the sniffing that used to live in
    ``Sebulba.__init__``, now quarantined to the migration shim), raising
    the same actionable errors on malformed agents.

    ``replay_hint`` disambiguates the one capability the old implicit
    protocol could not express: a marker-less agent whose loss takes three
    positional arguments is replay-capable *iff the runner is in replay
    mode* (the pre-protocol replay learner accepted any 3-positional loss
    and assumed the ``(metrics, td)`` aux; the same signature on-policy
    meant a plain metrics aux).  Declared-spec agents never need the hint.
    """
    name = type(agent).__name__
    recurrent = callable(getattr(agent, "initial_carry", None))
    capable, required, var_pos = _positional_arity(agent.act)
    if recurrent and not var_pos and capable < 4:
        raise ValueError(
            "recurrent agents (initial_carry present) must accept "
            f"act(params, obs, rng, carry); {name}.act takes {capable} "
            "positional arguments"
        )
    if not recurrent and required > 3:
        raise ValueError(
            f"{name}.act requires {required} positional arguments but the "
            "agent has no initial_carry; recurrent agents must expose "
            "initial_carry(batch_size) so the runner knows to thread "
            "(and store) a carry"
        )
    if recurrent:
        _check_zero_carry(agent, name)
    loss_capable, _req, loss_var_pos = _positional_arity(agent.loss)
    loss_weighted = loss_var_pos or loss_capable >= 3
    replay = bool(getattr(agent, "replay_protocol", False))
    if replay and not loss_weighted:
        # the replay learner calls loss positionally with three arguments
        raise ValueError(
            "replay-protocol agents need loss(params, trajectory, "
            "importance_weights) callable with three positional "
            f"arguments; {name}.loss accepts {loss_capable}"
        )
    if replay_hint and loss_weighted:
        replay = True
    return AgentSpec(recurrent=recurrent, replay=replay)


def resolve_agent(agent, *, replay_hint: bool = False) -> tuple[Agent, AgentSpec]:
    """Resolve any agent to ``(canonical agent, validated AgentSpec)``.

    Declared-spec agents are validated (signature conformance, zero-carry
    invariant) and returned as-is — zero indirection on the hot path.
    Spec-less agents go through the legacy derivation + adapter
    (``replay_hint`` — whether the calling runner is in replay mode —
    feeds only that derivation; see ``_derive_legacy_spec``).  All errors
    carry fix-it messages and fire here, at construction — never in a jit
    trace on the first actor step.
    """
    spec = getattr(agent, "spec", None)
    if isinstance(spec, AgentSpec):
        validate_agent(agent, spec)
        return agent, spec
    spec = _derive_legacy_spec(agent, replay_hint)
    return _LegacyAgent(agent, spec), spec


def is_legacy_adapter(agent) -> bool:
    """True for agents wrapped by the migration shim (their derived spec
    cannot declare extras_keys, so extras checks don't apply to them)."""
    return isinstance(agent, _LegacyAgent)
