"""repro.api — the unified Podracer agent/runner protocol.

One typed agent contract (``Agent``/``AgentSpec``, repro/api/agent.py) and
one runner surface (``Runner``/``make_result``/checkpoint helpers,
repro/api/runner.py) front every architecture in the repo.  See
ARCHITECTURE.md §Protocol for the capability matrix and migration notes.
"""

from repro.api.agent import (  # noqa: F401
    ActAux,
    Agent,
    AgentSpec,
    LossAux,
    is_legacy_adapter,
    resolve_agent,
    validate_agent,
    validate_extras,
)
from repro.api.env import (  # noqa: F401
    DeviceEnv,
    ScenarioMix,
    resolve_scenarios,
    scenario_rows,
    validate_device_env,
)
from repro.api.registry import (  # noqa: F401
    AgentFixture,
    make_agent,
    register_agent,
    registered_agents,
)
from repro.api.runner import (  # noqa: F401
    RESULT_KEYS,
    SERVE_RESULT_KEYS,
    CheckpointPolicy,
    Runner,
    checkpoint_path,
    checkpoint_stamps,
    latest_checkpoint,
    make_result,
    make_serve_result,
    newest_valid_checkpoint,
    resolve_auto_resume,
    restore_checkpoint,
    restore_for_fit,
    save_checkpoint,
    updates_for_frames,
)
