"""Device-environment capability on the `repro.api` runner contract.

The Podracer paper prescribes two env regimes: host environments that
"cannot be compiled to TPU" (Sebulba's batched host envs) and pure-JAX
environments that live on the accelerator (Anakin).  This module makes the
second regime a first-class, *declared* capability — mirroring how
``AgentSpec`` declares agent capabilities — so runners branch on a
validated contract instead of sniffing env objects at runtime:

    env.num_actions : int
    env.obs_shape   : tuple
    env.init(rng)           -> state            (pure, vmappable)
    env.observe(state)      -> obs              (pure, vmappable)
    env.step(state, action) -> (state, TimeStep) (pure, vmappable,
                               auto-resets: discount == 0 marks the
                               episode end and the returned obs already
                               belongs to the NEXT episode)

``validate_device_env`` checks the contract once at construction with
fix-it errors (the ``resolve_agent`` discipline applied to envs); nothing
here ever runs inside a jit trace.

Scenario-mix training (ROADMAP: "as many scenarios as you can imagine as a
config, not a fork"): a weighted portfolio of device envs/difficulties is
expressed as ``ScenarioMix(name, weight, env_factory)`` entries.
``resolve_scenarios`` normalizes a bare env (or factory) into a one-entry
portfolio and validates cross-scenario compatibility (every scenario must
share ``obs_shape``/``num_actions`` — one agent acts across all of them);
``scenario_rows`` deterministically apportions a fleet batch across the
portfolio by weight (largest-remainder, every scenario gets >= 1 row).
The fleet itself lives in ``repro/envs/device_env.py``.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple, Protocol, runtime_checkable

import jax

PyTree = Any


@runtime_checkable
class DeviceEnv(Protocol):
    """A pure-JAX batched-able environment (the Anakin contract)."""

    num_actions: int
    obs_shape: tuple

    def init(self, rng: jax.Array) -> PyTree: ...

    def observe(self, state: PyTree) -> jax.Array: ...

    def step(self, state: PyTree, action: jax.Array) -> tuple[PyTree, Any]: ...


class ScenarioMix(NamedTuple):
    """One entry of a scenario portfolio: a named, weighted env source.

    ``env_factory`` is a zero-argument callable returning a ``DeviceEnv``
    (env objects are stateless parameter holders — all mutable state lives
    in the pytree ``init`` returns — so one instance is safely shared by
    every fleet/thread).  ``weight`` is the relative share of fleet rows
    (and therefore of training frames) this scenario receives.
    """

    name: str
    weight: float
    env_factory: Callable[[], DeviceEnv]


def validate_device_env(env, name: str | None = None) -> None:
    """Check ``env`` against the DeviceEnv contract, raising ValueError
    with a fix-it message on the first violation.  Runs once at runner (or
    fleet) construction — never inside a trace."""
    name = name or type(env).__name__
    for attr in ("num_actions", "obs_shape"):
        if not hasattr(env, attr):
            raise ValueError(
                f"{name} does not implement the repro.api.DeviceEnv "
                f"contract: missing {attr} — see repro/api/env.py"
            )
    for method in ("init", "observe", "step"):
        if not callable(getattr(env, method, None)):
            raise ValueError(
                f"{name} does not implement the repro.api.DeviceEnv "
                f"contract: missing {method}() — device envs are pure-JAX "
                "(init(rng) -> state, observe(state) -> obs, step(state, "
                "action) -> (state, TimeStep)); host-API envs (reset/step) "
                "belong on the BatchedHostEnv path instead"
            )
    # abstract round trip: init -> observe/step must be evaluable and the
    # observation must match the declared obs_shape.  eval_shape never
    # executes device code, so this costs a trace, not a compile.
    state_spec = jax.eval_shape(env.init, jax.random.key(0))
    obs_spec = jax.eval_shape(env.observe, state_spec)
    if tuple(obs_spec.shape) != tuple(env.obs_shape):
        raise ValueError(
            f"{name}.observe returns shape {tuple(obs_spec.shape)} but "
            f"declares obs_shape {tuple(env.obs_shape)}"
        )
    new_state, ts = jax.eval_shape(
        env.step, state_spec, jax.ShapeDtypeStruct((), jax.numpy.int32)
    )
    if jax.tree.structure(new_state) != jax.tree.structure(state_spec):
        raise ValueError(
            f"{name}.step must return a state with the same pytree "
            "structure init produced (the fleet threads it through a "
            "donated jit)"
        )
    for field in ("obs", "reward", "discount"):
        if not hasattr(ts, field):
            raise ValueError(
                f"{name}.step must return (state, TimeStep) with "
                f"obs/reward/discount fields (repro/envs/types.py); the "
                f"returned timestep has no {field!r}"
            )


def resolve_scenarios(env_or_scenarios) -> tuple[ScenarioMix, ...]:
    """Normalize a device-env argument to a validated scenario portfolio.

    Accepts a bare ``DeviceEnv`` instance, a zero-arg factory, a single
    ``ScenarioMix``, or a sequence of them.  Factories are called once here
    (instances are reused — see ``ScenarioMix``), every env is validated
    against the contract, weights must be positive, names unique, and all
    scenarios must agree on ``obs_shape``/``num_actions``.

    Returns the normalized portfolio with ``env_factory`` replaced by a
    constant factory over the materialized instance, so downstream code
    (fleets on several actor threads) never re-runs user factories.
    """
    if isinstance(env_or_scenarios, ScenarioMix):
        scenarios = [env_or_scenarios]
    elif isinstance(env_or_scenarios, (list, tuple)):
        scenarios = list(env_or_scenarios)
        if not scenarios:
            raise ValueError("scenario portfolio is empty")
        for s in scenarios:
            if not isinstance(s, ScenarioMix):
                raise ValueError(
                    "scenario portfolios are sequences of ScenarioMix("
                    f"name, weight, env_factory); got {type(s).__name__}"
                )
    else:
        env = _materialize(env_or_scenarios)
        scenarios = [ScenarioMix(type(env).__name__, 1.0, _const(env))]

    seen: set[str] = set()
    resolved = []
    for s in scenarios:
        if not s.name or s.name in seen:
            raise ValueError(
                f"scenario names must be unique and non-empty; got "
                f"{s.name!r} twice" if s.name else "empty scenario name"
            )
        seen.add(s.name)
        if not (s.weight > 0):
            raise ValueError(
                f"scenario {s.name!r} has weight {s.weight}; weights must "
                "be > 0 (drop the entry instead of zero-weighting it)"
            )
        env = _materialize(s.env_factory)
        validate_device_env(env, name=f"scenario {s.name!r} env")
        resolved.append(ScenarioMix(s.name, float(s.weight), _const(env)))
    first = resolved[0].env_factory()
    for s in resolved[1:]:
        env = s.env_factory()
        if (
            tuple(env.obs_shape) != tuple(first.obs_shape)
            or env.num_actions != first.num_actions
        ):
            raise ValueError(
                "scenario mix trains ONE agent across the portfolio, so "
                "every scenario must share obs_shape and num_actions; "
                f"{resolved[0].name!r} has obs_shape "
                f"{tuple(first.obs_shape)} / {first.num_actions} actions "
                f"but {s.name!r} has {tuple(env.obs_shape)} / "
                f"{env.num_actions}"
            )
    return tuple(resolved)


def _const(env) -> Callable[[], DeviceEnv]:
    return lambda: env


def _materialize(source):
    """An env source is an instance, a zero-arg factory, or the env class
    itself.  A class always needs calling — ``hasattr(cls, "step")`` is
    true for the unbound method, but ``obs_shape`` only exists after
    ``__init__`` runs."""
    if isinstance(source, type) or (
        callable(source) and not hasattr(source, "step")
    ):
        return source()
    return source


def scenario_rows(
    scenarios: tuple[ScenarioMix, ...], batch: int
) -> tuple[int, ...]:
    """Apportion ``batch`` fleet rows across the portfolio by weight.

    Largest-remainder (Hamilton) apportionment after guaranteeing every
    scenario at least one row — deterministic, exact (rows sum to
    ``batch``), and stable under weight rescaling.  Raises when the batch
    cannot seat every scenario.
    """
    n = len(scenarios)
    if batch < n:
        raise ValueError(
            f"fleet batch {batch} cannot seat {n} scenarios (each needs "
            ">= 1 row); raise the batch or trim the portfolio"
        )
    total_w = sum(s.weight for s in scenarios)
    spare = batch - n  # one seat per scenario is already guaranteed
    quotas = [spare * s.weight / total_w for s in scenarios]
    rows = [1 + int(q) for q in quotas]
    remainders = sorted(
        range(n), key=lambda i: (quotas[i] - int(quotas[i]), -i), reverse=True
    )
    for i in remainders[: batch - sum(rows)]:
        rows[i] += 1
    return tuple(rows)
