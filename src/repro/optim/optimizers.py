"""Minimal optax-style gradient transformation library (optax is not
installed in this environment; this implements the subset the framework
needs, with the same (init, update) contract so it is drop-in swappable).

Optimizer *state* dtype policy: Adam moments default to the parameter dtype
of the tree passed at init — the launch configs for the very large
architectures pass bf16 params so moments are bf16 (a deliberate memory/
precision trade recorded in EXPERIMENTS.md §Perf); small-model RL training
uses f32 params and hence f32 moments.
"""

from __future__ import annotations

from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class GradientTransformation(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree | None], tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(l.astype(jnp.float32))) for l in leaves)
    )


def clip_by_global_norm(max_norm: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        norm = global_norm(grads)
        scale_ = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-9))
        return jax.tree.map(lambda g: g * scale_.astype(g.dtype), grads), state

    return GradientTransformation(init, update)


def scale(factor: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        del params
        return jax.tree.map(lambda g: g * factor, grads), state

    return GradientTransformation(init, update)


def scale_by_schedule(schedule: Callable[[jax.Array], jax.Array]):
    def init(params):
        del params
        return jnp.zeros((), jnp.int32)

    def update(grads, count, params=None):
        del params
        s = schedule(count)
        return jax.tree.map(lambda g: g * s.astype(g.dtype), grads), count + 1

    return GradientTransformation(init, update)


class AdamState(NamedTuple):
    count: jax.Array
    mu: PyTree
    nu: PyTree


def scale_by_adam(b1=0.9, b2=0.999, eps=1e-8) -> GradientTransformation:
    def init(params):
        return AdamState(
            count=jnp.zeros((), jnp.int32),
            mu=jax.tree.map(jnp.zeros_like, params),
            nu=jax.tree.map(jnp.zeros_like, params),
        )

    def update(grads, state, params=None):
        del params
        count = state.count + 1
        mu = jax.tree.map(
            lambda m, g: (b1 * m.astype(jnp.float32) + (1 - b1) * g.astype(jnp.float32)).astype(m.dtype),
            state.mu, grads,
        )
        nu = jax.tree.map(
            lambda v, g: (b2 * v.astype(jnp.float32) + (1 - b2) * jnp.square(g.astype(jnp.float32))).astype(v.dtype),
            state.nu, grads,
        )
        bc1 = 1 - b1**count.astype(jnp.float32)
        bc2 = 1 - b2**count.astype(jnp.float32)
        updates = jax.tree.map(
            lambda m, v: (
                (m.astype(jnp.float32) / bc1)
                / (jnp.sqrt(v.astype(jnp.float32) / bc2) + eps)
            ),
            mu, nu,
        )
        return updates, AdamState(count=count, mu=mu, nu=nu)

    return GradientTransformation(init, update)


class RMSPropState(NamedTuple):
    nu: PyTree


def scale_by_rms(decay=0.99, eps=1e-8) -> GradientTransformation:
    def init(params):
        return RMSPropState(nu=jax.tree.map(jnp.zeros_like, params))

    def update(grads, state, params=None):
        del params
        nu = jax.tree.map(
            lambda v, g: decay * v + (1 - decay) * jnp.square(g.astype(v.dtype)),
            state.nu, grads,
        )
        updates = jax.tree.map(
            lambda g, v: g.astype(jnp.float32)
            / (jnp.sqrt(v.astype(jnp.float32)) + eps),
            grads, nu,
        )
        return updates, RMSPropState(nu=nu)

    return GradientTransformation(init, update)


def add_decayed_weights(weight_decay: float) -> GradientTransformation:
    def init(params):
        del params
        return ()

    def update(grads, state, params=None):
        assert params is not None, "weight decay needs params"
        return (
            jax.tree.map(
                lambda g, p: g + weight_decay * p.astype(g.dtype), grads, params
            ),
            state,
        )

    return GradientTransformation(init, update)


def chain(*transforms: GradientTransformation) -> GradientTransformation:
    def init(params):
        return tuple(t.init(params) for t in transforms)

    def update(grads, state, params=None):
        new_state = []
        for t, s in zip(transforms, state):
            grads, s2 = t.update(grads, s, params)
            new_state.append(s2)
        return grads, tuple(new_state)

    return GradientTransformation(init, update)


# -- canned optimizers ------------------------------------------------------


def sgd(lr: float, momentum: float = 0.0) -> GradientTransformation:
    if momentum == 0.0:
        return chain(scale(-lr))

    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        del params
        state = jax.tree.map(lambda m, g: momentum * m + g.astype(m.dtype), state, grads)
        return jax.tree.map(lambda m: -lr * m, state), state

    return GradientTransformation(init, update)


def adam(lr, b1=0.9, b2=0.999, eps=1e-8, clip_norm: float = 0.0):
    parts = []
    if clip_norm:
        parts.append(clip_by_global_norm(clip_norm))
    parts.append(scale_by_adam(b1, b2, eps))
    if callable(lr):
        parts.append(scale_by_schedule(lambda c: -lr(c)))
    else:
        parts.append(scale(-lr))
    return chain(*parts)


def adamw(lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.01, clip_norm=1.0):
    parts = [clip_by_global_norm(clip_norm), scale_by_adam(b1, b2, eps),
             add_decayed_weights(weight_decay)]
    if callable(lr):
        parts.append(scale_by_schedule(lambda c: -lr(c)))
    else:
        parts.append(scale(-lr))
    return chain(*parts)


def rmsprop(lr, decay=0.99, eps=1e-8, clip_norm: float = 0.0):
    parts = []
    if clip_norm:
        parts.append(clip_by_global_norm(clip_norm))
    parts.extend([scale_by_rms(decay, eps), scale(-lr)])
    return chain(*parts)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(
        lambda p, u: (p.astype(jnp.float32) + u.astype(jnp.float32)).astype(p.dtype),
        params, updates,
    )


def state_shardings(opt_state, param_shardings, replicated):
    """Shardings for a chain(...)-built optimizer state.

    Adam/RMSProp moments mirror the parameter tree and inherit the parameter
    shardings; step counters and empty states are replicated.  Works on real
    states and on eval_shape ShapeDtypeStruct trees.
    """

    def one(s):
        if isinstance(s, AdamState):
            return AdamState(count=replicated, mu=param_shardings,
                             nu=param_shardings)
        if isinstance(s, RMSPropState):
            return RMSPropState(nu=param_shardings)
        return jax.tree.map(lambda _: replicated, s)

    return tuple(one(s) for s in opt_state)


# -- schedules ---------------------------------------------------------------


def cosine_schedule(base: float, total_steps: int, final_frac: float = 0.1):
    def schedule(count):
        frac = jnp.clip(count.astype(jnp.float32) / total_steps, 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base * (final_frac + (1 - final_frac) * cos)

    return schedule


def warmup_cosine(base: float, warmup: int, total_steps: int):
    def schedule(count):
        c = count.astype(jnp.float32)
        warm = c / max(warmup, 1)
        frac = jnp.clip((c - warmup) / max(total_steps - warmup, 1), 0.0, 1.0)
        cos = 0.5 * (1 + jnp.cos(jnp.pi * frac))
        return base * jnp.where(c < warmup, warm, cos)

    return schedule
