"""Logical-axis sharding rules (MaxText-style) for the production mesh.

Every parameter and activation dimension in the model stack is annotated with
a *logical* axis name ("embed", "vocab", "heads", ...).  A rules table maps
logical names to (tuples of) mesh axis names.  This file is pure metadata —
it never touches jax device state, so it is safe to import anywhere.

Mesh axes (see repro/launch/mesh.py):
  single pod : ("data", "model")            16 x 16 = 256 chips
  multi pod  : ("pod", "data", "model")     2 x 16 x 16 = 512 chips

The default rules implement the scheme described in DESIGN.md §5:
  * batch is data-parallel over ("pod", "data")
  * model-parallel dims (vocab, heads, mlp, experts) shard over "model"
  * "embed" is left replicated by default; the FSDP rule set (used by the
    very large architectures) additionally shards embed/mlp-stacked params
    over "data" so that optimizer state fits.
"""

from __future__ import annotations

from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

PyTree = Any

# Logical axis -> mesh axes.  None means replicated along that dim.
# Entries may be a single mesh axis name, a tuple of names, or None.
Rules = Mapping[str, Any]

# Baseline (paper-faithful data-parallel + model-parallel) rules.
DEFAULT_RULES: Rules = {
    # activations
    "batch": ("pod", "data"),
    "seq": None,
    "act_embed": None,
    "act_heads": "model",
    "act_kv_heads": "model",
    "act_mlp": "model",
    "kv_seq": None,
    # parameters
    "embed": None,
    "vocab": "model",
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",
    "expert_mlp": None,
    "ssm_state": None,
    "ssm_heads": "model",
    "ssm_inner": "model",
    "conv_width": None,
    "rnn_width": "model",
    "layers": None,  # stacked-layer leading dim from scan-over-layers
    "frames": None,
    "patches": None,
}

# FSDP rules: additionally shard the "embed" param dim over "data" so that
# params + Adam state of the 100B+ configs fit in HBM.  Activations keep the
# same layout as DEFAULT_RULES.
FSDP_RULES: Rules = dict(
    DEFAULT_RULES,
    embed=("pod", "data"),
)

# Long-context decode rules: batch=1 cannot use the data axis, so the KV
# cache / recurrent state sequence dim is sharded over "data" instead
# (flash-decoding style).  See DESIGN.md §5.
LONG_CONTEXT_RULES: Rules = dict(
    DEFAULT_RULES,
    batch=None,
    kv_seq="data",
)


def _normalize(entry: Any) -> Any:
    """Return a PartitionSpec element for a rules entry."""
    if entry is None:
        return None
    if isinstance(entry, str):
        return entry
    return tuple(entry)


def spec_for_axes(axes: Sequence[str | None], rules: Rules, mesh: Mesh) -> P:
    """Map a tuple of logical axis names to a PartitionSpec for ``mesh``.

    Mesh axes that do not exist on the mesh (e.g. "pod" on a single-pod mesh)
    are silently dropped.  A logical name missing from the rules table is an
    error — sharding must be explicit.
    """
    mesh_axes = set(mesh.axis_names)
    used: set[str] = set()
    out = []
    for name in axes:
        if name is None:
            out.append(None)
            continue
        if name not in rules:
            raise KeyError(f"no sharding rule for logical axis {name!r}")
        entry = _normalize(rules[name])
        if entry is None:
            out.append(None)
            continue
        if isinstance(entry, str):
            entry = (entry,)
        filtered = tuple(a for a in entry if a in mesh_axes and a not in used)
        used.update(filtered)
        if not filtered:
            out.append(None)
        elif len(filtered) == 1:
            out.append(filtered[0])
        else:
            out.append(filtered)
    return P(*out)


def spec_for_shape(
    shape: Sequence[int], axes: Sequence[str | None], rules: Rules, mesh: Mesh
) -> P:
    """Like spec_for_axes, but drops mesh axes that do not divide the dim.

    This is what makes one rules table serve every architecture: qwen2 has
    12 heads (not divisible by model=16) so its attention params stay
    replicated, while its 8960-wide MLP shards 16 ways.
    """
    base = spec_for_axes(axes, rules, mesh)
    out = []
    for dim, entry in zip(shape, tuple(base) + (None,) * (len(shape) - len(base))):
        if entry is None:
            out.append(None)
            continue
        names = (entry,) if isinstance(entry, str) else tuple(entry)
        kept: list[str] = []
        size = dim
        for a in names:
            n = mesh.shape[a]
            if size % n == 0:
                kept.append(a)
                size //= n
        if not kept:
            out.append(None)
        elif len(kept) == 1:
            out.append(kept[0])
        else:
            out.append(tuple(kept))
    return P(*out)


def tree_shardings(
    axes_tree: PyTree,
    mesh: Mesh,
    rules: Rules = DEFAULT_RULES,
    shapes_tree: PyTree | None = None,
) -> PyTree:
    """Build a NamedSharding pytree from a logical-axes pytree.

    If ``shapes_tree`` (a matching pytree of arrays / ShapeDtypeStructs) is
    given, shardings are divisibility-checked per leaf dim and non-dividing
    mesh axes dropped (replicated) — see spec_for_shape.
    """
    is_axes = lambda x: isinstance(x, tuple)
    if shapes_tree is None:
        return jax.tree.map(
            lambda axes: NamedSharding(mesh, spec_for_axes(axes, rules, mesh)),
            axes_tree,
            is_leaf=is_axes,
        )
    return jax.tree.map(
        lambda axes, leaf: NamedSharding(
            mesh, spec_for_shape(leaf.shape, axes, rules, mesh)
        ),
        axes_tree,
        shapes_tree,
        is_leaf=is_axes,
    )


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def data_parallel(mesh: Mesh, rules: Rules = DEFAULT_RULES) -> NamedSharding:
    """Sharding for a (batch, ...) activation: batch over data axes."""
    return NamedSharding(mesh, spec_for_axes(("batch",), rules, mesh))


def batch_axes(mesh: Mesh, rules: Rules = DEFAULT_RULES) -> tuple[str, ...]:
    """The concrete mesh axes the batch is sharded over (for psum/pmean)."""
    spec = spec_for_axes(("batch",), rules, mesh)
    entry = spec[0]
    if entry is None:
        return ()
    if isinstance(entry, str):
        return (entry,)
    return tuple(entry)


def divisible_batch(global_batch: int, mesh: Mesh, rules: Rules) -> bool:
    """Check the batch can actually be laid out over its assigned axes."""
    n = 1
    for a in batch_axes(mesh, rules):
        n *= mesh.shape[a]
    return global_batch % n == 0
