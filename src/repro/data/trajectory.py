"""Trajectory containers shared by Anakin and Sebulba.

``Trajectory`` is batch-major (B, T, ...).  Sebulba actors accumulate
fixed-length trajectories *on device* (the paper: "each actor thread
accumulates a batch of trajectories of fixed length on device") via
``TrajectoryAccumulator`` — a list of per-step device slices that is stacked
device-side only when the trajectory is complete, then split along the batch
dimension for the learner shards.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp
import numpy as np


class Trajectory(NamedTuple):
    obs: Any  # (B, T, ...)
    actions: jax.Array  # (B, T) int32
    rewards: jax.Array  # (B, T) float32
    discounts: jax.Array  # (B, T) float32
    behaviour_logp: jax.Array  # (B, T) float32
    bootstrap_obs: Any  # (B, ...) obs at T (for the bootstrap value)
    extras: Any = ()  # agent-specific per-step data (e.g. MCTS visit probs)


class TrajectoryAccumulator:
    """Accumulates T steps of (obs, action, reward, discount, logp, extras)."""

    def __init__(self, length: int):
        self.length = length
        self._steps: list[tuple] = []

    def add(self, obs, action, reward, discount, logp, extras=()) -> None:
        self._steps.append((obs, action, reward, discount, logp, extras))

    @property
    def full(self) -> bool:
        return len(self._steps) >= self.length

    def drain(self, bootstrap_obs) -> Trajectory:
        steps = self._steps[: self.length]
        self._steps = self._steps[self.length :]
        stack = lambda i: jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=1), *[s[i] for s in steps]
        )
        return Trajectory(
            obs=stack(0),
            actions=stack(1),
            rewards=stack(2),
            discounts=stack(3),
            behaviour_logp=stack(4),
            bootstrap_obs=bootstrap_obs,
            extras=(
                ()
                if isinstance(steps[0][5], tuple) and not steps[0][5]
                else stack(5)
            ),
        )


def split_for_learners(traj: Trajectory, num_learners: int) -> list[Trajectory]:
    """Split a trajectory batch along B into per-learner shards (paper:
    "splits the batch of trajectories along the batch dimension, sends each
    shard directly to one of the learners")."""

    def split(x):
        return jnp.split(x, num_learners, axis=0)

    parts = jax.tree.map(split, traj)
    return [
        jax.tree.map(lambda p: p[i], parts, is_leaf=lambda x: isinstance(x, list))
        for i in range(num_learners)
    ]
