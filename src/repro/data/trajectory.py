"""Trajectory containers shared by Anakin and Sebulba.

``Trajectory`` is batch-major (B, T, ...).  Sebulba actors accumulate
fixed-length trajectories *on device* (the paper: "each actor thread
accumulates a batch of trajectories of fixed length on device") in a
``DeviceTrajectoryBuffer`` — a preallocated (B, T, ...) pytree that the
fused actor step updates in place via ``lax.dynamic_update_index_in_dim``
with the buffer donated (the replay-ring recipe from repro/replay/buffer.py
applied to the actor half of the system).  Recurrent agents additionally
thread a carry through the fused step; the carry entering step 0 of a slice
is snapshotted into ``carry0`` and drained as ``Trajectory.init_carry`` —
the R2D2 "stored state" the learner (and the replay ring) replays from.
``TrajectoryAccumulator`` is the legacy host-list path, kept as the
bit-exactness reference for the fused pipeline and for host-side tooling.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp


class Trajectory(NamedTuple):
    obs: Any  # (B, T, ...)
    actions: jax.Array  # (B, T) int32
    rewards: jax.Array  # (B, T) float32
    discounts: jax.Array  # (B, T) float32
    behaviour_logp: jax.Array  # (B, T) float32
    bootstrap_obs: Any  # (B, ...) obs at T (for the bootstrap value)
    extras: Any = ()  # agent-specific per-step data (e.g. MCTS visit probs)
    # recurrent-agent carry at step 0 of this slice (R2D2 "stored state"):
    # (B, ...) leaves, or () for feed-forward agents.  Rides through the
    # learner shards and the replay ring like any other leaf, so sampled
    # sequences replay from the state the actor actually had.
    init_carry: Any = ()


class DeviceTrajectoryBuffer(NamedTuple):
    """Preallocated device-resident trajectory ring for one actor thread.

    All array leaves are (B, T, ...) storage plus two scalar cursors, so the
    whole buffer is a pure pytree that threads through a donated ``jax.jit``
    — each env step is a single in-place ``dynamic_update_index_in_dim``
    write instead of a growing host list of per-step arrays.

    Rewards and discounts for step t are only known on the host *after* the
    env consumed action t, so they arrive one step late: ``buffer_add``
    writes them at slot t-1 (``has_prev`` gates the first write after an
    init/drain, when there is no pending step), and the final step's
    reward/discount land in ``buffer_drain`` together with the bootstrap
    observation.
    """

    obs: Any  # (B, T, ...)
    actions: jax.Array  # (B, T)
    rewards: jax.Array  # (B, T) float32
    discounts: jax.Array  # (B, T) float32
    behaviour_logp: jax.Array  # (B, T)
    extras: Any  # agent extras; (B, T, ...) leaves or ()
    t: jax.Array  # () int32 — write cursor, wraps mod T
    has_prev: jax.Array  # () bool — a step since init/drain awaits its reward
    # recurrent carry entering step 0 of the slice being filled ((B, ...)
    # leaves, no time axis): snapshotted by ``buffer_add`` when t == 0 and
    # handed to the trajectory at drain.  () for feed-forward agents.
    carry0: Any = ()

    @property
    def length(self) -> int:
        return self.actions.shape[1]


def device_buffer_init(
    length: int, obs_spec: Any, action_spec, logp_spec, extras_spec: Any = (),
    carry_spec: Any = (),
) -> DeviceTrajectoryBuffer:
    """Allocate a zeroed ``DeviceTrajectoryBuffer`` from per-step specs.

    Specs are per-step (B, ...) ``ShapeDtypeStruct``s (or concrete arrays);
    the Sebulba actor derives them with ``jax.eval_shape`` over the agent's
    ``act`` so agent extras of any fixed-shape pytree structure get a
    storage slot without the agent knowing about the buffer.  ``carry_spec``
    describes the recurrent carry ((B, ...) leaves, stored WITHOUT a time
    axis — only the slice-initial state is kept); () for feed-forward.
    """

    def alloc(spec):
        return jnp.zeros((spec.shape[0], length) + spec.shape[1:], spec.dtype)

    B = action_spec.shape[0]
    return DeviceTrajectoryBuffer(
        obs=jax.tree.map(alloc, obs_spec),
        actions=alloc(action_spec),
        rewards=jnp.zeros((B, length), jnp.float32),
        discounts=jnp.zeros((B, length), jnp.float32),
        behaviour_logp=alloc(logp_spec),
        extras=jax.tree.map(alloc, extras_spec),
        t=jnp.zeros((), jnp.int32),
        has_prev=jnp.zeros((), jnp.bool_),
        carry0=jax.tree.map(
            lambda s: jnp.zeros(s.shape, s.dtype), carry_spec
        ),
    )


def buffer_add(
    buf: DeviceTrajectoryBuffer, obs, actions, logp, extras, rew_disc,
    carry: Any = (),
) -> DeviceTrajectoryBuffer:
    """Write one env step at the cursor; pure, composes into the fused step.

    ``rew_disc`` is the (2, B) float32 [rewards; discounts] of the
    *previous* step, batched into one host transfer — written at slot t-1
    (mod T) when ``has_prev``.  ``carry`` is the recurrent state entering
    this step (post episode-boundary reset): at t == 0 it is snapshotted
    into ``carry0`` as the slice's stored state; later steps leave the
    snapshot alone.  Trace this inside a jit that donates ``buf`` so every
    write is an in-place buffer update.
    """
    T = buf.actions.shape[1]
    t = buf.t
    upd = lambda s, x: jax.lax.dynamic_update_index_in_dim(s, x, t, 1)
    prev = jnp.remainder(t - 1, T)
    rewards = jnp.where(
        buf.has_prev,
        jax.lax.dynamic_update_index_in_dim(buf.rewards, rew_disc[0], prev, 1),
        buf.rewards,
    )
    discounts = jnp.where(
        buf.has_prev,
        jax.lax.dynamic_update_index_in_dim(buf.discounts, rew_disc[1], prev, 1),
        buf.discounts,
    )
    return DeviceTrajectoryBuffer(
        obs=jax.tree.map(upd, buf.obs, obs),
        actions=upd(buf.actions, actions),
        rewards=rewards,
        discounts=discounts,
        behaviour_logp=upd(buf.behaviour_logp, logp),
        extras=jax.tree.map(upd, buf.extras, extras),
        t=jnp.remainder(t + 1, T),
        has_prev=jnp.ones((), jnp.bool_),
        carry0=jax.tree.map(
            lambda c0, c: jnp.where(t == 0, c, c0), buf.carry0, carry
        ),
    )


def buffer_drain(
    buf: DeviceTrajectoryBuffer, rew_disc, bootstrap_obs
) -> tuple[Trajectory, DeviceTrajectoryBuffer]:
    """Complete the trajectory: final rewards in, fresh ring out.

    Call via a jit that donates ``buf``: the trajectory leaves then *alias*
    the donated storage (zero-copy handoff to the learner shards) while the
    returned ring gets fresh zeroed buffers — a memset instead of a T-leaf
    copy.  ``rew_disc`` is the (2, B) [rewards; discounts] of the last step
    (T-1), which the host only learned after the final ``buffer_add``.
    """
    T = buf.actions.shape[1]
    traj = Trajectory(
        obs=buf.obs,
        actions=buf.actions,
        rewards=jax.lax.dynamic_update_index_in_dim(
            buf.rewards, rew_disc[0], T - 1, 1
        ),
        discounts=jax.lax.dynamic_update_index_in_dim(
            buf.discounts, rew_disc[1], T - 1, 1
        ),
        behaviour_logp=buf.behaviour_logp,
        bootstrap_obs=bootstrap_obs,
        extras=buf.extras,
        init_carry=buf.carry0,
    )
    fresh = DeviceTrajectoryBuffer(
        obs=jax.tree.map(jnp.zeros_like, buf.obs),
        actions=jnp.zeros_like(buf.actions),
        rewards=jnp.zeros_like(buf.rewards),
        discounts=jnp.zeros_like(buf.discounts),
        behaviour_logp=jnp.zeros_like(buf.behaviour_logp),
        extras=jax.tree.map(jnp.zeros_like, buf.extras),
        t=jnp.zeros((), jnp.int32),
        has_prev=jnp.zeros((), jnp.bool_),
        # the zeroed snapshot slot is overwritten by the next t==0 add (the
        # LIVE carry persists across the drain on the actor side)
        carry0=jax.tree.map(jnp.zeros_like, buf.carry0),
    )
    return traj, fresh


class TrajectoryAccumulator:
    """Accumulates T steps of (obs, action, reward, discount, logp, extras).

    Legacy host-list path: one device dispatch per leaf per step at add time
    and a T-way ``jnp.stack`` per leaf at drain.  Sebulba's hot loop uses
    the fused ``DeviceTrajectoryBuffer`` instead; this stays as the
    reference the fused pipeline is pinned bit-exact against
    (tests/test_trajectory_buffer.py) and for host-side tooling.
    """

    def __init__(self, length: int):
        self.length = length
        self._steps: list[tuple] = []

    def add(self, obs, action, reward, discount, logp, extras=()) -> None:
        self._steps.append((obs, action, reward, discount, logp, extras))

    @property
    def full(self) -> bool:
        return len(self._steps) >= self.length

    def drain(self, bootstrap_obs) -> Trajectory:
        steps = self._steps[: self.length]
        self._steps = self._steps[self.length :]
        stack = lambda i: jax.tree.map(
            lambda *xs: jnp.stack(xs, axis=1), *[s[i] for s in steps]
        )
        return Trajectory(
            obs=stack(0),
            actions=stack(1),
            rewards=stack(2),
            discounts=stack(3),
            behaviour_logp=stack(4),
            bootstrap_obs=bootstrap_obs,
            extras=(
                ()
                if isinstance(steps[0][5], tuple) and not steps[0][5]
                else stack(5)
            ),
        )


def split_for_learners(traj: Trajectory, num_learners: int) -> list[Trajectory]:
    """Split a trajectory batch along B into per-learner shards (paper:
    "splits the batch of trajectories along the batch dimension, sends each
    shard directly to one of the learners")."""

    def split(x):
        return jnp.split(x, num_learners, axis=0)

    parts = jax.tree.map(split, traj)
    return [
        jax.tree.map(lambda p: p[i], parts, is_leaf=lambda x: isinstance(x, list))
        for i in range(num_learners)
    ]
