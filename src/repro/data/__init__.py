from repro.data.trajectory import Trajectory, TrajectoryAccumulator  # noqa: F401
