from repro.data.trajectory import (  # noqa: F401
    DeviceTrajectoryBuffer,
    Trajectory,
    TrajectoryAccumulator,
    buffer_add,
    buffer_drain,
    device_buffer_init,
    split_for_learners,
)
