from repro.checkpoint.checkpoint import (  # noqa: F401
    CheckpointCorruptError,
    restore,
    save,
    verify,
)
