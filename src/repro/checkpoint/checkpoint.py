"""npz-based checkpointing for nested-dict pytrees — durable edition.

Flat path-keyed storage ('a/b/c' -> array) with dtype preservation
(bfloat16 is stored via a uint16 view + sidecar dtype map).

Durability contract (ISSUE 7):

  * **Atomic write** — the payload is serialized fully in memory, written
    to a same-directory temp file, fsync'd, then ``os.replace``'d into
    place.  A crash at any point leaves either the previous checkpoint or
    temp-file debris the restore path never looks at — a stamped
    ``ckpt_*.npz`` is always a complete write.
  * **Corruption detection** — the payload embeds a sha256 over every
    array's (key, dtype, shape, bytes).  ``restore`` recomputes and
    raises :class:`CheckpointCorruptError` on mismatch, and wraps
    unreadable files (torn zip, truncated npz, missing sidecars) in the
    same error, so callers can distinguish "this file is damaged — fall
    back" from genuine structure mismatches (which stay
    ``KeyError``/``ValueError``).
  * **Fault hook** — ``save(..., fault=...)`` threads the deterministic
    checkpoint injector (repro/fault) through the writer: a ``ckpt_kill``
    raises mid-write (tmp debris stays, like real process death); a
    ``ckpt_corrupt`` tears the payload to exercise detection.

Good enough for single-host research checkpoints; a real multi-pod
deployment would swap in a sharded array-store behind the same functions.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import tempfile
import zipfile
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.fault.plan import InjectedCheckpointKill

PyTree = Any


class CheckpointCorruptError(RuntimeError):
    """The checkpoint file is damaged (unreadable container or checksum
    mismatch) — distinct from structure/shape mismatches so restore-time
    fallback logic can skip damaged stamps and keep strict errors
    strict."""


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def _digest(storable: dict[str, np.ndarray], dtypes: dict[str, str]) -> str:
    """sha256 over the stored arrays in sorted-key order.  Computed on the
    post-bfloat16-view arrays (what the file actually holds), keyed with
    dtype and shape so a reinterpreted or reshaped leaf can't collide."""
    h = hashlib.sha256()
    for key in sorted(storable):
        arr = storable[key]
        h.update(key.encode())
        h.update(dtypes[key].encode())
        h.update(str(tuple(arr.shape)).encode())
        h.update(np.ascontiguousarray(arr).tobytes())
    return h.hexdigest()


def save(path: str, tree: PyTree, *, fault: Callable | None = None) -> None:
    """Atomically write ``tree`` to ``path`` with an embedded checksum.

    The npz is built fully in memory first, so the on-disk write is one
    sequential dump of a complete payload: tmp file -> flush -> fsync ->
    ``os.replace``.  ``fault`` is the checkpoint fault injector (tests /
    chaos benches): it sees the serialized payload before the write and
    may raise (kill: tmp debris is deliberately left behind, like real
    process death) or return a mutated payload (torn write)."""
    flat = _flatten(tree)
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    storable = {
        k: v.view(np.uint16) if v.dtype == jnp.bfloat16 else v
        for k, v in flat.items()
    }
    buf = io.BytesIO()
    np.savez(
        buf,
        __dtypes__=json.dumps(dtypes),
        __checksum__=_digest(storable, dtypes),
        **storable,
    )
    payload = buf.getvalue()

    directory = os.path.dirname(path) or "."
    os.makedirs(directory, exist_ok=True)
    # per-process tmp prefix: many hosts checkpoint into ONE shared
    # directory (multi-host elasticity, ISSUE 8), so the staging name
    # must be collision-free across processes — mkstemp already
    # guarantees uniqueness within a process; the pid makes concurrent
    # writers' debris attributable and can never race another host's
    # staging file even across filesystems with weak mkstemp semantics
    fd, tmp = tempfile.mkstemp(
        dir=directory,
        prefix=f".{os.path.basename(path)}-{os.getpid()}-",
        suffix=".tmp",
    )
    try:
        with os.fdopen(fd, "wb") as f:
            if fault is not None:
                payload = fault(path, payload)
            f.write(payload)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except InjectedCheckpointKill:
        # simulated process death: a killed process cleans nothing up.
        # Leaving the tmp file proves the restore path ignores debris.
        raise
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _load_verified(path: str) -> tuple[dict[str, np.ndarray], dict[str, str]]:
    """Load and checksum-verify the raw stored arrays, wrapping every
    unreadable-container failure in CheckpointCorruptError."""
    try:
        with np.load(path, allow_pickle=False) as data:
            dtypes = json.loads(str(data["__dtypes__"]))
            stored_sum = (
                str(data["__checksum__"]) if "__checksum__" in data.files
                else None  # pre-durability checkpoints: no checksum to check
            )
            raw = {
                k: data[k] for k in data.files
                if k not in ("__dtypes__", "__checksum__")
            }
    except (zipfile.BadZipFile, ValueError, EOFError, OSError, KeyError,
            json.JSONDecodeError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {path} is unreadable ({type(e).__name__}: {e})"
        ) from e
    if stored_sum is not None and _digest(raw, dtypes) != stored_sum:
        raise CheckpointCorruptError(
            f"checkpoint {path} failed checksum verification "
            "(torn write or on-disk corruption)"
        )
    return raw, dtypes


def verify(path: str) -> bool:
    """True iff ``path`` is a complete, checksum-valid checkpoint.

    The read-only half of the restore fallback: a rejoining host scans
    the shared checkpoint directory newest-to-oldest and resumes from
    the first stamp this accepts, without paying a full restore per
    candidate (repro.api.newest_valid_checkpoint)."""
    try:
        _load_verified(path)
        return True
    except CheckpointCorruptError:
        return False


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes are validated).

    Raises :class:`CheckpointCorruptError` for damaged files (unreadable
    npz, checksum mismatch); ``KeyError``/``ValueError`` keep meaning
    structure mismatch against ``like``."""
    raw, dtypes = _load_verified(path)
    flat = {
        k: arr.view(jnp.bfloat16) if dtypes[k] == "bfloat16" else arr
        for k, arr in raw.items()
    }

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_keys, leaf in leaves_like:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_keys
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves
    )
