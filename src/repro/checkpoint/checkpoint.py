"""npz-based checkpointing for nested-dict pytrees.

Flat path-keyed storage ('a/b/c' -> array) with dtype preservation
(bfloat16 is stored via a uint16 view + sidecar dtype map).  Atomic write
via rename.  Good enough for single-host research checkpoints; a real
multi-pod deployment would swap in a sharded array-store behind the same
two functions.
"""

from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


def _flatten(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path
        )
        out[key] = np.asarray(leaf)
    return out


def save(path: str, tree: PyTree) -> None:
    flat = _flatten(tree)
    dtypes = {k: str(v.dtype) for k, v in flat.items()}
    storable = {
        k: v.view(np.uint16) if v.dtype == jnp.bfloat16 else v
        for k, v in flat.items()
    }
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(path) or ".")
    try:
        with os.fdopen(fd, "wb") as f:
            np.savez(f, __dtypes__=json.dumps(dtypes), **storable)
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def restore(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes are validated)."""
    with np.load(path, allow_pickle=False) as data:
        dtypes = json.loads(str(data["__dtypes__"]))
        flat = {}
        for k in data.files:
            if k == "__dtypes__":
                continue
            arr = data[k]
            if dtypes[k] == "bfloat16":
                arr = arr.view(jnp.bfloat16)
            flat[k] = arr

    leaves_like, treedef = jax.tree_util.tree_flatten_with_path(like)
    new_leaves = []
    for path_keys, leaf in leaves_like:
        key = "/".join(
            str(p.key) if hasattr(p, "key") else str(p.idx) for p in path_keys
        )
        if key not in flat:
            raise KeyError(f"checkpoint missing {key}")
        arr = flat[key]
        if tuple(arr.shape) != tuple(leaf.shape):
            raise ValueError(f"{key}: shape {arr.shape} != {leaf.shape}")
        new_leaves.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), new_leaves
    )
