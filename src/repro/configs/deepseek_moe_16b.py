"""deepseek-moe-16b — 2 shared + 64 routed top-6, fine-grained [arXiv:2401.06066].

28L d_model=2048 16H (MHA kv=16) expert d_ff=1408 vocab=102400.
First layer uses a dense FFN (width 10944); remaining 27 are MoE.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b",
    family="moe",
    source="arXiv:2401.06066",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    d_ff_dense=10_944,
    vocab_size=102_400,
    head_dim=128,
    num_experts=64,
    num_experts_per_tok=6,
    num_shared_experts=2,
    layer_pattern="D" + "M" * 27,
    rope_theta=10_000.0,
    tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=128,
        d_ff_dense=512,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=2,
        num_shared_experts=1,
        layer_pattern="DM",
    )
