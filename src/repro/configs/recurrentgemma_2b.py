"""recurrentgemma-2b — Griffin: RG-LRU + local attention 1:2 [arXiv:2402.19427].

26L d_model=2560 10H (GQA/MQA kv=1) d_ff=7680 vocab=256000, head_dim=256,
block pattern RRA (2 recurrent : 1 local-attention), lru width 2560,
local attention window 2048.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    source="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    sliding_window=2048,
    layer_pattern="RRA",
    rnn_width=2560,
    rnn_conv_width=4,
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=3,  # one full RRA block
        d_model=256,
        num_heads=4,
        num_kv_heads=1,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        sliding_window=64,
        rnn_width=256,
    )
