"""gemma3-4b — 5:1 local:global attention, 128k context [hf:google/gemma-3-1b-pt family].

34L d_model=2560 8H (GQA kv=4) d_ff=10240 vocab=262144, head_dim=256,
sliding window 1024 on local layers, attn pattern LLLLLG (5 local : 1 global).
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-4b",
    family="dense",
    source="hf:google/gemma-3-1b-pt (4b scaling per assignment)",
    num_layers=34,
    d_model=2560,
    num_heads=8,
    num_kv_heads=4,
    d_ff=10_240,
    vocab_size=262_144,
    head_dim=256,
    sliding_window=1024,
    layer_pattern="LLLLLG",
    rope_theta=1_000_000.0,  # global layers; local layers use 10k (model code)
    qk_norm=True,
    attn_logit_softcap=0.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        sliding_window=64,
        layer_pattern="LG",
    )
