"""qwen3-4b — qk-norm, GQA [hf:Qwen/Qwen3-8B family, 4b per assignment].

36L d_model=2560 32H (GQA kv=8) d_ff=9728 vocab=151936, head_dim=128,
qk-norm (RMSNorm on q/k heads), rope theta 1e6.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-4b",
    family="dense",
    source="hf:Qwen/Qwen3-8B (4b per assignment)",
    num_layers=36,
    d_model=2560,
    num_heads=32,
    num_kv_heads=8,
    d_ff=9728,
    vocab_size=151_936,
    head_dim=128,
    qk_norm=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
