"""mamba2-1.3b — SSD (state-space duality) [arXiv:2405.21060].

48L d_model=2048 attn-free, vocab=50280, ssm_state=128.
d_inner = 2*d_model = 4096, ssm heads = 4096/64 = 64.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-1.3b",
    family="ssm",
    source="arXiv:2405.21060",
    num_layers=48,
    d_model=2048,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    head_dim=1,  # unused (attn-free); nonzero to bypass d_model//H
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    conv_width=4,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        vocab_size=512,
        ssm_state=16,
        ssm_head_dim=32,
        ssm_chunk=32,
    )
