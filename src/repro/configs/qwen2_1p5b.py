"""qwen2-1.5b — GQA with QKV bias [arXiv:2407.10671].

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936, head_dim=128,
QKV bias, rope theta 1e6.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-1.5b",
    family="dense",
    source="arXiv:2407.10671",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    head_dim=128,
    qkv_bias=True,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
    )
