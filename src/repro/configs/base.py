"""Architecture + experiment configuration system.

Each assigned architecture lives in ``repro/configs/<id>.py`` and exports a
``CONFIG: ArchConfig`` with the exact published hyper-parameters (source
cited in the file) plus a ``reduced()`` variant for CPU smoke tests.

Input shapes are the four assigned workload shapes; ``decode_*`` shapes
lower ``serve_step`` (single-token decode against a seq_len KV cache/state),
the others lower ``train_step``.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "vlm", "audio"]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    # identity -------------------------------------------------------------
    name: str
    family: Family
    source: str  # citation: arXiv id or HF model card

    # trunk ------------------------------------------------------------------
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // num_heads

    # attention options ------------------------------------------------------
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    sliding_window: int = 0  # 0 -> full attention
    # layer pattern: e.g. gemma3 "LLLLLG" (5 local : 1 global), griffin "RRA".
    # One char per pattern element: L=local attn, G=global attn, R=recurrent,
    # A=(local) attn, X=cross-attn insert, S=self-attn, M=moe, D=dense-ff.
    layer_pattern: str = ""
    attn_logit_softcap: float = 0.0

    # MoE --------------------------------------------------------------------
    num_experts: int = 0
    num_experts_per_tok: int = 0
    num_shared_experts: int = 0
    moe_capacity_factor: float = 1.25
    router_aux_loss: float = 0.01
    d_ff_dense: int = 0  # deepseek: dense FFN width for 'D' pattern layers

    # SSM (mamba2 / SSD) -----------------------------------------------------
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    conv_width: int = 4

    # RG-LRU (griffin/recurrentgemma) -----------------------------------------
    rnn_width: int = 0  # lru width; 0 -> d_model
    rnn_conv_width: int = 4

    # multimodal ---------------------------------------------------------------
    cross_attn_every: int = 0  # vlm: insert a cross-attn layer every N layers
    num_image_tokens: int = 0  # vlm: patch embeddings per image
    num_audio_frames: int = 0  # audio: encoder frames
    encoder_layers: int = 0  # audio: encoder depth (decoder = num_layers)

    # positions: "rope" (default) or "learned" (whisper)
    pos_embed: str = "rope"
    max_position: int = 0  # learned pos table size; 0 -> unused

    # training ---------------------------------------------------------------
    param_dtype: str = "bfloat16"
    cache_dtype: str = "bfloat16"  # KV-cache dtype; fp8 = quantized serving
    tie_embeddings: bool = True
    rms_norm_eps: float = 1e-6
    # remat: "none" | "layer" | "full"; microbatches: grad-accumulation steps
    remat: str = "layer"
    microbatches: int = 1
    # sharding rule set: "default" | "fsdp" (see repro/sharding.py)
    sharding_rules: str = "default"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.family == "hybrid" and self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)

    # -- derived -----------------------------------------------------------

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // max(1, self.num_kv_heads)

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    @property
    def is_subquadratic(self) -> bool:
        """Whether long_500k decode is architecturally supported.

        True for SSM / hybrid archs and for dense archs whose *native* layer
        pattern includes sliding-window local attention (gemma3).  Pure
        full-attention archs skip long_500k (DESIGN.md §Arch-applicability).
        """
        if self.family in ("ssm", "hybrid"):
            return True
        return self.sliding_window > 0 and "L" in self.layer_pattern

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have an AR decoder

    def param_count(self) -> int:
        """Analytic parameter count (approximate; used for 6ND rooflines)."""
        d, L, V = self.d_model, self.num_layers, self.vocab_size
        hd, H, K = self.head_dim, self.num_heads, self.num_kv_heads
        emb = V * d * (1 if self.tie_embeddings else 2)
        if self.family == "ssm":
            di, s = self.d_inner, self.ssm_state
            # in_proj (2*di + 2*groups*s + heads), conv, dt, out_proj
            per_layer = d * (2 * di + 2 * s + self.ssm_heads) + di * d + 3 * di
        else:
            attn = d * H * hd + 2 * d * K * hd + H * hd * d
            if self.family == "moe":
                E = self.num_experts + self.num_shared_experts
                ff = E * 3 * d * self.d_ff + d * self.num_experts
            else:
                ff = 3 * d * self.d_ff
            per_layer = attn + ff
            if self.family == "hybrid":
                # ~2/3 of layers swap attn for an RG-LRU block of similar size
                per_layer = attn + 3 * d * self.d_ff
        n = emb + L * per_layer
        if self.cross_attn_every:
            n += (L // self.cross_attn_every) * (2 * d * H * hd + 2 * d * K * hd)
        if self.encoder_layers:
            n += self.encoder_layers * (4 * d * d + 3 * d * self.d_ff)
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: routed top-k + shared only)."""
        if self.family != "moe":
            return self.param_count()
        d, L = self.d_model, self.num_layers
        E_active = self.num_experts_per_tok + self.num_shared_experts
        attn = d * self.num_heads * self.head_dim * 2 + 2 * d * self.num_kv_heads * self.head_dim
        ff_active = E_active * 3 * d * self.d_ff
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        return emb + L * (attn + ff_active)


@dataclasses.dataclass(frozen=True)
class ReplayConfig:
    """Device-resident trajectory replay (repro/replay/).

    ``capacity`` and ``sample_batch_size`` are *global* counts; the Sebulba
    learner mesh shards both evenly across its cores, so each must divide by
    the learner count.  ``prioritized`` switches uniform -> PER sampling
    (Schaul et al. 2016): draws proportional to ``p^priority_exponent``,
    bias-corrected by ``(size * P(i))^-importance_exponent`` weights.

    ``importance_anneal_updates`` enables the original PER recipe's beta
    schedule: the importance exponent anneals linearly from
    ``importance_exponent`` to 1.0 (full bias correction) over that many
    learner updates, computed on device inside the fused off-policy step
    (``importance_beta``); 0 keeps beta fixed.

    Recurrent agents (R2D2): no extra replay config is needed — the
    per-sequence stored state (``Trajectory.init_carry``) is an ordinary
    trajectory leaf, so each ring slot carries it automatically and
    sampled sequences replay from the actor's recorded state; burn-in is
    the learner-side ``SebulbaConfig.burn_in`` (see ARCHITECTURE.md
    §Recurrent agents).
    """

    capacity: int = 4096  # trajectory slots across all learner shards
    sample_batch_size: int = 32  # replay trajectories drawn per update
    min_size: int = 256  # warmup: inserts only until this many slots filled
    prioritized: bool = True
    priority_exponent: float = 0.6  # PER alpha
    importance_exponent: float = 0.4  # PER beta (the t=0 value when annealed)
    importance_anneal_updates: int = 0  # 0 -> fixed beta
    priority_epsilon: float = 1e-3  # floor so no slot starves

    def __post_init__(self):
        if self.capacity < self.sample_batch_size:
            raise ValueError("replay capacity must cover one sample batch")
        if self.min_size > self.capacity:
            raise ValueError("replay min_size cannot exceed capacity")
        if self.min_size < 1:
            raise ValueError(
                "replay min_size must be >= 1: warmup must insert at least "
                "once before sampling (an empty ring samples NaN probs)"
            )
        if self.importance_anneal_updates < 0:
            raise ValueError("importance_anneal_updates must be >= 0")
        if not 0.0 <= self.importance_exponent <= 1.0:
            raise ValueError(
                "importance_exponent (PER beta) must lie in [0, 1]: it is "
                "the t=0 point of an anneal that ends at 1.0"
            )

    def importance_beta(self, update_idx):
        """PER beta at learner update ``update_idx`` (int or traced scalar).

        Linear anneal ``importance_exponent -> 1.0`` over
        ``importance_anneal_updates`` updates, clamped at 1.0 after; with
        annealing disabled this is the constant ``importance_exponent`` (so
        callers can thread it through jit unconditionally).
        """
        beta0 = self.importance_exponent
        if self.importance_anneal_updates <= 0:
            return beta0
        import jax.numpy as jnp

        frac = jnp.minimum(
            jnp.asarray(update_idx, jnp.float32)
            / self.importance_anneal_updates,
            1.0,
        )
        return beta0 + (1.0 - beta0) * frac


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: Literal["train", "prefill", "decode"]


INPUT_SHAPES: dict[str, InputShape] = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}

ARCH_IDS = [
    "mamba2_1p3b",
    "gemma3_4b",
    "recurrentgemma_2b",
    "granite_moe_1b",
    "llama3_405b",
    "deepseek_moe_16b",
    "qwen2_1p5b",
    "llama32_vision_11b",
    "whisper_medium",
    "qwen3_4b",
]

# CLI aliases matching the assignment table exactly.
ALIASES = {
    "mamba2-1.3b": "mamba2_1p3b",
    "gemma3-4b": "gemma3_4b",
    "recurrentgemma-2b": "recurrentgemma_2b",
    "granite-moe-1b-a400m": "granite_moe_1b",
    "llama3-405b": "llama3_405b",
    "deepseek-moe-16b": "deepseek_moe_16b",
    "qwen2-1.5b": "qwen2_1p5b",
    "llama-3.2-vision-11b": "llama32_vision_11b",
    "whisper-medium": "whisper_medium",
    "qwen3-4b": "qwen3_4b",
}


def get_config(arch: str) -> ArchConfig:
    arch = ALIASES.get(arch, arch)
    if arch not in ARCH_IDS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.CONFIG


def get_reduced_config(arch: str) -> ArchConfig:
    arch = ALIASES.get(arch, arch)
    mod = importlib.import_module(f"repro.configs.{arch}")
    return mod.reduced()


def all_configs() -> dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS}
