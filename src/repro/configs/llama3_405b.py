"""llama3-405b — GQA, 128k vocab [arXiv:2407.21783].

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256, head_dim=128,
rope theta 500k.  Uses FSDP sharding rules + microbatching so that params +
optimizer state + activations fit the production mesh (DESIGN.md §5).
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama3-405b",
    family="dense",
    source="arXiv:2407.21783",
    num_layers=126,
    d_model=16_384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53_248,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    tie_embeddings=False,
    sharding_rules="fsdp",
    remat="layer",
    microbatches=16,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=8,
        num_kv_heads=4,
        head_dim=32,
        d_ff=1024,
        vocab_size=512,
        sharding_rules="default",
        microbatches=1,
    )
