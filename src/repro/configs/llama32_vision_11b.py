"""llama-3.2-vision-11b — cross-attn image layers [hf:meta-llama/Llama-3.2-11B-Vision].

Language backbone only (per assignment): 40L d_model=4096 32H (GQA kv=8)
d_ff=14336 vocab=128256, a cross-attention layer inserted every 5th layer.
The ViT vision encoder is a STUB — input_specs() provides precomputed patch
embeddings (6404 = 4 tiles x 1601 patches) of width d_model.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="llama-3.2-vision-11b",
    family="vlm",
    source="hf:meta-llama/Llama-3.2-11B-Vision",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14_336,
    vocab_size=128_256,
    head_dim=128,
    rope_theta=500_000.0,
    cross_attn_every=5,
    num_image_tokens=6404,
    tie_embeddings=False,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        cross_attn_every=2,
        num_image_tokens=16,
    )
