"""granite-moe-1b-a400m — 32 experts top-8 [hf:ibm-granite/granite-3.0-1b-a400m-base].

24L d_model=1024 16H (GQA kv=8) d_ff=512 (per expert) vocab=49155,
MoE 32 experts top-8, fine-grained experts.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="granite-moe-1b-a400m",
    family="moe",
    source="hf:ibm-granite/granite-3.0-1b-a400m-base",
    num_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=8,
    d_ff=512,
    vocab_size=49_155,
    head_dim=64,
    num_experts=32,
    num_experts_per_tok=8,
    num_shared_experts=0,
    layer_pattern="M",
    rope_theta=10_000.0,
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=2,
        head_dim=64,
        d_ff=128,
        vocab_size=512,
        num_experts=4,
        num_experts_per_tok=2,
    )
