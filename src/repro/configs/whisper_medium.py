"""whisper-medium — encoder-decoder, conv frontend stubbed [arXiv:2212.04356].

24L encoder + 24L decoder, d_model=1024 16H (MHA kv=16) d_ff=4096
vocab=51865.  The mel-spectrogram + conv feature extractor is a STUB —
input_specs() provides 1500 precomputed frame embeddings.  Positions are
learned-absolute (as in the paper's decoder); the real decoder context is
448, noted in DESIGN.md — decode shapes are applied mechanically per the
assignment.
"""

import dataclasses

from repro.configs.base import ArchConfig

CONFIG = ArchConfig(
    name="whisper-medium",
    family="audio",
    source="arXiv:2212.04356",
    num_layers=24,  # decoder depth
    encoder_layers=24,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=51_865,
    head_dim=64,
    num_audio_frames=1500,
    pos_embed="learned",
    max_position=524_288,  # sized for the assigned decode shapes
    tie_embeddings=True,
)


def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG,
        num_layers=2,
        encoder_layers=2,
        d_model=256,
        num_heads=4,
        num_kv_heads=4,
        head_dim=64,
        d_ff=512,
        vocab_size=512,
        num_audio_frames=32,
        max_position=4096,
    )
