"""Lease-based host membership over a shared filesystem (ISSUE 8).

A TPU pod is many hosts stitched together by fast interconnect; the
elastic Sebulba treats that fleet the way a cloud scheduler does —
hosts join, get preempted, and rejoin mid-run, and training must keep
going.  This module is the membership layer: who is alive *right now*,
and which membership **epoch** the fleet is in.

Durability idiom (same as ``repro/checkpoint``): every write is an
atomic ``os.replace`` of a fully-written temp file, so a crashed host
never leaves a torn lease behind — it simply stops renewing, and its
lease **expires**.  There is no coordinator and no delete-on-death
protocol: death is the absence of renewal.

  * **Lease** — ``lease_<host>.json`` holds ``{host_id, expires}``.
    ``announce``/``renew`` stamp ``expires = now + ttl``; a host whose
    stamp is in the past is dead.  Preemption, SIGKILL, and a wedged
    process all look identical: the lease runs out.
  * **Epoch** — ``epoch.json`` records ``{epoch, hosts}``, the last
    membership anyone observed.  ``sync`` compares the live set against
    it and bumps the epoch (atomically) when they differ.  Concurrent
    bumps are safe: the record content is a pure function of the live
    set, so racing writers of the *same* change are idempotent, and a
    lost race over *different* changes is reconciled by the next
    ``sync`` (the epoch is monotone once membership is stable for a
    TTL).  Every consumer of the epoch must tolerate one extra bump,
    never a missed change.

Shard placement is a **pure function of (epoch, world_size)** —
``shard_assignment``/``owner_rank`` below — so every host computes the
same post-reshard layout from the epoch number alone, with zero
coordination messages.  That is the reshard invariant the routing layer
(repro/distributed/routing.py) and the chaos tests pin.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
import time

import numpy as np


@dataclasses.dataclass(frozen=True)
class Membership:
    """One observed membership: the epoch and the sorted live host set.

    ``hosts`` is sorted, so ``rank`` (a host's index) is the same on
    every host that observes this epoch — ranks are derived, never
    assigned.
    """

    epoch: int
    hosts: tuple[str, ...]

    @property
    def world_size(self) -> int:
        return len(self.hosts)

    def rank(self, host_id: str) -> int:
        """This host's rank at this epoch; raises KeyError for a host
        that is not (or no longer) a member — the caller is stale and
        must re-``sync``."""
        try:
            return self.hosts.index(host_id)
        except ValueError:
            raise KeyError(
                f"{host_id!r} is not a member at epoch {self.epoch} "
                f"(live: {list(self.hosts)})"
            ) from None


def _atomic_write_json(path: str, payload: dict) -> None:
    """Serialize fully, write to a unique same-directory temp file, then
    ``os.replace`` — the checkpoint durability idiom.  The temp name
    embeds the pid so concurrent writers (many hosts, one directory)
    can never collide on the staging file."""
    directory = os.path.dirname(path) or "."
    os.makedirs(directory, exist_ok=True)
    fd, tmp = tempfile.mkstemp(
        dir=directory, prefix=f".{os.path.basename(path)}-{os.getpid()}-",
        suffix=".tmp",
    )
    try:
        with os.fdopen(fd, "w") as f:
            json.dump(payload, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, path)
    except BaseException:
        if os.path.exists(tmp):
            os.unlink(tmp)
        raise


def _read_json(path: str) -> dict | None:
    """None for missing or torn files — a reader racing ``os.replace``
    never sees a partial write, but a crashed pre-durability writer (or
    stray debris) must read as absent, not raise."""
    try:
        with open(path) as f:
            return json.load(f)
    except (FileNotFoundError, json.JSONDecodeError, OSError):
        return None


class HostRegistry:
    """Host membership over one shared directory.

    Every host (and any pure observer, e.g. a bench parent process)
    opens a ``HostRegistry`` on the same path.  Hosts ``announce`` once
    and ``renew`` at least every ``ttl / 3``; anyone may ``sync`` to
    observe the live set and advance the epoch record.
    """

    def __init__(self, directory: str, *, ttl: float = 2.0):
        if ttl <= 0:
            raise ValueError("lease ttl must be > 0")
        self.directory = directory
        self.ttl = ttl
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------- leases

    def _lease_path(self, host_id: str) -> str:
        return os.path.join(self.directory, f"lease_{host_id}.json")

    def announce(self, host_id: str, *, now: float | None = None) -> None:
        """Write (or refresh) ``host_id``'s lease: alive until
        ``now + ttl`` unless renewed."""
        if "/" in host_id or host_id != host_id.strip() or not host_id:
            raise ValueError(f"invalid host id {host_id!r}")
        now = time.time() if now is None else now
        _atomic_write_json(
            self._lease_path(host_id),
            {"host_id": host_id, "expires": now + self.ttl},
        )

    renew = announce  # renewal IS re-announcement: one idempotent write

    def expire(self, host_id: str, *, now: float | None = None) -> None:
        """Fast-forward a lease to already-expired — equivalent to the
        TTL elapsing without renewal, without waiting wall-clock for it.
        This is the *simulated crash* surface (SimulatedPeerHost.crash):
        it keeps seeded chaos runs step-deterministic, and unlike
        ``retire`` it leaves the (expired) lease file behind exactly as
        a SIGKILLed host would."""
        now = time.time() if now is None else now
        _atomic_write_json(
            self._lease_path(host_id),
            {"host_id": host_id, "expires": now - self.ttl},
        )

    def retire(self, host_id: str) -> None:
        """Graceful leave: drop the lease now instead of waiting a TTL.
        Missing leases are fine — retiring twice (or after a crash
        already expired you) is a no-op."""
        try:
            os.unlink(self._lease_path(host_id))
        except FileNotFoundError:
            pass

    def live_hosts(self, now: float | None = None) -> tuple[str, ...]:
        """Sorted ids of every host whose lease has not expired."""
        now = time.time() if now is None else now
        live = []
        for name in os.listdir(self.directory):
            if not (name.startswith("lease_") and name.endswith(".json")):
                continue
            lease = _read_json(os.path.join(self.directory, name))
            if lease and float(lease.get("expires", 0.0)) > now:
                live.append(str(lease["host_id"]))
        return tuple(sorted(live))

    # -------------------------------------------------------------- epoch

    @property
    def _epoch_path(self) -> str:
        return os.path.join(self.directory, "epoch.json")

    def current(self) -> Membership:
        """The last recorded membership (epoch 0, empty, before any
        ``sync`` has run)."""
        rec = _read_json(self._epoch_path)
        if rec is None:
            return Membership(epoch=0, hosts=())
        return Membership(
            epoch=int(rec["epoch"]), hosts=tuple(rec["hosts"])
        )

    def sync(self, now: float | None = None) -> Membership:
        """Observe the live set and advance the epoch record if it
        changed.  Any participant may call this; racing writers of the
        same change write identical records (idempotent), and a lost
        race over different changes is reconciled by the next sync."""
        live = self.live_hosts(now)
        rec = self.current()
        if live == rec.hosts:
            return rec
        bumped = Membership(epoch=rec.epoch + 1, hosts=live)
        _atomic_write_json(
            self._epoch_path,
            {"epoch": bumped.epoch, "hosts": list(bumped.hosts)},
        )
        return bumped


# -------------------------------------------------- pure shard placement


def stable_hash(seq_id: int | str) -> int:
    """Process- and host-independent hash for routing keys.  Python's
    builtin ``hash`` is salted per process — two hosts would route the
    same sequence to different owners."""
    digest = hashlib.blake2b(
        str(seq_id).encode(), digest_size=8
    ).digest()
    return int.from_bytes(digest, "big")


def shard_assignment(epoch: int, world_size: int) -> tuple[int, ...]:
    """The epoch's shard layout: a permutation of ``range(world_size)``
    that is a pure function of ``(epoch, world_size)`` — every host
    derives the identical layout from the epoch number alone.  The
    permutation re-deals ownership each epoch so a membership change
    spreads the resharded load instead of shifting every key by one."""
    if world_size <= 0:
        raise ValueError("shard_assignment needs world_size >= 1")
    rng = np.random.default_rng(np.uint64(epoch) * np.uint64(0x9E3779B9))
    return tuple(int(r) for r in rng.permutation(world_size))


def owner_rank(seq_id: int | str, epoch: int, world_size: int) -> int:
    """Which member rank owns ``seq_id`` at this epoch.  Pure function
    of ``(seq_id, epoch, world_size)``: inserts route here, sampling
    fans from here, and a reshard is just re-evaluating this map under
    the bumped epoch."""
    perm = shard_assignment(epoch, world_size)
    return perm[stable_hash(seq_id) % world_size]
