"""Cross-host replay routing for the elastic Sebulba (ISSUE 8).

``repro/replay/sharded.py`` shards the ring across the learner cores
*inside* one host; this layer makes the shard set process-count-agnostic
across **hosts**.  Each live host owns one ring shard
(``repro.replay.buffer.ReplayState``), and three operations keep the
global buffer coherent as membership changes:

  * **insert** routes every sequence to its owner's shard by hashing the
    sequence id through the epoch's pure placement map
    (``registry.owner_rank``) — two hosts inserting the same id at the
    same epoch agree on the owner with zero coordination;
  * **sample** fans the draw across the live shards (per-shard RNG is
    the caller's key folded with the shard rank, so the whole draw is a
    pure deterministic function of ``(state, key)`` — bit-exact within
    an epoch) and re-normalizes the PER statistics over the *surviving*
    shard set: selection probabilities are scaled by each shard's draw
    allocation, and importance weights use the global valid-slot count,
    so losing a host re-weights what remains instead of training on
    stale per-shard normalizers;
  * **reshard** is the epoch-bump transition: items on surviving shards
    are re-routed under the new epoch's placement map in deterministic
    (sorted-id) order; items that lived only on a dead host are lost and
    counted.  Running the same reshard on two hosts produces
    bit-identical shard states — the invariant that lets every host
    reshard locally instead of electing a coordinator.

Every operation takes the caller's ``epoch`` and raises
:class:`StaleEpochError` on mismatch — the epoch check is the contract
that no insert or sample ever crosses a membership change unnoticed
(the caller reshard-then-retries).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.registry import Membership, owner_rank
from repro.replay import buffer
from repro.replay.sharded import (
    global_importance_weights,
    renormalize_probs,
)

PyTree = Any


class StaleEpochError(RuntimeError):
    """The caller's membership epoch is behind (or ahead of) the replay
    layer's — a membership change happened between the caller's last
    ``sync`` and this operation.  Reshard to the current membership and
    retry; silently proceeding would route sequences with the wrong
    placement map."""


class _Shard:
    """One host's ring: the device ``ReplayState`` plus the host-side
    sequence-id line (``ids[slot]``) the reshard re-routes by."""

    def __init__(self, state: buffer.ReplayState):
        self.state = state
        self.ids = np.full((state.capacity,), -1, np.int64)

    @property
    def size(self) -> int:
        return int(buffer.size(self.state))


class DistributedReplay:
    """Host-level routing over per-host replay ring shards.

    This is deliberately a *host-side orchestration* layer: each shard's
    storage stays a device-resident ``ReplayState`` (on the owning
    host's learner mesh in a real deployment), and the routing math —
    ownership, draw allocation, PER re-normalization — is cheap host
    arithmetic that never touches the donated update paths.
    """

    def __init__(
        self,
        capacity_per_host: int,
        *,
        prioritized: bool = False,
        priority_exponent: float = 0.6,
    ):
        if capacity_per_host <= 0:
            raise ValueError("capacity_per_host must be >= 1")
        self.capacity_per_host = capacity_per_host
        self.prioritized = prioritized
        self.priority_exponent = priority_exponent
        self._example: PyTree | None = None
        self._shards: dict[str, _Shard] = {}
        self.membership: Membership | None = None
        self.sequences_lost = 0  # cumulative, across every reshard

    # ------------------------------------------------------------- setup

    def attach(self, membership: Membership, example: PyTree) -> None:
        """Bind to a membership and allocate one empty shard per live
        host.  ``example`` is any pytree with a leading batch dim (one
        slot stores one batch element, as in ``replay.buffer.init``)."""
        if membership.world_size == 0:
            raise ValueError("cannot attach to an empty membership")
        self._example = example
        self.membership = membership
        self._shards = {
            host: _Shard(buffer.init(example, self.capacity_per_host))
            for host in membership.hosts
        }

    def _require_attached(self) -> Membership:
        if self.membership is None:
            raise RuntimeError(
                "DistributedReplay is not attached: call "
                "attach(membership, example) first (it allocates the "
                "per-host shards)"
            )
        return self.membership

    def _check_epoch(self, epoch: int, op: str) -> Membership:
        m = self._require_attached()
        if epoch != m.epoch:
            raise StaleEpochError(
                f"{op} at epoch {epoch} but the replay shards are laid "
                f"out for epoch {m.epoch}: a membership change happened "
                "— reshard(new_membership) and retry with the current "
                "epoch"
            )
        return m

    # ------------------------------------------------------------- state

    def size(self) -> int:
        """Valid slots across the surviving shard set — the global N
        that PER importance weights normalize against."""
        self._require_attached()
        return sum(s.size for s in self._shards.values())

    def sizes(self) -> dict[str, int]:
        return {host: s.size for host, s in self._shards.items()}

    def _global_max_priority(self) -> float:
        """Cross-shard max — the distributed analogue of
        ``buffer.insert``'s ``axis_name`` pmax: fresh sequences enter at
        the same default priority no matter which host's shard they land
        on."""
        mx = 0.0
        for s in self._shards.values():
            mx = max(mx, float(jnp.max(s.state.priorities)))
        return mx if mx > 0.0 else 1.0

    # ------------------------------------------------------------ insert

    def insert(
        self,
        seq_ids,
        batch: PyTree,
        *,
        epoch: int,
        priorities=None,
    ) -> None:
        """Route each sequence to its owner's shard and insert locally.

        ``seq_ids`` must be globally unique ints (callers derive them
        from monotone per-actor counters); ownership is
        ``owner_rank(id, epoch, world_size)`` — pure, coordination-free.
        New sequences default to the cross-shard max priority.
        """
        m = self._check_epoch(epoch, "insert")
        seq_ids = np.asarray(seq_ids, np.int64)
        leaves = jax.tree.leaves(batch)
        if seq_ids.shape[0] != leaves[0].shape[0]:
            raise ValueError(
                f"{seq_ids.shape[0]} sequence ids for a batch of "
                f"{leaves[0].shape[0]}"
            )
        if priorities is None:
            default_p = self._global_max_priority()
            priorities = np.full((len(seq_ids),), default_p, np.float32)
        else:
            priorities = np.asarray(priorities, np.float32)
        owners = np.array(
            [owner_rank(int(i), m.epoch, m.world_size) for i in seq_ids],
            np.int64,
        )
        cap = self.capacity_per_host
        for rank, host in enumerate(m.hosts):
            rows = np.nonzero(owners == rank)[0]
            if rows.size == 0:
                continue
            shard = self._shards[host]
            # chunk to the ring capacity: a reshard into fewer hosts (or
            # a hot hash bucket) can route more than one ring's worth to
            # a single shard in one call — ring semantics, the newest
            # writes survive
            for lo in range(0, rows.size, cap):
                part = rows[lo:lo + cap]
                sub = jax.tree.map(lambda x: x[part], batch)  # noqa: B023
                slots = np.asarray(
                    buffer.insert_slots(shard.state, part.size)
                )
                shard.state = buffer.insert(
                    shard.state, sub, priorities[part]
                )
                shard.ids[slots] = seq_ids[part]

    # ------------------------------------------------------------ sample

    def _allocation(self, batch_size: int, m: Membership) -> list[tuple]:
        """Deterministic draw allocation over the NON-EMPTY live shards:
        even split, remainder to the lowest ranks.  (A freshly joined
        host's empty shard contributes nothing until inserts reach it —
        sampling must not stall on it.)"""
        nonempty = [
            (host, self._shards[host]) for host in m.hosts
            if self._shards[host].size > 0
        ]
        if not nonempty:
            raise ValueError(
                "sample from an empty distributed replay: no shard "
                "holds a valid slot yet (insert before sampling, or "
                "gate on size() as Sebulba gates on min_size)"
            )
        k = len(nonempty)
        base, extra = divmod(batch_size, k)
        return [
            (host, shard, base + (1 if i < extra else 0))
            for i, (host, shard) in enumerate(nonempty)
        ]

    def sample(self, rng: jax.Array, batch_size: int, *, epoch: int):
        """Fan a ``batch_size`` draw across the live shards.

        Returns ``(batch, parts, probs)``:

          * ``batch`` — the concatenated sampled pytree;
          * ``parts`` — ``[(host, local_idx), ...]`` in draw order, the
            routing record ``update_priorities`` consumes;
          * ``probs`` — **globally re-normalized** per-draw selection
            probabilities: each shard's local probability scaled by the
            fraction of the draw allocated to that shard, so the PER
            correction sees one coherent distribution over the
            surviving shard set.

        Per-shard keys fold the shard's member rank into the caller's
        key: the whole draw is a pure function of ``(state, rng)`` —
        bit-exact within an epoch, re-dealt (deterministically) by the
        epoch bump.
        """
        if batch_size <= 0:
            raise ValueError("sample batch_size must be >= 1")
        m = self._check_epoch(epoch, "sample")
        parts: list[tuple[str, np.ndarray]] = []
        batches, probs = [], []
        for host, shard, alloc in self._allocation(batch_size, m):
            if alloc == 0:
                continue
            key = jax.random.fold_in(rng, m.rank(host))
            sub, idx, p_local = buffer.sample(
                shard.state, key, alloc,
                prioritized=self.prioritized,
                priority_exponent=self.priority_exponent,
            )
            batches.append(sub)
            parts.append((host, np.asarray(idx)))
            probs.append(
                renormalize_probs(np.asarray(p_local), alloc, batch_size)
            )
        batch = jax.tree.map(
            lambda *xs: jnp.concatenate(xs, axis=0), *batches
        )
        return batch, parts, np.concatenate(probs)

    def importance_weights(self, probs, beta: float) -> np.ndarray:
        """PER bias correction over the surviving shard set:
        ``(N_global * P(i))^-beta`` normalized by the batch max — the
        cross-host analogue of ``losses.per_importance_weights`` with
        the global size and globally re-normalized probabilities."""
        return global_importance_weights(probs, self.size(), beta)

    def update_priorities(self, parts, new_priorities) -> None:
        """Write fresh TD priorities back through the routing record
        ``sample`` returned (same draw order)."""
        self._require_attached()
        new_priorities = np.asarray(new_priorities, np.float32)
        start = 0
        for host, idx in parts:
            stop = start + len(idx)
            self._shards[host].state = buffer.update_priorities(
                self._shards[host].state, jnp.asarray(idx),
                new_priorities[start:stop],
            )
            start = stop
        if start != len(new_priorities):
            raise ValueError(
                f"{len(new_priorities)} priorities for {start} routed draws"
            )

    # ----------------------------------------------------------- reshard

    def _valid_items(self, shard: _Shard):
        """(seq_id, row pytree, priority) for every valid slot, oldest
        insert order — the ring's first ``size`` slots by cursor
        arithmetic."""
        n = shard.size
        if n == 0:
            return []
        cap = shard.state.capacity
        if int(shard.state.total_added) <= cap:
            slots = np.arange(n)
        else:  # wrapped: every slot valid, order irrelevant (sorted later)
            slots = np.arange(cap)
        pri = np.asarray(shard.state.priorities)
        return [
            (
                int(shard.ids[s]),
                jax.tree.map(lambda x: x[int(s)], shard.state.storage),
                float(pri[int(s)]),
            )
            for s in slots
        ]

    def reshard(self, new_membership: Membership) -> dict:
        """The membership-epoch transition: rebuild the shard set for
        ``new_membership`` and re-route every surviving sequence under
        the new epoch's placement map.

        Deterministic by construction — items are re-inserted in sorted
        sequence-id order through the pure ownership map, so every host
        running this reshard from the same surviving shards produces
        bit-identical new shards (no coordinator, no transfer protocol
        to agree on).  Sequences whose only copy lived on a lost host
        are gone; they are counted, not resurrected.

        Returns ``{"migrated", "lost", "hosts_lost", "hosts_joined"}``.
        """
        old = self._require_attached()
        if new_membership.epoch == old.epoch:
            return {
                "migrated": 0, "lost": 0,
                "hosts_lost": (), "hosts_joined": (),
            }
        if new_membership.world_size == 0:
            raise ValueError(
                "cannot reshard to an empty membership: the last host "
                "standing keeps its shard (and this host is still "
                "running, so at least it is alive)"
            )
        survivors = [h for h in old.hosts if h in new_membership.hosts]
        items = []
        lost = 0
        for host, shard in self._shards.items():
            if host in new_membership.hosts:
                items.extend(self._valid_items(shard))
            else:
                lost += shard.size
        items.sort(key=lambda it: it[0])

        self.attach(new_membership, self._example)
        if items:
            ids = [it[0] for it in items]
            batch = jax.tree.map(
                lambda *xs: jnp.stack(xs), *[it[1] for it in items]
            )
            pri = np.array([it[2] for it in items], np.float32)
            # re-insert routes by the NEW epoch's pure ownership map;
            # chunk by owner inside insert() as usual
            self.insert(
                ids, batch, epoch=new_membership.epoch, priorities=pri
            )
        self.sequences_lost += lost
        return {
            "migrated": len(items),
            "lost": lost,
            "hosts_lost": tuple(
                h for h in old.hosts if h not in new_membership.hosts
            ),
            "hosts_joined": tuple(
                h for h in new_membership.hosts
                if h not in old.hosts or h not in survivors
            ),
        }
