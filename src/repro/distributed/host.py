"""Host-tier supervision: PR 7's slot supervision, one level up (ISSUE 8).

``ActorSupervisor`` keeps one host alive through actor crashes; this
module keeps the *fleet* alive through host crashes.  The analogy is
exact — each worker process is a supervised slot, and the state machine
mirrors the actor tier::

    running -> (crash / preempt) lease expires -> LOST
            -> surviving hosts observe the epoch bump, reshard, and keep
               training at reduced throughput        (graceful degradation,
                                                      = actor quarantine)
    lost -> (rejoin) re-announce lease -> epoch bump -> RUNNING again,
            restored from the newest VALID checkpoint stamp
                                                      (= actor restart)

The difference from the actor tier is the failure detector: threads in
one process can be reaped directly, but a preempted *host* just goes
silent.  Detection is therefore the lease (repro/distributed/registry.py)
— death is the absence of renewal — and every membership transition is
announced to the survivors as an **epoch bump**, which is the signal
Sebulba's learner loop polls (``cluster.poll``) to force a param
republish and a deterministic replay reshard.

Two classes:

  * :class:`SimulatedPeerHost` — an in-process stand-in for a peer
    host's *membership behaviour* (announce / renew / crash / preempt /
    rejoin).  It drives the same lease files a real worker process
    writes, so single-process chaos tests and the ``--hosts N`` example
    exercise the identical detection path the multi-process bench does.
    On rejoin it restores from the newest valid checkpoint stamp —
    the PR 7 ``auto_resume`` contract, now a membership event.
  * :class:`HostSupervisor` — the per-host membership agent Sebulba
    mounts as ``cluster=``: renews this host's own lease from a
    heartbeat thread, fires seeded host-level FaultPlan events at their
    learner steps, and surfaces epoch bumps (with joined/lost/reshard
    accounting) to the learner loop.
"""

from __future__ import annotations

import threading
import time

from repro.distributed.registry import HostRegistry, Membership


class SimulatedPeerHost:
    """An in-process peer: a lease-renewal loop with fault hooks.

    The simulation is of the peer's *membership* behaviour only — it
    generates no trajectories.  What it proves is the detection and
    recovery path: a crashed peer's lease expires exactly as a
    SIGKILLed worker's would (``HostRegistry.expire`` fast-forwards the
    TTL so seeded chaos stays step-deterministic instead of
    wall-clock-bound), a preempted peer retires its lease (the graceful
    SIGTERM path), and a rejoining peer re-announces and records the
    checkpoint stamp it would restore from — the newest VALID one, via
    the PR 7 fallback scan.
    """

    def __init__(
        self,
        registry: HostRegistry,
        host_id: str,
        *,
        checkpoint_dir: str | None = None,
    ):
        self.registry = registry
        self.host_id = host_id
        self.checkpoint_dir = checkpoint_dir
        self.state = "new"  # new -> running -> crashed/preempted -> running
        self.resumed_from: str | None = None  # stamp path of the last rejoin
        self.rejoins = 0
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    def _renew_loop(self) -> None:
        interval = self.registry.ttl / 3.0
        while not self._stop.wait(interval):
            self.registry.renew(self.host_id)

    def start(self) -> None:
        if self.state == "running":
            return
        self.registry.announce(self.host_id)
        self._stop = threading.Event()
        self._thread = threading.Thread(
            target=self._renew_loop, daemon=True,
            name=f"peer-{self.host_id}",
        )
        self._thread.start()
        self.state = "running"

    def _halt_renewal(self) -> None:
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.registry.ttl)
            self._thread = None

    def crash(self) -> None:
        """SIGKILL / hard preemption: renewal simply stops and the lease
        runs out.  (Fast-forwarded so the epoch bump lands on the next
        sync, not a TTL later.)"""
        self._halt_renewal()
        self.registry.expire(self.host_id)
        self.state = "crashed"

    def preempt(self) -> None:
        """Graceful preemption (SIGTERM with time to say goodbye): the
        lease is retired immediately instead of expiring."""
        self._halt_renewal()
        self.registry.retire(self.host_id)
        self.state = "preempted"

    def rejoin(self) -> None:
        """Come back: restore from the newest VALID checkpoint stamp
        (recording which), re-announce the lease, resume renewing.  The
        next ``sync`` observes the join and bumps the epoch."""
        if self.state == "running":
            return
        if self.checkpoint_dir is not None:
            from repro import api  # lazy: api never imports distributed

            self.resumed_from = api.newest_valid_checkpoint(
                self.checkpoint_dir
            )
        self.rejoins += 1
        self.start()

    def stop(self) -> None:
        self._halt_renewal()
        if self.state == "running":
            self.registry.retire(self.host_id)
        self.state = "stopped"


class HostSupervisor:
    """This host's membership agent — Sebulba's ``cluster=`` mount.

    Owns three things:

      * **self-preservation**: announces this host's lease at ``start``
        and renews it from a daemon heartbeat thread every ``ttl / 3``
        (the host-tier analogue of ``ActorHandle.beat``);
      * **chaos**: seeded host-level FaultPlan events
        (``host_crash`` / ``host_preempt`` / ``host_rejoin``) fire on
        the in-process :class:`SimulatedPeerHost` fleet at their
        scheduled *learner steps*, driven by ``poll(step)`` — the
        host-tier mirror of PR 7's per-slot actor injectors;
      * **observation**: ``poll`` syncs the registry and, when the
        membership epoch bumped, returns the new :class:`Membership`
        (otherwise ``None``) while accounting ``hosts_joined`` /
        ``hosts_lost`` / ``reshards`` — the counters the unified result
        schema reports.

    ``poll`` is learner-driven like ``ActorSupervisor.poll``: no extra
    monitor thread beyond the lease heartbeat, and the learner reacts to
    a returned membership by republishing params and resharding replay.
    """

    def __init__(
        self,
        directory: str,
        host_id: str = "host0",
        *,
        ttl: float = 2.0,
        peers: tuple[str, ...] = (),
        fault_plan=None,
        checkpoint_dir: str | None = None,
    ):
        self.registry = HostRegistry(directory, ttl=ttl)
        self.host_id = host_id
        self.peers = {
            pid: SimulatedPeerHost(
                self.registry, pid, checkpoint_dir=checkpoint_dir
            )
            for pid in peers
        }
        if host_id in self.peers:
            raise ValueError(
                f"host id {host_id!r} cannot also be a simulated peer"
            )
        self._injector = (
            fault_plan.host_injector() if fault_plan is not None else None
        )
        self.membership: Membership | None = None
        self.hosts_joined = 0
        self.hosts_lost = 0
        self.reshards = 0
        self._started = False
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None

    @property
    def epoch(self) -> int:
        return self.membership.epoch if self.membership is not None else 0

    @property
    def world_size(self) -> int:
        return (
            self.membership.world_size if self.membership is not None else 0
        )

    # ----------------------------------------------------------- lifecycle

    def _renew_loop(self) -> None:
        interval = self.registry.ttl / 3.0
        while not self._stop.wait(interval):
            self.registry.renew(self.host_id)

    def start(self) -> Membership:
        """Announce this host (and its simulated peers), start the lease
        heartbeat, and record the baseline membership.  Idempotent — the
        bench workers start their supervisor before handing it to
        Sebulba, which starts it again."""
        if self._started:
            return self.membership
        self.registry.announce(self.host_id)
        for peer in self.peers.values():
            peer.start()
        self._thread = threading.Thread(
            target=self._renew_loop, daemon=True,
            name=f"host-lease-{self.host_id}",
        )
        self._thread.start()
        # the baseline epoch: joins/losses are counted as deltas from
        # here, so bringing the fleet up is not itself a "reshard"
        self.membership = self.registry.sync()
        self._started = True
        return self.membership

    def poll(self, step: int) -> Membership | None:
        """One learner-loop tick: fire due host chaos, observe the live
        set, and return the new :class:`Membership` iff the epoch
        bumped (the learner's republish-and-reshard signal)."""
        if not self._started:
            raise RuntimeError(
                "HostSupervisor.poll before start(): call start() (or let "
                "Sebulba.run do it) so the baseline membership exists"
            )
        if self._injector is not None:
            for event in self._injector.due(step):
                peer = self.peers.get(event.target.partition(":")[2])
                if peer is None:
                    continue  # event targets a host this process doesn't own
                if event.kind == "host_crash":
                    peer.crash()
                elif event.kind == "host_preempt":
                    peer.preempt()
                elif event.kind == "host_rejoin":
                    peer.rejoin()
        current = self.registry.sync()
        if current.epoch == self.membership.epoch:
            return None
        old = set(self.membership.hosts)
        new = set(current.hosts)
        self.hosts_lost += len(old - new)
        self.hosts_joined += len(new - old)
        self.reshards += 1
        self.membership = current
        return current

    def rank(self) -> int:
        """This host's rank at the current epoch (KeyError when our own
        lease expired — we are the one being preempted)."""
        if self.membership is None:
            raise RuntimeError("HostSupervisor.rank before start()")
        return self.membership.rank(self.host_id)

    def resumes(self) -> list[tuple[str, str]]:
        """(host_id, stamp path) for every simulated-peer rejoin that
        restored from a checkpoint — the chaos tests' proof that a
        rejoining host resumed from the newest valid stamp."""
        return [
            (pid, peer.resumed_from)
            for pid, peer in self.peers.items()
            if peer.resumed_from is not None
        ]

    def stop(self) -> None:
        """Graceful leave: retire this host's lease (and the simulated
        peers') instead of leaving them to expire."""
        if not self._started:
            return
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=self.registry.ttl)
            self._thread = None
        for peer in self.peers.values():
            peer.stop()
        self.registry.retire(self.host_id)
        self._started = False
