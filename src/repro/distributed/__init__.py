"""Multi-host elasticity for Sebulba (ISSUE 8).

Three layers, bottom up:

  * ``registry`` — lease-based membership over a shared directory
    (atomic ``os.replace`` stamps, death = absence of renewal) plus the
    pure shard-placement functions every host derives the same layout
    from;
  * ``routing`` — cross-host replay routing: owner-hashed inserts,
    fan-out sampling with global PER re-normalization, deterministic
    epoch-bump reshard;
  * ``host`` — the per-host membership agent (``HostSupervisor``,
    Sebulba's ``cluster=`` mount) and the in-process peer simulation the
    seeded host-chaos runs drive.

See ARCHITECTURE.md §Multi-host elasticity.
"""

from repro.distributed.host import HostSupervisor, SimulatedPeerHost
from repro.distributed.registry import (
    HostRegistry,
    Membership,
    owner_rank,
    shard_assignment,
    stable_hash,
)
from repro.distributed.routing import DistributedReplay, StaleEpochError

__all__ = [
    "DistributedReplay",
    "HostRegistry",
    "HostSupervisor",
    "Membership",
    "SimulatedPeerHost",
    "StaleEpochError",
    "owner_rank",
    "shard_assignment",
    "stable_hash",
]
