"""Return / advantage estimators: n-step, lambda-returns, GAE.

All batch-major (B, T); discounts are per-step gammas (0 at terminal).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def discounted_returns(
    rewards: jax.Array, discounts: jax.Array, bootstrap: jax.Array
) -> jax.Array:
    """G_t = r_t + gamma_t * G_{t+1}; (B, T)."""

    def body(acc, xs):
        r, d = xs
        acc = r + d * acc
        return acc, acc

    xs = (jnp.moveaxis(rewards, 1, 0)[::-1], jnp.moveaxis(discounts, 1, 0)[::-1])
    _, out = jax.lax.scan(body, bootstrap.astype(jnp.float32), xs)
    return jnp.moveaxis(out[::-1], 0, 1)


def lambda_returns(
    rewards: jax.Array,
    discounts: jax.Array,
    values_tp1: jax.Array,
    lambda_: float = 0.95,
) -> jax.Array:
    """TD(lambda) targets.  values_tp1: V(s_{t+1}) incl. bootstrap at t=T-1."""

    def body(acc, xs):
        r, d, v1 = xs
        acc = r + d * ((1 - lambda_) * v1 + lambda_ * acc)
        return acc, acc

    xs = jax.tree.map(
        lambda x: jnp.moveaxis(x, 1, 0)[::-1], (rewards, discounts, values_tp1)
    )
    _, out = jax.lax.scan(body, values_tp1[:, -1].astype(jnp.float32), xs)
    return jnp.moveaxis(out[::-1], 0, 1)


def gae(
    rewards: jax.Array,
    discounts: jax.Array,
    values: jax.Array,
    bootstrap: jax.Array,
    lambda_: float = 0.95,
) -> tuple[jax.Array, jax.Array]:
    """Generalized advantage estimation -> (advantages, value targets)."""
    values_tp1 = jnp.concatenate([values[:, 1:], bootstrap[:, None]], axis=1)
    deltas = rewards + discounts * values_tp1 - values

    def body(acc, xs):
        delta, d = xs
        acc = delta + d * lambda_ * acc
        return acc, acc

    xs = (jnp.moveaxis(deltas, 1, 0)[::-1], jnp.moveaxis(discounts, 1, 0)[::-1])
    _, adv = jax.lax.scan(body, jnp.zeros_like(bootstrap), xs)
    adv = jnp.moveaxis(adv[::-1], 0, 1)
    return adv, adv + values
