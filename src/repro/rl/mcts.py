"""Batched pure-JAX Monte-Carlo Tree Search (mctx-style).

The paper: "We could reproduce results from MuZero (no Reanalyse) ... using
Sebulba and a pure JAX implementation of MCTS."  This is that component:
the whole search is jit-able array code (vmapped over the batch), so it
runs *on the actor TPU cores* with no Python in the loop.

Tree layout (per batch element), with N = num_simulations + 1 nodes:
    hidden    (N, H)    latent state per node
    visits    (N,)      visit counts
    value_sum (N,)      sum of backed-up values
    prior     (N, A)    policy prior per node
    reward    (N,)      reward obtained on the edge INTO the node
    children  (N, A)    child node index or -1
    parent    (N,)      parent index (-1 at root)
    action    (N,)      action taken from parent

Selection uses PUCT; expansion adds exactly one node per simulation;
backup propagates discounted returns to the root.
"""

from __future__ import annotations

import functools
from typing import Callable, NamedTuple

import jax
import jax.numpy as jnp


class MCTSOutput(NamedTuple):
    action: jax.Array  # (B,) selected action
    visit_probs: jax.Array  # (B, A) normalized root visit distribution
    root_value: jax.Array  # (B,)


class _Tree(NamedTuple):
    hidden: jax.Array
    visits: jax.Array
    value_sum: jax.Array
    prior: jax.Array
    reward: jax.Array
    children: jax.Array
    parent: jax.Array
    action: jax.Array


def _puct(
    tree: _Tree, node: jax.Array, discount: float, c1: float = 1.25
) -> jax.Array:
    """PUCT scores over actions at ``node``.

    Q(s, a) = r(s, a) + gamma * V(child) — the edge reward lives on the
    child node (``tree.reward``), the state value in its visit statistics.
    """
    child = tree.children[node]  # (A,)
    expanded = child >= 0
    cidx = jnp.maximum(child, 0)
    v_child = tree.value_sum[cidx] / jnp.maximum(tree.visits[cidx], 1)
    q = jnp.where(
        expanded & (tree.visits[cidx] > 0),
        tree.reward[cidx] + discount * v_child,
        0.0,
    )
    n_parent = tree.visits[node]
    n_child = jnp.where(expanded, tree.visits[cidx], 0)
    u = tree.prior[node] * jnp.sqrt(n_parent + 1e-8) / (1.0 + n_child)
    return q + c1 * u


def _simulate(
    tree: _Tree,
    dynamics: Callable,
    prediction: Callable,
    params,
    sim: jax.Array,
    discount: float,
    max_depth: int,
):
    """One MCTS simulation for ONE batch element (vmapped by caller)."""
    new_node = sim + 1

    # --- selection: walk down until an unexpanded edge ---------------------
    def sel_cond(carry):
        node, action, depth, done = carry
        return ~done & (depth < max_depth)

    def sel_body(carry):
        node, action, depth, _ = carry
        scores = _puct(tree, node, discount)
        a = jnp.argmax(scores)
        child = tree.children[node, a]
        done = child < 0
        next_node = jnp.where(done, node, child)
        return (next_node, a, depth + 1, done)

    node, action, depth, _ = jax.lax.while_loop(
        sel_cond, sel_body, (jnp.int32(0), jnp.int32(0), jnp.int32(0), False)
    )

    # --- expansion ----------------------------------------------------------
    h_parent = tree.hidden[node]
    h_new, r_new = dynamics(params, h_parent, action)
    logits, v_new = prediction(params, h_new)
    p_new = jax.nn.softmax(logits)

    tree = tree._replace(
        hidden=tree.hidden.at[new_node].set(h_new),
        prior=tree.prior.at[new_node].set(p_new),
        reward=tree.reward.at[new_node].set(r_new),
        children=tree.children.at[node, action].set(new_node),
        parent=tree.parent.at[new_node].set(node),
        action=tree.action.at[new_node].set(action),
    )

    # --- backup --------------------------------------------------------------
    def back_cond(carry):
        node, g, tree = carry
        return node >= 0

    def back_body(carry):
        node, g, tree = carry
        tree = tree._replace(
            visits=tree.visits.at[node].add(1),
            value_sum=tree.value_sum.at[node].add(g),
        )
        g = tree.reward[node] + discount * g
        return (tree.parent[node], g, tree)

    _, _, tree = jax.lax.while_loop(back_cond, back_body, (new_node, v_new, tree))
    return tree


@functools.partial(
    jax.jit,
    static_argnames=(
        "representation", "dynamics", "prediction",
        "num_simulations", "num_actions", "max_depth", "temperature",
        "discount", "dirichlet_alpha", "exploration_frac",
    ),
)
def mcts_search(
    params,
    obs: jax.Array,  # (B, ...) observations
    rng: jax.Array,
    *,
    representation: Callable,  # (params, obs_single) -> hidden (H,)
    dynamics: Callable,  # (params, hidden, action) -> (hidden, reward)
    prediction: Callable,  # (params, hidden) -> (logits (A,), value ())
    num_simulations: int = 16,
    num_actions: int,
    max_depth: int = 8,
    discount: float = 0.99,
    temperature: float = 1.0,
    dirichlet_alpha: float = 0.3,
    exploration_frac: float = 0.25,
) -> MCTSOutput:
    B = obs.shape[0]
    N = num_simulations + 1

    def search_one(ob, key):
        h0 = representation(params, ob)
        logits0, v0 = prediction(params, h0)
        p0 = jax.nn.softmax(logits0)
        noise = jax.random.dirichlet(key, jnp.full((num_actions,), dirichlet_alpha))
        p0 = (1 - exploration_frac) * p0 + exploration_frac * noise

        H = h0.shape[-1]
        tree = _Tree(
            hidden=jnp.zeros((N, H), h0.dtype).at[0].set(h0),
            visits=jnp.zeros((N,), jnp.float32),
            value_sum=jnp.zeros((N,), jnp.float32),
            prior=jnp.zeros((N, num_actions), jnp.float32).at[0].set(p0),
            reward=jnp.zeros((N,), jnp.float32),
            children=jnp.full((N, num_actions), -1, jnp.int32),
            parent=jnp.full((N,), -1, jnp.int32),
            action=jnp.zeros((N,), jnp.int32),
        )
        tree = tree._replace(
            visits=tree.visits.at[0].set(1.0),
            value_sum=tree.value_sum.at[0].set(v0),
        )

        def body(sim, tree):
            return _simulate(
                tree, dynamics, prediction, params, sim, discount, max_depth
            )

        tree = jax.lax.fori_loop(0, num_simulations, body, tree)
        root_children = tree.children[0]
        counts = jnp.where(
            root_children >= 0, tree.visits[jnp.maximum(root_children, 0)], 0.0
        )
        probs = counts / jnp.maximum(counts.sum(), 1e-8)
        root_value = tree.value_sum[0] / jnp.maximum(tree.visits[0], 1.0)
        return probs, root_value

    keys = jax.random.split(rng, B + 1)
    probs, root_values = jax.vmap(search_one)(obs, keys[1:])
    if temperature == 0.0:
        actions = jnp.argmax(probs, axis=-1)
    else:
        logits = jnp.log(jnp.maximum(probs, 1e-9)) / temperature
        actions = jax.random.categorical(keys[0], logits)
    return MCTSOutput(action=actions, visit_probs=probs, root_value=root_values)
