"""RL losses: policy gradient, entropy, value, and the composed IMPALA
(V-trace actor-critic) and A2C objectives used by the Podracer agents.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.kernels.vtrace.ops import vtrace
from repro.rl import returns as rets


def log_prob(logits: jax.Array, actions: jax.Array) -> jax.Array:
    """logits (..., A), actions (...) -> log pi(a|s).

    Computed as logit[a] - logsumexp(logits): avoids materializing the full
    log_softmax array, which matters when A = an LLM vocabulary (§Perf).
    """
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    chosen = jnp.take_along_axis(logits, actions[..., None], axis=-1)[..., 0]
    return chosen - lse


def entropy(logits: jax.Array) -> jax.Array:
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    return -jnp.sum(jnp.exp(logp) * logp, axis=-1)


def policy_gradient_loss(
    logits: jax.Array, actions: jax.Array, advantages: jax.Array
) -> jax.Array:
    adv = jax.lax.stop_gradient(advantages)
    return -jnp.mean(log_prob(logits, actions) * adv)


class ImpalaLossOut(NamedTuple):
    total: jax.Array
    pg: jax.Array
    value: jax.Array
    entropy: jax.Array
    mean_rho: jax.Array


def impala_loss(
    logits: jax.Array,  # (B, T, A) learner policy
    values: jax.Array,  # (B, T)
    actions: jax.Array,  # (B, T)
    behaviour_logp: jax.Array,  # (B, T) log mu(a|s) from the actor
    rewards: jax.Array,  # (B, T)
    discounts: jax.Array,  # (B, T)
    bootstrap_value: jax.Array,  # (B,)
    *,
    entropy_cost: float = 0.01,
    value_cost: float = 0.5,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
) -> ImpalaLossOut:
    """The V-trace actor-critic loss (Espeholt et al. 2018, eq. 1-4).

    The uniform-weight special case of ``weighted_impala_loss`` (multiplying
    by 1.0 is exact, so the numerics are bit-identical), without the
    per-sequence TD output replay mode needs.
    """
    out = weighted_impala_loss(
        logits, values, actions, behaviour_logp, rewards, discounts,
        bootstrap_value, importance_weights=None,
        entropy_cost=entropy_cost, value_cost=value_cost,
        clip_rho=clip_rho, clip_c=clip_c,
    )
    return ImpalaLossOut(
        total=out.total, pg=out.pg, value=out.value, entropy=out.entropy,
        mean_rho=out.mean_rho,
    )


def per_importance_weights(
    probs: jax.Array, size: jax.Array, beta: float | jax.Array, *,
    axis_name: str | None = None,
) -> jax.Array:
    """PER bias correction: w_i = (N * P(i))^-beta, normalized by max.

    ``probs`` are the selection probabilities returned by ``replay.sample``
    and ``size`` the number of valid slots; ``beta`` anneals toward 1 over
    training in the original recipe and may be a traced scalar
    (``ReplayConfig.importance_beta`` computes the schedule inside the
    fused off-policy step) or a fixed float.

    Inside shard_map/pmap pass ``axis_name`` so the normalization uses the
    *global* max across learner shards: a per-shard max would give
    identical-priority slots different effective weights depending on
    which shard happened to draw them, making training depend on the
    learner count.
    """
    w = (jnp.maximum(size, 1).astype(jnp.float32) * probs) ** (-beta)
    w_max = jnp.max(w)
    if axis_name is not None:
        w_max = jax.lax.pmax(w_max, axis_name)
    return w / jnp.maximum(w_max, 1e-20)


class WeightedImpalaOut(NamedTuple):
    total: jax.Array
    pg: jax.Array
    value: jax.Array
    entropy: jax.Array
    mean_rho: jax.Array
    per_seq_td: jax.Array  # (B,) |vs - V| per sequence -> replay priorities


def weighted_impala_loss(
    logits: jax.Array,  # (B, T, A) learner policy
    values: jax.Array,  # (B, T)
    actions: jax.Array,  # (B, T)
    behaviour_logp: jax.Array,  # (B, T) log mu(a|s) from the actor
    rewards: jax.Array,  # (B, T)
    discounts: jax.Array,  # (B, T)
    bootstrap_value: jax.Array,  # (B,)
    *,
    importance_weights: jax.Array | None = None,  # (B,) replay IS weights
    entropy_cost: float = 0.01,
    value_cost: float = 0.5,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
) -> WeightedImpalaOut:
    """V-trace loss with per-sequence importance weighting (off-policy
    Sebulba): V-trace's rho/c clipping corrects the actor-policy lag, while
    ``importance_weights`` corrects the *sampling* bias a prioritized replay
    distribution introduces.  Also emits per-sequence TD magnitudes, the
    priority signal written back into the replay ring after each update.
    """
    target_logp = log_prob(logits, actions)
    log_rhos = target_logp - behaviour_logp
    vt = vtrace(
        log_rhos, discounts, rewards, values, bootstrap_value,
        clip_rho=clip_rho, clip_c=clip_c,
    )
    if importance_weights is None:
        w = jnp.ones(values.shape[:1], jnp.float32)
    else:
        w = jax.lax.stop_gradient(importance_weights)
    wn = w[:, None]
    pg = -jnp.mean(wn * target_logp * vt.pg_advantages)
    value = 0.5 * jnp.mean(wn * jnp.square(vt.vs - values))
    ent = jnp.mean(wn * entropy(logits))
    total = pg + value_cost * value - entropy_cost * ent
    per_seq_td = jnp.mean(
        jnp.abs(jax.lax.stop_gradient(vt.vs) - values), axis=1
    )
    return WeightedImpalaOut(
        total=total, pg=pg, value=value, entropy=ent,
        mean_rho=jnp.mean(jnp.exp(log_rhos)),
        per_seq_td=jax.lax.stop_gradient(per_seq_td),
    )


class PPOLossOut(NamedTuple):
    total: jax.Array
    pg: jax.Array
    value: jax.Array
    entropy: jax.Array
    clip_frac: jax.Array


def ppo_loss(
    logits: jax.Array,  # (B, T, A)
    values: jax.Array,  # (B, T)
    actions: jax.Array,  # (B, T)
    behaviour_logp: jax.Array,  # (B, T)
    rewards: jax.Array,
    discounts: jax.Array,
    bootstrap_value: jax.Array,
    *,
    clip_eps: float = 0.2,
    gae_lambda: float = 0.95,
    entropy_cost: float = 0.01,
    value_cost: float = 0.5,
) -> PPOLossOut:
    """Clipped-surrogate PPO with GAE advantages (Schulman et al. 2017).

    In Anakin's fused loop this runs one epoch per on-policy batch; in
    Sebulba the behaviour_logp comes from the (slightly stale) actor
    policy, so the ratio clip doubles as off-policy protection.
    """
    from repro.rl import returns as rets

    adv, targets = rets.gae(
        rewards, discounts, values, bootstrap_value, lambda_=gae_lambda
    )
    adv = jax.lax.stop_gradient(
        (adv - adv.mean()) / jnp.maximum(adv.std(), 1e-6)
    )
    targets = jax.lax.stop_gradient(targets)
    logp = log_prob(logits, actions)
    ratio = jnp.exp(logp - behaviour_logp)
    clipped = jnp.clip(ratio, 1 - clip_eps, 1 + clip_eps)
    pg = -jnp.mean(jnp.minimum(ratio * adv, clipped * adv))
    value = 0.5 * jnp.mean(jnp.square(targets - values))
    ent = jnp.mean(entropy(logits))
    total = pg + value_cost * value - entropy_cost * ent
    return PPOLossOut(
        total=total, pg=pg, value=value, entropy=ent,
        clip_frac=jnp.mean((jnp.abs(ratio - 1) > clip_eps).astype(jnp.float32)),
    )


class A2CLossOut(NamedTuple):
    total: jax.Array
    pg: jax.Array
    value: jax.Array
    entropy: jax.Array


def a2c_loss(
    logits: jax.Array,
    values: jax.Array,
    actions: jax.Array,
    rewards: jax.Array,
    discounts: jax.Array,
    bootstrap_value: jax.Array,
    *,
    entropy_cost: float = 0.01,
    value_cost: float = 0.5,
    td_lambda: float = 1.0,
) -> A2CLossOut:
    """On-policy advantage actor-critic (the Anakin agent objective)."""
    values_tp1 = jnp.concatenate([values[:, 1:], bootstrap_value[:, None]], axis=1)
    targets = rets.lambda_returns(rewards, discounts, values_tp1, td_lambda)
    targets = jax.lax.stop_gradient(targets)
    adv = targets - values
    pg = policy_gradient_loss(logits, actions, adv)
    value = 0.5 * jnp.mean(jnp.square(targets - values))
    ent = jnp.mean(entropy(logits))
    total = pg + value_cost * value - entropy_cost * ent
    return A2CLossOut(total=total, pg=pg, value=value, entropy=ent)
