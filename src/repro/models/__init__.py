from repro.models.model import Model, make_model  # noqa: F401
