"""Shared neural-net building blocks (pure JAX, no NN library).

Conventions:
  * params are nested dicts built by :class:`repro.param.ParamBuilder`
  * activations compute in bfloat16, reductions (softmax, norms) in float32
  * einsum subscripts annotate logical axes: B batch, T query seq, S kv seq,
    D d_model, H heads, K kv heads, G q-per-kv group, h head_dim, F d_ff
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.param import ParamBuilder, fan_in_init, normal_init, ones_init, zeros_init


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rms_norm(b: ParamBuilder, name: str, dim: int) -> None:
    with b.scope(name):
        b.param("scale", (dim,), ("act_embed",), ones_init(), dtype=jnp.float32)


def rms_norm(params, x: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_frequencies(head_dim: int, theta: float) -> jax.Array:
    """(head_dim//2,) inverse frequencies, float32."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta**exponent)


def apply_rope(
    x: jax.Array, positions: jax.Array, theta: float
) -> jax.Array:
    """Apply RoPE. x: (..., seq, heads, head_dim); positions: (..., seq)."""
    head_dim = x.shape[-1]
    freqs = rope_frequencies(head_dim, theta)  # (h/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., T, h/2)
    cos = jnp.cos(angles)[..., None, :]  # (..., T, 1, h/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Learned absolute positions (whisper)
# ---------------------------------------------------------------------------


def init_learned_pos(b: ParamBuilder, name: str, max_position: int, dim: int):
    with b.scope(name):
        b.param("table", (max_position, dim), ("kv_seq", "embed"), normal_init(0.01))


def learned_pos(params, positions: jax.Array) -> jax.Array:
    return jnp.take(params["table"], positions, axis=0)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(b: ParamBuilder, name: str, vocab: int, dim: int, tie: bool):
    with b.scope(name):
        b.param("table", (vocab, dim), ("vocab", "embed"), normal_init(0.02))
        if not tie:
            b.param("unembed", (dim, vocab), ("embed", "vocab"), normal_init(0.02))


def embed(params, tokens: jax.Array, dtype=jnp.bfloat16) -> jax.Array:
    return jnp.take(params["table"], tokens, axis=0).astype(dtype)


def unembed(params, x: jax.Array) -> jax.Array:
    """Returns float32 logits (B, T, V)."""
    if "unembed" in params:
        w = params["unembed"]
        return jnp.einsum("btd,dv->btv", x, w.astype(x.dtype)).astype(jnp.float32)
    w = params["table"]
    return jnp.einsum("btd,vd->btv", x, w.astype(x.dtype)).astype(jnp.float32)


# ---------------------------------------------------------------------------
# Gated MLP (SwiGLU)
# ---------------------------------------------------------------------------


def init_mlp(b: ParamBuilder, name: str, d_model: int, d_ff: int) -> None:
    with b.scope(name):
        b.param("w_gate", (d_model, d_ff), ("embed", "mlp"))
        b.param("w_up", (d_model, d_ff), ("embed", "mlp"))
        b.param("w_down", (d_ff, d_model), ("mlp", "embed"))


def mlp(params, x: jax.Array) -> jax.Array:
    dt = x.dtype
    gate = jnp.einsum("btd,df->btf", x, params["w_gate"].astype(dt))
    up = jnp.einsum("btd,df->btf", x, params["w_up"].astype(dt))
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    return jnp.einsum("btf,fd->btd", hidden, params["w_down"].astype(dt))


# ---------------------------------------------------------------------------
# Linear helpers
# ---------------------------------------------------------------------------


def init_linear(
    b: ParamBuilder,
    name: str,
    in_dim: int,
    out_dim: int,
    axes=("embed", "act_embed"),
    bias: bool = False,
    scale: float = 1.0,
) -> None:
    with b.scope(name):
        b.param("w", (in_dim, out_dim), axes, fan_in_init(scale))
        if bias:
            b.param("b", (out_dim,), (axes[1],), zeros_init(), dtype=jnp.float32)


def linear(params, x: jax.Array) -> jax.Array:
    out = x @ params["w"].astype(x.dtype)
    if "b" in params:
        out = out + params["b"].astype(out.dtype)
    return out


# ---------------------------------------------------------------------------
# Masks
# ---------------------------------------------------------------------------

NEG_INF = -1e30


def causal_mask(t: int, s: int | None = None, offset: int = 0) -> jax.Array:
    """(t, s) boolean mask, True = attend.  offset = kv positions before q0."""
    s = s or t
    q = jnp.arange(t)[:, None] + offset
    k = jnp.arange(s)[None, :]
    return k <= q


def sliding_window_mask(t: int, s: int, window: int, offset: int = 0) -> jax.Array:
    q = jnp.arange(t)[:, None] + offset
    k = jnp.arange(s)[None, :]
    return (k <= q) & (k > q - window)


def segment_mask(q_seg: jax.Array, kv_seg: jax.Array) -> jax.Array:
    """(B, T, S) mask allowing attention only within matching segments."""
    return q_seg[:, :, None] == kv_seg[:, None, :]
