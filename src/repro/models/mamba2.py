"""Mamba-2 (SSD) block [arXiv:2405.21060].

Block: RMSNorm -> fused in_proj to (z, x, B, C, dt) -> causal depthwise conv
over (x, B, C) -> SSD scan -> D skip -> gated RMSNorm -> out_proj.

Decode keeps two pieces of state per layer:
  * ssm  : (B, H, P, N) SSD state
  * conv : (B, conv_width-1, conv_dim) rolling window of recent conv inputs
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.ssd_scan.ops import ssd_scan
from repro.kernels.ssd_scan.ref import ssd_step_ref
from repro.models import layers
from repro.param import ParamBuilder, constant_init, fan_in_init, normal_init, zeros_init


def conv_dim(cfg: ArchConfig) -> int:
    return cfg.d_inner + 2 * cfg.ssm_state


def init_mamba2_block(b: ParamBuilder, name: str, cfg: ArchConfig) -> None:
    d, di, N, H = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    proj_out = 2 * di + 2 * N + H  # z, x, B, C, dt
    with b.scope(name):
        layers.init_rms_norm(b, "norm", d)
        b.param("in_proj", (d, proj_out), ("embed", "ssm_inner"), fan_in_init())
        b.param(
            "conv_w",
            (cfg.conv_width, conv_dim(cfg)),
            ("conv_width", "ssm_inner"),
            normal_init(0.1),
        )
        b.param("conv_b", (conv_dim(cfg),), ("ssm_inner",), zeros_init(),
                dtype=jnp.float32)
        b.param("A_log", (H,), ("ssm_heads",), constant_init(0.0), dtype=jnp.float32)
        b.param("dt_bias", (H,), ("ssm_heads",), constant_init(0.5), dtype=jnp.float32)
        b.param("D", (H,), ("ssm_heads",), constant_init(1.0), dtype=jnp.float32)
        layers.init_rms_norm(b, "out_norm", di)
        b.param("out_proj", (di, d), ("ssm_inner", "embed"), fan_in_init())


def _split_proj(cfg: ArchConfig, proj: jax.Array):
    di, N, H = cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    z = proj[..., :di]
    xc = proj[..., di : 2 * di]
    Bm = proj[..., 2 * di : 2 * di + N]
    Cm = proj[..., 2 * di + N : 2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N : 2 * di + 2 * N + H]
    return z, xc, Bm, Cm, dt


def _causal_conv(params, u: jax.Array, width: int) -> jax.Array:
    """Depthwise causal conv along T.  u: (B, T, C)."""
    pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + u.shape[1]] * params["conv_w"][i].astype(u.dtype)
        for i in range(width)
    )
    return jax.nn.silu(
        (out + params["conv_b"].astype(jnp.float32).astype(u.dtype))
    )


def mamba2_block(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """Train/prefill forward.  x: (B, T, D) -> (B, T, D)."""
    Bsz, T, _ = x.shape
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    h = layers.rms_norm(params["norm"], x, cfg.rms_norm_eps)
    proj = h @ params["in_proj"].astype(h.dtype)
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)
    conv_out = _causal_conv(params, conv_in, cfg.conv_width)
    xc = conv_out[..., : cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner : cfg.d_inner + cfg.ssm_state]
    Cm = conv_out[..., cfg.d_inner + cfg.ssm_state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,T,H)
    A = -jnp.exp(params["A_log"])  # (H,) negative decay
    xh = xc.reshape(Bsz, T, H, P)
    y, _ = ssd_scan(xh, dt, A, Bm, Cm, chunk=min(cfg.ssm_chunk, T))
    y = y + params["D"].astype(y.dtype)[:, None] * xh
    y = y.reshape(Bsz, T, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = layers.rms_norm(params["out_norm"], y, cfg.rms_norm_eps)
    return y @ params["out_proj"].astype(y.dtype)


def init_mamba2_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    return {
        "ssm": jnp.zeros((batch, H, P, N), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, conv_dim(cfg)), dtype),
    }


def mamba2_decode_step(
    params, cache: dict, x: jax.Array, cfg: ArchConfig
) -> tuple[jax.Array, dict]:
    """x: (B, 1, D) one token; returns (out (B,1,D), new cache)."""
    Bsz = x.shape[0]
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    h = layers.rms_norm(params["norm"], x, cfg.rms_norm_eps)[:, 0]  # (B, D)
    proj = h @ params["in_proj"].astype(h.dtype)
    z, xc, Bm, Cm, dt = _split_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, Bm, Cm], axis=-1)  # (B, C)
    window = jnp.concatenate([cache["conv"], conv_in[:, None]], axis=1)  # (B, W, C)
    w = params["conv_w"].astype(conv_in.dtype)  # (W, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bwc,wc->bc", window, w)
        + params["conv_b"].astype(conv_in.dtype)
    )
    xc = conv_out[..., : cfg.d_inner]
    Bm = conv_out[..., cfg.d_inner : cfg.d_inner + cfg.ssm_state]
    Cm = conv_out[..., cfg.d_inner + cfg.ssm_state :]
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B, H)
    A = -jnp.exp(params["A_log"])
    y, S = ssd_step_ref(cache["ssm"], xc.reshape(Bsz, H, P), dt, A, Bm, Cm)
    y = y + params["D"].astype(y.dtype)[:, None] * xc.reshape(Bsz, H, P)
    y = y.reshape(Bsz, cfg.d_inner)
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = layers.rms_norm(params["out_norm"], y[:, None], cfg.rms_norm_eps)[:, 0]
    out = (y @ params["out_proj"].astype(y.dtype))[:, None]
    new_cache = {"ssm": S, "conv": window[:, 1:]}
    return out, new_cache
