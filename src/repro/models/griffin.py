"""Griffin / RecurrentGemma blocks [arXiv:2402.19427].

Layer pattern "RRA": two recurrent blocks then one local-MQA attention
block, each followed by a gated-MLP block (two residual connections per
layer, as in the paper).

Recurrent block: RMSNorm -> two branches
  (1) linear d->W, causal depthwise conv(4), RG-LRU
  (2) linear d->W, GeLU
  merged multiplicatively -> linear W->d.

RG-LRU: r_t = sigmoid(W_a x_t + b_a); i_t = sigmoid(W_x x_t + b_x);
        a_t = exp(-c * softplus(Lambda) * r_t)  with c = 8;
        h_t = a_t h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t).

Decode state per recurrent layer: rg-lru hidden (B, W) + conv window
(B, conv_width-1, W).  Attention layers keep a *ring-buffer* KV cache of
size min(seq, window) — O(window) memory, which is what makes long_500k
decode architecturally cheap for this family.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_step_ref
from repro.models import attention, layers
from repro.param import ParamBuilder, constant_init, fan_in_init, normal_init, zeros_init

RGLRU_C = 8.0


def init_recurrent_block(b: ParamBuilder, name: str, cfg: ArchConfig) -> None:
    d, W = cfg.d_model, cfg.rnn_width
    with b.scope(name):
        layers.init_rms_norm(b, "norm", d)
        b.param("w_branch1", (d, W), ("embed", "rnn_width"), fan_in_init())
        b.param("w_branch2", (d, W), ("embed", "rnn_width"), fan_in_init())
        b.param(
            "conv_w",
            (cfg.rnn_conv_width, W),
            ("conv_width", "rnn_width"),
            normal_init(0.1),
        )
        b.param("conv_b", (W,), ("rnn_width",), zeros_init(), dtype=jnp.float32)
        # RG-LRU gates
        b.param("w_a", (W, W), ("rnn_width", "rnn_width"), fan_in_init())
        b.param("b_a", (W,), ("rnn_width",), zeros_init(), dtype=jnp.float32)
        b.param("w_x", (W, W), ("rnn_width", "rnn_width"), fan_in_init())
        b.param("b_x", (W,), ("rnn_width",), zeros_init(), dtype=jnp.float32)
        # Lambda init so that a^(1/c) ~ U[0.9, 0.999] as in the paper
        b.param("lam", (W,), ("rnn_width",), constant_init(0.7), dtype=jnp.float32)
        b.param("w_out", (W, d), ("rnn_width", "embed"), fan_in_init())


def _rglru_gates(params, u: jax.Array):
    """u: (..., W) conv output.  Returns (a, i) gates, float32."""
    uf = u.astype(jnp.float32)
    r = jax.nn.sigmoid(uf @ params["w_a"].astype(jnp.float32) + params["b_a"])
    gi = jax.nn.sigmoid(uf @ params["w_x"].astype(jnp.float32) + params["b_x"])
    log_a = -RGLRU_C * jax.nn.softplus(params["lam"]) * r
    return jnp.exp(log_a), gi


def _conv1d(params, u: jax.Array, width: int) -> jax.Array:
    pad = jnp.pad(u, ((0, 0), (width - 1, 0), (0, 0)))
    out = sum(
        pad[:, i : i + u.shape[1]] * params["conv_w"][i].astype(u.dtype)
        for i in range(width)
    )
    return out + params["conv_b"].astype(u.dtype)


def recurrent_block(params, x: jax.Array, cfg: ArchConfig) -> jax.Array:
    """x: (B, T, D) -> (B, T, D)."""
    h = layers.rms_norm(params["norm"], x, cfg.rms_norm_eps)
    u = h @ params["w_branch1"].astype(h.dtype)  # (B, T, W)
    g = jax.nn.gelu(
        (h @ params["w_branch2"].astype(h.dtype)).astype(jnp.float32)
    ).astype(h.dtype)
    u = _conv1d(params, u, cfg.rnn_conv_width)
    a, gi = _rglru_gates(params, u)
    y, _ = rglru_scan(u, a, gi)
    y = y.astype(h.dtype) * g
    return y @ params["w_out"].astype(y.dtype)


def init_recurrent_cache(cfg: ArchConfig, batch: int, dtype=jnp.bfloat16) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.rnn_conv_width - 1, cfg.rnn_width), dtype),
    }


def recurrent_decode_step(
    params, cache: dict, x: jax.Array, cfg: ArchConfig
) -> tuple[jax.Array, dict]:
    """x: (B, 1, D) -> (out (B, 1, D), cache)."""
    h = layers.rms_norm(params["norm"], x, cfg.rms_norm_eps)[:, 0]  # (B, D)
    u = h @ params["w_branch1"].astype(h.dtype)  # (B, W)
    g = jax.nn.gelu(
        (h @ params["w_branch2"].astype(h.dtype)).astype(jnp.float32)
    ).astype(h.dtype)
    window = jnp.concatenate([cache["conv"], u[:, None]], axis=1)  # (B, cw, W)
    w = params["conv_w"].astype(u.dtype)
    u = jnp.einsum("bwc,wc->bc", window, w) + params["conv_b"].astype(u.dtype)
    a, gi = _rglru_gates(params, u)
    y, h_new = rglru_step_ref(cache["h"], u, a, gi)
    y = y.astype(g.dtype) * g
    out = (y @ params["w_out"].astype(y.dtype))[:, None]
    return out, {"h": h_new, "conv": window[:, 1:]}


# ---------------------------------------------------------------------------
# Ring-buffer decode for sliding-window attention layers
# ---------------------------------------------------------------------------


def ring_cache_update(k_cache, v_cache, k, v, pos, window: int):
    """Write kv (B,1,K,h) at slot pos % window."""
    slot = jnp.mod(pos, window)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        k_cache, k.astype(k_cache.dtype), slot, 1
    )
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        v_cache, v.astype(v_cache.dtype), slot, 1
    )
    return k_cache, v_cache


def ring_decode_attention(q, k_cache, v_cache, pos, window: int):
    """Decode attention over a ring buffer; validity = slot already written.

    With the window mask implicit in the ring (slots hold the last `window`
    positions), only unwritten slots need masking.
    """
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, h) * (h**-0.5)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    slot_idx = jnp.arange(S)
    written = slot_idx <= jnp.minimum(pos, S - 1)
    # slots beyond pos (when pos < window-1) were never written
    logits = jnp.where(written[None, None, None, :], logits, layers.NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, h).astype(q.dtype)
