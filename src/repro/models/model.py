"""Unified model API over all assigned architecture families.

    model = make_model(cfg)
    params            = model.init(rng)            # real or under eval_shape
    sds, axes         = model.abstract()           # ShapeDtypeStructs + logical axes
    logits, v, aux    = model.forward(params, batch)
    cache, cache_axes = model.init_cache(batch, seq_len)
    logits, v, cache  = model.decode_step(params, cache, tokens, pos)

``batch`` dict keys: "tokens" (B, T) int32; VLM adds "images"
(B, num_image_tokens, D) patch embeddings; audio adds "frames"
(B, num_audio_frames, D) — both are modality-frontend STUBS per the
assignment (the backbone consumes precomputed embeddings).

The model provides a policy head (the LM logits) and a value head — the
heads the Sebulba learner (V-trace) and actor (decode) consume.

``unroll=True`` lays layers out as per-layer parameters and a Python loop
instead of stacked parameters + lax.scan.  The math is identical; the
dry-run uses it because XLA cost analysis counts a scan body once, so only
the unrolled HLO yields honest roofline FLOPs.  Production configs keep the
scan layout (small HLO, fast compiles).
"""

from __future__ import annotations

import math
from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.models import attention as attn
from repro.models import griffin, layers, mamba2
from repro.models import transformer as tf
from repro.param import ParamBuilder, fan_in_init

Params = Any


class _CacheBuilder:
    """Builds a cache pytree and its logical-axes twin in lockstep."""

    def __init__(self, dtype):
        self.dtype = dtype

    def zeros(self, shape, axes, dtype=None):
        assert len(shape) == len(axes), (shape, axes)
        return jnp.zeros(shape, dtype or self.dtype), axes


def _kv_cache(cb: _CacheBuilder, batch, s, K, h, stacked_layers=0, seq_axis="kv_seq"):
    shape = (batch, s, K, h)
    axes = ("batch", seq_axis, "act_kv_heads", "head_dim")
    if stacked_layers:
        shape = (stacked_layers,) + shape
        axes = ("layers",) + axes
    k, ka = cb.zeros(shape, axes)
    v, va = cb.zeros(shape, axes)
    return {"k": k, "v": v}, {"k": ka, "v": va}


class Model:
    def __init__(self, cfg: ArchConfig, moe_impl: str = "sort",
                 unroll: bool = False, mesh=None):
        self.cfg = cfg
        self.moe_impl = moe_impl
        self.mesh = mesh  # needed only by moe_impl='a2a' (shard_map)
        self.unroll = unroll
        self._axes: dict | None = None
        self.kinds = tf.layer_kinds(cfg)
        uniform = tf.is_uniform(cfg)
        # stacked = scan-over-layers layout applies.  Dense stacks must be
        # uniform GLOBAL attention ("G"): the scan body takes no per-layer
        # window/theta, so a uniform-local ("L") pattern — e.g. the
        # sliding-window long-context variants — must use the looped path.
        self.stacked = not unroll and (
            cfg.family in ("ssm", "audio", "moe")
            or (cfg.family == "dense" and uniform and self.kinds[0] == "G")
        )

    # ------------------------------------------------------------------ init

    def init(self, rng: jax.Array) -> Params:
        cfg = self.cfg
        b = ParamBuilder(rng, dtype=jnp.dtype(cfg.param_dtype))
        layers.init_embedding(b, "embedding", cfg.vocab_size, cfg.d_model,
                              cfg.tie_embeddings)
        if cfg.pos_embed == "learned":
            layers.init_learned_pos(b, "pos", cfg.max_position, cfg.d_model)
        getattr(self, f"_init_{cfg.family}")(b)
        layers.init_rms_norm(b, "final_norm", cfg.d_model)
        with b.scope("value_head"):
            b.param("w", (cfg.d_model, 1), ("embed", None), fan_in_init())
        params, axes = b.build()
        self._axes = axes
        return params

    def abstract(self) -> tuple[Params, Params]:
        sds = jax.eval_shape(self.init, jax.random.key(0))
        return sds, self._axes

    @property
    def axes(self) -> Params:
        if self._axes is None:
            jax.eval_shape(self.init, jax.random.key(0))
        return self._axes

    # -- family inits ---------------------------------------------------------

    def _init_dense(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        if self.stacked:
            def one(bb):
                tf.init_attn_layer(bb, cfg)
                tf.init_ffn_layer(bb, cfg, "dense")
            tf.init_stacked(b, "blocks", cfg.num_layers, one)
        else:
            for i in range(cfg.num_layers):
                with b.scope(f"layer_{i}"):
                    tf.init_attn_layer(b, cfg)
                    tf.init_ffn_layer(b, cfg, "dense")

    def _init_moe(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        if self.stacked:
            for i, kind in enumerate(self.kinds):
                if kind == "D":
                    with b.scope(f"layer_{i}"):
                        tf.init_attn_layer(b, cfg)
                        tf.init_ffn_layer(b, cfg, "dense")
            n_moe = sum(1 for k in self.kinds if k == "M")
            def one(bb):
                tf.init_attn_layer(bb, cfg)
                tf.init_ffn_layer(bb, cfg, "moe")
            tf.init_stacked(b, "blocks", n_moe, one)
        else:
            for i, kind in enumerate(self.kinds):
                with b.scope(f"layer_{i}"):
                    tf.init_attn_layer(b, cfg)
                    tf.init_ffn_layer(b, cfg, "moe" if kind == "M" else "dense")

    def _init_ssm(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        if self.stacked:
            def one(bb):
                mamba2.init_mamba2_block(bb, "mixer", cfg)
            tf.init_stacked(b, "blocks", cfg.num_layers, one)
        else:
            for i in range(cfg.num_layers):
                with b.scope(f"layer_{i}"):
                    mamba2.init_mamba2_block(b, "mixer", cfg)

    def _init_hybrid(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        for i, kind in enumerate(self.kinds):
            with b.scope(f"layer_{i}"):
                if kind == "R":
                    griffin.init_recurrent_block(b, "recurrent", cfg)
                else:
                    tf.init_attn_layer(b, cfg)
                tf.init_ffn_layer(b, cfg, "dense")

    def _init_vlm(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        with b.scope("projector"):
            b.param("w", (cfg.d_model, cfg.d_model), ("embed", "act_embed"),
                    fan_in_init())
        for i in range(cfg.num_layers):
            with b.scope(f"layer_{i}"):
                if self._is_cross(i):
                    tf.init_cross_layer(b, cfg)
                tf.init_attn_layer(b, cfg)
                tf.init_ffn_layer(b, cfg, "dense")

    def _init_audio(self, b: ParamBuilder) -> None:
        cfg = self.cfg
        with b.scope("enc_pos"):
            b.param("table", (cfg.num_audio_frames, cfg.d_model),
                    ("frames", "embed"), fan_in_init())
        def enc_one(bb):
            tf.init_attn_layer(bb, cfg)
            tf.init_ffn_layer(bb, cfg, "dense")
        def dec_one(bb):
            tf.init_attn_layer(bb, cfg)
            tf.init_cross_layer(bb, cfg)
            tf.init_ffn_layer(bb, cfg, "dense")
        if self.stacked:
            tf.init_stacked(b, "encoder", cfg.encoder_layers, enc_one)
            layers.init_rms_norm(b, "enc_norm", cfg.d_model)
            tf.init_stacked(b, "blocks", cfg.num_layers, dec_one)
        else:
            for i in range(cfg.encoder_layers):
                with b.scope(f"enc_layer_{i}"):
                    enc_one(b)
            layers.init_rms_norm(b, "enc_norm", cfg.d_model)
            for i in range(cfg.num_layers):
                with b.scope(f"layer_{i}"):
                    dec_one(b)

    def _is_cross(self, i: int) -> bool:
        every = self.cfg.cross_attn_every
        return every > 0 and (i + 2) % every == 0

    # --------------------------------------------------------------- forward

    def _embed(self, params, tokens: jax.Array) -> jax.Array:
        cfg = self.cfg
        x = layers.embed(params["embedding"], tokens, jnp.dtype(cfg.param_dtype))
        if "gemma" in cfg.name:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if cfg.pos_embed == "learned":
            pos = jnp.arange(tokens.shape[1])
            x = x + layers.learned_pos(params["pos"], pos).astype(x.dtype)
        return x

    def forward(self, params: Params, batch: dict) -> tuple[jax.Array, jax.Array, jax.Array]:
        """-> (logits (B,T,V) f32, values (B,T) f32, aux loss scalar)."""
        cfg = self.cfg
        tokens = batch["tokens"]
        T = tokens.shape[1]
        x = self._embed(params, tokens)
        positions = jnp.arange(T)
        remat = cfg.remat != "none"

        if cfg.family in ("dense", "moe"):
            x, aux = self._fwd_dense_moe(params, x, positions, remat)
        elif cfg.family == "ssm":
            def body(p, h):
                return h + mamba2.mamba2_block(p["mixer"], h, cfg), jnp.float32(0.0)
            x, aux = self._apply_layers(params, x, body, remat)
        elif cfg.family == "hybrid":
            x, aux = self._fwd_hybrid(params, x, positions, remat)
        elif cfg.family == "vlm":
            x, aux = self._fwd_vlm(params, x, positions, batch["images"], remat)
        elif cfg.family == "audio":
            x, aux = self._fwd_audio(params, x, positions, batch["frames"], remat)
        else:
            raise ValueError(cfg.family)

        x = layers.rms_norm(params["final_norm"], x, cfg.rms_norm_eps)
        logits = layers.unembed(params["embedding"], x)
        values = jnp.einsum(
            "btd,dk->btk", x, params["value_head"]["w"].astype(x.dtype)
        )[..., 0].astype(jnp.float32)
        return logits, values, aux

    def _apply_layers(self, params, x, body, remat, layer_ids=None):
        """Run ``body(p, x) -> (x, aux)`` over the trunk layers, using the
        scan layout when ``self.stacked`` else a Python loop."""
        if self.stacked:
            return tf.scan_layers(params["blocks"], x, body, remat=remat)
        aux = jnp.float32(0.0)
        ids = layer_ids if layer_ids is not None else range(self.cfg.num_layers)
        f = jax.checkpoint(body) if remat else body
        for i in ids:
            x, a = f(params[f"layer_{i}"], x)
            aux += a
        return x, aux

    def _fwd_dense_moe(self, params, x, positions, remat):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        if cfg.family == "moe" and self.stacked:
            # leading dense ('D') layers as a python loop (deepseek layer 0),
            # then the uniform MoE stack scanned.
            for i, kind in enumerate(self.kinds):
                if kind == "D":
                    x = tf.attn_sublayer(params[f"layer_{i}"], x, positions, cfg)
                    x, a = tf.ffn_sublayer(params[f"layer_{i}"], x, cfg)
                    aux += a
            def body(p, h):
                h = tf.attn_sublayer(p, h, positions, cfg)
                return tf.ffn_sublayer(p, h, cfg, self.moe_impl, self.mesh)
            x, a = tf.scan_layers(params["blocks"], x, body, remat=remat)
            return x, aux + a
        if self.stacked:  # uniform dense
            def body(p, h):
                h = tf.attn_sublayer(p, h, positions, cfg)
                return tf.ffn_sublayer(p, h, cfg, self.moe_impl, self.mesh)
            return tf.scan_layers(params["blocks"], x, body, remat=remat)
        # python loop: heterogeneous dense (gemma3) or unrolled layouts
        for i, kind in enumerate(self.kinds):
            window, theta = tf.local_params(cfg, kind)
            p = params[f"layer_{i}"]
            def one(p, h, window=window, theta=theta):
                h = tf.attn_sublayer(p, h, positions, cfg, window=window, theta=theta)
                return tf.ffn_sublayer(p, h, cfg, self.moe_impl, self.mesh)
            f = jax.checkpoint(one) if remat else one
            x, a = f(p, x)
            aux += a
        return x, aux

    def _fwd_hybrid(self, params, x, positions, remat):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        for i, kind in enumerate(self.kinds):
            p = params[f"layer_{i}"]
            if kind == "R":
                def one(p, h):
                    h = h + griffin.recurrent_block(p["recurrent"], h, cfg)
                    return tf.ffn_sublayer(p, h, cfg)
            else:
                def one(p, h):
                    h = tf.attn_sublayer(
                        p, h, positions, cfg, window=cfg.sliding_window
                    )
                    return tf.ffn_sublayer(p, h, cfg)
            f = jax.checkpoint(one) if remat else one
            x, a = f(p, x)
            aux += a
        return x, aux

    def _fwd_vlm(self, params, x, positions, images, remat):
        cfg = self.cfg
        aux = jnp.float32(0.0)
        mem = images.astype(x.dtype) @ params["projector"]["w"].astype(x.dtype)
        for i in range(cfg.num_layers):
            p = params[f"layer_{i}"]
            if self._is_cross(i):
                mk, mv = attn.cross_kv(p["cross"], mem)
                def one(p, h, mk=mk, mv=mv):
                    h = tf.cross_sublayer(p, h, mk, mv, cfg)
                    h = tf.attn_sublayer(p, h, positions, cfg)
                    return tf.ffn_sublayer(p, h, cfg)
            else:
                def one(p, h):
                    h = tf.attn_sublayer(p, h, positions, cfg)
                    return tf.ffn_sublayer(p, h, cfg)
            f = jax.checkpoint(one) if remat else one
            x, a = f(p, x)
            aux += a
        return x, aux

    def _encode_audio(self, params, frames):
        cfg = self.cfg
        enc = frames.astype(jnp.dtype(cfg.param_dtype))
        enc = enc + params["enc_pos"]["table"].astype(enc.dtype)[None]
        def body(p, h):
            h = tf.attn_sublayer(p, h, None, cfg, causal=False)
            return tf.ffn_sublayer(p, h, cfg)
        remat = cfg.remat != "none"
        if self.stacked:
            enc, _ = tf.scan_layers(params["encoder"], enc, body, remat=remat)
        else:
            f = jax.checkpoint(body) if remat else body
            for i in range(cfg.encoder_layers):
                enc, _ = f(params[f"enc_layer_{i}"], enc)
        return layers.rms_norm(params["enc_norm"], enc, cfg.rms_norm_eps)

    def _fwd_audio(self, params, x, positions, frames, remat):
        cfg = self.cfg
        enc = self._encode_audio(params, frames)
        def body(p, h):
            h = tf.attn_sublayer(p, h, positions, cfg)
            mk, mv = attn.cross_kv(p["cross"], enc)
            h = tf.cross_sublayer(p, h, mk, mv, cfg)
            return tf.ffn_sublayer(p, h, cfg)
        return self._apply_layers(params, x, body, remat)

    # ----------------------------------------------------------------- cache

    def init_cache(
        self, batch: int, seq_len: int, dtype=None
    ) -> tuple[Params, Params]:
        """Returns (cache, cache_logical_axes)."""
        cfg = self.cfg
        cb = _CacheBuilder(dtype or jnp.dtype(cfg.cache_dtype))
        K, h = cfg.num_kv_heads, cfg.head_dim
        L = cfg.num_layers

        if cfg.family in ("dense", "moe") and self.stacked:
            n = L if cfg.family == "dense" else sum(
                1 for k in self.kinds if k == "M"
            )
            caches, axes = _kv_cache(cb, batch, seq_len, K, h, stacked_layers=n)
            cache = {"blocks": caches}
            cache_axes = {"blocks": axes}
            if cfg.family == "moe" and n != L:
                for i, kind in enumerate(self.kinds):
                    if kind == "D":
                        c, a = _kv_cache(cb, batch, seq_len, K, h)
                        cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = c, a
            return cache, cache_axes

        if cfg.family in ("dense", "moe"):  # looped: gemma3 or unrolled
            cache, cache_axes = {}, {}
            for i, kind in enumerate(self.kinds):
                window, _ = tf.local_params(cfg, kind)
                s = min(window, seq_len) if window else seq_len
                c, a = _kv_cache(cb, batch, s, K, h)
                cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = c, a
            return cache, cache_axes

        if cfg.family == "ssm":
            H, P, N = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
            if self.stacked:
                ssm, sa = cb.zeros(
                    (L, batch, H, P, N),
                    ("layers", "batch", "ssm_heads", None, "ssm_state"),
                    jnp.float32,
                )
                conv, ca = cb.zeros(
                    (L, batch, cfg.conv_width - 1, mamba2.conv_dim(cfg)),
                    ("layers", "batch", None, "ssm_inner"),
                )
                return {"blocks": {"ssm": ssm, "conv": conv}}, {
                    "blocks": {"ssm": sa, "conv": ca}
                }
            cache, cache_axes = {}, {}
            for i in range(L):
                ssm, sa = cb.zeros(
                    (batch, H, P, N),
                    ("batch", "ssm_heads", None, "ssm_state"), jnp.float32,
                )
                conv, ca = cb.zeros(
                    (batch, cfg.conv_width - 1, mamba2.conv_dim(cfg)),
                    ("batch", None, "ssm_inner"),
                )
                cache[f"layer_{i}"] = {"ssm": ssm, "conv": conv}
                cache_axes[f"layer_{i}"] = {"ssm": sa, "conv": ca}
            return cache, cache_axes

        if cfg.family == "hybrid":
            cache, cache_axes = {}, {}
            for i, kind in enumerate(self.kinds):
                if kind == "R":
                    hst, ha = cb.zeros(
                        (batch, cfg.rnn_width), ("batch", "rnn_width"), jnp.float32
                    )
                    conv, ca = cb.zeros(
                        (batch, cfg.rnn_conv_width - 1, cfg.rnn_width),
                        ("batch", None, "rnn_width"),
                    )
                    cache[f"layer_{i}"] = {"h": hst, "conv": conv}
                    cache_axes[f"layer_{i}"] = {"h": ha, "conv": ca}
                else:
                    s = min(cfg.sliding_window, seq_len)
                    c, a = _kv_cache(cb, batch, s, K, h)
                    cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = c, a
            return cache, cache_axes

        if cfg.family == "vlm":
            cache, cache_axes = {}, {}
            for i in range(L):
                c, a = _kv_cache(cb, batch, seq_len, K, h)
                if self._is_cross(i):
                    mk, ma = cb.zeros(
                        (batch, cfg.num_image_tokens, K, h),
                        ("batch", "patches", "act_kv_heads", "head_dim"),
                    )
                    mv, _ = cb.zeros(
                        (batch, cfg.num_image_tokens, K, h),
                        ("batch", "patches", "act_kv_heads", "head_dim"),
                    )
                    c = dict(c, mem_k=mk, mem_v=mv)
                    a = dict(a, mem_k=ma, mem_v=ma)
                cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = c, a
            return cache, cache_axes

        if cfg.family == "audio":
            mem_axes = ("batch", "frames", "act_kv_heads", "head_dim")
            if self.stacked:
                c, a = _kv_cache(cb, batch, seq_len, K, h, stacked_layers=L)
                mk, ma = cb.zeros(
                    (L,) + (batch, cfg.num_audio_frames, K, h),
                    ("layers",) + mem_axes,
                )
                mv, _ = cb.zeros(
                    (L,) + (batch, cfg.num_audio_frames, K, h),
                    ("layers",) + mem_axes,
                )
                return {"blocks": dict(c, mem_k=mk, mem_v=mv)}, {
                    "blocks": dict(a, mem_k=ma, mem_v=ma)
                }
            cache, cache_axes = {}, {}
            for i in range(L):
                c, a = _kv_cache(cb, batch, seq_len, K, h)
                mk, ma = cb.zeros((batch, cfg.num_audio_frames, K, h), mem_axes)
                mv, _ = cb.zeros((batch, cfg.num_audio_frames, K, h), mem_axes)
                cache[f"layer_{i}"] = dict(c, mem_k=mk, mem_v=mv)
                cache_axes[f"layer_{i}"] = dict(a, mem_k=ma, mem_v=ma)
            return cache, cache_axes

        raise ValueError(cfg.family)

    def init_paged_cache(
        self, num_blocks: int, block_size: int, dtype=None
    ) -> tuple[Params, Params]:
        """Paged KV cache for the serving path: each attention layer's
        {"k", "v"} become physical page pools ``(P, bs, K, h)`` shared by
        all rows through a per-request block table (serve/blocks.py).
        Page 0 is reserved as scratch (never mapped to a live request),
        so out-of-range writes land there harmlessly.  Same pytree
        structure as ``init_cache`` — decode_step just threads
        ``block_tables`` through.  dense/moe only (the families whose
        decode path is pure global attention)."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"paged cache supports dense/moe only, not {cfg.family}"
            )
        for kind in self.kinds:
            if tf.local_params(cfg, kind)[0]:
                raise ValueError(
                    "paged cache requires uniform global attention; "
                    "sliding-window layers keep the dense cache"
                )
        cb = _CacheBuilder(dtype or jnp.dtype(cfg.cache_dtype))
        K, h = cfg.num_kv_heads, cfg.head_dim
        axes = ("pages", "page_slot", "act_kv_heads", "head_dim")

        def pool(stacked_layers=0):
            shape = (num_blocks, block_size, K, h)
            a = axes
            if stacked_layers:
                shape, a = (stacked_layers,) + shape, ("layers",) + a
            k, ka = cb.zeros(shape, a)
            v, va = cb.zeros(shape, a)
            return {"k": k, "v": v}, {"k": ka, "v": va}

        if self.stacked:
            n = cfg.num_layers if cfg.family == "dense" else sum(
                1 for k in self.kinds if k == "M"
            )
            c, a = pool(stacked_layers=n)
            cache, cache_axes = {"blocks": c}, {"blocks": a}
            if cfg.family == "moe" and n != cfg.num_layers:
                for i, kind in enumerate(self.kinds):
                    if kind == "D":
                        cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = pool()
            return cache, cache_axes
        cache, cache_axes = {}, {}
        for i in range(cfg.num_layers):
            cache[f"layer_{i}"], cache_axes[f"layer_{i}"] = pool()
        return cache, cache_axes

    # ----------------------------------------------------------- decode step

    def decode_step(
        self, params: Params, cache: Params, tokens: jax.Array, pos: jax.Array,
        block_tables: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, Params]:
        """tokens: (B, 1) -> (logits (B,1,V) f32, values (B,1) f32, cache).

        ``pos`` is a scalar (lockstep batch — the PR 9 path, unchanged) or
        a (B,) int32 vector of per-row positions.  ``block_tables`` (B, nb)
        switches dense/moe attention onto the paged cache from
        ``init_paged_cache``.
        """
        cfg = self.cfg
        if block_tables is not None and cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"block_tables requires a dense/moe model, not {cfg.family}"
            )
        x = self._embed_decode(params, tokens, pos)
        new_cache = {}

        if cfg.family in ("dense", "moe") and self.stacked:
            def step(p, c, h):
                h, c2 = tf.attn_sublayer_decode(
                    p, c, h, pos, cfg, block_tables=block_tables
                )
                h, _ = tf.ffn_sublayer(p, h, cfg, self.moe_impl, self.mesh)
                return h, c2
            if cfg.family == "moe" and "layer_0" in params:
                for i, kind in enumerate(self.kinds):
                    if kind == "D":
                        x, c2 = tf.attn_sublayer_decode(
                            params[f"layer_{i}"], cache[f"layer_{i}"], x, pos,
                            cfg, block_tables=block_tables,
                        )
                        x, _ = tf.ffn_sublayer(params[f"layer_{i}"], x, cfg)
                        new_cache[f"layer_{i}"] = c2
            x, blocks_cache = tf.scan_decode_layers(
                params["blocks"], cache["blocks"], x, step
            )
            new_cache["blocks"] = blocks_cache
        elif cfg.family in ("dense", "moe"):
            for i, kind in enumerate(self.kinds):
                window, theta = tf.local_params(cfg, kind)
                x, c2 = tf.attn_sublayer_decode(
                    params[f"layer_{i}"], cache[f"layer_{i}"], x, pos, cfg,
                    window=window, theta=theta, block_tables=block_tables,
                )
                x, _ = tf.ffn_sublayer(params[f"layer_{i}"], x, cfg, self.moe_impl, self.mesh)
                new_cache[f"layer_{i}"] = c2
        elif cfg.family == "ssm":
            def step(p, c, h):
                out, c2 = mamba2.mamba2_decode_step(p["mixer"], c, h, cfg)
                return h + out, c2
            if self.stacked:
                x, blocks_cache = tf.scan_decode_layers(
                    params["blocks"], cache["blocks"], x, step
                )
                new_cache["blocks"] = blocks_cache
            else:
                for i in range(cfg.num_layers):
                    x, c2 = step(params[f"layer_{i}"], cache[f"layer_{i}"], x)
                    new_cache[f"layer_{i}"] = c2
        elif cfg.family == "hybrid":
            for i, kind in enumerate(self.kinds):
                p = params[f"layer_{i}"]
                c = cache[f"layer_{i}"]
                if kind == "R":
                    out, c2 = griffin.recurrent_decode_step(p["recurrent"], c, x, cfg)
                    x = x + out
                else:
                    x, c2 = tf.attn_sublayer_decode(
                        p, c, x, pos, cfg, window=cfg.sliding_window
                    )
                x, _ = tf.ffn_sublayer(p, x, cfg)
                new_cache[f"layer_{i}"] = c2
        elif cfg.family == "vlm":
            for i in range(cfg.num_layers):
                p = params[f"layer_{i}"]
                c = cache[f"layer_{i}"]
                if self._is_cross(i):
                    x = self._cross_decode(p, c, x)
                x, c2 = tf.attn_sublayer_decode(p, {"k": c["k"], "v": c["v"]}, x,
                                                pos, cfg)
                if self._is_cross(i):
                    c2 = dict(c2, mem_k=c["mem_k"], mem_v=c["mem_v"])
                x, _ = tf.ffn_sublayer(p, x, cfg)
                new_cache[f"layer_{i}"] = c2
        elif cfg.family == "audio":
            def step(p, c, h):
                h, c2 = tf.attn_sublayer_decode(p, {"k": c["k"], "v": c["v"]}, h,
                                                pos, cfg)
                h = self._cross_decode(p, c, h)
                h, _ = tf.ffn_sublayer(p, h, cfg)
                return h, dict(c2, mem_k=c["mem_k"], mem_v=c["mem_v"])
            if self.stacked:
                x, blocks_cache = tf.scan_decode_layers(
                    params["blocks"], cache["blocks"], x, step
                )
                new_cache["blocks"] = blocks_cache
            else:
                for i in range(cfg.num_layers):
                    x, c2 = step(params[f"layer_{i}"], cache[f"layer_{i}"], x)
                    new_cache[f"layer_{i}"] = c2
        else:
            raise ValueError(cfg.family)

        x = layers.rms_norm(params["final_norm"], x, cfg.rms_norm_eps)
        logits = layers.unembed(params["embedding"], x)
        values = jnp.einsum(
            "btd,dk->btk", x, params["value_head"]["w"].astype(x.dtype)
        )[..., 0].astype(jnp.float32)
        return logits, values, new_cache

    # ---------------------------------------------------------- prefill step

    def prefill_step(
        self, params: Params, cache: Params, tokens: jax.Array, pos: jax.Array,
        block_tables: jax.Array | None = None,
    ) -> tuple[jax.Array, jax.Array, Params]:
        """Chunked prefill: process a (B, C) token chunk whose row-b tokens
        sit at positions pos[b]..pos[b]+C-1, writing K/V into the cache and
        returning per-position logits — the fused-forward equivalent of C
        sequential ``decode_step`` calls (bit-exact with them; the parity
        pin in test_models covers it).  tokens: (B, C); pos: scalar or (B,)
        -> (logits (B,C,V) f32, values (B,C) f32, cache).  dense/moe with
        global attention only (the serving path)."""
        cfg = self.cfg
        if cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"prefill_step supports dense/moe only, not {cfg.family}; "
                "other families decode token-by-token"
            )
        C = tokens.shape[1]
        pos = jnp.asarray(pos)
        x = layers.embed(params["embedding"], tokens, jnp.dtype(cfg.param_dtype))
        if "gemma" in cfg.name:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if cfg.pos_embed == "learned":
            positions = tf._rope_positions(pos, C)
            x = x + layers.learned_pos(params["pos"], positions).astype(x.dtype)
        new_cache = {}
        if self.stacked:
            def step(p, c, h):
                h, c2 = tf.attn_sublayer_prefill(
                    p, c, h, pos, cfg, block_tables=block_tables
                )
                h, _ = tf.ffn_sublayer(p, h, cfg, self.moe_impl, self.mesh)
                return h, c2
            if cfg.family == "moe" and "layer_0" in params:
                for i, kind in enumerate(self.kinds):
                    if kind == "D":
                        x, c2 = tf.attn_sublayer_prefill(
                            params[f"layer_{i}"], cache[f"layer_{i}"], x, pos,
                            cfg, block_tables=block_tables,
                        )
                        x, _ = tf.ffn_sublayer(params[f"layer_{i}"], x, cfg)
                        new_cache[f"layer_{i}"] = c2
            x, blocks_cache = tf.scan_decode_layers(
                params["blocks"], cache["blocks"], x, step
            )
            new_cache["blocks"] = blocks_cache
        else:
            for i, kind in enumerate(self.kinds):
                if tf.local_params(cfg, kind)[0]:
                    raise ValueError(
                        "prefill_step requires global attention layers; "
                        "sliding-window layers decode token-by-token"
                    )
                x, c2 = tf.attn_sublayer_prefill(
                    params[f"layer_{i}"], cache[f"layer_{i}"], x, pos, cfg,
                    block_tables=block_tables,
                )
                x, _ = tf.ffn_sublayer(
                    params[f"layer_{i}"], x, cfg, self.moe_impl, self.mesh
                )
                new_cache[f"layer_{i}"] = c2
        x = layers.rms_norm(params["final_norm"], x, cfg.rms_norm_eps)
        logits = layers.unembed(params["embedding"], x)
        values = jnp.einsum(
            "btd,dk->btk", x, params["value_head"]["w"].astype(x.dtype)
        )[..., 0].astype(jnp.float32)
        return logits, values, new_cache

    def _embed_decode(self, params, tokens, pos):
        cfg = self.cfg
        x = layers.embed(params["embedding"], tokens, jnp.dtype(cfg.param_dtype))
        if "gemma" in cfg.name:
            x = x * jnp.asarray(math.sqrt(cfg.d_model), x.dtype)
        if cfg.pos_embed == "learned":
            pos = jnp.asarray(pos)
            if pos.ndim == 0:
                pe = layers.learned_pos(params["pos"], pos[None])[None]
            else:  # per-row positions: (B,) -> (B, 1, D)
                pe = layers.learned_pos(params["pos"], pos[:, None])
            x = x + pe.astype(x.dtype)
        return x

    def _cross_decode(self, p, c, x):
        """Cross-attention during decode (memory K/V precomputed in cache)."""
        cfg = self.cfg
        h = layers.rms_norm(p["cross_norm"], x, cfg.rms_norm_eps)
        q = jnp.einsum("btd,dhk->bthk", h, p["cross"]["wq"].astype(h.dtype))
        out = attn.decode_attention(q, c["mem_k"], c["mem_v"], jnp.int32(10**9))
        x = x + attn.output_project(p["cross"], out)
        h = layers.rms_norm(p["cross_ffn_norm"], x, cfg.rms_norm_eps)
        return x + layers.mlp(p["cross_mlp"], h)


def make_model(cfg: ArchConfig, moe_impl: str = "sort",
               unroll: bool = False, mesh=None) -> Model:
    return Model(cfg, moe_impl=moe_impl, unroll=unroll, mesh=mesh)
