"""Decoder stacks for the dense / MoE / hybrid / VLM / audio families.

Layer layout strategy (compile-time-friendly, see DESIGN.md §5):
  * uniform-pattern archs (llama3-405b, qwen2, qwen3, granite-moe, mamba2,
    whisper, deepseek's 27 MoE layers) use **scan-over-layers** with stacked
    parameters (leading logical axis "layers") — one traced layer body
    regardless of depth, which keeps the 126-layer 405B HLO small;
  * heterogeneous patterns (gemma3 local:global, griffin RRA, VLM cross-attn
    inserts) use a Python loop — their pattern scalars (window size, rope
    theta) must be static per layer.

Every layer body is optionally wrapped in jax.checkpoint (cfg.remat).
"""

from __future__ import annotations

import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig
from repro.kernels.flash_decode.ops import flash_decode
from repro.models import attention as attn
from repro.models import griffin, layers, mamba2, moe as moe_lib
from repro.param import ParamBuilder

Params = Any


# ---------------------------------------------------------------------------
# Stacked-parameter helpers (scan-over-layers)
# ---------------------------------------------------------------------------


def init_stacked(
    b: ParamBuilder, name: str, n: int, init_one: Callable[[ParamBuilder], None]
) -> None:
    """Initialize ``n`` copies of a layer and stack along a "layers" dim."""
    for i in range(n):
        with b.scope(f"__tmp_{name}_{i}"):
            init_one(b)
    # stack: pull the temp subtrees out and stack leaves
    params_root = b._subdict(b._params)
    axes_root = b._subdict(b._axes)
    stacked_p = jax.tree.map(
        lambda *xs: jnp.stack(xs),
        *[params_root.pop(f"__tmp_{name}_{i}") for i in range(n)],
    )
    axes_trees = [axes_root.pop(f"__tmp_{name}_{i}") for i in range(n)]
    stacked_a = jax.tree.map(
        lambda a, *_: ("layers",) + a,
        axes_trees[0],
        *axes_trees[1:],
        is_leaf=lambda x: isinstance(x, tuple),
    )
    params_root[name] = stacked_p
    axes_root[name] = stacked_a


def scan_layers(
    stacked: Params,
    x: jax.Array,
    layer_fn: Callable,
    *,
    remat: bool = True,
) -> tuple[jax.Array, jax.Array]:
    """Run x through scanned layers.  layer_fn(p, x) -> (x, aux_scalar)."""
    f = jax.checkpoint(layer_fn) if remat else layer_fn

    def body(carry, p):
        x, aux = carry
        x, a = f(p, x)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.float32(0.0)), stacked)
    return x, aux


def scan_decode_layers(
    stacked: Params,
    cache: Params,
    x: jax.Array,
    step_fn: Callable,
) -> tuple[jax.Array, Params]:
    """Decode step through scanned layers, threading per-layer cache.

    step_fn(p, cache_layer, x) -> (x, new_cache_layer).
    """

    def body(x, inp):
        p, c = inp
        x, c2 = step_fn(p, c, x)
        return x, c2

    x, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return x, new_cache


# ---------------------------------------------------------------------------
# Sublayers
# ---------------------------------------------------------------------------


def init_attn_layer(b: ParamBuilder, cfg: ArchConfig) -> None:
    dims = attn.AttnDims(cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
    layers.init_rms_norm(b, "attn_norm", cfg.d_model)
    attn.init_attention(b, "attn", dims, qkv_bias=cfg.qkv_bias, qk_norm=cfg.qk_norm)


def attn_sublayer(
    p: Params,
    x: jax.Array,
    positions: jax.Array,
    cfg: ArchConfig,
    *,
    window: int = 0,
    theta: float | None = None,
    causal: bool = True,
) -> jax.Array:
    h = layers.rms_norm(p["attn_norm"], x, cfg.rms_norm_eps)
    rope_pos = positions if cfg.pos_embed == "rope" else None
    q, k, v = attn.qkv_project(
        p["attn"], h, positions=rope_pos,
        rope_theta=theta if theta is not None else cfg.rope_theta,
        eps=cfg.rms_norm_eps,
    )
    if window:
        # blocked local attention materializes O(T·2W) probabilities;
        # checkpoint so they are recomputed (transiently) in backward
        f = jax.checkpoint(
            lambda q, k, v: attn.sliding_window_attention(
                q, k, v, window=window, softcap=cfg.attn_logit_softcap
            )
        )
        out = f(q, k, v)
    else:
        # full_attention carries a flash-attention custom VJP: backward
        # recomputes probabilities per KV chunk from (q, k, lse) — the
        # O(T·S) scan residuals this replaces were the dominant training
        # memory term (§Perf, qwen2 train_4k)
        out = attn.full_attention(
            q, k, v, causal=causal, softcap=cfg.attn_logit_softcap
        )
    return x + attn.output_project(p["attn"], out)


def _rope_positions(pos: jax.Array | None, width: int = 1) -> jax.Array | None:
    """Decode-time rope positions: scalar pos -> (1, width) lockstep row;
    per-row (B,) pos -> (B, width), row b at pos[b]..pos[b]+width-1."""
    if pos is None:
        return None
    pos = jnp.asarray(pos)
    base = pos[None, None] if pos.ndim == 0 else pos[:, None]
    return base + jnp.arange(width)[None, :] if width > 1 else base


def attn_sublayer_decode(
    p: Params,
    cache: dict,
    x: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    *,
    window: int = 0,
    theta: float | None = None,
    block_tables: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One-token decode.  cache: {"k": (B,S,K,h), "v": ...} dense, or
    {"k": (P,bs,K,h), "v": ...} page pools when ``block_tables`` is given.
    ``pos`` is a scalar (lockstep, the PR 9 path — unchanged) or a (B,)
    vector of per-row positions (the serving path)."""
    h = layers.rms_norm(p["attn_norm"], x, cfg.rms_norm_eps)
    positions = _rope_positions(pos) if cfg.pos_embed == "rope" else None
    q, k, v = attn.qkv_project(
        p["attn"], h, positions=positions,
        rope_theta=theta if theta is not None else cfg.rope_theta,
        eps=cfg.rms_norm_eps,
    )
    if block_tables is not None:
        if window or cfg.attn_logit_softcap:
            raise ValueError(
                "paged decode supports global attention without logit "
                "softcap only; sliding-window / softcap layers keep the "
                "dense cache"
            )
        kc, vc = attn.update_paged_kv_cache(
            cache["k"], cache["v"], k, v, block_tables, pos
        )
        out = flash_decode(q, kc, vc, pos, block_tables=block_tables)
        return x + attn.output_project(p["attn"], out), {"k": kc, "v": vc}
    S = cache["k"].shape[1]
    if window and S == window:
        kc, vc = griffin.ring_cache_update(cache["k"], cache["v"], k, v, pos, window)
        out = griffin.ring_decode_attention(q, kc, vc, pos, window)
    else:
        kc, vc = attn.update_kv_cache(cache["k"], cache["v"], k, v, pos)
        if cfg.attn_logit_softcap:
            # softcapped logits (gemma3) stay on the jnp oracle — the
            # Pallas decode kernel has no softcap path
            out = attn.decode_attention(
                q, kc, vc, pos, window=window, softcap=cfg.attn_logit_softcap
            )
        else:
            # the decode hot loop: Pallas flash_decode on TPU, its
            # bit-identical jnp oracle elsewhere (kernels/flash_decode)
            out = flash_decode(q, kc, vc, pos, window=window)
    return x + attn.output_project(p["attn"], out), {"k": kc, "v": vc}


def attn_sublayer_prefill(
    p: Params,
    cache: dict,
    x: jax.Array,
    pos: jax.Array,
    cfg: ArchConfig,
    *,
    block_tables: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """Chunked prefill: a (B, C, D) token chunk whose row-b tokens sit at
    positions pos[b]..pos[b]+C-1.  The chunk's K/V is written into the
    cache first, then the chunk attends to the whole cache with the
    per-row position mask — within-chunk causality falls out of the mask,
    so this is exactly C fused copies of ``attn_sublayer_decode`` (the
    prefill-vs-decode parity pin).  Global attention, no softcap (the
    serving path); rows past their prompt write out-of-range and are
    dropped (dense) or land on the scratch page (paged)."""
    if cfg.attn_logit_softcap:
        raise ValueError("chunked prefill does not support logit softcap")
    C = x.shape[1]
    h = layers.rms_norm(p["attn_norm"], x, cfg.rms_norm_eps)
    positions = _rope_positions(pos, C) if cfg.pos_embed == "rope" else None
    q, k, v = attn.qkv_project(
        p["attn"], h, positions=positions, rope_theta=cfg.rope_theta,
        eps=cfg.rms_norm_eps,
    )
    if block_tables is not None:
        kc, vc = attn.update_paged_kv_cache(
            cache["k"], cache["v"], k, v, block_tables, pos
        )
        from repro.kernels.flash_decode.ref import gather_pages

        out = attn.chunk_decode_attention(
            q, gather_pages(kc, block_tables), gather_pages(vc, block_tables),
            pos,
        )
    else:
        kc, vc = attn.update_kv_cache_chunk(cache["k"], cache["v"], k, v, pos)
        out = attn.chunk_decode_attention(q, kc, vc, pos)
    return x + attn.output_project(p["attn"], out), {"k": kc, "v": vc}


def init_ffn_layer(b: ParamBuilder, cfg: ArchConfig, kind: str) -> None:
    layers.init_rms_norm(b, "ffn_norm", cfg.d_model)
    if kind == "moe":
        moe_lib.init_moe(b, "moe", moe_dims(cfg))
    else:
        d_ff = cfg.d_ff_dense or cfg.d_ff
        layers.init_mlp(b, "mlp", cfg.d_model, d_ff)


def moe_dims(cfg: ArchConfig) -> moe_lib.MoEDims:
    return moe_lib.MoEDims(
        cfg.d_model,
        cfg.d_ff,
        cfg.num_experts,
        cfg.num_experts_per_tok,
        cfg.num_shared_experts,
        cfg.moe_capacity_factor,
    )


def ffn_sublayer(
    p: Params,
    x: jax.Array,
    cfg: ArchConfig,
    moe_impl: str = "sort",
    mesh=None,
) -> tuple[jax.Array, jax.Array]:
    h = layers.rms_norm(p["ffn_norm"], x, cfg.rms_norm_eps)
    if "moe" in p:
        out, aux = moe_lib.moe_ffn(
            p["moe"], h, moe_dims(cfg), impl=moe_impl, mesh=mesh
        )
        return x + out, aux
    return x + layers.mlp(p["mlp"], h), jnp.float32(0.0)


def init_cross_layer(b: ParamBuilder, cfg: ArchConfig) -> None:
    dims = attn.AttnDims(cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim)
    layers.init_rms_norm(b, "cross_norm", cfg.d_model)
    attn.init_attention(b, "cross", dims, qk_norm=cfg.qk_norm)
    layers.init_rms_norm(b, "cross_ffn_norm", cfg.d_model)
    layers.init_mlp(b, "cross_mlp", cfg.d_model, cfg.d_ff)


def cross_sublayer(p: Params, x: jax.Array, mem_k, mem_v, cfg: ArchConfig):
    h = layers.rms_norm(p["cross_norm"], x, cfg.rms_norm_eps)
    x = x + attn.cross_attention(p["cross"], h, mem_k, mem_v)
    h = layers.rms_norm(p["cross_ffn_norm"], x, cfg.rms_norm_eps)
    return x + layers.mlp(p["cross_mlp"], h)


# ---------------------------------------------------------------------------
# Layer-pattern utilities
# ---------------------------------------------------------------------------


def layer_kinds(cfg: ArchConfig) -> list[str]:
    """Expand cfg.layer_pattern cyclically over num_layers."""
    pat = cfg.layer_pattern or "G"
    return [pat[i % len(pat)] for i in range(cfg.num_layers)]


def local_params(cfg: ArchConfig, kind: str) -> tuple[int, float]:
    """(window, rope_theta) for an attention layer of the given kind."""
    if kind == "L" or kind == "A":
        # local layers use the short rope theta (gemma3: 10k local / 1M global)
        return cfg.sliding_window, 10_000.0 if kind == "L" else cfg.rope_theta
    return 0, cfg.rope_theta


def is_uniform(cfg: ArchConfig) -> bool:
    kinds = set(layer_kinds(cfg))
    return len(kinds) == 1 and cfg.cross_attn_every == 0
