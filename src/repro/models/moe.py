"""Mixture-of-Experts FFN with top-k routing and expert parallelism.

Two dispatch implementations:

  * ``sort`` (default, production) — Megablocks/MaxText-style: flatten the
    (token, k) assignments, stable-sort by expert id, compute each
    assignment's position inside its expert via searchsorted, scatter into a
    capacity-bounded (E, C, D) buffer, run the expert matmuls as one batched
    einsum, gather + weighted-combine back.  Gathers/scatters are memory ops
    — HLO FLOPs stay ≈ active FLOPs (top-k × tokens × expert size × cf),
    which keeps the 6·N_active·D roofline honest.
  * ``dense`` (ablation / small configs) — GShard-style one-hot dispatch and
    combine einsums.  Simple and collective-friendly but pays O(N·E·C·D)
    dispatch FLOPs and memory; used in tests and for the §Perf comparison.

Expert parallelism: the (E, ...) expert dims carry the "experts" logical
axis, sharded over the "model" mesh axis; XLA GSPMD inserts the all-to-all
for the sharded scatter/gather.  Shared experts (DeepSeekMoE) are a fused
dense MLP of width num_shared × d_ff, always active.

Load balancing uses the Switch-Transformer auxiliary loss
(E · Σ_e fraction_e · prob_e) plus a router z-loss.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.compat import shard_map
from repro.models import layers
from repro.param import ParamBuilder, fan_in_init, normal_init


class MoEDims(NamedTuple):
    d_model: int
    d_ff: int
    num_experts: int
    top_k: int
    num_shared: int
    capacity_factor: float


def init_moe(b: ParamBuilder, name: str, dims: MoEDims) -> None:
    d, f, E = dims.d_model, dims.d_ff, dims.num_experts
    with b.scope(name):
        b.param("router", (d, E), ("embed", "experts"), normal_init(0.02),
                dtype=jnp.float32)
        b.param("w_gate", (E, d, f), ("experts", "embed", "expert_mlp"), fan_in_init())
        b.param("w_up", (E, d, f), ("experts", "embed", "expert_mlp"), fan_in_init())
        b.param("w_down", (E, f, d), ("experts", "expert_mlp", "embed"), fan_in_init())
        if dims.num_shared:
            layers.init_mlp(b, "shared", d, dims.num_shared * f)


def capacity(num_tokens: int, dims: MoEDims, *, round_multiple: int = 8) -> int:
    c = math.ceil(num_tokens * dims.top_k * dims.capacity_factor / dims.num_experts)
    # MXU-friendly: round up to a multiple of 8, at least top_k.  Per-sequence
    # dispatch (small num_tokens, vmapped over B) passes round_multiple=1:
    # rounding a ~1-slot capacity up to 8 for every sequence in the batch
    # inflates the expert buffers and padded-slot FFN work ~8x.
    return max(dims.top_k, -(-c // round_multiple) * round_multiple)


def _routing(params, x_flat: jax.Array, dims: MoEDims):
    """Router probabilities and top-k assignment.  x_flat: (N, D) -> ..."""
    logits = (x_flat.astype(jnp.float32) @ params["router"])  # (N, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, dims.top_k)  # (N, k)
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)  # renorm
    # Switch aux loss: mean fraction routed (top-1 assignments) x mean prob
    E = dims.num_experts
    frac = jnp.mean(jax.nn.one_hot(top_e, E, dtype=jnp.float32), axis=(0, 1))
    mean_p = jnp.mean(probs, axis=0)
    aux = E * jnp.sum(frac * mean_p)
    zloss = jnp.mean(jax.nn.logsumexp(logits, axis=-1) ** 2)
    return top_p, top_e, aux, zloss


def _expert_ffn(params, xs: jax.Array) -> jax.Array:
    """xs: (E, C, D) -> (E, C, D) through each expert's SwiGLU."""
    dt = xs.dtype
    gate = jnp.einsum("ecd,edf->ecf", xs, params["w_gate"].astype(dt))
    up = jnp.einsum("ecd,edf->ecf", xs, params["w_up"].astype(dt))
    hidden = jax.nn.silu(gate.astype(jnp.float32)).astype(dt) * up
    return jnp.einsum("ecf,efd->ecd", hidden, params["w_down"].astype(dt))


def _sort_dispatch(params, x_flat: jax.Array, dims: MoEDims,
                   cap_round: int = 8):
    N, D = x_flat.shape
    E, k = dims.num_experts, dims.top_k
    C = capacity(N, dims, round_multiple=cap_round)
    top_p, top_e, aux, zloss = _routing(params, x_flat, dims)

    flat_e = top_e.reshape(-1)  # (N*k,)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))  # first slot per expert
    pos = jnp.arange(N * k) - starts[sorted_e]  # slot within expert
    token_of = order // k  # source token per sorted slot
    keep = pos < C

    # scatter tokens into the (E, C, D) expert buffer; dropped slots vanish
    buf = jnp.zeros((E, C, D), x_flat.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos, C)].set(
        x_flat[token_of], mode="drop"
    )
    out_buf = _expert_ffn(params, buf)  # (E, C, D)

    # gather back: invert the sort to (N, k) slots
    inv = jnp.argsort(order)  # (N*k,) sorted-slot index of assignment i
    slot_e = sorted_e[inv].reshape(N, k)
    slot_pos = pos[inv].reshape(N, k)
    slot_keep = keep[inv].reshape(N, k)
    gathered = out_buf[slot_e, jnp.clip(slot_pos, 0, C - 1)]  # (N, k, D)
    w = (top_p * slot_keep).astype(gathered.dtype)
    return jnp.einsum("nkd,nk->nd", gathered, w), aux, zloss


def _dense_dispatch(params, x_flat: jax.Array, dims: MoEDims,
                    cap_round: int = 8):
    """GShard-style einsum dispatch (ablation path)."""
    N, D = x_flat.shape
    E, k = dims.num_experts, dims.top_k
    C = capacity(N, dims, round_multiple=cap_round)
    top_p, top_e, aux, zloss = _routing(params, x_flat, dims)
    # position of each assignment inside its expert via cumsum of one-hots
    onehot = jax.nn.one_hot(top_e, E, dtype=jnp.int32)  # (N, k, E)
    flat = onehot.reshape(N * k, E)
    pos = jnp.cumsum(flat, axis=0) - flat  # (N*k, E) slots before this one
    pos = (pos * flat).sum(-1).reshape(N, k)  # (N, k)
    keep = pos < C
    # dispatch: (N, k, E, C) one-hot
    disp = (
        jax.nn.one_hot(top_e, E, dtype=x_flat.dtype)[..., None]
        * jax.nn.one_hot(jnp.where(keep, pos, C), C + 1, dtype=x_flat.dtype)[
            :, :, None, :C
        ]
    )  # (N, k, E, C)
    buf = jnp.einsum("nkec,nd->ecd", disp, x_flat)
    out_buf = _expert_ffn(params, buf)
    combine = disp * top_p[..., None, None].astype(x_flat.dtype)
    out = jnp.einsum("nkec,ecd->nd", combine, out_buf)
    return out, aux, zloss


def _local_pack(params, x_loc: jax.Array, dims: MoEDims, cap: int):
    """Route local tokens and pack them into a capacity buffer (E, C, D).

    Runs per-device inside shard_map; the scatter is device-local, so the
    only cross-device traffic in the a2a impl is the two all_to_alls.
    """
    N, D = x_loc.shape
    E, k = dims.num_experts, dims.top_k
    top_p, top_e, aux, zloss = _routing(params, x_loc, dims)
    flat_e = top_e.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    sorted_e = flat_e[order]
    starts = jnp.searchsorted(sorted_e, jnp.arange(E))
    pos = jnp.arange(N * k) - starts[sorted_e]
    token_of = order // k
    keep = pos < cap
    buf = jnp.zeros((E, cap, D), x_loc.dtype)
    buf = buf.at[sorted_e, jnp.where(keep, pos, cap)].set(
        x_loc[token_of], mode="drop"
    )
    meta = (order, sorted_e, pos, keep, top_p)
    return buf, meta, aux, zloss


def _local_combine(out_buf: jax.Array, meta, N: int, k: int, cap: int):
    order, sorted_e, pos, keep, top_p = meta
    inv = jnp.argsort(order)
    slot_e = sorted_e[inv].reshape(N, k)
    slot_pos = pos[inv].reshape(N, k)
    slot_keep = keep[inv].reshape(N, k)
    gathered = out_buf[slot_e, jnp.clip(slot_pos, 0, cap - 1)]
    w = (top_p * slot_keep).astype(gathered.dtype)
    return jnp.einsum("nkd,nk->nd", gathered, w)


def moe_ffn_a2a(
    params, x_flat: jax.Array, dims: MoEDims, mesh, model_axis: str = "model"
):
    """Expert-parallel MoE via explicit shard_map + all_to_all.

    Tokens stay sharded over the data axes; experts are sharded over the
    model axis.  Each device packs its local tokens into an (E, C_loc, D)
    capacity buffer, all_to_all sends each expert's slice to the device
    that owns it, local experts run one batched einsum, and the reverse
    all_to_all returns results for a local weighted combine.  Collective
    bytes = 2 x top_k x capacity_factor x token bytes — the GSPMD
    scatter/gather path this replaces all-gathered the full activation per
    layer (see EXPERIMENTS.md §Perf).
    """
    from jax.sharding import PartitionSpec as P

    E = dims.num_experts
    Pm = mesh.shape[model_axis]
    assert E % Pm == 0, (E, Pm)
    E_loc = E // Pm
    data_axes = tuple(a for a in mesh.axis_names if a != model_axis)
    n_data = 1
    for a in data_axes:
        n_data *= mesh.shape[a]
    N_glob, D = x_flat.shape
    n_loc = N_glob // n_data
    cap = capacity(n_loc, dims)

    def local_fn(x_loc, router, wg, wu, wd):
        buf, meta, aux, zloss = _local_pack(
            {"router": router}, x_loc, dims, cap
        )
        # (E, C, D) -> (Pm, E_loc, C, D); tiled all_to_all over the model
        # axis with split==concat axis exchanges the Pm blocks between
        # devices (a device-transpose): afterwards dim 0 indexes the SOURCE
        # device whose tokens our local experts must process.
        buf = buf.reshape(Pm, E_loc, cap, D)
        buf = jax.lax.all_to_all(buf, model_axis, 0, 0, tiled=True)
        xs = buf.transpose(1, 0, 2, 3).reshape(E_loc, Pm * cap, D)
        out = _expert_ffn({"w_gate": wg, "w_up": wu, "w_down": wd}, xs)
        out = out.reshape(E_loc, Pm, cap, D).transpose(1, 0, 2, 3)
        out = jax.lax.all_to_all(out, model_axis, 0, 0, tiled=True)
        out_buf = out.reshape(E, cap, D)
        y = _local_combine(out_buf, meta, x_loc.shape[0], dims.top_k, cap)
        # average aux terms over every mesh axis so the output is replicated
        aux = jax.lax.pmean(aux, data_axes + (model_axis,))
        zloss = jax.lax.pmean(zloss, data_axes + (model_axis,))
        return y, aux, zloss

    first = data_axes if data_axes else None
    fn = shard_map(
        local_fn,
        mesh=mesh,
        in_specs=(
            P(first, None),  # tokens: data-sharded
            P(),  # router replicated
            P(model_axis), P(model_axis), P(model_axis),  # expert shards
        ),
        out_specs=(P(first, None), P(), P()),
    )
    y, aux, zloss = fn(
        x_flat, params["router"], params["w_gate"], params["w_up"],
        params["w_down"],
    )
    return y.reshape(N_glob, D), aux, zloss


def moe_ffn(
    params, x: jax.Array, dims: MoEDims, impl: str = "sort", mesh=None
) -> tuple[jax.Array, jax.Array]:
    """x: (B, T, D) -> (out (B, T, D), aux_loss scalar).

    The ``sort``/``dense`` paths dispatch **per sequence** (vmap over B):
    capacity slots are assigned by cumulative position, so contending for
    them across the flattened B*T stream would let one sequence's suffix
    evict another sequence's prefix from an expert — breaking the
    autoregressive causality invariant (test_causality.py).  Per-row
    dispatch keeps slot assignment causal within each sequence and
    independent across them.

    The ``a2a`` path still routes the flattened B*T stream (per-sequence
    dispatch inside its shard_map would change the all_to_all payload
    shapes): with a tight ``capacity_factor`` its drops can differ from
    ``sort``/``dense`` — cross-sequence slot contention within a data
    shard.  Equivalence to ``sort`` holds at generous capacity (the regime
    test_perf_features.py checks); don't mix impls at small capacity
    factors.
    """
    B, T, D = x.shape
    x_flat = x.reshape(B * T, D)
    if impl == "a2a":
        if mesh is None:
            raise ValueError("moe impl 'a2a' needs a mesh")
        out, aux, zloss = moe_ffn_a2a(params, x_flat, dims, mesh)
    elif impl in ("sort", "dense"):
        fn = _sort_dispatch if impl == "sort" else _dense_dispatch
        out, aux, zloss = jax.vmap(
            lambda xr: fn(params, xr, dims, cap_round=1)
        )(x)
        out = out.reshape(B * T, D)
        aux = jnp.mean(aux)
        zloss = jnp.mean(zloss)
    else:
        raise ValueError(f"unknown moe impl {impl!r}")
    if dims.num_shared:
        out = out + layers.mlp(params["shared"], x).reshape(B * T, D)
    return out.reshape(B, T, D), aux + 1e-3 * zloss
