"""GQA attention: parameter init + forward (train/prefill) + decode paths.

Three compute paths, chosen by workload:

  * ``full_attention`` — flash-style *chunked* online-softmax in pure jnp
    (lax.scan over KV chunks) with a flash-attention custom VJP.  Never
    materializes the (T, S) matrix, so prefill_32k lowers with bounded
    memory on any backend, and training memory is O(T·chunk).  The Pallas
    forward kernel (repro/kernels/flash_attention, same blocking) is the
    TPU inference/prefill fast path exposed via its ops.py wrapper; this
    jnp path is its oracle-structure twin and the training path.
  * ``sliding_window_attention`` — blocked local attention (each query block
    attends to its own + previous KV block), O(T·2W) compute.
  * ``decode_attention`` — single-token query against a (possibly very long)
    KV cache; O(S) einsum, no materialization issue.

All paths support GQA via the (K, G) head grouping, optional qk-norm
(RMSNorm per head, qwen3/gemma3), optional QKV bias (qwen2), and RoPE.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models import layers
from repro.param import ParamBuilder, fan_in_init, ones_init, zeros_init

NEG_INF = layers.NEG_INF


class AttnDims(NamedTuple):
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int


# ---------------------------------------------------------------------------
# Params
# ---------------------------------------------------------------------------


def init_attention(
    b: ParamBuilder,
    name: str,
    dims: AttnDims,
    *,
    qkv_bias: bool = False,
    qk_norm: bool = False,
) -> None:
    d, H, K, h = dims
    with b.scope(name):
        b.param("wq", (d, H, h), ("embed", "heads", "head_dim"), fan_in_init())
        b.param("wk", (d, K, h), ("embed", "kv_heads", "head_dim"), fan_in_init())
        b.param("wv", (d, K, h), ("embed", "kv_heads", "head_dim"), fan_in_init())
        b.param("wo", (H, h, d), ("heads", "head_dim", "embed"), fan_in_init())
        if qkv_bias:
            b.param("bq", (H, h), ("heads", "head_dim"), zeros_init(), dtype=jnp.float32)
            b.param("bk", (K, h), ("kv_heads", "head_dim"), zeros_init(), dtype=jnp.float32)
            b.param("bv", (K, h), ("kv_heads", "head_dim"), zeros_init(), dtype=jnp.float32)
        if qk_norm:
            b.param("q_norm", (h,), ("head_dim",), ones_init(), dtype=jnp.float32)
            b.param("k_norm", (h,), ("head_dim",), ones_init(), dtype=jnp.float32)


def _head_rms(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * scale).astype(x.dtype)


def qkv_project(
    params,
    x: jax.Array,
    *,
    positions: jax.Array | None,
    rope_theta: float,
    eps: float = 1e-6,
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """x: (B, T, D) -> q (B,T,H,h), k/v (B,T,K,h), RoPE applied."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    k = jnp.einsum("btd,dhk->bthk", x, params["wk"].astype(dt))
    v = jnp.einsum("btd,dhk->bthk", x, params["wv"].astype(dt))
    if "bq" in params:
        q = q + params["bq"].astype(dt)
        k = k + params["bk"].astype(dt)
        v = v + params["bv"].astype(dt)
    if "q_norm" in params:
        q = _head_rms(q, params["q_norm"], eps)
        k = _head_rms(k, params["k_norm"], eps)
    if positions is not None:
        q = layers.apply_rope(q, positions, rope_theta)
        k = layers.apply_rope(k, positions, rope_theta)
    return q, k, v


def output_project(params, out: jax.Array) -> jax.Array:
    """out: (B, T, H, h) -> (B, T, D)."""
    return jnp.einsum("bthk,hkd->btd", out, params["wo"].astype(out.dtype))


# ---------------------------------------------------------------------------
# Chunked (flash-style) full attention
# ---------------------------------------------------------------------------


def _group_heads(q: jax.Array, num_kv: int) -> jax.Array:
    """(B, T, H, h) -> (B, T, K, G, h)."""
    b, t, H, h = q.shape
    return q.reshape(b, t, num_kv, H // num_kv, h)


def _chunk_kv(x: jax.Array, n_chunks: int, chunk: int):
    """(B, S, K, h) -> (n, B, chunk, K, h)."""
    B, S, K, h = x.shape
    return x.reshape(B, n_chunks, chunk, K, h).transpose(1, 0, 2, 3, 4)


def _fa_forward(q, k, v, causal, chunk, softcap):
    """Chunked online-softmax forward.  Returns (out, lse) with
    out: (B, T, H, h) and lse: (B, K, G, T) log-sum-exp (for the VJP)."""
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = _group_heads(q, K) * (h**-0.5)
    kc = _chunk_kv(k, n_chunks, chunk)
    vc = _chunk_kv(v, n_chunks, chunk)
    q_pos = jnp.arange(T)

    def body(carry, inputs):
        m, l, acc = carry
        idx, kb, vb = inputs
        logits = jnp.einsum("btkgh,bskh->bkgts", qg, kb).astype(jnp.float32)
        if softcap > 0:
            logits = softcap * jnp.tanh(logits / softcap)
        k_pos = idx * chunk + jnp.arange(chunk)
        valid = k_pos < S
        if causal:
            valid = valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
            logits = jnp.where(valid[None, None, None], logits, NEG_INF)
        else:
            logits = jnp.where(valid[None, None, None, None], logits, NEG_INF)
        m_new = jnp.maximum(m, logits.max(axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        scale = jnp.exp(m - m_new)
        l_new = l * scale + p.sum(axis=-1)
        acc_new = acc * scale[..., None] + jnp.einsum(
            "bkgts,bskh->bkgth", p.astype(vb.dtype), vb
        ).astype(jnp.float32)
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((B, K, G, T), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((B, K, G, T), jnp.float32)
    acc0 = jnp.zeros((B, K, G, T, h), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, acc0), (jnp.arange(n_chunks), kc, vc)
    )
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    out = out.transpose(0, 3, 1, 2, 4).reshape(B, T, H, h)
    return out.astype(q.dtype), lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _fa(q, k, v, causal, chunk, softcap):
    out, _ = _fa_forward(q, k, v, causal, chunk, softcap)
    return out


def _fa_fwd(q, k, v, causal, chunk, softcap):
    out, lse = _fa_forward(q, k, v, causal, chunk, softcap)
    return out, (q, k, v, out, lse)


def _fa_bwd(causal, chunk, softcap, res, do):
    """Flash-attention backward: recompute p per KV chunk from (q, k, lse)
    instead of saving the (T, S) probabilities — O(T·chunk) live memory.

        p    = exp(q k^T · s − lse)
        dv   = p^T do
        dp   = do v^T
        ds   = p ⊙ (dp − Δ),  Δ_t = Σ_h do_t ⊙ out_t
        dq  += ds k · s ;  dk  = ds^T q · s
    """
    q, k, v, out, lse = res
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    sm = h**-0.5
    chunk = min(chunk, S)
    n_chunks = -(-S // chunk)
    pad = n_chunks * chunk - S
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qg = _group_heads(q, K).astype(jnp.float32)  # (B,T,K,G,h), unscaled
    dog = _group_heads(do, K).astype(jnp.float32)
    outg = _group_heads(out, K).astype(jnp.float32)
    delta = jnp.einsum("btkgh,btkgh->bkgt", dog, outg)  # (B,K,G,T)
    kc = _chunk_kv(k, n_chunks, chunk)
    vc = _chunk_kv(v, n_chunks, chunk)
    q_pos = jnp.arange(T)

    def body(dq_acc, inputs):
        idx, kb, vb = inputs
        kbf = kb.astype(jnp.float32)
        logits = sm * jnp.einsum("btkgh,bskh->bkgts", qg, kbf)
        if softcap > 0:
            tanh_arg = logits / softcap
            logits_capped = softcap * jnp.tanh(tanh_arg)
        else:
            logits_capped = logits
        k_pos = idx * chunk + jnp.arange(chunk)
        valid = k_pos < S
        if causal:
            valid = valid[None, :] & (k_pos[None, :] <= q_pos[:, None])
            mask = valid[None, None, None]
        else:
            mask = valid[None, None, None, None]
        p = jnp.where(mask, jnp.exp(logits_capped - lse[..., None]), 0.0)
        dv = jnp.einsum("bkgts,btkgh->bskh", p, dog)  # (B,chunk,K,h)
        dp = jnp.einsum("btkgh,bskh->bkgts", dog, vb.astype(jnp.float32))
        ds = p * (dp - delta[..., None])
        if softcap > 0:  # chain rule through the softcap tanh
            ds = ds * (1.0 - jnp.tanh(tanh_arg) ** 2)
        dq_acc = dq_acc + sm * jnp.einsum("bkgts,bskh->btkgh", ds, kbf)
        dk = sm * jnp.einsum("bkgts,btkgh->bskh", ds, qg)
        return dq_acc, (dk, dv)

    dq0 = jnp.zeros((B, T, K, G, h), jnp.float32)
    dq, (dks, dvs) = jax.lax.scan(
        body, dq0, (jnp.arange(n_chunks), kc, vc)
    )
    dk = dks.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, K, h)
    dv = dvs.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, K, h)
    if pad:
        dk, dv = dk[:, :S], dv[:, :S]
    return (
        dq.reshape(B, T, H, h).astype(q.dtype),
        dk.astype(k.dtype),
        dv.astype(v.dtype),
    )


_fa.defvjp(_fa_fwd, _fa_bwd)


@functools.partial(jax.jit, static_argnames=("causal", "chunk", "softcap"))
def full_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    chunk: int = 1024,
    softcap: float = 0.0,
) -> jax.Array:
    """Online-softmax attention over KV chunks with a flash-attention
    custom VJP (backward recomputes probabilities blockwise — O(T·chunk)
    memory instead of O(T·S); see EXPERIMENTS.md §Perf).

    q: (B, T, H, h); k, v: (B, S, K, h).  Returns (B, T, H, h).
    """
    return _fa(q, k, v, causal, chunk, softcap)


# ---------------------------------------------------------------------------
# Blocked sliding-window attention
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("window", "softcap"))
def sliding_window_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int,
    softcap: float = 0.0,
) -> jax.Array:
    """Causal local attention with window W, blocked O(T·2W).

    Each query block of size W attends to its own and the previous KV block;
    the causal + window mask inside that 2W slab is exact.
    """
    B, T, H, h = q.shape
    K = k.shape[2]
    G = H // K
    W = min(window, T)
    nb = -(-T // W)
    pad = nb * W - T
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(B, nb, W, H, h) * (h**-0.5)
    kb = k.reshape(B, nb, W, K, h)
    vb = v.reshape(B, nb, W, K, h)
    # previous block (zeros for block 0)
    k_prev = jnp.concatenate([jnp.zeros_like(kb[:, :1]), kb[:, :-1]], axis=1)
    v_prev = jnp.concatenate([jnp.zeros_like(vb[:, :1]), vb[:, :-1]], axis=1)
    k2 = jnp.concatenate([k_prev, kb], axis=2)  # (B, nb, 2W, K, h)
    v2 = jnp.concatenate([v_prev, vb], axis=2)
    qg = qb.reshape(B, nb, W, K, G, h)
    logits = jnp.einsum("bnwkgh,bnskh->bnkgws", qg, k2).astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    # mask: query index (global) i = n*W + w; key index j = (n-1)*W + s
    w_idx = jnp.arange(W)[:, None]
    s_idx = jnp.arange(2 * W)[None, :]
    rel = (w_idx + W) - s_idx  # = i - j, independent of block n
    mask = (rel >= 0) & (rel < window)
    # block 0 has no previous block: forbid s < W there
    blk = jnp.arange(nb)
    first = (blk == 0)[:, None, None]  # (nb,1,1)
    mask = mask[None] & ~(first & (s_idx < W)[None])
    logits = jnp.where(mask[None, :, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bnkgws,bnskh->bnwkgh", p.astype(v2.dtype), v2)
    out = out.reshape(B, nb * W, H, h)[:, :T]
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single new token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    softcap: float = 0.0,
) -> jax.Array:
    """q: (B, 1, H, h); caches: (B, S, K, h); pos: scalar current position
    (lockstep batch), or per-row (B,) int32 positions (ragged batch — the
    continuous-batching serving path).

    Row b attends to cache entries <= pos[b] (and > pos[b] - window when
    local).  The scalar form is unchanged from PR 9 and stays bit-exact.
    """
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    if k_cache.dtype.itemsize == 1:  # fp8-quantized cache: compute in bf16
        k_cache = k_cache.astype(jnp.bfloat16)
        v_cache = v_cache.astype(jnp.bfloat16)
    qg = q.reshape(B, K, G, h) * (h**-0.5)
    logits = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    k_pos = jnp.arange(S)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        valid = k_pos <= pos
        if window:
            valid &= k_pos > pos - window
        mask = valid[None, None, None, :]
    else:
        valid = k_pos[None, :] <= pos[:, None]
        if window:
            valid &= k_pos[None, :] > (pos[:, None] - window)
        mask = valid[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, h).astype(q.dtype)


def chunk_decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    softcap: float = 0.0,
) -> jax.Array:
    """Chunked-prefill attention: a (B, C, H, h) query chunk whose row-b
    queries sit at absolute positions pos[b]..pos[b]+C-1, attending to a
    (B, S, K, h) cache that ALREADY holds the chunk's own K/V (written
    before this call).  The position mask s <= pos[b] + i gives exact
    causality both against the cached prefix and within the chunk —
    ``decode_attention`` is the C == 1 special case.  Global attention
    only (the serving path)."""
    B, C, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    if k_cache.dtype.itemsize == 1:
        k_cache = k_cache.astype(jnp.bfloat16)
        v_cache = v_cache.astype(jnp.bfloat16)
    qg = q.reshape(B, C, K, G, h) * (h**-0.5)
    logits = jnp.einsum("bckgh,bskh->bkgcs", qg, k_cache).astype(jnp.float32)
    if softcap > 0:
        logits = softcap * jnp.tanh(logits / softcap)
    pos = jnp.asarray(pos)
    q_pos = pos.reshape(-1, 1) + jnp.arange(C)[None, :]  # (B|1, C)
    q_pos = jnp.broadcast_to(q_pos, (B, C))
    valid = jnp.arange(S)[None, None, :] <= q_pos[..., None]  # (B, C, S)
    logits = jnp.where(valid[:, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgcs,bskh->bckgh", p.astype(v_cache.dtype), v_cache)
    return out.reshape(B, C, H, h).astype(q.dtype)


def update_kv_cache(
    k_cache: jax.Array, v_cache: jax.Array, k: jax.Array, v: jax.Array, pos
) -> tuple[jax.Array, jax.Array]:
    """Write the new (B, 1, K, h) kv at position ``pos`` (scalar — the
    lockstep path, unchanged) or at per-row positions ((B,) int32)."""
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        k_cache = jax.lax.dynamic_update_slice_in_dim(k_cache, k.astype(k_cache.dtype), pos, 1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(v_cache, v.astype(v_cache.dtype), pos, 1)
        return k_cache, v_cache
    return update_kv_cache_chunk(k_cache, v_cache, k, v, pos)


def update_kv_cache_chunk(
    k_cache: jax.Array, v_cache: jax.Array, k: jax.Array, v: jax.Array, pos
) -> tuple[jax.Array, jax.Array]:
    """Write a (B, C, K, h) kv chunk at per-row start positions ``pos``
    ((B,) int32 — row b's token i lands at cache slot pos[b] + i).
    Out-of-range slots are dropped, not clamped: a padded final prefill
    chunk must never clobber the cache tail."""
    B, C = k.shape[0], k.shape[1]
    b_idx = jnp.arange(B)[:, None]
    s_idx = jnp.asarray(pos).reshape(-1, 1) + jnp.arange(C)[None, :]
    k_cache = k_cache.at[b_idx, s_idx].set(k.astype(k_cache.dtype), mode="drop")
    v_cache = v_cache.at[b_idx, s_idx].set(v.astype(v_cache.dtype), mode="drop")
    return k_cache, v_cache


def update_paged_kv_cache(
    k_pages: jax.Array,
    v_pages: jax.Array,
    k: jax.Array,
    v: jax.Array,
    block_tables: jax.Array,
    pos: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Write a (B, C, K, h) kv chunk into (P, bs, K, h) page pools through
    a (B, nb) block table at per-row start positions ``pos``.

    Logical position p = pos[b] + i maps to page block_tables[b, p // bs]
    at offset p % bs.  Positions past the table (padded prefill tails)
    redirect to the reserved scratch page 0 at offset 0 — the allocator
    never maps page 0 to a live row, so those writes are inert; distinct
    live rows hold disjoint pages, so the scatter never races."""
    P, bs = k_pages.shape[0], k_pages.shape[1]
    B, C = k.shape[0], k.shape[1]
    nb = block_tables.shape[1]
    p_idx = jnp.asarray(pos).reshape(-1, 1) + jnp.arange(C)[None, :]  # (B, C)
    in_range = p_idx < nb * bs
    blk = jnp.minimum(p_idx // bs, nb - 1)
    phys = jnp.take_along_axis(
        jnp.asarray(block_tables, jnp.int32), blk, axis=1
    )
    phys = jnp.where(in_range, phys, 0)
    off = jnp.where(in_range, p_idx % bs, 0)
    k_pages = k_pages.at[phys, off].set(k.astype(k_pages.dtype))
    v_pages = v_pages.at[phys, off].set(v.astype(v_pages.dtype))
    return k_pages, v_pages


# ---------------------------------------------------------------------------
# Cross attention (VLM image layers / whisper encoder-decoder)
# ---------------------------------------------------------------------------


def cross_kv(params, memory: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Project encoder/vision memory (B, S, D) to cross-attn K/V."""
    dt = memory.dtype
    k = jnp.einsum("bsd,dkh->bskh", memory, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dkh->bskh", memory, params["wv"].astype(dt))
    return k, v


def cross_attention(params, x: jax.Array, k: jax.Array, v: jax.Array) -> jax.Array:
    """Full (non-causal) attention from x (B,T,D) onto precomputed memory K/V."""
    dt = x.dtype
    q = jnp.einsum("btd,dhk->bthk", x, params["wq"].astype(dt))
    if "q_norm" in params:
        q = _head_rms(q, params["q_norm"], 1e-6)
    out = full_attention(q, k, v, causal=False)
    return output_project(params, out)
