"""IMPALA for Sebulba: the conv actor-critic network and the V-trace agent.

Batched apply (Sebulba actors do *batched* inference on an actor core —
paper Fig. 3).  The torso is a small residual conv stack (the IMPALA
"shallow" net scaled to HostPong frames); the paper's data-efficiency
experiments scale channels/blocks, which `channels`/`blocks` expose.

``ImpalaAgent`` is the default Sebulba agent and the reference
implementation of the ``repro.api`` protocol (see repro/api/agent.py):
feed-forward (empty () carry), on-policy (no importance weights, no
priorities), no extras — the all-defaults ``AgentSpec``.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.api import ActAux, AgentSpec, LossAux
from repro.param import ParamBuilder, fan_in_init, zeros_init
from repro.rl import losses


def _conv(params, x: jax.Array, stride: int = 1) -> jax.Array:
    return (
        jax.lax.conv_general_dilated(
            x, params["w"], (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        + params["b"]
    )


def init_conv_torso(
    b: ParamBuilder, obs_shape: tuple[int, ...],
    channels: Sequence[int], blocks: int, hidden: int,
) -> None:
    """Residual conv stack + trunk params (the IMPALA "shallow" torso).

    Shared by the feed-forward ``ConvActorCritic`` and the recurrent net
    (repro/agents/recurrent.py), which mounts an RG-LRU core on the trunk
    features instead of heads directly.
    """
    h, w, c = obs_shape
    for i, ch in enumerate(channels):
        with b.scope(f"conv_{i}"):
            b.param("w", (3, 3, c, ch), (None,) * 4, fan_in_init())
            b.param("b", (ch,), (None,), zeros_init())
        for j in range(blocks):
            for k in (0, 1):
                with b.scope(f"res_{i}_{j}_{k}"):
                    b.param("w", (3, 3, ch, ch), (None,) * 4, fan_in_init())
                    b.param("b", (ch,), (None,), zeros_init())
        c = ch
        h, w = -(-h // 2), -(-w // 2)
    flat = h * w * c
    with b.scope("trunk"):
        b.param("w", (flat, hidden), (None, None), fan_in_init())
        b.param("b", (hidden,), (None,), zeros_init())


def apply_conv_torso(
    params, obs: jax.Array, channels: Sequence[int], blocks: int
) -> jax.Array:
    """obs (B, H, W, C) -> trunk features (B, hidden)."""
    x = obs
    for i, ch in enumerate(channels):
        x = _conv(params[f"conv_{i}"], x, stride=1)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )
        for j in range(blocks):
            y = jax.nn.relu(x)
            y = _conv(params[f"res_{i}_{j}_0"], y)
            y = jax.nn.relu(y)
            y = _conv(params[f"res_{i}_{j}_1"], y)
            x = x + y
    x = jax.nn.relu(x).reshape(x.shape[0], -1)
    return jax.nn.relu(x @ params["trunk"]["w"] + params["trunk"]["b"])


class ConvActorCritic:
    def __init__(self, num_actions: int, channels: Sequence[int] = (16, 32),
                 blocks: int = 1, hidden: int = 256):
        self.num_actions = num_actions
        self.channels = tuple(channels)
        self.blocks = blocks
        self.hidden = hidden

    def init(self, rng: jax.Array, obs_shape: tuple[int, ...]):
        b = ParamBuilder(rng, dtype=jnp.float32)
        init_conv_torso(b, obs_shape, self.channels, self.blocks, self.hidden)
        with b.scope("policy"):
            b.param("w", (self.hidden, self.num_actions), (None, None),
                    fan_in_init(0.01))
            b.param("b", (self.num_actions,), (None,), zeros_init())
        with b.scope("value"):
            b.param("w", (self.hidden, 1), (None, None), fan_in_init())
            b.param("b", (1,), (None,), zeros_init())
        params, _ = b.build()
        return params

    def apply(self, params, obs: jax.Array):
        """obs (B, H, W, C) -> (logits (B, A), values (B,))."""
        x = apply_conv_torso(params, obs, self.channels, self.blocks)
        logits = x @ params["policy"]["w"] + params["policy"]["b"]
        values = (x @ params["value"]["w"] + params["value"]["b"])[:, 0]
        return logits, values


class ImpalaAgent:
    """Default Sebulba agent: batched-inference actor + V-trace learner.

    Implements the canonical ``repro.api`` agent protocol with the
    all-defaults capability spec — any network with ``init(rng,
    obs_shape)`` / ``apply(params, obs) -> (logits, values)`` plugs in
    (ConvActorCritic for frames, BatchedMLPActorCritic for vector obs).
    """

    spec = AgentSpec()  # feed-forward, on-policy, no extras

    def __init__(self, network, config):
        self.net = network
        self.cfg = config  # a SebulbaConfig (loss coefficients + clips)

    def init(self, rng, obs_shape):
        return self.net.init(rng, obs_shape)

    def initial_carry(self, batch: int):
        return ()  # feed-forward: nothing to thread

    def act(self, params, obs, rng, carry=()):
        """Batched acting: (params, obs (B, ...), rng, () carry) ->
        (actions (B,), ActAux(logp (B,), () extras), () carry).  Traced
        inside Sebulba's fused donated act-step, so it must be jit-pure
        and extras must be a fixed-shape pytree (its storage is
        preallocated in the device trajectory ring via ``jax.eval_shape``).
        """
        logits, _ = self.net.apply(params, obs)
        actions = jax.random.categorical(rng, logits)
        logp = losses.log_prob(logits, actions)
        return actions, ActAux(logp), ()

    def _forward(self, params, traj):
        """Run the net over a trajectory batch -> (logits (B,T,A),
        values (B,T), bootstrap values (B,)).  Shared by the on-policy and
        replay losses so the flatten/bootstrap plumbing exists once."""
        B, T = traj.actions.shape
        obs_flat = jax.tree.map(
            lambda o: o.reshape((B * T,) + o.shape[2:]), traj.obs
        )
        logits, values = self.net.apply(params, obs_flat)
        logits = logits.reshape(B, T, -1)
        values = values.reshape(B, T)
        _, bootstrap = self.net.apply(params, traj.bootstrap_obs)
        return logits, values, bootstrap

    @staticmethod
    def _metrics(out) -> dict:
        return {
            "loss": out.total, "pg": out.pg, "value": out.value,
            "entropy": out.entropy, "rho": out.mean_rho,
        }

    def loss(self, params, traj, weights=None):
        if weights is not None:
            raise ValueError(
                "ImpalaAgent is on-policy (AgentSpec.replay=False) and "
                "does not apply importance weights; use ReplayImpalaAgent "
                "for weighted replay losses"
            )
        cfg = self.cfg
        logits, values, bootstrap = self._forward(params, traj)
        out = losses.impala_loss(
            logits, values, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, bootstrap,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        return out.total, LossAux(self._metrics(out))
