"""IMPALA-style conv actor-critic network for Sebulba.

Batched apply (Sebulba actors do *batched* inference on an actor core —
paper Fig. 3).  The torso is a small residual conv stack (the IMPALA
"shallow" net scaled to HostPong frames); the paper's data-efficiency
experiments scale channels/blocks, which `channels`/`blocks` expose.
"""

from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.param import ParamBuilder, fan_in_init, zeros_init


def _conv(params, x: jax.Array, stride: int = 1) -> jax.Array:
    return (
        jax.lax.conv_general_dilated(
            x, params["w"], (stride, stride), "SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
        )
        + params["b"]
    )


def init_conv_torso(
    b: ParamBuilder, obs_shape: tuple[int, ...],
    channels: Sequence[int], blocks: int, hidden: int,
) -> None:
    """Residual conv stack + trunk params (the IMPALA "shallow" torso).

    Shared by the feed-forward ``ConvActorCritic`` and the recurrent net
    (repro/agents/recurrent.py), which mounts an RG-LRU core on the trunk
    features instead of heads directly.
    """
    h, w, c = obs_shape
    for i, ch in enumerate(channels):
        with b.scope(f"conv_{i}"):
            b.param("w", (3, 3, c, ch), (None,) * 4, fan_in_init())
            b.param("b", (ch,), (None,), zeros_init())
        for j in range(blocks):
            for k in (0, 1):
                with b.scope(f"res_{i}_{j}_{k}"):
                    b.param("w", (3, 3, ch, ch), (None,) * 4, fan_in_init())
                    b.param("b", (ch,), (None,), zeros_init())
        c = ch
        h, w = -(-h // 2), -(-w // 2)
    flat = h * w * c
    with b.scope("trunk"):
        b.param("w", (flat, hidden), (None, None), fan_in_init())
        b.param("b", (hidden,), (None,), zeros_init())


def apply_conv_torso(
    params, obs: jax.Array, channels: Sequence[int], blocks: int
) -> jax.Array:
    """obs (B, H, W, C) -> trunk features (B, hidden)."""
    x = obs
    for i, ch in enumerate(channels):
        x = _conv(params[f"conv_{i}"], x, stride=1)
        x = jax.lax.reduce_window(
            x, -jnp.inf, jax.lax.max, (1, 3, 3, 1), (1, 2, 2, 1), "SAME"
        )
        for j in range(blocks):
            y = jax.nn.relu(x)
            y = _conv(params[f"res_{i}_{j}_0"], y)
            y = jax.nn.relu(y)
            y = _conv(params[f"res_{i}_{j}_1"], y)
            x = x + y
    x = jax.nn.relu(x).reshape(x.shape[0], -1)
    return jax.nn.relu(x @ params["trunk"]["w"] + params["trunk"]["b"])


class ConvActorCritic:
    def __init__(self, num_actions: int, channels: Sequence[int] = (16, 32),
                 blocks: int = 1, hidden: int = 256):
        self.num_actions = num_actions
        self.channels = tuple(channels)
        self.blocks = blocks
        self.hidden = hidden

    def init(self, rng: jax.Array, obs_shape: tuple[int, ...]):
        b = ParamBuilder(rng, dtype=jnp.float32)
        init_conv_torso(b, obs_shape, self.channels, self.blocks, self.hidden)
        with b.scope("policy"):
            b.param("w", (self.hidden, self.num_actions), (None, None),
                    fan_in_init(0.01))
            b.param("b", (self.num_actions,), (None,), zeros_init())
        with b.scope("value"):
            b.param("w", (self.hidden, 1), (None, None), fan_in_init())
            b.param("b", (1,), (None,), zeros_init())
        params, _ = b.build()
        return params

    def apply(self, params, obs: jax.Array):
        """obs (B, H, W, C) -> (logits (B, A), values (B,))."""
        x = apply_conv_torso(params, obs, self.channels, self.blocks)
        logits = x @ params["policy"]["w"] + params["policy"]["b"]
        values = (x @ params["value"]["w"] + params["value"]["b"])[:, 0]
        return logits, values
