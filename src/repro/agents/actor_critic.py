"""Small MLP actor-critic network for Anakin (grid-world scale, as in the
paper's Colab demo).  Operates on a SINGLE observation (no batch dim) —
Anakin vmaps it across the per-core environment batch.

These are *networks*, not agents: Anakin consumes them directly (its loss
is the differentiated env unroll), while Sebulba mounts them behind a
``repro.api`` agent — ``ImpalaAgent(BatchedMLPActorCritic(...), cfg)`` is
the vector-obs Sebulba configuration (registered as ``actor_critic`` in
``repro.api.registry``).
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.param import ParamBuilder, fan_in_init, zeros_init


class MLPActorCritic:
    def __init__(self, num_actions: int, hidden: Sequence[int] = (128, 128)):
        self.num_actions = num_actions
        self.hidden = tuple(hidden)

    def init(self, rng: jax.Array, obs_shape: tuple[int, ...]):
        b = ParamBuilder(rng, dtype=jnp.float32)
        in_dim = math.prod(obs_shape)
        for i, h in enumerate(self.hidden):
            with b.scope(f"dense_{i}"):
                b.param("w", (in_dim, h), (None, None), fan_in_init())
                b.param("b", (h,), (None,), zeros_init())
            in_dim = h
        with b.scope("policy"):
            b.param("w", (in_dim, self.num_actions), (None, None), fan_in_init(0.01))
            b.param("b", (self.num_actions,), (None,), zeros_init())
        with b.scope("value"):
            b.param("w", (in_dim, 1), (None, None), fan_in_init())
            b.param("b", (1,), (None,), zeros_init())
        params, _ = b.build()
        return params

    def apply(self, params, obs: jax.Array):
        """obs (single observation) -> (logits (A,), value ())."""
        x = obs.reshape(-1)
        for i in range(len(self.hidden)):
            p = params[f"dense_{i}"]
            x = jax.nn.relu(x @ p["w"] + p["b"])
        logits = x @ params["policy"]["w"] + params["policy"]["b"]
        value = (x @ params["value"]["w"] + params["value"]["b"])[0]
        return logits, value


class BatchedMLPActorCritic(MLPActorCritic):
    """Batch-first MLP actor-critic for Sebulba's batched-inference actors.

    Anakin vmaps the single-observation ``MLPActorCritic`` across its
    per-core env batch; Sebulba agents instead call ``apply`` on an explicit
    (B, ...) batch, so this variant vmaps internally.  Used by the vector-obs
    host envs (HostBandit) where a conv torso would be overkill.
    """

    def apply(self, params, obs: jax.Array):
        """obs (B, ...) -> (logits (B, A), values (B,))."""
        return jax.vmap(lambda o: MLPActorCritic.apply(self, params, o))(obs)
