from repro.agents.actor_critic import (  # noqa: F401
    BatchedMLPActorCritic,
    MLPActorCritic,
)
from repro.agents.impala import ConvActorCritic  # noqa: F401
from repro.agents.recurrent import (  # noqa: F401
    RecurrentConvActorCritic,
    RecurrentImpalaAgent,
    RecurrentMLPActorCritic,
    RecurrentReplayImpalaAgent,
)
from repro.agents.replay_impala import ReplayImpalaAgent  # noqa: F401
