"""Agent zoo — every class here implements the canonical ``repro.api``
agent protocol and declares its capabilities via ``AgentSpec`` (see
ARCHITECTURE.md §Protocol for the capability matrix)."""

from repro.agents.actor_critic import (  # noqa: F401
    BatchedMLPActorCritic,
    MLPActorCritic,
)
from repro.agents.impala import ConvActorCritic, ImpalaAgent  # noqa: F401
from repro.agents.muzero import MuZeroAgent, MuZeroConfig  # noqa: F401
from repro.agents.ppo import PPOAgent, PPOConfig  # noqa: F401
from repro.agents.recurrent import (  # noqa: F401
    RecurrentConvActorCritic,
    RecurrentImpalaAgent,
    RecurrentMLPActorCritic,
    RecurrentReplayImpalaAgent,
)
from repro.agents.replay_impala import ReplayImpalaAgent  # noqa: F401
