from repro.agents.actor_critic import MLPActorCritic  # noqa: F401
from repro.agents.impala import ConvActorCritic  # noqa: F401
