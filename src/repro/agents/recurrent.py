"""R2D2-style recurrent agents for Sebulba (Kapturowski et al. 2019).

The temporal core is the RG-LRU recurrence (Griffin, arXiv:2402.19427),
driven through the existing ``rglru_scan`` kernel wrapper
(repro/kernels/rglru_scan/).  Stored-state scans — every training unroll
here passes the recorded carry as h0 — take the log-depth
``jax.lax.associative_scan`` path with its linear-memory custom VJP on
every backend; acting is a single ``rglru_step_ref`` step.  (The Pallas
TPU kernel starts from zero state and keeps serving griffin's prefill —
no R2D2 path reaches it.)  ``core="lax"`` swaps in the sequential
``jax.lax.scan`` oracle (``rglru_scan_ref``) as a pure-lax reference —
same math, linear depth; benchmarks/recurrent_bench.py compares the two.

Three pieces of R2D2 live here; the plumbing they need is in
``repro/core/sebulba.py`` and ``repro/data/trajectory.py``:

  * **stored state** — ``act`` threads an (B, W) carry through Sebulba's
    fused act-step; the carry entering step 0 of each trajectory slice is
    recorded as ``Trajectory.init_carry`` and travels through the learner
    shards and the replay ring, so replayed sequences unroll from the state
    the actor actually had (vs zero-state, which Kapturowski et al. show
    mis-trains the early steps of every sequence);
  * **episode-boundary resets** — inside a trajectory the learner re-derives
    the actor's resets from the discount channel (discount == 0 marks a
    terminal), folding them into the RG-LRU decay gate: ``a_t := 0`` cuts
    the ``h_{t-1}`` term, and the original ``beta = sqrt(1 - a^2)`` input
    scale is folded into the input so the driven term is unchanged — one
    masked scan instead of a per-step ``lax.cond``;
  * **burn-in** — ``SebulbaConfig.burn_in = K`` unrolls the first K steps
    from the stored state WITHOUT gradient (the stored state is stale: it
    was recorded under older params), then trains the V-trace loss on the
    remaining T-K steps from the refreshed carry.  Gradients w.r.t. the
    burn-in window are exactly zero.

Agent protocol (``repro.api``): these agents declare
``AgentSpec(recurrent=True)`` — the runner threads (and stores, and
episode-resets) the carry their canonical ``act(params, obs, rng, carry)``
returns.  The replay variant additionally declares ``replay=True`` (PER
importance weights in, per-sequence TD priorities out).
"""

from __future__ import annotations

import math
from typing import Sequence

import jax
import jax.numpy as jnp

from repro.agents.impala import ImpalaAgent, apply_conv_torso, init_conv_torso
from repro.api import ActAux, AgentSpec, LossAux
from repro.kernels.rglru_scan.ops import rglru_scan
from repro.kernels.rglru_scan.ref import rglru_scan_ref, rglru_step_ref
from repro.param import ParamBuilder, constant_init, fan_in_init, zeros_init
from repro.rl import losses

# Griffin's decay parametrization: a = exp(-c * softplus(lam) * r_t)
RGLRU_C = 8.0

CORES = ("rglru", "lax")


class _RecurrentActorCritic:
    """Torso -> RG-LRU temporal core -> policy/value heads.

    Subclasses supply the observation torso (conv for frames, MLP for
    vector obs) via ``_init_torso`` / ``_torso`` and set ``feat_dim``.
    The heads read the concatenation [torso features, recurrent state], so
    the policy keeps a direct (memoryless) path to the current observation
    while the RG-LRU contributes history.

    All recurrent-core math runs in float32 — the carry is (B, W) float32
    and bit-stable across the act / store / replay round trip.
    """

    feat_dim: int  # set by subclasses

    def __init__(self, num_actions: int, rnn_width: int, core: str):
        if core not in CORES:
            raise ValueError(f"core must be one of {CORES}, got {core!r}")
        self.num_actions = num_actions
        self.rnn_width = rnn_width
        self.core = core

    # -- torso hooks (subclasses) ---------------------------------------

    def _init_torso(self, b: ParamBuilder, obs_shape) -> None:
        raise NotImplementedError

    def _torso(self, params, obs: jax.Array) -> jax.Array:
        """obs (B, ...) -> features (B, feat_dim)."""
        raise NotImplementedError

    # -- params ----------------------------------------------------------

    def init(self, rng: jax.Array, obs_shape: tuple[int, ...]):
        b = ParamBuilder(rng, dtype=jnp.float32)
        self._init_torso(b, obs_shape)
        F, W = self.feat_dim, self.rnn_width
        with b.scope("rnn_in"):
            b.param("w", (F, W), (None, None), fan_in_init())
            b.param("b", (W,), (None,), zeros_init())
        with b.scope("rglru"):
            b.param("w_a", (W, W), (None, None), fan_in_init())
            b.param("b_a", (W,), (None,), zeros_init())
            b.param("w_x", (W, W), (None, None), fan_in_init())
            b.param("b_x", (W,), (None,), zeros_init())
            # softplus(0.7) * 8 ≈ 9 -> a^(1/8) in the paper's U[0.9, 0.999]
            # ballpark at r = 1 (same init as models/griffin.py)
            b.param("lam", (W,), (None,), constant_init(0.7))
        with b.scope("policy"):
            b.param("w", (F + W, self.num_actions), (None, None),
                    fan_in_init(0.01))
            b.param("b", (self.num_actions,), (None,), zeros_init())
        with b.scope("value"):
            b.param("w", (F + W, 1), (None, None), fan_in_init())
            b.param("b", (1,), (None,), zeros_init())
        params, _ = b.build()
        return params

    # -- recurrent core --------------------------------------------------

    def initial_state(self, batch: int) -> jax.Array:
        """Always zeros — NOT an override point: the learner-side episode
        reset is the decay-gate fold in ``apply_seq``, which restores zero
        state by construction, and Sebulba rejects nonzero initial carries
        at construction so the two reset paths cannot diverge."""
        return jnp.zeros((batch, self.rnn_width), jnp.float32)

    def _gates(self, params, u: jax.Array):
        """u (..., W) -> (decay a, input gate i), float32 (Griffin eqs)."""
        p = params["rglru"]
        uf = u.astype(jnp.float32)
        r = jax.nn.sigmoid(uf @ p["w_a"] + p["b_a"])
        gi = jax.nn.sigmoid(uf @ p["w_x"] + p["b_x"])
        a = jnp.exp(-RGLRU_C * jax.nn.softplus(p["lam"]) * r)
        return a, gi

    def _heads(self, params, feat: jax.Array, y: jax.Array):
        out = jnp.concatenate([feat, y], axis=-1)
        logits = out @ params["policy"]["w"] + params["policy"]["b"]
        values = (out @ params["value"]["w"] + params["value"]["b"])[..., 0]
        return logits, values

    def apply_step(self, params, obs, carry: jax.Array):
        """One acting step: obs (B, ...), carry (B, W) ->
        (logits (B, A), values (B,), new carry (B, W))."""
        feat = self._torso(params, obs)
        u = feat @ params["rnn_in"]["w"] + params["rnn_in"]["b"]
        a, gi = self._gates(params, u)
        y, h_new = rglru_step_ref(carry, u, a, gi)
        logits, values = self._heads(params, feat, y)
        return logits, values, h_new

    def apply_seq(self, params, obs, carry: jax.Array, reset: jax.Array):
        """Unroll a trajectory window: obs (B, T, ...), carry (B, W),
        reset (B, T) bool -> (logits (B, T, A), values (B, T), carry_T).

        ``reset[:, t]`` marks rows whose episode closed at step t-1; those
        rows restart the recurrence from zero state at step t, matching
        the actor's per-step reset.  The reset is folded into the scan
        inputs (decay masked to 0, beta compensation on the input) so both
        cores stay single fused scans with no per-step control flow.
        """
        B, T = reset.shape
        obs_flat = jax.tree.map(
            lambda o: o.reshape((B * T,) + o.shape[2:]), obs
        )
        feat = self._torso(params, obs_flat).reshape(B, T, self.feat_dim)
        u = feat @ params["rnn_in"]["w"] + params["rnn_in"]["b"]
        a, gi = self._gates(params, u)
        # a_t := 0 cuts h_{t-1}; the kernel would then use beta = 1, so the
        # original beta folds into the input:  h_t = i_t * (u_t * beta) —
        # exactly the zero-carry step the actor takes after a done.
        rm = reset[..., None]
        beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0))
        x_eff = jnp.where(rm, u * beta, u)
        a_eff = jnp.where(rm, 0.0, a)
        scan = rglru_scan if self.core == "rglru" else rglru_scan_ref
        y, h_last = scan(x_eff, a_eff, gi, carry)
        logits, values = self._heads(params, feat, y)
        return logits, values, h_last


class RecurrentConvActorCritic(_RecurrentActorCritic):
    """Frame-observation recurrent net: IMPALA conv torso + RG-LRU core."""

    def __init__(self, num_actions: int, channels: Sequence[int] = (16, 32),
                 blocks: int = 1, hidden: int = 256, rnn_width: int = 128,
                 core: str = "rglru"):
        super().__init__(num_actions, rnn_width, core)
        self.channels = tuple(channels)
        self.blocks = blocks
        self.hidden = hidden
        self.feat_dim = hidden

    def _init_torso(self, b, obs_shape) -> None:
        init_conv_torso(b, obs_shape, self.channels, self.blocks, self.hidden)

    def _torso(self, params, obs):
        return apply_conv_torso(params, obs, self.channels, self.blocks)


class RecurrentMLPActorCritic(_RecurrentActorCritic):
    """Vector-observation recurrent net (HostBandit-scale tests/benches)."""

    def __init__(self, num_actions: int, hidden: Sequence[int] = (32,),
                 rnn_width: int = 16, core: str = "rglru"):
        super().__init__(num_actions, rnn_width, core)
        self.hidden = tuple(hidden)
        self.feat_dim = self.hidden[-1]

    def _init_torso(self, b, obs_shape) -> None:
        in_dim = math.prod(obs_shape)
        for i, h in enumerate(self.hidden):
            with b.scope(f"dense_{i}"):
                b.param("w", (in_dim, h), (None, None), fan_in_init())
                b.param("b", (h,), (None,), zeros_init())
            in_dim = h

    def _torso(self, params, obs):
        x = obs.reshape(obs.shape[0], -1)
        for i in range(len(self.hidden)):
            p = params[f"dense_{i}"]
            x = jax.nn.relu(x @ p["w"] + p["b"])
        return x


class RecurrentImpalaAgent:
    """On-policy recurrent Sebulba agent (stored state + burn-in V-trace).

    ``network`` is a ``_RecurrentActorCritic``; ``config`` a
    ``SebulbaConfig`` (``burn_in`` selects the gradient-free prefix).
    """

    spec = AgentSpec(recurrent=True)

    def __init__(self, network: _RecurrentActorCritic, config):
        self.net = network
        self.cfg = config

    def init(self, rng, obs_shape):
        return self.net.init(rng, obs_shape)

    def initial_carry(self, batch: int):
        """Zeroed RG-LRU state (the ``AgentSpec(recurrent=True)``
        contract).  Episode-boundary resets restore exactly this value."""
        return self.net.initial_state(batch)

    def act(self, params, obs, rng, carry):
        """(params, obs (B, ...), rng, carry (B, W)) -> (actions,
        ActAux(log-prob, extras), new carry).  Traced inside Sebulba's
        fused donated act-step; the carry it receives is already
        episode-reset."""
        logits, _, carry = self.net.apply_step(params, obs, carry)
        actions = jax.random.categorical(rng, logits)
        logp = losses.log_prob(logits, actions)
        return actions, ActAux(logp), carry

    @staticmethod
    def _reset_mask(discounts: jax.Array) -> jax.Array:
        """(B, T) discounts -> (B, T) bool: reset BEFORE step t iff the
        episode closed at t-1.  Step 0's boundary is already baked into
        ``init_carry`` (the actor stores the post-reset carry), so column
        0 is always False."""
        return jnp.concatenate(
            [
                jnp.zeros_like(discounts[:, :1], jnp.bool_),
                discounts[:, :-1] == 0.0,
            ],
            axis=1,
        )

    def _unroll(self, params, traj):
        """Stored-state + burn-in unroll over a trajectory batch ->
        (logits, values, bootstrap values) for the trained window [K:].

        The burn-in prefix runs from ``traj.init_carry`` with the same
        resets the actor applied, but its only output is the refreshed
        carry, cut from the gradient tape — grads w.r.t. burn-in steps are
        exactly zero, and the V-trace loss sees T - K steps.
        """
        K = self.cfg.burn_in
        reset = self._reset_mask(traj.discounts)
        carry = traj.init_carry
        if K:
            burn_obs = jax.tree.map(lambda o: o[:, :K], traj.obs)
            _, _, carry = self.net.apply_seq(
                params, burn_obs, carry, reset[:, :K]
            )
            carry = jax.lax.stop_gradient(carry)
        obs = jax.tree.map(lambda o: o[:, K:], traj.obs)
        logits, values, carry_last = self.net.apply_seq(
            params, obs, carry, reset[:, K:]
        )
        # bootstrap_obs is the first obs of a fresh episode when the final
        # step was terminal — value it from a reset carry, as the actor
        # would.  (V-trace multiplies it by that zero discount anyway; the
        # reset just keeps the value finite and semantically right.)
        ended = (traj.discounts[:, -1] == 0.0)[:, None]
        boot_carry = jnp.where(ended, 0.0, carry_last)
        _, bootstrap, _ = self.net.apply_step(
            params, traj.bootstrap_obs, boot_carry
        )
        return logits, values, bootstrap

    def _loss_window(self, traj):
        K = self.cfg.burn_in
        return (
            traj.actions[:, K:], traj.behaviour_logp[:, K:],
            traj.rewards[:, K:], traj.discounts[:, K:],
        )

    # same learner metrics dict as the feed-forward agent — shared so the
    # packed on-device accumulator layout cannot silently diverge
    _metrics = staticmethod(ImpalaAgent._metrics)

    def loss(self, params, traj, weights=None):
        if weights is not None:
            raise ValueError(
                "RecurrentImpalaAgent is on-policy (AgentSpec.replay="
                "False) and does not apply importance weights; use "
                "RecurrentReplayImpalaAgent for weighted replay losses"
            )
        cfg = self.cfg
        logits, values, bootstrap = self._unroll(params, traj)
        actions, blogp, rewards, discounts = self._loss_window(traj)
        out = losses.impala_loss(
            logits, values, actions, blogp, rewards, discounts, bootstrap,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        return out.total, LossAux(self._metrics(out))


class RecurrentReplayImpalaAgent(RecurrentImpalaAgent):
    """Off-policy (replay) recurrent agent — true R2D2 on Sebulba.

    Same actor as ``RecurrentImpalaAgent``; the declared capabilities add
    ``replay=True``: ``loss(params, traj, weights)`` applies the PER
    importance weights (sampling-bias correction; V-trace handles the
    policy lag) and returns per-sequence TD magnitudes as
    ``LossAux.priorities`` (computed over the post-burn-in window only —
    burn-in steps are state refresh, not training signal), which go back
    into the ring as fresh priorities.
    """

    spec = AgentSpec(recurrent=True, replay=True)

    def loss(self, params, traj, weights=None):
        cfg = self.cfg
        logits, values, bootstrap = self._unroll(params, traj)
        actions, blogp, rewards, discounts = self._loss_window(traj)
        out = losses.weighted_impala_loss(
            logits, values, actions, blogp, rewards, discounts, bootstrap,
            importance_weights=weights,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        return out.total, LossAux(self._metrics(out), out.per_seq_td)
