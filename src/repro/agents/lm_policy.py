"""LM policy as a first-class Podracer agent (ISSUE 9 tentpole).

``LMPolicyAgent`` puts a model-zoo transformer (repro/configs/) on the
Sebulba dataflow with **zero changes to core/sebulba.py**: autoregressive
generation *is* the ``act()`` hot loop, and the decode state — KV cache
plus position counter — *is* the declared recurrent carry.  Everything the
runner already does for recurrent agents (thread the carry through the
fused donated act-step, episode-reset it where ``discount == 0``, snapshot
it into ``Trajectory.init_carry``, split it across learner shards, store
it through replay) therefore applies to LM rollouts for free.  This is the
RLAX architecture (PAPERS.md) expressed on our stack.

Carry layout (the contract tests/test_lm_policy.py pins):

  * the model is built with ``unroll=True``, which forces the looped
    per-layer cache layout whose every leaf is **batch-leading** —
    ``{"layer_i": {"k": (B, S, K, h), "v": ...}}`` for attention,
    ``{"ssm": (B, H, P, N), "conv": (B, W-1, C)}`` for ssm blocks.  The
    stacked layout is layers-leading ``(L, B, ...)`` and would break both
    the runner's episode-reset broadcast and ``split_for_learners``;
  * ``carry["pos"]`` is a per-row ``(B,)`` int32 step counter.  It is
    all-zero at init (so ``resolve_agent``'s zero-carry check passes) and
    reset to zero with the rest of the carry at episode boundaries.

Decode position: ``model.decode_step`` takes one *scalar* position (one
rope offset, one cache write index for the whole batch), so ``act`` uses
``max(carry["pos"])`` under a **lockstep invariant**: every fleet row
starts at t == 0 and ``TokenEnv`` episodes are fixed-length, so per-row
positions never diverge.  (A scenario mix of different episode lengths
would violate this — pair LM agents with equal-length token tasks.)

Inside ``decode_step`` the attention hot loop runs behind the
``flash_decode`` kernel wrapper (see models/transformer.py): the Pallas
kernel on TPU, its bit-identical jnp oracle elsewhere.

``loss()`` is the V-trace-corrected LM objective from ``launch/steps.py``:
one full causal forward over ``[obs, bootstrap_obs]`` (prefill teacher-
forcing the tokens the actor generated), next-token cross-entropy on that
sequence, plus the IMPALA V-trace actor-critic term in which stale
generations are importance-weighted via rho/c clipping against the stored
``behaviour_logp``.  The forward's position-t logits are conditioned on
obs <= t, exactly matching the actor's KV-cache conditioning when
trajectory slices are episode-aligned — so configure
``trajectory_length == env.episode_len`` (drains and episodes both start
at step 0, so they stay in phase).

``LMReplayPolicyAgent`` additionally declares ``replay=True``: PER
importance weights scale both the CE and RL terms per sequence, and
per-sequence TD magnitudes flow back as replay priorities.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.api import ActAux, AgentSpec, LossAux
from repro.launch.steps import TrainHParams
from repro.models.model import make_model
from repro.rl import losses


class LMPolicyAgent:
    """Autoregressive token policy on the ``repro.api`` agent contract.

    ``cfg`` is an ``ArchConfig`` from the model zoo; ``max_seq`` the cache
    capacity in tokens — at least the env's episode length, since the
    position counter only rewinds at episode resets.  ``hparams`` follows
    ``launch.steps.TrainHParams`` (CE + rl_weight * V-trace + aux).
    """

    spec = AgentSpec(recurrent=True)

    def __init__(self, cfg, *, max_seq: int, hparams: TrainHParams | None = None,
                 cache_dtype=None):
        self.cfg = cfg
        self.max_seq = int(max_seq)
        self.hp = hparams or TrainHParams()
        self.cache_dtype = cache_dtype
        # unroll=True -> looped per-layer params and the batch-leading
        # cache layout the Sebulba carry protocol requires (see module
        # docstring); params layout must match, hence set here once.
        self.model = make_model(cfg, unroll=True)

    # -- actor ------------------------------------------------------------

    def init(self, rng, obs_shape):
        """Token observations are scalar — obs_shape is accepted for the
        runner contract but carries no information."""
        return self.model.init(rng)

    def initial_carry(self, batch: int):
        """Zero-valued (NOT empty-shaped) decode state: zeroed KV cache +
        zeroed position counter.  Episode resets restore exactly this."""
        cache, _ = self.model.init_cache(
            batch, self.max_seq, dtype=self.cache_dtype
        )
        return {"cache": cache, "pos": jnp.zeros((batch,), jnp.int32)}

    def act(self, params, obs, rng, carry):
        """One autoregressive decode step: obs (B,) int32 tokens ->
        (sampled tokens (B,), ActAux(logp), advanced decode state).

        Runs inside Sebulba's fused donated act-step; the carry arriving
        here is already episode-reset, so ``pos`` is 0 exactly when the
        cache is freshly zeroed.
        """
        tokens = obs.astype(jnp.int32).reshape(-1, 1)
        # scalar decode position from the per-row counters (lockstep
        # invariant — see module docstring)
        pos = jnp.max(carry["pos"])
        logits, _, cache = self.model.decode_step(
            params, carry["cache"], tokens, pos
        )
        logits = logits[:, 0].astype(jnp.float32)
        actions = jax.random.categorical(rng, logits)
        logp = losses.log_prob(logits, actions)
        return actions, ActAux(logp), {"cache": cache, "pos": carry["pos"] + 1}

    # -- learner ----------------------------------------------------------

    def _objective(self, params, traj, weights):
        """Shared CE + V-trace objective -> (total, metrics, vtrace out)."""
        hp = self.hp
        B, T = traj.actions.shape
        # teacher-force the generated episode in one causal prefill; the
        # trailing bootstrap obs supplies both the last CE target and the
        # bootstrap value (V-trace scales it by the terminal discount).
        tokens = jnp.concatenate(
            [traj.obs.astype(jnp.int32),
             traj.bootstrap_obs.astype(jnp.int32)[:, None]], axis=1,
        )
        logits, values, aux = self.model.forward(params, {"tokens": tokens})
        logits_t = logits[:, :T]  # position t conditioned on obs <= t
        values_t = values[:, :T]
        # next-token CE over the rollout (launch/steps.py make_loss_fn)
        lse = jax.nn.logsumexp(logits_t, axis=-1)
        tgt = jnp.take_along_axis(
            logits_t, tokens[:, 1:][..., None], axis=-1
        )[..., 0]
        ce_seq = jnp.mean(lse - tgt, axis=1)  # (B,)
        if weights is None:
            ce = jnp.mean(ce_seq)
        else:
            ce = jnp.mean(ce_seq * weights)
        out = losses.weighted_impala_loss(
            logits_t, values_t, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, values[:, T],
            importance_weights=weights,
            entropy_cost=hp.entropy_cost, value_cost=hp.value_cost,
        )
        total = ce + hp.rl_weight * out.total + hp.aux_weight * aux
        metrics = {
            "loss": total, "ce": ce, "rl": out.total,
            "aux": jnp.asarray(aux, jnp.float32), "entropy": out.entropy,
        }
        return total, metrics, out

    def loss(self, params, traj, weights=None):
        if weights is not None:
            raise ValueError(
                "LMPolicyAgent is on-policy (AgentSpec.replay=False) and "
                "does not apply importance weights; use LMReplayPolicyAgent "
                "for PER-weighted replay losses"
            )
        total, metrics, _ = self._objective(params, traj, None)
        return total, LossAux(metrics)


class LMReplayPolicyAgent(LMPolicyAgent):
    """Off-policy LM agent: PER importance weights in (scaling CE and the
    V-trace term per sequence), per-sequence TD priorities out — stale
    generations replay with both corrections RLAX prescribes."""

    spec = AgentSpec(recurrent=True, replay=True)

    def loss(self, params, traj, weights=None):
        total, metrics, out = self._objective(params, traj, weights)
        return total, LossAux(metrics, out.per_seq_td)
