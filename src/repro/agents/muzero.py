"""MuZero-lite agent for Sebulba (paper §Sebulba, Fig. 4c).

Representation / dynamics / prediction MLPs + the pure-JAX MCTS
(repro/rl/mcts.py) for acting, and the MuZero training objective (K-step
unrolled value/reward/policy losses, no Reanalyse — matching the paper's
"MuZero (no Reanalyse)") for learning.

Implements the canonical ``repro.api`` agent protocol with
``AgentSpec(extras_keys=("visit_probs",))``: acting runs MCTS on the actor
cores and emits the (B, A) visit distribution as the named trajectory
extra the K-step unrolled loss trains the policy head against — the same
channel a future MuZero-reanalyze worker reads back out of replay.
"""

from __future__ import annotations

import dataclasses
import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.api import ActAux, AgentSpec, LossAux
from repro.param import ParamBuilder, fan_in_init, zeros_init
from repro.rl import returns as rets
from repro.rl.mcts import mcts_search


@dataclasses.dataclass(frozen=True)
class MuZeroConfig:
    hidden_dim: int = 64
    num_simulations: int = 16
    max_depth: int = 8
    unroll_steps: int = 4
    discount: float = 0.99
    td_lambda: float = 0.9
    value_cost: float = 0.25
    reward_cost: float = 1.0
    temperature: float = 1.0


class MuZeroNets:
    """repr: obs -> h; dynamics: (h, a) -> (h', r); prediction: h -> (pi, v)."""

    def __init__(self, num_actions: int, hidden_dim: int = 64, torso: int = 128):
        self.num_actions = num_actions
        self.hidden_dim = hidden_dim
        self.torso = torso

    def init(self, rng: jax.Array, obs_shape):
        b = ParamBuilder(rng, dtype=jnp.float32)
        in_dim = math.prod(obs_shape)
        H, A, T = self.hidden_dim, self.num_actions, self.torso
        def dense(scope, i, o, scale=1.0):
            with b.scope(scope):
                b.param("w", (i, o), (None, None), fan_in_init(scale))
                b.param("b", (o,), (None,), zeros_init())
        dense("repr_1", in_dim, T)
        dense("repr_2", T, H)
        dense("dyn_1", H + A, T)
        dense("dyn_2", T, H)
        dense("dyn_r", T, 1)
        dense("pred_1", H, T)
        dense("pred_pi", T, A, 0.01)
        dense("pred_v", T, 1)
        params, _ = b.build()
        return params

    @staticmethod
    def _ff(p, x):
        return x @ p["w"] + p["b"]

    def representation(self, params, obs):
        x = obs.reshape(-1)
        x = jax.nn.relu(self._ff(params["repr_1"], x))
        h = self._ff(params["repr_2"], x)
        # scale hidden to [0, 1] for stable dynamics (MuZero appendix)
        h_min, h_max = h.min(), h.max()
        return (h - h_min) / jnp.maximum(h_max - h_min, 1e-6)

    def dynamics(self, params, h, action):
        a = jax.nn.one_hot(action, self.num_actions, dtype=h.dtype)
        x = jnp.concatenate([h, a], axis=-1)
        x = jax.nn.relu(self._ff(params["dyn_1"], x))
        h_new = self._ff(params["dyn_2"], x)
        h_min, h_max = h_new.min(), h_new.max()
        h_new = (h_new - h_min) / jnp.maximum(h_max - h_min, 1e-6)
        reward = self._ff(params["dyn_r"], x)[0]
        return h_new, reward

    def prediction(self, params, h):
        x = jax.nn.relu(self._ff(params["pred_1"], h))
        logits = self._ff(params["pred_pi"], x)
        value = self._ff(params["pred_v"], x)[0]
        return logits, value


class MuZeroAgent:
    """Sebulba agent: MCTS acting + K-step unrolled MuZero loss."""

    spec = AgentSpec(extras_keys=("visit_probs",))

    def __init__(self, num_actions: int, cfg: MuZeroConfig = MuZeroConfig()):
        self.cfg = cfg
        self.num_actions = num_actions
        self.nets = MuZeroNets(num_actions, cfg.hidden_dim)

    def init(self, rng: jax.Array, obs_shape):
        return self.nets.init(rng, obs_shape)

    def initial_carry(self, batch: int):
        return ()  # the MCTS tree is rebuilt per step; no carried state

    # -- acting (runs on actor cores, batched) -------------------------------

    def act(self, params, obs, rng, carry=()):
        """MCTS acting.  Traced inside Sebulba's fused donated act-step;
        the (B, A) ``visit_probs`` extra (declared in the AgentSpec) gets
        a preallocated (B, T, A) slot in the device trajectory ring via
        ``jax.eval_shape``."""
        out = mcts_search(
            params, obs, rng,
            representation=self.nets.representation,
            dynamics=self.nets.dynamics,
            prediction=self.nets.prediction,
            num_simulations=self.cfg.num_simulations,
            num_actions=self.num_actions,
            max_depth=self.cfg.max_depth,
            discount=self.cfg.discount,
            temperature=self.cfg.temperature,
        )
        # behaviour logp under the search policy; extras = visit distribution
        # (the MuZero policy target)
        p = jnp.take_along_axis(out.visit_probs, out.action[:, None], axis=-1)
        logp = jnp.log(jnp.maximum(p[:, 0], 1e-9))
        return out.action, ActAux(logp, {"visit_probs": out.visit_probs}), ()

    # -- learning (runs on learner cores, per shard) -----------------------

    def loss(self, params, traj, weights=None):
        """``traj.extras["visit_probs"]`` holds the MCTS visit
        distributions (B, T, A) recorded by act."""
        if weights is not None:
            raise ValueError(
                "MuZeroAgent is on-policy (AgentSpec.replay=False) and "
                "does not apply importance weights; a reanalyze variant "
                "would declare AgentSpec(replay=True)"
            )
        cfg = self.cfg
        B, T = traj.actions.shape
        K = min(cfg.unroll_steps, T - 1)
        nets = self.nets

        obs_flat = traj.obs.reshape((B * T,) + traj.obs.shape[2:])
        h0 = jax.vmap(nets.representation, in_axes=(None, 0))(params, obs_flat)
        logits0, values = jax.vmap(nets.prediction, in_axes=(None, 0))(params, h0)
        values = values.reshape(B, T)

        # value targets: TD(lambda) over the real trajectory
        boot = values[:, -1]
        values_tp1 = jnp.concatenate([values[:, 1:], boot[:, None]], axis=1)
        targets = jax.lax.stop_gradient(
            rets.lambda_returns(traj.rewards, traj.discounts, values_tp1,
                                cfg.td_lambda)
        )

        # K-step latent unroll from each of the first T-K positions
        S = T - K
        h = h0.reshape(B, T, -1)[:, :S].reshape(B * S, -1)
        total_pi = jnp.float32(0.0)
        total_v = jnp.float32(0.0)
        total_r = jnp.float32(0.0)
        for k in range(K):
            logits, v = jax.vmap(nets.prediction, in_axes=(None, 0))(params, h)
            pi_target = jax.lax.dynamic_slice_in_dim(
                traj.extras["visit_probs"], k, S, axis=1
            ).reshape(B * S, -1)
            v_target = jax.lax.dynamic_slice_in_dim(
                targets, k, S, axis=1
            ).reshape(B * S)
            logp = jax.nn.log_softmax(logits, axis=-1)
            total_pi += -jnp.mean(jnp.sum(pi_target * logp, axis=-1))
            total_v += jnp.mean(jnp.square(v - v_target))
            a_k = jax.lax.dynamic_slice_in_dim(
                traj.actions, k, S, axis=1
            ).reshape(B * S)
            r_k = jax.lax.dynamic_slice_in_dim(
                traj.rewards, k, S, axis=1
            ).reshape(B * S)
            h, r_pred = jax.vmap(nets.dynamics, in_axes=(None, 0, 0))(
                params, h, a_k
            )
            h = jax.lax.stop_gradient(h) * 0.5 + h * 0.5  # gradient scaling
            total_r += jnp.mean(jnp.square(r_pred - r_k))

        total = (
            total_pi / K
            + cfg.value_cost * total_v / K
            + cfg.reward_cost * total_r / K
        )
        metrics = {
            "loss": total, "pi": total_pi / K, "value": total_v / K,
            "reward_pred": total_r / K,
        }
        return total, LossAux(metrics)
