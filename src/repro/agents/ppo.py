"""PPO agent for Sebulba.

Same actor path as IMPALA (batched inference on actor cores), but the
learner uses the clipped-surrogate objective with GAE — the ratio clip
against the actors' behaviour log-probs handles the same policy-lag that
V-trace corrects with importance clipping, so the two agents are directly
comparable on the same Sebulba harness (an ablation the paper's framing
invites but does not run).
"""

from __future__ import annotations

import dataclasses

import jax

from repro.api import ActAux, AgentSpec, LossAux
from repro.data.trajectory import Trajectory
from repro.rl import losses


@dataclasses.dataclass(frozen=True)
class PPOConfig:
    clip_eps: float = 0.2
    gae_lambda: float = 0.95
    entropy_cost: float = 0.01
    value_cost: float = 0.5


class PPOAgent:
    spec = AgentSpec()  # feed-forward, on-policy, no extras

    def __init__(self, network, config: PPOConfig = PPOConfig()):
        self.net = network
        self.cfg = config

    def init(self, rng, obs_shape):
        return self.net.init(rng, obs_shape)

    def initial_carry(self, batch: int):
        return ()

    def act(self, params, obs, rng, carry=()):
        """Batched acting; traced inside Sebulba's fused donated act-step
        (must be jit-pure; extras must be a fixed-shape pytree — storage
        for them is preallocated in the device trajectory ring)."""
        logits, _ = self.net.apply(params, obs)
        actions = jax.random.categorical(rng, logits)
        logp = losses.log_prob(logits, actions)
        return actions, ActAux(logp), ()

    def loss(self, params, traj: Trajectory, weights=None):
        if weights is not None:
            raise ValueError(
                "PPOAgent is on-policy (AgentSpec.replay=False) and does "
                "not apply importance weights"
            )
        cfg = self.cfg
        B, T = traj.actions.shape
        obs_flat = jax.tree.map(
            lambda o: o.reshape((B * T,) + o.shape[2:]), traj.obs
        )
        logits, values = self.net.apply(params, obs_flat)
        logits = logits.reshape(B, T, -1)
        values = values.reshape(B, T)
        _, bootstrap = self.net.apply(params, traj.bootstrap_obs)
        out = losses.ppo_loss(
            logits, values, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, bootstrap,
            clip_eps=cfg.clip_eps, gae_lambda=cfg.gae_lambda,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
        )
        metrics = {
            "loss": out.total, "pg": out.pg, "value": out.value,
            "entropy": out.entropy, "clip_frac": out.clip_frac,
        }
        return out.total, LossAux(metrics)
