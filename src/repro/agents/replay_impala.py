"""Replay-based IMPALA agent for off-policy Sebulba (R2D2-style recipe).

Same actor as ``ImpalaAgent`` (batched device inference, categorical
sampling), but the learner consumes *mixed* online/replay batches: V-trace
corrects the policy lag of replayed trajectories via its rho/c clipping
(exactly why the paper pairs Sebulba with V-trace), and PER importance
weights correct the prioritized-sampling bias.  The loss additionally
returns per-sequence TD magnitudes as ``LossAux.priorities``, which
Sebulba writes back into the replay ring as fresh priorities.

Capability declaration (``repro.api``): ``AgentSpec(replay=True)`` — the
canonical ``loss(params, traj, weights)`` applies the weights
(``weights=None`` means unweighted, e.g. the uniform-sampling mode) and
emits priorities.  Any agent declaring the same spec (a future
MuZero-with-reanalyze) plugs into Sebulba replay mode unchanged.
"""

from __future__ import annotations

from repro.agents.impala import ImpalaAgent
from repro.api import AgentSpec, LossAux
from repro.rl import losses


class ReplayImpalaAgent(ImpalaAgent):
    spec = AgentSpec(replay=True)

    def loss(self, params, traj, weights=None):
        cfg = self.cfg
        logits, values, bootstrap = self._forward(params, traj)
        out = losses.weighted_impala_loss(
            logits, values, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, bootstrap,
            importance_weights=weights,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        return out.total, LossAux(self._metrics(out), out.per_seq_td)
