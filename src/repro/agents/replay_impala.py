"""Replay-based IMPALA agent for off-policy Sebulba (R2D2-style recipe).

Same actor as ``ImpalaAgent`` (batched device inference, categorical
sampling), but the learner consumes *mixed* online/replay batches: V-trace
corrects the policy lag of replayed trajectories via its rho/c clipping
(exactly why the paper pairs Sebulba with V-trace), and PER importance
weights correct the prioritized-sampling bias.  The loss additionally
returns per-sequence TD magnitudes, which Sebulba writes back into the
replay ring as fresh priorities.

The off-policy learner protocol is ``loss(params, traj, weights) ->
(total, (metrics, per_seq_priority))`` — any agent implementing it (e.g. a
future MuZero-with-reanalyze) plugs into ``Sebulba`` replay mode unchanged.
"""

from __future__ import annotations

from repro.core.sebulba import ImpalaAgent
from repro.rl import losses


class ReplayImpalaAgent(ImpalaAgent):
    # loss aux is (metrics, per_seq_priorities) — only Sebulba's replay
    # mode understands it; the on-policy learner guard keys on this marker
    # (an isinstance check would miss the recurrent replay agent, which
    # shares the protocol but not this base class)
    replay_protocol = True

    def loss(self, params, traj, weights=None):
        cfg = self.cfg
        logits, values, bootstrap = self._forward(params, traj)
        out = losses.weighted_impala_loss(
            logits, values, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, bootstrap,
            importance_weights=weights,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        return out.total, (self._metrics(out), out.per_seq_td)
