"""RG-LRU linear recurrence as a Pallas TPU kernel.

Grid: (B, num_width_blocks, num_time_blocks) — time is the sequential TPU
grid dimension; the hidden state (one row of width ``block_w``) is carried
in VMEM scratch across time blocks.  Within a time block the recurrence
runs as an unrolled-by-lax.fori_loop elementwise loop over rows that are
already resident in VMEM — the same structure as the custom linear-scan
kernel the Griffin paper used on TPU (sequential in time, fully parallel in
batch x width on the VPU lanes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, i_ref, y_ref, h_ref, *, block_t: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)  # (bt, bw)
    a = a_ref[0].astype(jnp.float32)
    gi = i_ref[0].astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - a * a, 0.0))
    u = beta * (gi * x)  # (bt, bw)

    def step(t, carry):
        h, ys = carry
        h = a[t] * h + u[t]
        ys = jax.lax.dynamic_update_index_in_dim(ys, h, t, 0)
        return (h, ys)

    h0 = h_ref[...]
    h_final, ys = jax.lax.fori_loop(
        0, block_t, step, (h0, jnp.zeros_like(u))
    )
    y_ref[0] = ys.astype(y_ref.dtype)
    h_ref[...] = h_final


@functools.partial(jax.jit, static_argnames=("block_t", "block_w", "interpret"))
def rglru_scan_pallas(
    x: jax.Array,  # (B, T, W)
    a: jax.Array,  # (B, T, W) decay gates in (0, 1)
    gate_i: jax.Array,  # (B, T, W) input gates
    h0=None,  # kernel path starts from zero state
    *,
    block_t: int = 256,
    block_w: int = 512,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    if h0 is not None:
        raise NotImplementedError("kernel path starts from zero state")
    B, T, W = x.shape
    bt = min(block_t, T)
    bw = min(block_w, W)
    if T % bt or W % bw:
        raise ValueError(f"(T={T}, W={W}) must divide blocks ({bt}, {bw})")
    grid = (B, W // bw, T // bt)

    spec = pl.BlockSpec((1, bt, bw), lambda b, wi, ti: (b, ti, wi))
    y = pl.pallas_call(
        functools.partial(_rglru_kernel, block_t=bt),
        grid=grid,
        in_specs=[spec, spec, spec],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((B, T, W), x.dtype),
        scratch_shapes=[pltpu.VMEM((bw,), jnp.float32)],
        interpret=interpret,
    )(x, a, gate_i)
    return y, y[:, -1].astype(jnp.float32)
