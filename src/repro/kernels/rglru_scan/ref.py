"""Pure-jnp oracle for the RG-LRU recurrence (Griffin, arXiv:2402.19427).

    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

where ``a_t`` in (0, 1) is the state-decay gate and ``i_t`` the input gate
(both already computed by the caller).  All elementwise, width-parallel.

Shapes: x, a, i: (B, T, W);  h0: (B, W).  Returns y: (B, T, W), h_T: (B, W).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def rglru_scan_ref(
    x: jax.Array, a: jax.Array, gate_i: jax.Array, h0: jax.Array | None = None
) -> tuple[jax.Array, jax.Array]:
    B, T, W = x.shape
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    inf_ = gate_i.astype(jnp.float32)
    if h0 is None:
        h0 = jnp.zeros((B, W), jnp.float32)

    beta = jnp.sqrt(jnp.maximum(1.0 - af**2, 0.0))
    u = beta * (inf_ * xf)  # (B, T, W)

    def step(h, inp):
        at, ut = inp
        h = at * h + ut
        return h, h

    h_final, ys = jax.lax.scan(
        step, h0.astype(jnp.float32), (jnp.moveaxis(af, 1, 0), jnp.moveaxis(u, 1, 0))
    )
    return jnp.moveaxis(ys, 0, 1).astype(x.dtype), h_final


def rglru_step_ref(
    h: jax.Array, x: jax.Array, a: jax.Array, gate_i: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  h: (B, W); x, a, gate_i: (B, W)."""
    af = a.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - af**2, 0.0))
    h = af * h.astype(jnp.float32) + beta * (
        gate_i.astype(jnp.float32) * x.astype(jnp.float32)
    )
    return h.astype(x.dtype), h
