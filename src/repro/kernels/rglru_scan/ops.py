"""Jitted RG-LRU wrapper.

Default non-TPU path uses ``jax.lax.associative_scan`` (log-depth, XLA
friendly — the TPU-native adaptation of Griffin's linear scan); on TPU the
Pallas kernel (rglru_scan.py) runs the recurrence sequentially in VMEM,
which is faster than the log-depth scan for the widths used here.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _assoc_scan_fwd_impl(x, a, gate_i, h0):
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    beta = jnp.sqrt(jnp.maximum(1.0 - af**2, 0.0))
    u = beta * (gate_i.astype(jnp.float32) * xf)  # (B, T, W)
    if h0 is not None:
        # fold h0 into the first input: h_1 = a_1 h_0 + u_1
        u = u.at[:, 0].add(af[:, 0] * h0.astype(jnp.float32))

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    a_cum, h = jax.lax.associative_scan(combine, (af, u), axis=1)
    del a_cum
    return h.astype(x.dtype), h


@jax.custom_vjp
def _assoc_scan_core(x, a, gate_i):
    """RG-LRU scan (zero initial state) with a linear-cost custom VJP.

    Autodiff through associative_scan saves O(log T) tree levels of (B,T,W)
    intermediates — the dominant training-memory term for the hybrid arch
    (EXPERIMENTS.md §Perf rollout).  The recurrence backward is itself a
    reverse linear scan over the saved outputs:

        g_t = dy_t + a_{t+1} g_{t+1}
        dx_t = g_t β_t i_t;   di_t = g_t β_t x_t
        da_t = g_t (h_{t-1} − (a_t/β_t) i_t x_t)
    """
    y, _ = _assoc_scan_fwd_impl(x, a, gate_i, None)
    return y


def _assoc_core_fwd(x, a, gate_i):
    y, h = _assoc_scan_fwd_impl(x, a, gate_i, None)
    return y, (x, a, gate_i, h)


def _assoc_core_bwd(res, dy):
    # zero-h0 special case of the stateful backward below (dh0 discarded);
    # the reverse-scan gradient math lives in exactly one place
    x, a, gate_i, h = res
    h0 = jnp.zeros_like(h[:, 0])
    dx, da, di, _ = _assoc_core_h0_bwd(
        (x, a, gate_i, h0, h), (dy, jnp.zeros_like(h0))
    )
    return dx, da, di


_assoc_scan_core.defvjp(_assoc_core_fwd, _assoc_core_bwd)


@jax.custom_vjp
def _assoc_scan_core_h0(x, a, gate_i, h0):
    """RG-LRU scan from a provided initial state, linear-cost custom VJP.

    Same recurrence and backward as ``_assoc_scan_core`` with two h0
    differences: ``h_prev`` at t = 0 is ``h0`` (not zero), which also makes
    ``dh0 = a_1 * g_1`` a fourth cotangent; and the final state h_T is a
    second primal output so stateful callers (R2D2's stored-state unrolls,
    which are the training path that hits h0 != None) can chain carries
    without re-deriving it from y's dtype-cast output.  Without this path
    autodiff would go through ``associative_scan`` and re-pay the O(log T)
    tree levels of (B, T, W) saved intermediates the custom VJP exists to
    avoid.
    """
    y, h = _assoc_scan_fwd_impl(x, a, gate_i, h0)
    return y, h[:, -1]


def _assoc_core_h0_fwd(x, a, gate_i, h0):
    y, h = _assoc_scan_fwd_impl(x, a, gate_i, h0)
    return (y, h[:, -1]), (x, a, gate_i, h0, h)


def _assoc_core_h0_bwd(res, cts):
    x, a, gate_i, h0, h = res
    dy, dh_last = cts
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)
    gif = gate_i.astype(jnp.float32)
    dyf = dy.astype(jnp.float32)
    # h_T IS the (float32) scan state at step T-1, so its cotangent simply
    # adds to dy_{T-1} before the reverse scan
    dyf = dyf.at[:, -1].add(dh_last.astype(jnp.float32))
    beta = jnp.sqrt(jnp.maximum(1.0 - af**2, 0.0))

    # reverse scan: g_t = dy_t + a_{t+1} g_{t+1}  (A_t = a_{t+1}, B_t = dy_t;
    # reverse=True flips, runs the standard first-order combine, flips back)
    a_next = jnp.concatenate([af[:, 1:], jnp.zeros_like(af[:, :1])], axis=1)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, b1 * a2 + b2

    _, g = jax.lax.associative_scan(
        combine, (a_next, dyf), axis=1, reverse=True
    )
    h_prev = jnp.concatenate(
        [h0.astype(jnp.float32)[:, None], h[:, :-1]], axis=1
    )
    dx = g * beta * gif
    di = g * beta * xf
    dbeta_da = -af / jnp.maximum(beta, 1e-6)
    da = g * (h_prev + dbeta_da * gif * xf)
    dh0 = af[:, 0] * g[:, 0]
    return (
        dx.astype(x.dtype), da.astype(a.dtype), di.astype(gate_i.dtype),
        dh0.astype(h0.dtype),
    )


_assoc_scan_core_h0.defvjp(_assoc_core_h0_fwd, _assoc_core_h0_bwd)


def _assoc_scan(x, a, gate_i, h0):
    if h0 is None:
        y = _assoc_scan_core(x, a, gate_i)
        return y, y[:, -1].astype(jnp.float32)
    return _assoc_scan_core_h0(x, a, gate_i, h0)


@functools.partial(jax.jit, static_argnames=("impl", "interpret"))
def rglru_scan(
    x: jax.Array,
    a: jax.Array,
    gate_i: jax.Array,
    h0: jax.Array | None = None,
    *,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """RG-LRU scan.  x, a, gate_i: (B, T, W) -> y (B, T, W), h_T (B, W).

    Stored-state scans (``h0 is not None`` — the R2D2 training path) always
    take the associative-scan implementation with its linear-memory custom
    VJP: the Pallas kernel starts from zero state and has no backward, so
    routing it there would raise on TPU at the first learner trace.  The
    kernel serves the zero-state (inference/prefill) path it was built for.
    """
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if (impl == "pallas" or interpret) and h0 is None:
        from repro.kernels.rglru_scan.rglru_scan import rglru_scan_pallas

        return rglru_scan_pallas(x, a, gate_i, h0, interpret=interpret)
    return _assoc_scan(x, a, gate_i, h0)
