"""Flash-decoding for TPU in Pallas: single-token attention over a long KV
cache (the Sebulba-actor / serve_step hot loop).

Grid: (B, K, num_s_blocks) — the cache-sequence dimension is the sequential
TPU grid axis; the online-softmax state for the G grouped query heads lives
in VMEM scratch and persists across cache blocks.  Each grid step streams
one (block_s, h) tile of K and V through the MXU against the (G, h) query
tile, so the kernel is purely HBM-bandwidth-bound — the roofline floor for
decode.  Blocks whose positions are entirely masked (beyond ``pos`` or
outside the sliding window) are skipped with pl.when, so decode cost tracks
the *filled* cache length, not the allocated one.

Decode positions are **per row**: the scalar-prefetch ``pos`` vector holds
one int32 position per batch row (a scalar broadcasts), so rows of one
batch may sit at ragged depths — the continuous-batching serving invariant
(PR 10).  ``flash_decode_pallas_paged`` is the block-table variant: the KV
cache is a pool of fixed-size physical pages ``(P, bs, K, h)`` and a
prefetched ``(B, nb)`` block table maps row-local logical block ``si`` to
its physical page *in the BlockSpec index_map*, so the gather costs zero
extra copies — each grid step DMAs exactly the page the table names.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    pos_ref,  # scalar prefetch: (B,) int32 per-row decode positions
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # VMEM scratch
    *,
    block_s: int,
    num_s_blocks: int,
    window: int,
    sm_scale: float,
):
    si = pl.program_id(2)
    pos = pos_ref[pl.program_id(0)]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s_start = si * block_s
    run = s_start <= pos
    if window:
        run &= s_start + block_s - 1 > pos - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (G, h)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, h)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = q @ k.T  # (G, bs)
        k_pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos <= pos
        if window:
            valid &= k_pos > pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * scale[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(si == num_s_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


def _paged_decode_kernel(pos_ref, bt_ref, *rest, **kw):
    # the block table is consumed by the BlockSpec index_maps only; the
    # kernel body masks on logical positions exactly like the dense one
    del bt_ref
    _decode_kernel(pos_ref, *rest, **kw)


def _pos_vector(pos, batch: int) -> jax.Array:
    """Scalar or (B,) position -> the (B,) int32 prefetch vector."""
    pos = jnp.asarray(pos, jnp.int32)
    return jnp.broadcast_to(pos.reshape(-1), (batch,)) if pos.ndim else (
        jnp.full((batch,), pos, jnp.int32)
    )


@functools.partial(
    jax.jit, static_argnames=("window", "block_s", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,  # (B, 1, H, h)
    k_cache: jax.Array,  # (B, S, K, h)
    v_cache: jax.Array,  # (B, S, K, h)
    pos: jax.Array,  # scalar int32, or (B,) per-row positions
    *,
    window: int = 0,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} must divide block_s={block_s}")
    ns = S // block_s

    qh = q.reshape(B, K, G, h)  # (B, K, G, h)
    grid = (B, K, ns)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            block_s=block_s, num_s_blocks=ns, window=window,
            sm_scale=h**-0.5,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, h), lambda b, k, si, pos: (b, k, 0, 0)),
                pl.BlockSpec(
                    (1, block_s, 1, h), lambda b, k, si, pos: (b, si, k, 0)
                ),
                pl.BlockSpec(
                    (1, block_s, 1, h), lambda b, k, si, pos: (b, si, k, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, h), lambda b, k, si, pos: (b, k, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, h), q.dtype),
        interpret=interpret,
    )(_pos_vector(pos, B), qh, k_cache, v_cache)
    return out.reshape(B, 1, H, h)


@functools.partial(jax.jit, static_argnames=("interpret",))
def flash_decode_pallas_paged(
    q: jax.Array,  # (B, 1, H, h)
    k_pages: jax.Array,  # (P, bs, K, h) physical page pool
    v_pages: jax.Array,  # (P, bs, K, h)
    block_tables: jax.Array,  # (B, nb) int32: logical block -> physical page
    pos: jax.Array,  # scalar int32, or (B,) per-row positions
    *,
    interpret: bool = False,
) -> jax.Array:
    """Paged flash-decode: the block table rides the scalar prefetch and
    the K/V BlockSpec index_maps dereference it, so the "gather" is just
    which page each sequential grid step DMAs.  Logical position
    ``s = si * bs + off`` masks exactly like the dense kernel; pages the
    table maps beyond ``pos`` are skipped (their content — stale data
    from a freed request, or the reserved scratch page — never loads).
    Global attention only (the serving path); window layers stay dense.
    """
    B, _, H, h = q.shape
    P, bs, K, _ = k_pages.shape
    nb = block_tables.shape[1]
    G = H // K

    qh = q.reshape(B, K, G, h)
    grid = (B, K, nb)
    out = pl.pallas_call(
        functools.partial(
            _paged_decode_kernel,
            block_s=bs, num_s_blocks=nb, window=0, sm_scale=h**-0.5,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=2,  # pos, block_tables
            grid=grid,
            in_specs=[
                pl.BlockSpec(
                    (1, 1, G, h), lambda b, k, si, pos, bt: (b, k, 0, 0)
                ),
                pl.BlockSpec(
                    (1, bs, 1, h),
                    lambda b, k, si, pos, bt: (bt[b, si], 0, k, 0),
                ),
                pl.BlockSpec(
                    (1, bs, 1, h),
                    lambda b, k, si, pos, bt: (bt[b, si], 0, k, 0),
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, h), lambda b, k, si, pos, bt: (b, k, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, h), q.dtype),
        interpret=interpret,
    )(
        _pos_vector(pos, B),
        jnp.asarray(block_tables, jnp.int32),
        qh, k_pages, v_pages,
    )
    return out.reshape(B, 1, H, h)
