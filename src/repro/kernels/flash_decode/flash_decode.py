"""Flash-decoding for TPU in Pallas: single-token attention over a long KV
cache (the Sebulba-actor / serve_step hot loop).

Grid: (B, K, num_s_blocks) — the cache-sequence dimension is the sequential
TPU grid axis; the online-softmax state for the G grouped query heads lives
in VMEM scratch and persists across cache blocks.  Each grid step streams
one (block_s, h) tile of K and V through the MXU against the (G, h) query
tile, so the kernel is purely HBM-bandwidth-bound — the roofline floor for
decode.  Blocks whose positions are entirely masked (beyond ``pos`` or
outside the sliding window) are skipped with pl.when, so decode cost tracks
the *filled* cache length, not the allocated one.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(
    pos_ref,  # scalar prefetch: (1,) int32
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # VMEM scratch
    *,
    block_s: int,
    num_s_blocks: int,
    window: int,
    sm_scale: float,
):
    si = pl.program_id(2)
    pos = pos_ref[0]

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    s_start = si * block_s
    run = s_start <= pos
    if window:
        run &= s_start + block_s - 1 > pos - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (G, h)
        k = k_ref[0, :, 0].astype(jnp.float32)  # (bs, h)
        v = v_ref[0, :, 0].astype(jnp.float32)
        s = q @ k.T  # (G, bs)
        k_pos = s_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        valid = k_pos <= pos
        if window:
            valid &= k_pos > pos - window
        s = jnp.where(valid, s, NEG_INF)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * scale[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(si == num_s_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit, static_argnames=("window", "block_s", "interpret")
)
def flash_decode_pallas(
    q: jax.Array,  # (B, 1, H, h)
    k_cache: jax.Array,  # (B, S, K, h)
    v_cache: jax.Array,  # (B, S, K, h)
    pos: jax.Array,  # scalar int32
    *,
    window: int = 0,
    block_s: int = 256,
    interpret: bool = False,
) -> jax.Array:
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    block_s = min(block_s, S)
    if S % block_s:
        raise ValueError(f"S={S} must divide block_s={block_s}")
    ns = S // block_s

    qh = q.reshape(B, K, G, h)  # (B, K, G, h)
    grid = (B, K, ns)
    out = pl.pallas_call(
        functools.partial(
            _decode_kernel,
            block_s=block_s, num_s_blocks=ns, window=window,
            sm_scale=h**-0.5,
        ),
        grid_spec=pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1,
            grid=grid,
            in_specs=[
                pl.BlockSpec((1, 1, G, h), lambda b, k, si, pos: (b, k, 0, 0)),
                pl.BlockSpec(
                    (1, block_s, 1, h), lambda b, k, si, pos: (b, si, k, 0)
                ),
                pl.BlockSpec(
                    (1, block_s, 1, h), lambda b, k, si, pos: (b, si, k, 0)
                ),
            ],
            out_specs=pl.BlockSpec(
                (1, 1, G, h), lambda b, k, si, pos: (b, k, 0, 0)
            ),
            scratch_shapes=[
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G,), jnp.float32),
                pltpu.VMEM((G, h), jnp.float32),
            ],
        ),
        out_shape=jax.ShapeDtypeStruct((B, K, G, h), q.dtype),
        interpret=interpret,
    )(jnp.asarray([pos], jnp.int32), qh, k_cache, v_cache)
    return out.reshape(B, 1, H, h)
