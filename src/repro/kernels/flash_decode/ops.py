"""Jitted flash-decoding wrapper: Pallas on TPU, jnp einsum elsewhere.

``pos`` is a scalar (lockstep batch, the PR 9 path — unchanged) or a
``(B,)`` int32 vector of per-row decode positions (the continuous-batching
serving path, PR 10).  ``block_tables`` switches to the paged layout:
``k_cache``/``v_cache`` are physical page pools ``(P, bs, K, h)`` and the
``(B, nb)`` table maps each row's logical blocks onto them — the Pallas
kernel dereferences the table in its BlockSpec index_map on TPU; the jnp
path gathers pages to the dense layout and runs the dense oracle, which
keeps paged and dense decode bit-identical off-TPU.
"""

from __future__ import annotations

import functools

import jax


@functools.partial(jax.jit, static_argnames=("window", "impl", "interpret"))
def flash_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    block_tables: jax.Array | None = None,
    window: int = 0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if block_tables is not None:
        if window:
            raise ValueError(
                "paged decode is global-attention only: sliding-window "
                "layers keep the dense per-row cache (window=0 required "
                "with block_tables)"
            )
        if impl == "pallas" or interpret:
            from repro.kernels.flash_decode.flash_decode import (
                flash_decode_pallas_paged,
            )

            return flash_decode_pallas_paged(
                q, k_cache, v_cache, block_tables, pos, interpret=interpret
            )
        from repro.kernels.flash_decode.ref import gather_pages
        from repro.models.attention import decode_attention

        return decode_attention(
            q,
            gather_pages(k_cache, block_tables),
            gather_pages(v_cache, block_tables),
            pos,
        )
    if impl == "pallas" or interpret:
        from repro.kernels.flash_decode.flash_decode import flash_decode_pallas

        return flash_decode_pallas(
            q, k_cache, v_cache, pos, window=window, interpret=interpret
        )
    from repro.models.attention import decode_attention

    return decode_attention(q, k_cache, v_cache, pos, window=window)
