"""Jitted flash-decoding wrapper: Pallas on TPU, jnp einsum elsewhere."""

from __future__ import annotations

import functools

import jax


@functools.partial(jax.jit, static_argnames=("window", "impl", "interpret"))
def flash_decode(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas" or interpret:
        from repro.kernels.flash_decode.flash_decode import flash_decode_pallas

        return flash_decode_pallas(
            q, k_cache, v_cache, pos, window=window, interpret=interpret
        )
    from repro.models.attention import decode_attention

    return decode_attention(q, k_cache, v_cache, pos, window=window)
