"""Pure-jnp oracle for single-token decode attention against a KV cache.

q: (B, 1, H, h); k_cache/v_cache: (B, S, K, h); pos: scalar — attend to
cache entries <= pos (and > pos - window when window > 0).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, h).astype(jnp.float32) * (h**-0.5)
    logits = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache.astype(jnp.float32)
    )
    k_pos = jnp.arange(S)
    valid = k_pos <= pos
    if window:
        valid &= k_pos > pos - window
    logits = jnp.where(valid[None, None, None, :], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, h).astype(q.dtype)
