"""Pure-jnp oracle for single-token decode attention against a KV cache.

q: (B, 1, H, h); k_cache/v_cache: (B, S, K, h); pos: scalar OR per-row
(B,) int32 — row b attends to cache entries <= pos[b] (and > pos[b] -
window when window > 0).  The scalar form is the PR 9 lockstep path and
stays bit-identical; the per-row form is the serving path (PR 10), where
rows of one batch sit at ragged decode positions.

``gather_pages`` materializes a block-table-mapped paged cache as the
dense (B, S, K, h) layout, so the paged oracle is *literally* the dense
oracle over gathered pages — the bit-exactness anchor the Pallas paged
kernel and the ServeEngine equivalence tests pin against.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _valid_mask(S: int, pos: jax.Array, window: int) -> jax.Array:
    """-> (S,) for scalar pos (the PR 9 path, kept bit-identical) or
    (B, S) for per-row pos."""
    k_pos = jnp.arange(S)
    pos = jnp.asarray(pos)
    if pos.ndim == 0:
        valid = k_pos <= pos
        if window:
            valid &= k_pos > pos - window
        return valid
    valid = k_pos[None, :] <= pos[:, None]
    if window:
        valid &= k_pos[None, :] > (pos[:, None] - window)
    return valid


def decode_attention_ref(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    B, _, H, h = q.shape
    S, K = k_cache.shape[1], k_cache.shape[2]
    G = H // K
    qg = q.reshape(B, K, G, h).astype(jnp.float32) * (h**-0.5)
    logits = jnp.einsum(
        "bkgh,bskh->bkgs", qg, k_cache.astype(jnp.float32)
    )
    valid = _valid_mask(S, pos, window)
    if valid.ndim == 1:
        mask = valid[None, None, None, :]
    else:
        mask = valid[:, None, None, :]
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgs,bskh->bkgh", p, v_cache.astype(jnp.float32))
    return out.reshape(B, 1, H, h).astype(q.dtype)


def gather_pages(pages: jax.Array, block_tables: jax.Array) -> jax.Array:
    """pages: (P, bs, K, h); block_tables: (B, nb) int32 physical-block
    ids -> dense (B, nb*bs, K, h).  Logical position s of row b lives at
    pages[block_tables[b, s // bs], s % bs]."""
    B, nb = block_tables.shape
    _, bs, K, h = pages.shape
    gathered = pages[block_tables]  # (B, nb, bs, K, h)
    return gathered.reshape(B, nb * bs, K, h)


def paged_decode_attention_ref(
    q: jax.Array,
    k_pages: jax.Array,
    v_pages: jax.Array,
    block_tables: jax.Array,
    pos: jax.Array,
    *,
    window: int = 0,
) -> jax.Array:
    """Block-table-gathered decode oracle: gather pages to the dense
    layout, then run the dense oracle.  Positions beyond ``pos`` are
    masked to exactly NEG_INF before the softmax, so whatever an
    unmapped / stale block holds cannot reach the output — the property
    the paged-vs-dense bit-exactness contract rests on."""
    kc = gather_pages(k_pages, block_tables)
    vc = gather_pages(v_pages, block_tables)
    return decode_attention_ref(q, kc, vc, pos, window=window)
