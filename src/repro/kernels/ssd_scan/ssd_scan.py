"""Mamba-2 SSD chunked scan as a Pallas TPU kernel.

Grid: (B, H, num_chunks) — the chunk dimension is sequential on TPU, so the
inter-chunk SSM state (P, N) lives in VMEM scratch and persists across
chunks (exactly the carry of the chunked SSD algorithm).  Per grid step the
kernel does the three matmuls of the state-space-duality formulation
(intra-chunk "attention", inter-chunk state read-out, chunk-state update) —
all MXU work on (Q x Q), (Q x N) and (P x N) tiles.

This is the TPU-native adaptation: the original CUDA kernel leans on warp
shuffles for the recurrence; on TPU we rephrase the whole chunk as matmuls
(as §6 of the paper itself suggests) and let the sequential grid carry the
state in VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _ssd_kernel(
    x_ref, dt_ref, a_ref, b_ref, c_ref,  # inputs
    y_ref, s_out_ref,  # outputs
    state_ref,  # scratch: (P, N) f32 carried across chunks
    *,
    num_chunks: int,
):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        state_ref[...] = jnp.zeros_like(state_ref)

    x = x_ref[0, 0].astype(jnp.float32)  # (Q, P)
    dt = dt_ref[0, 0].astype(jnp.float32)  # (Q,)
    A = a_ref[0].astype(jnp.float32)  # scalar per head
    Bm = b_ref[0].astype(jnp.float32)  # (Q, N)
    Cm = c_ref[0].astype(jnp.float32)  # (Q, N)

    logdec = dt * A  # (Q,)
    cum = jnp.cumsum(logdec)  # inclusive log decay
    Q = x.shape[0]

    # intra-chunk: M[t,s] = (C_t . B_s) exp(cum_t - cum_s), s <= t
    scores = Cm @ Bm.T  # (Q, Q)
    delta = cum[:, None] - cum[None, :]
    causal = (
        jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 0)
        >= jax.lax.broadcasted_iota(jnp.int32, (Q, Q), 1)
    )
    attn = jnp.where(causal, scores * jnp.exp(delta), 0.0)
    dx = x * dt[:, None]  # (Q, P)
    y_intra = attn @ dx  # (Q, P)

    # inter-chunk: y_t += exp(cum_t) * C_t . S_prev
    state = state_ref[...]  # (P, N)
    y_inter = jnp.exp(cum)[:, None] * (Cm @ state.T)  # (Q, P)

    y_ref[0, 0] = (y_intra + y_inter).astype(y_ref.dtype)

    # state update: S = exp(cum_Q) S_prev + sum_s exp(cum_Q - cum_s) dx_s (x) B_s
    tail = jnp.exp(cum[-1] - cum)  # (Q,)
    state_new = state * jnp.exp(cum[-1]) + (dx * tail[:, None]).T @ Bm
    state_ref[...] = state_new

    @pl.when(ci == num_chunks - 1)
    def _emit_state():
        s_out_ref[0, 0] = state_new


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan_pallas(
    x: jax.Array,  # (B, T, H, P)
    dt: jax.Array,  # (B, T, H)
    A: jax.Array,  # (H,)
    Bm: jax.Array,  # (B, T, N)
    Cm: jax.Array,  # (B, T, N)
    init_state=None,  # unsupported in the kernel path (always zero)
    *,
    chunk: int = 256,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    if init_state is not None:
        raise NotImplementedError("kernel path starts from zero state")
    B, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    if T % Q:
        raise ValueError(f"T={T} must divide chunk={Q}")
    nc = T // Q

    # head-major, chunked layouts
    xh = x.transpose(0, 2, 1, 3)  # (B, H, T, P)
    dth = dt.transpose(0, 2, 1)  # (B, H, T)

    grid = (B, H, nc)
    y, s_final = pl.pallas_call(
        functools.partial(_ssd_kernel, num_chunks=nc),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, Q), lambda b, hh, c: (b, hh, c)),
            pl.BlockSpec((1,), lambda b, hh, c: (hh,)),
            pl.BlockSpec((1, Q, N), lambda b, hh, c: (b, c, 0)),
            pl.BlockSpec((1, Q, N), lambda b, hh, c: (b, c, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, Q, P), lambda b, hh, c: (b, hh, c, 0)),
            pl.BlockSpec((1, 1, P, N), lambda b, hh, c: (b, hh, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((B, H, T, P), x.dtype),
            jax.ShapeDtypeStruct((B, H, P, N), jnp.float32),
        ],
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(xh, dth, A, Bm, Cm)
    return y.transpose(0, 2, 1, 3), s_final
