"""Pure-jnp oracle for the Mamba-2 SSD scan (naive sequential recurrence).

Recurrence (per batch b, head h, with state S in R^{P x N}):

    S_t = exp(dt_t * A_h) * S_{t-1} + dt_t * (x_t outer B_t)
    y_t = S_t @ C_t

Shapes:
    x  : (B, T, H, P)   inputs per head
    dt : (B, T, H)      positive step sizes (already softplus-ed)
    A  : (H,)           negative per-head decay
    Bm : (B, T, N)      input->state projection (single group)
    Cm : (B, T, N)      state->output projection
Returns:
    y  : (B, T, H, P)
    S  : (B, H, P, N)   final state
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_scan_ref(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    init_state: jax.Array | None = None,
) -> tuple[jax.Array, jax.Array]:
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    Af = A.astype(jnp.float32)
    Bf = Bm.astype(jnp.float32)
    Cf = Cm.astype(jnp.float32)

    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(S, inp):
        xt, dtt, Bt, Ct = inp  # (B,H,P), (B,H), (B,N), (B,N)
        decay = jnp.exp(dtt * Af)  # (B, H)
        upd = jnp.einsum("bhp,bn->bhpn", xt * dtt[..., None], Bt)
        S = S * decay[..., None, None] + upd
        y = jnp.einsum("bhpn,bn->bhp", S, Ct)
        return S, y

    xs = (
        jnp.moveaxis(xf, 1, 0),
        jnp.moveaxis(dtf, 1, 0),
        jnp.moveaxis(Bf, 1, 0),
        jnp.moveaxis(Cf, 1, 0),
    )
    S, ys = jax.lax.scan(step, init_state.astype(jnp.float32), xs)
    y = jnp.moveaxis(ys, 0, 1).astype(x.dtype)  # (B, T, H, P)
    return y, S


def ssd_step_ref(
    S: jax.Array,
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  S: (B,H,P,N); x: (B,H,P); dt: (B,H); Bm/Cm: (B,N)."""
    decay = jnp.exp(dt.astype(jnp.float32) * A.astype(jnp.float32))
    upd = jnp.einsum(
        "bhp,bn->bhpn", x.astype(jnp.float32) * dt[..., None], Bm.astype(jnp.float32)
    )
    S = S * decay[..., None, None] + upd
    y = jnp.einsum("bhpn,bn->bhp", S, Cm.astype(jnp.float32))
    return y.astype(x.dtype), S
