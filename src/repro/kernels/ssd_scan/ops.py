"""Jitted SSD scan wrapper: chunked matmul formulation (TPU-native).

The chunked state-space-duality algorithm (Dao & Gu, arXiv:2405.21060 §6)
re-expresses the linear recurrence as per-chunk attention-like matmuls plus a
short sequential scan over chunk boundary states — this maps the SSM onto
the MXU instead of a length-T elementwise loop.

Dispatch: on TPU backends the Pallas kernel (ssd_scan.py) is used; elsewhere
(CPU dry-run, tests) the identical chunked algorithm runs as pure jnp.
``interpret=True`` forces the Pallas kernel in interpreter mode for kernel
tests on CPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp


def _chunked_ssd(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    chunk: int,
    init_state: jax.Array | None,
) -> tuple[jax.Array, jax.Array]:
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = min(chunk, T)
    if T % Q:
        raise ValueError(f"seq len {T} not divisible by chunk {Q}")
    nc = T // Q

    xf = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtf = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bf = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cf = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Af = A.astype(jnp.float32)

    # cumulative log-decay within each chunk, inclusive of step t
    logdec = dtf * Af  # (B, nc, Q, H)
    cum = jnp.cumsum(logdec, axis=2)  # L[t] = sum_{tau<=t} dt_tau * A

    # intra-chunk: M[t,s] = (C_t . B_s) * exp(L[t]-L[s]) for s<=t
    scores = jnp.einsum("bcqn,bcsn->bcqs", Cf, Bf)  # (B,nc,Q,Q)
    delta = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nc,Q(t),Q(s),H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay_m = jnp.where(causal[None, None, :, :, None], jnp.exp(delta), 0.0)
    attn = scores[..., None] * decay_m  # (B,nc,Q,Q,H)
    dx = xf * dtf[..., None]  # (B,nc,Q,H,P)
    y_intra = jnp.einsum("bcqsh,bcshp->bcqhp", attn, dx)

    # chunk-final states: S_c = sum_s exp(L[Q-1]-L[s]) dx_s (x) B_s
    tail = jnp.exp(cum[:, :, -1:, :] - cum)  # (B,nc,Q,H)
    S_c = jnp.einsum("bcqh,bcqhp,bcqn->bchpn", tail, dx, Bf)
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (B,nc,H) total decay per chunk

    # sequential inter-chunk scan (nc steps)
    if init_state is None:
        init_state = jnp.zeros((Bsz, H, P, N), jnp.float32)

    def step(S_prev, inp):
        S_chunk, dec = inp  # (B,H,P,N), (B,H)
        S_new = S_prev * dec[..., None, None] + S_chunk
        return S_new, S_prev

    (S_final, S_prevs) = jax.lax.scan(
        step,
        init_state.astype(jnp.float32),
        (jnp.moveaxis(S_c, 1, 0), jnp.moveaxis(chunk_decay, 1, 0)),
    )
    S_prevs = jnp.moveaxis(S_prevs, 0, 1)  # (B,nc,H,P,N) state entering chunk

    # inter-chunk contribution: y_t += C_t . (exp(L[t]) * S_prev)
    y_inter = jnp.einsum(
        "bcqn,bcqh,bchpn->bcqhp", Cf, jnp.exp(cum), S_prevs
    )
    y = (y_intra + y_inter).reshape(Bsz, T, H, P).astype(x.dtype)
    return y, S_final


def _ssd_one_chunk(S_prev, xc, dtc, Bc, Cc, A):
    """One chunk of the SSD duality.  S_prev: (B,H,P,N); xc: (B,Q,H,P);
    dtc: (B,Q,H); Bc/Cc: (B,Q,N); A: (H,).  Returns (y_c, S_new)."""
    logdec = dtc * A  # (B,Q,H)
    cum = jnp.cumsum(logdec, axis=1)
    Q = xc.shape[1]
    scores = jnp.einsum("bqn,bsn->bqs", Cc, Bc)
    delta = cum[:, :, None, :] - cum[:, None, :, :]  # (B,Q,S,H)
    causal = jnp.tril(jnp.ones((Q, Q), bool))
    decay = jnp.where(causal[None, :, :, None], jnp.exp(delta), 0.0)
    attn = scores[..., None] * decay  # (B,Q,S,H)
    dx = xc * dtc[..., None]
    y_intra = jnp.einsum("bqsh,bshp->bqhp", attn, dx)
    y_inter = jnp.einsum("bqn,bqh,bhpn->bqhp", Cc, jnp.exp(cum), S_prev)
    tail = jnp.exp(cum[:, -1:, :] - cum)
    S_new = S_prev * jnp.exp(cum[:, -1])[..., None, None] + jnp.einsum(
        "bqh,bqhp,bqn->bhpn", tail, dx, Bc
    )
    return y_intra + y_inter, S_new


@functools.partial(jax.custom_vjp, nondiff_argnums=(5,))
def _ssd_chunk_scan(x, dt, A, Bm, Cm, nc):
    y, S_final, _ = _ssd_chunk_scan_fwd_impl(x, dt, A, Bm, Cm, nc)
    return y, S_final


def _ssd_chunk_scan_fwd_impl(x, dt, A, Bm, Cm, nc):
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    Q = T // nc
    xc = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Af = A.astype(jnp.float32)

    def step(S, inp):
        xi, di, bi, ci = inp
        y, S_new = _ssd_one_chunk(S, xi, di, bi, ci, Af)
        return S_new, (y, S)  # also emit the INCOMING state (bwd residual)

    S0 = jnp.zeros((Bsz, H, P, N), jnp.float32)
    xs = jax.tree.map(lambda a: jnp.moveaxis(a, 1, 0), (xc, dtc, Bc, Cc))
    S_final, (ys, S_prevs) = jax.lax.scan(step, S0, xs)
    y = jnp.moveaxis(ys, 0, 1).reshape(Bsz, T, H, P).astype(x.dtype)
    return y, S_final, S_prevs  # S_prevs: (nc, B, H, P, N)


def _ssd_vjp_fwd(x, dt, A, Bm, Cm, nc):
    y, S_final, S_prevs = _ssd_chunk_scan_fwd_impl(x, dt, A, Bm, Cm, nc)
    return (y, S_final), (x, dt, A, Bm, Cm, S_prevs)


def _ssd_vjp_bwd(nc_static, res, cts):
    """Reverse scan over chunks: each step re-runs ONE chunk under jax.vjp —
    live memory is a single chunk's intermediates plus the (nc, B, H, P, N)
    state checkpoints, instead of every chunk's (B, Q, Q, H) decay/attn
    tensors (the dominant mamba2 training-memory term)."""
    dy, dS_final = cts
    x, dt, A, Bm, Cm, S_prevs = res
    Bsz, T, H, P = x.shape
    N = Bm.shape[-1]
    nc = nc_static
    Q = T // nc
    xc = x.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)
    dtc = dt.astype(jnp.float32).reshape(Bsz, nc, Q, H)
    Bc = Bm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Cc = Cm.astype(jnp.float32).reshape(Bsz, nc, Q, N)
    Af = A.astype(jnp.float32)
    dyc = dy.astype(jnp.float32).reshape(Bsz, nc, Q, H, P)

    def step(carry, inp):
        dS, dA_acc = carry  # cotangent wrt the chunk's OUTPUT state
        xi, di, bi, ci, dyi, S_prev = inp

        def f(S, xi, di, bi, ci, A):
            return _ssd_one_chunk(S, xi, di, bi, ci, A)

        _, vjp = jax.vjp(f, S_prev, xi, di, bi, ci, Af)
        dS_prev, dxi, ddi, dbi, dci, dAi = vjp((dyi, dS))
        return (dS_prev, dA_acc + dAi), (dxi, ddi, dbi, dci)

    xs = jax.tree.map(
        lambda a: jnp.moveaxis(a, 1, 0) if a.ndim > 3 else a,
        (xc, dtc, Bc, Cc, dyc),
    )
    xs = xs + (S_prevs,)
    dS0 = dS_final.astype(jnp.float32)
    (dS_first, dA), (dxs, ddts, dBs, dCs) = jax.lax.scan(
        step, (dS0, jnp.zeros_like(Af)), xs, reverse=True
    )
    del dS_first
    dx = jnp.moveaxis(dxs, 0, 1).reshape(Bsz, T, H, P).astype(x.dtype)
    ddt = jnp.moveaxis(ddts, 0, 1).reshape(Bsz, T, H).astype(dt.dtype)
    dB = jnp.moveaxis(dBs, 0, 1).reshape(Bsz, T, N).astype(Bm.dtype)
    dC = jnp.moveaxis(dCs, 0, 1).reshape(Bsz, T, N).astype(Cm.dtype)
    return dx, ddt, dA.astype(A.dtype), dB, dC


_ssd_chunk_scan.defvjp(_ssd_vjp_fwd, _ssd_vjp_bwd)


@functools.partial(jax.jit, static_argnames=("chunk", "impl", "interpret"))
def ssd_scan(
    x: jax.Array,
    dt: jax.Array,
    A: jax.Array,
    Bm: jax.Array,
    Cm: jax.Array,
    init_state: jax.Array | None = None,
    *,
    chunk: int = 256,
    impl: str = "auto",
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Chunked SSD scan.  See ref.py for shapes."""
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas" or interpret:
        from repro.kernels.ssd_scan.ssd_scan import ssd_scan_pallas

        return ssd_scan_pallas(
            x, dt, A, Bm, Cm, init_state, chunk=chunk, interpret=interpret
        )
    if impl == "jnp" and init_state is None:
        # chunk-scan layout with the memory-bounded custom VJP (§Perf):
        # backward re-runs one chunk at a time instead of saving every
        # chunk's (B, Q, Q, H) attn/decay tensors.
        T = x.shape[1]
        Q = min(chunk, T)
        if T % Q:
            raise ValueError(f"seq len {T} not divisible by chunk {Q}")
        return _ssd_chunk_scan(x, dt, A, Bm, Cm, T // Q)
    return _chunked_ssd(x, dt, A, Bm, Cm, chunk, init_state)
