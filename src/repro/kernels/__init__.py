"""Pallas TPU kernels for the perf-critical compute layers.

Each kernel package is <name>/{<name>.py, ops.py, ref.py}:
  * <name>.py — pl.pallas_call with explicit BlockSpec VMEM tiling
  * ops.py    — jitted wrapper with backend dispatch (pallas on TPU,
                equivalent jnp path elsewhere; interpret=True for CPU tests)
  * ref.py    — pure-jnp oracle the kernel is validated against

Kernels: flash_attention (prefill/training fwd; training bwd runs through
the flash custom VJP in repro/models/attention.py), flash_decode
(single-token decode over long KV caches), ssd_scan (Mamba-2 chunked SSD),
rglru_scan (Griffin RG-LRU), vtrace (IMPALA reverse scan).
"""
