"""Pure-jnp oracle for flash attention: naive full-softmax attention.

Only used at test shapes (the (T, S) matrix is materialized).  GQA via the
(K, G) head grouping; optional causality and sliding window.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def attention_ref(
    q: jax.Array,  # (B, T, H, h)
    k: jax.Array,  # (B, S, K, h)
    v: jax.Array,  # (B, S, K, h)
    *,
    causal: bool = True,
    window: int = 0,
) -> jax.Array:
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    qg = q.reshape(B, T, K, G, h).astype(jnp.float32) * (h**-0.5)
    logits = jnp.einsum(
        "btkgh,bskh->bkgts", qg, k.astype(jnp.float32)
    )  # (B, K, G, T, S)
    q_pos = jnp.arange(T)[:, None]
    k_pos = jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= k_pos <= q_pos
    if window:
        mask &= k_pos > q_pos - window
    logits = jnp.where(mask[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bkgts,bskh->btkgh", p, v.astype(jnp.float32))
    return out.reshape(B, T, H, h).astype(q.dtype)
