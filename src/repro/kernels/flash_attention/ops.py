"""Jitted flash attention wrapper with backend dispatch.

On TPU the Pallas kernel runs; elsewhere the chunked online-softmax jnp
implementation (repro/models/attention.py) — same math, same O(T·block)
memory — is used.  ``interpret=True`` exercises the Pallas kernel on CPU.
"""

from __future__ import annotations

import functools

import jax


@functools.partial(
    jax.jit, static_argnames=("causal", "window", "impl", "interpret")
)
def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int = 0,
    impl: str = "auto",
    interpret: bool = False,
) -> jax.Array:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas" or interpret:
        from repro.kernels.flash_attention.flash_attention import (
            flash_attention_pallas,
        )

        return flash_attention_pallas(
            q, k, v, causal=causal, window=window, interpret=interpret
        )
    from repro.models import attention

    if window:
        return attention.sliding_window_attention(q, k, v, window=window)
    return attention.full_attention(q, k, v, causal=causal)
