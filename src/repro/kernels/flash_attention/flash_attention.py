"""Flash attention for TPU in Pallas (prefill / training path).

Grid: (B, H, num_q_blocks, num_kv_blocks) — the last (kv) dimension is
sequential on TPU, so the online-softmax running state (m, l, acc) lives in
VMEM scratch and persists across kv steps.  BlockSpec index maps implement
GQA by pointing q-head ``head`` at kv-head ``head // G`` without
materializing broadcast K/V.  Causal q-blocks skip kv-blocks entirely in
the future (pl.when), so the causal kernel does ~half the work.

Block sizes default to (128, 128): MXU-aligned, and the VMEM working set
(q + k + v blocks + f32 accumulators) stays « 16 MB for head_dim ≤ 256.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(
    q_ref, k_ref, v_ref,  # inputs
    o_ref,  # output
    m_ref, l_ref, acc_ref,  # scratch (f32)
    *,
    causal: bool,
    window: int,
    sm_scale: float,
    block_q: int,
    block_kv: int,
    num_kv_blocks: int,
):
    qi = pl.program_id(2)
    kj = pl.program_id(3)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = kj * block_kv

    # skip kv blocks strictly in the future of this q block (causal) or
    # entirely outside the sliding window
    run = jnp.bool_(True)
    if causal:
        run &= k_start <= q_start + block_q - 1
    if window:
        run &= k_start + block_kv - 1 > q_start - window

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0, :, :].astype(jnp.float32) * sm_scale  # (bq, h)
        k = k_ref[0, 0, :, :].astype(jnp.float32)  # (bkv, h)
        v = v_ref[0, 0, :, :].astype(jnp.float32)
        s = q @ k.T  # (bq, bkv)
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
        mask = jnp.ones_like(s, dtype=jnp.bool_)
        if causal:
            mask &= k_pos <= q_pos
        if window:
            mask &= k_pos > q_pos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        scale = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * scale + p.sum(axis=1)
        acc_ref[...] = acc_ref[...] * scale[:, None] + p @ v
        m_ref[...] = m_new

    @pl.when(kj == num_kv_blocks - 1)
    def _finalize():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0, :, :] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_kv", "interpret"),
)
def flash_attention_pallas(
    q: jax.Array,  # (B, T, H, h)
    k: jax.Array,  # (B, S, K, h)
    v: jax.Array,  # (B, S, K, h)
    *,
    causal: bool = True,
    window: int = 0,
    block_q: int = 128,
    block_kv: int = 128,
    interpret: bool = False,
) -> jax.Array:
    B, T, H, h = q.shape
    S, K = k.shape[1], k.shape[2]
    G = H // K
    block_q = min(block_q, T)
    block_kv = min(block_kv, S)
    if T % block_q or S % block_kv:
        raise ValueError(f"T={T}, S={S} must divide blocks ({block_q},{block_kv})")
    nq, nkv = T // block_q, S // block_kv

    # layout: heads-major so each grid step reads one (block, head_dim) tile
    qh = q.transpose(0, 2, 1, 3)  # (B, H, T, h)
    kh = k.transpose(0, 2, 1, 3)  # (B, K, S, h)
    vh = v.transpose(0, 2, 1, 3)

    grid = (B, H, nq, nkv)
    out = pl.pallas_call(
        functools.partial(
            _flash_kernel,
            causal=causal, window=window, sm_scale=h**-0.5,
            block_q=block_q, block_kv=block_kv, num_kv_blocks=nkv,
        ),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, h), lambda b, hh, qi, kj: (b, hh, qi, 0)),
            pl.BlockSpec(
                (1, 1, block_kv, h), lambda b, hh, qi, kj: (b, hh // G, kj, 0)
            ),
            pl.BlockSpec(
                (1, 1, block_kv, h), lambda b, hh, qi, kj: (b, hh // G, kj, 0)
            ),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, h), lambda b, hh, qi, kj: (b, hh, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((B, H, T, h), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q,), jnp.float32),  # m: running max
            pltpu.VMEM((block_q,), jnp.float32),  # l: running sum
            pltpu.VMEM((block_q, h), jnp.float32),  # acc: weighted values
        ],
        interpret=interpret,
    )(qh, kh, vh)
    return out.transpose(0, 2, 1, 3)  # back to (B, T, H, h)
