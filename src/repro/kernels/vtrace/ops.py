"""Jitted V-trace wrapper with Pallas/TPU dispatch.

V-trace is the RL hot loop that every Sebulba learner step runs over the
full (B, T) trajectory batch.  On TPU it runs as a Pallas kernel (batch
rows tiled into VMEM, the T-recursion sequential in-register); elsewhere the
jnp reference runs (identical math).  ``interpret=True`` exercises the
Pallas kernel on CPU for tests.

No gradients flow through v-trace targets (IMPALA treats vs / advantages as
constants), so the op is wrapped in stop_gradient and needs no custom VJP.
"""

from __future__ import annotations

import functools

import jax

from repro.kernels.vtrace.ref import VTraceOutput, vtrace_ref


@functools.partial(
    jax.jit, static_argnames=("clip_rho", "clip_c", "lambda_", "impl", "interpret")
)
def vtrace(
    log_rhos: jax.Array,
    discounts: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    bootstrap_value: jax.Array,
    *,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
    lambda_: float = 1.0,
    impl: str = "auto",
    interpret: bool = False,
) -> VTraceOutput:
    if impl == "auto":
        impl = "pallas" if jax.default_backend() == "tpu" else "jnp"
    if impl == "pallas" or interpret:
        from repro.kernels.vtrace.vtrace import vtrace_pallas

        out = vtrace_pallas(
            log_rhos, discounts, rewards, values, bootstrap_value,
            clip_rho=clip_rho, clip_c=clip_c, lambda_=lambda_,
            interpret=interpret,
        )
    else:
        out = vtrace_ref(
            log_rhos, discounts, rewards, values, bootstrap_value,
            clip_rho=clip_rho, clip_c=clip_c, lambda_=lambda_,
        )
    return VTraceOutput(*jax.tree.map(jax.lax.stop_gradient, tuple(out)))
