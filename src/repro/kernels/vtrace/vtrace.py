"""V-trace reverse recursion as a Pallas TPU kernel.

Grid: (num_batch_blocks,).  A block of trajectory rows (block_b, T) is
resident in VMEM; the reverse time recursion runs as a fori_loop with the
accumulator held in registers/VMEM, fully parallel across the batch rows in
the VPU lanes.  One kernel launch computes both vs and pg_advantages —
fusing what would otherwise be two XLA while-loops over T.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _vtrace_kernel(
    logr_ref, disc_ref, rew_ref, val_ref, boot_ref,
    vs_ref, adv_ref,
    *,
    clip_rho: float,
    clip_c: float,
    lambda_: float,
    T: int,
):
    rhos = jnp.exp(logr_ref[...].astype(jnp.float32))  # (bb, T)
    clipped = jnp.minimum(clip_rho, rhos)
    cs = lambda_ * jnp.minimum(clip_c, rhos)
    disc = disc_ref[...].astype(jnp.float32)
    rew = rew_ref[...].astype(jnp.float32)
    val = val_ref[...].astype(jnp.float32)
    boot = boot_ref[...].astype(jnp.float32)  # (bb,)

    v_tp1 = jnp.concatenate([val[:, 1:], boot[:, None]], axis=1)
    deltas = clipped * (rew + disc * v_tp1 - val)

    def step(i, carry):
        acc, errs = carry  # acc (bb,), errs (bb, T)
        t = T - 1 - i
        acc = deltas[:, t] + disc[:, t] * cs[:, t] * acc
        errs = jax.lax.dynamic_update_index_in_dim(errs, acc, t, 1)
        return (acc, errs)

    _, errs = jax.lax.fori_loop(
        0, T, step, (jnp.zeros_like(boot), jnp.zeros_like(val))
    )
    vs = val + errs
    vs_tp1 = jnp.concatenate([vs[:, 1:], boot[:, None]], axis=1)
    adv = clipped * (rew + disc * vs_tp1 - val)
    vs_ref[...] = vs
    adv_ref[...] = adv


@functools.partial(
    jax.jit,
    static_argnames=("clip_rho", "clip_c", "lambda_", "block_b", "interpret"),
)
def vtrace_pallas(
    log_rhos: jax.Array,  # (B, T)
    discounts: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    bootstrap_value: jax.Array,  # (B,)
    *,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
    lambda_: float = 1.0,
    block_b: int = 128,
    interpret: bool = False,
):
    from repro.kernels.vtrace.ref import VTraceOutput

    B, T = log_rhos.shape
    bb = min(block_b, B)
    # pad B up to a multiple of the batch block instead of restricting the
    # caller to divisible shapes: padded rows cost one extra grid step at
    # most and compute benign values (log_rho 0 -> rho 1, everything else
    # 0), which are sliced off before returning
    B_pad = -(-B // bb) * bb
    if B_pad != B:
        row_pad = lambda x: jnp.pad(
            x, ((0, B_pad - B),) + ((0, 0),) * (x.ndim - 1)
        )
        log_rhos, discounts, rewards, values, bootstrap_value = (
            row_pad(log_rhos), row_pad(discounts), row_pad(rewards),
            row_pad(values), row_pad(bootstrap_value),
        )
    grid = (B_pad // bb,)
    spec2 = pl.BlockSpec((bb, T), lambda i: (i, 0))
    spec1 = pl.BlockSpec((bb,), lambda i: (i,))
    to_f32 = lambda x: x.astype(jnp.float32)
    vs, adv = pl.pallas_call(
        functools.partial(
            _vtrace_kernel, clip_rho=clip_rho, clip_c=clip_c,
            lambda_=lambda_, T=T,
        ),
        grid=grid,
        in_specs=[spec2, spec2, spec2, spec2, spec1],
        out_specs=[spec2, spec2],
        out_shape=[
            jax.ShapeDtypeStruct((B_pad, T), jnp.float32),
            jax.ShapeDtypeStruct((B_pad, T), jnp.float32),
        ],
        interpret=interpret,
    )(
        to_f32(log_rhos), to_f32(discounts), to_f32(rewards), to_f32(values),
        to_f32(bootstrap_value),
    )
    return VTraceOutput(vs=vs[:B], pg_advantages=adv[:B])
