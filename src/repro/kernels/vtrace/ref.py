"""Pure-jnp oracle for V-trace (IMPALA, Espeholt et al. 2018).

Given a trajectory of length T (time-major here is avoided; we use
batch-major (B, T) throughout, matching the rest of the code base):

    rho_t = min(rho_bar, exp(log pi - log mu))
    c_t   = lambda * min(c_bar, exp(log pi - log mu))
    delta_t = rho_t * (r_t + gamma_t * V_{t+1} - V_t)
    vs_t  = V_t + delta_t + gamma_t * c_t * (vs_{t+1} - V_{t+1})
    adv_t = rho_t * (r_t + gamma_t * vs_{t+1} - V_t)

The reverse recursion over t is the RL hot loop the Pallas kernel
(vtrace.py) implements; this oracle uses a reverse lax.scan.

Inputs (all (B, T) float32 except bootstrap (B,)):
    log_rhos    log pi - log mu
    discounts   gamma_t (0 at episode ends)
    rewards     r_t
    values      V(s_t)
    bootstrap   V(s_T)
Returns:
    vs (B, T), pg_advantages (B, T)
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class VTraceOutput(NamedTuple):
    vs: jax.Array
    pg_advantages: jax.Array


def vtrace_ref(
    log_rhos: jax.Array,
    discounts: jax.Array,
    rewards: jax.Array,
    values: jax.Array,
    bootstrap_value: jax.Array,
    *,
    clip_rho: float = 1.0,
    clip_c: float = 1.0,
    lambda_: float = 1.0,
) -> VTraceOutput:
    rhos = jnp.exp(log_rhos.astype(jnp.float32))
    clipped_rhos = jnp.minimum(clip_rho, rhos)
    cs = lambda_ * jnp.minimum(clip_c, rhos)
    values = values.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    discounts = discounts.astype(jnp.float32)

    values_tp1 = jnp.concatenate(
        [values[:, 1:], bootstrap_value[:, None].astype(jnp.float32)], axis=1
    )
    deltas = clipped_rhos * (rewards + discounts * values_tp1 - values)

    def body(acc, xs):
        delta, disc, c = xs  # (B,)
        acc = delta + disc * c * acc
        return acc, acc

    xs = (
        jnp.moveaxis(deltas, 1, 0)[::-1],
        jnp.moveaxis(discounts, 1, 0)[::-1],
        jnp.moveaxis(cs, 1, 0)[::-1],
    )
    _, errs_rev = jax.lax.scan(body, jnp.zeros_like(bootstrap_value, jnp.float32), xs)
    errs = jnp.moveaxis(errs_rev[::-1], 0, 1)  # (B, T): vs_t - V_t
    vs = values + errs

    vs_tp1 = jnp.concatenate(
        [vs[:, 1:], bootstrap_value[:, None].astype(jnp.float32)], axis=1
    )
    pg_adv = clipped_rhos * (rewards + discounts * vs_tp1 - values)
    return VTraceOutput(vs=vs, pg_advantages=pg_adv)
