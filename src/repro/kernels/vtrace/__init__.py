from repro.kernels.vtrace.ops import vtrace  # noqa: F401
