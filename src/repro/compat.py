"""Version compatibility shims for the pinned container toolchain."""

from __future__ import annotations

import jax


def shard_map(f, *, mesh, in_specs, out_specs):
    """``jax.shard_map`` across the API move.

    Newer jax exposes ``jax.shard_map(..., check_vma=...)``; the pinned
    0.4.x container only has ``jax.experimental.shard_map.shard_map`` with
    the older ``check_rep`` keyword.  Both checks are disabled: the Podracer
    updates rely on ``lax.pmean`` for the replicated outputs, which the
    strict checkers reject.
    """
    if hasattr(jax, "shard_map"):
        try:
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_vma=False,
            )
        except TypeError:
            # intermediate versions promoted jax.shard_map before the
            # check_rep -> check_vma rename
            return jax.shard_map(
                f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=False,
            )
    from jax.experimental.shard_map import shard_map as _shard_map

    return _shard_map(
        f, mesh=mesh, in_specs=in_specs, out_specs=out_specs, check_rep=False
    )
