"""repro.fault — deterministic fault injection (see fault/plan.py)."""

from repro.fault.plan import (  # noqa: F401
    ActorFaultInjector,
    CheckpointFaultInjector,
    FaultEvent,
    FaultPlan,
    FaultyHostEnv,
    HostFaultInjector,
    InjectedCheckpointKill,
    InjectedCrash,
    InjectedEnvError,
    InjectedFault,
)
