"""Deterministic, seeded fault injection for Sebulba (ISSUE 7).

Datacenter-scale RL (the Podracer setting) treats preemption, stragglers,
and partial failure as the steady state, not the exception.  This module
is the *test and bench surface* for that claim: a ``FaultPlan`` is a
deterministic schedule of failures — crash an actor at its Nth step, hang
it, inject per-step latency, make an env step raise, kill or tear a
checkpoint write — that the supervision subsystem
(repro/core/supervision.py) must absorb.  Determinism is the whole point:
the same seed produces the same schedule, so a chaos test is an ordinary
regression test.

Fault kinds and their injection points:

    crash        actor loop    raise ``InjectedCrash`` at the slot's step N
    hang         actor loop    stop heartbeating and sleep until the
                               watchdog cancels the incarnation (then raise
                               so the thread unwinds and can be restarted)
    slow         actor loop    sleep ``seconds`` per step for ``span`` steps
                               (a straggler, not a failure)
    env_error    host env /    raise ``InjectedEnvError`` from the env step
                 actor loop    (``FaultyHostEnv`` wraps a single host env;
                               the actor injector fires the same kind
                               in-loop for device-env mode)
    ckpt_kill    checkpoint    raise ``InjectedCheckpointKill`` mid-write —
                 writer        simulated process death: the tmp file is
                               left behind, the final stamp never lands
    ckpt_corrupt checkpoint    tear the write: a truncated payload reaches
                 writer        the final path (simulating a non-atomic
                               writer or disk corruption) for the restore
                               path's corruption detection to catch

Host-level kinds (ISSUE 8 — fired by ``HostSupervisor.poll`` on *learner
update* steps, against the simulated peer fleet):

    host_crash   membership     the peer's lease expires un-renewed
                                (SIGKILL / hard preemption) — surviving
                                hosts observe an epoch bump and reshard
    host_preempt membership     the peer retires its lease immediately
                                (graceful SIGTERM-with-goodbye)
    host_rejoin  membership     a lost peer re-announces, restoring from
                                the newest VALID checkpoint stamp

Step counters are PER SLOT and persist across restarts: an actor slot's
injector keeps counting through its incarnations, so ``crash @ step 5``
kills exactly one incarnation and the replacement runs clean — the
schedule describes the slot's lifetime, not each thread's.  Host events
count learner updates instead (membership is observed from the learner
loop), and their targets are ``"host:<host_id>"``.
"""

from __future__ import annotations

import dataclasses
import time
from collections import deque
from typing import Iterable

import numpy as np

KINDS = (
    "crash", "hang", "slow", "env_error", "ckpt_kill", "ckpt_corrupt",
    "host_crash", "host_preempt", "host_rejoin",
)
_ACTOR_KINDS = ("crash", "hang", "slow", "env_error")
_CKPT_KINDS = ("ckpt_kill", "ckpt_corrupt")
_HOST_KINDS = ("host_crash", "host_preempt", "host_rejoin")


class InjectedFault(RuntimeError):
    """Base class for every scheduled failure this module raises."""


class InjectedCrash(InjectedFault):
    """A scheduled actor-thread death (also raised when a scheduled hang
    is cancelled by the watchdog, so the hung incarnation unwinds)."""


class InjectedEnvError(InjectedFault):
    """A scheduled environment-step failure."""


class InjectedCheckpointKill(InjectedFault):
    """Process death mid-checkpoint-write (the tmp file is left behind)."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.

    ``target`` is ``"actor:<slot>"`` (per-slot step counter), ``"env"``
    (``FaultyHostEnv`` step counter), or ``"checkpoint"`` (``step`` counts
    checkpoint *writes*).  ``seconds``/``span`` only apply to ``slow``:
    sleep ``seconds`` on each of ``span`` consecutive steps from ``step``.
    """

    kind: str
    target: str
    step: int
    seconds: float = 0.0
    span: int = 1

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown fault kind {self.kind!r}; one of {KINDS}")
        if self.step < 0:
            raise ValueError("fault step must be >= 0")
        if self.kind in _CKPT_KINDS and self.target != "checkpoint":
            raise ValueError(f"{self.kind} events target 'checkpoint'")
        if self.kind in _HOST_KINDS and not self.target.startswith("host:"):
            raise ValueError(
                f"{self.kind} events target 'host:<host_id>', "
                f"got {self.target!r}"
            )
        if self.span < 1:
            raise ValueError("span must be >= 1")


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """An immutable, deterministic fault schedule.

    Build explicitly from events, or derive one from a seed with
    :meth:`random` — same seed, same schedule, always (the draws are a
    fixed-order ``np.random.Generator`` walk, independent of wall clock
    or thread timing).
    """

    events: tuple[FaultEvent, ...] = ()
    seed: int | None = None  # provenance when built by .random

    def __post_init__(self):
        object.__setattr__(self, "events", tuple(self.events))

    @staticmethod
    def random(
        seed: int,
        *,
        actors: int,
        horizon: int,
        crash_rate: float = 0.0,
        hang_rate: float = 0.0,
        slow_rate: float = 0.0,
        slow_seconds: float = 0.02,
        env_error_rate: float = 0.0,
        ckpt_kill_every: int = 0,
        warmup: int = 2,
        peer_hosts: tuple[str, ...] = (),
        host_crash_rate: float = 0.0,
        host_preempt_rate: float = 0.0,
        host_rejoin_after: int = 0,
    ) -> "FaultPlan":
        """Seeded Bernoulli schedule over ``actors`` slots x ``horizon``
        steps.  ``*_rate`` are per-slot-per-step probabilities; draws are
        taken in fixed (slot, step, kind) order so the schedule is a pure
        function of the arguments.  ``warmup`` protects each slot's first
        steps (a slot that dies before its buffer exists exercises nothing
        interesting).  ``ckpt_kill_every`` > 0 kills every Nth checkpoint
        write (deterministic, not sampled — checkpoint writes are rare).

        Host events (ISSUE 8): per peer host in ``peer_hosts``, Bernoulli
        over *learner update* steps in the same warmup..horizon window —
        one fault cycle per host (the first crash/preempt wins; a dead
        host draws no further faults), with an optional scheduled rejoin
        ``host_rejoin_after`` updates later.  Host draws happen AFTER the
        actor/checkpoint schedule is fully drawn, so adding hosts to an
        existing seed leaves the PR 7 actor chaos schedule bit-identical.
        """
        rng = np.random.default_rng(seed)
        events: list[FaultEvent] = []
        for slot in range(actors):
            for step in range(warmup, horizon):
                for kind, rate in (
                    ("crash", crash_rate),
                    ("hang", hang_rate),
                    ("slow", slow_rate),
                    ("env_error", env_error_rate),
                ):
                    if rate and rng.random() < rate:
                        events.append(FaultEvent(
                            kind, f"actor:{slot}", step,
                            seconds=slow_seconds if kind == "slow" else 0.0,
                        ))
        if ckpt_kill_every:
            for n in range(ckpt_kill_every - 1, horizon, ckpt_kill_every):
                events.append(FaultEvent("ckpt_kill", "checkpoint", n))
        for host in peer_hosts:
            for step in range(warmup, horizon):
                fired = None
                for kind, rate in (
                    ("host_crash", host_crash_rate),
                    ("host_preempt", host_preempt_rate),
                ):
                    if rate and rng.random() < rate:
                        fired = fired or kind  # first kind drawn wins
                if fired is None:
                    continue
                events.append(FaultEvent(fired, f"host:{host}", step))
                if host_rejoin_after > 0:
                    events.append(FaultEvent(
                        "host_rejoin", f"host:{host}",
                        step + host_rejoin_after,
                    ))
                break  # one fault cycle per host
        return FaultPlan(events=tuple(events), seed=seed)

    def for_target(self, target: str) -> tuple[FaultEvent, ...]:
        return tuple(
            sorted(
                (e for e in self.events if e.target == target),
                key=lambda e: (e.step, e.kind),
            )
        )

    def actor_injector(self, slot: int) -> "ActorFaultInjector | None":
        """The persistent per-slot injector (None when the plan holds
        nothing for the slot — the common fleet-wide fast path)."""
        events = self.for_target(f"actor:{slot}")
        return ActorFaultInjector(events) if events else None

    def env_injector(self) -> "ActorFaultInjector | None":
        events = self.for_target("env")
        return ActorFaultInjector(events) if events else None

    def checkpoint_injector(self) -> "CheckpointFaultInjector | None":
        events = self.for_target("checkpoint")
        return CheckpointFaultInjector(events) if events else None

    def host_injector(self) -> "HostFaultInjector | None":
        """Every ``host:*`` event, as one learner-driven injector (the
        host tier has no per-slot counters — membership is global)."""
        events = tuple(
            e for e in self.events if e.target.startswith("host:")
        )
        return HostFaultInjector(events) if events else None


class ActorFaultInjector:
    """Per-slot fault firing, shared across the slot's incarnations.

    The actor loop calls :meth:`tick` once per env step.  ``tick`` sleeps
    for scheduled ``slow`` latency, blocks on a scheduled ``hang`` until
    the stop/cancel event fires (heartbeats freeze, which is exactly what
    the watchdog looks for), and raises ``InjectedCrash`` /
    ``InjectedEnvError`` on their steps.  The step counter belongs to the
    SLOT: a restarted incarnation resumes counting where its predecessor
    died, so each scheduled fault fires exactly once.
    """

    def __init__(self, events: Iterable[FaultEvent]):
        self._slow: dict[int, float] = {}
        self._fatal: dict[int, FaultEvent] = {}
        for e in events:
            if e.kind == "slow":
                for s in range(e.step, e.step + e.span):
                    self._slow[s] = self._slow.get(s, 0.0) + e.seconds
            else:
                # one fatal event per step: the earliest-sorted kind wins
                self._fatal.setdefault(e.step, e)
        self.step = 0
        self.fired: list[FaultEvent] = []

    def tick(self, stop=None, cancel=None) -> None:
        step, self.step = self.step, self.step + 1
        lag = self._slow.get(step)
        if lag:
            time.sleep(lag)
        event = self._fatal.get(step)
        if event is None:
            return
        self.fired.append(event)
        if event.kind == "crash":
            raise InjectedCrash(f"injected crash at step {step}")
        if event.kind == "env_error":
            raise InjectedEnvError(f"injected env failure at step {step}")
        if event.kind == "hang":
            # freeze: no heartbeats, no puts.  Wake only for shutdown
            # (stop) or the watchdog abandoning this incarnation (cancel),
            # then unwind as a crash so the supervisor can restart the slot.
            while not (
                (stop is not None and stop.is_set())
                or (cancel is not None and cancel.is_set())
            ):
                time.sleep(0.01)
            raise InjectedCrash(
                f"injected hang at step {step} (cancelled by watchdog)"
            )
        raise InjectedFault(f"unhandled fault kind {event.kind}")  # pragma: no cover


class HostFaultInjector:
    """Host-level fault firing, driven by the learner loop.

    Unlike actor injectors (per-slot env-step ``tick`` counters), host
    events are scheduled on LEARNER UPDATE steps and observed by
    ``HostSupervisor.poll(step)``: :meth:`due` drains every
    not-yet-fired event scheduled at or before ``step``, in
    (step, kind, target) order.  The learner loop is the only place
    membership is observed, so it is also the only clock host chaos
    needs.
    """

    def __init__(self, events: Iterable[FaultEvent]):
        self._pending = deque(
            sorted(events, key=lambda e: (e.step, e.kind, e.target))
        )
        self.fired: list[FaultEvent] = []

    def due(self, step: int) -> list[FaultEvent]:
        out = []
        while self._pending and self._pending[0].step <= step:
            out.append(self._pending.popleft())
        self.fired.extend(out)
        return out


class CheckpointFaultInjector:
    """Checkpoint-writer faults; ``step`` counts *writes*.

    ``repro.checkpoint.save`` calls the injector with the serialized
    payload right before the tmp-file write.  ``ckpt_kill`` raises —
    simulated process death, the atomic-replace never runs and the tmp
    debris stays on disk.  ``ckpt_corrupt`` returns a truncated payload
    that IS written through (a torn, non-atomic write), which the restore
    path's checksum must reject.
    """

    def __init__(self, events: Iterable[FaultEvent]):
        self._by_write = {e.step: e for e in events}
        self.writes = 0
        self.fired: list[FaultEvent] = []

    def __call__(self, path: str, payload: bytes) -> bytes:
        write, self.writes = self.writes, self.writes + 1
        event = self._by_write.get(write)
        if event is None:
            return payload
        self.fired.append(event)
        if event.kind == "ckpt_kill":
            raise InjectedCheckpointKill(
                f"injected kill during checkpoint write #{write} ({path})"
            )
        return payload[: max(1, len(payload) // 2)]  # torn write


class FaultyHostEnv:
    """A host-env wrapper that fails on schedule — the env-level injection
    point (actor loops get the same kind in-loop via the actor injector).
    Wraps a single env (the ``env_factory`` unit); the injector's step
    counter counts this env's ``step`` calls."""

    def __init__(self, env, injector: ActorFaultInjector):
        self._env = env
        self._injector = injector
        self.num_actions = env.num_actions
        self.obs_shape = env.obs_shape

    def reset(self):
        return self._env.reset()

    def step(self, action):
        self._injector.tick()
        return self._env.step(action)

    def close(self):
        close = getattr(self._env, "close", None)
        if callable(close):
            close()
