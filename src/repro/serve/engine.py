"""ServeEngine: the continuous-batching serving loop.

One engine iteration is a handful of donated-jit dispatches at fixed
shapes — paged mode runs one ``(1, C)`` chunked-prefill dispatch per
prefilling ROW (the page pools have no batch dimension, so prefill cost
tracks real tokens instead of billing every idle row) plus an optional
``(B, 1)`` decode step; dense mode keeps a single ``(B, C)`` prefill
dispatch.  Either way the whole serving lifetime compiles exactly twice
(the PR 2/6 fused-step idiom: model step + sampling + cache update in
one dispatch, cache donated).  Rows not participating in a dispatch
carry ``pos = max_seq``: their writes drop (dense) or land on the
reserved scratch page (paged), and their outputs are ignored.

Sampling is keyed per REQUEST, not per step:
``fold_in(fold_in(key(seed), rid), token_index)`` — so a request's token
stream is independent of scheduling, batch composition, row assignment,
and cache layout.  That is what makes paged-vs-dense generation
bit-exact and preemption's recompute-on-restart produce identical
outputs (tests/test_serve.py pins both).

Latency accounting: TTFT is measured from the moment a request becomes
eligible (its ``arrival`` step reached) to its first sampled token; TPOT
is the mean inter-token time over the remaining tokens.  Results use the
``api.make_serve_result`` schema — absent counters read 0, never
missing, like the training ``RESULT_KEYS``.
"""

from __future__ import annotations

import time
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.api.runner import make_serve_result
from repro.launch.steps import request_keys, sample_tokens
from repro.models import transformer as tf
from repro.serve.blocks import BlockAllocator, CacheExhausted, RowTables
from repro.serve.scheduler import Request, Scheduler, ServeConfig

PyTree = Any


class ServeEngine:
    """Continuous-batching engine over a dense or paged KV cache.

    ``paged=True`` (default) runs the block-table path over the page
    pools from ``Model.init_paged_cache``; ``paged=False`` runs the same
    scheduler over a plain ``(B, max_seq)`` dense cache — the
    equivalence baseline (both produce bit-identical tokens).
    """

    def __init__(self, model, params: PyTree, cfg: ServeConfig,
                 paged: bool = True):
        if model.cfg.family not in ("dense", "moe"):
            raise ValueError(
                f"ServeEngine serves dense/moe models, not {model.cfg.family}"
            )
        if model.cfg.attn_logit_softcap:
            raise ValueError("ServeEngine does not support logit softcap")
        for kind in model.kinds:
            if tf.local_params(model.cfg, kind)[0]:
                raise ValueError(
                    "ServeEngine requires uniform global attention"
                )
        cfg.validate()
        self.model = model
        self.params = params
        self.cfg = cfg
        self.paged = paged
        self._build_steps()
        self.reset()

    # ------------------------------------------------------------- jitted

    def _build_steps(self) -> None:
        model, cfg = self.model, self.cfg
        temperature, top_k, seed = cfg.temperature, cfg.top_k, cfg.seed

        def decode(params, cache, tokens, pos, tables, rids, tok_idx):
            logits, _values, cache = model.decode_step(
                params, cache, tokens, pos, tables
            )
            keys = request_keys(seed, rids, tok_idx)
            nxt = sample_tokens(
                logits[:, 0], keys, temperature=temperature, top_k=top_k
            )
            return nxt, cache

        def prefill(params, cache, tokens, pos, lens, tables, rids, tok_idx):
            logits, _values, cache = model.prefill_step(
                params, cache, tokens, pos, tables
            )
            # the logits of each row's LAST real chunk token sample the
            # first generated token (rows not finishing ignore theirs)
            last = jnp.maximum(lens - 1, 0)
            lg = jnp.take_along_axis(logits, last[:, None, None], axis=1)
            keys = request_keys(seed, rids, tok_idx)
            nxt = sample_tokens(
                lg[:, 0], keys, temperature=temperature, top_k=top_k
            )
            return nxt, cache

        self._decode = jax.jit(decode, donate_argnums=(1,))
        # paged prefill dispatches per ROW at a fixed (1, C) shape — the
        # page pools have no batch dimension, so a one-row chunk writes
        # straight into the row's pages and prefill cost tracks REAL
        # tokens (a (B, C) dispatch would bill every idle row).  Dense
        # prefill keeps the (B, C) shape: the (B, S) cache rows are baked
        # into the dispatch, and dense mode is the correctness baseline,
        # not the throughput path.
        self._prefill = jax.jit(prefill, donate_argnums=(1,))

    # -------------------------------------------------------------- state

    def reset(self) -> None:
        """Fresh serving state (cache zeroed, queue/counters cleared);
        the compiled steps are reused across resets."""
        cfg = self.cfg
        if self.paged:
            self.cache, _ = self.model.init_paged_cache(
                cfg.num_blocks, cfg.block_size
            )
            self.allocator = BlockAllocator(cfg.num_blocks)
            self.tables = RowTables(
                cfg.batch_rows, cfg.blocks_per_row, cfg.block_size,
                self.allocator,
            )
        else:
            self.cache, _ = self.model.init_cache(cfg.batch_rows, cfg.max_seq)
            self.allocator = None
            self.tables = None
        self.scheduler = Scheduler(cfg)
        self.steps = 0
        self.prefill_chunks = 0
        self.tokens_prefilled = 0
        self.tokens_decoded = 0
        self.queue_depth_peak = 0
        self._occupancy: list[float] = []
        self._eligible_t: dict[int, float] = {}
        self._first_t: dict[int, float] = {}
        self._finish_t: dict[int, float] = {}
        self._gen_counts: dict[int, int] = {}

    # -------------------------------------------------------------- serve

    def submit(self, req: Request) -> None:
        if self.paged:
            need = (len(req.prompt) + req.max_new_tokens - 2) \
                // self.cfg.block_size + 1
            if need > self.cfg.num_blocks - 1:
                raise ValueError(
                    f"request {req.rid} needs {need} pages; the pool has "
                    f"{self.cfg.num_blocks - 1} allocatable"
                )
        self.scheduler.submit(req)

    def _ensure_pages(self, plan) -> None:
        for row in plan.prefill_rows:
            through = int(plan.prefill_pos[row] + plan.prefill_len[row]) - 1
            self.tables.ensure(row, through)
        for row in plan.decode_rows:
            self.tables.ensure(row, int(plan.decode_pos[row]))

    def _plan_with_preemption(self):
        """Plan the step; on cache exhaustion preempt the youngest active
        request (releasing its pages) and replan.  A lone request always
        fits (checked at submit), so this terminates."""
        while True:
            plan = self.scheduler.plan_step()
            if not self.paged:
                return plan
            try:
                self._ensure_pages(plan)
                return plan
            except CacheExhausted:
                victim = self.scheduler.preempt_youngest()
                if victim is None:
                    raise
                self.tables.release(victim[0])

    def step(self) -> None:
        """One engine iteration: admit -> plan (preempting under cache
        pressure) -> at most one prefill dispatch + one decode dispatch
        -> evict finished rows."""
        now = self.steps
        t_now = time.monotonic()
        for req in list(self.scheduler._queue):
            if req.arrival <= now:
                self._eligible_t.setdefault(req.rid, t_now)
        self.scheduler.admit(now)
        self.queue_depth_peak = max(self.queue_depth_peak,
                                    self.scheduler.pending)
        plan = self._plan_with_preemption()
        tables = jnp.asarray(self.tables.as_array()) if self.paged else None

        if plan.prefill_rows:
            pt = jnp.asarray(plan.prefill_tokens)
            pp = jnp.asarray(plan.prefill_pos)
            pl = jnp.asarray(plan.prefill_len)
            rids = jnp.asarray(plan.rids)
            ti = jnp.asarray(plan.tok_idx)
            if self.paged:
                outs = []
                for row in plan.prefill_rows:
                    sl = slice(row, row + 1)
                    nxt, self.cache = self._prefill(
                        self.params, self.cache, pt[sl], pp[sl], pl[sl],
                        tables[sl], rids[sl], ti[sl],
                    )
                    outs.append((row, nxt))
                    self.prefill_chunks += 1
                sampled = np.zeros((self.cfg.batch_rows,), np.int32)
                for row, nxt in outs:
                    sampled[row] = int(np.asarray(nxt)[0])
            else:
                nxt, self.cache = self._prefill(
                    self.params, self.cache, pt, pp, pl, tables, rids, ti,
                )
                sampled = np.asarray(nxt)
                self.prefill_chunks += 1
            finished = self.scheduler.record_prefill(plan, sampled)
            t = time.monotonic()
            for row in finished:
                self._first_t.setdefault(int(plan.rids[row]), t)
            self.tokens_prefilled += int(plan.prefill_len.sum())

        if plan.decode_rows:
            nxt, self.cache = self._decode(
                self.params, self.cache,
                jnp.asarray(plan.decode_tokens),
                jnp.asarray(plan.decode_pos),
                tables,
                jnp.asarray(plan.rids), jnp.asarray(plan.tok_idx),
            )
            self.scheduler.record_decode(plan, np.asarray(nxt))
            self.tokens_decoded += len(plan.decode_rows)

        t = time.monotonic()
        for row in self.scheduler.evict_finished():
            if self.paged:
                self.tables.release(row)
        for rid, toks in self.scheduler.completed.items():
            if rid not in self._finish_t:
                self._finish_t[rid] = t
                self._gen_counts[rid] = len(toks)
        if self.paged:
            self._occupancy.append(self.tables.occupancy())
        else:
            self._occupancy.append(
                len(self.scheduler.active) / self.cfg.batch_rows
            )
        self.steps += 1

    def run(self, requests=None, max_steps: int = 100_000) -> dict:
        """Serve ``requests`` (plus anything already queued) to
        completion and return the ``make_serve_result`` dict."""
        for req in requests or ():
            self.submit(req)
        t0 = time.monotonic()
        while not self.scheduler.idle:
            if self.steps >= max_steps:
                raise RuntimeError(f"serve loop exceeded {max_steps} steps")
            self.step()
        return self.result(seconds=time.monotonic() - t0)

    # ------------------------------------------------------------- result

    def _percentiles(self) -> dict[str, float]:
        ttft = [self._first_t[r] - self._eligible_t.get(r, self._first_t[r])
                for r in self._first_t]
        tpot = [
            (self._finish_t[r] - self._first_t[r]) / (self._gen_counts[r] - 1)
            for r in self._finish_t
            if r in self._first_t and self._gen_counts.get(r, 0) > 1
        ]
        out = {}
        for name, xs in (("ttft", ttft), ("tpot", tpot)):
            out[f"{name}_p50"] = float(np.percentile(xs, 50)) if xs else 0.0
            out[f"{name}_p95"] = float(np.percentile(xs, 95)) if xs else 0.0
        return out

    def result(self, seconds: float = 0.0) -> dict:
        occ = self._occupancy
        return make_serve_result(
            outputs=dict(self.scheduler.completed),
            seconds=seconds,
            completed=len(self.scheduler.completed),
            admitted=self.scheduler.admitted,
            preempted=self.scheduler.preempted,
            steps=self.steps,
            prefill_chunks=self.prefill_chunks,
            tokens_prefilled=self.tokens_prefilled,
            tokens_decoded=self.tokens_decoded,
            queue_depth_peak=self.queue_depth_peak,
            cache_occupancy_peak=max(occ) if occ else 0.0,
            cache_occupancy_mean=float(np.mean(occ)) if occ else 0.0,
            **self._percentiles(),
        )
