"""Continuous-batching LM serving (PR 10).

The serving stack behind the Podracer decode path:

  * :mod:`repro.serve.blocks` — the paged KV cache bookkeeping: a
    free-list page allocator plus per-request block tables over the
    ``(P, bs, K, h)`` page pools ``Model.init_paged_cache`` allocates;
  * :mod:`repro.serve.scheduler` — sarathi-style continuous batching:
    admit requests from a queue, interleave chunked prefill with decode
    under a fixed token budget per step, evict finished rows, preempt on
    cache exhaustion;
  * :mod:`repro.serve.engine` — ``ServeEngine``: one donated-jit serve
    step per iteration (decode + sample + cache update in one dispatch),
    seeded per-request sampling streams, and the
    ``api.make_serve_result`` counter schema.
"""

from repro.serve.blocks import BlockAllocator, CacheExhausted, RowTables
from repro.serve.engine import ServeEngine
from repro.serve.scheduler import Request, Scheduler, ServeConfig

__all__ = [
    "BlockAllocator",
    "CacheExhausted",
    "Request",
    "RowTables",
    "Scheduler",
    "ServeConfig",
    "ServeEngine",
]
