"""Paged-KV-cache bookkeeping: free-list page allocator + block tables.

The device side is dumb on purpose: ``Model.init_paged_cache`` allocates
per-layer page pools ``(P, bs, K, h)`` and the kernels consume a single
shared ``(B, nb)`` int32 block table (``kernels/flash_decode``
dereferences it in the BlockSpec index_map).  Everything stateful lives
here, on the host, in plain Python — the same host-control / device-data
split the Sebulba actors use.

Invariants the rest of the serving stack leans on:

  * **Page 0 is reserved scratch.**  It is never handed out, so an
    all-zero table row is "inactive", and out-of-range writes (padded
    prefill tails, idle decode rows) redirect to page 0 where nothing
    ever reads them back.
  * **Live rows hold disjoint pages** — allocation is exclusive, so the
    per-step scatter write never races between rows.
  * **Allocation is deterministic**: the free list is a LIFO stack, so
    the same admission/eviction sequence always yields the same physical
    page assignment (the paged-vs-dense bit-exactness tests rely on
    replayable layouts, including after reuse).
"""

from __future__ import annotations

import numpy as np


class CacheExhausted(Exception):
    """No free pages left — the scheduler's cue to preempt a request."""


class BlockAllocator:
    """LIFO free-list allocator over physical pages 1..num_blocks-1."""

    def __init__(self, num_blocks: int):
        if num_blocks < 2:
            raise ValueError(
                "num_blocks must be >= 2: page 0 is reserved scratch"
            )
        self.num_blocks = num_blocks
        # stack ordered so the first pops hand out 1, 2, 3, ...
        self._free = list(range(num_blocks - 1, 0, -1))

    @property
    def free_blocks(self) -> int:
        return len(self._free)

    @property
    def used_blocks(self) -> int:
        return (self.num_blocks - 1) - len(self._free)

    def alloc(self) -> int:
        if not self._free:
            raise CacheExhausted("no free KV-cache pages")
        return self._free.pop()

    def release(self, block: int) -> None:
        if block == 0:
            raise ValueError("page 0 is reserved scratch, never allocated")
        self._free.append(block)


class RowTables:
    """Host-side ``(B, nb)`` block tables, one row per engine batch slot.

    ``ensure(row, through_pos)`` grows row's mapping until logical
    position ``through_pos`` is backed by a physical page; ``release``
    returns a row's pages to the free list (LIFO, newest first — so the
    next admission replays onto the just-freed pages, exercising reuse).
    """

    def __init__(self, batch_rows: int, blocks_per_row: int, block_size: int,
                 allocator: BlockAllocator):
        self.block_size = block_size
        self.blocks_per_row = blocks_per_row
        self.allocator = allocator
        self._tables = np.zeros((batch_rows, blocks_per_row), np.int32)
        self._counts = np.zeros((batch_rows,), np.int32)

    def ensure(self, row: int, through_pos: int) -> int:
        """Map row's logical blocks through ``through_pos``; returns how
        many pages were newly allocated.  Raises :class:`CacheExhausted`
        (after rolling back nothing — already-mapped pages stay mapped)
        when the pool runs dry mid-growth."""
        need = through_pos // self.block_size + 1
        if need > self.blocks_per_row:
            raise ValueError(
                f"position {through_pos} exceeds the per-row capacity "
                f"{self.blocks_per_row * self.block_size}"
            )
        added = 0
        while self._counts[row] < need:
            self._tables[row, self._counts[row]] = self.allocator.alloc()
            self._counts[row] += 1
            added += 1
        return added

    def release(self, row: int) -> None:
        for i in reversed(range(int(self._counts[row]))):
            self.allocator.release(int(self._tables[row, i]))
        self._tables[row] = 0
        self._counts[row] = 0

    def mapped_blocks(self, row: int) -> int:
        return int(self._counts[row])

    def occupancy(self) -> float:
        """Fraction of allocatable pages currently mapped."""
        return self.allocator.used_blocks / (self.allocator.num_blocks - 1)

    def as_array(self) -> np.ndarray:
        """The (B, nb) int32 table to feed the jitted serve step."""
        return self._tables.copy()
