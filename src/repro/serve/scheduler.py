"""Continuous-batching request scheduler (sarathi-style).

The scheduler is pure host-side bookkeeping over a fixed grid of ``B``
engine rows: it admits requests from a queue into free rows, splits a
fixed per-step **token budget** between decode (one token per active
row, priority) and chunked prefill (whatever budget remains, in fixed
``(B, C)``-shaped chunks so the jitted step never recompiles), evicts
finished rows, and — when the paged cache runs dry — preempts the
YOUNGEST active request (recompute-on-restart: its state resets and it
re-enters at the FRONT of the queue, so completed work is never starved
by a late arrival).

Admission order is deterministic: FIFO by default, or a seeded
pseudo-random permutation (``shuffle_admissions``) keyed on
``(seed, request id)`` via crc32 — stable across processes, unlike
``hash()``.  Combined with per-request sampling streams keyed the same
way (engine), a request's output tokens are a function of
``(params, prompt, seed, rid)`` only — not of what else is in flight.

Position convention (the engine's contract with the model):

  * prompt tokens occupy cache slots ``0..L-1``;
  * prefill of the chunk covering slot ``L-1`` yields the logits that
    sample generated token 1 (the TTFT token);
  * generated token ``g`` is decoded by feeding token ``g-1``'s id at
    position ``L+g-2`` — so a finished request of ``max_new`` tokens has
    written slots ``0..L+max_new-2``.
"""

from __future__ import annotations

import dataclasses
import zlib
from collections import deque

import numpy as np


@dataclasses.dataclass(frozen=True)
class Request:
    """One generation request.  ``arrival`` is the engine step index at
    which the request becomes visible to admission (0 = immediately) —
    staggered arrivals in tests and benchmarks without wall-clock."""

    rid: int
    prompt: tuple[int, ...]
    max_new_tokens: int
    arrival: int = 0

    def __post_init__(self):
        if len(self.prompt) < 1:
            raise ValueError("empty prompt")
        if self.max_new_tokens < 1:
            raise ValueError("max_new_tokens must be >= 1")


@dataclasses.dataclass(frozen=True)
class ServeConfig:
    """Engine + scheduler knobs.  ``token_budget`` caps tokens processed
    per step (decode rows first, leftover to prefill — sarathi's chunked
    interleaving).  ``max_seq`` is the per-row logical capacity; in paged
    mode it must equal ``blocks_per_row * block_size`` and ``num_blocks``
    counts the physical pool INCLUDING the reserved scratch page 0."""

    batch_rows: int = 4
    prefill_chunk: int = 8
    token_budget: int = 12
    block_size: int = 8
    num_blocks: int = 17
    max_seq: int = 64
    temperature: float = 0.0
    top_k: int = 0
    seed: int = 0
    shuffle_admissions: bool = False

    @property
    def blocks_per_row(self) -> int:
        if self.max_seq % self.block_size:
            raise ValueError("max_seq must be a multiple of block_size")
        return self.max_seq // self.block_size

    def validate(self) -> None:
        if self.token_budget < 1:
            raise ValueError("token_budget must be >= 1")
        if self.prefill_chunk < 1:
            raise ValueError("prefill_chunk must be >= 1")
        _ = self.blocks_per_row


@dataclasses.dataclass
class _RowState:
    rid: int
    prompt: list[int]
    max_new: int
    admit_seq: int          # monotonic admission stamp (youngest = max)
    prefilled: int = 0      # prompt tokens written to cache so far
    generated: list[int] = dataclasses.field(default_factory=list)

    @property
    def in_decode(self) -> bool:
        return self.prefilled == len(self.prompt) and self.generated

    @property
    def done(self) -> bool:
        return len(self.generated) >= self.max_new


@dataclasses.dataclass
class StepPlan:
    """One engine iteration, as fixed-shape arrays (B rows, C-wide
    chunks).  Idle rows carry ``pos = max_seq`` so their writes drop
    (dense) or land on the scratch page (paged) — see models/attention."""

    # prefill dispatch ((B, C); skipped when no row prefills this step)
    prefill_rows: list[int]
    prefill_tokens: np.ndarray
    prefill_pos: np.ndarray
    prefill_len: np.ndarray          # real tokens per row in this chunk
    finish_rows: list[int]           # rows whose prefill completes now
    # decode dispatch ((B, 1); skipped when no row is in decode phase)
    decode_rows: list[int]
    decode_tokens: np.ndarray
    decode_pos: np.ndarray
    rids: np.ndarray                 # (B,) request ids (0 for idle rows)
    tok_idx: np.ndarray              # (B,) per-request token indices


class Scheduler:
    def __init__(self, cfg: ServeConfig):
        cfg.validate()
        self.cfg = cfg
        self._queue: deque[Request] = deque()      # normal arrivals
        self._requeued: deque[Request] = deque()   # preempted, front-of-line
        self.active: dict[int, _RowState] = {}
        self._free_rows = list(range(cfg.batch_rows - 1, -1, -1))
        self._admit_seq = 0
        # counters surfaced through make_serve_result
        self.admitted = 0
        self.preempted = 0
        self.completed: dict[int, list[int]] = {}

    # -------------------------------------------------------------- intake

    def submit(self, req: Request) -> None:
        slots = len(req.prompt) + req.max_new_tokens - 1
        if slots > self.cfg.max_seq:
            raise ValueError(
                f"request {req.rid} needs {slots} cache slots; "
                f"max_seq is {self.cfg.max_seq}"
            )
        self._queue.append(req)

    @property
    def pending(self) -> int:
        return len(self._queue) + len(self._requeued)

    @property
    def idle(self) -> bool:
        return not self.active and not self.pending

    # ----------------------------------------------------------- admission

    def _admission_order(self, eligible: list[Request]) -> list[Request]:
        if not self.cfg.shuffle_admissions:
            return eligible
        return sorted(
            eligible,
            key=lambda r: zlib.crc32(f"{self.cfg.seed}:{r.rid}".encode()),
        )

    def admit(self, now: int) -> list[int]:
        """Move eligible requests into free rows.  Preempted requests go
        first (front-of-line, FIFO among themselves); fresh arrivals
        follow in FIFO or seeded order.  Returns admitted rids."""
        admitted = []
        while self._free_rows and self._requeued:
            admitted.append(self._place(self._requeued.popleft()))
        eligible = [r for r in self._queue if r.arrival <= now]
        for req in self._admission_order(eligible):
            if not self._free_rows:
                break
            self._queue.remove(req)
            admitted.append(self._place(req))
        return admitted

    def _place(self, req: Request) -> int:
        row = self._free_rows.pop()
        self.active[row] = _RowState(
            rid=req.rid, prompt=list(req.prompt),
            max_new=req.max_new_tokens, admit_seq=self._admit_seq,
        )
        self._admit_seq += 1
        self.admitted += 1
        return req.rid

    # ---------------------------------------------------------- preemption

    def preempt_youngest(self) -> tuple[int, int] | None:
        """Evict the most recently admitted active request, dropping its
        progress (recompute-on-restart — its per-request sampling streams
        make the rerun produce identical tokens) and requeueing it at the
        front.  Returns ``(row, rid)`` — the engine releases the row's
        pages — or None when nothing can yield."""
        if not self.active:
            return None
        row = max(self.active, key=lambda r: self.active[r].admit_seq)
        st = self.active.pop(row)
        self._free_rows.append(row)
        self._requeued.appendleft(Request(
            rid=st.rid, prompt=tuple(st.prompt),
            max_new_tokens=st.max_new, arrival=0,
        ))
        self.preempted += 1
        return row, st.rid

    # ------------------------------------------------------------ planning

    def plan_step(self) -> StepPlan:
        cfg = self.cfg
        B, C = cfg.batch_rows, cfg.prefill_chunk
        idle_pos = cfg.max_seq  # out-of-range: writes drop / hit scratch
        plan = StepPlan(
            prefill_rows=[], finish_rows=[], decode_rows=[],
            prefill_tokens=np.zeros((B, C), np.int32),
            prefill_pos=np.full((B,), idle_pos, np.int32),
            prefill_len=np.zeros((B,), np.int32),
            decode_tokens=np.zeros((B, 1), np.int32),
            decode_pos=np.full((B,), idle_pos, np.int32),
            rids=np.zeros((B,), np.int32),
            tok_idx=np.zeros((B,), np.int32),
        )
        decode_rows = [r for r, st in sorted(self.active.items())
                       if st.in_decode]
        budget = cfg.token_budget - len(decode_rows)
        for row, st in sorted(self.active.items()):
            plan.rids[row] = st.rid
            if st.in_decode:
                plan.decode_rows.append(row)
                g = len(st.generated)
                plan.decode_tokens[row, 0] = st.generated[-1]
                plan.decode_pos[row] = len(st.prompt) + g - 1
                plan.tok_idx[row] = g  # sampling token g+1
            elif st.prefilled < len(st.prompt) and budget > 0:
                n = min(C, len(st.prompt) - st.prefilled, budget)
                budget -= n
                chunk = st.prompt[st.prefilled:st.prefilled + n]
                plan.prefill_rows.append(row)
                plan.prefill_tokens[row, :n] = chunk
                plan.prefill_pos[row] = st.prefilled
                plan.prefill_len[row] = n
                if st.prefilled + n == len(st.prompt):
                    plan.finish_rows.append(row)
                    plan.tok_idx[row] = 0  # sampling token 1 (TTFT)
        return plan

    # ------------------------------------------------------------- results

    def record_prefill(self, plan: StepPlan,
                       sampled: np.ndarray) -> list[int]:
        """Advance prefill progress; rows in ``finish_rows`` bank their
        first generated token from ``sampled`` (B,).  Returns those rows
        (the engine stamps TTFT on them)."""
        for row in plan.prefill_rows:
            self.active[row].prefilled += int(plan.prefill_len[row])
        for row in plan.finish_rows:
            self.active[row].generated.append(int(sampled[row]))
        return list(plan.finish_rows)

    def record_decode(self, plan: StepPlan, sampled: np.ndarray) -> None:
        for row in plan.decode_rows:
            self.active[row].generated.append(int(sampled[row]))

    def evict_finished(self) -> list[int]:
        """Retire rows whose generation is complete; returns their row
        indices (the engine releases their pages)."""
        rows = [r for r, st in sorted(self.active.items()) if st.done]
        for row in rows:
            st = self.active.pop(row)
            self.completed[st.rid] = list(st.generated)
            self._free_rows.append(row)
        return rows
