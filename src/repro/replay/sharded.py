"""Replay sharded across the Sebulba learner mesh.

Each learner core owns an independent ``capacity / L`` slice of the ring
(paper Fig. 3 dataflow, extended off-policy): actor trajectory shards are
already laid out batch-over-learners by ``Sebulba._shard_for_learners``, so
an insert is a purely local write on every core — no collective, no
host round-trip.  Sampling likewise draws ``batch / L`` slots per core and
the results compose into one globally-sharded batch, exactly the layout the
learner's ``shard_map`` update consumes.

The scalar cursors (``insert_pos``, ``total_added``) are *replicated*: every
core inserts the same number of items per call, so the local cursors stay
bit-identical across shards and can be read host-side without a gather.

Sampling RNG: the caller passes one key; each shard folds in its mesh axis
index, so shards draw decorrelated slots while the whole operation stays a
pure deterministic function of (state, key).
"""

from __future__ import annotations

from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.compat import shard_map
from repro.replay import buffer
from repro.replay.buffer import ReplayState

PyTree = Any


def renormalize_probs(local_probs, allocation: int, batch_size: int):
    """One shard's local selection probabilities -> global.

    A draw fanned across shards selects item i with
    ``P(shard) * P(i | shard)``; when ``allocation`` of the
    ``batch_size`` draws go to this shard, ``P(shard)`` is the
    allocation fraction.  Shared by the in-host sharded ring and the
    cross-host routing layer (repro/distributed/routing.py) so the PER
    correction sees ONE coherent distribution over whatever shard set
    currently survives.
    """
    return local_probs * (allocation / batch_size)


def global_importance_weights(probs, global_size: int, beta: float):
    """PER bias correction against the GLOBAL buffer: ``(N * P(i))^-beta``
    normalized by the batch max.  ``global_size`` is the valid-slot
    count summed over every surviving shard — after a shard is lost,
    callers re-normalize over what remains rather than training on the
    stale pre-loss N."""
    w = (max(global_size, 1) * np.asarray(probs, np.float64)) ** (-beta)
    return (w / max(float(np.max(w)), 1e-20)).astype(np.float32)


class ShardedReplay:
    """Host-side handle for a replay ring sharded over a 1-D device mesh."""

    def __init__(
        self,
        mesh: Mesh,
        capacity: int,
        *,
        prioritized: bool = False,
        priority_exponent: float = 0.6,
        axis_name: str = "batch",
    ):
        self.mesh = mesh
        self.axis = axis_name
        self.num_shards = mesh.shape[axis_name]
        if capacity % self.num_shards != 0:
            raise ValueError(
                f"capacity {capacity} must divide across {self.num_shards} "
                "learner shards"
            )
        self.capacity = capacity
        self.prioritized = prioritized
        self.priority_exponent = priority_exponent
        self._insert_fn = None
        self._update_fn = None
        self._sample_fns: dict[int, Any] = {}

    # ------------------------------------------------------------- specs

    def state_spec(self, tree: PyTree) -> ReplayState:
        """PartitionSpec tree: ring dims over the mesh, cursors replicated."""
        return ReplayState(
            storage=jax.tree.map(lambda _: P(self.axis), tree),
            priorities=P(self.axis),
            insert_pos=P(),
            total_added=P(),
        )

    def batch_spec(self, tree: PyTree) -> PyTree:
        return jax.tree.map(lambda _: P(self.axis), tree)

    # ------------------------------------------------------------- setup

    def init(self, example: PyTree) -> ReplayState:
        """Allocate the sharded ring from a (global-batch) example pytree."""
        spec = self.state_spec(example)
        shardings = jax.tree.map(
            lambda s: NamedSharding(self.mesh, s),
            spec,
            is_leaf=lambda x: isinstance(x, P),
        )
        fn = jax.jit(
            lambda ex: buffer.init(ex, self.capacity), out_shardings=shardings
        )
        state = fn(example)
        self._build(state, example)
        return state

    def _build(self, state: ReplayState, example: PyTree) -> None:
        spec = self.state_spec(example)
        bspec = self.batch_spec(example)
        # a re-init with a different trajectory structure must not reuse
        # sample fns compiled against the previous spec
        self._sample_fns.clear()

        def _insert(st, batch):
            # global-max default priorities: see buffer.insert's axis_name note
            return buffer.insert(
                st, batch,
                axis_name=self.axis if self.prioritized else None,
            )

        self._insert_fn = jax.jit(
            shard_map(
                _insert, mesh=self.mesh, in_specs=(spec, bspec),
                out_specs=spec,
            ),
            donate_argnums=0,
        )

        def _update(st, idx, new_p):
            return buffer.update_priorities(st, idx, new_p)

        self._update_fn = jax.jit(
            shard_map(
                _update, mesh=self.mesh,
                in_specs=(spec, P(self.axis), P(self.axis)),
                out_specs=spec,
            ),
            donate_argnums=0,
        )
        self._spec = spec
        self._bspec = bspec

    # --------------------------------------------------------------- ops

    def _require_built(self) -> None:
        if self._insert_fn is None:
            raise RuntimeError(
                "ShardedReplay ops need the compiled sharded paths: call "
                "init(example) first (it allocates the ring and builds them)"
            )

    def insert(self, state: ReplayState, batch: PyTree) -> ReplayState:
        """Insert a globally-sharded batch; every shard writes locally."""
        self._require_built()
        return self._insert_fn(state, batch)

    def sample(self, state: ReplayState, rng: jax.Array, batch_size: int):
        """Draw a globally-sharded batch of ``batch_size`` slots.

        Returns (batch, idx, probs); ``idx`` are *shard-local* slot indices,
        valid only for ``update_priorities`` on this same sharded state.
        """
        if batch_size % self.num_shards != 0:
            raise ValueError(
                f"sample batch {batch_size} must divide across "
                f"{self.num_shards} shards"
            )
        self._require_built()
        fn = self._sample_fns.get(batch_size)
        if fn is None:
            local = batch_size // self.num_shards

            def _sample(st, key):
                key = jax.random.fold_in(key, jax.lax.axis_index(self.axis))
                return buffer.sample(
                    st, key, local,
                    prioritized=self.prioritized,
                    priority_exponent=self.priority_exponent,
                )

            fn = jax.jit(
                shard_map(
                    _sample, mesh=self.mesh,
                    in_specs=(self._spec, P()),
                    out_specs=(self._bspec, P(self.axis), P(self.axis)),
                )
            )
            self._sample_fns[batch_size] = fn
        return fn(state, rng)

    def update_priorities(self, state, idx, new_priorities) -> ReplayState:
        self._require_built()
        return self._update_fn(state, idx, new_priorities)

    def size(self, state: ReplayState) -> int:
        """Global slot count = shards x the (replicated) local size."""
        local = min(int(state.total_added), self.capacity // self.num_shards)
        return self.num_shards * local
