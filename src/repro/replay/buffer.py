"""Device-resident trajectory replay (the off-policy half of Sebulba).

The Podracer paper notes Sebulba hosts both on-policy agents (IMPALA, PPO)
and replay-based ones (MuZero, R2D2); ElegantRL-Podracer makes the same
point more forcefully — keeping the replay store *on the accelerator*
removes the host<->device copy from both the insert and the sample path.

``ReplayState`` is a pure pytree: a fixed-capacity ring of trajectory
*slots* (one slot = one batch element of a ``Trajectory``), a priority
vector, and two scalar cursors.  All operations are pure functions of the
state so they compose with ``jax.jit`` (with buffer donation, so insert and
sample update the ring in place), with ``shard_map`` (repro/replay/sharded.py
shards the ring across the learner mesh), and with ``lax.cond``/``scan``.

Priorities follow PER (Schaul et al., 2016): new items enter at the current
maximum priority, sampling is ``p_i^alpha``-proportional, and the learner
corrects the induced bias with importance weights
(repro/rl/losses.py:per_importance_weights).

The slot layout is structure-agnostic: a slot stores one batch element of
whatever pytree it was initialized with, so R2D2's per-sequence stored
state (``Trajectory.init_carry``, a (B, W) leaf) rides the ring with no
replay-side code — insert scatters it, sample gathers it, bit-exact
(tests/test_recurrent.py).
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class ReplayState(NamedTuple):
    """Fixed-capacity ring over trajectory slots.  All leaves live on device."""

    storage: PyTree  # Trajectory-shaped pytree; leaves (capacity, ...)
    priorities: jax.Array  # (capacity,) float32; 0 marks an empty slot
    insert_pos: jax.Array  # () int32 — next slot to overwrite
    total_added: jax.Array  # () int32 — monotone insert count

    @property
    def capacity(self) -> int:
        return self.priorities.shape[0]


def size(state: ReplayState) -> jax.Array:
    """Number of valid slots (saturates at capacity once the ring wraps)."""
    return jnp.minimum(state.total_added, state.priorities.shape[0])


def init(example: PyTree, capacity: int) -> ReplayState:
    """Allocate an empty ring whose slots match ``example``'s batch elements.

    ``example`` is any pytree whose leaves have a leading batch dimension
    (e.g. a ``Trajectory`` with (B, T, ...) leaves); one slot stores one
    batch element, so storage leaves are (capacity, ...) zeros.
    """
    storage = jax.tree.map(
        lambda x: jnp.zeros((capacity,) + x.shape[1:], x.dtype), example
    )
    return ReplayState(
        storage=storage,
        priorities=jnp.zeros((capacity,), jnp.float32),
        insert_pos=jnp.zeros((), jnp.int32),
        total_added=jnp.zeros((), jnp.int32),
    )


def insert_slots(state: ReplayState, batch_size: int) -> jax.Array:
    """Ring slots the next ``insert`` of ``batch_size`` items will write.

    The single source of truth for the placement policy — callers that need
    the written indices (e.g. the fused Sebulba step writing TD priorities
    back) must use this rather than re-deriving the arithmetic.
    """
    capacity = state.priorities.shape[0]
    return (
        state.insert_pos + jnp.arange(batch_size, dtype=jnp.int32)
    ) % capacity


def insert(
    state: ReplayState, batch: PyTree, priorities: jax.Array | None = None,
    *, axis_name: str | None = None,
) -> ReplayState:
    """Write a batch of items into the ring, wrapping at capacity.

    New items default to the current max priority (PER: every transition is
    replayed at least once before its TD error is known).  Inside
    shard_map/pmap pass ``axis_name`` so that default uses the *global* max
    — a shard-local max would replay identical fresh trajectories at
    different rates depending on which shard they landed on.
    """
    leaves = jax.tree.leaves(batch)
    B = leaves[0].shape[0]
    capacity = state.priorities.shape[0]
    if B > capacity:
        raise ValueError(
            f"insert batch {B} exceeds ring capacity {capacity}: the "
            "scatter would write duplicate slots, and which element "
            "survives is unspecified"
        )
    slots = insert_slots(state, B)
    storage = jax.tree.map(
        lambda s, x: s.at[slots].set(x), state.storage, batch
    )
    if priorities is None:
        # 1.0 only bootstraps the empty ring; once TD priorities exist an
        # unconditional floor would pin fresh inserts above converged
        # (sub-1.0) priorities and starve old high-TD slots.
        max_p = jnp.max(state.priorities)
        if axis_name is not None:
            max_p = jax.lax.pmax(max_p, axis_name)
        priorities = jnp.full(
            (B,), jnp.where(max_p > 0.0, max_p, 1.0), jnp.float32
        )
    return ReplayState(
        storage=storage,
        priorities=state.priorities.at[slots].set(priorities),
        insert_pos=(state.insert_pos + B) % capacity,
        total_added=state.total_added + B,
    )


def sample(
    state: ReplayState,
    rng: jax.Array,
    batch_size: int,
    *,
    prioritized: bool = False,
    priority_exponent: float = 0.6,
) -> tuple[PyTree, jax.Array, jax.Array]:
    """Draw ``batch_size`` slots (with replacement) -> (batch, idx, probs).

    ``probs`` is the per-draw selection probability — feed it to
    ``losses.per_importance_weights`` for the PER bias correction.  Uniform
    mode is the ``priority_exponent -> 0`` limit but skips the log/exp.

    Precondition: ``size(state) > 0`` — with no valid slots the total
    sampling weight is zero and ``probs`` comes back NaN (callers gate on
    ``ReplayConfig.min_size``, see ``core/sebulba.py``).

    Drawn by inverse-CDF (cumsum + searchsorted): O(capacity + B log
    capacity), where ``jax.random.categorical`` would materialize a
    (B, capacity) Gumbel matrix — at R2D2-scale capacities that matrix
    dominates the learner step.
    """
    capacity = state.priorities.shape[0]
    valid = jnp.arange(capacity) < size(state)
    if prioritized:
        w = jnp.where(
            valid, (state.priorities + 1e-20) ** priority_exponent, 0.0
        )
    else:
        w = valid.astype(jnp.float32)
    cdf = jnp.cumsum(w)
    total = cdf[-1]
    u = jax.random.uniform(rng, (batch_size,)) * total
    idx = jnp.clip(
        jnp.searchsorted(cdf, u, side="right"), 0, capacity - 1
    )
    probs = w[idx] / total
    batch = jax.tree.map(lambda s: s[idx], state.storage)
    return batch, idx, probs


def update_priorities(
    state: ReplayState, idx: jax.Array, new_priorities: jax.Array
) -> ReplayState:
    """Refresh the priorities of previously-sampled slots (post-update TD)."""
    return state._replace(
        priorities=state.priorities.at[idx].set(
            jnp.asarray(new_priorities, jnp.float32)
        )
    )


class ReplayBuffer:
    """Host-side handle: config + donated-jit single-mesh entry points.

    The sharded Sebulba path calls the pure functions above inside its own
    ``shard_map``; this wrapper is the single-device API used by examples,
    benchmarks, and tests.  ``insert``/``update_priorities`` donate the old
    state so the ring is updated in place on device.
    """

    def __init__(
        self,
        capacity: int,
        *,
        prioritized: bool = False,
        priority_exponent: float = 0.6,
    ):
        self.capacity = capacity
        self.prioritized = prioritized
        self.priority_exponent = priority_exponent
        self._insert = jax.jit(insert, donate_argnums=0)
        self._update_priorities = jax.jit(update_priorities, donate_argnums=0)
        self._sample = jax.jit(
            functools.partial(
                sample,
                prioritized=prioritized,
                priority_exponent=priority_exponent,
            ),
            static_argnames=("batch_size",),
        )

    def init(self, example: PyTree) -> ReplayState:
        return init(example, self.capacity)

    def insert(
        self, state: ReplayState, batch: PyTree, priorities=None
    ) -> ReplayState:
        return self._insert(state, batch, priorities)

    def sample(self, state: ReplayState, rng: jax.Array, batch_size: int):
        return self._sample(state, rng, batch_size=batch_size)

    def update_priorities(self, state, idx, new_priorities) -> ReplayState:
        return self._update_priorities(state, idx, new_priorities)

    def size(self, state: ReplayState) -> int:
        return int(size(state))
