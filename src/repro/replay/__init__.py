from repro.replay.buffer import (  # noqa: F401
    ReplayBuffer,
    ReplayState,
    init,
    insert,
    insert_slots,
    sample,
    size,
    update_priorities,
)
from repro.replay.sharded import ShardedReplay  # noqa: F401
