"""ShapeDtypeStruct input stand-ins for every (arch x input-shape) workload.

Train/prefill batches: token trajectories + V-trace fields (+ stub modality
embeddings for vlm/audio).  Decode: one new token + the seq_len cache.
No device memory is ever allocated here.
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.configs.base import ArchConfig, InputShape

SDS = jax.ShapeDtypeStruct


def batch_specs(cfg: ArchConfig, shape: InputShape) -> tuple[dict, dict]:
    """(specs, logical_axes) for a train/prefill batch."""
    B, T = shape.global_batch, shape.seq_len
    specs: dict[str, Any] = {
        "tokens": SDS((B, T), jnp.int32),
        "rewards": SDS((B, T), jnp.float32),
        "discounts": SDS((B, T), jnp.float32),
        "behaviour_logp": SDS((B, T), jnp.float32),
    }
    axes: dict[str, Any] = {k: ("batch", "seq") for k in specs}
    if cfg.family == "vlm":
        specs["images"] = SDS((B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
        axes["images"] = ("batch", "patches", "act_embed")
    if cfg.family == "audio":
        specs["frames"] = SDS((B, cfg.num_audio_frames, cfg.d_model), jnp.bfloat16)
        axes["frames"] = ("batch", "frames", "act_embed")
    return specs, axes


def decode_specs(cfg: ArchConfig, shape: InputShape) -> tuple[dict, dict]:
    """(specs, logical_axes) for one serve_step call (token + position)."""
    B = shape.global_batch
    specs = {
        "tokens": SDS((B, 1), jnp.int32),
        "pos": SDS((), jnp.int32),
    }
    axes = {"tokens": ("batch", None), "pos": ()}
    return specs, axes


def make_batch(cfg: ArchConfig, batch_size: int, seq_len: int, rng=None) -> dict:
    """A REAL (allocated) random batch at reduced scale, for smoke tests."""
    rng = rng if rng is not None else jax.random.key(0)
    ks = jax.random.split(rng, 4)
    batch = {
        "tokens": jax.random.randint(
            ks[0], (batch_size, seq_len), 0, cfg.vocab_size
        ),
        "rewards": jax.random.normal(ks[1], (batch_size, seq_len)) * 0.1,
        "discounts": jnp.full((batch_size, seq_len), 0.99, jnp.float32),
        "behaviour_logp": -jnp.abs(
            jax.random.normal(ks[2], (batch_size, seq_len))
        ),
    }
    if cfg.family == "vlm":
        batch["images"] = jax.random.normal(
            ks[3], (batch_size, cfg.num_image_tokens, cfg.d_model)
        ).astype(jnp.bfloat16)
    if cfg.family == "audio":
        batch["frames"] = jax.random.normal(
            ks[3], (batch_size, cfg.num_audio_frames, cfg.d_model)
        ).astype(jnp.bfloat16)
    return batch
