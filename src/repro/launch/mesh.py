"""Production mesh construction.

Defined as FUNCTIONS (never module-level constants) so importing this
module never touches jax device state — the dry-run must set XLA_FLAGS
before the first jax device query.
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """The assigned production mesh: 16x16 = 256 chips per pod (v5e),
    2 pods = 512 chips when ``multi_pod``.

    Axes: ("data", "model"), plus a leading "pod" axis in multi-pod mode.
    Gradient/batch parallelism runs over ("pod", "data"); tensor/expert
    parallelism over "model".
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(axis: str = "batch"):
    """All local devices on one axis (Anakin replication / tests)."""
    return jax.make_mesh((len(jax.devices()),), (axis,))
