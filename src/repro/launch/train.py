"""Training launcher: run the Sebulba-learner train_step for any assigned
architecture on the local mesh (reduced config by default — the full configs
are exercised via the dry-run on the production mesh).

    PYTHONPATH=src python -m repro.launch.train --arch qwen3-4b --steps 50
    PYTHONPATH=src python -m repro.launch.train --arch deepseek-moe-16b \
        --moe-impl a2a --steps 20   # needs >1 device for the model axis
"""

from __future__ import annotations

import argparse
import time

import jax

from repro import optim
from repro.checkpoint import save
from repro.configs.base import ALIASES, get_config, get_reduced_config
from repro.launch.specs import make_batch
from repro.launch.steps import TrainHParams, make_train_step
from repro.models import make_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help=f"one of {sorted(ALIASES)}")
    ap.add_argument("--full", action="store_true",
                    help="full config (needs real accelerators)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--moe-impl", default="sort")
    ap.add_argument("--ckpt", default="")
    args = ap.parse_args()

    cfg = get_config(args.arch) if args.full else get_reduced_config(args.arch)
    mesh = None
    if args.moe_impl == "a2a":
        from repro.launch.mesh import make_host_mesh

        mesh = make_host_mesh()
    model = make_model(cfg, moe_impl=args.moe_impl, mesh=mesh)
    params = model.init(jax.random.key(0))
    n = sum(x.size for x in jax.tree.leaves(params))
    print(f"{cfg.name}: {n / 1e6:.1f}M params on {jax.device_count()} device(s)")

    opt = optim.adam(
        optim.warmup_cosine(args.lr, warmup=10, total_steps=args.steps),
        clip_norm=1.0,
    )
    step = jax.jit(make_train_step(model, opt, TrainHParams()))
    opt_state = opt.init(params)
    t0 = time.time()
    for i in range(args.steps):
        batch = make_batch(cfg, args.batch, args.seq, rng=jax.random.key(i))
        params, opt_state, metrics = step(params, opt_state, batch)
        if i % 10 == 0 or i == args.steps - 1:
            tps = args.batch * args.seq * (i + 1) / (time.time() - t0)
            print(f"step {i:4d}  loss {float(metrics['loss']):.4f}  "
                  f"ce {float(metrics['ce']):.4f}  tok/s {tps:,.0f}")
    if args.ckpt:
        save(args.ckpt, params)
        print(f"checkpoint -> {args.ckpt}")


if __name__ == "__main__":
    main()
