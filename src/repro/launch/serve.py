"""Serving launcher: continuous-batching ServeEngine (dense/moe
attention families) or the static batched decode loop (everything else —
ssm/hybrid recurrent state has no paged layout).

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2-1.5b --gen 32
    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ALIASES, get_reduced_config
from repro.launch.steps import make_serve_step
from repro.models import make_model
from repro.serve import Request, ServeConfig, ServeEngine


def _serve_static(model, params, args) -> None:
    """The pre-engine path: one fixed batch, lockstep greedy decode."""
    cache, _ = model.init_cache(args.batch, args.cache_len)
    serve = jax.jit(make_serve_step(model))
    tok = jnp.ones((args.batch, 1), jnp.int32)
    tok, cache = serve(params, cache, tok, jnp.int32(0))  # compile
    t0 = time.time()
    toks = [tok]
    for t in range(1, args.gen):
        tok, cache = serve(params, cache, tok, jnp.int32(t))
        toks.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"{model.cfg.name}: {args.batch} streams x {args.gen} tokens "
          f"(static batch), "
          f"{args.batch * (args.gen - 1) / dt:,.0f} tok/s steady-state")
    print("stream 0:", out[0, :16].tolist())


def _serve_engine(model, params, args) -> None:
    cfg = model.cfg
    scfg = ServeConfig(
        batch_rows=args.batch,
        prefill_chunk=16,
        token_budget=args.batch + 16,
        block_size=16,
        num_blocks=1 + args.batch * (args.cache_len // 16),
        max_seq=args.cache_len,
        temperature=args.temperature,
        top_k=args.top_k,
        seed=0,
    )
    engine = ServeEngine(model, params, scfg, paged=True)
    prompts = jax.random.randint(
        jax.random.key(1), (2 * args.batch, 8), 0, cfg.vocab_size
    )
    reqs = [
        Request(rid=i + 1, prompt=tuple(int(t) for t in prompts[i]),
                max_new_tokens=args.gen)
        for i in range(2 * args.batch)
    ]
    res = engine.run(reqs)
    print(f"{cfg.name}: {res['completed']} requests x {args.gen} tokens "
          f"(continuous batching, paged KV), "
          f"{res['tokens_per_s']:,.0f} tok/s processed, "
          f"TTFT p50 {res['ttft_p50'] * 1e3:.1f} ms, "
          f"cache occupancy peak {res['cache_occupancy_peak']:.0%}")
    print("request 1:", res["outputs"][1][:16])


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help=f"one of {sorted(ALIASES)}")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--top-k", type=int, default=0)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    try:
        _serve_engine(model, params, args)
    except ValueError as e:
        # family the engine can't page (recurrent state, local attention,
        # softcap) — serve it with the static lockstep loop instead
        print(f"[serve] falling back to static batching: {e}")
        _serve_static(model, params, args)


if __name__ == "__main__":
    main()
