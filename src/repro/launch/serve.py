"""Serving launcher: batched decode (the Sebulba-actor path) for any
assigned architecture at reduced scale.

    PYTHONPATH=src python -m repro.launch.serve --arch mamba2-1.3b --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs.base import ALIASES, get_reduced_config
from repro.launch.steps import make_serve_step
from repro.models import make_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, help=f"one of {sorted(ALIASES)}")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--cache-len", type=int, default=128)
    args = ap.parse_args()

    cfg = get_reduced_config(args.arch)
    model = make_model(cfg)
    params = model.init(jax.random.key(0))
    cache, _ = model.init_cache(args.batch, args.cache_len)
    serve = jax.jit(make_serve_step(model))

    tok = jnp.ones((args.batch, 1), jnp.int32)
    tok, cache = serve(params, cache, tok, jnp.int32(0))  # compile
    t0 = time.time()
    toks = [tok]
    for t in range(1, args.gen):
        tok, cache = serve(params, cache, tok, jnp.int32(t))
        toks.append(tok)
    dt = time.time() - t0
    out = jnp.concatenate(toks, axis=1)
    print(f"{cfg.name}: {args.batch} streams x {args.gen} tokens, "
          f"{args.batch * (args.gen - 1) / dt:,.0f} tok/s steady-state")
    print("stream 0:", out[0, :16].tolist())


if __name__ == "__main__":
    main()
