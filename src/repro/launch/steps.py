"""train_step / serve_step builders for the assigned architectures.

``train_step`` is the Sebulba-learner update at LLM scale: the backbone
consumes token trajectories and optimizes a joint objective

    L = LM cross-entropy  +  rl_weight * V-trace actor-critic terms
        +  aux_weight * router aux losses (MoE)

using the same V-trace op the small-scale Sebulba agent uses (the paper's
technique as a first-class feature of the large-model learner).  Gradient
accumulation over microbatches (lax.scan) + per-layer remat come from the
arch config.

``serve_step`` is the Sebulba-actor decode: one new token against a
seq_len KV cache / recurrent state.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro import optim
from repro.configs.base import ArchConfig
from repro.models.model import Model
from repro.rl import losses

PyTree = Any


@dataclasses.dataclass(frozen=True)
class TrainHParams:
    learning_rate: float = 3e-4
    rl_weight: float = 0.1
    aux_weight: float = 0.01
    entropy_cost: float = 0.001
    value_cost: float = 0.5
    clip_norm: float = 1.0


def make_optimizer(hp: TrainHParams) -> optim.GradientTransformation:
    return optim.adam(hp.learning_rate, clip_norm=hp.clip_norm)


def make_loss_fn(model: Model, hp: TrainHParams) -> Callable:
    def loss_fn(params, batch):
        logits, values, aux = model.forward(params, batch)
        tokens = batch["tokens"]
        B, T = tokens.shape
        # next-token prediction: position t predicts token t+1
        logits_t = logits[:, :-1]
        targets = tokens[:, 1:]
        # CE as logsumexp - target logit: avoids materializing the full
        # (B, T, V) log_softmax array (§Perf: 45 GB/dev on qwen2 train_4k)
        lse = jax.nn.logsumexp(logits_t, axis=-1)
        tgt = jnp.take_along_axis(logits_t, targets[..., None], axis=-1)[..., 0]
        ce = jnp.mean(lse - tgt)
        # V-trace actor-critic on the same trajectory (actions = next tokens)
        out = losses.impala_loss(
            logits_t,
            values[:, :-1],
            targets,
            batch["behaviour_logp"][:, 1:],
            batch["rewards"][:, 1:],
            batch["discounts"][:, 1:],
            values[:, -1],
            entropy_cost=hp.entropy_cost,
            value_cost=hp.value_cost,
        )
        total = ce + hp.rl_weight * out.total + hp.aux_weight * aux
        metrics = {
            "loss": total, "ce": ce, "rl": out.total, "aux": aux,
            "entropy": out.entropy,
        }
        return total, metrics

    return loss_fn


def make_train_step(
    model: Model,
    optimizer: optim.GradientTransformation,
    hp: TrainHParams = TrainHParams(),
) -> Callable:
    loss_fn = make_loss_fn(model, hp)
    micro = model.cfg.microbatches

    def train_step(params, opt_state, batch):
        if micro > 1:
            def accum(carry, mb):
                g_sum, m_sum = carry
                g, m = jax.grad(loss_fn, has_aux=True)(params, mb)
                return (
                    jax.tree.map(jnp.add, g_sum, g),
                    jax.tree.map(jnp.add, m_sum, m),
                ), None

            mbs = jax.tree.map(
                lambda x: x.reshape((micro, x.shape[0] // micro) + x.shape[1:]),
                batch,
            )
            zeros_g = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params
            )
            zeros_m = {k: jnp.float32(0.0)
                       for k in ("loss", "ce", "rl", "aux", "entropy")}
            (g_sum, m_sum), _ = jax.lax.scan(accum, (zeros_g, zeros_m), mbs)
            grads = jax.tree.map(lambda g: g / micro, g_sum)
            metrics = jax.tree.map(lambda m: m / micro, m_sum)
        else:
            grads, metrics = jax.grad(loss_fn, has_aux=True)(params, batch)
        updates, opt_state = optimizer.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, metrics

    return train_step


def sample_tokens(
    logits: jax.Array,
    keys: jax.Array,
    *,
    temperature: float = 0.0,
    top_k: int = 0,
) -> jax.Array:
    """Seeded sampling from (B, V) logits with one PRNG key per row.

    ``temperature <= 0`` is greedy argmax (the old serve-loop behaviour).
    ``top_k > 0`` masks everything below the k-th largest logit to -inf
    before the draw (>= threshold survives, so ties keep deterministic
    membership).  Per-row keys let callers key each row on its REQUEST
    identity — ``fold_in(fold_in(key(seed), request_id), token_index)`` —
    so a request's tokens are independent of batch composition, row
    assignment, and scheduling (the serve determinism contract).
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    scaled = logits.astype(jnp.float32) / temperature
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]
        scaled = jnp.where(scaled >= kth, scaled, -jnp.inf)
    draw = jax.vmap(lambda key, lg: jax.random.categorical(key, lg))
    return draw(keys, scaled).astype(jnp.int32)


def request_keys(seed: int, rids: jax.Array, tok_idx: jax.Array) -> jax.Array:
    """Per-row sampling keys from request ids + per-request token indices."""
    base = jax.random.key(seed)
    return jax.vmap(
        lambda r, t: jax.random.fold_in(jax.random.fold_in(base, r), t)
    )(rids, tok_idx)


def make_serve_step(
    model: Model, temperature: float = 0.0, top_k: int = 0, seed: int = 0
) -> Callable:
    """Serve-loop decode step.  Greedy by default (the original 4-arg
    signature, unchanged for existing callers); with ``temperature > 0``
    the step takes per-row ``(rids, tok_idx)`` int32 vectors and draws
    from seeded per-request streams (same seed -> same tokens, whatever
    the batch around them looks like)."""
    if temperature <= 0.0:
        def serve_step(params, cache, tokens, pos):
            """One decode step: (B, 1) token -> next (B, 1) token (greedy)."""
            logits, _values, cache = model.decode_step(params, cache, tokens, pos)
            next_tokens = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            return next_tokens, cache

        return serve_step

    def serve_step(params, cache, tokens, pos, rids, tok_idx):
        """One sampled decode step: (B, 1) token -> next (B, 1) token."""
        logits, _values, cache = model.decode_step(params, cache, tokens, pos)
        keys = request_keys(seed, rids, tok_idx)
        next_tokens = sample_tokens(
            logits[:, 0], keys, temperature=temperature, top_k=top_k
        )
        return next_tokens[:, None], cache

    return serve_step


def make_prefill_step(model: Model, hp: TrainHParams = TrainHParams()) -> Callable:
    """Inference-prefill: full forward, return last-position logits."""

    def prefill_step(params, batch):
        logits, values, _ = model.forward(params, batch)
        return logits[:, -1], values[:, -1]

    return prefill_step
