import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape) on
the production mesh, and derive the roofline terms from the compiled
artifact.  No real memory is allocated — all inputs are ShapeDtypeStructs.

The two lines above MUST stay the first statements in this file: jax locks
the device count on first backend initialization, and the production mesh
needs 512 placeholder devices.  (Everything else in the repo sees the real
single CPU device — this flag is set here and nowhere else.)

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-1.5b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --arch all --shape all [--multi-pod]
"""

import argparse
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import optim
from repro.configs.base import (
    ALIASES,
    ARCH_IDS,
    INPUT_SHAPES,
    ArchConfig,
    InputShape,
    get_config,
)
from repro.launch import roofline as rf
from repro.launch.mesh import make_production_mesh
from repro.launch.specs import batch_specs, decode_specs
from repro.launch.steps import (
    TrainHParams,
    make_optimizer,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)
from repro.models.model import make_model
from repro.sharding import (
    DEFAULT_RULES,
    FSDP_RULES,
    tree_shardings,
)


def rules_for(cfg: ArchConfig, shape: InputShape, optimized: bool = False) -> dict:
    base = FSDP_RULES if cfg.sharding_rules == "fsdp" else DEFAULT_RULES
    rules = dict(base)
    if shape.name == "long_500k":
        # batch=1 cannot use the data axis; shard the KV/state sequence
        # dim over it instead (flash-decoding style).
        rules["batch"] = None
        rules["kv_seq"] = "data"
    elif shape.kind == "decode" and optimized:
        # §Perf iteration (llama3-405b decode_32k): GQA kv_heads rarely
        # divide model=16, leaving the KV cache replicated on the model
        # axis — shard its sequence dim there instead (kv_seq takes the
        # axis first; flash-decoding-style partial softmax combines).
        # Baseline: 410 GB/dev + 5.4 s collective; optimized: 40 GB/dev
        # (13 GB after donation aliasing) + 0.018 s.  See EXPERIMENTS.md.
        rules["kv_seq"] = "model"
    return rules


def skip_reason(cfg: ArchConfig, shape: InputShape) -> str | None:
    if shape.name == "long_500k" and not cfg.is_subquadratic:
        return (
            "long_500k requires sub-quadratic attention; "
            f"{cfg.name} is pure full-attention (DESIGN.md §Arch-applicability)"
        )
    return None


def lower_step(model, cfg, shape, mesh, rules):
    """Lower the workload's step function with explicit shardings."""
    replicated = NamedSharding(mesh, P())
    params_sds, axes = model.abstract()
    p_shard = tree_shardings(axes, mesh, rules, params_sds)

    if shape.kind in ("train", "prefill"):
        b_sds, b_axes = batch_specs(cfg, shape)
        b_shard = tree_shardings(b_axes, mesh, rules, b_sds)
        if shape.kind == "train":
            opt = make_optimizer(TrainHParams())
            opt_sds = jax.eval_shape(opt.init, params_sds)
            o_shard = optim.state_shardings(opt_sds, p_shard, replicated)
            step = make_train_step(model, opt)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, o_shard, b_shard),
                    out_shardings=(p_shard, o_shard, replicated),
                    donate_argnums=(0, 1),
                ).lower(params_sds, opt_sds, b_sds)
        else:
            step = make_prefill_step(model)
            with mesh:
                lowered = jax.jit(
                    step,
                    in_shardings=(p_shard, b_shard),
                    out_shardings=(replicated, replicated),
                ).lower(params_sds, b_sds)
    else:  # decode
        box = {}

        def build_cache():
            cache, cache_axes = model.init_cache(
                shape.global_batch, shape.seq_len
            )
            box["axes"] = cache_axes
            return cache

        cache_sds = jax.eval_shape(build_cache)
        c_shard = tree_shardings(box["axes"], mesh, rules, cache_sds)
        tok_sds, tok_axes = decode_specs(cfg, shape)
        tok_shard = {
            "tokens": tree_shardings(
                tok_axes["tokens"], mesh, rules, tok_sds["tokens"]
            ),
            "pos": replicated,
        }
        step = make_serve_step(model)
        with mesh:
            lowered = jax.jit(
                step,
                in_shardings=(p_shard, c_shard, tok_shard["tokens"],
                              tok_shard["pos"]),
                out_shardings=(tok_shard["tokens"], c_shard),
                donate_argnums=(1,),
            ).lower(params_sds, cache_sds, tok_sds["tokens"], tok_sds["pos"])
    return lowered


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mesh=None,
    moe_impl: str = "sort",
    extra_rules: dict | None = None,
    roofline_pass: bool | None = None,
    cfg_overrides: dict | None = None,
    optimized: bool = False,
) -> dict:
    """Two-pass dry-run for one (arch, shape, mesh):

    Pass A — the PRODUCTION artifact (scan-over-layers, microbatching,
    remat): lower + compile proves the distribution config is coherent;
    memory_analysis() proves it fits.

    Pass B — an UNROLLED twin (python-loop layers, microbatches=1): XLA
    cost analysis counts a scan body once, so only the unrolled HLO yields
    honest roofline FLOPs/bytes/collective terms.  Single-pod only (the
    roofline table is single-pod per the assignment); multi-pod runs pass A
    only.
    """
    import dataclasses as _dc

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = _dc.replace(cfg, **cfg_overrides)
    shape = INPUT_SHAPES[shape_name]
    result = {
        "arch": cfg.name,
        "shape": shape_name,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "kind": shape.kind,
        "moe_impl": moe_impl,
        "cfg_overrides": cfg_overrides or {},
        "extra_rules": {k: str(v) for k, v in (extra_rules or {}).items()},
    }
    reason = skip_reason(cfg, shape)
    if reason:
        result["skipped"] = reason
        return result

    mesh = mesh if mesh is not None else make_production_mesh(multi_pod=multi_pod)
    chips = mesh.devices.size
    rules = rules_for(cfg, shape, optimized=optimized)
    if extra_rules:
        rules.update(extra_rules)
    if roofline_pass is None:
        roofline_pass = not multi_pod

    # ---- pass A: production artifact ------------------------------------
    t0 = time.time()
    lowered = lower_step(make_model(cfg, moe_impl=moe_impl, mesh=mesh),
                         cfg, shape, mesh, rules)
    result["lower_s"] = round(time.time() - t0, 1)
    t0 = time.time()
    compiled = lowered.compile()
    result["compile_s"] = round(time.time() - t0, 1)
    mem = compiled.memory_analysis()
    result["memory"] = {
        "argument_gb": getattr(mem, "argument_size_in_bytes", 0) / 1e9,
        "output_gb": getattr(mem, "output_size_in_bytes", 0) / 1e9,
        "temp_gb": getattr(mem, "temp_size_in_bytes", 0) / 1e9,
        "peak_gb": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )
        / 1e9,
        "fits_16gb": (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )
        < 16e9,
    }

    # ---- pass B: unrolled twin for honest roofline terms -----------------
    if roofline_pass:
        import dataclasses as dc

        t0 = time.time()
        model_flops = rf.model_flops_for(cfg, shape, shape.kind)
        if (
            cfg.num_layers >= 40
            and not cfg.layer_pattern
            and cfg.cross_attn_every == 0
        ):
            # deep uniform stacks (llama3-405b 126L, mamba2 48L): compiling
            # the fully-unrolled twin is prohibitively slow, and per-layer
            # cost is exactly linear in depth for a uniform stack.  Lower
            # two shallow unrolled twins and extrapolate the scalars.
            pts = []
            for L in (2, 4):
                cfg_l = dc.replace(cfg, microbatches=1, num_layers=L)
                lowered_l = lower_step(
                    make_model(cfg_l, moe_impl=moe_impl, unroll=True,
                               mesh=mesh),
                    cfg_l, shape, mesh, rules,
                )
                pts.append(rf.analyze(lowered_l.compile(), chips, model_flops))
            roof = rf.extrapolate_layers(pts[0], pts[1], (2, 4),
                                         cfg.num_layers)
            result["roofline_method"] = "layer-extrapolated (L=2,4)"
        else:
            cfg_b = dc.replace(cfg, microbatches=1)
            lowered_b = lower_step(
                make_model(cfg_b, moe_impl=moe_impl, unroll=True, mesh=mesh),
                cfg_b, shape, mesh, rules,
            )
            roof = rf.analyze(lowered_b.compile(), chips, model_flops)
            result["roofline_method"] = "unrolled"
        result["roofline_pass_s"] = round(time.time() - t0, 1)
        result["roofline"] = roof.to_dict()

    result["params_m"] = cfg.param_count() / 1e6
    result["active_params_m"] = cfg.active_param_count() / 1e6
    return result


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all", help="arch id or 'all'")
    ap.add_argument("--shape", default="all", help="input shape or 'all'")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--moe-impl", default="sort",
                    choices=["sort", "dense", "a2a"])
    ap.add_argument("--out", default="experiments/dryrun")
    ap.add_argument("--set", action="append", default=[],
                    help="cfg override key=val (perf iterations)")
    ap.add_argument("--rule", action="append", default=[],
                    help="sharding rule override key=axis|none")
    ap.add_argument("--tag", default="", help="suffix for output filenames")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the §Perf-tuned rule set")
    args = ap.parse_args()

    def parse_val(v):
        if v.lower() in ("none", "null"):
            return None
        if v.lower() in ("true", "false"):
            return v.lower() == "true"
        try:
            return int(v)
        except ValueError:
            try:
                return float(v)
            except ValueError:
                return v

    cfg_overrides = {}
    for item in args.set:
        k, v = item.split("=", 1)
        cfg_overrides[k] = parse_val(v)
    extra_rules = {}
    for item in args.rule:
        k, v = item.split("=", 1)
        extra_rules[k] = parse_val(v)

    archs = ARCH_IDS if args.arch == "all" else [ALIASES.get(args.arch, args.arch)]
    shapes = list(INPUT_SHAPES) if args.shape == "all" else [args.shape]
    meshes = [False, True] if args.both_meshes else [args.multi_pod]

    os.makedirs(args.out, exist_ok=True)
    failures = 0
    for multi_pod in meshes:
        mesh = make_production_mesh(multi_pod=multi_pod)
        for arch in archs:
            for shape in shapes:
                tag = f"{arch}_{shape}_{'2x16x16' if multi_pod else '16x16'}"
                if args.tag:
                    tag += "_" + args.tag
                path = os.path.join(args.out, tag + ".json")
                try:
                    res = dryrun_one(
                        arch, shape, multi_pod=multi_pod, mesh=mesh,
                        moe_impl=args.moe_impl,
                        cfg_overrides=cfg_overrides or None,
                        extra_rules=extra_rules or None,
                        optimized=args.optimized,
                    )
                    if "skipped" in res:
                        status = "SKIP"
                    else:
                        dom = res.get("roofline", {}).get("dominant", "-")
                        status = (
                            f"ok lower={res['lower_s']}s "
                            f"compile={res['compile_s']}s dom={dom} "
                            f"peak={res['memory']['peak_gb']:.2f}GB/dev"
                        )
                except Exception as e:  # noqa: BLE001
                    failures += 1
                    res = {
                        "arch": arch, "shape": shape,
                        "mesh": "2x16x16" if multi_pod else "16x16",
                        "error": f"{type(e).__name__}: {e}",
                        "traceback": traceback.format_exc(),
                    }
                    status = f"FAIL {type(e).__name__}: {str(e)[:120]}"
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=str)
                print(f"{tag:55s} {status}", flush=True)
    print(f"done; {failures} failures")
    raise SystemExit(1 if failures else 0)


if __name__ == "__main__":
    main()
