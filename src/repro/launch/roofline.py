"""Roofline-term derivation from compiled dry-run artifacts.

    compute term    = HLO_FLOPs / (chips x peak_FLOP/s)
    memory term     = HLO_bytes / (chips x HBM_bw)
    collective term = collective_bytes / (chips x link_bw)

FLOPs/bytes come from ``compiled.cost_analysis()``.  Collective bytes are
parsed from the optimized HLO text (``compiled.as_text()``): we sum the
output-shape bytes of every all-gather / all-reduce / reduce-scatter /
all-to-all / collective-permute instruction.  Hardware constants are the
assigned TPU v5e numbers.
"""

from __future__ import annotations

import dataclasses
import re

# TPU v5e (assigned constants)
PEAK_FLOPS = 197e12  # bf16 FLOP/s per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8,
    "u64": 8, "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = (
    "all-gather", "all-reduce", "reduce-scatter", "all-to-all",
    "collective-permute",
)

# e.g. "bf16[16,128,2048]{2,1,0}" -> dtype + dims
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")


def _shape_bytes(shape_str: str) -> int:
    total = 0
    for dtype, dims in _SHAPE_RE.findall(shape_str):
        if dtype not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dtype]
    return total


def collective_bytes(hlo_text: str) -> dict[str, int]:
    """Sum output bytes per collective kind over the optimized HLO."""
    out: dict[str, int] = {k: 0 for k in _COLLECTIVES}
    for line in hlo_text.splitlines():
        line = line.strip()
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(.*)", line)
        if not m:
            continue
        rhs = m.group(1)
        for kind in _COLLECTIVES:
            # match the op name, e.g. "bf16[...] all-gather(...)"
            if re.search(rf"\b{kind}(?:-start|-done)?\(", rhs):
                if kind + "-done(" in rhs:
                    break  # bytes already counted at the -start op
                # bytes: the shape(s) before the op name
                head = rhs.split(kind)[0]
                out[kind] += _shape_bytes(head)
                break
    return out


@dataclasses.dataclass
class Roofline:
    """All raw quantities are PER DEVICE: under SPMD partitioning,
    ``compiled.cost_analysis()`` describes the per-device program (verified
    empirically — see EXPERIMENTS.md §Dry-run methodology), and the HLO text
    we parse collectives from is likewise the per-device module.  The roofline
    time for a step is therefore quantity / per-chip rate, no chip division.
    """

    flops: float  # per-device HLO flops
    hbm_bytes: float  # per-device bytes accessed
    coll_bytes: float  # per-device collective bytes
    coll_breakdown: dict
    chips: int
    model_flops: float  # 6*N*D analytic (GLOBAL, whole step)
    per_device_memory_bytes: float

    @property
    def compute_s(self) -> float:
        return self.flops / PEAK_FLOPS

    @property
    def memory_s(self) -> float:
        return self.hbm_bytes / HBM_BW

    @property
    def collective_s(self) -> float:
        return self.coll_bytes / ICI_BW

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.compute_s,
            "memory": self.memory_s,
            "collective": self.collective_s,
        }
        return max(terms, key=terms.get)

    @property
    def total_flops(self) -> float:
        return self.flops * self.chips

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / compiled FLOPs — catches remat/redundancy waste.
        > 1 would mean the compiled program does LESS than the analytic
        model (e.g. replicated compute not actually sharded); < 1 means
        overhead (remat recompute, attention quadratic terms, dispatch)."""
        return self.model_flops / max(self.total_flops, 1.0)

    def to_dict(self) -> dict:
        return {
            "flops_per_device": self.flops,
            "flops_total": self.total_flops,
            "hbm_bytes_per_device": self.hbm_bytes,
            "collective_bytes_per_device": self.coll_bytes,
            "collective_breakdown": self.coll_breakdown,
            "chips": self.chips,
            "model_flops": self.model_flops,
            "per_device_memory_gb": self.per_device_memory_bytes / 1e9,
            "compute_s": self.compute_s,
            "memory_s": self.memory_s,
            "collective_s": self.collective_s,
            "dominant": self.dominant,
            "useful_flops_ratio": self.useful_flops_ratio,
        }


def analyze(compiled, chips: int, model_flops: float) -> Roofline:
    cost = compiled.cost_analysis()
    if isinstance(cost, list):  # older jax returns [dict]
        cost = cost[0]
    flops = float(cost.get("flops", 0.0))
    hbm = float(cost.get("bytes accessed", 0.0))
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)
    mem = compiled.memory_analysis()
    per_dev = 0.0
    if mem is not None:
        per_dev = (
            getattr(mem, "argument_size_in_bytes", 0)
            + getattr(mem, "output_size_in_bytes", 0)
            + getattr(mem, "temp_size_in_bytes", 0)
        )
    return Roofline(
        flops=flops,
        hbm_bytes=hbm,
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        chips=chips,
        model_flops=model_flops,
        per_device_memory_bytes=per_dev,
    )


def extrapolate_layers(
    r2: Roofline, r4: Roofline, depths: tuple[int, int], target: int
) -> Roofline:
    """Linear-in-depth extrapolation for uniform layer stacks.

    For a uniform stack, per-device flops/bytes/collective bytes are exactly
    affine in layer count: base (embedding, head, optimizer epilogue) +
    per-layer slope.  Two shallow unrolled points determine both terms.
    """
    d2, d4 = depths

    def extra(a, b):
        slope = (b - a) / (d4 - d2)
        return a + slope * (target - d2)

    coll = {
        k: extra(r2.coll_breakdown[k], r4.coll_breakdown[k])
        for k in r2.coll_breakdown
    }
    return Roofline(
        flops=extra(r2.flops, r4.flops),
        hbm_bytes=extra(r2.hbm_bytes, r4.hbm_bytes),
        coll_bytes=float(sum(coll.values())),
        coll_breakdown=coll,
        chips=r2.chips,
        model_flops=r2.model_flops,
        per_device_memory_bytes=extra(
            r2.per_device_memory_bytes, r4.per_device_memory_bytes
        ),
    )


def model_flops_for(cfg, shape, kind: str) -> float:
    """6*N*D (train) / 2*N*D (inference) analytic model FLOPs."""
    n_active = cfg.active_param_count()
    if kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens
    if kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape.global_batch
