"""Sebulba — decomposed actors and learners on a single host (paper Fig. 3).

Reproduces the paper's dataflow exactly:

  * the host's devices are split into A actor cores + (n-A) learner cores;
  * one-or-more Python threads per actor core each own a *batched host
    environment* (repro/envs/batched_env.py) and alternate in using their
    actor core, hiding env-stepping latency behind device inference;
  * the actor hot path is ONE fused donated-jit ``act_step`` per env step:
    RNG split -> policy inference -> log-prob -> in-place write into a
    preallocated device-resident ``DeviceTrajectoryBuffer``
    (repro/data/trajectory.py), with the per-step host data (rewards,
    discounts) batched into a single (2, B) transfer.  The only host sync
    per step is reading the actions the env needs;
  * when the ring is full the actor drains it (the trajectory leaves alias
    the donated ring storage — no stacking, no copy), slices the batch on
    the actor core, and sends each shard *device-to-device* to its learner
    core — trajectory leaves never round-trip through host numpy;
  * a single learner thread assembles the shards into one globally-sharded
    batch over the learner mesh and runs the same update on every learner
    core (shard_map), averaging gradients with jax.lax.pmean;
  * the learner update is built once per trajectory shape and cached, with
    params, opt_state, the incoming trajectory shards, and the on-device
    metrics accumulator all donated — the steady-state learner loop is one
    XLA dispatch per update that reuses its buffers in place and never
    syncs device->host (metrics drain to host only on ``log_every``
    boundaries);
  * after each update the learner publishes fresh parameters
    device-to-device to every actor core through a lock-free versioned
    params slot (device_put dispatches async, so the publish never blocks
    the learner); actor threads pick the slot up before their next step.
    The publish is overlap-aware: a core that has not consumed its last
    publish is skipped (``SebulbaConfig.publish_throttle``), so params
    bytes only move when an actor will actually act on them.

The V-trace (IMPALA) objective corrects for the actor/learner policy lag.
``learner_microbatches`` implements the paper's MuZero trick of splitting
the learner batch into N sequential micro-updates to decouple acting batch
size from learning batch size.

Off-policy mode (``SebulbaConfig.replay``): the paper's MuZero recipe keeps
a replay buffer between actors and learner.  Actor trajectory shards are
written into a device-resident replay ring sharded across the learner mesh
(repro/replay/), and each learner update trains on a *mixed* batch — the
fresh online shard concatenated with trajectories sampled from replay —
inside one fused ``shard_map`` step: insert -> sample -> weighted V-trace
update -> priority write-back, with the ring buffers donated so nothing
round-trips through the host.

Agents plug in through the canonical ``repro.api`` protocol — ``init`` /
``initial_carry`` / ``act(params, obs, rng, carry)`` / ``loss(params,
traj, weights)`` with capabilities DECLARED on an ``AgentSpec``
(``recurrent``, ``replay``, ``extras_keys``) and validated once at
construction (``api.resolve_agent``), never sniffed from signatures at
runtime.  Recurrent agents (R2D2, repro/agents/recurrent.py) get their
carry threaded through the fused act-step (donated, reset on episode
boundaries via the discount channel), the carry entering step 0 of each
trajectory slice stored alongside it (``Trajectory.init_carry`` — the
R2D2 "stored state", which rides the replay ring like any other leaf), and
a learner-side burn-in (``SebulbaConfig.burn_in``) that re-unrolls the
first K steps gradient-free to refresh the stale stored state before the
V-trace loss.  Feed-forward agents declare no capabilities and thread the
empty () carry — zero extra leaves, bit-identical programs.  The protocol
costs the hot path nothing: the NamedTuple auxes flatten to the same
leaves, so every donated jit traces to the pre-protocol program.  See
ARCHITECTURE.md §Protocol.

Fault tolerance (repro/core/supervision.py, repro/fault/): actor threads
run as supervised slots — crash -> exponential-backoff restart under a
fresh RNG fold, repeat offender -> quarantine with the surviving actors
still feeding every learner shard, hang -> heartbeat-watchdog cancel —
and a learner that raises a structured ``SebulbaStallError`` (full
diagnostics + every traceback) when no actor can make progress, instead
of polling an empty queue forever.  Checkpoints are atomic + checksummed
with newest-valid-stamp fallback and ``fit(..., auto_resume=True)``.
The supervision hot-path cost is one monotonic heartbeat stamp per env
step.  See ARCHITECTURE.md §Fault tolerance & elasticity.

Multi-host elasticity (repro/distributed/): mounting a ``HostSupervisor``
as ``cluster=`` makes this Sebulba one host of an elastic fleet.  The
learner loop polls host membership once per drain iteration; a
membership epoch bump (a host's lease expired, or a host rejoined)
forces a param republish so every actor restarts from a consistent
version, trajectories are epoch-tagged at enqueue and stale-tagged ones
are dropped at the learner (the epoch-checked insert path — a trajectory
routed under a dead membership never crosses the bump), and the result
schema reports ``hosts_joined`` / ``hosts_lost`` / ``reshards`` /
``epoch``.  See ARCHITECTURE.md §Multi-host elasticity.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
import warnings
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import api, optim
# ImpalaAgent moved to repro/agents/impala.py with the repro.api redesign;
# re-exported here for back-compat with pre-protocol imports.
from repro.agents.impala import ImpalaAgent  # noqa: F401
from repro.compat import shard_map
from repro.configs.base import ReplayConfig
from repro.core.supervision import (
    ActorHandle,
    ActorSupervisor,
    SebulbaStallError,  # noqa: F401  (re-exported: the learner raises it)
)
from repro.core.topology import CoreSplit, split_devices
from repro.envs.device_env import DeviceEnvFleet, FleetStats  # noqa: F401
from repro.data.trajectory import (
    Trajectory,
    buffer_add,
    buffer_drain,
    device_buffer_init,
    split_for_learners,
)
from repro.replay import buffer as replay_buffer
from repro.replay.sharded import ShardedReplay
from repro.rl import losses

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SebulbaConfig:
    num_actor_cores: int = 2  # paper default: 2 actor / 6 learner
    threads_per_actor_core: int = 2  # hide env latency (paper)
    actor_batch_size: int = 32  # envs per actor thread (paper: 32..128)
    trajectory_length: int = 20  # paper: 20 (IMPALA) .. 60
    queue_capacity: int = 4
    discount: float = 0.99
    entropy_cost: float = 0.01
    value_cost: float = 0.5
    clip_rho: float = 1.0
    clip_c: float = 1.0
    learner_microbatches: int = 1  # MuZero batch-splitting trick
    # skip republishing params to an actor core whose previous publish is
    # still unconsumed (the actor acts with the standing slot and the next
    # publish lands instead) — fewer transfers at the cost of up to one
    # actor-pickup interval of extra policy lag when the learner outpaces
    # actors; V-trace absorbs the lag.  False -> publish every update.
    publish_throttle: bool = True
    # recurrent agents only (R2D2, Kapturowski et al. 2019): unroll the
    # first ``burn_in`` steps of every trajectory with the stored carry but
    # WITHOUT gradient, refreshing the (stale, recorded-under-old-params)
    # state before the V-trace loss runs on the remaining steps.  Happens
    # inside the agent loss, i.e. inside the compile-cached donated update.
    burn_in: int = 0
    replay: ReplayConfig | None = None  # set -> off-policy (replay) mode
    # actor supervision (repro/core/supervision.py): a crashed actor
    # incarnation is restarted with exponential backoff (restart_backoff *
    # 2**restarts seconds) under a fresh RNG fold; after ``max_restarts``
    # restarts the slot is quarantined and the fleet degrades gracefully.
    # An actor whose heartbeat is older than ``stall_timeout`` seconds is
    # declared hung by the watchdog (cancelled + restarted/quarantined) —
    # size it above worst-case jit-compile + env-step latency.
    max_restarts: int = 3
    restart_backoff: float = 0.05
    stall_timeout: float = 60.0


class Sebulba:
    def __init__(
        self,
        env_factory: Callable[[int], object] = None,  # seed -> host env
        make_batched_env: Callable[[Callable, int], object] = None,
        network=None,
        optimizer: optim.GradientTransformation = None,
        config: SebulbaConfig = SebulbaConfig(),
        devices=None,
        agent=None,
        device_env=None,  # DeviceEnv / factory / ScenarioMix(es) / fleet
        fault_plan=None,  # repro.fault.FaultPlan — chaos test/bench surface
        cluster=None,  # repro.distributed.HostSupervisor — elastic fleet
    ):
        self.cfg = config
        if device_env is None and (env_factory is None or make_batched_env is None):
            raise ValueError(
                "Sebulba needs an environment: either the host pair "
                "(env_factory, make_batched_env) or device_env= (a "
                "repro.api.DeviceEnv, a zero-arg factory, ScenarioMix "
                "entries, or a prebuilt DeviceEnvFleet)"
            )
        if agent is None:
            if config.replay is not None:
                from repro.agents.replay_impala import ReplayImpalaAgent

                agent = ReplayImpalaAgent(network, config)
            else:
                agent = ImpalaAgent(network, config)
        # One protocol, validated once: signature conformance, the
        # zero-carry invariant, and legacy-agent adaptation all live in
        # repro.api — this class never sniffs arities or class markers.
        self._agent_name = type(agent).__name__
        self.agent, self.spec = api.resolve_agent(
            agent, replay_hint=config.replay is not None
        )
        self._recurrent = self.spec.recurrent
        self.opt = optimizer
        self.env_factory = env_factory
        self.make_batched_env = make_batched_env
        self.split: CoreSplit = split_devices(config.num_actor_cores, devices)
        self.learner_mesh = Mesh(list(self.split.learner_devices), ("batch",))
        self.L = self.split.num_learners
        if (config.actor_batch_size % self.L) != 0:
            raise ValueError("actor batch must divide evenly across learners")

        # device-resident env fleet (the Anakin-style regime): the actor
        # loop fuses env.step + agent.act into one donated jit and never
        # syncs actions to the host.  The fleet is sharded L-ways so each
        # learner's slice of the batch carries the same scenario mix.
        self._fleet: DeviceEnvFleet | None = None
        if device_env is not None:
            if isinstance(device_env, DeviceEnvFleet):
                if device_env.num_envs != config.actor_batch_size:
                    raise ValueError(
                        f"device fleet has {device_env.num_envs} envs but "
                        f"actor_batch_size is {config.actor_batch_size}; "
                        "size the fleet to the actor batch"
                    )
                if device_env.shards % self.L:
                    raise ValueError(
                        f"device fleet is laid out in {device_env.shards} "
                        f"scenario blocks, which does not tile across "
                        f"{self.L} learner cores — build the fleet with "
                        "shards equal to (a multiple of) the learner count "
                        "so every learner sees the same scenario mix"
                    )
                self._fleet = device_env
            else:
                self._fleet = DeviceEnvFleet(
                    device_env, config.actor_batch_size, shards=self.L
                )

        self._replay: ShardedReplay | None = None
        if config.replay is not None:
            rcfg = config.replay
            if config.learner_microbatches != 1:
                raise ValueError(
                    "learner_microbatches is an on-policy feature; replay "
                    "mode decouples batch sizes via sample_batch_size"
                )
            if rcfg.capacity % self.L or rcfg.sample_batch_size % self.L:
                raise ValueError(
                    "replay capacity and sample_batch_size must divide "
                    f"across {self.L} learner cores"
                )
            if config.actor_batch_size > rcfg.capacity:
                raise ValueError(
                    "replay capacity must be >= actor_batch_size: each "
                    "update inserts the full online shard, and a ring "
                    "smaller than one insert would write duplicate slots"
                )
            # capability check, not an arity sniff: replay mode needs the
            # declared replay contract (weights in, priorities out).
            # Fail here, not in a jit trace on the first learner update.
            if not self.spec.replay:
                raise ValueError(
                    "replay mode needs agent.loss(params, trajectory, "
                    "importance_weights) returning LossAux(metrics, "
                    f"priorities); {self._agent_name} declares AgentSpec("
                    "replay=False) — declare AgentSpec(replay=True) and "
                    "emit per-sequence priorities for the write-back"
                )
            self._replay = ShardedReplay(
                self.learner_mesh, rcfg.capacity,
                prioritized=rcfg.prioritized,
                priority_exponent=rcfg.priority_exponent,
            )
            # scenario-mix replay strata: per-learner ring slots are
            # written sequentially (insert_slots), so when the local ring
            # capacity is a multiple of the local online shard, slot s
            # permanently holds scenario scenario_ids[s % local_B] — the
            # ring is structurally stratified by scenario, per learner
            if self._fleet is not None and self._fleet.num_scenarios > 1:
                local_cap = rcfg.capacity // self.L
                local_B = config.actor_batch_size // self.L
                if local_cap % local_B:
                    raise ValueError(
                        "scenario-mix replay needs the per-learner ring "
                        f"capacity ({local_cap}) to be a multiple of the "
                        f"per-learner online shard ({local_B}) so replay "
                        "slots stay scenario-pure (each slot always holds "
                        "the same scenario's trajectories); round "
                        "ReplayConfig.capacity accordingly"
                    )
        elif self.spec.replay:
            raise ValueError(
                f"{self._agent_name} requires SebulbaConfig.replay: it "
                "declares AgentSpec(replay=True) — its loss expects "
                "importance weights and emits replay priorities the "
                "on-policy learner has no ring to write back into"
            )

        # slot counts of the structural replay strata (per learner ring),
        # reported through the per-scenario result counters
        self.replay_strata: dict | None = None
        if self._replay is not None and self._fleet is not None:
            local_cap = config.replay.capacity // self.L
            local_B = config.actor_batch_size // self.L
            if local_cap % local_B == 0:
                cycles = local_cap // local_B
                self.replay_strata = {
                    s.name: (self._fleet.rows[i] // self.L) * cycles
                    for i, s in enumerate(self._fleet.scenarios)
                }

        if config.burn_in < 0:
            raise ValueError("burn_in must be >= 0")
        if config.burn_in:
            if not self._recurrent:
                raise ValueError(
                    "burn_in is a recurrent-agent feature (it refreshes the "
                    "stored carry); feed-forward agents have no state to "
                    "burn in"
                )
            if config.burn_in >= config.trajectory_length:
                raise ValueError(
                    f"burn_in ({config.burn_in}) must leave at least one "
                    "trained step: it must be < trajectory_length "
                    f"({config.trajectory_length})"
                )
        # learner updates are built lazily (they need the trajectory
        # structure), cached per trajectory shape, and donated end to end
        self._update_cache: dict = {}
        self._update_off = None
        self._update_off_core = None
        self._macc_spec = None  # metrics structure, captured at first update
        self.update_traces = 0  # compile probe: jit traces once per compile

        # the fused actor hot path: one donated-jit program per env step
        # (buffer, rng, and recurrent carry donated -> in-place ring and
        # state writes), one donated-jit drain per trajectory (the outputs
        # alias the donated ring storage)
        self._act_step = jax.jit(self._act_step_fn, donate_argnums=(1, 2, 5))
        # device-env mode: env.step fuses INTO the actor program — buffer,
        # rng, env state, and carry all update in place, and nothing (not
        # even the actions) syncs back to the host per step
        self._device_act_step = (
            jax.jit(self._device_act_step_fn, donate_argnums=(1, 2, 3, 6))
            if self._fleet is not None else None
        )
        self._drain = jax.jit(buffer_drain, donate_argnums=(0,))
        self._split_traj = jax.jit(
            lambda traj: split_for_learners(traj, self.L)
        )

        # host-side state shared between threads.  No locks on the hot path:
        # the params slot is a versioned tuple per actor core (list-item
        # assignment/read are atomic under the GIL) and every other mutable
        # field lives on the incarnation's own ActorHandle — heartbeat,
        # frame/backpressure counters, fleet-stats snapshot — written only
        # by its thread and read by the learner.
        self._params_version = 0
        self._param_slots: list[tuple[int, PyTree]] = (
            [(0, None)] * self.split.num_actors
        )
        # last params version each actor core picked up (stamped by actor
        # threads); drives the overlap-aware publish skip
        self._slot_consumed: list[int] = [0] * self.split.num_actors
        self.publishes_sent = 0
        self.publishes_skipped = 0
        # degenerate topology (e.g. single-device CPU): an actor core that
        # is also a learner core shares buffers with the donated update —
        # publishes to it need their own storage (see _publish_params)
        self._shared_devices = frozenset(self.split.actor_devices) & frozenset(
            self.split.learner_devices
        )
        self._queue: queue.Queue = queue.Queue(maxsize=config.queue_capacity)
        self._stop = threading.Event()
        self.episode_returns: deque = deque(maxlen=256)
        # the supervised actor fleet: one slot per (core, thread); slot i's
        # base seed i+1 matches the pre-supervision thread seeds, so a
        # fault-free run is bit-exact with the unsupervised pipeline
        slot_specs = [
            (core, 1 + core * config.threads_per_actor_core + k)
            for core in range(self.split.num_actors)
            for k in range(config.threads_per_actor_core)
        ]
        self.supervisor = ActorSupervisor(
            slots=slot_specs,
            spawn=self._run_actor,
            stop=self._stop,
            max_restarts=config.max_restarts,
            restart_backoff=config.restart_backoff,
            stall_timeout=config.stall_timeout,
            fault_plan=fault_plan,
        )
        self._fault_plan = fault_plan
        # multi-host membership (ISSUE 8): the learner loop polls the
        # cluster each drain iteration and reacts to epoch bumps; actors
        # tag every enqueued trajectory with the epoch they produced it
        # under (one int read — the hot path pays nothing else)
        self._cluster = cluster
        self._epoch = 0
        self.stale_epoch_trajs = 0

    @property
    def frames(self) -> int:
        """Total host env frames generated (summed over every actor
        incarnation the supervisor ever spawned)."""
        return sum(h.frames for h in self.supervisor.handles())

    # -------------------------------------------------------------- setup

    def init(self, rng: jax.Array, obs_shape):
        params = self.agent.init(rng, obs_shape)
        replicated = NamedSharding(self.learner_mesh, P())
        params = jax.device_put(params, replicated)
        opt_state = jax.device_put(self.opt.init(params), replicated)
        self._publish_params(params, force=True)
        return params, opt_state

    def _publish_params(self, params: PyTree, force: bool = False) -> None:
        """Overlap-aware, non-blocking device-to-device publish.

        ``device_put`` only *dispatches* the transfers; the learner thread
        never waits on them.  Each actor core has a versioned slot — a
        (version, params) tuple swapped in one atomic list assignment — so
        actors always read a consistent pair without taking a lock, and the
        versions any actor observes are monotone.

        Publish throttling (``SebulbaConfig.publish_throttle``): a core
        whose consumed stamp trails its slot version has not acted with the
        previous publish yet, so re-publishing would replace params nobody
        ever used — skip the transfer and let the slot stand.  The actor
        consumes the standing slot, its stamp catches up, and the *next*
        publish lands: at most one publish is in flight per core, and
        staleness is bounded by one actor pickup interval.  Skips only
        trigger when the learner outpaces actor pickup; in that regime the
        standing slot can be up to updates-per-actor-step staler than
        publish-every-update would leave it (the transfer saving and the
        extra lag have the same source).  V-trace semantics are unaffected
        either way — behaviour log-probs are recorded from whatever params
        the actor actually used, and the learner's V-trace correction
        absorbs this lag exactly as it absorbs queueing lag; set
        ``publish_throttle=False`` if minimum policy lag matters more than
        publish bandwidth.
        """
        self._params_version += 1
        version = self._params_version
        throttle = self.cfg.publish_throttle and not force
        for i, dev in enumerate(self.split.actor_devices):
            if throttle and self._slot_consumed[i] < self._param_slots[i][0]:
                self.publishes_skipped += 1
                continue
            fresh = jax.device_put(params, dev)
            if dev in self._shared_devices:
                # device_put to the device params already live on returns a
                # handle on the SAME buffers — buffers the donated learner
                # update is about to consume.  Give the slot private storage
                # so actors never read donated-away memory.
                fresh = jax.tree.map(jnp.copy, fresh)
            self._param_slots[i] = (version, fresh)
            self.publishes_sent += 1

    # -------------------------------------------------------------- actor

    def _act_step_fn(self, params, buf, rng, obs, rew_disc, carry):
        """The fused per-step actor program: RNG split, episode-boundary
        carry reset, policy inference, log-prob, and the in-place
        trajectory-ring write — one XLA dispatch per env step, with
        ``buf``, ``rng``, and ``carry`` donated.

        ``carry`` is the recurrent state (or () for feed-forward agents, in
        which case this traces to exactly the pre-carry program).  The
        reset rides the discount channel: ``rew_disc[1]`` is zero where the
        previous env step ended an episode, so those batch rows restart
        from the agent's initial state before acting.  The post-reset carry
        is what ``buffer_add`` snapshots at t == 0 — the R2D2 stored state
        for the slice.

        Every agent takes the canonical ``act(params, obs, rng, carry)``
        (repro.api); the reset branch keys on the DECLARED capability at
        trace time, so the protocol adds zero traced ops either way.
        """
        rng, a_rng = jax.random.split(rng)
        if self._recurrent:
            B = rew_disc.shape[1]
            ended = rew_disc[1] == 0.0  # (B,) prev step closed the episode
            init = self.agent.initial_carry(B)
            carry = jax.tree.map(
                lambda c, c0: jnp.where(
                    ended.reshape((B,) + (1,) * (c.ndim - 1)), c0, c
                ),
                carry, init,
            )
        actions, aux, new_carry = self.agent.act(params, obs, a_rng, carry)
        buf = buffer_add(
            buf, obs, actions, aux.logp, aux.extras, rew_disc, carry
        )
        return actions, buf, rng, new_carry

    def _initial_carry(self, device):
        """This thread's starting recurrent state on its actor core (() for
        feed-forward agents)."""
        if not self._recurrent:
            return ()
        return jax.device_put(
            self.agent.initial_carry(self.cfg.actor_batch_size), device
        )

    def _make_actor_buffer(self, params, obs_dev, device):
        """Preallocate this thread's device trajectory ring, deriving the
        action/logp/extras/carry storage shapes from the agent's canonical
        act (no tracing side effects — ``eval_shape`` is abstract).  Also
        the one place act's extras structure meets the declared
        ``AgentSpec.extras_keys`` — checked here, once per thread, never
        on the hot path (legacy-adapted agents predate the declaration and
        keep their unchecked pytree extras)."""
        as_spec = lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype)
        obs_spec = jax.tree.map(as_spec, obs_dev)
        carry_spec = jax.tree.map(
            as_spec, self.agent.initial_carry(self.cfg.actor_batch_size)
        )
        act_spec, aux_spec, _ = jax.eval_shape(
            self.agent.act, params, obs_spec, jax.random.key(0), carry_spec
        )
        if not api.is_legacy_adapter(self.agent):
            api.validate_extras(aux_spec.extras, self.spec, self._agent_name)
        buf = device_buffer_init(
            self.cfg.trajectory_length, obs_spec, act_spec, aux_spec.logp,
            aux_spec.extras, carry_spec,
        )
        return jax.device_put(buf, device)

    def _run_actor(self, handle: ActorHandle) -> None:
        """One supervised actor incarnation (the ``ActorSupervisor`` spawn
        body).  Exceptions propagate to the supervisor wrapper, which
        records them — with tracebacks — on the handle for the restart /
        quarantine path; nothing here needs a try/except."""
        if self._fleet is not None:
            self._device_actor_loop(handle)
        else:
            self._actor_loop(handle)

    def _actor_loop(self, handle: ActorHandle) -> None:
        cfg = self.cfg
        device = self.split.actor_devices[handle.core_id]
        seed = handle.seed
        env = self.make_batched_env(
            lambda i: self.env_factory(seed * 10_000 + i), cfg.actor_batch_size
        )
        try:
            self._host_actor_loop(handle, env, device)
        finally:
            # release the env's share of the host stepping pool (the shared
            # ThreadPoolExecutor shuts down with its last reference)
            close = getattr(env, "close", None)
            if callable(close):
                close()

    def _actor_live(self, handle: ActorHandle) -> bool:
        """The actor-loop continuation check: run until shutdown (stop) or
        this incarnation is abandoned by the watchdog (cancel)."""
        return not (self._stop.is_set() or handle.cancel.is_set())

    def _host_actor_loop(self, handle: ActorHandle, env, device) -> None:
        cfg = self.cfg
        obs = env.reset()
        rng = jax.device_put(jax.random.key(handle.seed), device)
        running_return = np.zeros(cfg.actor_batch_size)
        # previous step's [rewards; discounts], batched into ONE transfer
        host_data = np.zeros((2, cfg.actor_batch_size), np.float32)
        buf = None
        carry = self._initial_carry(device)  # recurrent state, or ()
        t = 0  # host mirror of the ring cursor (control flow only, no sync)
        last_version = 0
        injector = handle.injector

        while self._actor_live(handle):
            # watchdog heartbeat: one monotonic stamp per env step.  A
            # scheduled fault fires AFTER the stamp, so a hang freezes the
            # heartbeat exactly as a real wedged env would.
            handle.beat()
            if injector is not None:
                injector.tick(stop=self._stop, cancel=handle.cancel)
            version, params = self._param_slots[handle.core_id]
            if version != last_version:
                last_version = version
                # stamp consumption so the learner's throttled publish knows
                # this slot was picked up.  The racy read-modify-write across
                # this core's threads is benign: a stale-low stamp lasts one
                # env step at most (the thread re-reads the slot next loop)
                # and only ever delays a publish, never loses one.
                if self._slot_consumed[handle.core_id] < version:
                    self._slot_consumed[handle.core_id] = version
            obs_dev = jax.device_put(obs, device)
            hd_dev = jax.device_put(host_data, device)
            if buf is None:
                buf = self._make_actor_buffer(params, obs_dev, device)
            if t == cfg.trajectory_length:
                # ring full: merge the final step's rewards, hand the
                # trajectory (aliasing the donated ring storage) to the
                # learner shards, and continue on a fresh ring.  The LIVE
                # carry persists across the drain — only the stored
                # snapshot travels with the trajectory.
                traj, buf = self._drain(buf, hd_dev, obs_dev)
                t = 0
                shards = self._shard_for_learners(traj)
                if not self._queue_put(shards, handle):
                    return  # stopping — the in-flight trajectory is dropped
            actions, buf, rng, carry = self._act_step(
                params, buf, rng, obs_dev, hd_dev, carry
            )
            # the one host sync per step: the env needs the actions
            actions_host = np.asarray(actions)
            next_obs, rewards, dones = env.step(actions_host)

            running_return += rewards
            for r in running_return[dones]:
                self.episode_returns.append(float(r))
            running_return[dones] = 0.0

            host_data = np.stack(
                [rewards, (~dones).astype(np.float32) * cfg.discount]
            )
            handle.frames += cfg.actor_batch_size
            obs = next_obs
            t += 1

    # ------------------------------------------------- actor (device envs)

    def _device_act_step_fn(
        self, params, buf, rng, env_state, obs, rew_disc, carry, stats
    ):
        """The fused per-step actor program for device-resident envs: one
        XLA dispatch covering RNG split, carry reset, policy inference, the
        in-place ring write, the BATCHED ENV STEP, and the per-scenario
        stats fold — with ``buf``, ``rng``, ``env_state``, and ``carry``
        donated.  Where the host path syncs actions back for ``env.step``,
        here the env consumes them inside the same program: the device
        actor loop has NO per-step host sync at all.

        ``rew_disc``/``obs`` are this step's inputs and next step's outputs
        (same convention as the host path: the reward/discount written at
        slot t belong to the step that produced obs_t), left undonated so
        the trajectory drain can read them on boundaries.
        """
        rng, a_rng = jax.random.split(rng)
        if self._recurrent:
            B = rew_disc.shape[1]
            ended = rew_disc[1] == 0.0  # prev step closed the episode
            init = self.agent.initial_carry(B)
            carry = jax.tree.map(
                lambda c, c0: jnp.where(
                    ended.reshape((B,) + (1,) * (c.ndim - 1)), c0, c
                ),
                carry, init,
            )
        actions, aux, new_carry = self.agent.act(params, obs, a_rng, carry)
        buf = buffer_add(
            buf, obs, actions, aux.logp, aux.extras, rew_disc, carry
        )
        env_state, ts = self._fleet.step(env_state, actions)
        stats = self._fleet.update_stats(stats, ts)
        # same discount convention as the host path: cfg.discount on live
        # steps, 0 across episode boundaries (the env's discount channel
        # supplies the boundary)
        rew_disc = jnp.stack([
            ts.reward,
            (ts.discount != 0.0).astype(jnp.float32) * self.cfg.discount,
        ])
        return buf, rng, env_state, ts.obs, rew_disc, new_carry, stats

    def _device_actor_loop(self, handle: ActorHandle) -> None:
        cfg = self.cfg
        device = self.split.actor_devices[handle.core_id]
        fleet = self._fleet
        env_key, rng = jax.random.split(jax.random.key(handle.seed))
        env_state = jax.device_put(fleet.init(env_key), device)
        obs = jax.device_put(fleet.observe(env_state), device)
        rew_disc = jax.device_put(
            jnp.zeros((2, cfg.actor_batch_size), jnp.float32), device
        )
        stats = jax.device_put(fleet.init_stats(), device)
        rng = jax.device_put(rng, device)
        carry = self._initial_carry(device)
        buf = None
        t = 0
        last_version = 0
        injector = handle.injector
        try:
            while self._actor_live(handle):
                handle.beat()
                if injector is not None:
                    injector.tick(stop=self._stop, cancel=handle.cancel)
                version, params = self._param_slots[handle.core_id]
                if version != last_version:
                    last_version = version
                    if self._slot_consumed[handle.core_id] < version:
                        self._slot_consumed[handle.core_id] = version
                if buf is None:
                    buf = self._make_actor_buffer(params, obs, device)
                if t == cfg.trajectory_length:
                    traj, buf = self._drain(buf, rew_disc, obs)
                    t = 0
                    # stats is undonated and cumulative: publishing the
                    # handle is the whole snapshot (no copy, no sync)
                    handle.stats = stats
                    shards = self._shard_for_learners(traj)
                    if not self._queue_put(shards, handle):
                        return
                buf, rng, env_state, obs, rew_disc, carry, stats = (
                    self._device_act_step(
                        params, buf, rng, env_state, obs, rew_disc, carry,
                        stats,
                    )
                )
                handle.frames += cfg.actor_batch_size
                t += 1
        finally:
            handle.stats = stats

    def _queue_put(self, shards, handle: ActorHandle) -> bool:
        """Blocking put that never silently drops a trajectory.

        Retries on a full queue (counting the blocked intervals so ``run``
        can surface learner back-pressure) until the put lands or the
        system is stopping; every retry re-checks the shared stop event AND
        this incarnation's cancel flag, so a shutdown (or a watchdog
        abandonment) can never leave the put spinning.  Only those exits
        drop the trajectory, and that drop is counted too.  Returns False
        when stopping.
        """
        # retry granularity must beat the watchdog: a put blocked on the
        # learner heartbeats once per retry, so the retry interval has to
        # sit well inside the stall budget or back-pressure reads as a hang
        timeout = min(0.5, self.cfg.stall_timeout / 4)
        while self._actor_live(handle):
            try:
                # epoch-tagged: the learner drops entries produced under
                # a stale membership (see run's epoch check)
                self._queue.put((self._epoch, shards), timeout=timeout)
                handle.mark_put()
                return True
            except queue.Full:
                handle.beat()  # blocked on the learner, not hung
                handle.put_blocked += 1
        handle.traj_dropped += 1
        return False

    def _shard_for_learners(self, traj: Trajectory):
        """Slice the completed trajectory on the actor core and send each
        shard directly to its learner device (the paper's device-to-device
        trajectory transfer), reassembling the single-device handles as one
        globally-sharded array per leaf.  No trajectory leaf ever becomes
        host numpy on this path."""
        sharding = NamedSharding(self.learner_mesh, P("batch"))
        if self.L == 1:
            return jax.tree.map(lambda x: jax.device_put(x, sharding), traj)

        # one fused program slices every leaf on the actor core ...
        parts = self._split_traj(traj)
        # ... then each slice is copied device-to-device to its learner
        parts = [
            jax.device_put(part, dev)
            for part, dev in zip(parts, self.split.learner_devices)
        ]

        def assemble(*shards):
            global_shape = (
                shards[0].shape[0] * self.L,
            ) + shards[0].shape[1:]
            return jax.make_array_from_single_device_arrays(
                global_shape, sharding, list(shards)
            )

        return jax.tree.map(assemble, *parts)

    # ------------------------------------------------------------- learner

    def _sgd_step(self, params, opt_state, loss_fn):
        """One synchronized step inside shard_map: grad -> cross-shard
        pmean -> optimizer update.  Shared by the on-policy and replay
        learners so the gradient-step sequence exists once.
        """
        grads, aux = jax.grad(loss_fn, has_aux=True)(params)
        grads = jax.lax.pmean(grads, "batch")
        updates, opt_state = self.opt.update(grads, opt_state, params)
        params = optim.apply_updates(params, updates)
        return params, opt_state, aux

    def _build_update(self, example: Trajectory):
        """The shard_map'd on-policy update core for trajectories shaped
        like ``example``: (params, opt_state, traj) -> (params, opt_state,
        metrics).  Un-jitted — ``_get_update`` wraps it with donation and
        the metrics accumulator; keeping the core separate lets callers
        ``jax.eval_shape`` the metrics structure without compiling."""
        cfg = self.cfg

        def shard_update(params, opt_state, traj):
            def micro_step(carry, mb: Trajectory):
                params, opt_state = carry
                params, opt_state, aux = self._sgd_step(
                    params, opt_state, lambda p: self.agent.loss(p, mb)
                )
                metrics = jax.lax.pmean(aux.metrics, "batch")
                return (params, opt_state), metrics

            if cfg.learner_microbatches > 1:
                n = cfg.learner_microbatches
                mbs = jax.tree.map(
                    lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), traj
                )
                (params, opt_state), metrics = jax.lax.scan(
                    micro_step, (params, opt_state), mbs
                )
                metrics = jax.tree.map(jnp.mean, metrics)
            else:
                (params, opt_state), metrics = micro_step(
                    (params, opt_state), traj
                )
            return params, opt_state, metrics

        traj_spec = jax.tree.map(lambda _: P("batch"), example)
        return shard_map(
            shard_update,
            mesh=self.learner_mesh,
            in_specs=(P(), P(), traj_spec),
            out_specs=(P(), P(), P()),
        )

    @staticmethod
    def _traj_key(traj: Trajectory):
        return (
            jax.tree.structure(traj),
            tuple(
                (tuple(leaf.shape), jnp.dtype(leaf.dtype).name)
                for leaf in jax.tree.leaves(traj)
            ),
        )

    def _get_update(self, traj: Trajectory):
        """The donated, compile-cached on-policy update for this trajectory
        shape -> (jitted update, core).

        Built once per (structure, shapes, dtypes) key and jitted with
        ``donate_argnums`` covering params, opt_state, the trajectory
        shards (they alias the actor ring's D2D copies and are dead after
        the grad step), and the metrics accumulator — the steady-state
        learner update reuses all its buffers in place.
        """
        key = self._traj_key(traj)
        entry = self._update_cache.get(key)
        if entry is None:
            core = self._build_update(traj)

            def update(params, opt_state, traj, macc):
                # trace-time side effect: jit traces exactly once per
                # compile, so this counter is the tests' compile probe
                self.update_traces += 1
                params, opt_state, metrics = core(params, opt_state, traj)
                return params, opt_state, self._macc_add(macc, metrics)

            entry = (jax.jit(update, donate_argnums=(0, 1, 2, 3)), core)
            self._update_cache[key] = entry
        return entry

    # --------------------------------------------- device-resident metrics

    @staticmethod
    def _macc_add(macc, metrics):
        """Fold one update's metrics into the accumulator (traced inside
        the donated update, so accumulation is in-place on device).  The
        accumulator is ONE packed f32 vector — [count, *metric sums] — so
        it adds a single leaf to the update's dispatch, not one per
        metric."""
        leaves = [x.astype(jnp.float32) for x in jax.tree.leaves(metrics)]
        return macc + jnp.stack([jnp.float32(1.0), *leaves])

    def _fresh_macc(self, metrics_spec=None) -> jax.Array:
        """Zeroed device-resident metrics accumulator, replicated over the
        learner mesh.  Every update folds its metrics into it on device;
        the host reads (and therefore syncs on) it only at ``log_every``
        boundaries — the steady-state learner loop never syncs."""
        if metrics_spec is not None:
            self._macc_spec = jax.tree.structure(metrics_spec)
        zeros = jnp.zeros((1 + self._macc_spec.num_leaves,), jnp.float32)
        return jax.device_put(
            zeros, NamedSharding(self.learner_mesh, P())
        )

    def _drain_macc(self, macc) -> dict | None:
        """Pull the accumulated metric means to host — the one
        device->host sync, paid only on log boundaries.  None if nothing
        has accumulated since the last drain."""
        vals = np.asarray(macc)
        if vals[0] == 0.0:
            return None
        return jax.tree.unflatten(
            self._macc_spec, [float(v) / float(vals[0]) for v in vals[1:]]
        )

    # ------------------------------------------------- learner (off-policy)

    def _build_offpolicy_update(self, example: Trajectory):
        """One fused device step: insert the online shard into the local
        replay ring, sample a replay shard, train on the concatenated mixed
        batch with PER importance weights, write TD priorities back.
        Params, opt_state, the replay ring, and the metrics accumulator are
        all donated, so the whole learner state updates in place and never
        leaves the learner cores.  Returns (jitted update, core) — the core
        exists so ``run`` can ``eval_shape`` the metrics structure.
        """
        cfg = self.cfg
        rcfg = cfg.replay
        local_sample = rcfg.sample_batch_size // self.L

        def shard_update(params, opt_state, rstate, traj, key, update_idx):
            key = jax.random.fold_in(key, jax.lax.axis_index("batch"))
            B_on = traj.actions.shape[0]
            # sample from the PRE-insert ring: the online shard already sits
            # in the mixed batch at weight 1.0, and inserting first would
            # put it at max priority and have the sample double-draw it
            sampled, idx, probs = replay_buffer.sample(
                rstate, key, local_sample,
                prioritized=rcfg.prioritized,
                priority_exponent=rcfg.priority_exponent,
            )
            if rcfg.prioritized:
                w_replay = losses.per_importance_weights(
                    probs, replay_buffer.size(rstate),
                    rcfg.importance_beta(update_idx), axis_name="batch",
                )
                ins_slots = replay_buffer.insert_slots(rstate, B_on)
                rstate = replay_buffer.insert(
                    rstate, traj, axis_name="batch"
                )
            else:
                w_replay = jnp.ones((local_sample,), jnp.float32)
                ins_slots = None
                rstate = replay_buffer.insert(rstate, traj)
            mixed = jax.tree.map(
                lambda a, b: jnp.concatenate([a, b], axis=0), traj, sampled
            )
            weights = jnp.concatenate(
                [jnp.ones((B_on,), jnp.float32), w_replay]
            )

            params, opt_state, aux = self._sgd_step(
                params, opt_state,
                lambda p: self.agent.loss(p, mixed, weights),
            )
            td = aux.priorities  # per-sequence TD magnitudes (AgentSpec.replay)
            metrics = jax.lax.pmean(aux.metrics, "batch")
            if rcfg.prioritized:
                # fresh TD priorities for the sampled replay slots, then the
                # just-inserted online slots (uniform mode never reads
                # priorities — skip the dead scatters on the hot path).  Two
                # sequential scatters, replay first: where the insert
                # overwrote a sampled slot, the slot now holds the fresh
                # trajectory, so its TD must deterministically win
                eps = rcfg.priority_epsilon
                rstate = replay_buffer.update_priorities(
                    rstate, idx, td[B_on:] + eps
                )
                rstate = replay_buffer.update_priorities(
                    rstate, ins_slots, td[:B_on] + eps
                )
            return params, opt_state, rstate, metrics

        rspec = self._replay.state_spec(example)
        tspec = self._replay.batch_spec(example)
        core = shard_map(
            shard_update,
            mesh=self.learner_mesh,
            in_specs=(P(), P(), rspec, tspec, P(), P()),
            out_specs=(P(), P(), rspec, P()),
        )

        def update(params, opt_state, rstate, traj, macc, key, update_idx):
            self.update_traces += 1  # compile probe (see _get_update)
            params, opt_state, rstate, metrics = core(
                params, opt_state, rstate, traj, key, update_idx
            )
            return params, opt_state, rstate, self._macc_add(macc, metrics)

        return jax.jit(update, donate_argnums=(0, 1, 2, 4)), core

    def _scenario_snapshot(self):
        """Aggregate the per-thread FleetStats snapshots into the
        per-scenario counters dict (plus the overall mean completed-episode
        return).  Reads — and therefore syncs on — the snapshot arrays, so
        callers only hit this on log/result boundaries."""
        snaps = [
            h.stats for h in self.supervisor.handles() if h.stats is not None
        ]
        if not snaps:
            return {}, float("nan")
        # threads on different actor cores hold stats on different devices;
        # pull each snapshot to host before summing (this IS the boundary
        # sync the docstring describes)
        snaps = [jax.device_get(s) for s in snaps]
        total = jax.tree.map(lambda *xs: sum(xs), *snaps)
        scenarios = self._fleet.stats_summary(total)
        if self.replay_strata:
            for name, slots in self.replay_strata.items():
                scenarios[name]["replay_slots"] = slots
        eps = sum(v["episodes"] for v in scenarios.values())
        rets = sum(v["return_sum"] for v in scenarios.values())
        return scenarios, (rets / eps if eps else float("nan"))

    # ----------------------------------------------------------------- run

    def run(
        self,
        rng: jax.Array,
        obs_shape,
        total_frames: int,
        log_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        restore_from: str | None = None,
        auto_resume: bool = False,
    ) -> dict:
        """Train until ``total_frames`` host env frames have been generated.

        Returns the unified Podracer result schema (``repro.api.runner``).
        ``checkpoint_dir``/``checkpoint_every`` make the runner persist
        ``param_version``-stamped checkpoints every N learner updates
        (plus a final one); ``restore_from`` warm-starts params from a
        checkpoint file or directory before training (the optimizer state
        restarts fresh — research-checkpoint semantics — while the version
        line and cumulative update/frame stamps continue from the
        checkpoint, so resuming into the same directory keeps
        ``latest_checkpoint`` honest).  ``auto_resume=True`` scans
        ``checkpoint_dir`` and restores from the newest VALID stamp when
        one exists (corrupt files are skipped and counted as
        ``checkpoint_fallbacks``), starting fresh on an empty directory —
        the preemption-recovery entry point.  Checkpoint
        writes sync params to host, so like metric drains they only ever
        happen on boundaries, never in the steady-state donated loop.

        Actor threads run under :class:`~repro.core.supervision.\
ActorSupervisor`: a crashed actor restarts with exponential backoff
        (fresh RNG fold, current published params), a slot exceeding
        ``cfg.max_restarts`` is quarantined while the surviving actors
        keep feeding every learner shard, and a hung actor (heartbeat
        older than ``cfg.stall_timeout``) is cancelled by the watchdog.
        Only when NO actor can make progress does the learner raise
        :class:`SebulbaStallError` with the full diagnostics snapshot and
        every recorded traceback.
        """
        cfg = self.cfg
        params, opt_state = self.init(rng, obs_shape)
        restore_from = api.resolve_auto_resume(
            restore_from, checkpoint_dir, auto_resume
        )
        base_updates = base_frames = 0
        checkpoint_fallbacks = 0
        if restore_from is not None:
            params, opt_state, meta = api.restore_for_fit(
                restore_from, params, self.opt,
                NamedSharding(self.learner_mesh, P()),
            )
            # continue the checkpoint's version line (and cumulative
            # update/frame stamps) so new saves sort ABOVE the restored
            # one — otherwise a resume into the same checkpoint_dir would
            # stamp below it and latest_checkpoint would keep resolving
            # to the stale pre-restore params
            self._params_version = meta["param_version"]
            base_updates = meta["updates"]
            base_frames = meta["frames"]
            checkpoint_fallbacks = meta.get("fallbacks", 0)
            self._publish_params(params, force=True)
        ckpt = api.CheckpointPolicy(
            checkpoint_dir, checkpoint_every, base_updates=base_updates,
            fault=(
                self._fault_plan.checkpoint_injector()
                if self._fault_plan is not None else None
            ),
        )

        if self._cluster is not None:
            # join the fleet before actors produce: the baseline epoch
            # tags every trajectory from the first drain onward
            self._epoch = self._cluster.start().epoch
        self.supervisor.start()

        updates = 0
        last_metrics: dict = {}
        macc = None  # device-resident metrics accumulator (init at 1st update)
        replay_state = None
        replay_warmed = False  # size() is monotone: check device once, latch
        replay_rng = jax.random.fold_in(rng, 0x5EB)  # decorrelate from init
        t0 = time.time()
        try:
            while self.frames < total_frames:
                # supervision is learner-driven: every drain iteration
                # (<= ~1 s apart) reaps dead incarnations, fires the
                # heartbeat watchdog, and executes due restarts — no
                # monitor thread, no locks on the actor hot path
                self.supervisor.poll()
                if self._cluster is not None:
                    # host-tier supervision: fire due host chaos, observe
                    # the live set, and on an epoch bump force-republish
                    # so every actor's next step runs the current params
                    # under the current membership (the epoch-checked
                    # publish path)
                    bumped = self._cluster.poll(updates)
                    if bumped is not None:
                        self._epoch = bumped.epoch
                        self._publish_params(params, force=True)
                try:
                    # short poll so supervision stays responsive even when
                    # no actor is producing
                    epoch_tag, shards = self._queue.get(timeout=0.5)
                except queue.Empty:
                    # re-poll before judging progress: the snapshot from the
                    # top of the iteration is up to a get-timeout stale, and
                    # a death in that window must be reaped into the
                    # restarting state (which counts as progress), not
                    # mistaken for a dead fleet
                    self.supervisor.poll()
                    if not self.supervisor.can_progress():
                        # every slot is quarantined/stopped (or hung past
                        # its stall budget): the queue will never fill
                        # again.  Raise the structured stall error instead
                        # of polling forever.
                        raise self.supervisor.stall_error(
                            queue_depth=self._queue.qsize(),
                            param_versions=[
                                v for v, _ in self._param_slots
                            ],
                            frames=self.frames,
                            updates=updates,
                        )
                    continue
                if epoch_tag != self._epoch:
                    # epoch-checked insert: this trajectory was produced
                    # (and its replay routing would be computed) under a
                    # membership that no longer exists — count and drop
                    # rather than train across the reshard boundary
                    self.stale_epoch_trajs += 1
                    continue
                if self._replay is not None:
                    if replay_state is None:
                        replay_state = self._replay.init(shards)
                        self._update_off, self._update_off_core = (
                            self._build_offpolicy_update(shards)
                        )
                    if not replay_warmed:
                        # warmup: fill the ring before learning starts.  The
                        # size() read syncs device->host, so latch the result
                        # rather than re-reading it in the steady-state loop
                        # (it would serialize every donated async update).
                        if self._replay.size(replay_state) < cfg.replay.min_size:
                            replay_state = self._replay.insert(
                                replay_state, shards
                            )
                            continue
                        replay_warmed = True
                    key = jax.random.fold_in(replay_rng, updates)
                    if macc is None:
                        macc = self._fresh_macc(jax.eval_shape(
                            self._update_off_core, params, opt_state,
                            replay_state, shards, key, jnp.int32(0),
                        )[3])
                    params, opt_state, replay_state, macc = self._update_off(
                        params, opt_state, replay_state, shards, macc, key,
                        jnp.int32(updates),
                    )
                else:
                    update, core = self._get_update(shards)
                    if macc is None:
                        macc = self._fresh_macc(jax.eval_shape(
                            core, params, opt_state, shards
                        )[2])
                    params, opt_state, macc = update(
                        params, opt_state, shards, macc
                    )
                self._publish_params(params)
                updates += 1
                ckpt.maybe_save(
                    params, param_version=self._params_version,
                    updates=base_updates + updates,
                    frames=base_frames + self.frames,
                )
                if log_every and updates % log_every == 0:
                    m = self._drain_macc(macc)
                    if m is not None:
                        last_metrics = m
                        macc = self._fresh_macc()
                    if self._fleet is not None:
                        _, ret = self._scenario_snapshot()
                    else:
                        ret = (
                            np.mean(self.episode_returns)
                            if self.episode_returns else float("nan")
                        )
                    print(
                        f"update {updates} frames {self.frames} "
                        f"return {ret:.2f} " +
                        " ".join(
                            f"{k}={v:.3f}" for k, v in last_metrics.items()
                        )
                    )
        finally:
            self._stop.set()
            if self._cluster is not None:
                self._cluster.stop()  # graceful leave: retire our lease
            leaked = self.supervisor.join(timeout=10.0)
            if leaked:
                # a thread that survives stop+cancel+join is wedged beyond
                # recovery (e.g. a truly hung env).  It is daemonic, so the
                # process can still exit — but report it rather than
                # pretending shutdown was clean.
                warnings.warn(
                    "Sebulba shutdown leaked actor threads (still running "
                    f"after stop/cancel/join): {', '.join(leaked)}",
                    RuntimeWarning,
                    stacklevel=2,
                )

        if macc is not None:
            m = self._drain_macc(macc)
            if m is not None:
                last_metrics = m
        ckpt.final_save(
            params, param_version=self._params_version,
            updates=base_updates + updates, frames=base_frames + self.frames,
        )
        dt = time.time() - t0
        if self._fleet is not None:
            scenarios, mean_return = self._scenario_snapshot()
        else:
            scenarios = {}
            mean_return = (
                float(np.mean(self.episode_returns))
                if self.episode_returns else float("nan")
            )
        return api.make_result(
            params=params,
            updates=updates,
            frames=self.frames,
            seconds=dt,
            metrics=last_metrics,
            mean_return=mean_return,
            scenarios=scenarios,
            # logical publish version actors observe via the versioned
            # slots: init's publish + one per learner update (throttled
            # cores skip transfers, not versions)
            param_version=self._params_version,
            publishes_sent=self.publishes_sent,
            publishes_skipped=self.publishes_skipped,
            # learner back-pressure / shutdown accounting (the actor loop
            # retries full-queue puts instead of dropping); sums span every
            # incarnation the supervisor ever spawned
            put_blocked=sum(
                h.put_blocked for h in self.supervisor.handles()
            ),
            traj_dropped=sum(
                h.traj_dropped for h in self.supervisor.handles()
            ),
            # supervision accounting (ISSUE 7): absent-as-0 counters
            actor_restarts=self.supervisor.actor_restarts,
            actor_quarantined=self.supervisor.actor_quarantined,
            watchdog_stalls=self.supervisor.watchdog_stalls,
            checkpoint_fallbacks=checkpoint_fallbacks,
            # multi-host elasticity accounting (ISSUE 8): zeros when no
            # cluster is mounted — one result shape either way
            hosts_joined=(
                self._cluster.hosts_joined if self._cluster else 0
            ),
            hosts_lost=self._cluster.hosts_lost if self._cluster else 0,
            reshards=self._cluster.reshards if self._cluster else 0,
            epoch=self._epoch,
            replay_size=(
                self._replay.size(replay_state)
                if self._replay is not None and replay_state is not None
                else 0
            ),
            checkpoints_saved=ckpt.saved,
        )

    def fit(
        self,
        rng: jax.Array,
        total_frames: int,
        *,
        obs_shape=None,
        log_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        restore_from: str | None = None,
        auto_resume: bool = False,
    ) -> dict:
        """The unified ``repro.api.Runner`` entry point (same loop as
        ``run``).  ``obs_shape`` defaults to what the env factory reports:
        a probe env is constructed for its ``.obs_shape`` and closed if it
        supports closing — pass ``obs_shape`` explicitly when env
        construction is expensive."""
        if obs_shape is None:
            if self._fleet is not None:
                obs_shape = self._fleet.obs_shape
            else:
                probe = self.env_factory(0)
                obs_shape = probe.obs_shape
                close = getattr(probe, "close", None)
                if callable(close):
                    close()
        return self.run(
            rng, obs_shape, total_frames, log_every=log_every,
            checkpoint_dir=checkpoint_dir, checkpoint_every=checkpoint_every,
            restore_from=restore_from, auto_resume=auto_resume,
        )
