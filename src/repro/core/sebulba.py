"""Sebulba — decomposed actors and learners on a single host (paper Fig. 3).

Reproduces the paper's dataflow exactly:

  * the host's devices are split into A actor cores + (n-A) learner cores;
  * one-or-more Python threads per actor core each own a *batched host
    environment* (repro/envs/batched_env.py) and alternate in using their
    actor core, hiding env-stepping latency behind device inference;
  * actors accumulate fixed-length trajectories ON DEVICE, split them along
    the batch dimension, send each shard device-to-device to a learner core,
    and put the (device-array) handles on a Python queue;
  * a single learner thread assembles the shards into one globally-sharded
    batch over the learner mesh and runs the same update on every learner
    core (shard_map), averaging gradients with jax.lax.pmean;
  * after each update the learner pushes fresh parameters device-to-device
    to every actor core; actor threads pick them up before their next
    inference step.

The V-trace (IMPALA) objective corrects for the actor/learner policy lag.
``learner_microbatches`` implements the paper's MuZero trick of splitting
the learner batch into N sequential micro-updates to decouple acting batch
size from learning batch size.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import optim
from repro.core.topology import CoreSplit, split_devices
from repro.data.trajectory import Trajectory, TrajectoryAccumulator
from repro.rl import losses

PyTree = Any


@dataclasses.dataclass(frozen=True)
class SebulbaConfig:
    num_actor_cores: int = 2  # paper default: 2 actor / 6 learner
    threads_per_actor_core: int = 2  # hide env latency (paper)
    actor_batch_size: int = 32  # envs per actor thread (paper: 32..128)
    trajectory_length: int = 20  # paper: 20 (IMPALA) .. 60
    queue_capacity: int = 4
    discount: float = 0.99
    entropy_cost: float = 0.01
    value_cost: float = 0.5
    clip_rho: float = 1.0
    clip_c: float = 1.0
    learner_microbatches: int = 1  # MuZero batch-splitting trick


class ImpalaAgent:
    """Default Sebulba agent: batched-inference actor + V-trace learner.

    Any object with the same three methods (init / act / loss) plugs into
    Sebulba — MuZeroAgent (repro/agents/muzero.py) is the search-based one.
    """

    def __init__(self, network, config: "SebulbaConfig"):
        self.net = network
        self.cfg = config

    def init(self, rng, obs_shape):
        return self.net.init(rng, obs_shape)

    def act(self, params, obs, rng):
        logits, _ = self.net.apply(params, obs)
        actions = jax.random.categorical(rng, logits)
        logp = losses.log_prob(logits, actions)
        return actions, logp, ()

    def loss(self, params, traj: Trajectory):
        cfg = self.cfg
        B, T = traj.actions.shape
        obs_flat = jax.tree.map(
            lambda o: o.reshape((B * T,) + o.shape[2:]), traj.obs
        )
        logits, values = self.net.apply(params, obs_flat)
        logits = logits.reshape(B, T, -1)
        values = values.reshape(B, T)
        _, bootstrap = self.net.apply(params, traj.bootstrap_obs)
        out = losses.impala_loss(
            logits, values, traj.actions, traj.behaviour_logp,
            traj.rewards, traj.discounts, bootstrap,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            clip_rho=cfg.clip_rho, clip_c=cfg.clip_c,
        )
        metrics = {
            "loss": out.total, "pg": out.pg, "value": out.value,
            "entropy": out.entropy, "rho": out.mean_rho,
        }
        return out.total, metrics


class Sebulba:
    def __init__(
        self,
        env_factory: Callable[[int], object],  # seed -> batched-able host env
        make_batched_env: Callable[[Callable, int], object],
        network=None,
        optimizer: optim.GradientTransformation = None,
        config: SebulbaConfig = SebulbaConfig(),
        devices=None,
        agent=None,
    ):
        self.cfg = config
        self.agent = agent if agent is not None else ImpalaAgent(network, config)
        self.opt = optimizer
        self.env_factory = env_factory
        self.make_batched_env = make_batched_env
        self.split: CoreSplit = split_devices(config.num_actor_cores, devices)
        self.learner_mesh = Mesh(list(self.split.learner_devices), ("batch",))
        self.L = self.split.num_learners
        if (config.actor_batch_size % self.L) != 0:
            raise ValueError("actor batch must divide evenly across learners")

        self._inference = jax.jit(self._inference_fn)
        self._update = jax.jit(self._build_update())

        # host-side state shared between threads
        self._param_lock = threading.Lock()
        self._actor_params: list[PyTree] = [None] * self.split.num_actors
        self._queue: queue.Queue = queue.Queue(maxsize=config.queue_capacity)
        self._stop = threading.Event()
        self._actor_errors: list[BaseException] = []
        self.frames = 0
        self._frames_lock = threading.Lock()
        self.episode_returns: deque = deque(maxlen=256)

    # -------------------------------------------------------------- setup

    def init(self, rng: jax.Array, obs_shape):
        params = self.agent.init(rng, obs_shape)
        replicated = NamedSharding(self.learner_mesh, P())
        params = jax.device_put(params, replicated)
        opt_state = jax.device_put(self.opt.init(params), replicated)
        self._publish_params(params)
        return params, opt_state

    def _publish_params(self, params: PyTree) -> None:
        """Device-to-device transfer of fresh params to every actor core."""
        with self._param_lock:
            for i, dev in enumerate(self.split.actor_devices):
                self._actor_params[i] = jax.device_put(params, dev)

    # -------------------------------------------------------------- actor

    def _inference_fn(self, params, obs, rng):
        return self.agent.act(params, obs, rng)

    def _actor_thread(self, thread_id: int, core_id: int, seed: int) -> None:
        try:
            self._actor_loop(thread_id, core_id, seed)
        except BaseException as e:  # surface crashes to the learner loop
            self._actor_errors.append(e)
            self._stop.set()
            raise

    def _actor_loop(self, thread_id: int, core_id: int, seed: int) -> None:
        cfg = self.cfg
        device = self.split.actor_devices[core_id]
        env = self.make_batched_env(
            lambda i: self.env_factory(seed * 10_000 + i), cfg.actor_batch_size
        )
        obs = env.reset()
        acc = TrajectoryAccumulator(cfg.trajectory_length)
        rng = jax.random.key(seed)
        running_return = np.zeros(cfg.actor_batch_size)

        while not self._stop.is_set():
            with self._param_lock:
                params = self._actor_params[core_id]
            rng, a_rng = jax.random.split(rng)
            obs_dev = jax.device_put(obs, device)
            actions, logp, extras = self._inference(params, obs_dev, a_rng)
            actions_host = np.asarray(actions)
            next_obs, rewards, dones = env.step(actions_host)

            running_return += rewards
            for r in running_return[dones]:
                self.episode_returns.append(float(r))
            running_return[dones] = 0.0

            discounts = (~dones).astype(np.float32) * cfg.discount
            acc.add(
                obs_dev,
                actions,
                jax.device_put(rewards, device),
                jax.device_put(discounts, device),
                logp,
                extras,
            )
            with self._frames_lock:
                self.frames += cfg.actor_batch_size
            obs = next_obs

            if acc.full:
                traj = acc.drain(bootstrap_obs=jax.device_put(obs, device))
                shards = self._shard_for_learners(traj)
                try:
                    self._queue.put(shards, timeout=5.0)
                except queue.Full:
                    if self._stop.is_set():
                        return

    def _shard_for_learners(self, traj: Trajectory):
        """Split along batch, device_put each shard onto its learner core
        (the paper's direct device-to-device trajectory transfer), and
        reassemble handles as one globally-sharded array per leaf."""
        sharding = NamedSharding(self.learner_mesh, P("batch"))

        def to_global(x):
            pieces = np.split(np.asarray(x), self.L, axis=0) if self.L > 1 else None
            if pieces is None:
                return jax.device_put(x, sharding)
            shards = [
                jax.device_put(p, d)
                for p, d in zip(pieces, self.split.learner_devices)
            ]
            return jax.make_array_from_single_device_arrays(
                x.shape, sharding, shards
            )

        return jax.tree.map(to_global, traj)

    # ------------------------------------------------------------- learner

    def _build_update(self):
        cfg = self.cfg

        def shard_update(params, opt_state, traj):
            def micro_step(carry, mb: Trajectory):
                params, opt_state = carry
                grads, metrics = jax.grad(self.agent.loss, has_aux=True)(params, mb)
                grads = jax.lax.pmean(grads, "batch")
                metrics = jax.lax.pmean(metrics, "batch")
                updates, opt_state = self.opt.update(grads, opt_state, params)
                params = optim.apply_updates(params, updates)
                return (params, opt_state), metrics

            if cfg.learner_microbatches > 1:
                n = cfg.learner_microbatches
                mbs = jax.tree.map(
                    lambda x: x.reshape((n, x.shape[0] // n) + x.shape[1:]), traj
                )
                (params, opt_state), metrics = jax.lax.scan(
                    micro_step, (params, opt_state), mbs
                )
                metrics = jax.tree.map(jnp.mean, metrics)
            else:
                (params, opt_state), metrics = micro_step(
                    (params, opt_state), traj
                )
            return params, opt_state, metrics

        def update(params, opt_state, traj):
            traj_spec = jax.tree.map(lambda _: P("batch"), traj)
            fn = jax.shard_map(
                shard_update,
                mesh=self.learner_mesh,
                in_specs=(P(), P(), traj_spec),
                out_specs=(P(), P(), P()),
                check_vma=False,
            )
            return fn(params, opt_state, traj)

        return update

    # ----------------------------------------------------------------- run

    def run(
        self,
        rng: jax.Array,
        obs_shape,
        total_frames: int,
        log_every: int = 0,
    ) -> dict:
        """Train until ``total_frames`` host env frames have been generated."""
        cfg = self.cfg
        params, opt_state = self.init(rng, obs_shape)

        threads = []
        tid = 0
        for core in range(self.split.num_actors):
            for _ in range(cfg.threads_per_actor_core):
                t = threading.Thread(
                    target=self._actor_thread, args=(tid, core, tid + 1),
                    daemon=True, name=f"actor-{tid}",
                )
                t.start()
                threads.append(t)
                tid += 1

        updates = 0
        metrics = {}
        t0 = time.time()
        try:
            while self.frames < total_frames:
                if self._actor_errors:
                    raise RuntimeError(
                        "actor thread crashed"
                    ) from self._actor_errors[0]
                try:
                    shards = self._queue.get(timeout=10.0)
                except queue.Empty:
                    continue
                params, opt_state, metrics = self._update(params, opt_state, shards)
                self._publish_params(params)
                updates += 1
                if log_every and updates % log_every == 0:
                    m = {k: float(v) for k, v in metrics.items()}
                    ret = (
                        np.mean(self.episode_returns)
                        if self.episode_returns else float("nan")
                    )
                    print(
                        f"update {updates} frames {self.frames} "
                        f"return {ret:.2f} " +
                        " ".join(f"{k}={v:.3f}" for k, v in m.items())
                    )
        finally:
            self._stop.set()
            for t in threads:
                t.join(timeout=10.0)

        dt = time.time() - t0
        return {
            "params": params,
            "updates": updates,
            "frames": self.frames,
            "fps": self.frames / dt,
            "seconds": dt,
            "mean_return": (
                float(np.mean(self.episode_returns))
                if self.episode_returns else float("nan")
            ),
            "metrics": {k: float(v) for k, v in metrics.items()},
        }
