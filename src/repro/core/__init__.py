"""Podracer core: the paper's two architectures (Anakin, Sebulba)."""

from repro.core.anakin import Anakin, AnakinConfig  # noqa: F401
from repro.core.sebulba import Sebulba, SebulbaConfig  # noqa: F401
from repro.core.topology import CoreSplit, split_devices  # noqa: F401
