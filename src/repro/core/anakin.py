"""Anakin — online learning with the environment ON the accelerator.

Paper Fig. 2, reproduced exactly:

    def step_and_update_fn(...):
        # 1) step the agent and environment N times
        # 2) compute the loss or other RL objective
        # 3) differentiate back through the entire loop

    batched_fn    = jax.vmap(step_and_update)     # fill a TPU core
    iterated_fn   = jax.lax.fori_loop(batched_fn) # stay out of Python
    replicated_fn = <replicate across cores>      # paper: jax.pmap

Two replication paths are provided:

  * ``mode="shard_map"`` (paper-faithful): explicit SPMD via jax.shard_map
    over a 1-D device mesh with an explicit ``jax.lax.pmean`` on the
    gradients — the modern spelling of the paper's ``pmap`` + ``pmean``.
  * ``mode="jit"``: jit + NamedSharding on the batch dimension; XLA GSPMD
    inserts the gradient all-reduce automatically.  Same program, modern
    idiom — kept separate so EXPERIMENTS.md §Perf can compare both.

Properties preserved from the paper: zero host<->device transfers inside
the training loop (env state lives on device), zero Python in the hot loop
(``iterations`` steps run inside one XLA program via lax.scan), and bitwise
determinism given a seed.
"""

from __future__ import annotations

import dataclasses
import functools
import time
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

import numpy as np

from repro import api, optim
from repro.compat import shard_map
from repro.envs.device_env import DeviceEnvFleet
from repro.rl import losses

PyTree = Any


@dataclasses.dataclass(frozen=True)
class AnakinConfig:
    unroll_length: int = 16  # N env steps per update
    batch_per_device: int = 32  # parallel envs per core (vmap width)
    iterations_per_call: int = 16  # updates fused into one XLA program
    entropy_cost: float = 0.01
    value_cost: float = 0.5
    td_lambda: float = 0.9
    mode: str = "shard_map"  # "shard_map" (paper-faithful) | "jit"


class AnakinState(NamedTuple):
    params: PyTree
    opt_state: PyTree
    env_state: PyTree  # (num_devices * batch_per_device, ...)
    obs: jax.Array
    rng: jax.Array  # per-env keys
    step: jax.Array


class Anakin:
    """env + network + optimizer -> a fully-on-device online learner."""

    def __init__(
        self,
        env,
        network,  # .init(rng, obs_shape) -> params; .apply(params, obs) -> (logits, value)
        optimizer: optim.GradientTransformation,
        config: AnakinConfig = AnakinConfig(),
        devices=None,
    ):
        self.env = env
        self.net = network
        self.opt = optimizer
        self.cfg = config
        devices = list(devices if devices is not None else jax.devices())
        self.mesh = Mesh(devices, ("batch",))
        self.num_devices = len(devices)
        self.global_batch = self.num_devices * config.batch_per_device
        # scenario-mix fleet support: Anakin reuses the same DeviceEnvFleet
        # Sebulba's device actor drives.  The per-env vmap path stays
        # untouched for single envs; a fleet swaps in the batched unroll
        # (the fleet steps the whole row batch, heterogeneous scenarios
        # included, inside the scan).
        self._fleet = env if isinstance(env, DeviceEnvFleet) else None
        if self._fleet is not None:
            if self._fleet.num_envs != self.global_batch:
                raise ValueError(
                    f"fleet has {self._fleet.num_envs} envs but Anakin's "
                    f"global batch is {self.global_batch} ({self.num_devices}"
                    f" devices x batch_per_device {config.batch_per_device})"
                )
            if self._fleet.shards % self.num_devices:
                raise ValueError(
                    f"fleet is laid out in {self._fleet.shards} scenario "
                    f"blocks, which does not tile across {self.num_devices} "
                    "devices — build it with shards equal to (a multiple "
                    "of) the device count"
                )
            # shard_map sees per-device slices, so the loss steps a LOCAL
            # fleet whose block layout matches this device's slice of the
            # global rows (jit/GSPMD mode operates on the global batch)
            self._loss_fleet = (
                DeviceEnvFleet(
                    self._fleet.scenarios, config.batch_per_device,
                    shards=self._fleet.shards // self.num_devices,
                )
                if config.mode == "shard_map" else self._fleet
            )
        self._run = self._build()

    # ------------------------------------------------------------------

    def init_state(self, rng: jax.Array) -> AnakinState:
        rng, net_rng = jax.random.split(rng)
        params = self.net.init(net_rng, self.env.obs_shape)
        opt_state = self.opt.init(params)
        env_rngs = jax.random.split(rng, self.global_batch)
        if self._fleet is not None:
            # the fleet splits its own per-row keys; env_rngs stay the
            # per-row ACTION keys either way
            env_state = self.env.init(jax.random.fold_in(rng, 1))
            obs = self.env.observe(env_state)
        else:
            env_state = jax.vmap(self.env.init)(env_rngs)
            obs = jax.vmap(self.env.observe)(env_state)
        state = AnakinState(
            params=params,
            opt_state=opt_state,
            env_state=env_state,
            obs=obs,
            rng=env_rngs,
            step=jnp.zeros((), jnp.int32),
        )
        # place: params/opt replicated, env/obs/rng sharded over the batch axis
        batch_sharded = NamedSharding(self.mesh, P("batch"))
        replicated = NamedSharding(self.mesh, P())
        return AnakinState(
            params=jax.device_put(state.params, replicated),
            opt_state=jax.device_put(state.opt_state, replicated),
            env_state=jax.device_put(state.env_state, batch_sharded),
            obs=jax.device_put(state.obs, batch_sharded),
            rng=jax.device_put(state.rng, batch_sharded),
            step=jax.device_put(state.step, replicated),
        )

    # ------------------------------------------------------------------

    def _unroll_and_loss(self, params, env_state, obs, rng):
        """The paper's minimal unit (top of Fig. 2), for ONE environment.

        Steps the env ``unroll_length`` times and computes the A2C loss;
        differentiating this function differentiates back through the whole
        interaction loop.  Called under vmap (batch) and grad.
        """
        cfg = self.cfg

        def one_step(carry, _):
            env_state, obs, rng = carry
            rng, a_rng = jax.random.split(rng)
            logits, value = self.net.apply(params, obs)
            action = jax.random.categorical(a_rng, logits)
            env_state, ts = self.env.step(env_state, action)
            out = (logits, value, action, ts.reward, ts.discount)
            return (env_state, ts.obs, rng), out

        (env_state, obs, rng), (logits, values, actions, rewards, discounts) = (
            jax.lax.scan(one_step, (env_state, obs, rng), None, cfg.unroll_length)
        )
        _, bootstrap = self.net.apply(params, obs)
        return (
            (logits, values, actions, rewards, discounts, bootstrap),
            (env_state, obs, rng),
        )

    def _fleet_unroll(self, fleet, params, env_state, obs, rng):
        """The batched twin of ``_unroll_and_loss``: the fleet steps its
        whole row batch (a heterogeneous scenario portfolio) inside the
        scan, so one program drives every scenario.  Per-row action keys
        split in lockstep; outputs are transposed to the (B, T, ...) the
        loss expects."""
        cfg = self.cfg
        apply = jax.vmap(self.net.apply, in_axes=(None, 0))

        def one_step(carry, _):
            env_state, obs, rng = carry
            keys = jax.vmap(jax.random.split)(rng)  # (B, 2)
            rng, a_rng = keys[:, 0], keys[:, 1]
            logits, values = apply(params, obs)
            actions = jax.vmap(jax.random.categorical)(a_rng, logits)
            env_state, ts = fleet.step(env_state, actions)
            out = (logits, values, actions, ts.reward, ts.discount)
            return (env_state, ts.obs, rng), out

        (env_state, obs, rng), outs = jax.lax.scan(
            one_step, (env_state, obs, rng), None, cfg.unroll_length
        )
        logits, values, actions, rewards, discounts = jax.tree.map(
            lambda x: jnp.swapaxes(x, 0, 1), outs
        )
        _, bootstrap = apply(params, obs)
        return (
            (logits, values, actions, rewards, discounts, bootstrap),
            (env_state, obs, rng),
        )

    def _loss_fn(self, params, env_state, obs, rng):
        cfg = self.cfg
        if self._fleet is not None:
            (logits, values, actions, rewards, discounts, bootstrap), carry = (
                self._fleet_unroll(
                    self._loss_fleet, params, env_state, obs, rng
                )
            )
        else:
            # vmap the minimal unit over this device's batch of environments
            (logits, values, actions, rewards, discounts, bootstrap), carry = jax.vmap(
                self._unroll_and_loss, in_axes=(None, 0, 0, 0)
            )(params, env_state, obs, rng)
        # (B, T, ...) — exactly what the loss wants
        out = losses.a2c_loss(
            logits, values, actions, rewards, discounts, bootstrap,
            entropy_cost=cfg.entropy_cost, value_cost=cfg.value_cost,
            td_lambda=cfg.td_lambda,
        )
        metrics = {
            "loss": out.total, "pg": out.pg, "value": out.value,
            "entropy": out.entropy, "reward": jnp.mean(rewards),
            "episodes": jnp.sum(discounts == 0.0),
        }
        if self._fleet is not None:
            # per-scenario RATES (per row per step), so the values are
            # invariant under the cross-replica pmean (every replica holds
            # the same scenario composition) and identical in both modes
            lf = self._loss_fleet
            seg = jnp.asarray(lf.scenario_ids)
            denom = jnp.asarray(
                np.array(lf.rows, np.float32) * rewards.shape[1]
            )
            metrics["reward_per_scenario"] = (
                jax.ops.segment_sum(
                    jnp.sum(rewards, axis=1), seg, lf.num_scenarios
                ) / denom
            )
            metrics["episodes_per_scenario"] = (
                jax.ops.segment_sum(
                    jnp.sum((discounts == 0.0).astype(jnp.float32), axis=1),
                    seg, lf.num_scenarios,
                ) / denom
            )
        return out.total, (carry, metrics)

    def _update_once(self, state: AnakinState, sync: Callable) -> tuple[AnakinState, dict]:
        grads, (carry, metrics) = jax.grad(self._loss_fn, has_aux=True)(
            state.params, state.env_state, state.obs, state.rng
        )
        grads = sync(grads)  # pmean across replicas (paper's psum/pmean)
        metrics = sync(metrics)
        env_state, obs, rng = carry
        updates, opt_state = self.opt.update(grads, state.opt_state, state.params)
        params = optim.apply_updates(state.params, updates)
        return (
            AnakinState(params, opt_state, env_state, obs, rng, state.step + 1),
            metrics,
        )

    def _build(self):
        cfg = self.cfg

        def iterated(state: AnakinState, sync) -> tuple[AnakinState, dict]:
            # fori_loop/scan over many updates: no Python in the hot loop
            def body(state, _):
                return self._update_once(state, sync)

            state, metrics = jax.lax.scan(
                body, state, None, cfg.iterations_per_call
            )
            # reduce the per-iteration metrics stack on device: one value
            # per metric leaves the compiled block instead of an
            # (iterations,) stack per metric per call (axis 0 only, so the
            # (S,) per-scenario vectors keep their scenario axis)
            return state, jax.tree.map(
                lambda x: jnp.mean(x, axis=0), metrics
            )

        if cfg.mode == "shard_map":
            def sync(tree):
                return jax.lax.pmean(tree, "batch")

            @functools.partial(jax.jit, donate_argnums=0)
            def run(state):
                fn = shard_map(
                    lambda s: iterated(s, sync),
                    mesh=self.mesh,
                    in_specs=(AnakinState(
                        params=P(), opt_state=P(), env_state=P("batch"),
                        obs=P("batch"), rng=P("batch"), step=P(),
                    ),),
                    out_specs=(
                        AnakinState(
                            params=P(), opt_state=P(), env_state=P("batch"),
                            obs=P("batch"), rng=P("batch"), step=P(),
                        ),
                        P(),
                    ),
                )
                return fn(state)

            return run

        if cfg.mode == "jit":
            batch_sharded = NamedSharding(self.mesh, P("batch"))
            replicated = NamedSharding(self.mesh, P())
            shardings = AnakinState(
                params=replicated, opt_state=replicated,
                env_state=batch_sharded, obs=batch_sharded, rng=batch_sharded,
                step=replicated,
            )

            @functools.partial(jax.jit, donate_argnums=0)
            def run(state):
                state = jax.lax.with_sharding_constraint(state, shardings)
                return iterated(state, lambda tree: tree)

            return run

        raise ValueError(f"unknown anakin mode {cfg.mode!r}")

    # ------------------------------------------------------------------

    def run(self, state: AnakinState, num_calls: int = 1):
        """Run ``num_calls`` compiled blocks of ``iterations_per_call`` updates.

        The compiled block DONATES its input state — (params, opt_state,
        env_state, obs, rng) update in place instead of double-buffering
        the whole pytree, halving peak state memory for large env batches.
        Callers must chain the returned state (``state, m = ank.run(state)``)
        and not touch the donated-away input afterwards.  Metrics come back
        as on-device scalars already averaged over the block's iterations.
        """
        metrics = None
        for _ in range(num_calls):
            state, metrics = self._run(state)
        return state, metrics

    def fit(
        self,
        rng: jax.Array,
        total_frames: int,
        *,
        log_every: int = 0,
        checkpoint_dir: str | None = None,
        checkpoint_every: int = 0,
        restore_from: str | None = None,
        auto_resume: bool = False,
    ) -> dict:
        """The unified ``repro.api.Runner`` entry point: init (or
        ``restore_from``; ``auto_resume=True`` restores from the newest
        VALID stamp in ``checkpoint_dir`` when one exists — corrupt files
        are skipped and surface as the ``checkpoint_fallbacks`` counter),
        run enough compiled blocks to cover
        ``total_frames`` env steps, checkpoint every ``checkpoint_every``
        updates, and return the unified Podracer result schema.

        Counters that only exist on decomposed architectures (publishes,
        queue back-pressure, replay) are reported as 0 — Anakin has one
        program and no transport.  ``param_version`` is the update count:
        every optimizer step is a new logical params version, there being
        no publish step for versions to lag behind (cumulative across
        ``restore_from``, so resumed stamps keep sorting above the
        restored checkpoint's).  ``log_every`` is in
        learner updates, rounded up to the compiled-block granularity
        (``iterations_per_call`` updates per host visit — metrics are
        means over each block, already reduced on device).
        """
        cfg = self.cfg
        state = self.init_state(rng)
        restore_from = api.resolve_auto_resume(
            restore_from, checkpoint_dir, auto_resume
        )
        base_updates = base_frames = 0
        checkpoint_fallbacks = 0
        if restore_from is not None:
            params, opt_state, meta = api.restore_for_fit(
                restore_from, state.params, self.opt,
                NamedSharding(self.mesh, P()),
            )
            state = state._replace(params=params, opt_state=opt_state)
            # continue the checkpoint's version line so new stamps sort
            # above the restored one (see Sebulba.run)
            base_updates = meta["param_version"]
            base_frames = meta["frames"]
            checkpoint_fallbacks = meta.get("fallbacks", 0)
        ckpt = api.CheckpointPolicy(
            checkpoint_dir, checkpoint_every, base_updates=base_updates
        )
        frames_per_call = self.steps_per_call
        num_calls = api.updates_for_frames(total_frames, frames_per_call)
        metrics = None
        # round UP to block granularity, as documented: log_every=150 with
        # 100-update blocks logs every 200 updates, not every 100
        calls_per_log = max(1, -(-log_every // cfg.iterations_per_call))
        t0 = time.time()
        for call in range(num_calls):
            state, metrics = self._run(state)
            updates = base_updates + (call + 1) * cfg.iterations_per_call
            ckpt.maybe_save(
                state.params, param_version=updates, updates=updates,
                frames=base_frames + (call + 1) * frames_per_call,
            )
            if log_every and (call + 1) % calls_per_log == 0:
                drained = {
                    k: float(v) for k, v in metrics.items()
                    if np.ndim(v) == 0
                }
                # both counters cumulative — `updates` already includes the
                # restored base, so frames must too or resumed logs read
                # as a frames-per-update collapse
                print(
                    f"update {updates} frames "
                    f"{base_frames + (call + 1) * frames_per_call} " +
                    " ".join(f"{k}={v:.3f}" for k, v in drained.items())
                )
        updates = num_calls * cfg.iterations_per_call
        frames = num_calls * frames_per_call
        ckpt.final_save(
            state.params, param_version=base_updates + updates,
            updates=base_updates + updates, frames=base_frames + frames,
        )
        dt = time.time() - t0
        drained = (
            {k: float(v) for k, v in metrics.items() if np.ndim(v) == 0}
            if metrics else {}
        )
        # fleet mode: the (S,) per-scenario rate metrics become the unified
        # ``scenarios`` result key (rates over the final compiled block;
        # Sebulba reports exact cumulative counters on its side)
        scenarios = {}
        if self._fleet is not None and metrics is not None:
            rew = np.asarray(metrics["reward_per_scenario"])
            eps = np.asarray(metrics["episodes_per_scenario"])
            for i, s in enumerate(self._fleet.scenarios):
                scenarios[s.name] = {
                    "weight": s.weight,
                    "rows": self._fleet.rows[i],
                    "reward_per_step": float(rew[i]),
                    "episodes_per_step": float(eps[i]),
                }
        result = api.make_result(
            params=state.params,
            updates=updates,
            frames=frames,
            seconds=dt,
            metrics=drained,
            scenarios=scenarios,
            param_version=base_updates + updates,
            checkpoints_saved=ckpt.saved,
            checkpoint_fallbacks=checkpoint_fallbacks,
        )
        # architecture-specific extra: the full donated AnakinState, so
        # callers can keep stepping the compiled block where fit left off
        result["state"] = state
        return result

    @property
    def steps_per_call(self) -> int:
        """Env steps per compiled call (the FPS numerator)."""
        return (
            self.cfg.iterations_per_call
            * self.cfg.unroll_length
            * self.global_batch
        )
