"""Actor supervision for Sebulba (ISSUE 7) — restart, quarantine, degrade.

The Podracer paper decouples actors from learners so the system survives
datacenter reality: preempted workers, stragglers, hung env processes.
This module owns that survival on a single host.  ``ActorSupervisor``
replaces Sebulba's bare thread list:

  * every actor *slot* (one per ``num_actor_cores x threads_per_actor_core``)
    is a supervised lifecycle, not a thread: crash -> restart with
    exponential backoff under a fresh RNG fold (the incarnation re-reads
    the versioned params slot on its first step, so a restarted actor acts
    on current policy, not the one it died under);
  * a slot that keeps dying is QUARANTINED after ``max_restarts`` restarts
    — the fleet degrades gracefully: every surviving actor produces full
    batches that shard across all learner cores, so training continues at
    reduced throughput rather than deadlocking or dying;
  * a heartbeat watchdog: each incarnation stamps a monotonic heartbeat
    every env step (and every blocked queue-put retry); a stamp older than
    ``stall_timeout`` means the actor is hung, not slow — the watchdog
    counts the stall, sets the incarnation's ``cancel`` event (cooperative
    faults and well-behaved envs unwind; a truly wedged thread is
    abandoned and reported at join), and the slot re-enters the restart /
    quarantine path;
  * when NO slot can make progress (all quarantined or stopped) the
    learner's queue drain raises :class:`SebulbaStallError` with a full
    diagnostics snapshot — per-slot states, heartbeat ages, restart
    counts, queue depth, param versions, and EVERY recorded traceback —
    instead of polling an empty queue forever or surfacing only the first
    crash.

State machine per slot::

    new -> running -> (clean exit) stopped
                   -> (crash / watchdog stall) --restarts < max--> restarting
                                               --else-----------> quarantined
    restarting --backoff elapsed--> running (fresh incarnation)

The supervisor is driven by the learner loop (``poll`` once per queue
drain iteration, <= ~0.5 s apart) — no extra monitor thread, no locks on
the actor hot path: an incarnation only writes its own ``ActorHandle``
fields (heartbeat stamp, counters), and the learner reads them.
"""

from __future__ import annotations

import threading
import time
import traceback
from typing import Callable

# incarnation seeds fold the slot's base seed with a large prime so no two
# incarnations (or slots) ever reuse an env/RNG seed line
_SEED_STRIDE = 7919


class SebulbaStallError(RuntimeError):
    """The learner can no longer make progress: no live actor remains (or
    none has produced within the stall budget).  Carries a structured
    ``diagnostics`` snapshot — per-actor heartbeats and states, queue
    depth, param versions — and every per-thread traceback recorded over
    the run, so a cascading failure is diagnosed from all its symptoms,
    not the last one."""

    def __init__(self, message: str, diagnostics: dict):
        super().__init__(message)
        self.diagnostics = diagnostics


class ActorHandle:
    """One incarnation of one supervised actor slot.

    The actor loop runs against its handle: stamps ``beat()`` each step,
    accumulates its own counters (no cross-thread shared lists), and
    checks ``cancel`` so the watchdog can abandon it.  Aggregation sums
    over every handle the supervisor ever created — a restarted slot's
    frames are the sum of its incarnations' frames.
    """

    def __init__(self, slot: int, incarnation: int, core_id: int, seed: int,
                 injector=None):
        self.slot = slot
        self.incarnation = incarnation
        self.core_id = core_id
        self.seed = seed
        self.injector = injector  # persistent per-slot fault injector
        self.cancel = threading.Event()
        self.heartbeat = time.monotonic()
        self.frames = 0
        self.put_blocked = 0
        self.traj_dropped = 0
        self.stats = None  # device-env FleetStats snapshot
        self.error: tuple[BaseException | None, str] | None = None
        self.first_put_at: float | None = None  # recovery-latency probe
        self.died_at: float | None = None
        self.thread: threading.Thread | None = None

    @property
    def name(self) -> str:
        return f"actor-{self.slot}r{self.incarnation}"

    def beat(self) -> None:
        self.heartbeat = time.monotonic()

    def mark_put(self) -> None:
        """Stamp the first successful trajectory put (and heartbeat).  The
        first-put stamp pairs with the previous incarnation's ``died_at``
        to measure recovery latency."""
        self.heartbeat = time.monotonic()
        if self.first_put_at is None:
            self.first_put_at = self.heartbeat

    def heartbeat_age(self, now: float | None = None) -> float:
        return (time.monotonic() if now is None else now) - self.heartbeat


class _Slot:
    def __init__(self, slot_id: int, core_id: int, base_seed: int, injector):
        self.slot_id = slot_id
        self.core_id = core_id
        self.base_seed = base_seed
        self.injector = injector
        self.state = "new"
        self.restarts = 0
        self.handles: list[ActorHandle] = []
        self.next_restart = 0.0

    @property
    def current(self) -> ActorHandle | None:
        return self.handles[-1] if self.handles else None


class ActorSupervisor:
    """Owns the actor fleet's threads and their lifecycle.

    ``spawn`` is the actor body — ``spawn(handle)`` runs the loop for one
    incarnation; the supervisor wraps it so every exception (including a
    scheduled fault) is recorded on the handle with its traceback instead
    of dying silently or masking later crashes.
    """

    def __init__(
        self,
        *,
        slots: list[tuple[int, int]],  # (core_id, base_seed) per slot
        spawn: Callable[[ActorHandle], None],
        stop: threading.Event,
        max_restarts: int = 3,
        restart_backoff: float = 0.05,
        stall_timeout: float = 60.0,
        fault_plan=None,
    ):
        if max_restarts < 0:
            raise ValueError("max_restarts must be >= 0")
        if restart_backoff <= 0:
            raise ValueError("restart_backoff must be > 0")
        if stall_timeout <= 0:
            raise ValueError("stall_timeout must be > 0")
        self._spawn = spawn
        self._stop = stop
        self.max_restarts = max_restarts
        self.restart_backoff = restart_backoff
        self.stall_timeout = stall_timeout
        self.actor_restarts = 0
        self.actor_quarantined = 0
        self.watchdog_stalls = 0
        self._slots = [
            _Slot(
                i, core_id, seed,
                fault_plan.actor_injector(i) if fault_plan is not None else None,
            )
            for i, (core_id, seed) in enumerate(slots)
        ]

    # ------------------------------------------------------------ lifecycle

    def start(self) -> None:
        now = time.monotonic()
        for slot in self._slots:
            self._spawn_incarnation(slot, now)

    def _spawn_incarnation(self, slot: _Slot, now: float) -> None:
        inc = len(slot.handles)
        handle = ActorHandle(
            slot.slot_id, inc, slot.core_id,
            seed=slot.base_seed + _SEED_STRIDE * inc,
            injector=slot.injector,
        )
        handle.heartbeat = now
        thread = threading.Thread(
            target=self._body, args=(handle,), daemon=True, name=handle.name
        )
        handle.thread = thread
        slot.handles.append(handle)
        slot.state = "running"
        thread.start()

    def _body(self, handle: ActorHandle) -> None:
        try:
            self._spawn(handle)
        except BaseException as e:  # record EVERY crash with its traceback
            handle.error = (e, traceback.format_exc())
        finally:
            handle.died_at = time.monotonic()

    # ----------------------------------------------------------- monitoring

    def poll(self, now: float | None = None) -> None:
        """Reap deaths, fire the watchdog, execute due restarts.  Driven by
        the learner loop every queue-drain iteration; all transitions are
        cheap host-side checks."""
        now = time.monotonic() if now is None else now
        for slot in self._slots:
            if slot.state == "running":
                handle = slot.current
                if not handle.thread.is_alive():
                    if handle.error is None:
                        # clean exit: shutdown or cooperative cancel
                        slot.state = "stopped"
                    else:
                        self._on_death(slot, now)
                elif (
                    handle.frames > 0
                    and handle.heartbeat_age(now) > self.stall_timeout
                ):
                    # hung, not slow: no heartbeat for a full stall budget.
                    # Incarnations that have not completed a step yet are
                    # exempt (startup grace): the first step jit-compiles
                    # the fused act/step program, which can dwarf any
                    # reasonable stall budget and is progress, not a hang.
                    # Cancel the incarnation (cooperative hangs unwind; a
                    # wedged thread is abandoned and reported at join) and
                    # put the slot through the restart/quarantine path.
                    self.watchdog_stalls += 1
                    handle.cancel.set()
                    if handle.error is None:
                        handle.error = (None, (
                            f"watchdog: {handle.name} heartbeat stalled "
                            f"({handle.heartbeat_age(now):.1f}s > "
                            f"{self.stall_timeout:.1f}s stall_timeout); "
                            "incarnation cancelled\n"
                        ))
                    handle.died_at = now
                    self._on_death(slot, now)
            if (
                slot.state == "restarting"
                and now >= slot.next_restart
                and not self._stop.is_set()
            ):
                slot.restarts += 1
                self.actor_restarts += 1
                self._spawn_incarnation(slot, now)

    def _on_death(self, slot: _Slot, now: float) -> None:
        if slot.restarts >= self.max_restarts:
            slot.state = "quarantined"
            self.actor_quarantined += 1
        else:
            slot.state = "restarting"
            slot.next_restart = now + self.restart_backoff * (2 ** slot.restarts)

    def can_progress(self, now: float | None = None) -> bool:
        """True while some slot can still feed the learner: running with a
        live heartbeat, pending restart, or not yet started.  False means
        the queue will never fill again — the learner must raise, not
        poll."""
        now = time.monotonic() if now is None else now
        for slot in self._slots:
            if slot.state in ("new", "restarting"):
                return True
            if slot.state == "running":
                handle = slot.current
                if handle.thread.is_alive() and (
                    handle.frames == 0  # startup grace: still compiling
                    or handle.heartbeat_age(now) <= self.stall_timeout
                ):
                    return True
        return False

    # ------------------------------------------------------------ reporting

    def handles(self) -> list[ActorHandle]:
        """Every incarnation ever spawned (counter aggregation surface)."""
        return [h for slot in self._slots for h in slot.handles]

    def errors(self) -> list[tuple[str, str]]:
        """(incarnation name, traceback) for every recorded failure, in
        slot/incarnation order — nothing is masked by arrival order."""
        return [
            (h.name, h.error[1])
            for slot in self._slots
            for h in slot.handles
            if h.error is not None
        ]

    def recovery_latencies(self) -> list[float]:
        """Seconds from each incarnation's death to its replacement's
        first successful trajectory put (the fleet's measured recovery
        latency).

        Incomplete pairs are DROPPED, never mis-paired: a dead
        incarnation with no replacement (quarantined slot) measures
        nothing, and an incarnation that died before its own first put
        (e.g. a replacement cancelled by the watchdog mid-compile)
        neither completes the previous pairing nor baselines the next —
        a latency is only ever adjacent death -> adjacent first put.
        """
        out = []
        for slot in self._slots:
            for prev, nxt in zip(slot.handles, slot.handles[1:]):
                if (
                    prev.first_put_at is not None
                    and prev.died_at is not None
                    and nxt.first_put_at is not None
                ):
                    out.append(max(0.0, nxt.first_put_at - prev.died_at))
        return out

    def diagnostics(self, now: float | None = None, **extra) -> dict:
        now = time.monotonic() if now is None else now
        actors = []
        for slot in self._slots:
            handle = slot.current
            actors.append({
                "slot": slot.slot_id,
                "core": slot.core_id,
                "state": slot.state,
                "restarts": slot.restarts,
                "incarnations": len(slot.handles),
                "heartbeat_age": (
                    round(handle.heartbeat_age(now), 3) if handle else None
                ),
                "alive": bool(handle and handle.thread.is_alive()),
                "frames": sum(h.frames for h in slot.handles),
                "last_error": (
                    repr(handle.error[0]) if handle and handle.error else None
                ),
            })
        return {
            "actors": actors,
            "actor_restarts": self.actor_restarts,
            "actor_quarantined": self.actor_quarantined,
            "watchdog_stalls": self.watchdog_stalls,
            **extra,
        }

    def stall_error(self, **extra) -> SebulbaStallError:
        """Build the structured learner-side stall error: diagnostics
        snapshot plus every recorded traceback."""
        diag = self.diagnostics(**extra)
        tracebacks = self.errors()
        lines = [
            "Sebulba learner stalled: no actor can make progress "
            f"({sum(1 for a in diag['actors'] if a['state'] == 'quarantined')}"
            f"/{len(diag['actors'])} quarantined).",
            f"diagnostics: {diag}",
        ]
        for name, tb in tracebacks:
            lines.append(f"--- {name} ---\n{tb.rstrip()}")
        diag["tracebacks"] = tracebacks
        return SebulbaStallError("\n".join(lines), diag)

    # ------------------------------------------------------------- shutdown

    def join(self, timeout: float) -> list[str]:
        """Join every incarnation ever spawned (current AND abandoned),
        spreading ``timeout`` across them; returns the names of threads
        that failed to stop (leaked — e.g. truly wedged in a hung env)."""
        threads = [
            h for h in self.handles()
            if h.thread is not None and h.thread.is_alive()
        ]
        deadline = time.monotonic() + timeout
        leaked = []
        for h in threads:
            h.cancel.set()
            h.thread.join(timeout=max(0.0, deadline - time.monotonic()))
            if h.thread.is_alive():
                leaked.append(h.name)
        return leaked
