"""Device topology bookkeeping for the Podracer architectures.

Sebulba splits the cores attached to each host into disjoint actor and
learner sets (paper Fig. 1c / Fig. 3); Anakin uses every core uniformly
(paper Fig. 1b).  On real TPU hosts ``jax.local_devices()`` returns the 8
cores of Fig. 1a; on this CPU container the same code runs against
``--xla_force_host_platform_device_count`` placeholder devices.

Multi-host (ISSUE 8): a TPU pod presents each host with its own local
cores, so the host-aware path carves the global device list into
``num_hosts`` contiguous per-host groups first — ``host_rank`` selects
this host's group, and the actor/learner split happens inside it.  The
split stays a pure function of ``(devices, num_hosts, host_rank)``, so
every host derives its own (disjoint) cores from the same global list
with no coordination, and the elastic bench can emulate a pod by giving
each worker process a different ``host_rank`` over one placeholder
device list.
"""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class CoreSplit:
    actor_devices: tuple
    learner_devices: tuple
    # which host's slice of the pod this split is (host-aware path);
    # single-host callers keep the 0-of-1 defaults
    host_rank: int = 0
    num_hosts: int = 1

    @property
    def num_actors(self) -> int:
        return len(self.actor_devices)

    @property
    def num_learners(self) -> int:
        return len(self.learner_devices)


def split_devices(
    num_actor_cores: int,
    devices=None,
    *,
    host_rank: int = 0,
    num_hosts: int = 1,
) -> CoreSplit:
    """Split local devices into A actor cores + (n - A) learner cores.

    The paper's default for model-free agents is a 1:3 actor:learner split
    (2 actor + 6 learner cores on an 8-core host).  With a single device
    (CPU quickstart) the same device plays both roles.

    ``num_hosts`` > 1 enables the host-aware path: ``devices`` (default
    every local device) is carved into ``num_hosts`` contiguous groups
    and the actor/learner split is taken inside group ``host_rank`` —
    each host of the pod owns a disjoint device set derived from the
    same global list.
    """
    devices = tuple(devices if devices is not None else jax.local_devices())
    if not 0 <= host_rank < num_hosts:
        raise ValueError(
            f"need 0 <= host_rank < num_hosts, got host_rank={host_rank} "
            f"with num_hosts={num_hosts}"
        )
    if num_hosts > 1:
        if len(devices) % num_hosts:
            raise ValueError(
                f"{len(devices)} devices do not tile across {num_hosts} "
                "hosts; the host-aware split carves contiguous equal "
                "groups — size the device list (or "
                "--xla_force_host_platform_device_count) to a multiple "
                "of num_hosts"
            )
        per_host = len(devices) // num_hosts
        devices = devices[host_rank * per_host:(host_rank + 1) * per_host]
    if len(devices) == 1:
        return CoreSplit(
            actor_devices=devices, learner_devices=devices,
            host_rank=host_rank, num_hosts=num_hosts,
        )
    if not 0 < num_actor_cores < len(devices):
        raise ValueError(
            f"cannot split {len(devices)} device(s) into "
            f"{num_actor_cores} actor core(s) + at least one learner "
            "core: need 0 < num_actor_cores < the per-host device "
            "count. Fix-its: run with more placeholder devices "
            "(XLA_FLAGS=--xla_force_host_platform_device_count=N), "
            "lower SebulbaConfig.num_actor_cores, or rely on the "
            "single-device fallback (exactly one device -> that device "
            "plays both roles). Multi-host runs split per host: "
            "split_devices(..., host_rank=r, num_hosts=H) carves the "
            "device list H ways first, so each host needs "
            "num_actor_cores < devices/H."
        )
    return CoreSplit(
        actor_devices=devices[:num_actor_cores],
        learner_devices=devices[num_actor_cores:],
        host_rank=host_rank,
        num_hosts=num_hosts,
    )
