"""Device topology bookkeeping for the Podracer architectures.

Sebulba splits the cores attached to each host into disjoint actor and
learner sets (paper Fig. 1c / Fig. 3); Anakin uses every core uniformly
(paper Fig. 1b).  On real TPU hosts ``jax.local_devices()`` returns the 8
cores of Fig. 1a; on this CPU container the same code runs against
``--xla_force_host_platform_device_count`` placeholder devices.
"""

from __future__ import annotations

import dataclasses

import jax


@dataclasses.dataclass(frozen=True)
class CoreSplit:
    actor_devices: tuple
    learner_devices: tuple

    @property
    def num_actors(self) -> int:
        return len(self.actor_devices)

    @property
    def num_learners(self) -> int:
        return len(self.learner_devices)


def split_devices(num_actor_cores: int, devices=None) -> CoreSplit:
    """Split local devices into A actor cores + (n - A) learner cores.

    The paper's default for model-free agents is a 1:3 actor:learner split
    (2 actor + 6 learner cores on an 8-core host).  With a single device
    (CPU quickstart) the same device plays both roles.
    """
    devices = tuple(devices if devices is not None else jax.local_devices())
    if len(devices) == 1:
        return CoreSplit(actor_devices=devices, learner_devices=devices)
    if not 0 < num_actor_cores < len(devices):
        raise ValueError(
            f"need 0 < actor cores < {len(devices)}, got {num_actor_cores}"
        )
    return CoreSplit(
        actor_devices=devices[:num_actor_cores],
        learner_devices=devices[num_actor_cores:],
    )
