"""Pure-JAX token tasks for LM-policy Sebulba (ISSUE 9).

``TokenEnv`` is a ``repro.api.DeviceEnv`` whose observations are single
int32 tokens (``obs_shape == ()``) and whose actions are tokens from the
model's vocabulary — so ``agent.act`` *is* autoregressive generation and
the whole rollout fuses into the device-fleet actor step.

An episode has two phases of ``prompt_len`` steps each:

  * prompt phase (t < P): the env feeds the prompt one token per step;
    actions are ignored (teacher forcing), reward is 0;
  * generation phase (t >= P): the env shows a SEP token once, then the
    agent's *own previous action* — a true autoregressive feedback loop —
    and pays dense per-token reward 1.0 for each emitted token matching
    the target (``copy``: the prompt; ``reverse``: the prompt backwards).

Auto-reset follows the house idiom (repro/api/env.py): the final step of
an episode returns ``discount == 0`` and an obs that already belongs to
the next episode (its first prompt token).  Episodes are fixed-length
(``2 * prompt_len``), so a fleet whose rows all start at t == 0 stays in
lockstep forever — the invariant LMPolicyAgent's shared decode position
relies on (see repro/agents/lm_policy.py).

Token layout: 0 = PAD (initial "previous action"), 1 = SEP, data tokens
drawn from ``[2, 2 + data_vocab)``.  ``data_vocab`` defaults to filling
the declared vocabulary but can be shrunk so small models learn the task
quickly while keeping the full-size action space.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.types import TimeStep

PAD = 0
SEP = 1


class TokenEnvState(NamedTuple):
    prompt: jax.Array  # (prompt_len,) int32 data tokens of this episode
    t: jax.Array  # () int32 step index within the episode
    last_action: jax.Array  # () int32 token the agent emitted last step
    rng: jax.Array


class TokenEnv:
    def __init__(
        self,
        vocab_size: int = 64,
        prompt_len: int = 4,
        task: str = "copy",
        data_vocab: int | None = None,
    ):
        if task not in ("copy", "reverse"):
            raise ValueError(
                f"TokenEnv task must be 'copy' or 'reverse', got {task!r}"
            )
        if data_vocab is None:
            data_vocab = vocab_size - 2
        if not (1 <= data_vocab <= vocab_size - 2):
            raise ValueError(
                f"data_vocab {data_vocab} must fit in [1, vocab_size - 2] "
                f"(vocab {vocab_size} reserves 0=PAD, 1=SEP)"
            )
        self.num_actions = int(vocab_size)
        self.obs_shape = ()  # scalar int32 token
        self.prompt_len = int(prompt_len)
        self.episode_len = 2 * self.prompt_len
        self.task = task
        self.data_vocab = int(data_vocab)

    def _draw_prompt(self, rng: jax.Array) -> jax.Array:
        return jax.random.randint(
            rng, (self.prompt_len,), SEP + 1, SEP + 1 + self.data_vocab,
            dtype=jnp.int32,
        )

    def init(self, rng: jax.Array) -> TokenEnvState:
        rng, sub = jax.random.split(rng)
        return TokenEnvState(
            prompt=self._draw_prompt(sub),
            t=jnp.int32(0),
            last_action=jnp.int32(PAD),
            rng=rng,
        )

    def observe(self, s: TokenEnvState) -> jax.Array:
        P = self.prompt_len
        prompt_tok = s.prompt[jnp.clip(s.t, 0, P - 1)]
        gen_tok = jnp.where(s.t == P, jnp.int32(SEP), s.last_action)
        return jnp.where(s.t < P, prompt_tok, gen_tok).astype(jnp.int32)

    def _target(self, prompt: jax.Array, i: jax.Array) -> jax.Array:
        if self.task == "copy":
            return prompt[i]
        return prompt[self.prompt_len - 1 - i]

    def step(self, s: TokenEnvState, action: jax.Array):
        P, E = self.prompt_len, self.episode_len
        action = action.astype(jnp.int32)
        i = jnp.clip(s.t - P, 0, P - 1)
        hit = (s.t >= P) & (action == self._target(s.prompt, i))
        reward = hit.astype(jnp.float32)
        t_next = s.t + 1
        done = t_next >= E
        # rng advances every step so the reset branch below never reuses a
        # key; jnp.where on the key itself would trip typed-key dtypes.
        rng, sub = jax.random.split(s.rng)
        fresh_prompt = self._draw_prompt(sub)
        new_state = TokenEnvState(
            prompt=jnp.where(done, fresh_prompt, s.prompt),
            t=jnp.where(done, jnp.int32(0), t_next),
            last_action=jnp.where(done, jnp.int32(PAD), action),
            rng=rng,
        )
        ts = TimeStep(
            obs=self.observe(new_state),
            reward=reward,
            discount=jnp.where(done, 0.0, 1.0).astype(jnp.float32),
            first=done,
        )
        return new_state, ts
