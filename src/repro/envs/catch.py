"""Catch — the bsuite grid environment used in the paper's Anakin Colab.

A ball falls from the top of a (rows x cols) board; the agent moves a paddle
on the bottom row (left / stay / right) and gets +1 for catching the ball,
-1 for missing.  Written as pure JAX so the whole env lives on the
accelerator (Anakin's requirement).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.types import TimeStep


class CatchState(NamedTuple):
    ball_y: jax.Array
    ball_x: jax.Array
    paddle_x: jax.Array
    rng: jax.Array


class Catch:
    def __init__(self, rows: int = 10, cols: int = 5):
        self.rows = rows
        self.cols = cols
        self.num_actions = 3
        self.obs_shape = (rows, cols)
        self.discount = 0.99

    def _spawn(self, rng: jax.Array) -> CatchState:
        rng, sub = jax.random.split(rng)
        ball_x = jax.random.randint(sub, (), 0, self.cols)
        return CatchState(
            ball_y=jnp.int32(0),
            ball_x=ball_x,
            paddle_x=jnp.int32(self.cols // 2),
            rng=rng,
        )

    def init(self, rng: jax.Array) -> CatchState:
        return self._spawn(rng)

    def observe(self, s: CatchState) -> jax.Array:
        board = jnp.zeros((self.rows, self.cols), jnp.float32)
        board = board.at[s.ball_y, s.ball_x].set(1.0)
        board = board.at[self.rows - 1, s.paddle_x].set(1.0)
        return board

    def step(self, s: CatchState, action: jax.Array) -> tuple[CatchState, TimeStep]:
        dx = action - 1  # {0,1,2} -> {-1,0,1}
        paddle_x = jnp.clip(s.paddle_x + dx, 0, self.cols - 1)
        ball_y = s.ball_y + 1
        done = ball_y == self.rows - 1
        caught = done & (s.ball_x == paddle_x)
        reward = jnp.where(done, jnp.where(caught, 1.0, -1.0), 0.0)
        discount = jnp.where(done, 0.0, self.discount)

        moved = CatchState(ball_y=ball_y, ball_x=s.ball_x, paddle_x=paddle_x,
                           rng=s.rng)
        fresh = self._spawn(s.rng)
        fresh = fresh._replace(paddle_x=paddle_x)
        new_state = jax.tree.map(
            lambda a, b: jnp.where(done, a, b), fresh, moved
        )
        ts = TimeStep(
            obs=self.observe(new_state),
            reward=reward.astype(jnp.float32),
            discount=discount.astype(jnp.float32),
            first=done,
        )
        return new_state, ts
