"""Host-side (CPU, numpy) environment for Sebulba — the "arbitrary
environment that cannot be compiled to TPU" of the paper (their Atari).

``HostPong`` is a minimal Pong-like arcade game: a ball bounces around an
(H x W) board, the agent moves a paddle on the bottom row; an episode is a
rally of ``max_lives`` balls.  Observations are (H, W, 1) float32 frames.
Deliberately implemented with numpy state mutation + a dm_env-style step
API, so it exercises exactly the host<->device pipeline Sebulba exists for.

Ball spawns come from the counter-based ``spawn_ball`` stream shared with
the device twin (repro/envs/pong.py) — ``jax.random`` draws are
deterministic and identical whether evaluated eagerly here or traced on
the device, which is what makes the twins bit-exact under the parity
suite (tests/test_device_envs.py).  The terminal miss keeps the board
exactly as the agent saw it die: the ``done=True`` frame shows the missed
ball at the bottom row, and the respawn draw happens in ``reset()``.
"""

from __future__ import annotations

import numpy as np

import jax

from repro.envs.pong import spawn_ball


class HostPong:
    num_actions = 3  # left / stay / right

    def __init__(self, height: int = 16, width: int = 16, max_lives: int = 3,
                 seed: int = 0):
        self.h = height
        self.w = width
        self.max_lives = max_lives
        self.obs_shape = (height, width, 1)
        self._key = jax.random.key(seed)
        self._spawn_n = 0
        self._reset_ball()
        self.paddle = self.w // 2
        self.lives = self.max_lives
        self.needs_reset = False

    def _reset_ball(self) -> None:
        ball_x, vx = spawn_ball(self._key, self._spawn_n, self.w)
        self._spawn_n += 1
        self.ball_y = 0.0
        self.ball_x = float(ball_x)
        self.vy = 1.0
        self.vx = float(vx)

    def reset(self) -> np.ndarray:
        self._reset_ball()
        self.paddle = self.w // 2
        self.lives = self.max_lives
        self.needs_reset = False
        return self._observe()

    def _observe(self) -> np.ndarray:
        obs = np.zeros(self.obs_shape, np.float32)
        y = int(np.clip(round(self.ball_y), 0, self.h - 1))
        x = int(np.clip(round(self.ball_x), 0, self.w - 1))
        obs[y, x, 0] = 1.0
        obs[self.h - 1, self.paddle, 0] = 1.0
        return obs

    def step(self, action: int):
        """-> (obs, reward, done, info).  Auto-requires reset() after done."""
        assert not self.needs_reset, "episode ended; call reset()"
        self.paddle = int(np.clip(self.paddle + (action - 1), 0, self.w - 1))
        self.ball_y += self.vy
        self.ball_x += self.vx
        if self.ball_x <= 0 or self.ball_x >= self.w - 1:
            self.vx = -self.vx
            self.ball_x = float(np.clip(self.ball_x, 0, self.w - 1))
        reward = 0.0
        if self.ball_y >= self.h - 1:
            if abs(self.ball_x - self.paddle) <= 1:
                reward = 1.0
                self.vy = -1.0
                self.ball_y = float(self.h - 2)
            else:
                reward = -1.0
                self.lives -= 1
                if self.lives > 0:
                    # mid-episode miss: respawn the ball.  The terminal
                    # miss keeps the board intact so the done frame shows
                    # the miss; reset() draws the next spawn.
                    self._reset_ball()
        elif self.ball_y <= 0:
            self.vy = 1.0
        done = self.lives <= 0
        if done:
            self.needs_reset = True
        return self._observe(), reward, done, {}
