"""GridWorld — procedurally-placed goal navigation, pure JAX.

The agent walks an N x N grid (4 actions); +1 and episode end at the goal,
small step penalty otherwise, timeout after ``horizon`` steps.  Stands in
for the "rich set of JAX environments" regime of Oh et al. (2021) that
Anakin was built to drive.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.envs.types import TimeStep


class GridState(NamedTuple):
    pos: jax.Array  # (2,) int32
    goal: jax.Array  # (2,) int32
    t: jax.Array  # steps so far
    rng: jax.Array


_MOVES = jnp.array([[-1, 0], [1, 0], [0, -1], [0, 1]], jnp.int32)


class GridWorld:
    def __init__(self, size: int = 7, horizon: int = 50):
        self.size = size
        self.horizon = horizon
        self.num_actions = 4
        self.obs_shape = (size, size, 2)
        self.discount = 0.99

    def _spawn(self, rng: jax.Array) -> GridState:
        rng, k1, k2 = jax.random.split(rng, 3)
        pos = jax.random.randint(k1, (2,), 0, self.size)
        goal = jax.random.randint(k2, (2,), 0, self.size)
        # nudge goal off the agent deterministically if they collide
        goal = jnp.where(
            jnp.all(goal == pos), (goal + 1) % self.size, goal
        )
        return GridState(pos=pos, goal=goal, t=jnp.int32(0), rng=rng)

    def init(self, rng: jax.Array) -> GridState:
        return self._spawn(rng)

    def observe(self, s: GridState) -> jax.Array:
        obs = jnp.zeros(self.obs_shape, jnp.float32)
        obs = obs.at[s.pos[0], s.pos[1], 0].set(1.0)
        obs = obs.at[s.goal[0], s.goal[1], 1].set(1.0)
        return obs

    def step(self, s: GridState, action: jax.Array) -> tuple[GridState, TimeStep]:
        pos = jnp.clip(s.pos + _MOVES[action], 0, self.size - 1)
        t = s.t + 1
        reached = jnp.all(pos == s.goal)
        timeout = t >= self.horizon
        done = reached | timeout
        reward = jnp.where(reached, 1.0, -0.01)
        discount = jnp.where(done, 0.0, self.discount)

        moved = GridState(pos=pos, goal=s.goal, t=t, rng=s.rng)
        fresh = self._spawn(s.rng)
        new_state = jax.tree.map(lambda a, b: jnp.where(done, a, b), fresh, moved)
        ts = TimeStep(
            obs=self.observe(new_state),
            reward=reward.astype(jnp.float32),
            discount=discount.astype(jnp.float32),
            first=done,
        )
        return new_state, ts
