from repro.envs.catch import Catch  # noqa: F401
from repro.envs.gridworld import GridWorld  # noqa: F401
from repro.envs.pong import Pong, spawn_ball  # noqa: F401
from repro.envs.host_env import HostPong  # noqa: F401
from repro.envs.batched_env import BatchedHostEnv  # noqa: F401
from repro.envs.bandit import Bandit, HostBandit  # noqa: F401
from repro.envs.device_env import (  # noqa: F401
    DeviceEnvFleet,
    FleetStats,
    HostDeviceEnv,
)
from repro.envs.token_env import TokenEnv  # noqa: F401
from repro.envs.types import TimeStep  # noqa: F401
